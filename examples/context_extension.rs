//! Context-capacity extension demo — the paper's title claim.
//!
//! A multi-turn dialogue re-submits its growing transcript every turn.
//! Without recycling, turn N re-encodes the whole transcript (O(N²) total
//! prefill work over a conversation); with recycling, each turn re-encodes
//! only the new text, so the *same compute budget* sustains a much longer
//! conversation inside the fixed context window — "expanding usable
//! context capacity".
//!
//! ```bash
//! make artifacts && cargo run --release --example context_extension
//! ```

use std::path::PathBuf;

use recycle_serve::bench::{session_workload, Table};
use recycle_serve::config::{CacheConfig, ServerConfig};
use recycle_serve::coordinator::Coordinator;
use recycle_serve::engine::Engine;
use recycle_serve::index::NgramEmbedder;
use recycle_serve::recycler::{RecyclePolicy, Recycler};
use recycle_serve::runtime::Runtime;

type Result<T> = std::result::Result<T, Box<dyn std::error::Error>>;

fn run_conversation(
    artifacts: PathBuf,
    policy: RecyclePolicy,
    turns: &[String],
    max_new: usize,
) -> Result<(Table, u64, f64)> {
    let coordinator = Coordinator::spawn(
        move |_worker| {
            let rt = Runtime::load(&artifacts).expect("artifacts");
            let tok = rt.tokenizer();
            Recycler::new(
                Engine::new(rt),
                tok,
                Box::new(NgramEmbedder::new(128)),
                CacheConfig::default(),
                policy,
            )
        },
        ServerConfig::default(),
    );
    let mut table = Table::new(&["turn", "prompt toks", "reused", "prefilled", "latency s"]);
    let mut total_latency = 0.0;
    for (i, msg) in turns.iter().enumerate() {
        let out = coordinator.chat("demo", msg, max_new)?;
        table.row(vec![
            (i + 1).to_string(),
            out.prompt_tokens.to_string(),
            out.reuse_depth.to_string(),
            (out.prompt_tokens - out.reuse_depth).to_string(),
            format!("{:.4}", out.latency_s),
        ]);
        total_latency += out.latency_s;
    }
    let prefilled = coordinator.stats().engine.tokens_prefilled;
    coordinator.shutdown();
    Ok((table, prefilled, total_latency))
}

fn main() -> Result<()> {
    let artifacts = PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".into()),
    );
    if !artifacts.join("manifest.json").exists() {
        return Err("run `make artifacts` first".into());
    }
    let turns = session_workload(5, 7);
    let max_new = 12;

    println!("=== multi-turn conversation, recycling OFF ===\n");
    let (t_off, prefilled_off, lat_off) =
        run_conversation(artifacts.clone(), RecyclePolicy::Off, &turns, max_new)?;
    println!("{}", t_off.render());

    println!("=== same conversation, recycling ON (strict) ===\n");
    let (t_on, prefilled_on, lat_on) =
        run_conversation(artifacts.clone(), RecyclePolicy::Strict, &turns, max_new)?;
    println!("{}", t_on.render());

    println!("total prompt tokens prefilled (encode work):");
    println!("  recycling OFF: {prefilled_off}");
    println!(
        "  recycling ON : {prefilled_on}  ({:.1}% of baseline)",
        100.0 * prefilled_on as f64 / prefilled_off.max(1) as f64
    );
    println!(
        "total latency: OFF {lat_off:.3}s -> ON {lat_on:.3}s ({:.1}% faster)",
        (lat_off - lat_on) / lat_off * 100.0
    );
    println!(
        "\nInterpretation: the encode budget saved per turn is capacity the\n\
         fixed context window can spend on *new* dialogue instead of\n\
         re-encoding history — the paper's 'expanded usable context'."
    );
    Ok(())
}
