//! END-TO-END VALIDATION DRIVER (EXPERIMENTS.md §E2E).
//!
//! Loads the trained nano model through the full production stack —
//! PJRT runtime → engine → recycler → coordinator → TCP server — then
//! drives a batched request stream over real sockets and reports
//! latency/throughput with recycling on vs off, plus per-tenant
//! first-token latency over the streaming front.
//!
//! ```bash
//! make artifacts && cargo run --release --example serving_demo
//! ```

use std::path::PathBuf;
use std::sync::Arc;

use recycle_serve::bench::{paper_cache_prompts, paper_test_prompts};
use recycle_serve::config::{CacheConfig, ServerConfig};
use recycle_serve::coordinator::Coordinator;
use recycle_serve::engine::Engine;
use recycle_serve::index::NgramEmbedder;
use recycle_serve::recycler::{RecyclePolicy, Recycler};
use recycle_serve::runtime::Runtime;
use recycle_serve::server::{Server, TcpClient};
use recycle_serve::util::timing::{Samples, Stopwatch};

type Result<T> = std::result::Result<T, Box<dyn std::error::Error>>;

fn spawn_stack(artifacts: PathBuf, policy: RecyclePolicy) -> Result<(Arc<Coordinator>, Server)> {
    let coordinator = Arc::new(Coordinator::spawn(
        move |_worker| {
            let rt = Runtime::load(&artifacts).expect("artifacts");
            let tok = rt.tokenizer();
            let mut r = Recycler::new(
                Engine::new(rt),
                tok,
                Box::new(NgramEmbedder::new(128)),
                CacheConfig::default(),
                policy,
            );
            r.populate_cache = true;
            r
        },
        ServerConfig {
            max_batch: 4,
            ..Default::default()
        },
    ));
    let server = Server::start(Arc::clone(&coordinator), "127.0.0.1:0")?;
    Ok((coordinator, server))
}

fn drive(
    server_addr: std::net::SocketAddr,
    prompts: &[String],
    max_new: usize,
) -> Result<(Samples, usize, usize)> {
    let mut client = TcpClient::connect(server_addr)?;
    let mut lat = Samples::new();
    let mut hits = 0;
    let mut reused = 0;
    for p in prompts {
        let resp = client.request(p, max_new, None)?;
        if resp.get("ok").and_then(|v| v.as_bool()) != Some(true) {
            return Err(format!("request failed: {}", resp.to_json()).into());
        }
        lat.push(resp.get("latency_s").and_then(|v| v.as_f64()).unwrap_or(0.0));
        if resp.get("cache_hit").and_then(|v| v.as_bool()) == Some(true) {
            hits += 1;
            reused += resp
                .get("reuse_depth")
                .and_then(|v| v.as_usize())
                .unwrap_or(0);
        }
    }
    Ok((lat, hits, reused))
}

fn main() -> Result<()> {
    let artifacts = PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".into()),
    );
    if !artifacts.join("manifest.json").exists() {
        return Err("run `make artifacts` first".into());
    }
    let data = PathBuf::from("data");
    let max_new = 24;

    // The request stream: the paper's 6 test prompts, repeated in 3 waves
    // (wave 2+ also benefits from online cache population).
    let mut stream: Vec<String> = Vec::new();
    for _ in 0..3 {
        stream.extend(paper_test_prompts(&data));
    }

    println!("=== serving_demo: end-to-end over TCP (trained nano model) ===\n");

    // --- arm 1: recycling OFF ---
    let (c_off, s_off) = spawn_stack(artifacts.clone(), RecyclePolicy::Off)?;
    {
        // warmup ping: absorbs the worker's Runtime::load (HLO compile)
        // so wallclock timing measures serving, not startup
        let mut ping = TcpClient::connect(s_off.addr())?;
        ping.request("warmup", 1, None)?;
    }
    let sw = Stopwatch::start();
    let (lat_off, _, _) = drive(s_off.addr(), &stream, max_new)?;
    let wall_off = sw.elapsed_secs();
    let stats_off = c_off.stats();
    s_off.stop();

    // --- arm 2: recycling ON (strict), warmed with the cache prompts ---
    let (c_on, s_on) = spawn_stack(artifacts.clone(), RecyclePolicy::Strict)?;
    {
        // warm via the same public interface: serve the cache prompts once
        let mut warm_client = TcpClient::connect(s_on.addr())?;
        for p in paper_cache_prompts(&data) {
            warm_client.request(&p, 1, None)?;
        }
    }
    let sw = Stopwatch::start();
    let (lat_on, hits, reused) = drive(s_on.addr(), &stream, max_new)?;
    let wall_on = sw.elapsed_secs();
    let stats_on = c_on.stats();
    // Aggregate + per-worker breakdown over the wire (`{"cmd":"stats"}`),
    // fetched before stop() like any other client request.
    let cluster = TcpClient::connect(s_on.addr())?.stats()?;

    // --- streamed TTFT per tenant (the streaming front) ---
    // Two tenants replay the test prompts as streaming requests against
    // the warmed stack; the client-visible first-token latency is what
    // streaming buys an interactive caller versus waiting for the full
    // reply, and the tenant label exercises the per-tenant QoS ledger.
    let demo_prompts = paper_test_prompts(&data);
    let mut ttft_report: Vec<(&str, f64, f64, usize)> = Vec::new();
    for tenant in ["gold", "bronze"] {
        let mut client = TcpClient::connect(s_on.addr())?;
        let mut ttft_ms = Samples::new();
        let mut full_ms = Samples::new();
        let mut streamed = 0usize;
        for p in &demo_prompts {
            let sw = Stopwatch::start();
            let rep = client.generate_streaming(p, max_new, None, Some(tenant))?;
            full_ms.push(sw.elapsed_secs() * 1e3);
            if !rep.is_ok() {
                return Err(format!("stream failed: {}", rep.done.to_json()).into());
            }
            if let Some(t) = rep.ttft {
                ttft_ms.push(t.as_secs_f64() * 1e3);
            }
            streamed += rep.tokens.len();
        }
        ttft_report.push((tenant, ttft_ms.mean(), full_ms.mean(), streamed));
    }
    s_on.stop();

    // --- report ---
    let n = stream.len();
    println!("requests per arm      : {n}");
    println!("generated per request : {max_new} tokens (greedy)\n");
    println!("                         recycling OFF   recycling ON");
    println!(
        "mean latency           : {:>9.4}s      {:>9.4}s",
        lat_off.mean(),
        lat_on.mean()
    );
    println!(
        "p95 latency            : {:>9.4}s      {:>9.4}s",
        lat_off.percentile(95.0),
        lat_on.percentile(95.0)
    );
    println!(
        "throughput             : {:>9.2} req/s {:>9.2} req/s",
        n as f64 / wall_off,
        n as f64 / wall_on
    );
    println!(
        "cache hits             : {:>9}       {:>9}",
        0, hits
    );
    println!("tokens reused          : {:>9}       {:>9}", 0, reused);
    println!(
        "engine tokens prefilled: {:>9}       {:>9}",
        stats_off.engine.tokens_prefilled, stats_on.engine.tokens_prefilled
    );
    // continuous-batching scheduler health: occupancy > 1 means decode
    // steps were genuinely shared across concurrent requests
    println!(
        "decode batch occupancy : {:>9.2}       {:>9.2}  (peak {} / {})",
        stats_off.scheduler.avg_occupancy(),
        stats_on.scheduler.avg_occupancy(),
        stats_off.scheduler.peak_occupancy,
        stats_on.scheduler.peak_occupancy
    );
    println!(
        "mean queue wait        : {:>7.1}ms       {:>7.1}ms",
        stats_off.scheduler.avg_queue_wait_ms(),
        stats_on.scheduler.avg_queue_wait_ms()
    );
    // capacity-multiplier meters: physical vs logical cold-tier bytes
    // (their ratio is the spill-compression win) and quantized residents
    println!(
        "cold bytes phys/logic  : {:>4}/{:<9} {:>4}/{:<9}",
        stats_off.cache.cold_bytes_physical,
        stats_off.cache.cold_bytes_logical,
        stats_on.cache.cold_bytes_physical,
        stats_on.cache.cold_bytes_logical
    );
    println!(
        "quantized blocks/bytes : {:>4}/{:<9} {:>4}/{:<9}",
        stats_off.cache.quantized_blocks,
        stats_off.cache.quantized_bytes,
        stats_on.cache.quantized_blocks,
        stats_on.cache.quantized_bytes
    );
    let speedup = (lat_off.mean() - lat_on.mean()) / lat_off.mean() * 100.0;
    println!("\nmean-latency speedup   : {speedup:.1}%");
    println!(
        "hit rate               : {}/{} ({:.0}%)",
        hits,
        n,
        100.0 * hits as f64 / n as f64
    );
    println!(
        "\nstreamed TTFT per tenant ({} prompts each, recycling ON):",
        demo_prompts.len()
    );
    for (tenant, ttft, full, tokens) in &ttft_report {
        println!(
            "  {tenant:<8} mean TTFT {ttft:>7.1}ms   full reply {full:>7.1}ms   ({tokens} tokens)"
        );
    }
    println!("\ncluster stats (the `{{\"cmd\":\"stats\"}}` wire reply, recycling ON):");
    println!("{}", cluster.to_json());
    // degraded-mode health: a misconfigured spill_dir silently costs hit
    // rate, so surface it where the numbers are read
    for warning in stats_on.health_warnings() {
        println!("\nWARNING (degraded mode): {warning}");
    }
    Ok(())
}
