//! Quickstart: load the AOT artifacts, serve one baseline generation and
//! one recycled generation, and print the speedup.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use recycle_serve::config::CacheConfig;
use recycle_serve::engine::Engine;
use recycle_serve::index::NgramEmbedder;
use recycle_serve::recycler::{RecyclePolicy, Recycler};
use recycle_serve::runtime::Runtime;

type Result<T> = std::result::Result<T, Box<dyn std::error::Error>>;

fn main() -> Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let rt = Runtime::load(&artifacts)
        .map_err(|e| format!("run `make artifacts` first (looked in {artifacts}): {e}"))?;
    let tokenizer = rt.tokenizer();
    println!(
        "loaded model '{}' ({} layers, context {})",
        rt.config().name,
        rt.config().n_layer,
        rt.config().max_seq
    );

    let mut recycler = Recycler::new(
        Engine::new(rt),
        Arc::clone(&tokenizer),
        Box::new(NgramEmbedder::new(128)),
        CacheConfig::default(),
        RecyclePolicy::Strict,
    );

    // 1. Build the cache from one prompt (the paper's cache-construction pass).
    let cache_prompt = "User: What is the capital of France?\nBot:";
    recycler.warm(&[cache_prompt])?;
    println!("\ncached: {cache_prompt:?}");

    // 2. A test prompt extending the cached one: baseline vs recycled.
    let test_prompt = "User: What is the capital of France?\nBot: The capital";

    recycler.policy = RecyclePolicy::Off;
    let baseline = recycler.generate(test_prompt, 24)?;
    recycler.policy = RecyclePolicy::Strict;
    let recycled = recycler.generate(test_prompt, 24)?;

    println!("\nbaseline  ({:.4}s): {:?}", baseline.latency_s, baseline.text);
    println!(
        "recycled  ({:.4}s): {:?}  [reused {} of {} prompt tokens]",
        recycled.latency_s, recycled.text, recycled.reuse_depth, recycled.prompt_tokens
    );
    assert_eq!(baseline.ids, recycled.ids, "fidelity: outputs must be identical");
    let speedup = (baseline.latency_s - recycled.latency_s) / baseline.latency_s * 100.0;
    println!("\nspeedup: {speedup:.1}%  (outputs token-identical ✓)");
    Ok(())
}
