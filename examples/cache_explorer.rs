//! Cache explorer: build the paper's activation cache, inspect entries,
//! exercise retrieval/prefix decisions, persistence, and eviction.
//!
//! ```bash
//! make artifacts && cargo run --release --example cache_explorer
//! ```

use std::path::PathBuf;

use recycle_serve::bench::{paper_cache_prompts, paper_test_prompts, Table};
use recycle_serve::config::CacheConfig;
use recycle_serve::engine::Engine;
use recycle_serve::index::NgramEmbedder;
use recycle_serve::kvcache::persist;
use recycle_serve::prefix::reuse_depth;
use recycle_serve::recycler::{RecyclePolicy, Recycler};
use recycle_serve::runtime::Runtime;

type Result<T> = std::result::Result<T, Box<dyn std::error::Error>>;

fn main() -> Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let rt = Runtime::load(&artifacts)
        .map_err(|e| format!("run `make artifacts` first: {e}"))?;
    let tokenizer = rt.tokenizer();
    let cfg = rt.config().clone();
    let data = PathBuf::from("data");

    let mut recycler = Recycler::new(
        Engine::new(rt),
        tokenizer.clone(),
        Box::new(NgramEmbedder::new(128)),
        CacheConfig::default(),
        RecyclePolicy::Strict,
    );

    // --- build the cache (paper §4.4 cache construction) ---
    let cache_prompts = paper_cache_prompts(&data);
    let refs: Vec<&str> = cache_prompts.iter().map(|s| s.as_str()).collect();
    recycler.warm(&refs)?;

    println!("=== cache contents ({} entries) ===\n", recycler.cache_len());
    let mut t = Table::new(&["id", "tokens", "kv KiB", "text"]);
    let mut entries: Vec<_> = recycler.store().iter()
        .map(|(id, r)| (id, r.token_len(), r.kv_bytes(), r.text.clone()))
        .collect();
    entries.sort();
    for (id, toks, bytes, text) in &entries {
        t.row(vec![
            id.to_string(),
            toks.to_string(),
            format!("{:.1}", *bytes as f64 / 1024.0),
            text.chars().take(48).collect(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "total cache footprint: {:.1} KiB (full window would be {:.1} KiB/entry)\n",
        recycler.store().live_bytes() as f64 / 1024.0,
        cfg.kv_bytes() as f64 / 1024.0
    );

    // --- retrieval decisions for the test prompts ---
    println!("=== retrieval + prefix test per test prompt ===\n");
    let mut t = Table::new(&["test prompt", "r (depth)", "full prefix?", "decision"]);
    for p in paper_test_prompts(&data) {
        let ids = tokenizer.encode(&p);
        // best candidate by token overlap (mirror of what strict retrieval
        // finds via embeddings on this workload)
        let mut best = (0usize, false, String::new());
        for (_, rec) in recycler.store().iter() {
            let (r, full) = reuse_depth(&rec.tokens, &ids);
            if r > best.0 {
                best = (r, full, rec.text.clone());
            }
        }
        t.row(vec![
            p.chars().take(44).collect(),
            best.0.to_string(),
            best.1.to_string(),
            if best.1 { "RECYCLE".into() } else { "baseline".to_string() },
        ]);
    }
    println!("{}", t.render());

    // --- persistence roundtrip ---
    let dir = std::env::temp_dir().join("recycle_serve_cache_explorer");
    std::fs::create_dir_all(&dir)?;
    let (id, rec) = {
        let (id, r) = recycler.store().iter().next().map(|(i, r)| (i, r.clone())).unwrap();
        (id, r)
    };
    let plain = persist::to_bytes(&rec, false);
    let packed = persist::to_bytes(&rec, true);
    println!("=== persistence (entry {id}) ===\n");
    println!("raw payload        : {:>8} bytes", plain.len());
    println!(
        "deflate payload    : {:>8} bytes ({:.1}% of raw)",
        packed.len(),
        100.0 * packed.len() as f64 / plain.len() as f64
    );
    let path = dir.join("entry.kv");
    persist::save(&rec, &path, true)?;
    let loaded = persist::load(&path, recycler.arena())?;
    println!(
        "roundtrip          : ok ({} tokens, {} arena blocks, crc verified)\n",
        loaded.token_len(),
        loaded.kv_blocks()
    );
    std::fs::remove_dir_all(&dir).ok();

    // --- eviction under pressure ---
    println!("=== eviction: shrink cache to 4 entries (LRU) ===\n");
    let rt2 = Runtime::load(&artifacts)?;
    let tok2 = rt2.tokenizer();
    let mut small = Recycler::new(
        Engine::new(rt2),
        tok2,
        Box::new(NgramEmbedder::new(128)),
        CacheConfig {
            max_entries: 4,
            ..Default::default()
        },
        RecyclePolicy::Strict,
    );
    small.warm(&refs)?;
    println!(
        "inserted {} prompts into a 4-entry store -> {} live, {} evictions",
        refs.len(),
        small.store().len(),
        small.store().stats().evictions
    );
    Ok(())
}
