//! Regenerate the paper's §5 results on the real model:
//! the §5.1 summary table plus the per-prompt series behind the three
//! figures. Writes results/baseline.csv and results/recycled.csv exactly
//! like the paper's notebook.
//!
//! ```bash
//! make artifacts && cargo run --release --example paper_eval
//! ```

use std::path::PathBuf;

use recycle_serve::bench::{format_row_series, format_table, paper_cache_prompts,
                           paper_test_prompts, run_comparison, EvalOptions, Workload};
use recycle_serve::runtime::Runtime;

type Result<T> = std::result::Result<T, Box<dyn std::error::Error>>;

fn main() -> Result<()> {
    let artifacts = PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".into()),
    );
    let data = PathBuf::from("data");
    let results = PathBuf::from("results");
    std::fs::create_dir_all(&results)?;

    let rt0 = Runtime::load(&artifacts)
        .map_err(|e| format!("run `make artifacts` first: {e}"))?;
    let tokenizer = rt0.tokenizer();
    drop(rt0);

    let workload = Workload {
        cache_prompts: paper_cache_prompts(&data),
        test_prompts: paper_test_prompts(&data),
    };
    let opts = EvalOptions {
        max_new_tokens: 32,
        results_dir: Some(results.clone()),
        ..Default::default()
    };
    let report = run_comparison(
        || Runtime::load(&artifacts).expect("reload artifacts"),
        tokenizer,
        &workload,
        &opts,
    )?;

    // §5.1 summary table
    println!("{}", format_table("Paper §5.1 summary (measured)", &report.summary_rows()));

    // §5.2 latency figure series
    let lat: Vec<(f64, f64)> = report
        .baseline_rows
        .iter()
        .zip(&report.recycled_rows)
        .enumerate()
        .map(|(i, (b, _r))| (i as f64, b.latency_s))
        .collect();
    let lat_rec: Vec<(f64, f64)> = report
        .recycled_rows
        .iter()
        .enumerate()
        .map(|(i, r)| (i as f64, r.latency_s))
        .collect();
    println!("{}", format_row_series("fig §5.2 baseline latency (prompt idx, s)", &lat));
    println!("{}", format_row_series("fig §5.2 recycled latency (prompt idx, s)", &lat_rec));

    // §5.4 output-similarity figure series
    let sim: Vec<(f64, f64)> = report
        .comparison
        .output_similarity
        .iter()
        .enumerate()
        .map(|(i, s)| (i as f64, *s))
        .collect();
    println!("{}", format_row_series("fig §5.4 output similarity (prompt idx, cos)", &sim));

    // §5.5 speedup-vs-depth series + alpha
    let sd: Vec<(f64, f64)> = report
        .speedup_samples
        .iter()
        .map(|&(k, m, s)| (k as f64 / m as f64, s))
        .collect();
    println!("{}", format_row_series("fig §5.5 speedup vs k/m (ratio, fraction)", &sd));
    println!("alpha fit (paper: 1.2-1.5 on a T4): {:.3}", report.alpha);
    println!("\nresults written to {}", results.display());
    Ok(())
}
