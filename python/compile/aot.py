"""AOT pipeline: corpus -> tokenizer -> trained weights -> HLO text artifacts.

Python's ONLY role in the system: this script runs once under
`make artifacts` and emits everything the Rust runtime needs. Nothing here
is ever imported on the request path.

Interchange format is HLO *text*, not `.serialize()`: jax >= 0.5 writes
HloModuleProto with 64-bit instruction ids, which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (artifacts/):
  forward_c{C}.hlo.txt  one per chunk bucket C in cfg.chunk_sizes
  embed.hlo.txt         sentence-embedding encoder
  weights.bin           flat little-endian f32 tensors (order = param_spec)
  embed_weights.bin     same for the embed encoder
  manifest.json         config + tensor table + artifact names
  tokenizer.json        byte-level BPE merges
  fixtures.json         cross-language goldens (tokenizer, forward, greedy,
                        recycling equivalence, embedding)
  train_log.csv         step,loss curve from the build-time training run
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus as corpus_mod
from .embedmodel import embed_forward, embed_param_spec, init_embed_params
from .model import (ModelConfig, PRESETS, empty_kv, flatten_params,
                    forward_chunk, greedy_generate, init_params, param_spec,
                    unflatten_params)
from .tokenizer import Tokenizer, train_bpe
from .train import train


def to_hlo_text(lowered) -> str:
    """jax lowered -> stablehlo -> XlaComputation -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def make_forward_fn(cfg: ModelConfig):
    """Forward wrapper lowered per bucket.

    Returns (logits [C, V], new_kv_rows [L, 2, H, C, D]) — only the chunk's
    freshly-written KV rows, NOT the whole buffer: the Rust engine keeps the
    authoritative host-side KV buffer and splices these rows in at cur_len,
    halving device<->host traffic per step (see runtime/executor.rs).
    """
    n = len(param_spec(cfg))

    def fn(*args):
        flat = args[:n]
        tokens, valid_len, kv, cur_len = args[n:]
        params = unflatten_params(cfg, flat)
        c = tokens.shape[0]
        logits, kv2 = forward_chunk(cfg, params, tokens, valid_len, kv, cur_len,
                                    use_pallas=True)
        rows = jax.lax.dynamic_slice(
            kv2, (0, 0, 0, cur_len, 0),
            (cfg.n_layer, 2, cfg.n_head, c, cfg.head_dim))
        return logits, rows

    return fn


def lower_forward(cfg: ModelConfig, c: int, seq: int | None = None) -> str:
    """Lower one (chunk, seq-capacity) bucket. `seq` defaults to max_seq.

    The seq-bucketed variants run the same computation against a truncated
    KV buffer [L, 2, H, seq, D]: when the live context fits in a smaller
    bucket the runtime uploads (and the attention kernel scans) only `seq`
    rows — the §Perf optimization for short contexts.
    """
    seq = seq or cfg.max_seq
    f32, i32 = jnp.float32, jnp.int32
    kv_shape = (cfg.n_layer, 2, cfg.n_head, seq, cfg.head_dim)
    specs = [jax.ShapeDtypeStruct(s, f32) for _, s in param_spec(cfg)]
    specs += [
        jax.ShapeDtypeStruct((c,), i32),        # tokens
        jax.ShapeDtypeStruct((), i32),          # valid_len
        jax.ShapeDtypeStruct(kv_shape, f32),    # kv (seq-bucketed)
        jax.ShapeDtypeStruct((), i32),          # cur_len
    ]
    lowered = jax.jit(make_forward_fn(cfg), keep_unused=True).lower(*specs)
    return to_hlo_text(lowered)


def lower_embed(cfg: ModelConfig) -> str:
    f32, i32 = jnp.float32, jnp.int32
    n = len(embed_param_spec(cfg))

    def fn(*args):
        eparams = {name: a for (name, _), a in zip(embed_param_spec(cfg), args[:n])}
        tokens, length = args[n:]
        return (embed_forward(cfg, eparams, tokens, length),)

    specs = [jax.ShapeDtypeStruct(s, f32) for _, s in embed_param_spec(cfg)]
    specs += [jax.ShapeDtypeStruct((cfg.embed_seq,), i32),
              jax.ShapeDtypeStruct((), i32)]
    return to_hlo_text(jax.jit(fn, keep_unused=True).lower(*specs))


def write_weights(path: str, arrays: list[np.ndarray],
                  spec: list[tuple[str, tuple[int, ...]]]) -> list[dict]:
    """Concatenate f32 little-endian tensors; return the manifest table."""
    table = []
    offset = 0
    with open(path, "wb") as f:
        for (name, shape), arr in zip(spec, arrays):
            a = np.ascontiguousarray(np.asarray(arr, dtype="<f4"))
            assert tuple(a.shape) == tuple(shape), (name, a.shape, shape)
            raw = a.tobytes()
            f.write(raw)
            table.append({"name": name, "shape": list(shape),
                          "offset": offset, "bytes": len(raw)})
            offset += len(raw)
    return table


def build_fixtures(cfg: ModelConfig, tok: Tokenizer, params, eparams) -> dict:
    """Cross-language goldens asserted by both pytest and cargo test."""
    texts = [
        "Hello world",
        "User: What is the capital of France?\nBot:",
        "Explain machine learning in simple terms.",
        "  leading spaces and\n\nnewlines\n",
        "punctuation, quotes \"x\" and unicode: café → あ",
        "",
        " ",
        "\n",
        "aaaaaaaaaaaaaaaaaaaaaaaa",
    ] + corpus_mod.CACHE_PROMPTS[:4] + corpus_mod.TEST_PROMPTS[:3]
    tok_cases = [{"text": t, "ids": tok.encode(t)} for t in texts]

    # Greedy generation golden (the Rust engine must reproduce these tokens).
    prompt = "User: What is the capital of France?\nBot:"
    pids = tok.encode(prompt)
    gen_ids, kv, plen = greedy_generate(cfg, params, pids, 16, eot_id=tok.eot_id,
                                        use_pallas=True)

    # Forward-logits golden: last-row logits after prefilling the prompt.
    kv0 = empty_kv(cfg)
    toks = jnp.asarray(pids + [0] * (64 - len(pids)), jnp.int32) if len(pids) <= 64 \
        else jnp.asarray(pids[:64], jnp.int32)
    logits, _ = forward_chunk(cfg, params, toks,
                              jnp.asarray(len(pids), jnp.int32), kv0,
                              jnp.asarray(0, jnp.int32), use_pallas=True)
    last = np.asarray(logits[len(pids) - 1])

    # Recycling-equivalence golden: cached prompt is an exact prefix of the
    # test prompt; recycled continuation must equal the from-scratch one.
    cache_text = corpus_mod.CACHE_PROMPTS[1]
    test_text = corpus_mod.TEST_PROMPTS[1]
    cids, tids = tok.encode(cache_text), tok.encode(test_text)
    depth = 0
    for a, b in zip(cids, tids):
        if a != b:
            break
        depth += 1
    assert depth == len(cids), "test prompt must extend its cache prompt"
    base_ids, _, _ = greedy_generate(cfg, params, tids, 12, eot_id=tok.eot_id,
                                     use_pallas=True)
    _, kvc, clen = greedy_generate(cfg, params, cids, 0, eot_id=tok.eot_id,
                                   use_pallas=True)
    rec_ids, _, _ = greedy_generate(cfg, params, tids, 12, kv=kvc, cur_len=clen,
                                    eot_id=tok.eot_id, use_pallas=True)
    assert rec_ids == base_ids, "recycled generation diverged from baseline"

    # Embedding golden.
    etoks = tok.encode(cache_text)[:cfg.embed_seq]
    epad = etoks + [0] * (cfg.embed_seq - len(etoks))
    evec = np.asarray(embed_forward(cfg, eparams, jnp.asarray(epad, jnp.int32),
                                    jnp.asarray(len(etoks), jnp.int32)))

    return {
        "tokenizer": tok_cases,
        "greedy": {
            "prompt": prompt,
            "prompt_ids": pids,
            "generated_ids": gen_ids,
            "generated_text": tok.decode(gen_ids),
            "final_len": plen,
        },
        "forward_logits": {
            "prompt_ids": pids,
            "chunk": int(toks.shape[0]),
            "last_row_first8": [float(x) for x in last[:8]],
            "last_row_argmax": int(np.argmax(last)),
            "last_row_sum": float(np.sum(last)),
        },
        "recycle": {
            "cache_text": cache_text,
            "test_text": test_text,
            "cache_ids": cids,
            "test_ids": tids,
            "reuse_depth": depth,
            "baseline_ids": base_ids,
            "recycled_ids": rec_ids,
        },
        "embed": {
            "text": cache_text,
            "first8": [float(x) for x in evec[:8]],
            "norm": float(np.linalg.norm(evec)),
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", default="nano", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=400,
                    help="build-time training steps (0 = random init)")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--data-dir", default="../data")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = PRESETS[args.model]
    os.makedirs(args.out_dir, exist_ok=True)
    t0 = time.time()

    # 1. Corpus + the paper's prompt files.
    text = corpus_mod.build_corpus(seed=args.seed)
    corpus_mod.write_prompt_files(args.data_dir)

    # 2. Tokenizer.
    tok = train_bpe(text, cfg.vocab_size)
    with open(os.path.join(args.out_dir, "tokenizer.json"), "w") as f:
        f.write(tok.to_json())
    print(f"tokenizer: {tok.vocab_size} tokens ({len(tok.merges)} merges)")

    # 3. Weights (trained unless --steps 0). The stream interleaves
    # exchanges with <|endoftext|> so the model learns to stop after an
    # answer (DialoGPT-style EOS), which is what gives the paper its
    # short-generation latency profile.
    stream_ids: list[int] = []
    for ex in corpus_mod.corpus_exchanges(seed=args.seed):
        stream_ids.extend(tok.encode(ex))
        stream_ids.append(tok.eot_id)
    stream = np.asarray(stream_ids, np.int32)
    print(f"corpus: {len(text)} chars -> {len(stream)} tokens (incl. EOT)")
    if args.steps > 0:
        params, log = train(cfg, stream, steps=args.steps, seed=args.seed)
    else:
        params, log = init_params(cfg, jax.random.PRNGKey(args.seed)), []
    with open(os.path.join(args.out_dir, "train_log.csv"), "w") as f:
        f.write("step,loss\n")
        for s, l in log:
            f.write(f"{s},{l:.6f}\n")
    eparams = init_embed_params(cfg, jax.random.PRNGKey(args.seed + 1))

    # 4. Weights files.
    flat = [np.asarray(a) for a in flatten_params(cfg, params)]
    table = write_weights(os.path.join(args.out_dir, "weights.bin"), flat,
                          param_spec(cfg))
    eflat = [np.asarray(eparams[name]) for name, _ in embed_param_spec(cfg)]
    etable = write_weights(os.path.join(args.out_dir, "embed_weights.bin"),
                           eflat, embed_param_spec(cfg))

    # 5. HLO artifacts: one per (chunk, seq-capacity) bucket.
    artifacts = {}
    for c in cfg.chunk_sizes:
        for s in cfg.seq_buckets:
            if c > s:
                continue  # chunk cannot exceed the KV capacity
            name = f"forward_c{c}_s{s}.hlo.txt"
            hlo = lower_forward(cfg, c, s)
            with open(os.path.join(args.out_dir, name), "w") as f:
                f.write(hlo)
            artifacts[f"forward_c{c}_s{s}"] = name
            print(f"lowered {name}: {len(hlo)} chars")
    ehlo = lower_embed(cfg)
    with open(os.path.join(args.out_dir, "embed.hlo.txt"), "w") as f:
        f.write(ehlo)
    artifacts["embed"] = "embed.hlo.txt"

    # 6. Fixtures.
    fixtures = build_fixtures(cfg, tok, params, eparams)
    with open(os.path.join(args.out_dir, "fixtures.json"), "w") as f:
        json.dump(fixtures, f)

    # 7. Manifest (the Rust runtime's single entry point).
    manifest = {
        "version": 1,
        "model": {
            "name": cfg.name, "n_layer": cfg.n_layer, "n_head": cfg.n_head,
            "d_model": cfg.d_model, "vocab_size": cfg.vocab_size,
            "max_seq": cfg.max_seq, "d_ff": cfg.d_ff,
            "head_dim": cfg.head_dim, "embed_dim": cfg.embed_dim,
            "embed_seq": cfg.embed_seq,
            "chunk_sizes": list(cfg.chunk_sizes),
            "seq_buckets": list(cfg.seq_buckets),
            "eot_id": tok.eot_id,
        },
        "tensors": table,
        "embed_tensors": etable,
        "artifacts": artifacts,
        "weights": "weights.bin",
        "embed_weights": "embed_weights.bin",
        "tokenizer": "tokenizer.json",
        "fixtures": "fixtures.json",
        "corpus_sha256": hashlib.sha256(text.encode()).hexdigest(),
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"artifacts written to {args.out_dir} in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
