"""Tiny sentence-embedding encoder (sentence-transformers substitute).

The paper indexes cached prompts with sentence-transformer embeddings and
retrieves by dot product. Offline we provide two interchangeable embedders:

  * Rust `index::ngram` — hashed character-n-gram embedding on the request
    path (default: deterministic, no model call).
  * This module — a small mean-pooled token encoder exported as
    `embed.hlo.txt`, demonstrating the "embedding model behind PJRT" path.

The encoder is *untrained* (fixed-seed init): retrieval quality in our
workloads comes from lexical overlap, which both embedders preserve. This is
recorded as a substitution in DESIGN.md §2.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .model import ModelConfig


def embed_param_spec(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    return [
        ("ewte", (cfg.vocab_size, cfg.embed_dim)),
        ("ewpe", (cfg.embed_seq, cfg.embed_dim)),
        ("ew", (cfg.embed_dim, cfg.embed_dim)),
    ]


def init_embed_params(cfg: ModelConfig, key: jax.Array) -> dict[str, jax.Array]:
    params = {}
    for name, shape in embed_param_spec(cfg):
        key, sub = jax.random.split(key)
        params[name] = 0.1 * jax.random.normal(sub, shape, jnp.float32)
    return params


def embed_forward(cfg: ModelConfig, params: dict[str, jax.Array],
                  tokens: jax.Array, length: jax.Array) -> jax.Array:
    """tokens: [E] int32 right-padded; length: scalar int32. Returns [De] unit vec."""
    e = cfg.embed_seq
    x = params["ewte"][tokens] + params["ewpe"][jnp.arange(e)]
    mask = (jnp.arange(e) < length)[:, None].astype(jnp.float32)
    pooled = jnp.sum(x * mask, axis=0) / jnp.maximum(length.astype(jnp.float32), 1.0)
    h = jnp.tanh(pooled @ params["ew"])
    return h / jnp.maximum(jnp.linalg.norm(h), 1e-6)
