"""Byte-level BPE tokenizer: trainer + encoder/decoder.

Build-time twin of `rust/src/tokenizer/` — the two implementations MUST agree
token-for-token (the Rust side runs on the request path; this side runs once
to train merges on the synthetic corpus and to emit cross-check fixtures).

Design points shared with the Rust port:
  * GPT-2 byte<->unicode table (every byte maps to a printable code point).
  * Pre-tokenization is a small hand-rolled scanner (NOT the GPT-2 regex) so
    both languages implement the exact same character-class logic:
      - a run of newlines is one piece;
      - a run of non-newline whitespace followed by a word is glued to the
        word (" hello" is one piece);
      - a trailing/isolated whitespace run is its own piece.
  * Merge ties break lexicographically, making training deterministic.
  * Vocabulary layout: specials, then the 256 byte symbols, then merges.
"""

from __future__ import annotations

import json

END_OF_TEXT = "<|endoftext|>"
SPECIALS = [END_OF_TEXT]


def bytes_to_unicode() -> dict[int, str]:
    """GPT-2's reversible byte -> printable-unicode map."""
    bs = list(range(ord("!"), ord("~") + 1)) + \
         list(range(ord("\xa1"), ord("\xac") + 1)) + \
         list(range(ord("\xae"), ord("\xff") + 1))
    cs = bs[:]
    n = 0
    for b in range(256):
        if b not in bs:
            bs.append(b)
            cs.append(256 + n)
            n += 1
    return dict(zip(bs, [chr(c) for c in cs]))


BYTE_TO_UNI = bytes_to_unicode()
UNI_TO_BYTE = {v: k for k, v in BYTE_TO_UNI.items()}


# Explicit space class shared with the Rust port (NOT str.isspace(), whose
# semantics differ between Python and Rust on exotic code points).
_SPACE = frozenset(" \t\r\x0b\x0c")


def _is_space(c: str) -> bool:
    return c in _SPACE


def pretokenize(text: str) -> list[str]:
    """Split text into BPE word pieces. Mirrors rust tokenizer::pretokenize."""
    pieces: list[str] = []
    i, n = 0, len(text)
    while i < n:
        c = text[i]
        if c == "\n":
            j = i
            while j < n and text[j] == "\n":
                j += 1
            pieces.append(text[i:j])
            i = j
        elif _is_space(c):
            j = i
            while j < n and _is_space(text[j]):
                j += 1
            if j < n and text[j] != "\n":
                k = j
                while k < n and not _is_space(text[k]) and text[k] != "\n":
                    k += 1
                pieces.append(text[i:k])
                i = k
            else:
                pieces.append(text[i:j])
                i = j
        else:
            j = i
            while j < n and not _is_space(text[j]) and text[j] != "\n":
                j += 1
            pieces.append(text[i:j])
            i = j
    return pieces


def _to_symbols(piece: str) -> tuple[str, ...]:
    return tuple(BYTE_TO_UNI[b] for b in piece.encode("utf-8"))


def train_bpe(text: str, vocab_size: int) -> "Tokenizer":
    """Train merges until `vocab_size` is reached or no pair repeats."""
    n_merges = vocab_size - 256 - len(SPECIALS)
    if n_merges < 0:
        raise ValueError("vocab_size too small for byte alphabet + specials")
    word_freq: dict[tuple[str, ...], int] = {}
    for piece in pretokenize(text):
        sym = _to_symbols(piece)
        word_freq[sym] = word_freq.get(sym, 0) + 1

    merges: list[tuple[str, str]] = []
    words = dict(word_freq)
    for _ in range(n_merges):
        pairs: dict[tuple[str, str], int] = {}
        for w, f in words.items():
            for a, b in zip(w, w[1:]):
                pairs[(a, b)] = pairs.get((a, b), 0) + f
        if not pairs:
            break
        # Highest count; ties broken by lexicographic order for determinism.
        best = min(pairs.items(), key=lambda kv: (-kv[1], kv[0]))[0]
        if pairs[best] < 2:
            break
        merges.append(best)
        merged = best[0] + best[1]
        new_words: dict[tuple[str, ...], int] = {}
        for w, f in words.items():
            out: list[str] = []
            i = 0
            while i < len(w):
                if i + 1 < len(w) and w[i] == best[0] and w[i + 1] == best[1]:
                    out.append(merged)
                    i += 2
                else:
                    out.append(w[i])
                    i += 1
            t = tuple(out)
            new_words[t] = new_words.get(t, 0) + f
        words = new_words
    return Tokenizer(merges)


class Tokenizer:
    """Byte-level BPE encoder/decoder over a fixed merge list."""

    def __init__(self, merges: list[tuple[str, str]]):
        self.merges = merges
        self.rank = {m: i for i, m in enumerate(merges)}
        vocab: list[str] = list(SPECIALS)
        vocab += [BYTE_TO_UNI[b] for b in range(256)]
        vocab += [a + b for a, b in merges]
        self.token_to_id = {t: i for i, t in enumerate(vocab)}
        self.id_to_token = vocab
        self._cache: dict[str, list[int]] = {}

    @property
    def vocab_size(self) -> int:
        return len(self.id_to_token)

    @property
    def eot_id(self) -> int:
        return self.token_to_id[END_OF_TEXT]

    def _bpe(self, piece: str) -> list[str]:
        word = [BYTE_TO_UNI[b] for b in piece.encode("utf-8")]
        while len(word) > 1:
            best_rank, best_i = None, -1
            for i in range(len(word) - 1):
                r = self.rank.get((word[i], word[i + 1]))
                if r is not None and (best_rank is None or r < best_rank):
                    best_rank, best_i = r, i
            if best_rank is None:
                break
            word[best_i:best_i + 2] = [word[best_i] + word[best_i + 1]]
        return word

    def encode(self, text: str) -> list[int]:
        ids: list[int] = []
        for piece in pretokenize(text):
            cached = self._cache.get(piece)
            if cached is None:
                cached = [self.token_to_id[t] for t in self._bpe(piece)]
                self._cache[piece] = cached
            ids.extend(cached)
        return ids

    def decode(self, ids: list[int]) -> str:
        out = bytearray()
        for i in ids:
            tok = self.id_to_token[i]
            if tok in SPECIALS:
                continue
            for ch in tok:
                out.append(UNI_TO_BYTE[ch])
        return out.decode("utf-8", errors="replace")

    def to_json(self) -> str:
        return json.dumps(
            {
                "specials": SPECIALS,
                "merges": [[a, b] for a, b in self.merges],
            },
            ensure_ascii=False,
        )

    @staticmethod
    def from_json(s: str) -> "Tokenizer":
        obj = json.loads(s)
        return Tokenizer([tuple(m) for m in obj["merges"]])
