"""Pallas retrieval kernel: dot-product similarity scores against a bank.

The paper retrieves the candidate cache entry with faiss-cpu over
sentence-transformer embeddings. Our bank is tiny (tens of entries), so the
exact algorithm is a dense matvec over L2-normalized embeddings; this kernel
is the TPU-shaped version (tiled over bank rows so the bank streams
HBM->VMEM while the query stays resident). Top-k selection happens outside
the kernel (jnp.argmax / Rust) — k is 1 in the paper.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scores_kernel(e_ref, q_ref, o_ref):
    o_ref[...] = e_ref[...] @ q_ref[...]


def similarity_scores(embeddings, query, *, block_n: int = 128, interpret: bool = True):
    """scores[i] = <embeddings[i], query>.

    Args:
      embeddings: [N, D] float32 (caller normalizes for cosine similarity).
      query: [D] float32.
      block_n: bank tile rows per program instance; N is padded up to a
        multiple internally.

    Returns: [N] float32 scores.
    """
    n, d = embeddings.shape
    n_pad = -(-n // block_n) * block_n
    if n_pad != n:
        embeddings = jnp.pad(embeddings, ((0, n_pad - n), (0, 0)))
    out = pl.pallas_call(
        _scores_kernel,
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        interpret=interpret,
    )(embeddings, query)
    return out[:n]
