"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its `ref_*` twin to float32 tolerance across the shape/dtype sweep
in python/tests/test_kernels.py. The oracles are written for clarity, not
speed; they also serve as the spec for the Rust-side golden fixtures.
"""

from __future__ import annotations

import jax.numpy as jnp

# Mask value used instead of -inf so that exp(m_prev - m_new) never sees a
# (-inf) - (-inf) NaN when an entire key block is masked.
NEG_INF = -1e30


def ref_cached_attention(q, k, v, cur_len, valid_len):
    """Causal attention of a chunk of new queries against a KV buffer.

    Args:
      q: [H, C, D] queries for the C new (possibly right-padded) tokens.
      k: [H, S, D] key buffer; rows [0, cur_len) hold the cached prefix and
         rows [cur_len, cur_len + valid_len) hold the new tokens' keys.
      v: [H, S, D] value buffer, same layout.
      cur_len: scalar int32, number of valid cached positions.
      valid_len: scalar int32, number of valid tokens in the chunk (<= C).
        Only used to document the garbage region; masking is causal.

    Returns:
      [H, C, D] attention outputs. Rows i >= valid_len are garbage-but-finite
      (they attend over the causal window as if real) and must be ignored by
      the caller.
    """
    del valid_len  # rows beyond valid_len are ignored downstream
    h, c, d = q.shape
    s = k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    scores = jnp.einsum("hcd,hsd->hcs", q, k) * scale  # [H, C, S]
    # Query i sits at absolute position cur_len + i; it may attend to any
    # key j with j <= cur_len + i.
    j = jnp.arange(s)[None, None, :]
    i = jnp.arange(c)[None, :, None]
    mask = j <= (cur_len + i)
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("hcs,hsd->hcd", p / l, v)


def ref_similarity_scores(embeddings, query):
    """Dot-product similarity of one query against a bank of embeddings.

    Args:
      embeddings: [N, D] (assumed L2-normalized by the caller).
      query: [D].

    Returns: [N] scores.
    """
    return embeddings @ query


def ref_layernorm(x, gamma, beta, eps=1e-5):
    """LayerNorm over the last axis: (x - mu) / sqrt(var + eps) * gamma + beta."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta
