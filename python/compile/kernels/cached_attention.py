"""Pallas flash-style cached attention — the L1 compute hot-spot.

Computes causal attention of a chunk of C new query tokens against a KV
buffer of capacity S whose first `cur_len` rows hold a previously-computed
(possibly *recycled*, i.e. loaded from the cross-prompt cache) prefix.

TPU mapping of the paper's idea (the paper ran CUDA via HF/torch; we rethink
for the MXU/VMEM model — see DESIGN.md §3):

  * grid = (heads, S / BK): one program instance per (head, key-block).
  * BlockSpec streams K/V HBM->VMEM one [BK, D] tile at a time; the C-row
    query tile stays resident in VMEM across all key blocks of a head.
  * online softmax (flash attention): running max `m`, denominator `l`, and
    unnormalized accumulator live in the output refs, which Pallas keeps in
    VMEM across sequential grid steps because their index map ignores the
    key-block axis (revisiting semantics).
  * masking is positional: key j is visible to chunk query i iff
    j <= cur_len + i — exactly the paper's "cached prompt is a full prefix"
    condition expressed at the kernel level.

interpret=True is mandatory here: real-TPU lowering emits a Mosaic
custom-call that the CPU PJRT plugin cannot execute. The kernel is still
*structured* for TPU (tile sizes, VMEM footprint) and those estimates are
what sim::roofline reports.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF


def _attn_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *, block_k: int):
    """One (head, key-block) step of online-softmax attention.

    Refs (leading head axis of size 1 comes from the BlockSpec):
      len_ref: [1] int32 — cur_len.
      q_ref:   [1, C, D] queries (resident across key blocks).
      k_ref:   [1, BK, D] this key block.
      v_ref:   [1, BK, D] this value block.
      o_ref:   [1, C, D] unnormalized accumulator; normalized in the epilogue.
      m_ref:   [1, C] running row max.
      l_ref:   [1, C] running row denominator.
    """
    kb = pl.program_id(1)
    nkb = pl.num_programs(1)
    cur_len = len_ref[0]

    q = q_ref[0]  # [C, D]
    k = k_ref[0]  # [BK, D]
    v = v_ref[0]  # [BK, D]
    c, d = q.shape

    @pl.when(kb == 0)
    def _init():
        m_ref[0] = jnp.full((c,), NEG_INF, jnp.float32)
        l_ref[0] = jnp.zeros((c,), jnp.float32)
        o_ref[0] = jnp.zeros((c, d), jnp.float32)

    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    s = (q @ k.T) * scale  # [C, BK] — MXU matmul on real TPU

    # Positional causal mask with recycled-prefix offset.
    j = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, (c, block_k), 1)
    i = jax.lax.broadcasted_iota(jnp.int32, (c, block_k), 0)
    s = jnp.where(j <= cur_len + i, s, NEG_INF)

    m_prev = m_ref[0]
    l_prev = l_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])  # [C, BK]
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    o_new = o_ref[0] * corr[:, None] + p @ v  # second MXU matmul

    m_ref[0] = m_new
    l_ref[0] = l_new
    o_ref[0] = o_new

    @pl.when(kb == nkb - 1)
    def _epilogue():
        l_fin = l_ref[0]
        # Fully-masked rows (can only happen for padded queries when
        # cur_len + i targets an empty window, which causality prevents for
        # real rows) get denominator 1 to stay finite.
        l_safe = jnp.where(l_fin == 0.0, 1.0, l_fin)
        o_ref[0] = o_ref[0] / l_safe[:, None]


def cached_attention(q, k, v, cur_len, *, block_k: int = 64, interpret: bool = True):
    """Flash-style causal attention over a prefix-cached KV buffer.

    Args:
      q: [H, C, D] float32 — queries for the new chunk.
      k, v: [H, S, D] float32 — KV buffer (prefix of cur_len rows is live;
        rows [cur_len, cur_len + C) were just written for this chunk).
      cur_len: scalar int32 — live prefix length (the recycled depth).
      block_k: key tile size (S must be a multiple).
      interpret: must stay True on CPU PJRT; see module docstring.

    Returns: [H, C, D] float32 attention output.
    """
    h, c, d = q.shape
    s = k.shape[1]
    if s % block_k != 0:
        raise ValueError(f"S={s} not a multiple of block_k={block_k}")
    nkb = s // block_k
    cur_len_arr = jnp.reshape(jnp.asarray(cur_len, jnp.int32), (1,))

    kernel = functools.partial(_attn_kernel, block_k=block_k)
    out, _m, _l = pl.pallas_call(
        kernel,
        grid=(h, nkb),
        in_specs=[
            pl.BlockSpec((1,), lambda hh, kb: (0,)),
            pl.BlockSpec((1, c, d), lambda hh, kb: (hh, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda hh, kb: (hh, kb, 0)),
            pl.BlockSpec((1, block_k, d), lambda hh, kb: (hh, kb, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, d), lambda hh, kb: (hh, 0, 0)),
            pl.BlockSpec((1, c), lambda hh, kb: (hh, 0)),
            pl.BlockSpec((1, c), lambda hh, kb: (hh, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, c, d), jnp.float32),
            jax.ShapeDtypeStruct((h, c), jnp.float32),
            jax.ShapeDtypeStruct((h, c), jnp.float32),
        ],
        interpret=interpret,
    )(cur_len_arr, q, k, v)
    return out


def vmem_bytes(c: int, d: int, block_k: int) -> int:
    """Estimated VMEM working set per program instance, in bytes (f32).

    q tile + k tile + v tile + o accumulator + m/l vectors + p scratch.
    Used by sim::roofline (Rust mirrors this formula) and the perf notes.
    """
    f = 4
    return f * (c * d + 2 * block_k * d + c * d + 2 * c + c * block_k)
