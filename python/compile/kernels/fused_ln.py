"""Pallas fused LayerNorm.

One pass per row tile: mean, variance, normalize, scale+shift — fused so the
row is read from VMEM once instead of the 4 separate HLO reductions a naive
lowering produces. Rows are tiled so arbitrarily many rows stream through a
fixed VMEM budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...]  # [BR, D]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    xc = x - mu
    var = jnp.mean(xc * xc, axis=-1, keepdims=True)
    o_ref[...] = xc * jax.lax.rsqrt(var + eps) * g_ref[...] + b_ref[...]


def fused_layernorm(x, gamma, beta, *, eps: float = 1e-5, block_rows: int = 32,
                    interpret: bool = True):
    """LayerNorm over the last axis of a 2-D input.

    Args:
      x: [R, D] float32.
      gamma, beta: [D] float32.
      block_rows: rows per program instance; R is padded up internally.

    Returns: [R, D] float32.
    """
    r, d = x.shape
    r_pad = -(-r // block_rows) * block_rows
    if r_pad != r:
        x = jnp.pad(x, ((0, r_pad - r), (0, 0)))
    kernel = functools.partial(_ln_kernel, eps=eps)
    out = pl.pallas_call(
        kernel,
        grid=(r_pad // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r_pad, d), jnp.float32),
        interpret=interpret,
    )(x, gamma, beta)
    return out[:r]
