"""L2: GPT-2-family decoder with an external KV buffer (the recycling surface).

The paper uses DialoGPT-medium (GPT-2, 345M) through HF `generate`
(past_key_values injection). We rebuild the same architecture family with the
KV cache as an *explicit argument*: `forward_chunk` consumes and returns the
whole [L, 2, H, S, D] buffer plus a `cur_len` scalar, which is exactly the
object the Rust coordinator caches, serializes, retrieves and re-injects
across prompts.

Two forward paths share one parameter set:
  * `forward_chunk`  — inference path; calls the Pallas kernels
    (cached_attention, fused_layernorm); this is what `aot.py` lowers to HLO
    per chunk-size bucket.
  * `forward_train`  — plain-jnp full-sequence path used by the build-time
    trainer (fast under jit, no KV buffer).
The equivalence of the two paths (and of 1-chunk vs N-chunk encodings) is
asserted in python/tests/test_model.py — that equivalence IS the paper's
correctness claim for KV reuse.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

from .kernels.cached_attention import cached_attention
from .kernels.fused_ln import fused_layernorm
from .kernels.ref import NEG_INF


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (GPT-2 family)."""

    name: str
    n_layer: int
    n_head: int
    d_model: int
    vocab_size: int
    max_seq: int
    d_ff: int
    # Prefill chunk-size buckets exported as separate HLO executables.
    chunk_sizes: tuple[int, ...] = (1, 8, 32, 64)
    # KV sequence-capacity buckets: each (chunk, seq) pair gets its own
    # executable. Short live contexts run against a small KV buffer —
    # less host->device traffic AND less attention compute (the kernel
    # scans only seq rows). The largest must equal max_seq.
    seq_buckets: tuple[int, ...] = (64, 128, 256)
    # Embedding-encoder dims (see embedmodel.py).
    embed_dim: int = 64
    embed_seq: int = 64

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_head == 0
        return self.d_model // self.n_head

    def kv_shape(self) -> tuple[int, ...]:
        return (self.n_layer, 2, self.n_head, self.max_seq, self.head_dim)

    def kv_bytes(self) -> int:
        n = 1
        for d in self.kv_shape():
            n *= d
        return 4 * n

    def n_params(self) -> int:
        return sum(math.prod(s) for _, s in param_spec(self))


PRESETS: dict[str, ModelConfig] = {
    # Build-time-trainable testbed (the DialoGPT-medium stand-in).
    "nano": ModelConfig("nano", n_layer=4, n_head=4, d_model=128,
                        vocab_size=512, max_seq=256, d_ff=512),
    # Mid-size config for scaling experiments.
    "small": ModelConfig("small", n_layer=6, n_head=8, d_model=256,
                         vocab_size=1024, max_seq=512, d_ff=1024,
                         seq_buckets=(64, 256, 512)),
    # Shape-identical to DialoGPT-medium; used for roofline analysis only
    # (too slow to train or serve on the single-core CPU CI substrate).
    "dialogpt-medium": ModelConfig("dialogpt-medium", n_layer=24, n_head=16,
                                   d_model=1024, vocab_size=50257,
                                   max_seq=1024, d_ff=4096,
                                   seq_buckets=(64, 256, 1024)),
}


def param_spec(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Ordered (name, shape) list — the single source of truth for the
    weights.bin layout consumed by rust/src/runtime/artifacts.rs."""
    spec: list[tuple[str, tuple[int, ...]]] = [
        ("wte", (cfg.vocab_size, cfg.d_model)),
        ("wpe", (cfg.max_seq, cfg.d_model)),
    ]
    for l in range(cfg.n_layer):
        p = f"h{l}."
        spec += [
            (p + "ln1.g", (cfg.d_model,)),
            (p + "ln1.b", (cfg.d_model,)),
            (p + "attn.wqkv", (cfg.d_model, 3 * cfg.d_model)),
            (p + "attn.bqkv", (3 * cfg.d_model,)),
            (p + "attn.wo", (cfg.d_model, cfg.d_model)),
            (p + "attn.bo", (cfg.d_model,)),
            (p + "ln2.g", (cfg.d_model,)),
            (p + "ln2.b", (cfg.d_model,)),
            (p + "mlp.wfc", (cfg.d_model, cfg.d_ff)),
            (p + "mlp.bfc", (cfg.d_ff,)),
            (p + "mlp.wproj", (cfg.d_ff, cfg.d_model)),
            (p + "mlp.bproj", (cfg.d_model,)),
        ]
    spec += [("lnf.g", (cfg.d_model,)), ("lnf.b", (cfg.d_model,))]
    return spec


def init_params(cfg: ModelConfig, key: jax.Array) -> dict[str, jax.Array]:
    """GPT-2 style init: N(0, 0.02) weights, zero biases, unit LN gains."""
    params: dict[str, jax.Array] = {}
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith((".g",)):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith((".b", ".bqkv", ".bo", ".bfc", ".bproj")):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            std = 0.02
            if name.endswith(("attn.wo", "mlp.wproj")):
                # GPT-2 residual-branch scaling.
                std = 0.02 / math.sqrt(2 * cfg.n_layer)
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return params


def flatten_params(cfg: ModelConfig, params: dict[str, jax.Array]) -> list[jax.Array]:
    return [params[name] for name, _ in param_spec(cfg)]


def unflatten_params(cfg: ModelConfig, flat: tuple[jax.Array, ...]) -> dict[str, jax.Array]:
    return {name: arr for (name, _), arr in zip(param_spec(cfg), flat)}


def _gelu(x: jax.Array) -> jax.Array:
    # tanh approximation, as in GPT-2.
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x * x * x)))


def forward_chunk(cfg: ModelConfig, params: dict[str, jax.Array],
                  tokens: jax.Array, valid_len: jax.Array,
                  kv: jax.Array, cur_len: jax.Array,
                  *, use_pallas: bool = True) -> tuple[jax.Array, jax.Array]:
    """Process one chunk of C new tokens given a KV buffer with cur_len live rows.

    Args:
      tokens: [C] int32, right-padded; only the first valid_len are real.
      valid_len: scalar int32.
      kv: [L, 2, H, S, D] float32 KV buffer.
      cur_len: scalar int32, live prefix length (recycled depth on a cache hit).

    Returns:
      logits: [C, V] float32 (rows >= valid_len are garbage; the sampler reads
        row valid_len - 1).
      kv': updated buffer; live length becomes cur_len + valid_len.
    """
    c = tokens.shape[0]
    cur_len = jnp.asarray(cur_len, jnp.int32)
    positions = cur_len + jnp.arange(c, dtype=jnp.int32)
    # Clamp padded-row positions into range (their outputs are discarded).
    positions = jnp.minimum(positions, cfg.max_seq - 1)
    x = params["wte"][tokens] + params["wpe"][positions]  # [C, Dm]

    def ln(x2d, g, b):
        if use_pallas:
            return fused_layernorm(x2d, g, b, block_rows=min(32, c))
        mu = jnp.mean(x2d, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x2d - mu), axis=-1, keepdims=True)
        return (x2d - mu) / jnp.sqrt(var + 1e-5) * g + b

    for l in range(cfg.n_layer):
        p = f"h{l}."
        h = ln(x, params[p + "ln1.g"], params[p + "ln1.b"])
        qkv = h @ params[p + "attn.wqkv"] + params[p + "attn.bqkv"]  # [C, 3Dm]
        q, k_new, v_new = jnp.split(qkv, 3, axis=-1)
        # [C, Dm] -> [H, C, D]
        def heads(t):
            return t.reshape(c, cfg.n_head, cfg.head_dim).transpose(1, 0, 2)
        q, k_new, v_new = heads(q), heads(k_new), heads(v_new)
        # Write the chunk's K/V into the buffer at [cur_len, cur_len + C).
        upd = jnp.stack([k_new, v_new])[None]  # [1, 2, H, C, D]
        kv = jax.lax.dynamic_update_slice(kv, upd, (l, 0, 0, cur_len, 0))
        if use_pallas:
            attn = cached_attention(q, kv[l, 0], kv[l, 1], cur_len)
        else:
            from .kernels.ref import ref_cached_attention
            attn = ref_cached_attention(q, kv[l, 0], kv[l, 1], cur_len, valid_len)
        attn = attn.transpose(1, 0, 2).reshape(c, cfg.d_model)
        x = x + attn @ params[p + "attn.wo"] + params[p + "attn.bo"]
        h2 = ln(x, params[p + "ln2.g"], params[p + "ln2.b"])
        x = x + _gelu(h2 @ params[p + "mlp.wfc"] + params[p + "mlp.bfc"]) \
            @ params[p + "mlp.wproj"] + params[p + "mlp.bproj"]

    x = ln(x, params["lnf.g"], params["lnf.b"])
    logits = x @ params["wte"].T  # weight tying, as GPT-2
    return logits, kv


def forward_train(cfg: ModelConfig, params: dict[str, jax.Array],
                  tokens: jax.Array) -> jax.Array:
    """Full-sequence training forward (plain jnp, batched). tokens: [B, T]."""
    b, t = tokens.shape
    x = params["wte"][tokens] + params["wpe"][jnp.arange(t)]

    def ln(x3d, g, b_):
        mu = jnp.mean(x3d, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x3d - mu), axis=-1, keepdims=True)
        return (x3d - mu) / jnp.sqrt(var + 1e-5) * g + b_

    causal = jnp.tril(jnp.ones((t, t), bool))
    scale = 1.0 / math.sqrt(cfg.head_dim)
    for l in range(cfg.n_layer):
        p = f"h{l}."
        h = ln(x, params[p + "ln1.g"], params[p + "ln1.b"])
        qkv = h @ params[p + "attn.wqkv"] + params[p + "attn.bqkv"]
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def heads(z):
            return z.reshape(b, t, cfg.n_head, cfg.head_dim).transpose(0, 2, 1, 3)
        q, k, v = heads(q), heads(k), heads(v)
        s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
        s = jnp.where(causal, s, NEG_INF)
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", a, v)
        o = o.transpose(0, 2, 1, 3).reshape(b, t, cfg.d_model)
        x = x + o @ params[p + "attn.wo"] + params[p + "attn.bo"]
        h2 = ln(x, params[p + "ln2.g"], params[p + "ln2.b"])
        x = x + _gelu(h2 @ params[p + "mlp.wfc"] + params[p + "mlp.bfc"]) \
            @ params[p + "mlp.wproj"] + params[p + "mlp.bproj"]

    x = ln(x, params["lnf.g"], params["lnf.b"])
    return x @ params["wte"].T


def empty_kv(cfg: ModelConfig) -> jax.Array:
    return jnp.zeros(cfg.kv_shape(), jnp.float32)


@functools.lru_cache(maxsize=64)
def _jitted_forward(cfg: ModelConfig, c: int, use_pallas: bool):
    """jit-compiled forward per (config, chunk size): interpret-mode Pallas
    lowers to plain HLO under jit, so repeated build-time calls are fast."""
    del c  # keyed for cache identity; shape specializes on first call

    def fn(params, tokens, valid_len, kv, cur_len):
        return forward_chunk(cfg, params, tokens, valid_len, kv, cur_len,
                             use_pallas=use_pallas)

    return jax.jit(fn)


def greedy_generate(cfg: ModelConfig, params: dict[str, jax.Array],
                    prompt_ids: list[int], max_new_tokens: int,
                    kv: jax.Array | None = None, cur_len: int = 0,
                    eot_id: int = 0, use_pallas: bool = False):
    """Reference greedy decoder (build-time only; mirrors rust engine::generate).

    Returns (generated_ids, kv, new_len). Used to produce golden fixtures that
    the Rust engine must reproduce token-for-token.
    """
    if kv is None:
        kv = empty_kv(cfg)
    ids = list(prompt_ids)
    # Prefill the prompt suffix one greedy chunk at a time using the largest
    # bucket that fits (same schedule as rust engine::plan_chunks).
    pos = cur_len
    pending = ids[cur_len:]
    logits = None
    while pending:
        # Smallest bucket that covers everything pending (padded), else the
        # largest bucket. Minimizes call count — each call re-uploads the KV
        # buffer, so fewer calls beat fewer padded rows. Mirrors rust
        # engine::plan_chunks.
        fits = [cs for cs in cfg.chunk_sizes if cs >= len(pending)]
        csize = min(fits) if fits else max(cfg.chunk_sizes)
        chunk = pending[:csize]
        pending = pending[csize:]
        pad = csize - len(chunk)
        toks = jnp.asarray(chunk + [0] * pad, jnp.int32)
        fwd = _jitted_forward(cfg, csize, use_pallas)
        logits, kv = fwd(params, toks, jnp.asarray(len(chunk), jnp.int32),
                         kv, jnp.asarray(pos, jnp.int32))
        logits = logits[len(chunk) - 1]
        pos += len(chunk)
    out: list[int] = []
    for _ in range(max_new_tokens):
        nxt = int(jnp.argmax(logits))
        if nxt == eot_id or pos >= cfg.max_seq:
            break
        out.append(nxt)
        toks = jnp.asarray([nxt], jnp.int32)
        fwd = _jitted_forward(cfg, 1, use_pallas)
        logits, kv = fwd(params, toks, jnp.asarray(1, jnp.int32),
                         kv, jnp.asarray(pos, jnp.int32))
        logits = logits[0]
        pos += 1
    return out, kv, pos
