"""Build-time LM training (the 'load a small real model' requirement).

Trains the nano/small GPT-2-family config on the synthetic dialogue corpus
with a hand-rolled Adam (optax is not vendored). Runs once inside
`make artifacts`; the resulting weights are what the Rust server loads, so
the served model is a *trained* conversational model, not noise. The loss
curve is written to artifacts/train_log.csv and summarized in EXPERIMENTS.md.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .model import ModelConfig, forward_train, init_params


def batches(token_ids: np.ndarray, batch: int, seq: int, steps: int, seed: int = 0):
    """Deterministic sampler of [batch, seq+1] windows from the token stream."""
    rng = np.random.default_rng(seed)
    n = len(token_ids) - seq - 1
    for _ in range(steps):
        idx = rng.integers(0, n, size=batch)
        yield np.stack([token_ids[i:i + seq + 1] for i in idx])


def make_loss_fn(cfg: ModelConfig):
    def loss_fn(params, window):
        x, y = window[:, :-1], window[:, 1:]
        logits = forward_train(cfg, params, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
        return -jnp.mean(ll)
    return loss_fn


def train(cfg: ModelConfig, token_ids: np.ndarray, *, steps: int = 300,
          batch: int = 8, seq: int = 64, lr: float = 3e-3, seed: int = 0,
          log_every: int = 10):
    """Adam training loop. Returns (params, [(step, loss), ...])."""
    params = init_params(cfg, jax.random.PRNGKey(seed))
    loss_fn = make_loss_fn(cfg)

    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    b1, b2, eps = 0.9, 0.999, 1e-8

    @jax.jit
    def step_fn(params, m, v, window, t):
        loss, grads = jax.value_and_grad(loss_fn)(params, window)
        m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
        v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
        mhat = jax.tree.map(lambda a: a / (1 - b1 ** t), m)
        vhat = jax.tree.map(lambda a: a / (1 - b2 ** t), v)
        params = jax.tree.map(lambda p, a, b: p - lr * a / (jnp.sqrt(b) + eps),
                              params, mhat, vhat)
        return params, m, v, loss

    log: list[tuple[int, float]] = []
    t0 = time.time()
    for i, window in enumerate(batches(token_ids, batch, seq, steps, seed)):
        t = jnp.asarray(i + 1, jnp.float32)
        params, m, v, loss = step_fn(params, m, v, jnp.asarray(window), t)
        if i % log_every == 0 or i == steps - 1:
            log.append((i, float(loss)))
    dt = time.time() - t0
    print(f"train[{cfg.name}]: {steps} steps in {dt:.1f}s, "
          f"loss {log[0][1]:.3f} -> {log[-1][1]:.3f}")
    return params, log
