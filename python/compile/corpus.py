"""Deterministic synthetic dialogue corpus.

Substitute for DialoGPT's 147M Reddit exchanges (unavailable offline): a
templated question/answer corpus in the same conversational register as the
paper's prompt sets (§4.3: capitals, machine learning, airplanes, ...). The
generator is seeded and pure, so `make artifacts` is reproducible bit-for-bit.

Also writes the paper's two prompt files:
  data/cache_prompts.csv — 10 prompts used to build the activation cache.
  data/test_prompts.csv  — 6 prompts, extended versions of cache prompts
                           (near-duplicate / extended-prefix cases).
"""

from __future__ import annotations

import os
import random

# --- topic bank -------------------------------------------------------------

CAPITALS = [
    ("France", "Paris", "the Eiffel Tower"),
    ("Japan", "Tokyo", "the Shibuya crossing"),
    ("Italy", "Rome", "the Colosseum"),
    ("Spain", "Madrid", "the Prado museum"),
    ("Germany", "Berlin", "the Brandenburg Gate"),
    ("India", "New Delhi", "the Red Fort"),
    ("Brazil", "Brasilia", "the national congress"),
    ("Canada", "Ottawa", "the Rideau canal"),
    ("Egypt", "Cairo", "the pyramids of Giza"),
    ("Kenya", "Nairobi", "the national park"),
    ("Norway", "Oslo", "the fjord museum"),
    ("Greece", "Athens", "the Acropolis"),
]

CONCEPTS = [
    ("machine learning", "computers learn patterns from data instead of following fixed rules",
     "spam filters that learn from examples"),
    ("deep learning", "neural networks with many layers learn features automatically",
     "image recognition in photo apps"),
    ("the internet", "computers exchange packets of data over shared networks",
     "loading a web page from a server"),
    ("gravity", "masses attract each other with a force that grows with mass",
     "an apple falling from a tree"),
    ("photosynthesis", "plants turn sunlight and carbon dioxide into sugar and oxygen",
     "leaves making food for the plant"),
    ("evolution", "species change over generations as useful traits spread",
     "bacteria becoming resistant to drugs"),
    ("inflation", "prices rise over time so money buys less",
     "bread costing more each decade"),
    ("a transformer model", "attention layers mix information between all tokens",
     "a chatbot answering questions"),
    ("a cache", "a small fast store keeps recent results close to the user",
     "a browser keeping images on disk"),
    ("recycling", "used materials are processed into new products",
     "old bottles becoming new glass"),
]

MECHANISMS = [
    ("airplanes fly", "their wings deflect air downward which pushes the wing up",
     "lift grows with speed and wing area"),
    ("boats float", "they displace water heavier than their own weight",
     "a steel hull encloses mostly air"),
    ("fridges cool", "a pump moves heat from inside to the coils outside",
     "compressing a gas makes it hot"),
    ("radios work", "antennas turn electric signals into waves and back",
     "tuning selects a single frequency"),
    ("batteries store energy", "chemical reactions push electrons through a circuit",
     "charging reverses the reaction"),
    ("vaccines protect", "they teach the immune system to recognize a pathogen",
     "antibodies form before infection"),
    ("rockets launch", "burning fuel throws mass down so the rocket goes up",
     "thrust must exceed weight"),
    ("computers add numbers", "logic gates combine bits with carries",
     "an adder circuit chains gates"),
]

SMALLTALK = [
    ("how are you today", "i am doing well, thanks for asking"),
    ("what did you do this weekend", "i mostly read and went for a long walk"),
    ("do you like coffee or tea", "i prefer tea in the morning and coffee after lunch"),
    ("any plans for the holidays", "i want to visit family and rest a little"),
    ("what music do you enjoy", "mostly jazz, but lately a lot of classical piano"),
    ("did you watch the game", "i caught the second half, what a finish"),
]

Q_TEMPLATES_CAPITAL = [
    "What is the capital of {c}?",
    "Tell me the capital of {c}.",
    "Which city is the capital of {c}?",
]
A_TEMPLATES_CAPITAL = [
    "The capital of {c} is {cap}.",
    "{cap} is the capital of {c}. You could also visit {sight}.",
    "It is {cap}. Many visitors also enjoy {sight}.",
]

Q_TEMPLATES_CONCEPT = [
    "Explain {t} in simple terms.",
    "What is {t}?",
    "Can you describe {t} briefly?",
]
A_TEMPLATES_CONCEPT = [
    "In simple terms, {t} means that {d}.",
    "{t} is when {d}. For example, {e}.",
    "Think of it like this: {d}. A common example is {e}.",
]

Q_TEMPLATES_MECH = [
    "How do {t}?",
    "Why do {t}?",
    "Explain how {t}.",
]
A_TEMPLATES_MECH = [
    "They do because {d}.",
    "It works like this: {d}. Remember that {e}.",
    "The short answer is that {d}.",
]


def corpus_exchanges(seed: int = 0, n_exchanges: int = 2400) -> list[str]:
    """One 'User: ...\nBot: ...\n' string per exchange. The trainer inserts
    an <|endoftext|> token between exchanges so the model learns to stop
    after answering (DialoGPT's EOS behaviour, which the paper's latency
    profile depends on)."""
    rng = random.Random(seed)
    lines = []
    for _ in range(n_exchanges):
        kind = rng.randrange(4)
        if kind == 0:
            c, cap, sight = rng.choice(CAPITALS)
            q = rng.choice(Q_TEMPLATES_CAPITAL).format(c=c)
            a = rng.choice(A_TEMPLATES_CAPITAL).format(c=c, cap=cap, sight=sight)
        elif kind == 1:
            t, d, e = rng.choice(CONCEPTS)
            q = rng.choice(Q_TEMPLATES_CONCEPT).format(t=t)
            a = rng.choice(A_TEMPLATES_CONCEPT).format(t=t, d=d, e=e)
        elif kind == 2:
            t, d, e = rng.choice(MECHANISMS)
            q = rng.choice(Q_TEMPLATES_MECH).format(t=t)
            a = rng.choice(A_TEMPLATES_MECH).format(t=t, d=d, e=e)
        else:
            q, a = rng.choice(SMALLTALK)
            q = q.capitalize() + "?"
            a = a.capitalize() + "."
        lines.append(f"User: {q}\nBot: {a}\n")
    return lines


def build_corpus(seed: int = 0, n_exchanges: int = 2400) -> str:
    """The raw training text (tokenizer training; no special tokens)."""
    return "".join(corpus_exchanges(seed, n_exchanges))


# --- the paper's prompt sets (§4.3) ------------------------------------------

CACHE_PROMPTS = [
    "Explain machine learning in simple terms.",
    "What is the capital of France?",
    "How do airplanes fly?",
    "What is deep learning?",
    "Explain gravity in simple terms.",
    "How do boats float?",
    "What is the capital of Japan?",
    "Explain photosynthesis in simple terms.",
    "How do rockets launch?",
    "What is a cache?",
]

TEST_PROMPTS = [
    "Explain machine learning in simple terms. Give an example application.",
    "What is the capital of France? Also mention a nearby tourist destination.",
    "How do airplanes fly? Keep the answer short.",
    "What is deep learning? Compare it with machine learning.",
    "Explain gravity in simple terms. Why does the moon stay in orbit?",
    "What is a cache? Why do browsers use one?",
]


def _write_csv(path: str, header: str, rows: list[str]) -> None:
    def quote(s: str) -> str:
        if any(ch in s for ch in ',"\n'):
            return '"' + s.replace('"', '""') + '"'
        return s

    with open(path, "w") as f:
        f.write(header + "\n")
        for r in rows:
            f.write(quote(r) + "\n")


def write_prompt_files(data_dir: str) -> None:
    os.makedirs(data_dir, exist_ok=True)
    _write_csv(os.path.join(data_dir, "cache_prompts.csv"), "text", CACHE_PROMPTS)
    _write_csv(os.path.join(data_dir, "test_prompts.csv"), "text", TEST_PROMPTS)
