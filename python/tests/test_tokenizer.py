"""BPE tokenizer: roundtrip, determinism, and pretokenizer invariants.

The Rust port is cross-checked against the same fixtures in
artifacts/fixtures.json; these tests pin the Python side.
"""

import json

from hypothesis import given, settings, strategies as st

from compile.corpus import build_corpus
from compile.tokenizer import (SPECIALS, Tokenizer, bytes_to_unicode,
                               pretokenize, train_bpe)

_CORPUS = build_corpus(seed=0, n_exchanges=300)
_TOK = train_bpe(_CORPUS, 512)

text_strategy = st.text(
    alphabet=st.characters(codec="utf-8", exclude_characters="\r"),
    max_size=200,
)


def test_byte_unicode_table_bijective():
    t = bytes_to_unicode()
    assert len(t) == 256
    assert len(set(t.values())) == 256


@settings(max_examples=200, deadline=None)
@given(text_strategy)
def test_pretokenize_concat_identity(text):
    assert "".join(pretokenize(text)) == text


@settings(max_examples=200, deadline=None)
@given(text_strategy)
def test_encode_decode_roundtrip(text):
    assert _TOK.decode(_TOK.encode(text)) == text


def test_encode_deterministic_and_cached():
    a = _TOK.encode("User: How do airplanes fly?\nBot:")
    b = _TOK.encode("User: How do airplanes fly?\nBot:")
    assert a == b


def test_vocab_layout():
    assert _TOK.vocab_size == 512
    assert _TOK.id_to_token[0] == "<|endoftext|>"
    # byte tokens occupy [len(SPECIALS), len(SPECIALS)+256)
    assert len(_TOK.id_to_token) == len(SPECIALS) + 256 + len(_TOK.merges)


def test_prefix_tokenization_stability():
    """The paper's prefix condition needs: tokens(cache) is a prefix of
    tokens(cache + suffix) when the suffix starts at a piece boundary."""
    cache = "What is the capital of France?"
    test = cache + " Also mention a nearby tourist destination."
    c, t = _TOK.encode(cache), _TOK.encode(test)
    assert t[:len(c)] == c


def test_json_roundtrip():
    tok2 = Tokenizer.from_json(_TOK.to_json())
    s = "Explain machine learning in simple terms."
    assert tok2.encode(s) == _TOK.encode(s)
    json.loads(_TOK.to_json())  # valid JSON


def test_training_compresses_corpus():
    """Merges must actually compress: fewer tokens than bytes."""
    sample = _CORPUS[:2000]
    assert len(_TOK.encode(sample)) < 0.6 * len(sample.encode())
