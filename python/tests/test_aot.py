"""AOT artifact contract: HLO text is parseable, manifest matches weights,
fixtures are internally consistent. Validates artifacts/ when present (built
by `make artifacts`); lowering itself is exercised on a throwaway config.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import lower_embed, lower_forward, make_forward_fn
from compile.model import ModelConfig, PRESETS, param_spec

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
HAVE_ART = os.path.exists(os.path.join(ART, "manifest.json"))

TINY = ModelConfig("tiny-aot", n_layer=1, n_head=2, d_model=32,
                   vocab_size=64, max_seq=64, d_ff=64, chunk_sizes=(1, 4))


def test_lower_forward_emits_hlo_text():
    hlo = lower_forward(TINY, 4)
    assert "ENTRY" in hlo and "HloModule" in hlo
    # all params + tokens/valid_len/kv/cur_len appear as entry parameters
    # (fusion sub-computations also use `parameter(`, so >= not ==)
    assert hlo.count("parameter(") >= len(param_spec(TINY)) + 4


def test_lower_embed_emits_hlo_text():
    hlo = lower_embed(TINY)
    assert "ENTRY" in hlo


@pytest.mark.skipif(not HAVE_ART, reason="run `make artifacts` first")
def test_manifest_tensor_table_is_contiguous():
    with open(os.path.join(ART, "manifest.json")) as f:
        m = json.load(f)
    offset = 0
    for t in m["tensors"]:
        assert t["offset"] == offset
        n = 1
        for d in t["shape"]:
            n *= d
        assert t["bytes"] == 4 * n
        offset += t["bytes"]
    assert offset == os.path.getsize(os.path.join(ART, m["weights"]))


@pytest.mark.skipif(not HAVE_ART, reason="run `make artifacts` first")
def test_manifest_artifacts_exist():
    with open(os.path.join(ART, "manifest.json")) as f:
        m = json.load(f)
    for name in m["artifacts"].values():
        path = os.path.join(ART, name)
        assert os.path.exists(path), name
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head


@pytest.mark.skipif(not HAVE_ART, reason="run `make artifacts` first")
def test_fixture_recycle_consistency():
    with open(os.path.join(ART, "fixtures.json")) as f:
        fx = json.load(f)
    rec = fx["recycle"]
    assert rec["test_ids"][:rec["reuse_depth"]] == rec["cache_ids"]
    assert rec["baseline_ids"] == rec["recycled_ids"]
    assert fx["greedy"]["generated_ids"], "greedy fixture must be non-empty"


@pytest.mark.skipif(not HAVE_ART, reason="run `make artifacts` first")
def test_fixture_logits_reproduce():
    """Recompute the forward golden from weights.bin — pins the serialized
    weights to the lowered computation."""
    with open(os.path.join(ART, "manifest.json")) as f:
        m = json.load(f)
    with open(os.path.join(ART, "fixtures.json")) as f:
        fx = json.load(f)
    cfg = PRESETS[m["model"]["name"]]
    raw = np.fromfile(os.path.join(ART, m["weights"]), dtype="<f4")
    params = {}
    for t in m["tensors"]:
        n = t["bytes"] // 4
        params[t["name"]] = jnp.asarray(
            raw[t["offset"] // 4: t["offset"] // 4 + n].reshape(t["shape"]))
    fn = make_forward_fn(cfg)
    flat = [params[name] for name, _ in param_spec(cfg)]
    g = fx["forward_logits"]
    c = g["chunk"]
    toks = jnp.asarray(g["prompt_ids"] + [0] * (c - len(g["prompt_ids"])), jnp.int32)
    kv = jnp.zeros(cfg.kv_shape(), jnp.float32)
    logits, _ = fn(*flat, toks, jnp.asarray(len(g["prompt_ids"]), jnp.int32),
                   kv, jnp.asarray(0, jnp.int32))
    row = np.asarray(logits[len(g["prompt_ids"]) - 1])
    np.testing.assert_allclose(row[:8], g["last_row_first8"], rtol=1e-4, atol=1e-4)
    assert int(row.argmax()) == g["last_row_argmax"]
