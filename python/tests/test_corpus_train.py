"""Corpus determinism, prompt-file contract, and trainer sanity."""

import os

import numpy as np

from compile.corpus import (CACHE_PROMPTS, TEST_PROMPTS, build_corpus,
                            write_prompt_files)
from compile.model import ModelConfig
from compile.tokenizer import train_bpe
from compile.train import batches, train


def test_corpus_deterministic():
    assert build_corpus(seed=0, n_exchanges=50) == build_corpus(seed=0, n_exchanges=50)
    assert build_corpus(seed=1, n_exchanges=50) != build_corpus(seed=0, n_exchanges=50)


def test_corpus_is_dialogue_shaped():
    text = build_corpus(seed=0, n_exchanges=100)
    assert text.count("User: ") == 100
    assert text.count("Bot: ") == 100


def test_prompt_sets_match_paper_scale():
    """§4.6: 10 cached and 6 test prompts."""
    assert len(CACHE_PROMPTS) == 10
    assert len(TEST_PROMPTS) == 6


def test_test_prompts_extend_cache_prompts():
    """§4.3: test prompts are extended versions of cache prompts — every test
    prompt must have some cache prompt as a strict text prefix."""
    for t in TEST_PROMPTS:
        assert any(t.startswith(c) and len(t) > len(c) for c in CACHE_PROMPTS), t


def test_write_prompt_files(tmp_path):
    write_prompt_files(str(tmp_path))
    cache = (tmp_path / "cache_prompts.csv").read_text().splitlines()
    test = (tmp_path / "test_prompts.csv").read_text().splitlines()
    assert cache[0] == "text" and len(cache) == 11
    assert test[0] == "text" and len(test) == 7


def test_batches_shape_and_determinism():
    ids = np.arange(1000, dtype=np.int32) % 50
    a = list(batches(ids, batch=4, seq=16, steps=3, seed=2))
    b = list(batches(ids, batch=4, seq=16, steps=3, seed=2))
    assert all((x == y).all() for x, y in zip(a, b))
    assert a[0].shape == (4, 17)


def test_train_loss_decreases():
    cfg = ModelConfig("tiny-train", n_layer=1, n_head=2, d_model=32,
                      vocab_size=300, max_seq=64, d_ff=64, chunk_sizes=(1, 8))
    corpus = build_corpus(seed=0, n_exchanges=200)
    tok = train_bpe(corpus, cfg.vocab_size)
    stream = np.asarray(tok.encode(corpus), np.int32)
    _, log = train(cfg, stream, steps=25, batch=4, seq=32, log_every=5)
    assert log[-1][1] < log[0][1] * 0.9, log
