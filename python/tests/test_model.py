"""L2 model invariants — the paper's correctness claims, asserted in jnp.

Central claim (paper §2.1/§3.1): encoding tokens 1..m from scratch equals
encoding 1..k, caching KV, then encoding k+1..m with the cache injected.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (PRESETS, ModelConfig, empty_kv, flatten_params,
                           forward_chunk, forward_train, greedy_generate,
                           init_params, param_spec, unflatten_params)

CFG = PRESETS["nano"]
PARAMS = init_params(CFG, jax.random.PRNGKey(0))
IDS = [int(x) for x in
       np.random.default_rng(7).integers(1, CFG.vocab_size, size=48)]

TOL = dict(rtol=3e-4, atol=3e-4)


def prefill(ids, kv, cur, chunk, use_pallas=False):
    pad = chunk - len(ids)
    toks = jnp.asarray(list(ids) + [0] * pad, jnp.int32)
    logits, kv = forward_chunk(CFG, PARAMS, toks, jnp.asarray(len(ids), jnp.int32),
                               kv, jnp.asarray(cur, jnp.int32),
                               use_pallas=use_pallas)
    return logits[len(ids) - 1], kv


def test_param_spec_counts():
    spec = param_spec(CFG)
    assert spec[0][0] == "wte"
    assert len(spec) == 2 + 12 * CFG.n_layer + 2
    assert CFG.n_params() > 0.8e6  # nano is ~1M params


def test_flatten_unflatten_roundtrip():
    flat = flatten_params(CFG, PARAMS)
    params2 = unflatten_params(CFG, tuple(flat))
    for name, _ in param_spec(CFG):
        assert params2[name] is PARAMS[name]


def test_kv_shape_and_bytes():
    assert CFG.kv_shape() == (4, 2, 4, 256, 32)
    assert CFG.kv_bytes() == 4 * 2 * 4 * 256 * 32 * 4


@pytest.mark.parametrize("split", [1, 8, 20, 40])
def test_recycled_prefill_equals_full(split):
    """THE paper claim: KV computed for a prefix can be reused exactly."""
    m = len(IDS)
    full_logits, _ = prefill(IDS, empty_kv(CFG), 0, 64)
    _, kv = prefill(IDS[:split], empty_kv(CFG), 0, 64)
    rec_logits, _ = prefill(IDS[split:], kv, split, 64)
    np.testing.assert_allclose(np.asarray(full_logits),
                               np.asarray(rec_logits), **TOL)


def test_many_small_chunks_equal_one_big():
    _, kv = prefill(IDS[:8], empty_kv(CFG), 0, 8)
    _, kv = prefill(IDS[8:16], kv, 8, 8)
    _, kv = prefill(IDS[16:24], kv, 16, 8)
    lg_a, _ = prefill(IDS[24:32], kv, 24, 8)
    lg_b, _ = prefill(IDS[:32], empty_kv(CFG), 0, 32)
    np.testing.assert_allclose(np.asarray(lg_a), np.asarray(lg_b), **TOL)


def test_padding_does_not_change_logits():
    """Right-padding a chunk must not affect the valid rows."""
    lg_a, _ = prefill(IDS[:10], empty_kv(CFG), 0, 16)
    lg_b, _ = prefill(IDS[:10], empty_kv(CFG), 0, 64)
    np.testing.assert_allclose(np.asarray(lg_a), np.asarray(lg_b), **TOL)


def test_train_path_matches_kv_path():
    lg_train = forward_train(CFG, PARAMS, jnp.asarray([IDS], jnp.int32))
    lg_kv, _ = prefill(IDS, empty_kv(CFG), 0, 64)
    np.testing.assert_allclose(np.asarray(lg_train[0, -1]),
                               np.asarray(lg_kv), **TOL)


def test_pallas_path_matches_jnp_path():
    lg_a, _ = prefill(IDS[:16], empty_kv(CFG), 0, 16, use_pallas=True)
    lg_b, _ = prefill(IDS[:16], empty_kv(CFG), 0, 16, use_pallas=False)
    np.testing.assert_allclose(np.asarray(lg_a), np.asarray(lg_b), **TOL)


def test_greedy_generate_deterministic():
    a, _, _ = greedy_generate(CFG, PARAMS, IDS[:12], 6)
    b, _, _ = greedy_generate(CFG, PARAMS, IDS[:12], 6)
    assert a == b
    assert len(a) <= 6


def test_greedy_recycled_equals_baseline():
    """End-to-end recycling equivalence at the generation level."""
    cache_ids, test_ids = IDS[:20], IDS[:32]
    base, _, _ = greedy_generate(CFG, PARAMS, test_ids, 8)
    _, kv, clen = greedy_generate(CFG, PARAMS, cache_ids, 0)
    assert clen == 20
    rec, _, _ = greedy_generate(CFG, PARAMS, test_ids, 8, kv=kv, cur_len=clen)
    assert rec == base


def test_context_capacity_guard():
    """Generation stops at the context window (max_seq) rather than
    writing out of bounds."""
    small = ModelConfig("t", n_layer=1, n_head=2, d_model=32, vocab_size=64,
                        max_seq=32, d_ff=64, chunk_sizes=(1, 8))
    p = init_params(small, jax.random.PRNGKey(1))
    ids = [1] * 30
    out, _, pos = greedy_generate(small, p, ids, 10)
    assert pos <= small.max_seq
