"""Embedding encoder: unit norm, padding mask, determinism."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.embedmodel import embed_forward, init_embed_params
from compile.model import PRESETS

CFG = PRESETS["nano"]
EP = init_embed_params(CFG, jax.random.PRNGKey(42))


def emb(ids):
    pad = ids + [0] * (CFG.embed_seq - len(ids))
    return np.asarray(embed_forward(CFG, EP, jnp.asarray(pad, jnp.int32),
                                    jnp.asarray(len(ids), jnp.int32)))


def test_unit_norm():
    np.testing.assert_allclose(np.linalg.norm(emb([5, 9, 200])), 1.0, rtol=1e-5)


def test_padding_is_masked():
    a = emb([5, 9, 200])
    pad = [5, 9, 200] + [77] * 20
    x = jnp.asarray([5, 9, 200] + [77] * 20 + [0] * (CFG.embed_seq - 23), jnp.int32)
    b = np.asarray(embed_forward(CFG, EP, x, jnp.asarray(3, jnp.int32)))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_similar_inputs_closer_than_dissimilar():
    a = emb([5, 9, 200, 31])
    b = emb([5, 9, 200, 32])   # one-token difference
    c = emb([400, 401, 402, 403])
    assert a @ b > a @ c


def test_deterministic():
    np.testing.assert_array_equal(emb([1, 2, 3]), emb([1, 2, 3]))
