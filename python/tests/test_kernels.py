"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes (heads, chunk, head-dim, buffer size, block sizes)
and the cur_len offset; assert_allclose against ref.py at f32 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.cached_attention import cached_attention, vmem_bytes
from compile.kernels.fused_ln import fused_layernorm
from compile.kernels.ref import (ref_cached_attention, ref_layernorm,
                                 ref_similarity_scores)
from compile.kernels.sim_topk import similarity_scores

SETTINGS = dict(max_examples=15, deadline=None)


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# --- cached_attention --------------------------------------------------------

@settings(**SETTINGS)
@given(
    h=st.sampled_from([1, 2, 4]),
    c=st.sampled_from([1, 3, 8, 16]),
    d=st.sampled_from([8, 16, 32]),
    nkb=st.integers(1, 4),
    block_k=st.sampled_from([16, 32, 64]),
    cur_frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**16),
)
def test_cached_attention_matches_ref(h, c, d, nkb, block_k, cur_frac, seed):
    s = nkb * block_k
    cur_len = min(int(cur_frac * (s - c)), s - c)
    q = rand(seed, (h, c, d))
    k = rand(seed + 1, (h, s, d))
    v = rand(seed + 2, (h, s, d))
    out = cached_attention(q, k, v, cur_len, block_k=block_k)
    ref = ref_cached_attention(q, k, v, cur_len, c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_cached_attention_zero_prefix_is_plain_causal():
    """cur_len=0 must equal plain causal self-attention over the chunk."""
    h, c, d, s = 2, 8, 16, 64
    q = rand(0, (h, c, d))
    kf = rand(1, (h, s, d))
    vf = rand(2, (h, s, d))
    out = cached_attention(q, kf, vf, 0, block_k=32)
    # plain causal attention over first c keys only
    scale = 1.0 / np.sqrt(d)
    sc = np.einsum("hcd,hsd->hcs", np.asarray(q), np.asarray(kf[:, :c])) * scale
    mask = np.tril(np.ones((c, c), bool))
    sc = np.where(mask, sc, -1e30)
    p = np.exp(sc - sc.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    ref = np.einsum("hcs,hsd->hcd", p, np.asarray(vf[:, :c]))
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-5, atol=2e-5)


def test_cached_attention_ignores_garbage_beyond_window():
    """Keys beyond cur_len + i must not affect the output at all."""
    h, c, d, s = 2, 4, 16, 64
    q = rand(0, (h, c, d))
    k = rand(1, (h, s, d))
    v = rand(2, (h, s, d))
    cur = 10
    out1 = cached_attention(q, k, v, cur, block_k=32)
    # Poison everything beyond the furthest visible key (cur + c - 1).
    k2 = k.at[:, cur + c:].set(1e9)
    v2 = v.at[:, cur + c:].set(-1e9)
    out2 = cached_attention(q, k2, v2, cur, block_k=32)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-6, atol=1e-6)


def test_cached_attention_decode_step():
    """C=1 (decode) against ref at several depths."""
    h, d, s = 4, 32, 128
    k = rand(1, (h, s, d))
    v = rand(2, (h, s, d))
    for cur in [0, 1, 63, 100, 126]:
        q = rand(cur + 7, (h, 1, d))
        out = cached_attention(q, k, v, cur, block_k=64)
        ref = ref_cached_attention(q, k, v, cur, 1)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


def test_cached_attention_rejects_bad_block():
    with pytest.raises(ValueError):
        cached_attention(rand(0, (1, 1, 8)), rand(1, (1, 100, 8)),
                         rand(2, (1, 100, 8)), 0, block_k=64)


def test_vmem_estimate_positive_and_monotonic():
    a = vmem_bytes(8, 32, 64)
    b = vmem_bytes(8, 32, 128)
    assert 0 < a < b


# --- similarity_scores -------------------------------------------------------

@settings(**SETTINGS)
@given(
    n=st.integers(1, 200),
    d=st.sampled_from([16, 64, 128]),
    block_n=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 2**16),
)
def test_similarity_scores_matches_ref(n, d, block_n, seed):
    e = rand(seed, (n, d))
    q = rand(seed + 1, (d,))
    out = similarity_scores(e, q, block_n=block_n)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref_similarity_scores(e, q)),
                               rtol=1e-5, atol=1e-5)


def test_similarity_identical_vector_wins():
    d = 32
    e = rand(0, (10, d))
    e = e / jnp.linalg.norm(e, axis=1, keepdims=True)
    q = e[7]
    scores = np.asarray(similarity_scores(e, q, block_n=8))
    assert scores.argmax() == 7
    np.testing.assert_allclose(scores[7], 1.0, rtol=1e-5)


# --- fused_layernorm ---------------------------------------------------------

@settings(**SETTINGS)
@given(
    r=st.integers(1, 70),
    d=st.sampled_from([16, 128, 256]),
    block_rows=st.sampled_from([1, 4, 32]),
    seed=st.integers(0, 2**16),
)
def test_fused_layernorm_matches_ref(r, d, block_rows, seed):
    x = rand(seed, (r, d)) * 3.0 + 0.5
    g = rand(seed + 1, (d,))
    b = rand(seed + 2, (d,))
    out = fused_layernorm(x, g, b, block_rows=block_rows)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref_layernorm(x, g, b)),
                               rtol=2e-5, atol=2e-5)


def test_fused_layernorm_output_stats():
    """With unit gain / zero shift, rows are ~zero-mean unit-var."""
    x = rand(3, (16, 256)) * 7 + 2
    out = np.asarray(fused_layernorm(x, jnp.ones(256), jnp.zeros(256)))
    np.testing.assert_allclose(out.mean(-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(out.std(-1), 1.0, atol=1e-2)
