//! Shared bench plumbing (criterion is not vendored; these binaries are
//! `harness = false` drivers over `recycle_serve::bench`).

// each bench binary includes this module and uses a subset of it
#![allow(dead_code)]

use std::path::{Path, PathBuf};

/// Artifact dir when built (None -> benches degrade to the mock model).
pub fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

pub fn data_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("data")
}

pub fn results_dir() -> PathBuf {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("results");
    std::fs::create_dir_all(&dir).ok();
    dir
}

/// `--quick` flag: fewer repetitions (used by `make test`-style smoke runs).
pub fn quick() -> bool {
    std::env::args().any(|a| a == "--quick")
}

pub fn banner(name: &str, what: &str) {
    println!("\n######## bench: {name} ########");
    println!("# regenerates: {what}\n");
}
