//! E2 — the paper's §5.2 figure: per-prompt latency, baseline vs recycled
//! (printed as two aligned series + a table; CSV in results/).

mod common;

use recycle_serve::bench::{paper_cache_prompts, paper_test_prompts, run_comparison,
                           EvalOptions, Table, Workload};
use recycle_serve::runtime::Runtime;

fn main() {
    common::banner("fig_latency", "paper §5.2 per-prompt latency comparison");
    let Some(artifacts) = common::artifacts_dir() else {
        println!("artifacts/ missing — run `make artifacts`; skipping");
        return;
    };
    let data = common::data_dir();
    let workload = Workload {
        cache_prompts: paper_cache_prompts(&data),
        test_prompts: paper_test_prompts(&data),
    };
    let rt0 = Runtime::load(&artifacts).expect("artifacts");
    let tokenizer = rt0.tokenizer();
    drop(rt0);
    let opts = EvalOptions {
        max_new_tokens: 32,
        ..Default::default()
    };
    let report = run_comparison(
        || Runtime::load(&artifacts).expect("reload"),
        tokenizer,
        &workload,
        &opts,
    )
    .expect("eval");

    let mut t = Table::new(&["prompt", "m toks", "k reused", "base s", "recycled s", "S %"]);
    for (b, r) in report.baseline_rows.iter().zip(&report.recycled_rows) {
        let s = (b.latency_s - r.latency_s) / b.latency_s * 100.0;
        t.row(vec![
            b.prompt.chars().take(40).collect(),
            r.prompt_tokens.to_string(),
            r.reused_tokens.to_string(),
            format!("{:.4}", b.latency_s),
            format!("{:.4}", r.latency_s),
            format!("{s:+.1}"),
        ]);
    }
    println!("{}", t.render());
    std::fs::write(common::results_dir().join("fig_latency.csv"), t.to_csv()).ok();
    println!("series written to results/fig_latency.csv");
    println!("paper shape: recycled <= baseline on every prompt, biggest gaps at larger k");
}
