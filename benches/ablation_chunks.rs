//! A3 — prefill chunk-bucket ablation: time-to-prefill for several prompt
//! lengths when the engine is restricted to different bucket subsets.
//! Shows why the serving config exports {1, 8, 32, 64} and why the planner
//! rounds up to a single padded call (each call re-uploads the KV buffer).

mod common;

use recycle_serve::engine::plan_chunks;
use recycle_serve::engine::ForwardModel;
use recycle_serve::kvcache::KvArena;
use recycle_serve::runtime::Runtime;
use recycle_serve::util::timing::{Samples, Stopwatch};

fn main() {
    common::banner("ablation_chunks", "A3 prefill chunk-bucket sweep");
    let Some(artifacts) = common::artifacts_dir() else {
        println!("artifacts/ missing — run `make artifacts`; skipping");
        return;
    };
    let reps = if common::quick() { 2 } else { 5 };
    let rt = Runtime::load(&artifacts).expect("artifacts");
    let cfg = rt.config().clone();
    let arena = KvArena::with_defaults(&cfg);
    let v = cfg.vocab_size as u32;

    let subsets: Vec<(&str, Vec<usize>)> = vec![
        ("c1 only (token-at-a-time)", vec![1]),
        ("c8 only", vec![8]),
        ("c32 only", vec![32]),
        ("c64 only", vec![64]),
        ("all buckets {1,8,32,64}", vec![1, 8, 32, 64]),
    ];

    println!(
        "{:<28} {:>8} {:>8} {:>8} {:>8}",
        "bucket set \\ prompt toks", "16", "48", "96", "192"
    );
    let mut csv = vec!["buckets,m,calls,ms".to_string()];
    for (name, buckets) in &subsets {
        let mut cells = Vec::new();
        for &m in &[16usize, 48, 96, 192] {
            let ids: Vec<u32> = (0..m as u32).map(|i| 1 + (i * 17 + 3) % (v - 1)).collect();
            let plan = plan_chunks(buckets, m);
            let mut s = Samples::new();
            for _ in 0..reps {
                let mut kv = arena.new_view();
                let sw = Stopwatch::start();
                // drive the chunks manually against the restricted bucket set
                let mut pos = 0usize;
                for &c in &plan {
                    let take = (m - pos).min(c);
                    let mut chunk: Vec<u32> = ids[pos..pos + take].to_vec();
                    chunk.resize(c, 0);
                    rt.forward_chunk(&chunk, take, &mut kv, pos).expect("fwd");
                    pos += take;
                }
                s.push(sw.elapsed_ms());
            }
            cells.push(format!("{:>7.1}", s.median()));
            csv.push(format!("{name},{m},{},{:.3}", plan.len(), s.median()));
        }
        println!("{name:<28} {}", cells.join(" "));
    }
    std::fs::write(
        common::results_dir().join("ablation_chunks.csv"),
        csv.join("\n") + "\n",
    )
    .ok();
    println!("\nexpected shape: per-call overhead (KV upload) dominates small buckets;");
    println!("the mixed bucket set tracks the best single bucket at every length.");
}
