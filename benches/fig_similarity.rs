//! E3 — the paper's §5.4 figure: per-prompt output similarity between
//! baseline and recycled generations (embedding cosine).
//!
//! Expected divergence from the paper: the paper measured 0.59-0.82
//! because HF sampling paths introduce nondeterminism; our stack is greedy
//! with bitwise-identical KV, so outputs are token-identical and the
//! similarity is 1.0 on every hit — the *stronger* form of the paper's
//! fidelity claim.

mod common;

use recycle_serve::bench::{paper_cache_prompts, paper_test_prompts, run_comparison,
                           EvalOptions, Table, Workload};
use recycle_serve::runtime::Runtime;

fn main() {
    common::banner("fig_similarity", "paper §5.4 output-similarity per prompt");
    let Some(artifacts) = common::artifacts_dir() else {
        println!("artifacts/ missing — run `make artifacts`; skipping");
        return;
    };
    let data = common::data_dir();
    let workload = Workload {
        cache_prompts: paper_cache_prompts(&data),
        test_prompts: paper_test_prompts(&data),
    };
    let rt0 = Runtime::load(&artifacts).expect("artifacts");
    let tokenizer = rt0.tokenizer();
    drop(rt0);
    let report = run_comparison(
        || Runtime::load(&artifacts).expect("reload"),
        tokenizer,
        &workload,
        &EvalOptions {
            max_new_tokens: 32,
            ..Default::default()
        },
    )
    .expect("eval");

    let mut t = Table::new(&["prompt", "prompt sim", "output sim", "identical?"]);
    for ((r, out_sim), prom_sim) in report
        .recycled_rows
        .iter()
        .zip(&report.comparison.output_similarity)
        .zip(report.recycled_rows.iter().map(|r| r.prompt_similarity))
    {
        let base = report
            .baseline_rows
            .iter()
            .find(|b| b.prompt == r.prompt)
            .unwrap();
        t.row(vec![
            r.prompt.chars().take(40).collect(),
            format!("{prom_sim:.3}"),
            format!("{out_sim:.3}"),
            (base.output == r.output).to_string(),
        ]);
    }
    println!("{}", t.render());
    std::fs::write(common::results_dir().join("fig_similarity.csv"), t.to_csv()).ok();
    println!(
        "avg output similarity: {:.3} (paper: 0.594 avg, 0.66-0.82 range; see header note)",
        report.comparison.avg_output_similarity()
    );
    println!(
        "avg prompt similarity: {:.3} (paper: 0.819)",
        report.comparison.avg_prompt_similarity()
    );
}
