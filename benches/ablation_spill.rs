//! A7 — tiered KV store ablation: disk spill as the eviction destination
//! vs drop-on-evict, under an arena sized to hold HALF the cache working
//! set.
//!
//! Scenario: 8 distinct ~64-token prompts are warmed into the cache, but
//! the arena only has room for about half of them alongside serving
//! headroom — the recycler's arena-pressure pass must evict. With the
//! spill tier OFF (`max_spill_bytes = 0`, the pre-tier behavior and this
//! ablation's control arm) evicted records are destroyed, so every later
//! request for one recomputes its prefill from scratch. With the tier ON,
//! eviction serializes the record to disk and a later lookup transparently
//! reloads it (shedding a hot sibling), so the request still recycles —
//! paying a bounded reload latency instead of the full recompute.
//!
//! Reported per arm: hit rate, mean request latency, mean *hit* latency,
//! spill/reload counters, and the tier's average reload latency. The
//! spill arm must beat the control on hit rate, and — because a disk
//! reload is far cheaper than recomputing a 64-token prefill on the
//! delayed mock backend — on mean latency too (the "bounded overhead"
//! claim, asserted).
//!
//! A second sweep re-runs the spill arm under injected transient
//! cold-tier read faults (0% / 1% / 10% per reload, seeded — see
//! `recycle_serve::faults`): a failed reload falls back to recomputing
//! that request, so hit rate and latency must degrade *smoothly* with the
//! fault rate, never collapse or panic. Written to `ablation_faults.csv`.
//!
//! ```bash
//! cargo bench --bench ablation_spill            # full
//! cargo bench --bench ablation_spill -- --quick # smoke
//! ```

mod common;

use std::sync::Arc;
use std::time::Duration;

use recycle_serve::config::{CacheConfig, ModelConfig};
use recycle_serve::engine::Engine;
use recycle_serve::faults::{FaultHandle, FaultPlan, FaultSite};
use recycle_serve::index::NgramEmbedder;
use recycle_serve::kvcache::KvArena;
use recycle_serve::recycler::{RecyclePolicy, Recycler};
use recycle_serve::testutil::{MockModel, TempDir};
use recycle_serve::tokenizer::Tokenizer;

const N_PROMPTS: usize = 8;

/// ~64-token distinct documents (byte-level tokenizer: chars == tokens).
fn prompts() -> Vec<String> {
    let topics = [
        "alpha", "bravo", "charlie", "delta", "echo", "foxtrot", "golf", "hotel",
    ];
    (0..N_PROMPTS)
        .map(|i| {
            let mut s = format!("document {i} discusses {} at length: ", topics[i]);
            while s.len() < 64 {
                s.push_str(topics[i]);
                s.push(' ');
            }
            s.truncate(64);
            s
        })
        .collect()
}

struct ArmReport {
    requests: usize,
    hits: usize,
    mean_ms: f64,
    mean_hit_ms: f64,
    spills: u64,
    spill_hits: u64,
    spill_load_errors: u64,
    avg_reload_ms: f64,
}

impl ArmReport {
    fn hit_rate(&self) -> f64 {
        self.hits as f64 / self.requests as f64
    }
}

/// Run one arm: warm all prompts under arena pressure, then serve
/// `passes` rounds of extended requests over every prompt.
fn run(
    spill_dir: Option<&TempDir>,
    passes: usize,
    delay: Duration,
    faults: FaultHandle,
) -> ArmReport {
    let cfg = ModelConfig::nano();
    // Arena: 32 blocks of 16 tokens. The 8 warmed records need ~32 blocks
    // in total, and the headroom pass keeps >= 16 blocks free for serving
    // — so the hot tier can pin only about HALF the working set.
    let arena = KvArena::new(&cfg, 16, 32);
    let engine = Engine::with_arena(MockModel::with_delay(cfg, delay), arena);
    let cache = CacheConfig {
        max_entries: 0,
        max_bytes: 0,
        max_spill_bytes: if spill_dir.is_some() { 256 << 20 } else { 0 },
        spill_dir: spill_dir.map(|t| t.path_string()),
        ..Default::default()
    };
    // Radix policy: exact longest-prefix retrieval, so the two arms differ
    // only in what eviction did to the record — not in retrieval noise.
    let mut r = Recycler::new(
        engine,
        Arc::new(Tokenizer::new(vec![])),
        Box::new(NgramEmbedder::new(64)),
        cache,
        RecyclePolicy::Radix,
    );
    r.populate_cache = false;
    r.install_faults(faults);

    let docs = prompts();
    let refs: Vec<&str> = docs.iter().map(|s| s.as_str()).collect();
    r.warm(&refs).expect("warm");

    let mut report = ArmReport {
        requests: 0,
        hits: 0,
        mean_ms: 0.0,
        mean_hit_ms: 0.0,
        spills: 0,
        spill_hits: 0,
        spill_load_errors: 0,
        avg_reload_ms: 0.0,
    };
    let mut total_ms = 0.0;
    let mut hit_ms = 0.0;
    for _ in 0..passes {
        for doc in &docs {
            let q = format!("{doc} tell me more");
            let out = r.generate(&q, 8).expect("serve");
            report.requests += 1;
            total_ms += out.latency_s * 1e3;
            if out.cache_hit {
                report.hits += 1;
                hit_ms += out.latency_s * 1e3;
            }
        }
    }
    let s = r.store().stats();
    report.mean_ms = total_ms / report.requests as f64;
    report.mean_hit_ms = if report.hits > 0 {
        hit_ms / report.hits as f64
    } else {
        f64::NAN
    };
    report.spills = s.spills;
    report.spill_hits = s.spill_hits;
    report.spill_load_errors = s.spill_load_errors;
    report.avg_reload_ms = s.avg_reload_ms();
    report
}

fn main() {
    common::banner(
        "ablation_spill",
        "A7 tiered KV store: spill-on-evict vs drop-on-evict",
    );
    let passes = if common::quick() { 1 } else { 3 };
    let delay = Duration::from_micros(300);

    let tmp = TempDir::new("bench_spill");
    let off = run(None, passes, delay, FaultHandle::off());
    let on = run(Some(&tmp), passes, delay, FaultHandle::off());

    println!(
        "{:<10} {:>9} {:>6} {:>9} {:>10} {:>13} {:>8} {:>11} {:>13}",
        "mode", "requests", "hits", "hit_rate", "mean_ms", "mean_hit_ms", "spills",
        "spill_hits", "avg_reload_ms"
    );
    let mut rows: Vec<Vec<String>> = Vec::new();
    for (mode, r) in [("spill-off", &off), ("spill-on", &on)] {
        println!(
            "{mode:<10} {:>9} {:>6} {:>9.3} {:>10.2} {:>13.2} {:>8} {:>11} {:>13.3}",
            r.requests,
            r.hits,
            r.hit_rate(),
            r.mean_ms,
            r.mean_hit_ms,
            r.spills,
            r.spill_hits,
            r.avg_reload_ms
        );
        rows.push(vec![
            mode.to_string(),
            r.requests.to_string(),
            r.hits.to_string(),
            format!("{:.4}", r.hit_rate()),
            format!("{:.3}", r.mean_ms),
            format!("{:.3}", r.mean_hit_ms),
            r.spills.to_string(),
            r.spill_hits.to_string(),
            format!("{:.4}", r.avg_reload_ms),
        ]);
    }
    let out = common::results_dir().join("ablation_spill.csv");
    recycle_serve::util::csv::write_file(
        &out,
        &[
            "mode", "requests", "hits", "hit_rate", "mean_ms", "mean_hit_ms",
            "spills", "spill_hits", "avg_reload_ms",
        ],
        &rows,
    )
    .expect("write csv");
    println!("\nwrote {}", out.display());
    println!(
        "spill tier: hit rate {:.0}% -> {:.0}%, mean latency {:.2} -> {:.2} ms \
         (avg reload {:.3} ms)",
        off.hit_rate() * 100.0,
        on.hit_rate() * 100.0,
        off.mean_ms,
        on.mean_ms,
        on.avg_reload_ms
    );

    assert!(
        on.hit_rate() > off.hit_rate(),
        "spill tier must recover hits drop-on-evict destroys: {:.3} !> {:.3}",
        on.hit_rate(),
        off.hit_rate()
    );
    assert!(
        on.spill_hits > 0,
        "the spill arm must actually reload from disk"
    );
    assert!(
        on.mean_ms < off.mean_ms,
        "reload overhead must stay bounded below the recompute it replaces \
         ({:.2} !< {:.2} ms)",
        on.mean_ms,
        off.mean_ms
    );

    // --- fault-rate sweep: transient cold-tier read faults ---
    // Same spill-on scenario, with a seeded fault plan failing 0% / 1% /
    // 10% of cold-tier reads. A failed reload keeps the record cold and
    // recomputes that request, so degradation must be smooth: no panic,
    // no hit-rate collapse, latency bounded by the recompute path.
    println!("\nfault-rate sweep (transient spill-read faults):");
    println!(
        "{:<10} {:>9} {:>6} {:>9} {:>10} {:>8} {:>11} {:>12}",
        "read_fault", "requests", "hits", "hit_rate", "mean_ms", "spills",
        "spill_hits", "load_errors"
    );
    let mut fault_rows: Vec<Vec<String>> = Vec::new();
    let mut swept: Vec<(f64, ArmReport)> = Vec::new();
    for rate in [0.0, 0.01, 0.10] {
        let dir = TempDir::new("bench_spill_faults");
        let h = FaultPlan::new(0xFA17)
            .with_rate(FaultSite::SpillRead, rate)
            .install();
        let rep = run(Some(&dir), passes, delay, h);
        println!(
            "{:<10.2} {:>9} {:>6} {:>9.3} {:>10.2} {:>8} {:>11} {:>12}",
            rate,
            rep.requests,
            rep.hits,
            rep.hit_rate(),
            rep.mean_ms,
            rep.spills,
            rep.spill_hits,
            rep.spill_load_errors
        );
        fault_rows.push(vec![
            format!("{rate:.2}"),
            rep.requests.to_string(),
            rep.hits.to_string(),
            format!("{:.4}", rep.hit_rate()),
            format!("{:.3}", rep.mean_ms),
            rep.spills.to_string(),
            rep.spill_hits.to_string(),
            rep.spill_load_errors.to_string(),
        ]);
        swept.push((rate, rep));
    }
    let out = common::results_dir().join("ablation_faults.csv");
    recycle_serve::util::csv::write_file(
        &out,
        &[
            "read_fault_rate", "requests", "hits", "hit_rate", "mean_ms",
            "spills", "spill_hits", "spill_load_errors",
        ],
        &fault_rows,
    )
    .expect("write csv");
    println!("wrote {}", out.display());

    let clean = &swept[0].1;
    assert_eq!(
        clean.spill_load_errors, 0,
        "a zero-rate plan must behave exactly like no plan"
    );
    assert_eq!(
        clean.hits, on.hits,
        "installed-but-zero fault plan changed behavior"
    );
    for (rate, rep) in &swept[1..] {
        let pct = *rate * 100.0;
        assert!(
            rep.hit_rate() >= 0.5 * clean.hit_rate(),
            "hit rate collapsed under {pct:.0}% read faults: \
             {:.3} vs clean {:.3}",
            rep.hit_rate(),
            clean.hit_rate()
        );
        assert!(
            rep.mean_ms <= 3.0 * clean.mean_ms.max(off.mean_ms),
            "latency blew past the recompute bound under {pct:.0}% faults: \
             {:.2} ms vs clean {:.2} / recompute {:.2}",
            rep.mean_ms,
            clean.mean_ms,
            off.mean_ms
        );
    }
}
