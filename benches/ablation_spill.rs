//! A7 — tiered KV store ablation: disk spill as the eviction destination
//! vs drop-on-evict, under an arena sized to hold HALF the cache working
//! set.
//!
//! Scenario: 8 distinct ~64-token prompts are warmed into the cache, but
//! the arena only has room for about half of them alongside serving
//! headroom — the recycler's arena-pressure pass must evict. With the
//! spill tier OFF (`max_spill_bytes = 0`, the pre-tier behavior and this
//! ablation's control arm) evicted records are destroyed, so every later
//! request for one recomputes its prefill from scratch. With the tier ON,
//! eviction serializes the record to disk and a later lookup transparently
//! reloads it (shedding a hot sibling), so the request still recycles —
//! paying a bounded reload latency instead of the full recompute.
//!
//! Reported per arm: hit rate, mean request latency, mean *hit* latency,
//! spill/reload counters, and the tier's average reload latency. The
//! spill arm must beat the control on hit rate, and — because a disk
//! reload is far cheaper than recomputing a 64-token prefill on the
//! delayed mock backend — on mean latency too (the "bounded overhead"
//! claim, asserted).
//!
//! A second sweep re-runs the spill arm under injected transient
//! cold-tier read faults (0% / 1% / 10% per reload, seeded — see
//! `recycle_serve::faults`): a failed reload falls back to recomputing
//! that request, so hit rate and latency must degrade *smoothly* with the
//! fault rate, never collapse or panic. Written to `ablation_faults.csv`.
//!
//! A third section measures the **capacity frontier** of the two
//! capacity-multiplier knobs (written to `ablation_capacity.csv`):
//!
//! * cold tier, raw v1 vs `spill_compression` (v2): same
//!   `max_spill_bytes` budget, same records — the compressed tier must
//!   retain >= 1.5x as many cold records at <= 2x the mean reload
//!   latency (the decompress cost stays bounded).
//! * hot tier, f32 blocks vs `quantized_blocks`: same `max_bytes`
//!   budget — the quantized store must admit >= 1.8x as many resident
//!   entries, and a recycled-vs-baseline run over the quantized cache
//!   must clear the output-fidelity gate (the capacity win does not
//!   count if outputs drift).
//!
//! ```bash
//! cargo bench --bench ablation_spill            # full
//! cargo bench --bench ablation_spill -- --quick # smoke
//! ```

mod common;

use std::sync::Arc;
use std::time::Duration;

use recycle_serve::bench::{overlap_workload, run_comparison, EvalOptions, OverlapSpec};
use recycle_serve::config::{CacheConfig, ModelConfig};
use recycle_serve::engine::Engine;
use recycle_serve::faults::{FaultHandle, FaultPlan, FaultSite};
use recycle_serve::index::NgramEmbedder;
use recycle_serve::kvcache::{KvArena, KvRecord, KvStore, KvView};
use recycle_serve::recycler::{RecyclePolicy, Recycler};
use recycle_serve::testutil::{MockModel, TempDir};
use recycle_serve::tokenizer::Tokenizer;

const N_PROMPTS: usize = 8;

/// ~64-token distinct documents (byte-level tokenizer: chars == tokens).
fn prompts() -> Vec<String> {
    let topics = [
        "alpha", "bravo", "charlie", "delta", "echo", "foxtrot", "golf", "hotel",
    ];
    (0..N_PROMPTS)
        .map(|i| {
            let mut s = format!("document {i} discusses {} at length: ", topics[i]);
            while s.len() < 64 {
                s.push_str(topics[i]);
                s.push(' ');
            }
            s.truncate(64);
            s
        })
        .collect()
}

struct ArmReport {
    requests: usize,
    hits: usize,
    mean_ms: f64,
    mean_hit_ms: f64,
    spills: u64,
    spill_hits: u64,
    spill_load_errors: u64,
    avg_reload_ms: f64,
}

impl ArmReport {
    fn hit_rate(&self) -> f64 {
        self.hits as f64 / self.requests as f64
    }
}

/// Run one arm: warm all prompts under arena pressure, then serve
/// `passes` rounds of extended requests over every prompt.
fn run(
    spill_dir: Option<&TempDir>,
    passes: usize,
    delay: Duration,
    faults: FaultHandle,
) -> ArmReport {
    let cfg = ModelConfig::nano();
    // Arena: 32 blocks of 16 tokens. The 8 warmed records need ~32 blocks
    // in total, and the headroom pass keeps >= 16 blocks free for serving
    // — so the hot tier can pin only about HALF the working set.
    let arena = KvArena::new(&cfg, 16, 32);
    let engine = Engine::with_arena(MockModel::with_delay(cfg, delay), arena);
    let cache = CacheConfig {
        max_entries: 0,
        max_bytes: 0,
        max_spill_bytes: if spill_dir.is_some() { 256 << 20 } else { 0 },
        spill_dir: spill_dir.map(|t| t.path_string()),
        ..Default::default()
    };
    // Radix policy: exact longest-prefix retrieval, so the two arms differ
    // only in what eviction did to the record — not in retrieval noise.
    let mut r = Recycler::new(
        engine,
        Arc::new(Tokenizer::new(vec![])),
        Box::new(NgramEmbedder::new(64)),
        cache,
        RecyclePolicy::Radix,
    );
    r.populate_cache = false;
    r.install_faults(faults);

    let docs = prompts();
    let refs: Vec<&str> = docs.iter().map(|s| s.as_str()).collect();
    r.warm(&refs).expect("warm");

    let mut report = ArmReport {
        requests: 0,
        hits: 0,
        mean_ms: 0.0,
        mean_hit_ms: 0.0,
        spills: 0,
        spill_hits: 0,
        spill_load_errors: 0,
        avg_reload_ms: 0.0,
    };
    let mut total_ms = 0.0;
    let mut hit_ms = 0.0;
    for _ in 0..passes {
        for doc in &docs {
            let q = format!("{doc} tell me more");
            let out = r.generate(&q, 8).expect("serve");
            report.requests += 1;
            total_ms += out.latency_s * 1e3;
            if out.cache_hit {
                report.hits += 1;
                hit_ms += out.latency_s * 1e3;
            }
        }
    }
    let s = r.store().stats();
    report.mean_ms = total_ms / report.requests as f64;
    report.mean_hit_ms = if report.hits > 0 {
        hit_ms / report.hits as f64
    } else {
        f64::NAN
    };
    report.spills = s.spills;
    report.spill_hits = s.spill_hits;
    report.spill_load_errors = s.spill_load_errors;
    report.avg_reload_ms = s.avg_reload_ms();
    report
}

fn main() {
    common::banner(
        "ablation_spill",
        "A7 tiered KV store: spill-on-evict vs drop-on-evict",
    );
    let passes = if common::quick() { 1 } else { 3 };
    let delay = Duration::from_micros(300);

    let tmp = TempDir::new("bench_spill");
    let off = run(None, passes, delay, FaultHandle::off());
    let on = run(Some(&tmp), passes, delay, FaultHandle::off());

    println!(
        "{:<10} {:>9} {:>6} {:>9} {:>10} {:>13} {:>8} {:>11} {:>13}",
        "mode", "requests", "hits", "hit_rate", "mean_ms", "mean_hit_ms", "spills",
        "spill_hits", "avg_reload_ms"
    );
    let mut rows: Vec<Vec<String>> = Vec::new();
    for (mode, r) in [("spill-off", &off), ("spill-on", &on)] {
        println!(
            "{mode:<10} {:>9} {:>6} {:>9.3} {:>10.2} {:>13.2} {:>8} {:>11} {:>13.3}",
            r.requests,
            r.hits,
            r.hit_rate(),
            r.mean_ms,
            r.mean_hit_ms,
            r.spills,
            r.spill_hits,
            r.avg_reload_ms
        );
        rows.push(vec![
            mode.to_string(),
            r.requests.to_string(),
            r.hits.to_string(),
            format!("{:.4}", r.hit_rate()),
            format!("{:.3}", r.mean_ms),
            format!("{:.3}", r.mean_hit_ms),
            r.spills.to_string(),
            r.spill_hits.to_string(),
            format!("{:.4}", r.avg_reload_ms),
        ]);
    }
    let out = common::results_dir().join("ablation_spill.csv");
    recycle_serve::util::csv::write_file(
        &out,
        &[
            "mode", "requests", "hits", "hit_rate", "mean_ms", "mean_hit_ms",
            "spills", "spill_hits", "avg_reload_ms",
        ],
        &rows,
    )
    .expect("write csv");
    println!("\nwrote {}", out.display());
    println!(
        "spill tier: hit rate {:.0}% -> {:.0}%, mean latency {:.2} -> {:.2} ms \
         (avg reload {:.3} ms)",
        off.hit_rate() * 100.0,
        on.hit_rate() * 100.0,
        off.mean_ms,
        on.mean_ms,
        on.avg_reload_ms
    );

    assert!(
        on.hit_rate() > off.hit_rate(),
        "spill tier must recover hits drop-on-evict destroys: {:.3} !> {:.3}",
        on.hit_rate(),
        off.hit_rate()
    );
    assert!(
        on.spill_hits > 0,
        "the spill arm must actually reload from disk"
    );
    assert!(
        on.mean_ms < off.mean_ms,
        "reload overhead must stay bounded below the recompute it replaces \
         ({:.2} !< {:.2} ms)",
        on.mean_ms,
        off.mean_ms
    );

    // --- fault-rate sweep: transient cold-tier read faults ---
    // Same spill-on scenario, with a seeded fault plan failing 0% / 1% /
    // 10% of cold-tier reads. A failed reload keeps the record cold and
    // recomputes that request, so degradation must be smooth: no panic,
    // no hit-rate collapse, latency bounded by the recompute path.
    println!("\nfault-rate sweep (transient spill-read faults):");
    println!(
        "{:<10} {:>9} {:>6} {:>9} {:>10} {:>8} {:>11} {:>12}",
        "read_fault", "requests", "hits", "hit_rate", "mean_ms", "spills",
        "spill_hits", "load_errors"
    );
    let mut fault_rows: Vec<Vec<String>> = Vec::new();
    let mut swept: Vec<(f64, ArmReport)> = Vec::new();
    for rate in [0.0, 0.01, 0.10] {
        let dir = TempDir::new("bench_spill_faults");
        let h = FaultPlan::new(0xFA17)
            .with_rate(FaultSite::SpillRead, rate)
            .install();
        let rep = run(Some(&dir), passes, delay, h);
        println!(
            "{:<10.2} {:>9} {:>6} {:>9.3} {:>10.2} {:>8} {:>11} {:>12}",
            rate,
            rep.requests,
            rep.hits,
            rep.hit_rate(),
            rep.mean_ms,
            rep.spills,
            rep.spill_hits,
            rep.spill_load_errors
        );
        fault_rows.push(vec![
            format!("{rate:.2}"),
            rep.requests.to_string(),
            rep.hits.to_string(),
            format!("{:.4}", rep.hit_rate()),
            format!("{:.3}", rep.mean_ms),
            rep.spills.to_string(),
            rep.spill_hits.to_string(),
            rep.spill_load_errors.to_string(),
        ]);
        swept.push((rate, rep));
    }
    let out = common::results_dir().join("ablation_faults.csv");
    recycle_serve::util::csv::write_file(
        &out,
        &[
            "read_fault_rate", "requests", "hits", "hit_rate", "mean_ms",
            "spills", "spill_hits", "spill_load_errors",
        ],
        &fault_rows,
    )
    .expect("write csv");
    println!("wrote {}", out.display());

    let clean = &swept[0].1;
    assert_eq!(
        clean.spill_load_errors, 0,
        "a zero-rate plan must behave exactly like no plan"
    );
    assert_eq!(
        clean.hits, on.hits,
        "installed-but-zero fault plan changed behavior"
    );
    for (rate, rep) in &swept[1..] {
        let pct = *rate * 100.0;
        assert!(
            rep.hit_rate() >= 0.5 * clean.hit_rate(),
            "hit rate collapsed under {pct:.0}% read faults: \
             {:.3} vs clean {:.3}",
            rep.hit_rate(),
            clean.hit_rate()
        );
        assert!(
            rep.mean_ms <= 3.0 * clean.mean_ms.max(off.mean_ms),
            "latency blew past the recompute bound under {pct:.0}% faults: \
             {:.2} ms vs clean {:.2} / recompute {:.2}",
            rep.mean_ms,
            clean.mean_ms,
            off.mean_ms
        );
    }

    capacity_frontier();
}

/// A record shaped like what the mock backend caches: one small-integer
/// marker per token, zeros everywhere else — deflate-friendly and exactly
/// representable by the 8-bit block format.
fn frontier_record(arena: &KvArena, len: usize, tag: usize) -> KvRecord {
    let g = arena.geometry();
    let ept = g.elems_per_token();
    let mut data = vec![0f32; ept * len];
    for t in 0..len {
        data[t * ept] = ((t + tag) % 120 + 1) as f32;
    }
    KvRecord {
        text: format!("frontier doc {tag}"),
        tokens: (0..len as u32).collect(),
        embedding: vec![1.0, 0.5],
        kv: KvView::from_contiguous(arena, &data, len).unwrap(),
    }
}

/// Cold-tier arm: hot capacity pinned to 1 so everything else lands in
/// the tier, which then enforces the shared `max_spill_bytes` budget.
/// Returns (cold records retained, mean reload ms over one reload of
/// every survivor — each reload re-spills the displaced resident, whose
/// cost the honest clock must exclude).
fn cold_capacity_arm(compressed: bool, arena: &KvArena, budget: usize, n: usize) -> (usize, f64) {
    let tmp = TempDir::new(if compressed { "bench_cap_v2" } else { "bench_cap_v1" });
    let mut store = KvStore::new(CacheConfig {
        max_entries: 1,
        max_bytes: 0,
        max_spill_bytes: budget,
        spill_dir: Some(tmp.path_string()),
        spill_compression: compressed,
        ..Default::default()
    });
    let mut ids = Vec::with_capacity(n);
    for i in 0..n {
        let (id, _) = store.insert(frontier_record(arena, 24, i));
        ids.push(id);
    }
    let cold = store.spilled_len();
    for &id in &ids {
        if store.is_spilled(id) {
            let _ = store.reload_spilled(id, arena);
        }
    }
    (cold, store.stats().avg_reload_ms())
}

/// Hot-tier arm: same `max_bytes`, f32 blocks vs quantized residents.
/// Returns (resident entries, quantized block count).
fn hot_capacity_arm(quantized: bool, arena: &KvArena, budget: usize, n: usize) -> (usize, usize) {
    let mut store = KvStore::new(CacheConfig {
        max_entries: 0,
        max_bytes: budget,
        max_spill_bytes: 0,
        quantized_blocks: quantized,
        ..Default::default()
    });
    for i in 0..n {
        store.insert(frontier_record(arena, 24, i));
    }
    (store.len(), store.stats().quantized_blocks)
}

/// The capacity-multiplier frontier: both knobs, asserted, CSV'd.
fn capacity_frontier() {
    let cfg = ModelConfig::nano();
    let arena = KvArena::new(&cfg, 16, 64);

    // --- cold tier: raw v1 vs whole-body-compressed v2 ---
    // A 24-token nano record serializes to ~98 KB raw; a 400 KB budget
    // holds 4 raw files, while the sparse payload deflates to a few
    // hundred bytes so the v2 tier keeps every spilled record.
    let cold_budget = 400_000;
    let (raw_cold, raw_reload_ms) = cold_capacity_arm(false, &arena, cold_budget, 16);
    let (v2_cold, v2_reload_ms) = cold_capacity_arm(true, &arena, cold_budget, 16);

    // --- hot tier: f32 blocks vs quantized residents ---
    // A 24-token record pins 2 arena blocks = 128 KB f32, vs ~24 KB
    // quantized; a 6-record f32 budget must fit >= 1.8x that quantized.
    let hot_budget = 6 * 2 * 16 * arena.geometry().elems_per_token() * 4;
    let (f32_len, f32_qblocks) = hot_capacity_arm(false, &arena, hot_budget, 40);
    let (q_len, q_qblocks) = hot_capacity_arm(true, &arena, hot_budget, 40);

    // --- fidelity gate over the quantized cache ---
    // Small vocab keeps every KV marker <= 127: integer-valued and in the
    // 8-bit range, so dequantize-on-attach is exact and greedy outputs
    // must stay token-identical to the baseline arm.
    let mut mcfg = ModelConfig::nano();
    mcfg.vocab_size = 64;
    let w = overlap_workload(OverlapSpec {
        pairs: 3,
        prefix_words: 10,
        suffix_words: 3,
        miss_rate: 0.0,
        seed: 9,
    });
    let report = run_comparison(
        || MockModel::with_delay(mcfg.clone(), Duration::from_micros(120)),
        Arc::new(Tokenizer::new(vec![])),
        &w,
        &EvalOptions {
            max_new_tokens: 4,
            cache: CacheConfig {
                quantized_blocks: true,
                ..Default::default()
            },
            reps: 1,
            ..Default::default()
        },
    )
    .expect("fidelity comparison");

    println!("\ncapacity frontier (same budgets, multiplier knobs on/off):");
    println!(
        "cold tier  : raw {raw_cold} records ({raw_reload_ms:.3} ms reload)  \
         compressed {v2_cold} records ({v2_reload_ms:.3} ms reload)"
    );
    println!(
        "hot tier   : f32 {f32_len} entries  quantized {q_len} entries \
         ({q_qblocks} 8-bit blocks)"
    );
    println!(
        "fidelity   : {}/{} hits, output similarity {:.4}",
        report.comparison.cache_hits,
        report.comparison.total_prompts,
        report.fidelity()
    );

    let out = common::results_dir().join("ablation_capacity.csv");
    recycle_serve::util::csv::write_file(
        &out,
        &["arm", "capacity", "metric", "value"],
        &[
            vec!["cold_raw".into(), raw_cold.to_string(), "avg_reload_ms".into(),
                 format!("{raw_reload_ms:.4}")],
            vec!["cold_compressed".into(), v2_cold.to_string(), "avg_reload_ms".into(),
                 format!("{v2_reload_ms:.4}")],
            vec!["hot_f32".into(), f32_len.to_string(), "quantized_blocks".into(),
                 f32_qblocks.to_string()],
            vec!["hot_quantized".into(), q_len.to_string(), "quantized_blocks".into(),
                 q_qblocks.to_string()],
            vec!["fidelity_quantized".into(), report.comparison.cache_hits.to_string(),
                 "output_similarity".into(), format!("{:.4}", report.fidelity())],
        ],
    )
    .expect("write csv");
    println!("wrote {}", out.display());

    // the frontier the ISSUE's capacity-multiplier claim rests on
    assert!(
        v2_cold as f64 >= 1.5 * raw_cold as f64,
        "compressed tier must hold >= 1.5x more cold records in the same \
         budget: {v2_cold} !>= 1.5 * {raw_cold}"
    );
    assert!(
        v2_reload_ms <= 2.0 * raw_reload_ms + 0.25,
        "decompress must keep reloads within 2x of raw (+0.25 ms slack): \
         {v2_reload_ms:.3} vs raw {raw_reload_ms:.3} ms"
    );
    assert!(
        q_len as f64 >= 1.8 * f32_len as f64,
        "quantized store must admit >= 1.8x entries at the same max_bytes: \
         {q_len} !>= 1.8 * {f32_len}"
    );
    assert_eq!(f32_qblocks, 0, "f32 arm must hold zero quantized blocks");
    assert!(q_qblocks > 0, "quantized arm must actually hold 8-bit blocks");
    assert!(
        report.comparison.cache_hits > 0,
        "fidelity run must exercise the quantized hit path"
    );
    assert!(
        report.passes_fidelity(0.999),
        "quantized cache failed the output-fidelity gate: {:.4}",
        report.fidelity()
    );
}
