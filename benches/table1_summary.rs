//! E1 — the paper's §5.1 summary table, on the real model + the paper's
//! exact 10-cache/6-test prompt sets. Prints the same 11 rows the paper
//! reports and writes results/{baseline,recycled}.csv.

mod common;

use recycle_serve::bench::{format_table, paper_cache_prompts, paper_test_prompts,
                           run_comparison, EvalOptions, Workload};
use recycle_serve::runtime::Runtime;

fn main() {
    common::banner("table1_summary", "paper §5.1 summary metrics table");
    let Some(artifacts) = common::artifacts_dir() else {
        println!("artifacts/ missing — run `make artifacts`; skipping");
        return;
    };
    let data = common::data_dir();
    let workload = Workload {
        cache_prompts: paper_cache_prompts(&data),
        test_prompts: paper_test_prompts(&data),
    };
    let rt0 = Runtime::load(&artifacts).expect("artifacts");
    let tokenizer = rt0.tokenizer();
    drop(rt0);

    let opts = EvalOptions {
        max_new_tokens: 32,
        results_dir: Some(common::results_dir()),
        ..Default::default()
    };
    let report = run_comparison(
        || Runtime::load(&artifacts).expect("reload"),
        tokenizer,
        &workload,
        &opts,
    )
    .expect("eval");

    println!(
        "{}",
        format_table("Paper §5.1 summary (measured, nano on CPU PJRT)", &report.summary_rows())
    );
    println!("paper reported (DialoGPT-medium on T4): hits 6/6, reuse 38.0 tok,");
    println!("  avg speedup 46.46%, out-sim 0.594, prompt-sim 0.819, >0.8: 4/6,");
    println!("  latency 0.221s -> 0.108s");
    println!("\nalpha fit: {:.3} (paper: 1.2-1.5)", report.alpha);
}
