//! E5 — the paper's §3.3 efficiency intuition: recycling wins iff
//! T_enc(k) > T_loadKV. Measures both sides of the inequality as k grows:
//! encode cost of a k-token prefix vs the cost of making a cached KV
//! record servable — as a zero-copy arena attach (the serving hit path
//! after the paged refactor), as a dense full-window copy (the
//! pre-refactor hit path, kept as the before/after baseline), and from
//! disk (raw / DEFLATE).

mod common;

use recycle_serve::engine::Engine;
use recycle_serve::kvcache::{persist, KvRecord};
use recycle_serve::runtime::Runtime;
use recycle_serve::util::timing::{Samples, Stopwatch};

fn main() {
    common::banner(
        "ablation_loadkv",
        "paper §3.3 T_enc(k) vs T_loadKV crossover (attach/copy/disk/deflate)",
    );
    let Some(artifacts) = common::artifacts_dir() else {
        println!("artifacts/ missing — run `make artifacts`; skipping");
        return;
    };
    let reps = if common::quick() { 2 } else { 5 };
    let rt = Runtime::load(&artifacts).expect("artifacts");
    let cfg = rt.config().clone();
    let mut engine = Engine::new(rt);
    let v = cfg.vocab_size as u32;
    let dir = std::env::temp_dir().join("recycle_serve_loadkv_bench");
    std::fs::create_dir_all(&dir).ok();

    println!(
        "{:<6} {:>12} {:>12} {:>12} {:>14} {:>16} {:>10}",
        "k", "T_enc(k) ms", "attach ms", "copy RAM ms", "load disk ms", "load deflate ms",
        "enc wins?"
    );

    let mut rows =
        vec!["k,t_enc_ms,t_attach_ms,t_copy_ms,t_disk_ms,t_deflate_ms".to_string()];
    for &k in &[8usize, 16, 32, 64, 128, 192] {
        let ids: Vec<u32> = (0..k as u32).map(|i| 1 + (i * 13 + 5) % (v - 1)).collect();

        // T_enc(k): prefill of k tokens from scratch
        let mut t_enc = Samples::new();
        for _ in 0..reps {
            let mut kv = engine.empty_kv();
            let sw = Stopwatch::start();
            engine.prefill(&ids, &mut kv, 0).expect("prefill");
            t_enc.push(sw.elapsed_ms());
        }

        // a real cached record for this prefix (shares the request's view)
        let mut kv = engine.empty_kv();
        engine.prefill(&ids, &mut kv, 0).expect("prefill");
        let rec = KvRecord::from_view("bench", ids.clone(), vec![1.0], &kv);

        // T_loadKV, serving hit path: zero-copy attach (block-table clone)
        let mut t_attach = Samples::new();
        for _ in 0..reps {
            let sw = Stopwatch::start();
            let view = rec.attach();
            t_attach.push(sw.elapsed_ms());
            std::hint::black_box(view);
        }

        // T_loadKV, pre-refactor hit path: dense full-window copy
        let g = engine.arena().geometry().clone();
        let full_elems = g.planes() * cfg.max_seq * g.head_dim;
        let mut t_copy = Samples::new();
        for _ in 0..reps {
            let sw = Stopwatch::start();
            let mut full = vec![0f32; full_elems];
            rec.kv.gather_into(&mut full, cfg.max_seq, k);
            t_copy.push(sw.elapsed_ms());
            std::hint::black_box(full);
        }

        // T_loadKV from disk (uncompressed / deflate), materialized + attached
        let plain = dir.join(format!("k{k}.kv"));
        let packed = dir.join(format!("k{k}.kvz"));
        persist::save(&rec, &plain, false).expect("save");
        persist::save(&rec, &packed, true).expect("save");
        let mut t_disk = Samples::new();
        let mut t_deflate = Samples::new();
        for _ in 0..reps {
            let sw = Stopwatch::start();
            let r = persist::load(&plain, engine.arena()).expect("load");
            let view = r.attach();
            t_disk.push(sw.elapsed_ms());
            std::hint::black_box(view);
            drop(r);
            let sw = Stopwatch::start();
            let r = persist::load(&packed, engine.arena()).expect("load");
            let view = r.attach();
            t_deflate.push(sw.elapsed_ms());
            std::hint::black_box(view);
            drop(r);
        }

        println!(
            "{:<6} {:>12.3} {:>12.4} {:>12.3} {:>14.3} {:>16.3} {:>10}",
            k,
            t_enc.median(),
            t_attach.median(),
            t_copy.median(),
            t_disk.median(),
            t_deflate.median(),
            t_enc.median() > t_attach.median()
        );
        rows.push(format!(
            "{k},{:.4},{:.5},{:.4},{:.4},{:.4}",
            t_enc.median(),
            t_attach.median(),
            t_copy.median(),
            t_disk.median(),
            t_deflate.median()
        ));
    }
    std::fs::write(
        common::results_dir().join("ablation_loadkv.csv"),
        rows.join("\n") + "\n",
    )
    .ok();
    std::fs::remove_dir_all(&dir).ok();
    println!("\npaper claim: loading CPU-resident KVs is cheap vs multi-layer attention");
    println!("over k tokens, so any k > 0 with T_enc(k) > T_loadKV is a net win.");
    println!("paged arena: the attach column is O(prefix blocks) — it must sit far");
    println!("below the dense copy column at every k, widening the recycling win.");
}
