//! E4 — the paper's §5.5 figure: speedup vs reuse depth, S ≈ α·k/m.
//!
//! Sweeps the k/m ratio at several prompt lengths m on the real model,
//! prints the (k/m, S) series, and fits α the way the paper's empirical
//! constant (1.2-1.5) was obtained.

mod common;

use recycle_serve::bench::format_row_series;
use recycle_serve::engine::Engine;
use recycle_serve::runtime::Runtime;
use recycle_serve::sim::fit_alpha;
use recycle_serve::util::timing::Samples;

fn main() {
    common::banner("fig_speedup_depth", "paper §5.5 speedup vs reuse depth + alpha fit");
    let Some(artifacts) = common::artifacts_dir() else {
        println!("artifacts/ missing — run `make artifacts`; skipping");
        return;
    };
    let reps = if common::quick() { 1 } else { 3 };
    let max_new = 8; // short generations isolate the encode-side effect (§3.3)

    let rt = Runtime::load(&artifacts).expect("artifacts");
    let cfg = rt.config().clone();
    let mut engine = Engine::new(rt);
    let v = cfg.vocab_size as u32;

    let mut samples: Vec<(usize, usize, f64)> = Vec::new();
    let mut series: Vec<(f64, f64)> = Vec::new();

    for &m in &[64usize, 128, 192] {
        // deterministic pseudo-prompt of m tokens
        let ids: Vec<u32> = (0..m as u32).map(|i| 1 + (i * 31 + 7) % (v - 1)).collect();
        for &ratio_pct in &[0usize, 25, 50, 75, 90] {
            let k = m * ratio_pct / 100;
            // median-of-reps timing for both arms
            let mut base_s = Samples::new();
            let mut rec_s = Samples::new();
            for _ in 0..reps {
                let b = engine
                    .generate(&ids, engine.empty_kv(), 0, max_new, false)
                    .expect("baseline");
                base_s.push(b.latency_s);
                if k > 0 {
                    let mut kv = engine.empty_kv();
                    engine.prefill(&ids[..k], &mut kv, 0).expect("warm");
                    let r = engine.generate(&ids, kv, k, max_new, false).expect("rec");
                    assert_eq!(r.ids, b.ids, "fidelity at k={k} m={m}");
                    rec_s.push(r.latency_s);
                } else {
                    rec_s.push(b.latency_s);
                }
            }
            let s = (base_s.median() - rec_s.median()) / base_s.median();
            println!(
                "m={m:<4} k={k:<4} k/m={:<5.2} base={:.4}s rec={:.4}s S={:+.1}%",
                k as f64 / m as f64,
                base_s.median(),
                rec_s.median(),
                s * 100.0
            );
            if k > 0 {
                samples.push((k, m, s));
            }
            series.push((k as f64 / m as f64, s));
        }
    }

    println!();
    println!("{}", format_row_series("fig §5.5 (k/m, speedup fraction)", &series));
    let alpha = fit_alpha(&samples);
    println!("alpha fit: {alpha:.3}   (paper: 1.2-1.5; shape: S grows ~linearly in k/m)");

    let csv: String = std::iter::once("k_over_m,speedup\n".to_string())
        .chain(series.iter().map(|(x, y)| format!("{x:.4},{y:.4}\n")))
        .collect();
    std::fs::write(common::results_dir().join("fig_speedup_depth.csv"), csv).ok();
}
