//! A8 — sharded multi-worker serving ablation: the prefix-affinity
//! router over N schedulers, with the shared spill tier as the
//! cache-mobility layer.
//!
//! Three sweeps over the real `Coordinator` (router + N workers, each a
//! full Scheduler + KvArena + KvStore + Recycler stack on its own
//! thread), driven by the delayed mock backend so wall-clock is a cost
//! model, not noise:
//!
//! 1. **Throughput scaling** — 48 distinct-family prompts submitted
//!    concurrently against 1 / 2 / 4 workers. Work is dominated by the
//!    per-token prefill delay, which serializes per worker, so tokens/s
//!    must grow with the worker count (asserted on the round-robin arms,
//!    whose placement is perfectly balanced by construction).
//!
//! 2. **Placement quality** — the seeded multi-tenant trace
//!    (`bench::multi_tenant_trace`: bursty arrivals, heavy-tailed
//!    session reuse, tenant-shared prompt templates) served serially
//!    under PrefixAffinity vs RoundRobin at 2 and 4 workers.
//!    PrefixAffinity co-locates each tenant's prefix family on one
//!    worker, so its hit set is a superset of round-robin's partitioned
//!    caches — it must win on hit rate AND mean latency (asserted).
//!
//! 3. **Cross-worker cache mobility** — 2 round-robin workers over a
//!    shared `spill_dir` with per-worker namespaces and `max_entries=1`.
//!    Worker 0 computes and then spills a record; worker 1, which never
//!    saw the prompt, must serve an extension of it by *adopting* the
//!    spilled record out of its sibling's namespace: a spill-reload hit
//!    on a worker that did not produce the record (asserted via the
//!    per-worker `adoptions` counter in `cluster_stats()`).
//!
//! ```bash
//! cargo bench --bench ablation_sharding            # full
//! cargo bench --bench ablation_sharding -- --quick # smoke
//! ```

mod common;

use std::sync::Arc;
use std::time::Duration;

use recycle_serve::bench::{multi_tenant_trace, TraceSpec};
use recycle_serve::config::{CacheConfig, ModelConfig, RoutingPolicy, ServerConfig};
use recycle_serve::coordinator::Coordinator;
use recycle_serve::engine::Engine;
use recycle_serve::index::NgramEmbedder;
use recycle_serve::kvcache::KvArena;
use recycle_serve::recycler::{RecyclePolicy, Recycler};
use recycle_serve::testutil::{MockModel, TempDir};
use recycle_serve::tokenizer::Tokenizer;
use recycle_serve::util::timing::Stopwatch;

/// Simulated per-token encode cost: large enough that prefill work
/// dominates scheduling overhead, so throughput reflects placement.
const DELAY: Duration = Duration::from_micros(200);
const MAX_NEW: usize = 8;

/// A full serving cluster on the delayed mock backend. Each worker gets
/// its own arena; when the cache has a `spill_dir`, each worker derives
/// its collision-safe namespace from its index (the production scheme).
fn cluster(
    workers: usize,
    routing: RoutingPolicy,
    cache: CacheConfig,
    arena_blocks: usize,
) -> Coordinator {
    Coordinator::spawn(
        move |w| {
            let cfg = ModelConfig::nano();
            let arena = KvArena::new(&cfg, 16, arena_blocks);
            let engine = Engine::with_arena(MockModel::with_delay(cfg, DELAY), arena);
            let mut cache = cache.clone();
            if cache.spill_dir.is_some() {
                cache.spill_namespace = format!("w{w}_");
            }
            Recycler::new(
                engine,
                Arc::new(Tokenizer::new(vec![])),
                Box::new(NgramEmbedder::new(64)),
                cache,
                RecyclePolicy::Radix,
            )
        },
        ServerConfig {
            num_workers: workers,
            routing,
            queue_capacity: 4096,
            ..Default::default()
        },
    )
}

struct ArmReport {
    phase: &'static str,
    workers: usize,
    routing: &'static str,
    requests: usize,
    hits: usize,
    mean_ms: f64,
    wall_s: f64,
    tokens_generated: u64,
    spills: u64,
    spill_hits: u64,
    adoptions: u64,
}

impl ArmReport {
    fn hit_rate(&self) -> f64 {
        self.hits as f64 / self.requests.max(1) as f64
    }
    fn tokens_per_s(&self) -> f64 {
        self.tokens_generated as f64 / self.wall_s
    }
    fn row(&self) -> Vec<String> {
        vec![
            self.phase.to_string(),
            self.workers.to_string(),
            self.routing.to_string(),
            self.requests.to_string(),
            self.hits.to_string(),
            format!("{:.4}", self.hit_rate()),
            format!("{:.3}", self.mean_ms),
            format!("{:.4}", self.wall_s),
            format!("{:.1}", self.tokens_per_s()),
            self.spills.to_string(),
            self.spill_hits.to_string(),
            self.adoptions.to_string(),
        ]
    }
}

fn report(
    phase: &'static str,
    c: &Coordinator,
    routing: &'static str,
    requests: usize,
    hits: usize,
    total_ms: f64,
    wall_s: f64,
) -> ArmReport {
    let s = c.cluster_stats();
    ArmReport {
        phase,
        workers: c.num_workers(),
        routing,
        requests,
        hits,
        mean_ms: total_ms / requests.max(1) as f64,
        wall_s,
        tokens_generated: s.aggregate.engine.tokens_generated,
        spills: s.aggregate.cache.spills,
        spill_hits: s.aggregate.cache.spill_hits,
        adoptions: s.aggregate.cache.adoptions,
    }
}

/// Distinct-family prompts (~80 byte-level tokens each, unique within
/// the leading fingerprint window) — zero recycling, pure serving work.
fn scaling_prompts(n: usize) -> Vec<String> {
    (0..n)
        .map(|i| {
            let mut s = format!("request {i:03} wants a summary of topic {i:03}: ");
            while s.len() < 80 {
                s.push_str("data ");
            }
            s.truncate(80);
            s
        })
        .collect()
}

/// Sweep 1: submit every prompt up front, then collect; wall-clock
/// covers the whole drain, so tokens/s measures cluster parallelism.
fn run_scaling(workers: usize, routing: RoutingPolicy, prompts: &[String]) -> ArmReport {
    let c = cluster(workers, routing, CacheConfig::default(), 512);
    let sw = Stopwatch::start();
    let rxs: Vec<_> = prompts
        .iter()
        .map(|p| c.submit(p, MAX_NEW, None).expect("submit"))
        .collect();
    let mut hits = 0;
    let mut total_ms = 0.0;
    for rx in rxs {
        let out = rx.recv().expect("worker reply").ok().expect("request ok");
        total_ms += out.latency_s * 1e3;
        if out.cache_hit {
            hits += 1;
        }
    }
    let wall = sw.elapsed_secs();
    let rep = report("scaling", &c, routing.name(), prompts.len(), hits, total_ms, wall);
    c.shutdown();
    rep
}

/// Sweep 2: the shared multi-tenant trace, served serially so cache
/// population is deterministic — hit rate and mean *service* latency
/// isolate placement quality from queueing.
fn run_quality(workers: usize, routing: RoutingPolicy, spec: TraceSpec) -> ArmReport {
    let c = cluster(
        workers,
        routing,
        CacheConfig {
            max_entries: 256,
            ..Default::default()
        },
        768,
    );
    let trace = multi_tenant_trace(spec);
    let mut hits = 0;
    let mut total_ms = 0.0;
    let sw = Stopwatch::start();
    for r in &trace {
        let out = match &r.session {
            Some(s) => c.chat(s, &r.prompt, r.max_new_tokens),
            None => c.generate(&r.prompt, r.max_new_tokens),
        }
        .expect("serve trace request");
        total_ms += out.latency_s * 1e3;
        if out.cache_hit {
            hits += 1;
        }
    }
    let wall = sw.elapsed_secs();
    let rep = report("quality", &c, routing.name(), trace.len(), hits, total_ms, wall);
    c.shutdown();
    rep
}

/// Sweep 3: force worker 0 to spill a record into the shared dir, then
/// make worker 1 serve an extension of it — the hit must come from
/// adopting the sibling's spilled record (cross-worker cache mobility).
fn run_adoption() -> ArmReport {
    let tmp = TempDir::new("bench_sharding_spill");
    let cache = CacheConfig {
        max_entries: 1,
        max_spill_bytes: 64 << 20,
        spill_dir: Some(tmp.path_string()),
        ..Default::default()
    };
    let c = cluster(2, RoutingPolicy::RoundRobin, cache, 64);
    let pad = |mut s: String| {
        while s.len() < 64 {
            s.push_str("corpus ");
        }
        s.truncate(64);
        s
    };
    let base = pad("shared corpus alpha, the one worth recycling: ".into());
    let fill1 = pad("unrelated filler bravo: ".into());
    let fill2 = pad("unrelated filler charlie: ".into());

    let mut hits = 0;
    let mut total_ms = 0.0;
    let sw = Stopwatch::start();
    // Round-robin over 2 workers alternates deterministically:
    //   base  -> w0 (cached hot)
    //   fill1 -> w1
    //   fill2 -> w0 (max_entries=1 evicts base -> spilled under w0_)
    //   probe -> w1 (never saw base; must adopt w0's spilled record)
    let probe = format!("{base} tell me more");
    for p in [&base, &fill1, &fill2, &probe] {
        let out = c.generate(p, MAX_NEW).expect("serve");
        total_ms += out.latency_s * 1e3;
        if out.cache_hit {
            hits += 1;
        }
    }
    let wall = sw.elapsed_secs();
    let cs = c.cluster_stats();
    let rep = report("adoption", &c, "round-robin", 4, hits, total_ms, wall);
    c.shutdown();

    assert!(
        rep.adoptions >= 1,
        "expected >= 1 cross-worker adoption, got {}",
        rep.adoptions
    );
    let adopter = cs
        .workers
        .iter()
        .find(|w| w.stats.cache.adoptions > 0)
        .expect("an adopting worker");
    assert!(
        adopter.stats.cache.spill_hits > 0,
        "an adoption is a spill-reload hit; worker {} counts none",
        adopter.worker
    );
    assert!(
        cs.workers
            .iter()
            .any(|w| w.worker != adopter.worker && w.stats.cache.spills > 0),
        "the adopted record must have been spilled by a DIFFERENT worker"
    );
    assert_eq!(rep.hits, 1, "only the probe recycles in this scenario");
    rep
}

fn arm<'a>(
    arms: &'a [ArmReport],
    phase: &str,
    routing: &str,
    workers: usize,
) -> &'a ArmReport {
    arms.iter()
        .find(|r| r.phase == phase && r.routing == routing && r.workers == workers)
        .expect("arm not found")
}

fn main() {
    common::banner(
        "ablation_sharding",
        "A8 sharded serving: router scaling, placement quality, cache mobility",
    );
    let quick = common::quick();
    let n_scaling = if quick { 24 } else { 48 };
    let spec = TraceSpec {
        tenants: 4,
        requests: if quick { 48 } else { 96 },
        mean_burst: 4,
        session_reuse: 0.3,
        min_words: 3,
        max_words: 12,
        max_new_tokens: MAX_NEW,
        seed: 0x5AFE,
    };

    let mut arms: Vec<ArmReport> = Vec::new();
    let prompts = scaling_prompts(n_scaling);
    for routing in [RoutingPolicy::RoundRobin, RoutingPolicy::PrefixAffinity] {
        for workers in [1usize, 2, 4] {
            arms.push(run_scaling(workers, routing, &prompts));
        }
    }
    for workers in [2usize, 4] {
        for routing in [RoutingPolicy::PrefixAffinity, RoutingPolicy::RoundRobin] {
            arms.push(run_quality(workers, routing, spec));
        }
    }
    arms.push(run_adoption());

    println!(
        "{:<9} {:>7} {:<16} {:>8} {:>5} {:>9} {:>9} {:>8} {:>11} {:>7} {:>11} {:>10}",
        "phase", "workers", "routing", "requests", "hits", "hit_rate", "mean_ms",
        "wall_s", "tokens_per_s", "spills", "spill_hits", "adoptions"
    );
    for r in &arms {
        println!(
            "{:<9} {:>7} {:<16} {:>8} {:>5} {:>9.3} {:>9.2} {:>8.3} {:>11.1} {:>7} {:>11} {:>10}",
            r.phase,
            r.workers,
            r.routing,
            r.requests,
            r.hits,
            r.hit_rate(),
            r.mean_ms,
            r.wall_s,
            r.tokens_per_s(),
            r.spills,
            r.spill_hits,
            r.adoptions
        );
    }
    let out = common::results_dir().join("ablation_sharding.csv");
    recycle_serve::util::csv::write_file(
        &out,
        &[
            "phase", "workers", "routing", "requests", "hits", "hit_rate",
            "mean_ms", "wall_s", "tokens_per_s", "spills", "spill_hits",
            "adoptions",
        ],
        &arms.iter().map(|r| r.row()).collect::<Vec<_>>(),
    )
    .expect("write csv");
    println!("\nwrote {}", out.display());

    // --- assertion 1: tokens/s scales with workers (round-robin arms:
    // placement is perfectly balanced, so scaling is structural) ---
    let (rr1, rr2, rr4) = (
        arm(&arms, "scaling", "round-robin", 1).tokens_per_s(),
        arm(&arms, "scaling", "round-robin", 2).tokens_per_s(),
        arm(&arms, "scaling", "round-robin", 4).tokens_per_s(),
    );
    println!(
        "\nscaling (round-robin): {rr1:.0} -> {rr2:.0} -> {rr4:.0} tokens/s \
         ({:.2}x at 2 workers, {:.2}x at 4)",
        rr2 / rr1,
        rr4 / rr1
    );
    assert!(
        rr2 > 1.2 * rr1,
        "2 workers must out-serve 1: {rr2:.0} !> 1.2 * {rr1:.0} tokens/s"
    );
    assert!(
        rr4 > 1.6 * rr1,
        "4 workers must out-serve 1 by a wide margin: {rr4:.0} !> 1.6 * {rr1:.0}"
    );
    let (pa1, pa4) = (
        arm(&arms, "scaling", "prefix-affinity", 1).tokens_per_s(),
        arm(&arms, "scaling", "prefix-affinity", 4).tokens_per_s(),
    );
    assert!(
        pa4 > 1.3 * pa1,
        "prefix-affinity must also scale (least-loaded spread of new \
         families): {pa4:.0} !> 1.3 * {pa1:.0}"
    );

    // --- assertion 2: prefix affinity beats round-robin on hit rate AND
    // mean latency at every sharded width ---
    for workers in [2usize, 4] {
        let pa = arm(&arms, "quality", "prefix-affinity", workers);
        let rr = arm(&arms, "quality", "round-robin", workers);
        println!(
            "quality at {workers} workers: hit rate {:.3} (PA) vs {:.3} (RR), \
             mean {:.2} vs {:.2} ms",
            pa.hit_rate(),
            rr.hit_rate(),
            pa.mean_ms,
            rr.mean_ms
        );
        assert!(
            pa.hit_rate() > rr.hit_rate(),
            "prefix affinity must beat round-robin on hit rate at \
             {workers} workers: {:.3} !> {:.3}",
            pa.hit_rate(),
            rr.hit_rate()
        );
        assert!(
            pa.mean_ms < rr.mean_ms,
            "prefix affinity must beat round-robin on mean latency at \
             {workers} workers: {:.2} !< {:.2} ms",
            pa.mean_ms,
            rr.mean_ms
        );
    }
    println!("adoption: cross-worker spill-reload hit confirmed");
}
