//! A1 + A2 — ablations the paper motivates but does not run:
//!   A1: eviction policy under cache pressure (LRU/LFU/FIFO/cost-aware).
//!   A2: strict full-prefix retrieval (the paper) vs radix longest-prefix
//!       (its §6.2 future work), on workloads with graded overlap.
//! Runs on the mock model with a per-token delay so hit-rate differences
//! translate into measurable latency, independent of PJRT noise.

mod common;

use std::sync::Arc;
use std::time::Duration;

use recycle_serve::bench::{overlap_workload, OverlapSpec, Table};
use recycle_serve::config::{CacheConfig, EvictionPolicy, ModelConfig};
use recycle_serve::engine::Engine;
use recycle_serve::index::NgramEmbedder;
use recycle_serve::recycler::{RecyclePolicy, Recycler};
use recycle_serve::testutil::MockModel;
use recycle_serve::tokenizer::Tokenizer;
use recycle_serve::util::rng::Rng;

fn recycler(policy: RecyclePolicy, cache: CacheConfig) -> Recycler<MockModel> {
    Recycler::new(
        Engine::new(MockModel::with_delay(
            ModelConfig::nano(),
            Duration::from_micros(100),
        )),
        Arc::new(Tokenizer::new(vec![])),
        Box::new(NgramEmbedder::new(128)),
        cache,
        policy,
    )
}

fn main() {
    common::banner("ablation_policies", "A1 eviction policies + A2 strict vs radix");

    // ---------- A1: eviction under pressure ----------
    // 24 base prompts, capacity 8; a skewed re-reference stream (some
    // prompts hot, most cold) — hit rate per policy.
    println!("== A1: eviction policy (capacity 8, 24 prompts, skewed stream) ==\n");
    let mut t = Table::new(&["policy", "hits", "misses", "hit rate", "evictions"]);
    for policy in EvictionPolicy::ALL {
        let mut r = recycler(
            RecyclePolicy::Strict,
            CacheConfig {
                max_entries: 8,
                eviction: policy,
                ..Default::default()
            },
        );
        r.populate_cache = false;
        let w = overlap_workload(OverlapSpec {
            pairs: 24,
            prefix_words: 10,
            suffix_words: 3,
            miss_rate: 0.0,
            seed: 5,
        });
        let refs: Vec<&str> = w.cache_prompts.iter().map(|s| s.as_str()).collect();
        // skewed access: hot prompts get re-inserted + re-queried more
        let mut rng = Rng::new(77);
        let mut hits = 0u32;
        let mut total = 0u32;
        for step in 0..200 {
            // Zipf-ish: 70% of queries hit the first 6 prompts
            let i = if rng.chance(0.7) { rng.below(6) } else { rng.below(24) };
            if step < 24 || rng.chance(0.15) {
                // (re)build cache entries over time
                r.insert_prompt(refs[i % refs.len()]).unwrap();
            }
            let out = r.generate(&w.test_prompts[i], 2).unwrap();
            hits += out.cache_hit as u32;
            total += 1;
        }
        let stats = r.store().stats();
        t.row(vec![
            policy.name().to_string(),
            hits.to_string(),
            (total - hits).to_string(),
            format!("{:.1}%", 100.0 * hits as f64 / total as f64),
            stats.evictions.to_string(),
        ]);
    }
    println!("{}", t.render());

    // ---------- A2: strict vs radix on graded overlap ----------
    println!("== A2: strict (paper) vs radix (future-work §6.2) ==\n");
    let mut t = Table::new(&[
        "workload", "policy", "hit rate", "avg reused toks", "mean latency ms",
    ]);
    for (wname, miss_rate, graded) in [
        ("exact-extension", 0.0, false),
        ("mixed (25% novel)", 0.25, false),
        ("graded partial overlap", 0.0, true),
    ] {
        for policy in [RecyclePolicy::Strict, RecyclePolicy::Radix] {
            let mut r = recycler(policy, CacheConfig::default());
            r.populate_cache = false;
            let w = overlap_workload(OverlapSpec {
                pairs: 16,
                prefix_words: 12,
                suffix_words: 4,
                miss_rate,
                seed: 9,
            });
            let refs: Vec<&str> = w.cache_prompts.iter().map(|s| s.as_str()).collect();
            r.warm(&refs).unwrap();
            if graded {
                // also cache the first-half prefixes so radix has graded
                // depths to find (strict retrieval usually picks the longer,
                // diverging candidate)
                for c in &w.cache_prompts {
                    let words: Vec<&str> = c.split(' ').collect();
                    let half = words[..words.len() / 2].join(" ");
                    r.insert_prompt(&half).unwrap();
                }
            }
            let queries: Vec<String> = if graded {
                // diverge in the second half: only the half-prefix matches
                w.cache_prompts
                    .iter()
                    .map(|c| {
                        let words: Vec<&str> = c.split(' ').collect();
                        let half = words[..words.len() / 2].join(" ");
                        format!("{half} entirely novel continuation words here")
                    })
                    .collect()
            } else {
                w.test_prompts.clone()
            };
            let mut hits = 0usize;
            let mut reused = 0usize;
            let mut lat = recycle_serve::util::timing::Samples::new();
            for q in &queries {
                let out = r.generate(q, 2).unwrap();
                hits += out.cache_hit as usize;
                reused += out.reuse_depth;
                lat.push(out.latency_s * 1e3);
            }
            t.row(vec![
                wname.to_string(),
                out_policy(policy),
                format!("{}/{}", hits, queries.len()),
                format!("{:.1}", reused as f64 / queries.len() as f64),
                format!("{:.2}", lat.mean()),
            ]);
        }
    }
    println!("{}", t.render());
    println!("expected shape: identical on exact-extension; radix strictly better");
    println!("on graded partial overlap (the paper's stated limitation).");
}

fn out_policy(p: RecyclePolicy) -> String {
    p.name().to_string()
}
