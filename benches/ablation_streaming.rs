//! A10 — async streaming front ablation: client-visible TTFT under
//! overload, typed load shedding, and weighted per-tenant fairness.
//!
//! Two phases over the real nonblocking TCP front (readiness loop +
//! WDRR QoS admission), using the live-tunable mock cost model
//! (`MockModel::with_shared_delay`): the recycling cache is populated
//! in a free warmup window, then the per-token price is switched on so
//! the measured window isolates queueing + decode from prompt encode.
//!
//! 1. **Streaming TTFT under overload** — the same warmed 4-tenant
//!    trace is offered at ~2x the service rate to a streaming front and
//!    to a blocking (aggregate) front, on fresh identical stacks with
//!    unit admission queues. Time-to-first-token is client-measured:
//!    the first `token` frame (streaming) vs the single aggregate reply
//!    (blocking). Streaming must at least halve p99 TTFT, the bounded
//!    queues must shed with a typed `overloaded` instead of building an
//!    unbounded wait, and the front's per-tenant ledger must agree with
//!    the client-side tallies (all asserted).
//!
//! 2. **Weighted fairness** — gold/silver/bronze tenants (weights
//!    4:2:1) flood one stack simultaneously with equal offered work and
//!    every request completes; fairness is judged on who finished
//!    early. Among the first half of completions (client completion
//!    order), each tenant's token share must reach its weight share
//!    minus a 35% tolerance (asserted). That is the WDRR pass
//!    structure, not luck: `qos_quantum_tokens == max_new` grants whole
//!    requests in exact weight proportion each pass.
//!
//! ```bash
//! cargo bench --bench ablation_streaming            # full
//! cargo bench --bench ablation_streaming -- --quick # smoke
//! ```

mod common;

use std::net::SocketAddr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use recycle_serve::bench::{multi_tenant_trace, TraceSpec};
use recycle_serve::config::{CacheConfig, ModelConfig, ServerConfig};
use recycle_serve::coordinator::Coordinator;
use recycle_serve::engine::Engine;
use recycle_serve::error::Error;
use recycle_serve::index::NgramEmbedder;
use recycle_serve::kvcache::KvArena;
use recycle_serve::recycler::{RecyclePolicy, Recycler};
use recycle_serve::server::{Server, TcpClient};
use recycle_serve::testutil::MockModel;
use recycle_serve::tokenizer::Tokenizer;
use recycle_serve::util::json::Value;
use recycle_serve::util::timing::Stopwatch;

/// Measured-window per-token cost (phase 1): decode dominates, so a
/// blocked aggregate reply costs ~`TTFT_MAX_NEW * DELAY` after dequeue.
const DELAY: Duration = Duration::from_millis(2);
/// Decode length of every measured phase-1 request (prompt + decode
/// stays under the nano model's 256-token window with margin).
const TTFT_MAX_NEW: usize = 80;
/// Warmup decode length (cache population, priced at zero).
const WARM_MAX_NEW: usize = 8;
/// Offered inter-arrival gap: 16 batch lanes complete one 80-token
/// decode every `80 * 2ms / 16 = 10ms`, so 5ms offers ~2x overload.
const PACE: Duration = Duration::from_millis(5);

/// Phase-2 decode length; equals `qos_quantum_tokens` so one WDRR pass
/// grants whole requests in exact weight proportion.
const FAIR_MAX_NEW: usize = 16;
/// Phase-2 per-token cost: cheap enough to drain the full flood fast,
/// pricey enough that completion order tracks grant order.
const FAIR_DELAY: Duration = Duration::from_micros(500);
const WEIGHTS: [(&str, usize); 3] = [("gold", 4), ("silver", 2), ("bronze", 1)];
/// First-half token share must reach `weight share * (1 - FAIR_EPS)`.
const FAIR_EPS: f64 = 0.35;

/// A served stack whose model re-reads its per-token cost from a shared
/// knob on every forward — phases retune the price without rebuilding.
struct Stack {
    server: Server,
    coordinator: Arc<Coordinator>,
    delay: Arc<AtomicU64>,
}

fn stack(cfg: ServerConfig, arena_blocks: usize) -> Stack {
    let delay = Arc::new(AtomicU64::new(0));
    let knob = Arc::clone(&delay);
    let coordinator = Arc::new(Coordinator::spawn(
        move |_w| {
            let model_cfg = ModelConfig::nano();
            let arena = KvArena::new(&model_cfg, 16, arena_blocks);
            let model = MockModel::with_shared_delay(model_cfg, knob.clone());
            Recycler::new(
                Engine::with_arena(model, arena),
                Arc::new(Tokenizer::new(vec![])),
                Box::new(NgramEmbedder::new(64)),
                CacheConfig {
                    max_entries: 256,
                    ..Default::default()
                },
                RecyclePolicy::Radix,
            )
        },
        cfg,
    ));
    let server = Server::start(Arc::clone(&coordinator), "127.0.0.1:0").expect("server start");
    Stack {
        server,
        coordinator,
        delay,
    }
}

impl Stack {
    fn set_delay(&self, d: Duration) {
        self.delay.store(d.as_nanos() as u64, Ordering::Relaxed);
    }
    fn addr(&self) -> SocketAddr {
        self.server.addr()
    }
    fn stop(self) {
        self.server.stop();
        if let Ok(c) = Arc::try_unwrap(self.coordinator) {
            c.shutdown();
        }
    }
}

/// One client-side observation (a dedicated connection per request).
#[derive(Clone)]
struct Obs {
    tenant: String,
    /// "done", a typed error kind ("overloaded", ...), or "transport".
    kind: String,
    /// Client-visible TTFT: first `token` frame (streaming) or the
    /// whole aggregate reply (blocking — its first token IS the reply).
    ttft_ms: f64,
    tokens: usize,
    done_at: Instant,
}

fn err_kind(v: &Value) -> String {
    v.get("error_kind")
        .and_then(Value::as_str)
        .unwrap_or("error")
        .to_string()
}

fn fire(addr: SocketAddr, prompt: &str, max_new: usize, tenant: &str, streaming: bool) -> Obs {
    let sent = Instant::now();
    let mut kind = "transport".to_string();
    let mut ttft_ms = f64::NAN;
    let mut tokens = 0usize;
    if let Ok(mut client) = TcpClient::connect(addr) {
        if streaming {
            if let Ok(rep) = client.generate_streaming(prompt, max_new, None, Some(tenant)) {
                if rep.is_ok() {
                    kind = "done".into();
                    tokens = rep.tokens.len();
                    if let Some(t) = rep.ttft {
                        ttft_ms = t.as_secs_f64() * 1e3;
                    }
                } else {
                    kind = err_kind(&rep.done);
                }
            }
        } else if let Ok(v) = client.request_opts(prompt, max_new, None, Some(tenant)) {
            if v.get("ok").and_then(Value::as_bool) == Some(true) {
                kind = "done".into();
                tokens = v.get("new_tokens").and_then(Value::as_usize).unwrap_or(0);
                ttft_ms = sent.elapsed().as_secs_f64() * 1e3;
            } else {
                kind = err_kind(&v);
            }
        }
    }
    Obs {
        tenant: tenant.to_string(),
        kind,
        ttft_ms,
        tokens,
        done_at: Instant::now(),
    }
}

/// Populate the recycling cache with every prompt at zero per-token
/// cost, via the coordinator (bypassing the QoS front keeps the tenant
/// ledger clean for the measured window). The stack's unit admission
/// queue sheds eagerly, so warmup retries until everything lands.
fn warm_cache(c: &Coordinator, prompts: &[(String, String)]) {
    let mut pending = Vec::new();
    for (_, p) in prompts {
        loop {
            match c.submit(p, WARM_MAX_NEW, None) {
                Ok(rx) => {
                    pending.push(rx);
                    break;
                }
                Err(Error::Overloaded { .. }) => thread::sleep(Duration::from_micros(200)),
                Err(e) => panic!("warmup submit: {e}"),
            }
        }
    }
    for rx in pending {
        rx.recv().expect("warmup reply").ok().expect("warmup ok");
    }
}

/// Nearest-rank percentile over an already-sorted sample.
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((sorted.len() as f64 * p).ceil() as usize).clamp(1, sorted.len()) - 1;
    sorted[idx]
}

/// Sum (completed, shed) over the front's per-tenant stats counters.
fn front_totals(reply: &Value) -> (usize, usize) {
    let mut completed = 0;
    let mut shed = 0;
    if let Some(Value::Obj(rows)) = reply.get("front").and_then(|f| f.get("tenants")) {
        for (_, t) in rows {
            completed += t.get("completed").and_then(Value::as_usize).unwrap_or(0);
            shed += t.get("shed").and_then(Value::as_usize).unwrap_or(0);
        }
    }
    (completed, shed)
}

struct ArmReport {
    phase: &'static str,
    arm: String,
    weight: usize,
    offered: usize,
    done: usize,
    shed: usize,
    deadline: usize,
    other: usize,
    /// Phase 1: total tokens delivered. Phase 2: tokens delivered within
    /// the first half of completions (the fairness window).
    tokens: usize,
    token_share: f64,
    /// Sorted client-visible TTFTs (ms) of completed requests.
    ttft: Vec<f64>,
    wall_s: f64,
}

impl ArmReport {
    fn p50(&self) -> f64 {
        percentile(&self.ttft, 0.50)
    }
    fn p99(&self) -> f64 {
        percentile(&self.ttft, 0.99)
    }
    fn row(&self) -> Vec<String> {
        vec![
            self.phase.to_string(),
            self.arm.clone(),
            self.offered.to_string(),
            self.done.to_string(),
            self.shed.to_string(),
            self.deadline.to_string(),
            self.tokens.to_string(),
            format!("{:.3}", self.p50()),
            format!("{:.3}", self.p99()),
            format!("{:.4}", self.token_share),
            self.weight.to_string(),
            format!("{:.4}", self.wall_s),
        ]
    }
}

fn summarize(
    phase: &'static str,
    arm: String,
    weight: usize,
    obs: &[Obs],
    wall_s: f64,
) -> ArmReport {
    let mut ttft: Vec<f64> = obs
        .iter()
        .filter(|o| o.kind == "done" && o.ttft_ms.is_finite())
        .map(|o| o.ttft_ms)
        .collect();
    ttft.sort_by(|a, b| a.partial_cmp(b).expect("finite ttft"));
    let count = |k: &str| obs.iter().filter(|o| o.kind == k).count();
    let (done, shed, deadline) =
        (count("done"), count("overloaded"), count("deadline_exceeded"));
    ArmReport {
        phase,
        arm,
        weight,
        offered: obs.len(),
        done,
        shed,
        deadline,
        other: obs.len() - done - shed - deadline,
        tokens: obs.iter().map(|o| o.tokens).sum(),
        token_share: 0.0,
        ttft,
        wall_s,
    }
}

/// The measured phase-1 workload: the seeded multi-tenant trace as
/// (tenant label, prompt) pairs. Prompts repeat the warmup exactly, so
/// measured TTFT isolates queueing + decode from prompt encode.
fn ttft_prompts(quick: bool) -> Vec<(String, String)> {
    multi_tenant_trace(TraceSpec {
        tenants: 4,
        requests: if quick { 48 } else { 96 },
        mean_burst: 3,
        session_reuse: 0.0,
        min_words: 2,
        max_words: 6,
        max_new_tokens: TTFT_MAX_NEW,
        seed: 0x57EA,
    })
    .into_iter()
    .map(|r| (format!("t{}", r.tenant), r.prompt))
    .collect()
}

/// Phase 1 arm: warm every prompt at zero cost, switch the price on,
/// then offer the trace at ~2x the service rate, one thread and one
/// connection per request (a stalled reply never delays the next
/// arrival). Checks the front's per-tenant ledger against the
/// client-side tallies before tearing the stack down.
fn run_ttft(streaming: bool, prompts: &[(String, String)]) -> ArmReport {
    let s = stack(
        ServerConfig {
            queue_capacity: 1,
            tenant_queue_capacity: 1,
            max_batch: 16,
            max_prefilling_slots: 16,
            ..Default::default()
        },
        4096,
    );
    warm_cache(&s.coordinator, prompts);
    s.set_delay(DELAY);

    let sw = Stopwatch::start();
    let (tx, rx) = mpsc::channel::<Obs>();
    let mut handles = Vec::new();
    let start = Instant::now();
    for (i, (tenant, prompt)) in prompts.iter().enumerate() {
        let target = start + PACE * i as u32;
        let now = Instant::now();
        if target > now {
            thread::sleep(target - now);
        }
        let (tx, addr) = (tx.clone(), s.addr());
        let (tenant, prompt) = (tenant.clone(), prompt.clone());
        handles.push(thread::spawn(move || {
            let _ = tx.send(fire(addr, &prompt, TTFT_MAX_NEW, &tenant, streaming));
        }));
    }
    drop(tx);
    let obs: Vec<Obs> = rx.into_iter().collect();
    for h in handles {
        let _ = h.join();
    }
    let wall = sw.elapsed_secs();

    let mut probe = TcpClient::connect(s.addr()).expect("stats probe");
    let ledger = probe.stats().expect("front stats");
    drop(probe);
    s.stop();

    let arm = if streaming { "streaming" } else { "blocking" };
    let rep = summarize("ttft", arm.to_string(), 0, &obs, wall);
    let (completed, shed) = front_totals(&ledger);
    assert_eq!(
        completed, rep.done,
        "{arm}: front per-tenant completed must match client-side done"
    );
    assert_eq!(
        shed, rep.shed,
        "{arm}: front per-tenant shed must match client-observed overloaded"
    );
    rep
}

/// Phase 2: equal offered work per weighted tenant, flooded at once
/// over one stack; every request completes, and the early completions
/// must split in weight proportion.
fn run_fairness(quick: bool) -> Vec<ArmReport> {
    let per_tenant = if quick { 20 } else { 32 };
    let s = stack(
        ServerConfig {
            queue_capacity: 1,
            max_batch: 2,
            tenant_queue_capacity: per_tenant + 2,
            qos_quantum_tokens: FAIR_MAX_NEW,
            tenant_weights: WEIGHTS.iter().map(|&(n, w)| (n.to_string(), w)).collect(),
            ..Default::default()
        },
        1024,
    );
    s.set_delay(FAIR_DELAY);

    let sw = Stopwatch::start();
    let (tx, rx) = mpsc::channel::<Obs>();
    let mut handles = Vec::new();
    for i in 0..per_tenant {
        for (name, _) in WEIGHTS {
            let (tx, addr) = (tx.clone(), s.addr());
            let prompt = format!("{name} fairness probe {i:03}");
            handles.push(thread::spawn(move || {
                let _ = tx.send(fire(addr, &prompt, FAIR_MAX_NEW, name, true));
            }));
        }
    }
    drop(tx);
    let mut obs: Vec<Obs> = rx.into_iter().collect();
    for h in handles {
        let _ = h.join();
    }
    let wall = sw.elapsed_secs();
    s.stop();

    // Everything was served; fairness is judged on WHO finished early —
    // the first half of completions in client-observed completion order.
    obs.sort_by_key(|o| o.done_at);
    let half = &obs[..obs.len() / 2];
    let half_total: usize = half.iter().map(|o| o.tokens).sum();
    WEIGHTS
        .iter()
        .map(|&(name, w)| {
            let mine: Vec<Obs> = obs.iter().filter(|o| o.tenant == name).cloned().collect();
            let half_tokens: usize = half
                .iter()
                .filter(|o| o.tenant == name)
                .map(|o| o.tokens)
                .sum();
            let mut rep = summarize("fairness", name.to_string(), w, &mine, wall);
            rep.tokens = half_tokens;
            rep.token_share = half_tokens as f64 / half_total.max(1) as f64;
            rep
        })
        .collect()
}

fn main() {
    common::banner(
        "ablation_streaming",
        "A10 streaming front: TTFT under overload, typed shedding, weighted fairness",
    );
    let quick = common::quick();
    let prompts = ttft_prompts(quick);

    let mut arms = vec![run_ttft(true, &prompts), run_ttft(false, &prompts)];
    arms.extend(run_fairness(quick));

    println!(
        "{:<9} {:<10} {:>7} {:>5} {:>5} {:>9} {:>7} {:>12} {:>12} {:>11} {:>6} {:>7}",
        "phase", "arm", "offered", "done", "shed", "deadline", "tokens", "ttft_p50_ms",
        "ttft_p99_ms", "token_share", "weight", "wall_s"
    );
    for r in &arms {
        println!(
            "{:<9} {:<10} {:>7} {:>5} {:>5} {:>9} {:>7} {:>12.2} {:>12.2} {:>11.4} {:>6} {:>7.3}",
            r.phase,
            r.arm,
            r.offered,
            r.done,
            r.shed,
            r.deadline,
            r.tokens,
            r.p50(),
            r.p99(),
            r.token_share,
            r.weight,
            r.wall_s
        );
    }
    let out = common::results_dir().join("ablation_streaming.csv");
    recycle_serve::util::csv::write_file(
        &out,
        &[
            "phase", "arm", "offered", "done", "shed", "deadline_exceeded", "tokens",
            "ttft_p50_ms", "ttft_p99_ms", "token_share", "weight", "wall_s",
        ],
        &arms.iter().map(|r| r.row()).collect::<Vec<_>>(),
    )
    .expect("write csv");
    println!("\nwrote {}", out.display());

    // --- assertion 1: overload ends in typed outcomes, never hangs ---
    // arms[0] and arms[1] are the phase-1 streaming and blocking runs
    let (stream, block) = (&arms[0], &arms[1]);
    for r in [stream, block] {
        assert_eq!(
            r.done + r.shed + r.deadline,
            r.offered,
            "{}: every request must end done/overloaded/deadline (other={})",
            r.arm,
            r.other
        );
        assert!(r.done >= 8, "{}: too few completions to compare TTFT ({})", r.arm, r.done);
        assert!(
            r.shed >= 1,
            "{}: 2x overload against unit queues must shed at least once",
            r.arm
        );
    }

    // --- assertion 2: streaming at least halves client-visible p99 TTFT ---
    println!(
        "\nttft: streaming p99 {:.1}ms vs blocking p99 {:.1}ms ({:.2}x)",
        stream.p99(),
        block.p99(),
        block.p99() / stream.p99()
    );
    assert!(
        stream.p99() * 2.0 <= block.p99(),
        "streaming must at least halve p99 TTFT under overload: {:.1}ms !<= {:.1}ms / 2",
        stream.p99(),
        block.p99()
    );

    // --- assertion 3: early completions split in weight proportion ---
    let wsum: usize = WEIGHTS.iter().map(|&(_, w)| w).sum();
    let fair: Vec<&ArmReport> = arms.iter().filter(|r| r.phase == "fairness").collect();
    for r in &fair {
        assert_eq!(
            r.done, r.offered,
            "fairness/{}: every request must complete (shed={} other={})",
            r.arm, r.shed, r.other
        );
        let floor = (r.weight as f64 / wsum as f64) * (1.0 - FAIR_EPS);
        println!(
            "fairness: {} first-half token share {:.3} (weighted floor {:.3})",
            r.arm, r.token_share, floor
        );
        assert!(
            r.token_share >= floor,
            "{} got {:.3} of the early tokens, below its weighted floor {:.3}",
            r.arm,
            r.token_share,
            floor
        );
    }
    // fairness rows follow WEIGHTS order: gold, silver, bronze
    let (gold, bronze) = (fair[0], fair[2]);
    assert!(
        gold.tokens > bronze.tokens,
        "weight 4 must land more early tokens than weight 1: {} !> {}",
        gold.tokens,
        bronze.tokens
    );
    println!("fairness: weighted early-token shares hold under WDRR admission");
}
