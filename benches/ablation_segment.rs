//! A9 — segment-grain KV recycling ablation: tier-2 (semantic segment
//! retrieval + position re-anchoring) vs exact-prefix-only serving on an
//! **offset-shifted shared-document workload**.
//!
//! The workload is the prefix tier's blind spot, built from
//! `bench::multi_tenant_trace` templates: every request carries a unique
//! head (`req NNNN` + a trace-prompt preamble), then one of two shared
//! documents assembled from the trace's own template text. The shared
//! span therefore sits at a *different token offset* in every request —
//! an exact-prefix or radix lookup can never reuse it, while the segment
//! tier retrieves it semantically, verifies the tokens verbatim, and
//! re-anchors the cached rows at the new position.
//!
//! Two arms over the delayed mock backend (per-token prefill cost, so
//! wall-clock is a cost model):
//!
//! * **exact**   — `segment_tokens = 0`: the PR-7 serving stack.
//! * **segment** — stride 16, fidelity budget 0.1.
//!
//! Asserted claims:
//!  1. the segment arm serves a nonzero segment-hit rate (the exact arm
//!     serves none by construction);
//!  2. the segment arm's mean latency beats the exact arm's (re-anchoring
//!     skips prefilling the shared span);
//!  3. measured infidelity (1 − output similarity vs a cold baseline,
//!     the `bench::eval` score) stays within the configured budget —
//!     and is exactly 0 for the exact arm (byte-identity).
//!
//! ```bash
//! cargo bench --bench ablation_segment            # full
//! cargo bench --bench ablation_segment -- --quick # smoke
//! ```

mod common;

use std::sync::Arc;
use std::time::Duration;

use recycle_serve::bench::{multi_tenant_trace, TraceRequest, TraceSpec};
use recycle_serve::config::{CacheConfig, ModelConfig};
use recycle_serve::engine::Engine;
use recycle_serve::index::NgramEmbedder;
use recycle_serve::kvcache::KvArena;
use recycle_serve::recycler::{RecyclePolicy, Recycler};
use recycle_serve::testutil::MockModel;
use recycle_serve::tokenizer::Tokenizer;

/// Simulated per-token encode cost — large enough that prefill dominates
/// lookup overhead, so latency reflects reuse, not scheduling noise.
const DELAY: Duration = Duration::from_micros(150);
const MAX_NEW: usize = 4;
const STRIDE: usize = 16;
const BUDGET: f64 = 0.1;
/// Shared-document length in characters (byte-level tokens).
const DOC_CHARS: usize = 110;
/// Per-request unique head budget in characters.
const HEAD_CHARS: usize = 50;

/// Assemble a shared document from the trace's own template/prompt text,
/// starting at a request offset so the two documents are distinct.
fn make_doc(trace: &[TraceRequest], skip: usize) -> String {
    let mut d = String::new();
    for r in trace.iter().skip(skip) {
        d.push_str(&r.prompt);
        d.push(' ');
        if d.len() >= DOC_CHARS {
            break;
        }
    }
    d.truncate(DOC_CHARS);
    d
}

/// The offset-shifted workload: unique head, then a shared document.
fn build_prompts(n: usize) -> Vec<String> {
    let trace = multi_tenant_trace(TraceSpec {
        tenants: 4,
        requests: n,
        mean_burst: 3,
        session_reuse: 0.0,
        min_words: 3,
        max_words: 8,
        max_new_tokens: MAX_NEW,
        seed: 0xD0C5,
    });
    let docs = [make_doc(&trace, 0), make_doc(&trace, n / 2)];
    trace
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let head: String = r.prompt.chars().take(HEAD_CHARS).collect();
            format!("req {i:04} {head} :: {}", docs[i % docs.len()])
        })
        .collect()
}

fn recycler(cache: CacheConfig, delayed: bool) -> Recycler<MockModel> {
    let cfg = ModelConfig::nano();
    let arena = KvArena::new(&cfg, 16, 1024);
    let model = if delayed {
        MockModel::with_delay(cfg, DELAY)
    } else {
        MockModel::new(cfg)
    };
    Recycler::new(
        Engine::with_arena(model, arena),
        Arc::new(Tokenizer::new(vec![])),
        Box::new(NgramEmbedder::new(64)),
        cache,
        RecyclePolicy::Strict,
    )
}

/// Cold no-cache reference outputs (undelayed: only the text matters).
fn baseline_texts(prompts: &[String]) -> Vec<String> {
    let mut r = recycler(CacheConfig::default(), false);
    r.policy = RecyclePolicy::Off;
    r.populate_cache = false;
    prompts
        .iter()
        .map(|p| r.generate(p, MAX_NEW).expect("baseline").text)
        .collect()
}

struct Arm {
    name: &'static str,
    requests: usize,
    hits: usize,
    segment_hits: u64,
    reanchored_tokens: u64,
    mean_ms: f64,
    mean_infidelity: f64,
    max_infidelity: f64,
}

impl Arm {
    fn hit_rate(&self) -> f64 {
        self.hits as f64 / self.requests.max(1) as f64
    }
    fn row(&self) -> Vec<String> {
        vec![
            self.name.to_string(),
            self.requests.to_string(),
            self.hits.to_string(),
            format!("{:.4}", self.hit_rate()),
            self.segment_hits.to_string(),
            self.reanchored_tokens.to_string(),
            format!("{:.3}", self.mean_ms),
            format!("{:.6}", self.mean_infidelity),
            format!("{:.6}", self.max_infidelity),
        ]
    }
}

fn run_arm(
    name: &'static str,
    cache: CacheConfig,
    prompts: &[String],
    baseline: &[String],
) -> Arm {
    let mut r = recycler(cache, true);
    let mut hits = 0usize;
    let mut total_ms = 0.0;
    let mut sum_inf = 0.0;
    let mut max_inf = 0.0f64;
    for (p, want) in prompts.iter().zip(baseline) {
        let out = r.generate(p, MAX_NEW).expect("serve");
        total_ms += out.latency_s * 1e3;
        if out.cache_hit {
            hits += 1;
        }
        // the eval-protocol fidelity score: embedding similarity of the
        // served output against the cold baseline's
        let inf = 1.0 - r.text_similarity(&out.text, want);
        sum_inf += inf;
        max_inf = max_inf.max(inf);
    }
    let s = r.store().stats();
    Arm {
        name,
        requests: prompts.len(),
        hits,
        segment_hits: s.segment_hits,
        reanchored_tokens: s.reanchored_tokens,
        mean_ms: total_ms / prompts.len().max(1) as f64,
        mean_infidelity: sum_inf / prompts.len().max(1) as f64,
        max_infidelity: max_inf,
    }
}

fn main() {
    common::banner(
        "ablation_segment",
        "A9 segment recycling: re-anchored reuse vs exact-prefix-only",
    );
    let n = if common::quick() { 24 } else { 60 };
    let prompts = build_prompts(n);
    let baseline = baseline_texts(&prompts);

    let exact = run_arm(
        "exact",
        CacheConfig {
            max_entries: 256,
            ..Default::default()
        },
        &prompts,
        &baseline,
    );
    let segment = run_arm(
        "segment",
        CacheConfig {
            max_entries: 256,
            segment_tokens: STRIDE,
            segment_fidelity_budget: BUDGET,
            ..Default::default()
        },
        &prompts,
        &baseline,
    );

    println!(
        "{:<8} {:>8} {:>5} {:>9} {:>12} {:>17} {:>9} {:>12} {:>11}",
        "arm",
        "requests",
        "hits",
        "hit_rate",
        "segment_hits",
        "reanchored_tokens",
        "mean_ms",
        "mean_infid",
        "max_infid"
    );
    for a in [&exact, &segment] {
        println!(
            "{:<8} {:>8} {:>5} {:>9.3} {:>12} {:>17} {:>9.2} {:>12.6} {:>11.6}",
            a.name,
            a.requests,
            a.hits,
            a.hit_rate(),
            a.segment_hits,
            a.reanchored_tokens,
            a.mean_ms,
            a.mean_infidelity,
            a.max_infidelity
        );
    }
    let out = common::results_dir().join("ablation_segment.csv");
    recycle_serve::util::csv::write_file(
        &out,
        &[
            "arm",
            "requests",
            "hits",
            "hit_rate",
            "segment_hits",
            "reanchored_tokens",
            "mean_ms",
            "mean_infidelity",
            "max_infidelity",
        ],
        &[exact.row(), segment.row()],
    )
    .expect("write csv");
    println!("\nwrote {}", out.display());

    // --- claim 1: only the segment tier catches offset-shifted reuse ---
    assert_eq!(
        exact.segment_hits, 0,
        "exact arm must serve zero segment hits"
    );
    assert_eq!(
        exact.hits, 0,
        "unique heads must defeat the prefix tier entirely"
    );
    assert!(
        segment.segment_hits > 0 && segment.reanchored_tokens > 0,
        "segment arm must re-anchor shared documents (got {} hits)",
        segment.segment_hits
    );

    // --- claim 2: re-anchoring skips shared-span prefill ---
    println!(
        "latency: {:.2} ms (segment) vs {:.2} ms (exact-only)",
        segment.mean_ms, exact.mean_ms
    );
    assert!(
        segment.mean_ms < exact.mean_ms,
        "segment arm must beat exact-only on mean latency: {:.2} !< {:.2} ms",
        segment.mean_ms,
        exact.mean_ms
    );

    // --- claim 3: fidelity within budget (and exact stays byte-exact) ---
    // byte-identical text; the f32 cosine self-similarity wobbles ~1e-7
    assert!(
        exact.max_infidelity <= 1e-5,
        "exact-prefix serving must be byte-identical, infidelity {}",
        exact.max_infidelity
    );
    assert!(
        segment.max_infidelity <= BUDGET,
        "segment arm infidelity {} exceeds the budget {BUDGET}",
        segment.max_infidelity
    );
    println!(
        "fidelity: max infidelity {:.6} within budget {BUDGET}",
        segment.max_infidelity
    );
}
