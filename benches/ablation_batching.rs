//! A6 — continuous-batching ablations: (1) decode tokens/s for the same
//! request stream at batch sizes {1, 4, 8}; (2) head-of-line latency under
//! a long cache-cold arrival, chunked prefill vs inline admission.
//!
//! Runs on the mock backend (no artifacts needed) with a simulated
//! per-token device cost, so the numbers isolate the *scheduling* effect:
//! `forward_batch` models one device dispatch per step (cost = slowest
//! lane), exactly like a batched decode executable — a batch of B near-
//! identical decode lanes costs ~1 lane, so tokens/s should scale with
//! occupancy. Batch size 1 reproduces the paper's request-at-a-time
//! serving and is the baseline every other row must beat.
//!
//! The head-of-line scenario drives the tick-level `Scheduler` directly:
//! three streams are decoding when a long cache-cold prompt and a short
//! "victim" request arrive together. Inline admission (chunk budget >=
//! max_seq — the PR-2 behavior) runs the whole 200-token prefill in one
//! tick, so the in-flight streams' next token and the victim's first
//! token both wait for all of it. Chunked admission bounds the per-tick
//! prefill work, so the reported worst decode stall and the victim's
//! time-to-first-token must both improve. The long prompt's own TTFT is
//! reported too: chunking cannot speed up its prefill (same total work,
//! now sharing ticks with decode), so that column stays roughly flat —
//! the win is everyone behind it no longer being blocked.
//!
//! ```bash
//! cargo bench --bench ablation_batching            # full
//! cargo bench --bench ablation_batching -- --quick # smoke
//! ```

mod common;

use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use recycle_serve::config::{ModelConfig, ServerConfig};
use recycle_serve::coordinator::{Request, Response, SchedEvent, Scheduler};
use recycle_serve::engine::{DecodeStream, Engine};
use recycle_serve::index::NgramEmbedder;
use recycle_serve::recycler::{RecyclePolicy, Recycler};
use recycle_serve::testutil::MockModel;
use recycle_serve::tokenizer::Tokenizer;
use recycle_serve::util::timing::Stopwatch;

/// Serve `n_req` prompts through the stream API at a fixed max occupancy,
/// returning (decoded tokens, wallclock seconds).
fn run(batch: usize, n_req: usize, prompt_len: usize, max_new: usize) -> (usize, f64) {
    let cfg = ModelConfig::nano();
    // 200us/token simulated device cost: decode-dominated workload
    let model = MockModel::with_delay(cfg.clone(), Duration::from_micros(200));
    let mut engine = Engine::new(model);
    let prompts: Vec<Vec<u32>> = (0..n_req)
        .map(|r| {
            (0..prompt_len)
                .map(|t| 1 + ((r * 31 + t * 7) % (cfg.vocab_size - 1)) as u32)
                .collect()
        })
        .collect();

    let sw = Stopwatch::start();
    let mut decoded = 0usize;
    let mut next = 0usize;
    let mut running: Vec<DecodeStream> = Vec::new();
    loop {
        // continuous admission: refill free slots between decode steps
        while running.len() < batch && next < n_req {
            let kv = engine.empty_kv();
            running.push(
                engine
                    .start_stream(&prompts[next], kv, 0, max_new, false)
                    .expect("start"),
            );
            next += 1;
        }
        if running.is_empty() {
            break;
        }
        let mut refs: Vec<&mut DecodeStream> = running.iter_mut().collect();
        engine.step_streams(&mut refs).expect("step");
        drop(refs);
        running.retain(|s| {
            if s.is_finished() {
                decoded += s.generated().len();
                false
            } else {
                true
            }
        });
    }
    (decoded, sw.elapsed_secs())
}

/// What the head-of-line scenario measured, all in milliseconds.
struct HolReport {
    /// Worst gap between consecutive decode dispatches after the long
    /// prompt arrived (how badly in-flight streams stalled).
    stall_ms_max: f64,
    /// Submission -> first token for the short victim arriving right
    /// behind the long prompt.
    ttft_victim_ms: f64,
    /// Submission -> first token for the long cold prompt itself.
    ttft_long_ms: f64,
}

/// Three in-flight decode streams; a 200-token cache-cold prompt and an
/// 8-token victim arrive together. Tick the scheduler to completion of
/// both arrivals, timing decode-dispatch gaps and first tokens.
/// `budget >= max_seq` reproduces inline admission (whole prefill in the
/// admission tick); small budgets are the chunked path.
fn hol_scenario(budget: usize, delay: Duration) -> HolReport {
    let cfg = ModelConfig::nano();
    let recycler = Recycler::new(
        Engine::new(MockModel::with_delay(cfg.clone(), delay)),
        Arc::new(Tokenizer::new(vec![])),
        Box::new(NgramEmbedder::new(64)),
        Default::default(),
        RecyclePolicy::Off, // every prompt is cache-cold
    );
    let mut sched = Scheduler::new(
        recycler,
        ServerConfig {
            max_batch: 8,
            prefill_chunk_tokens: budget,
            max_prefilling_slots: 2,
            populate_cache: false,
            ..Default::default()
        },
    );
    let mk_req = |id: u64, prompt: String, max_new: usize| {
        let (tx, rx) = mpsc::channel::<Response>();
        (
            Request {
                id,
                prompt,
                max_new_tokens: max_new,
                session: None,
                reply: tx,
                queued_at: Instant::now(),
            },
            rx,
        )
    };
    // phase 1: three streams decoding (keep them busy past the scenario)
    let mut keep_rx = Vec::new();
    let mut warm = Vec::new();
    for i in 0..3u64 {
        let (r, rx) = mk_req(i + 1, format!("warm prompt {i}"), 200);
        warm.push(r);
        keep_rx.push(rx);
    }
    sched.tick(warm);
    let mut guard = 0;
    while sched.stats().first_tokens < 3 {
        sched.tick(Vec::new());
        guard += 1;
        assert!(guard < 100, "warmup never produced first tokens");
    }

    // phase 2: the long cold prompt + the victim behind it
    let (long_req, long_rx) = mk_req(4, "z".repeat(200), 4);
    let (victim_req, victim_rx) = mk_req(5, "tiny ask".into(), 4);
    let injected = Instant::now();
    let mut last_decode = injected;
    let mut report = HolReport {
        stall_ms_max: 0.0,
        ttft_victim_ms: f64::NAN,
        ttft_long_ms: f64::NAN,
    };
    let mut fresh = vec![long_req, victim_req];
    let mut done = (false, false);
    let mut ticks = 0;
    while !(done.0 && done.1) {
        let out = sched.tick(std::mem::take(&mut fresh));
        let now = Instant::now();
        for (tx, resp) in out.replies {
            let _ = tx.send(resp);
        }
        for ev in &out.events {
            match ev {
                SchedEvent::DecodeStep { .. } => {
                    let gap = now.duration_since(last_decode).as_secs_f64() * 1e3;
                    report.stall_ms_max = report.stall_ms_max.max(gap);
                    last_decode = now;
                }
                SchedEvent::FirstToken { id: 4 } => {
                    report.ttft_long_ms =
                        now.duration_since(injected).as_secs_f64() * 1e3;
                }
                SchedEvent::FirstToken { id: 5 } => {
                    report.ttft_victim_ms =
                        now.duration_since(injected).as_secs_f64() * 1e3;
                }
                SchedEvent::Finished { id: 4, .. } => done.0 = true,
                SchedEvent::Finished { id: 5, .. } => done.1 = true,
                _ => {}
            }
        }
        ticks += 1;
        assert!(ticks < 10_000, "HOL scenario never converged");
    }
    drop(long_rx);
    drop(victim_rx);
    drop(keep_rx);
    report
}

fn main() {
    common::banner("ablation_batching", "A6 continuous-batching throughput");
    let (n_req, max_new) = if common::quick() { (8, 16) } else { (16, 32) };
    let prompt_len = 8;

    println!(
        "{:<8} {:>10} {:>10} {:>12} {:>10}",
        "batch", "requests", "tokens", "elapsed_s", "tok/s"
    );
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut tps_at = Vec::new();
    for &batch in &[1usize, 4, 8] {
        let (tokens, secs) = run(batch, n_req, prompt_len, max_new);
        let tps = tokens as f64 / secs;
        println!(
            "{batch:<8} {n_req:>10} {tokens:>10} {secs:>12.3} {tps:>10.1}"
        );
        rows.push(vec![
            batch.to_string(),
            n_req.to_string(),
            tokens.to_string(),
            format!("{secs:.4}"),
            format!("{tps:.1}"),
        ]);
        tps_at.push((batch, tps));
    }

    let out = common::results_dir().join("ablation_batching.csv");
    recycle_serve::util::csv::write_file(
        &out,
        &["batch", "requests", "tokens", "elapsed_s", "tokens_per_s"],
        &rows,
    )
    .expect("write csv");
    println!("\nwrote {}", out.display());

    let base = tps_at[0].1;
    for &(b, tps) in &tps_at[1..] {
        println!("batch {b} speedup over batch 1: {:.2}x", tps / base);
    }
    assert!(
        tps_at[1..].iter().all(|&(_, tps)| tps > base),
        "continuous batching must beat request-at-a-time on the mock backend"
    );

    // --- head-of-line: chunked prefill vs inline admission -------------
    println!("\nhead-of-line under a 200-token cold arrival (3 decoding):");
    println!(
        "{:<10} {:>14} {:>16} {:>14}",
        "mode", "stall_ms_max", "ttft_victim_ms", "ttft_long_ms"
    );
    let delay = Duration::from_micros(200);
    let max_seq = ModelConfig::nano().max_seq;
    let inline = hol_scenario(max_seq, delay); // whole prefill in one tick
    let chunked = hol_scenario(32, delay);
    let mut hol_rows: Vec<Vec<String>> = Vec::new();
    for (mode, r) in [("inline", &inline), ("chunked", &chunked)] {
        println!(
            "{mode:<10} {:>14.2} {:>16.2} {:>14.2}",
            r.stall_ms_max, r.ttft_victim_ms, r.ttft_long_ms
        );
        hol_rows.push(vec![
            mode.to_string(),
            format!("{:.3}", r.stall_ms_max),
            format!("{:.3}", r.ttft_victim_ms),
            format!("{:.3}", r.ttft_long_ms),
        ]);
    }
    let hol_out = common::results_dir().join("ablation_chunked_prefill.csv");
    recycle_serve::util::csv::write_file(
        &hol_out,
        &["mode", "stall_ms_max", "ttft_victim_ms", "ttft_long_ms"],
        &hol_rows,
    )
    .expect("write csv");
    println!("wrote {}", hol_out.display());
    println!(
        "chunked improves worst decode stall {:.1}x, victim TTFT {:.1}x \
         (long-prompt TTFT {:.2} -> {:.2} ms: its own prefill work is \
         unchanged by design)",
        inline.stall_ms_max / chunked.stall_ms_max,
        inline.ttft_victim_ms / chunked.ttft_victim_ms,
        inline.ttft_long_ms, chunked.ttft_long_ms,
    );
    assert!(
        chunked.stall_ms_max < inline.stall_ms_max,
        "chunked prefill must shrink the worst in-flight decode stall \
         ({:.2} vs {:.2} ms)",
        chunked.stall_ms_max,
        inline.stall_ms_max
    );
    assert!(
        chunked.ttft_victim_ms < inline.ttft_victim_ms,
        "a request behind the long cold prompt must reach its first token \
         sooner under chunked prefill ({:.2} vs {:.2} ms)",
        chunked.ttft_victim_ms,
        inline.ttft_victim_ms
    );
}
