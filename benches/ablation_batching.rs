//! A6 — continuous-batching throughput ablation: decode tokens/s for the
//! same request stream at batch sizes {1, 4, 8}.
//!
//! Runs on the mock backend (no artifacts needed) with a simulated
//! per-token device cost, so the numbers isolate the *scheduling* effect:
//! `forward_batch` models one device dispatch per step (cost = slowest
//! lane), exactly like a batched decode executable — a batch of B near-
//! identical decode lanes costs ~1 lane, so tokens/s should scale with
//! occupancy. Batch size 1 reproduces the paper's request-at-a-time
//! serving and is the baseline every other row must beat.
//!
//! ```bash
//! cargo bench --bench ablation_batching            # full
//! cargo bench --bench ablation_batching -- --quick # smoke
//! ```

mod common;

use std::time::Duration;

use recycle_serve::config::ModelConfig;
use recycle_serve::engine::{DecodeStream, Engine};
use recycle_serve::testutil::MockModel;
use recycle_serve::util::timing::Stopwatch;

/// Serve `n_req` prompts through the stream API at a fixed max occupancy,
/// returning (decoded tokens, wallclock seconds).
fn run(batch: usize, n_req: usize, prompt_len: usize, max_new: usize) -> (usize, f64) {
    let cfg = ModelConfig::nano();
    // 200us/token simulated device cost: decode-dominated workload
    let model = MockModel::with_delay(cfg.clone(), Duration::from_micros(200));
    let mut engine = Engine::new(model);
    let prompts: Vec<Vec<u32>> = (0..n_req)
        .map(|r| {
            (0..prompt_len)
                .map(|t| 1 + ((r * 31 + t * 7) % (cfg.vocab_size - 1)) as u32)
                .collect()
        })
        .collect();

    let sw = Stopwatch::start();
    let mut decoded = 0usize;
    let mut next = 0usize;
    let mut running: Vec<DecodeStream> = Vec::new();
    loop {
        // continuous admission: refill free slots between decode steps
        while running.len() < batch && next < n_req {
            let kv = engine.empty_kv();
            running.push(
                engine
                    .start_stream(&prompts[next], kv, 0, max_new, false)
                    .expect("start"),
            );
            next += 1;
        }
        if running.is_empty() {
            break;
        }
        let mut refs: Vec<&mut DecodeStream> = running.iter_mut().collect();
        engine.step_streams(&mut refs).expect("step");
        drop(refs);
        running.retain(|s| {
            if s.is_finished() {
                decoded += s.generated().len();
                false
            } else {
                true
            }
        });
    }
    (decoded, sw.elapsed_secs())
}

fn main() {
    common::banner("ablation_batching", "A6 continuous-batching throughput");
    let (n_req, max_new) = if common::quick() { (8, 16) } else { (16, 32) };
    let prompt_len = 8;

    println!(
        "{:<8} {:>10} {:>10} {:>12} {:>10}",
        "batch", "requests", "tokens", "elapsed_s", "tok/s"
    );
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut tps_at = Vec::new();
    for &batch in &[1usize, 4, 8] {
        let (tokens, secs) = run(batch, n_req, prompt_len, max_new);
        let tps = tokens as f64 / secs;
        println!(
            "{batch:<8} {n_req:>10} {tokens:>10} {secs:>12.3} {tps:>10.1}"
        );
        rows.push(vec![
            batch.to_string(),
            n_req.to_string(),
            tokens.to_string(),
            format!("{secs:.4}"),
            format!("{tps:.1}"),
        ]);
        tps_at.push((batch, tps));
    }

    let out = common::results_dir().join("ablation_batching.csv");
    recycle_serve::util::csv::write_file(
        &out,
        &["batch", "requests", "tokens", "elapsed_s", "tokens_per_s"],
        &rows,
    )
    .expect("write csv");
    println!("\nwrote {}", out.display());

    let base = tps_at[0].1;
    for &(b, tps) in &tps_at[1..] {
        println!("batch {b} speedup over batch 1: {:.2}x", tps / base);
    }
    assert!(
        tps_at[1..].iter().all(|&(_, tps)| tps > base),
        "continuous batching must beat request-at-a-time on the mock backend"
    );
}
