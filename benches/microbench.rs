//! A4 — runtime microbenchmarks: the primitive costs every other number
//! decomposes into. Used by the §Perf iteration log in EXPERIMENTS.md.
//!
//! The `KV attach` vs `KV full-copy` pair is the before/after of the paged
//! arena refactor: the old hit path inflated a trimmed record into a dense
//! `[L, 2, H, max_seq, D]` buffer (a full-context memcpy per hit); the new
//! path clones the record's block table — O(prefix blocks) refcount bumps,
//! no tensor traffic. Both are measured below at several prefix depths so
//! the scaling (flat-per-block vs linear-in-window) is visible in the
//! output.

mod common;

use recycle_serve::config::ModelConfig;
use recycle_serve::engine::ForwardModel;
use recycle_serve::index::{Embedder, FlatIndex, NgramEmbedder};
use recycle_serve::kvcache::{KvArena, KvRecord, KvView};
use recycle_serve::runtime::Runtime;
use recycle_serve::tokenizer::Tokenizer;
use recycle_serve::util::timing::measure;

fn main() {
    common::banner("microbench", "A4 runtime primitive costs");
    let reps = if common::quick() { 20 } else { 100 };

    // --- pure-Rust primitives (no artifacts needed) ---
    let cfg = ModelConfig::nano();
    let emb = NgramEmbedder::new(128);
    let text = "What is the capital of France? Also mention a nearby tourist destination.";
    let s = measure(3, reps, || {
        std::hint::black_box(emb.embed(text));
    });
    println!("ngram embed (74 chars)        : {}", s.summary_us());

    let mut index = FlatIndex::new(128);
    for i in 0..64 {
        index.add(i, &emb.embed(&format!("prompt number {i} with words")));
    }
    let q = emb.embed(text);
    let s = measure(3, reps, || {
        std::hint::black_box(index.top_k(&q, 1));
    });
    println!("flat index top-1 (64 entries) : {}", s.summary_us());

    // --- paged-KV hit-path primitives (the A4 before/after) ---
    let arena = KvArena::with_defaults(&cfg);
    let g = arena.geometry().clone();
    println!(
        "\nhit-path KV injection, {}-token blocks (before = dense full-window copy,",
        g.block_tokens
    );
    println!("after = block-table attach; attach must scale with blocks, not window)\n");
    for &k in &[32usize, 128, 256] {
        let data: Vec<f32> = (0..g.elems_per_token() * k).map(|i| i as f32 * 0.5).collect();
        let view = KvView::from_contiguous(&arena, &data, k).unwrap();
        let tokens: Vec<u32> = (0..k as u32).collect();
        let rec = KvRecord::from_view("p", tokens, vec![1.0], &view);

        // BEFORE (pre-refactor hit path): gather the trimmed payload into a
        // dense [L, 2, H, max_seq, D] request buffer.
        let full_elems = g.planes() * cfg.max_seq * g.head_dim;
        let s_copy = measure(3, reps, || {
            let mut full = vec![0f32; full_elems];
            rec.kv.gather_into(&mut full, cfg.max_seq, k);
            std::hint::black_box(full);
        });
        // AFTER (paged hit path): attach = clone the block table.
        let s_attach = measure(3, reps, || {
            std::hint::black_box(rec.attach());
        });
        println!(
            "k={k:<4} blocks={:<3} full-copy: {}",
            rec.kv_blocks(),
            s_copy.summary_us()
        );
        println!("                attach   : {}", s_attach.summary_us());
    }

    // record construction is also O(blocks) now (was: full trim memcpy)
    let data: Vec<f32> = (0..g.elems_per_token() * 32).map(|i| i as f32).collect();
    let view = KvView::from_contiguous(&arena, &data, 32).unwrap();
    let tokens: Vec<u32> = (0..32).collect();
    let s = measure(3, reps, || {
        std::hint::black_box(KvRecord::from_view("p", tokens.clone(), vec![1.0], &view));
    });
    println!("\nKV record admit (32 tok)      : {}", s.summary_us());

    // --- artifact-backed primitives ---
    let Some(artifacts) = common::artifacts_dir() else {
        println!("\nartifacts/ missing — PJRT microbenches skipped");
        return;
    };
    let rt = Runtime::load(&artifacts).expect("artifacts");
    let rcfg = rt.config().clone();
    let tok = Tokenizer::from_file(&artifacts.join("tokenizer.json")).expect("tok");

    let s = measure(3, reps, || {
        std::hint::black_box(tok.encode(text));
    });
    println!("BPE encode (74 chars)         : {}", s.summary_us());

    let rt_arena = KvArena::with_defaults(&rcfg);
    for &c in &rcfg.chunk_sizes.clone() {
        let toks: Vec<u32> = vec![5; c];
        let s = measure(2, reps.min(40), || {
            let mut kv = rt_arena.new_view();
            std::hint::black_box(rt.forward_chunk(&toks, c, &mut kv, 0).expect("fwd"));
        });
        println!("forward_chunk c={c:<3}           : {}", s.summary_us());
    }

    let ids = tok.encode(text);
    let s = measure(2, reps.min(40), || {
        std::hint::black_box(rt.embedder().embed_tokens(&ids).expect("embed"));
    });
    println!("HLO embed exec                : {}", s.summary_us());
}
