//! A4 — runtime microbenchmarks: the primitive costs every other number
//! decomposes into. Used by the §Perf iteration log in EXPERIMENTS.md.

mod common;

use recycle_serve::config::ModelConfig;
use recycle_serve::engine::ForwardModel;
use recycle_serve::index::{Embedder, FlatIndex, NgramEmbedder};
use recycle_serve::kvcache::KvRecord;
use recycle_serve::runtime::Runtime;
use recycle_serve::tokenizer::Tokenizer;
use recycle_serve::util::timing::measure;

fn main() {
    common::banner("microbench", "A4 runtime primitive costs");
    let reps = if common::quick() { 20 } else { 100 };

    // --- pure-Rust primitives (no artifacts needed) ---
    let cfg = ModelConfig::nano();
    let emb = NgramEmbedder::new(128);
    let text = "What is the capital of France? Also mention a nearby tourist destination.";
    let s = measure(3, reps, || {
        std::hint::black_box(emb.embed(text));
    });
    println!("ngram embed (74 chars)        : {}", s.summary_us());

    let mut index = FlatIndex::new(128);
    for i in 0..64 {
        index.add(i, &emb.embed(&format!("prompt number {i} with words")));
    }
    let q = emb.embed(text);
    let s = measure(3, reps, || {
        std::hint::black_box(index.top_k(&q, 1));
    });
    println!("flat index top-1 (64 entries) : {}", s.summary_us());

    let full: Vec<f32> = (0..cfg.kv_elems()).map(|i| i as f32 * 0.5).collect();
    let tokens: Vec<u32> = (0..32).collect();
    let s = measure(3, reps, || {
        std::hint::black_box(KvRecord::from_full_buffer(
            &cfg, "p", tokens.clone(), vec![1.0], &full,
        ));
    });
    println!("KV trim (32 tok of 256)       : {}", s.summary_us());
    let rec = KvRecord::from_full_buffer(&cfg, "p", tokens.clone(), vec![1.0], &full);
    let s = measure(3, reps, || {
        std::hint::black_box(rec.to_full_buffer(&cfg));
    });
    println!("KV inflate (32 tok -> full)   : {}", s.summary_us());

    // --- artifact-backed primitives ---
    let Some(artifacts) = common::artifacts_dir() else {
        println!("\nartifacts/ missing — PJRT microbenches skipped");
        return;
    };
    let rt = Runtime::load(&artifacts).expect("artifacts");
    let rcfg = rt.config().clone();
    let tok = Tokenizer::from_file(&artifacts.join("tokenizer.json")).expect("tok");

    let s = measure(3, reps, || {
        std::hint::black_box(tok.encode(text));
    });
    println!("BPE encode (74 chars)         : {}", s.summary_us());

    for &c in &rcfg.chunk_sizes.clone() {
        let toks: Vec<u32> = vec![5; c];
        let mut kv = vec![0f32; rcfg.kv_elems()];
        let s = measure(2, reps.min(40), || {
            std::hint::black_box(rt.forward_chunk(&toks, c, &mut kv, 0).expect("fwd"));
        });
        println!("forward_chunk c={c:<3}           : {}", s.summary_us());
    }

    let ids = tok.encode(text);
    let s = measure(2, reps.min(40), || {
        std::hint::black_box(rt.embedder().embed_tokens(&ids).expect("embed"));
    });
    println!("HLO embed exec                : {}", s.summary_us());
}
