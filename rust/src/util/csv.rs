//! RFC-4180-style CSV reader/writer (quoted fields, embedded commas,
//! quotes and newlines) — mirrors the paper's data/ and results/ file
//! formats (`cache_prompts.csv`, `baseline.csv`, `recycled.csv`).

use std::fs;
use std::path::Path;

use crate::error::{Error, Result};

/// Parse CSV text into rows of fields.
pub fn parse(text: &str) -> Result<Vec<Vec<String>>> {
    let mut rows = Vec::new();
    let mut row: Vec<String> = Vec::new();
    let mut field = String::new();
    let mut chars = text.chars().peekable();
    let mut in_quotes = false;
    let mut any = false;

    while let Some(c) = chars.next() {
        any = true;
        if in_quotes {
            match c {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        field.push('"');
                    } else {
                        in_quotes = false;
                    }
                }
                c => field.push(c),
            }
        } else {
            match c {
                '"' => {
                    if !field.is_empty() {
                        return Err(Error::Csv("quote inside unquoted field".into()));
                    }
                    in_quotes = true;
                }
                ',' => {
                    row.push(std::mem::take(&mut field));
                }
                '\r' => {} // tolerate CRLF
                '\n' => {
                    row.push(std::mem::take(&mut field));
                    rows.push(std::mem::take(&mut row));
                }
                c => field.push(c),
            }
        }
    }
    if in_quotes {
        return Err(Error::Csv("unterminated quoted field".into()));
    }
    if any && (!field.is_empty() || !row.is_empty()) {
        row.push(field);
        rows.push(row);
    }
    Ok(rows)
}

/// Quote a field if needed.
pub fn quote(field: &str) -> String {
    if field.contains(',') || field.contains('"') || field.contains('\n') {
        format!("\"{}\"", field.replace('"', "\"\""))
    } else {
        field.to_string()
    }
}

/// Serialize rows to CSV text.
pub fn to_string(rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    for row in rows {
        let fields: Vec<String> = row.iter().map(|f| quote(f)).collect();
        out.push_str(&fields.join(","));
        out.push('\n');
    }
    out
}

/// Read a single-column CSV with a header row (the prompt-file format).
pub fn read_single_column(path: &Path) -> Result<Vec<String>> {
    let text = fs::read_to_string(path)?;
    let rows = parse(&text)?;
    if rows.is_empty() {
        return Err(Error::Csv(format!("{}: empty", path.display())));
    }
    Ok(rows[1..].iter().map(|r| r.join(",")).collect())
}

/// Write rows (with header) to a file, creating parent dirs.
pub fn write_file(path: &Path, header: &[&str], rows: &[Vec<String>]) -> Result<()> {
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir)?;
    }
    let mut all = vec![header.iter().map(|s| s.to_string()).collect::<Vec<_>>()];
    all.extend(rows.iter().cloned());
    fs::write(path, to_string(&all))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple() {
        let rows = parse("a,b\n1,2\n").unwrap();
        assert_eq!(rows, vec![vec!["a", "b"], vec!["1", "2"]]);
    }

    #[test]
    fn quoted_fields() {
        let rows = parse("\"a,b\",\"c\"\"d\",\"e\nf\"\n").unwrap();
        assert_eq!(rows[0], vec!["a,b", "c\"d", "e\nf"]);
    }

    #[test]
    fn roundtrip() {
        let rows = vec![
            vec!["text".to_string(), "lat".to_string()],
            vec!["hello, \"world\"\nx".to_string(), "0.5".to_string()],
        ];
        let text = to_string(&rows);
        assert_eq!(parse(&text).unwrap(), rows);
    }

    #[test]
    fn no_trailing_newline() {
        let rows = parse("a,b\n1,2").unwrap();
        assert_eq!(rows.len(), 2);
    }

    #[test]
    fn crlf_tolerated() {
        let rows = parse("a,b\r\n1,2\r\n").unwrap();
        assert_eq!(rows[1], vec!["1", "2"]);
    }

    #[test]
    fn errors() {
        assert!(parse("a\"b").is_err());
        assert!(parse("\"unterminated").is_err());
    }
}
