//! CRC-32 (IEEE 802.3, the zlib/`crc32fast` polynomial) — a dependency-free
//! stand-in so persistence checksums don't pull an external crate. The
//! reflected table is built at compile time; `hash` matches
//! `crc32fast::hash` bit-for-bit (verified against the standard test
//! vectors below).

const fn make_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = make_table();

/// CRC-32 of `bytes` (init 0xFFFFFFFF, reflected, final XOR).
pub fn hash(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_vectors() {
        // The canonical CRC-32/ISO-HDLC check values.
        assert_eq!(hash(b""), 0x0000_0000);
        assert_eq!(hash(b"123456789"), 0xCBF4_3926);
        assert_eq!(hash(b"a"), 0xE8B7_BE43);
        assert_eq!(hash(b"abc"), 0x3524_41C2);
    }

    #[test]
    fn sensitive_to_single_bit() {
        let a = hash(&[0u8; 64]);
        let mut flipped = [0u8; 64];
        flipped[31] = 1;
        assert_ne!(a, hash(&flipped));
    }
}
