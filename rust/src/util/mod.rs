//! Small self-contained substrates the framework depends on.
//!
//! These exist because the offline vendor set has no serde/csv/rand/crc
//! crates: each submodule is a deliberately minimal, fully-tested stand-in.

pub mod crc32;
pub mod csv;
pub mod json;
pub mod rng;
pub mod sync;
pub mod timing;
