//! Deterministic PRNG (SplitMix64) — the offline vendor set has no `rand`.
//!
//! Used by the workload generator, the property-test harness, and eviction
//! tie-breaking. Deterministic seeding keeps every benchmark and test
//! reproducible bit-for-bit.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes (workload
/// shuffling, not cryptography).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // 128-bit multiply rejection-free mapping (Lemire).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn f64_in_unit_interval_and_spread() {
        let mut r = Rng::new(2);
        let mut lo = false;
        let mut hi = false;
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            lo |= x < 0.3;
            hi |= x > 0.7;
        }
        assert!(lo && hi, "distribution should cover the interval");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>(), "seed 3 should permute");
    }
}
