//! Poison-tolerant locking.
//!
//! A `Mutex` poisons when a thread panics while holding it; every later
//! `lock().unwrap()` then panics too, turning one crashed worker or
//! connection thread into a cascade through `stats()` / `stop()` / the
//! accept loop. For the locks in this codebase — stats counters and
//! registries whose invariants never span a panic point — the right
//! degradation is to take the inner guard and keep serving: the worst
//! case is a stale counter, not a wedged server.

use std::sync::{Mutex, MutexGuard};

/// Lock `m`, recovering the guard if a previous holder panicked.
///
/// Use only for state that is valid at every instruction boundary
/// (counters, maps of handles); state with multi-step invariants should
/// keep the poisoning panic instead.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn recovers_after_a_panicked_holder() {
        let m = Arc::new(Mutex::new(7u64));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("holder dies with the lock");
        })
        .join();
        assert!(m.lock().is_err(), "mutex must actually be poisoned");
        // the cascade repro: plain unwrap would panic here
        let mut g = lock_recover(&m);
        assert_eq!(*g, 7);
        *g += 1;
        drop(g);
        assert_eq!(*lock_recover(&m), 8);
    }

    #[test]
    fn plain_lock_passthrough() {
        let m = Mutex::new(1i32);
        *lock_recover(&m) += 1;
        assert_eq!(*lock_recover(&m), 2);
    }
}
