//! Wallclock timing + summary statistics (criterion is not vendored).
//!
//! `Stopwatch` wraps a monotonic clock; `Samples` accumulates measurements
//! and reports mean/median/percentiles/stddev. The paper's CUDA-synchronized
//! timing maps to plain monotonic timing here because PJRT CPU `execute` is
//! synchronous.

use std::time::{Duration, Instant};

/// Monotonic stopwatch.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }
    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }
    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_secs() * 1e3
    }
}

/// A set of latency/throughput samples with summary statistics.
#[derive(Debug, Clone, Default)]
pub struct Samples {
    values: Vec<f64>,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    pub fn stddev(&self) -> f64 {
        if self.values.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.values.iter().map(|v| (v - m) * (v - m)).sum::<f64>()
            / (self.values.len() - 1) as f64;
        var.sqrt()
    }

    pub fn min(&self) -> f64 {
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Nearest-rank percentile, p in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.values.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
        sorted[rank.min(sorted.len() - 1)]
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    /// One-line summary used by the bench binaries.
    pub fn summary(&self, unit: &str) -> String {
        format!(
            "n={} mean={:.4}{u} p50={:.4}{u} p95={:.4}{u} min={:.4}{u} max={:.4}{u} sd={:.4}",
            self.len(),
            self.mean(),
            self.median(),
            self.percentile(95.0),
            self.min(),
            self.max(),
            self.stddev(),
            u = unit
        )
    }

    /// Summary with seconds rendered in microseconds (sub-ms primitives).
    pub fn summary_us(&self) -> String {
        format!(
            "n={} mean={:.1}us p50={:.1}us p95={:.1}us min={:.1}us max={:.1}us",
            self.len(),
            self.mean() * 1e6,
            self.median() * 1e6,
            self.percentile(95.0) * 1e6,
            self.min() * 1e6,
            self.max() * 1e6,
        )
    }
}

/// Measure a closure `warmup + iters` times; returns per-iteration seconds.
pub fn measure<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Samples {
    for _ in 0..warmup {
        f();
    }
    let mut s = Samples::new();
    for _ in 0..iters {
        let sw = Stopwatch::start();
        f();
        s.push(sw.elapsed_secs());
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let mut s = Samples::new();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.push(v);
        }
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.stddev() - 1.5811).abs() < 1e-3);
    }

    #[test]
    fn percentile_bounds() {
        let mut s = Samples::new();
        for v in 0..100 {
            s.push(v as f64);
        }
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(100.0), 99.0);
        assert!((s.percentile(95.0) - 94.0).abs() <= 1.0);
    }

    #[test]
    fn empty_is_nan() {
        let s = Samples::new();
        assert!(s.mean().is_nan());
    }

    #[test]
    fn measure_counts() {
        let mut n = 0;
        let s = measure(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(sw.elapsed_ms() >= 1.0);
    }
}
