//! Minimal JSON parser/serializer (serde is not in the offline vendor set).
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes and
//! \uXXXX including surrogate pairs, numbers, bools, null). Object key order
//! is preserved (insertion order) so round-trips are stable. Used for the
//! artifact manifest, tokenizer file, fixtures, config files, and the TCP
//! wire protocol.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// Objects keep a Vec for order-preserving iteration plus a map for
    /// O(log n) lookup of duplicate-free keys.
    Obj(Vec<(String, Value)>),
}

impl Value {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Typed field access with a contextual error — the manifest loader uses
    /// these so a malformed artifact fails loudly with the field name.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key)
            .ok_or_else(|| Error::Json(format!("missing field '{key}'")))
    }
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| Error::Json(format!("field '{key}' is not a string")))
    }
    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| Error::Json(format!("field '{key}' is not a non-negative number")))
    }
    pub fn req_arr(&self, key: &str) -> Result<&[Value]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| Error::Json(format!("field '{key}' is not an array")))
    }

    /// Serialize to a compact JSON string.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        self.write_json(&mut out);
        out
    }

    fn write_json(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_json(out);
                }
                out.push(']');
            }
            Value::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write_json(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Builder helpers for constructing values programmatically.
pub fn obj(kvs: Vec<(&str, Value)>) -> Value {
    Value::Obj(kvs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn arr(vs: Vec<Value>) -> Value {
    Value::Arr(vs)
}
pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}
pub fn n(v: f64) -> Value {
    Value::Num(v)
}
pub fn b(v: bool) -> Value {
    Value::Bool(v)
}

/// Parse a JSON document. Trailing whitespace is allowed; trailing garbage
/// is an error.
pub fn parse(input: &str) -> Result<Value> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(Error::Json(format!("trailing garbage at byte {}", p.pos)));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        txt.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("bad low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("lone low surrogate"));
                        } else {
                            out.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let len = utf8_len(c).ok_or_else(|| self.err("bad utf8"))?;
                        let start = self.pos - 1;
                        for _ in 1..len {
                            self.bump().ok_or_else(|| self.err("truncated utf8"))?;
                        }
                        let s = std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?;
                        out.push_str(s);
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut out: Vec<(String, Value)> = Vec::new();
        let mut seen: BTreeMap<String, ()> = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            if seen.insert(k.clone(), ()).is_none() {
                out.push((k, v));
            }
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(out)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = parse(r#""a\n\t\"\\ é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ é 😀");
    }

    #[test]
    fn parse_raw_utf8() {
        let v = parse("\"café → あ\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "café → あ");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"\\ud800x\"").is_err());
        assert!(parse("tru").is_err());
    }

    #[test]
    fn roundtrip() {
        let cases = [
            r#"{"a":[1,2,3],"b":"x\ny","c":null,"d":true,"e":-0.5}"#,
            r#"[]"#,
            r#"{}"#,
            r#"[[[1]]]"#,
        ];
        for c in cases {
            let v = parse(c).unwrap();
            assert_eq!(parse(&v.to_json()).unwrap(), v, "{c}");
        }
    }

    #[test]
    fn object_order_preserved() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        assert_eq!(v.to_json(), r#"{"z":1,"a":2,"m":3}"#);
    }

    #[test]
    fn duplicate_keys_first_wins() {
        let v = parse(r#"{"a":1,"a":2}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_i64(), Some(1));
    }

    #[test]
    fn req_errors_name_the_field() {
        let v = parse(r#"{"a":1}"#).unwrap();
        let e = v.req_str("missing").unwrap_err();
        assert!(e.to_string().contains("missing"));
    }

    #[test]
    fn builders() {
        let v = obj(vec![("x", arr(vec![n(1.0), s("y")])), ("b", b(false))]);
        assert_eq!(v.to_json(), r#"{"x":[1,"y"],"b":false}"#);
    }
}
