//! The paper's §3.3 / §5.5 analytical latency model.
//!
//! Baseline ≈ T_enc(m) + T_dec(g); Recycled ≈ T_enc(m-k) + T_dec(g) +
//! T_loadKV. Recycling wins iff T_enc(k) > T_loadKV. §5.5 approximates the
//! speedup as S ≈ α·k/m; [`fit_alpha`] recovers α from measurements the way
//! the paper's empirical constant (≈1.2–1.5) was obtained.

/// Linear-cost latency model, fit from measurements by the benches.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Seconds per encoded prompt token (slope of T_enc).
    pub enc_per_token: f64,
    /// Fixed overhead per forward call (dispatch + literal marshalling).
    pub call_overhead: f64,
    /// Seconds per decoded token.
    pub dec_per_token: f64,
    /// Seconds to load + inject one cached KV token (T_loadKV slope).
    pub load_per_token: f64,
}

impl CostModel {
    /// Baseline latency for an m-token prompt and g generated tokens.
    pub fn baseline(&self, m: usize, g: usize) -> f64 {
        self.call_overhead + self.enc_per_token * m as f64 + self.dec_per_token * g as f64
    }

    /// Recycled latency with reuse depth k.
    pub fn recycled(&self, m: usize, k: usize, g: usize) -> f64 {
        assert!(k <= m);
        self.call_overhead
            + self.enc_per_token * (m - k) as f64
            + self.load_per_token * k as f64
            + self.dec_per_token * g as f64
    }

    /// Predicted speedup percentage S = (L_base - L_rec)/L_base * 100.
    pub fn speedup_pct(&self, m: usize, k: usize, g: usize) -> f64 {
        let b = self.baseline(m, g);
        (b - self.recycled(m, k, g)) / b * 100.0
    }

    /// The k at which recycling starts to win: smallest k with
    /// T_enc(k) > T_loadKV(k) (in this linear model, any k>0 iff
    /// enc slope exceeds load slope — the paper's claim; returns None if
    /// loading is never cheaper).
    pub fn breakeven_k(&self) -> Option<usize> {
        if self.enc_per_token > self.load_per_token {
            Some(1)
        } else {
            None
        }
    }
}

/// Least-squares fit of α in S ≈ α·(k/m) from (k, m, speedup_fraction)
/// samples — reproduces the paper's §5.5 empirical constant.
pub fn fit_alpha(samples: &[(usize, usize, f64)]) -> f64 {
    let mut num = 0f64;
    let mut den = 0f64;
    for &(k, m, s) in samples {
        if m == 0 {
            continue;
        }
        let x = k as f64 / m as f64;
        num += x * s;
        den += x * x;
    }
    if den == 0.0 {
        f64::NAN
    } else {
        num / den
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> CostModel {
        CostModel {
            enc_per_token: 1e-3,
            call_overhead: 2e-3,
            dec_per_token: 3e-3,
            load_per_token: 1e-5,
        }
    }

    #[test]
    fn recycled_is_faster_when_k_positive() {
        let m = model();
        assert!(m.recycled(32, 16, 10) < m.baseline(32, 10));
        assert_eq!(m.recycled(32, 0, 10), m.baseline(32, 10));
    }

    #[test]
    fn speedup_monotone_in_k() {
        let m = model();
        let s1 = m.speedup_pct(32, 8, 10);
        let s2 = m.speedup_pct(32, 24, 10);
        assert!(s2 > s1 && s1 > 0.0);
    }

    #[test]
    fn breakeven() {
        assert_eq!(model().breakeven_k(), Some(1));
        let slow_load = CostModel {
            load_per_token: 1.0,
            ..model()
        };
        assert_eq!(slow_load.breakeven_k(), None);
    }

    #[test]
    fn fit_alpha_recovers_planted_constant() {
        // Plant S = 1.35 * k/m exactly.
        let samples: Vec<(usize, usize, f64)> = (1..20)
            .map(|k| (k, 20, 1.35 * k as f64 / 20.0))
            .collect();
        let a = fit_alpha(&samples);
        assert!((a - 1.35).abs() < 1e-9, "{a}");
    }

    #[test]
    fn fit_alpha_empty_is_nan() {
        assert!(fit_alpha(&[]).is_nan());
        assert!(fit_alpha(&[(0, 0, 1.0)]).is_nan());
    }
}
