//! Analytical models: TPU roofline estimates for the L1 kernel (DESIGN.md
//! §3 — interpret-mode wallclock is not a TPU proxy, so structure is
//! estimated instead) and the paper's §3.3 efficiency model
//! `T_base ≈ T_enc(m) + T_dec(g)` vs `T_rec ≈ T_enc(m-k) + T_dec(g) + T_loadKV`.

mod cost;
mod roofline;

pub use cost::{CostModel, fit_alpha};
pub use roofline::{AttentionTile, Roofline, TpuTarget};
