//! TPU roofline estimator for the cached-attention kernel and the model
//! forward pass.
//!
//! Mirrors `python/compile/kernels/cached_attention.py::vmem_bytes` and adds
//! FLOP / HBM-byte accounting so DESIGN.md can report estimated MXU
//! utilization per config. Numbers are *estimates for a hypothetical TPU
//! target* — the CPU CI substrate only validates numerics.

use crate::config::ModelConfig;

/// A TPU-like hardware target (defaults roughly TPU v4-lite class).
#[derive(Debug, Clone, Copy)]
pub struct TpuTarget {
    /// Peak bf16 matmul throughput, FLOP/s.
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// VMEM capacity per core, bytes.
    pub vmem_bytes: usize,
}

impl Default for TpuTarget {
    fn default() -> Self {
        TpuTarget {
            peak_flops: 137e12,
            hbm_bw: 1.2e12,
            vmem_bytes: 16 << 20,
        }
    }
}

/// One (head, key-block) program instance of the cached-attention kernel.
#[derive(Debug, Clone, Copy)]
pub struct AttentionTile {
    /// Query rows resident in VMEM (chunk size C).
    pub c: usize,
    /// Head dim D.
    pub d: usize,
    /// Key tile rows BK.
    pub block_k: usize,
}

impl AttentionTile {
    /// VMEM working set in bytes (f32 on CPU validation; bf16 halves this
    /// on a real TPU). Must match kernels/cached_attention.py::vmem_bytes.
    pub fn vmem_bytes(&self) -> usize {
        4 * (self.c * self.d          // q tile
            + 2 * self.block_k * self.d // k + v tiles
            + self.c * self.d          // o accumulator
            + 2 * self.c               // m + l vectors
            + self.c * self.block_k)   // p scratch
    }

    /// MXU FLOPs per program instance: two matmuls (QK^T and PV).
    pub fn flops(&self) -> f64 {
        (2.0 * self.c as f64 * self.block_k as f64 * self.d as f64) * 2.0
    }

    /// HBM bytes streamed per instance (K and V tiles; q/o stay resident
    /// across the key-block axis).
    pub fn hbm_bytes(&self) -> f64 {
        (2 * self.block_k * self.d * 4) as f64
    }

    /// Arithmetic intensity (FLOP per HBM byte).
    pub fn intensity(&self) -> f64 {
        self.flops() / self.hbm_bytes()
    }

    /// Fraction of peak MXU this tile can sustain on `t`
    /// (min(1, intensity / machine-balance) — classic roofline).
    pub fn mxu_utilization(&self, t: &TpuTarget) -> f64 {
        let balance = t.peak_flops / t.hbm_bw;
        (self.intensity() / balance).min(1.0)
    }

    /// Does the working set fit VMEM?
    pub fn fits(&self, t: &TpuTarget) -> bool {
        self.vmem_bytes() <= t.vmem_bytes
    }
}

/// Whole-model roofline summary for a prefill chunk.
#[derive(Debug, Clone)]
pub struct Roofline {
    pub cfg: ModelConfig,
    pub target: TpuTarget,
}

impl Roofline {
    pub fn new(cfg: ModelConfig) -> Self {
        Roofline {
            cfg,
            target: TpuTarget::default(),
        }
    }

    /// FLOPs to encode a chunk of `c` new tokens against a live prefix of
    /// `cur` positions (attention + MLPs + projections, fwd only).
    pub fn chunk_flops(&self, c: usize, cur: usize) -> f64 {
        let m = &self.cfg;
        let dm = m.d_model as f64;
        let dff = m.d_ff as f64;
        let cf = c as f64;
        let span = (cur + c) as f64;
        let per_layer = 2.0 * cf * dm * (3.0 * dm)   // qkv proj
            + 2.0 * cf * span * dm * 2.0              // QK^T + PV across heads
            + 2.0 * cf * dm * dm                      // output proj
            + 2.0 * cf * dm * dff * 2.0;              // mlp
        per_layer * m.n_layer as f64 + 2.0 * cf * dm * m.vocab_size as f64
    }

    /// Estimated seconds for the chunk on the TPU target (max of compute
    /// and memory time — roofline).
    pub fn chunk_seconds(&self, c: usize, cur: usize) -> f64 {
        let flops = self.chunk_flops(c, cur);
        // weights + KV traffic dominate HBM
        let weight_bytes = 2.0 * self.param_count() as f64; // bf16
        let kv_bytes = (self.cfg.kv_bytes_for_len(cur + c)) as f64 / 2.0;
        let t = &self.target;
        (flops / t.peak_flops).max((weight_bytes + kv_bytes) / t.hbm_bw)
    }

    /// Parameter count (mirrors python param_spec arithmetic).
    pub fn param_count(&self) -> usize {
        let m = &self.cfg;
        let per_layer = 2 * m.d_model                      // ln1
            + m.d_model * 3 * m.d_model + 3 * m.d_model     // qkv
            + m.d_model * m.d_model + m.d_model             // wo
            + 2 * m.d_model                                 // ln2
            + m.d_model * m.d_ff + m.d_ff                   // fc
            + m.d_ff * m.d_model + m.d_model;               // proj
        m.vocab_size * m.d_model + m.max_seq * m.d_model
            + m.n_layer * per_layer + 2 * m.d_model
    }

    /// The fraction of prefill compute skipped by recycling a k-token
    /// prefix of an m-token prompt — the paper's efficiency intuition with
    /// real FLOP accounting instead of the linear approximation.
    pub fn recycle_flop_saving(&self, m_tokens: usize, k: usize) -> f64 {
        let full = self.chunk_flops(m_tokens, 0);
        let rest = self.chunk_flops(m_tokens - k, k);
        (full - rest) / full
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_vmem_matches_python_formula() {
        // python: f * (c*d + 2*bk*d + c*d + 2*c + c*bk) with f=4
        let t = AttentionTile { c: 8, d: 32, block_k: 64 };
        assert_eq!(t.vmem_bytes(), 4 * (8 * 32 + 2 * 64 * 32 + 8 * 32 + 16 + 8 * 64));
    }

    #[test]
    fn tile_fits_vmem_for_all_serving_shapes() {
        let target = TpuTarget::default();
        for c in [1, 8, 32, 64] {
            for block_k in [64, 128, 256] {
                let t = AttentionTile { c, d: 64, block_k };
                assert!(t.fits(&target), "c={c} bk={block_k}");
            }
        }
    }

    #[test]
    fn bigger_blocks_raise_intensity() {
        let a = AttentionTile { c: 8, d: 64, block_k: 64 };
        let b = AttentionTile { c: 32, d: 64, block_k: 64 };
        // more query rows per tile => more FLOPs per streamed KV byte
        assert!(b.intensity() > a.intensity());
        assert!(b.mxu_utilization(&TpuTarget::default())
            >= a.mxu_utilization(&TpuTarget::default()));
    }

    #[test]
    fn param_count_nano_close_to_python() {
        // nano is ~0.89M params (weight-tied head, incl. positional)
        let r = Roofline::new(ModelConfig::nano());
        let n = r.param_count();
        assert!((850_000..1_200_000).contains(&n), "{n}");
    }

    #[test]
    fn medium_param_count_is_dialogpt_scale() {
        let r = Roofline::new(ModelConfig::dialogpt_medium());
        let n = r.param_count();
        // DialoGPT-medium is ~345M (355M with positional/tied variations)
        assert!((300_000_000..420_000_000).contains(&n), "{n}");
    }

    #[test]
    fn recycle_saving_grows_with_k() {
        let r = Roofline::new(ModelConfig::nano());
        let s1 = r.recycle_flop_saving(64, 16);
        let s2 = r.recycle_flop_saving(64, 48);
        assert!(s2 > s1);
        assert!(s1 > 0.0 && s2 < 1.0);
    }

    #[test]
    fn chunk_seconds_monotone_in_work() {
        let r = Roofline::new(ModelConfig::dialogpt_medium());
        assert!(r.chunk_seconds(64, 0) <= r.chunk_seconds(64, 512));
        assert!(r.chunk_seconds(1, 0) <= r.chunk_seconds(64, 0));
    }
}
