//! The paper's algorithm: cross-prompt KV recycling.
//!
//! Per request (paper §2.5/§3.1/§4.4):
//!  1. embed the prompt,
//!  2. retrieve the most similar cached prompt (`i* = argmax <e_i, e_t>`),
//!  3. exact-prefix token test (`r == k`, strict),
//!  4. on success *attach* the cached `past_key_values` — a block-table
//!     clone over the shared [`KvArena`], O(prefix blocks), no tensor
//!     copy — and feed only the suffix; otherwise run the baseline path,
//!  5. optionally insert the new prompt's KV into the cache (the paper
//!     builds the cache in a separate offline pass — [`Recycler::warm`] —
//!     but online population is the serving-system generalization).
//!
//! Policies:
//!  * [`RecyclePolicy::Off`]      — always baseline (the paper's control arm).
//!  * [`RecyclePolicy::Strict`]   — the paper: embedding top-1 + full-prefix.
//!  * [`RecyclePolicy::Radix`]    — future-work §6.2: longest cached prefix
//!    across all entries via the token radix tree (no embedding involved in
//!    the hit decision; the embedding is still logged for similarity
//!    metrics).

use std::collections::HashMap;
use std::sync::Arc;

use crate::config::{CacheConfig, ModelConfig};
use crate::engine::{Engine, ForwardModel};
use crate::error::Result;
use crate::index::{cosine, Embedder, FlatIndex, NgramEmbedder};
use crate::kvcache::{KvArena, KvRecord, KvStore, KvView};
use crate::metrics::RequestRow;
use crate::prefix::{reuse_depth, RadixTree};
use crate::tokenizer::Tokenizer;
use crate::util::timing::Stopwatch;

/// Recycling decision policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecyclePolicy {
    Off,
    Strict,
    Radix,
}

impl RecyclePolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "off" | "baseline" => Some(Self::Off),
            "strict" | "paper" => Some(Self::Strict),
            "radix" => Some(Self::Radix),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Off => "off",
            Self::Strict => "strict",
            Self::Radix => "radix",
        }
    }
}

/// Outcome of one request through the recycler.
#[derive(Debug, Clone)]
pub struct Outcome {
    pub text: String,
    pub ids: Vec<u32>,
    pub prompt_tokens: usize,
    pub reuse_depth: usize,
    pub cache_hit: bool,
    /// Similarity of the retrieved candidate (NaN when none).
    pub similarity: f64,
    pub latency_s: f64,
    pub prefill_calls: usize,
}

impl Outcome {
    /// Convert to the paper's per-request CSV row.
    pub fn to_row(&self, prompt: &str) -> RequestRow {
        RequestRow {
            prompt: prompt.to_string(),
            output: self.text.clone(),
            latency_s: self.latency_s,
            reused_tokens: self.reuse_depth,
            prompt_similarity: self.similarity,
            cache_hit: self.cache_hit,
            prompt_tokens: self.prompt_tokens,
            new_tokens: self.ids.len(),
        }
    }
}

/// The full recycling stack over any [`ForwardModel`].
pub struct Recycler<M: ForwardModel> {
    engine: Engine<M>,
    tokenizer: Arc<Tokenizer>,
    embedder: Box<dyn Embedder>,
    store: KvStore,
    index: FlatIndex,
    radix: RadixTree,
    /// id -> tokens side table for radix eviction.
    tokens_of: HashMap<u64, Vec<u32>>,
    pub policy: RecyclePolicy,
    /// Insert served prompts into the cache (online population).
    pub populate_cache: bool,
}

impl<M: ForwardModel> Recycler<M> {
    pub fn new(
        engine: Engine<M>,
        tokenizer: Arc<Tokenizer>,
        embedder: Box<dyn Embedder>,
        cache_cfg: CacheConfig,
        policy: RecyclePolicy,
    ) -> Self {
        let dim = embedder.dim();
        Recycler {
            engine,
            tokenizer,
            embedder,
            store: KvStore::new(cache_cfg),
            index: FlatIndex::new(dim),
            radix: RadixTree::new(),
            tokens_of: HashMap::new(),
            policy,
            populate_cache: true,
        }
    }

    /// Default stack: n-gram embedder, default cache config, strict policy.
    pub fn with_defaults(engine: Engine<M>, tokenizer: Arc<Tokenizer>) -> Self {
        Self::new(
            engine,
            tokenizer,
            Box::new(NgramEmbedder::new(128)),
            CacheConfig::default(),
            RecyclePolicy::Strict,
        )
    }

    pub fn config(&self) -> &ModelConfig {
        self.engine.config()
    }

    pub fn engine(&self) -> &Engine<M> {
        &self.engine
    }

    /// The paged KV arena shared by the engine and every cache record.
    pub fn arena(&self) -> &KvArena {
        self.engine.arena()
    }

    pub fn store(&self) -> &KvStore {
        &self.store
    }

    pub fn tokenizer(&self) -> Arc<Tokenizer> {
        Arc::clone(&self.tokenizer)
    }

    pub fn cache_len(&self) -> usize {
        self.store.len()
    }

    /// Embedding of a prompt (exposed for output-similarity metrics).
    pub fn embed_text(&self, text: &str) -> Vec<f32> {
        self.embedder.embed(text)
    }

    /// Cosine similarity of two texts under the configured embedder — the
    /// paper's output-similarity metric.
    pub fn text_similarity(&self, a: &str, b: &str) -> f64 {
        cosine(&self.embedder.embed(a), &self.embedder.embed(b)) as f64
    }

    /// Build the cache from a prompt set (the paper's §4.4 cache
    /// construction pass: one forward per prompt, `use_cache=True`).
    pub fn warm(&mut self, prompts: &[&str]) -> Result<usize> {
        let mut n = 0;
        for p in prompts {
            self.insert_prompt(p)?;
            n += 1;
        }
        Ok(n)
    }

    /// Evict cache entries until the arena has headroom for one worst-case
    /// request (a full-context sequence). Cached records pin blocks; under
    /// sustained population pressure the cache must shrink rather than
    /// starve live requests into `ArenaExhausted` failures. Blocks shared
    /// with other records are only truly freed when the last holder goes,
    /// so this loops (bounded by the store size).
    fn ensure_arena_headroom(&mut self) {
        // Cap the target at half the arena: a deliberately tiny arena
        // (capacity below one full-context sequence) must not drain the
        // cache to empty on every request chasing unreachable headroom.
        let arena = self.engine.arena();
        let need = arena
            .blocks_for(self.engine.config().max_seq)
            .min(arena.capacity_blocks() / 2);
        while self.engine.arena().free_blocks() < need && !self.store.is_empty() {
            let Some((id, rec)) = self.store.evict_one() else { break };
            self.index.remove(id);
            self.radix.remove(&rec.tokens);
            self.tokens_of.remove(&id);
        }
    }

    /// Prefill a prompt and insert its KV record into the cache.
    pub fn insert_prompt(&mut self, text: &str) -> Result<u64> {
        self.ensure_arena_headroom();
        let ids = self.tokenizer.encode(text);
        let mut kv = self.engine.empty_kv();
        self.engine.prefill(&ids, &mut kv, 0)?;
        Ok(self.admit(text, ids, &kv))
    }

    /// Admit a prefilled (text, ids, kv-view) into store + index + radix.
    /// The record *shares* the view's blocks (trimmed to the prompt) — no
    /// tensor copy; a served request and its cache entry hold the same
    /// physical prefix, copy-on-write.
    fn admit(&mut self, text: &str, ids: Vec<u32>, kv: &KvView) -> u64 {
        let emb = self.embedder.embed(text);
        let rec = KvRecord::from_view(text, ids.clone(), emb.clone(), kv);
        let (id, evicted) = self.store.insert(rec);
        for (eid, erec) in evicted {
            self.index.remove(eid);
            self.radix.remove(&erec.tokens);
            self.tokens_of.remove(&eid);
        }
        self.index.add(id, &emb);
        self.radix.insert(&ids, id);
        self.tokens_of.insert(id, ids);
        id
    }

    /// The retrieval + prefix test. Returns (record, reuse_depth,
    /// similarity) on a hit; logs similarity of the candidate either way.
    fn lookup(&mut self, ids: &[u32], emb: &[f32]) -> (Option<(Arc<KvRecord>, usize)>, f64) {
        match self.policy {
            RecyclePolicy::Off => (None, f64::NAN),
            RecyclePolicy::Strict => {
                let Some((cand, sim)) = self.index.nearest(emb) else {
                    self.store.note_miss();
                    return (None, f64::NAN);
                };
                if sim < self.store.config().min_similarity {
                    self.store.note_miss();
                    return (None, sim as f64);
                }
                let Some(rec) = self.store.peek(cand) else {
                    self.store.note_miss();
                    return (None, sim as f64);
                };
                let (r, full) = reuse_depth(&rec.tokens, ids);
                if full {
                    let rec = self.store.hit(cand).expect("peeked entry exists");
                    (Some((rec, r)), sim as f64)
                } else {
                    self.store.note_miss();
                    (None, sim as f64)
                }
            }
            RecyclePolicy::Radix => {
                let Some((depth, key)) = self.radix.longest_prefix(ids) else {
                    self.store.note_miss();
                    return (None, f64::NAN);
                };
                let Some(rec) = self.store.hit(key) else {
                    return (None, f64::NAN);
                };
                debug_assert_eq!(depth, rec.token_len());
                let sim = cosine(&rec.embedding, emb) as f64;
                (Some((rec, depth)), sim)
            }
        }
    }

    /// Serve one prompt: the paper's per-test-prompt loop.
    pub fn generate(&mut self, prompt: &str, max_new_tokens: usize) -> Result<Outcome> {
        let ids = self.tokenizer.encode(prompt);
        self.generate_ids(prompt, ids, max_new_tokens, false)
    }

    /// Serve a prompt whose token ids the caller already owns (session
    /// continuation: ids may extend a previous turn's exact token sequence,
    /// which text re-tokenization cannot guarantee at BPE merge
    /// boundaries). With `admit_full`, the *entire* final sequence
    /// (prompt + generated response) is inserted into the cache so the next
    /// turn can reuse all of it.
    pub fn generate_ids(
        &mut self,
        prompt: &str,
        ids: Vec<u32>,
        max_new_tokens: usize,
        admit_full: bool,
    ) -> Result<Outcome> {
        let sw = Stopwatch::start();
        // Shed cache entries first if the arena is running low — a live
        // request must never starve on blocks pinned by cold cache state.
        self.ensure_arena_headroom();
        let emb = self.embedder.embed(prompt);
        let (hit, similarity) = self.lookup(&ids, &emb);

        let (kv, cur_len, cache_hit, depth) = match hit {
            Some((rec, depth)) => {
                // Zero-copy injection: attach the record's block table
                // (refcount bumps, O(prefix blocks) — no tensor memcpy).
                (rec.attach(), depth, true, depth)
            }
            None => (self.engine.empty_kv(), 0, false, 0),
        };

        let want_capture = self.populate_cache && !cache_hit && !admit_full;
        let g = self
            .engine
            .generate(&ids, kv, cur_len, max_new_tokens, want_capture)?;

        if let Some(prompt_kv) = g.prompt_kv {
            self.admit(prompt, ids.clone(), &prompt_kv);
        }
        if admit_full && self.populate_cache {
            // Cache prompt + response (token-exact), the session fast path.
            // The record shares the request's final view — turn N+1's
            // attach reuses turn N's blocks outright.
            let mut full_ids = ids.clone();
            full_ids.extend_from_slice(&g.ids);
            let full_text = format!("{prompt}{}", self.tokenizer.decode(&g.ids));
            self.admit(&full_text, full_ids, &g.final_kv);
        }

        Ok(Outcome {
            text: self.tokenizer.decode(&g.ids),
            ids: g.ids,
            prompt_tokens: g.prompt_tokens,
            reuse_depth: depth,
            cache_hit,
            similarity,
            latency_s: sw.elapsed_secs(),
            prefill_calls: g.prefill_calls,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EvictionPolicy;
    use crate::testutil::MockModel;

    fn toy_tokenizer() -> Arc<Tokenizer> {
        Arc::new(Tokenizer::new(vec![
            ("t".into(), "h".into()),
            ("th".into(), "e".into()),
        ]))
    }

    fn recycler(policy: RecyclePolicy) -> Recycler<MockModel> {
        let engine = Engine::new(MockModel::new(ModelConfig::nano()));
        Recycler::new(
            engine,
            toy_tokenizer(),
            Box::new(NgramEmbedder::new(64)),
            CacheConfig {
                max_entries: 8,
                eviction: EvictionPolicy::Lru,
                ..Default::default()
            },
            policy,
        )
    }

    const CACHE: &str = "what is the capital of france?";
    const TEST: &str = "what is the capital of france? also mention a nearby town.";
    const OTHER: &str = "how do rockets launch into orbit today?";

    #[test]
    fn strict_hit_on_extended_prompt() {
        let mut r = recycler(RecyclePolicy::Strict);
        r.warm(&[CACHE, OTHER]).unwrap();
        let out = r.generate(TEST, 4).unwrap();
        assert!(out.cache_hit);
        let cache_len = r.tokenizer().encode(CACHE).len();
        assert_eq!(out.reuse_depth, cache_len);
        assert!(out.similarity > 0.5);
    }

    #[test]
    fn recycled_output_identical_to_baseline() {
        // the paper's fidelity claim, end-to-end through the recycler
        let mut base = recycler(RecyclePolicy::Off);
        let baseline = base.generate(TEST, 6).unwrap();
        let mut rec = recycler(RecyclePolicy::Strict);
        rec.warm(&[CACHE]).unwrap();
        let recycled = rec.generate(TEST, 6).unwrap();
        assert!(recycled.cache_hit);
        assert_eq!(recycled.ids, baseline.ids);
        assert_eq!(recycled.text, baseline.text);
    }

    #[test]
    fn miss_on_unrelated_prompt_falls_back() {
        let mut r = recycler(RecyclePolicy::Strict);
        r.warm(&[CACHE]).unwrap();
        let out = r.generate(OTHER, 4).unwrap();
        assert!(!out.cache_hit);
        assert_eq!(out.reuse_depth, 0);
        // behaviour matches baseline
        let mut b = recycler(RecyclePolicy::Off);
        assert_eq!(b.generate(OTHER, 4).unwrap().ids, out.ids);
    }

    #[test]
    fn diverging_prompt_with_high_similarity_is_rejected() {
        // shares words (high embedding similarity) but not a token prefix
        let mut r = recycler(RecyclePolicy::Strict);
        r.warm(&["what is the capital of france?"]).unwrap();
        let out = r
            .generate("what is the capital of germany? france is nearby.", 4)
            .unwrap();
        assert!(!out.cache_hit, "prefix test must reject sim={}", out.similarity);
    }

    #[test]
    fn off_policy_never_hits() {
        let mut r = recycler(RecyclePolicy::Off);
        r.warm(&[CACHE]).unwrap();
        let out = r.generate(TEST, 4).unwrap();
        assert!(!out.cache_hit);
    }

    #[test]
    fn radix_hits_deepest_entry() {
        let mut r = recycler(RecyclePolicy::Radix);
        r.populate_cache = false;
        r.warm(&["what is", "what is the capital of france?"]).unwrap();
        let out = r.generate(TEST, 4).unwrap();
        assert!(out.cache_hit);
        let deep_len = r.tokenizer().encode("what is the capital of france?").len();
        assert_eq!(out.reuse_depth, deep_len);
    }

    #[test]
    fn radix_equals_baseline_output() {
        let mut base = recycler(RecyclePolicy::Off);
        let baseline = base.generate(TEST, 5).unwrap();
        let mut r = recycler(RecyclePolicy::Radix);
        r.warm(&[CACHE]).unwrap();
        let out = r.generate(TEST, 5).unwrap();
        assert!(out.cache_hit);
        assert_eq!(out.ids, baseline.ids);
    }

    #[test]
    fn online_population_enables_future_hits() {
        let mut r = recycler(RecyclePolicy::Strict);
        assert_eq!(r.cache_len(), 0);
        r.generate(CACHE, 2).unwrap(); // miss, but populates
        assert_eq!(r.cache_len(), 1);
        let out = r.generate(TEST, 2).unwrap(); // now hits
        assert!(out.cache_hit);
    }

    #[test]
    fn arena_pressure_sheds_cache_instead_of_failing_requests() {
        // A deliberately tiny arena: room for ~3 full-context sequences.
        // Sustained online population must evict cache entries to keep
        // serving, never surface ArenaExhausted to a request.
        let cfg = ModelConfig::nano();
        let arena = crate::kvcache::KvArena::new(&cfg, 16, 3 * cfg.max_seq / 16);
        let engine = Engine::with_arena(MockModel::new(cfg), arena);
        let mut r = Recycler::new(
            engine,
            toy_tokenizer(),
            Box::new(NgramEmbedder::new(64)),
            CacheConfig {
                max_entries: 0, // unbounded by count: only arena pressure evicts
                ..Default::default()
            },
            RecyclePolicy::Strict,
        );
        for i in 0..24 {
            let prompt = format!("distinct prompt number {i} padded with several words");
            let out = r.generate(&prompt, 3);
            assert!(out.is_ok(), "request {i} failed under arena pressure: {out:?}");
        }
        assert!(r.store().stats().evictions > 0, "pressure must have evicted");
        assert!(r.cache_len() >= 1, "cache still serves after shedding");
        // structures stayed in lockstep through pressure evictions
        assert_eq!(r.index.len(), r.store.len());
        assert_eq!(r.radix.len(), r.store.len());
        assert_eq!(r.tokens_of.len(), r.store.len());
    }

    #[test]
    fn session_turns_share_prefix_blocks() {
        // turn N+1's cached record must physically share turn N's blocks
        // (the arena's raison d'être) rather than duplicate them.
        let mut r = recycler(RecyclePolicy::Strict);
        let ids1 = r.tokenizer().encode(CACHE);
        let out1 = r.generate_ids(CACHE, ids1.clone(), 4, true).unwrap();
        assert_eq!(r.cache_len(), 1);

        let full_text1 = format!("{CACHE}{}", out1.text);
        let mut ids2 = ids1.clone();
        ids2.extend_from_slice(&out1.ids);
        let seg = " tell me more";
        let prompt2 = format!("{full_text1}{seg}");
        ids2.extend(r.tokenizer().encode(seg));
        let out2 = r.generate_ids(&prompt2, ids2, 4, true).unwrap();
        assert!(out2.cache_hit, "turn 2 must reuse turn 1's KV");
        assert_eq!(r.cache_len(), 2);

        let entry_ids = r.store().ids();
        let rec1 = r.store().peek(entry_ids[0]).unwrap();
        let rec2 = r.store().peek(entry_ids[1]).unwrap();
        // every fully-covered block of turn 1 is the SAME physical block in
        // turn 2's record (the boundary block may have copied on write)
        let bt = r.arena().block_tokens();
        let shared_blocks = rec1.token_len() / bt;
        assert!(shared_blocks >= 1, "workload too small to share blocks");
        assert_eq!(
            rec2.kv.block_ids()[..shared_blocks],
            rec1.kv.block_ids()[..shared_blocks],
            "prefix blocks must be shared, not copied"
        );
    }

    #[test]
    fn eviction_keeps_index_and_radix_consistent() {
        let engine = Engine::new(MockModel::new(ModelConfig::nano()));
        let mut r = Recycler::new(
            engine,
            toy_tokenizer(),
            Box::new(NgramEmbedder::new(64)),
            CacheConfig {
                max_entries: 2,
                ..Default::default()
            },
            RecyclePolicy::Strict,
        );
        r.populate_cache = false;
        r.warm(&["alpha beta gamma", "delta epsilon zeta", "eta theta iota"])
            .unwrap();
        assert_eq!(r.cache_len(), 2);
        // "alpha beta gamma" was evicted: retrieving its extension must miss
        let out = r.generate("alpha beta gamma delta", 2).unwrap();
        assert!(!out.cache_hit);
        // store/index sizes stay in lockstep
        assert_eq!(r.index.len(), r.store.len());
        assert_eq!(r.radix.len(), r.store.len());
        assert_eq!(r.tokens_of.len(), r.store.len());
    }

    #[test]
    fn exact_duplicate_prompt_hits_with_full_depth() {
        let mut r = recycler(RecyclePolicy::Strict);
        r.warm(&[CACHE]).unwrap();
        let out = r.generate(CACHE, 3).unwrap();
        assert!(out.cache_hit);
        // baseline equivalence for the identical-prompt case
        let mut b = recycler(RecyclePolicy::Off);
        assert_eq!(b.generate(CACHE, 3).unwrap().ids, out.ids);
    }
}
