//! The paper's algorithm: cross-prompt KV recycling — now a TWO-TIER
//! lookup.
//!
//! **Tier 1 — exact prefix** (paper §2.5/§3.1/§4.4). Per request:
//!  1. embed the prompt,
//!  2. retrieve the most similar cached prompt (`i* = argmax <e_i, e_t>`),
//!  3. exact-prefix token test (`r == k`, strict),
//!  4. on success *attach* the cached `past_key_values` — a block-table
//!     clone over the shared [`KvArena`], O(prefix blocks), no tensor
//!     copy — and feed only the suffix; otherwise run the baseline path,
//!  5. optionally insert the new prompt's KV into the cache (the paper
//!     builds the cache in a separate offline pass — [`Recycler::warm`] —
//!     but online population is the serving-system generalization).
//!
//! **Tier 2 — segment re-anchoring** (the paper's §6 "beyond exact
//! prefix" direction). A tier-1 miss falls through to segment lookup:
//! each admitted record is also indexed at a fixed token stride
//! ([`KvRecord::segment_spans`], `CacheConfig::segment_tokens`), each
//! segment embedded independently. The query's token windows are embedded
//! and matched against the segment index; a semantic candidate above
//! `segment_min_similarity` is then **verified by exact token
//! subsequence** and extended maximally in both directions — so the tier
//! only ever re-anchors spans whose tokens literally occur in the query,
//! just at a *different position* than where they were cached. The attach
//! re-anchors at serve time: the head of the prompt (everything before
//! the matched span) is prefilled fresh, the cached span's rows are
//! copied into their new positions behind the arena block table, and the
//! engine continues from there. A shared document pasted after different
//! preambles — offset-shifted reuse the prefix tier can never catch — is
//! the target workload (`benches/ablation_segment.rs`).
//!
//! The tier is gated by a per-request **fidelity budget**
//! (`CacheConfig::segment_fidelity_budget`, overridable cluster-wide via
//! `ServerConfig::segment_fidelity_budget`): `0.0` (the default) disables
//! segment serving entirely, preserving every token-identity property of
//! the exact tier byte-for-byte; a positive budget enables it, and the
//! ablation bench certifies measured infidelity (1 − output similarity
//! vs. the baseline arm, `bench::eval` scoring) stays within the budget.
//! Position re-anchoring is approximate on a real positional-encoding
//! backend; the budget is the contract that bounds the approximation.
//!
//! Policies:
//!  * [`RecyclePolicy::Off`]      — always baseline (the paper's control
//!    arm; neither tier runs).
//!  * [`RecyclePolicy::Strict`]   — the paper: embedding top-1 + full-prefix.
//!  * [`RecyclePolicy::Radix`]    — future-work §6.2: longest cached prefix
//!    across all entries via the token radix tree (no embedding involved in
//!    the hit decision; the embedding is still logged for similarity
//!    metrics).

use std::collections::HashMap;
use std::sync::Arc;

use crate::config::{CacheConfig, ModelConfig};
use crate::engine::{Engine, ForwardModel, Generated};
use crate::error::Result;
use crate::index::{cosine, Embedder, FlatIndex, NgramEmbedder};
use crate::kvcache::{Eviction, KvArena, KvRecord, KvStore, KvView};
use crate::metrics::RequestRow;
use crate::prefix::{reuse_depth, RadixTree};
use crate::tokenizer::Tokenizer;
use crate::util::timing::Stopwatch;

/// Recycling decision policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecyclePolicy {
    Off,
    Strict,
    Radix,
}

impl RecyclePolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "off" | "baseline" => Some(Self::Off),
            "strict" | "paper" => Some(Self::Strict),
            "radix" => Some(Self::Radix),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Off => "off",
            Self::Strict => "strict",
            Self::Radix => "radix",
        }
    }
}

/// Outcome of one request through the recycler.
#[derive(Debug, Clone)]
pub struct Outcome {
    pub text: String,
    pub ids: Vec<u32>,
    pub prompt_tokens: usize,
    pub reuse_depth: usize,
    pub cache_hit: bool,
    /// Similarity of the retrieved candidate (NaN when none).
    pub similarity: f64,
    pub latency_s: f64,
    pub prefill_calls: usize,
}

impl Outcome {
    /// Convert to the paper's per-request CSV row.
    pub fn to_row(&self, prompt: &str) -> RequestRow {
        RequestRow {
            prompt: prompt.to_string(),
            output: self.text.clone(),
            latency_s: self.latency_s,
            reused_tokens: self.reuse_depth,
            prompt_similarity: self.similarity,
            cache_hit: self.cache_hit,
            prompt_tokens: self.prompt_tokens,
            new_tokens: self.ids.len(),
        }
    }
}

/// The full recycling stack over any [`ForwardModel`].
pub struct Recycler<M: ForwardModel> {
    engine: Engine<M>,
    tokenizer: Arc<Tokenizer>,
    embedder: Box<dyn Embedder>,
    store: KvStore,
    index: FlatIndex,
    radix: RadixTree,
    /// id -> tokens side table: the prefix test reads it without touching
    /// the record (or disk — a spilled candidate is only reloaded AFTER
    /// its tokens pass the test), and unindexing a destroyed record needs
    /// it for the radix removal. Entries survive a spill, like the index
    /// and radix entries they back.
    tokens_of: HashMap<u64, Vec<u32>>,
    /// Segment tier (tier 2): embeddings of fixed-stride record slices.
    /// Keys are segment ids (`next_seg`), resolved through `seg_of`.
    /// Like `index`/`radix`, entries survive a spill of their record and
    /// die with it ([`Recycler::unindex`]).
    seg_index: FlatIndex,
    /// segment id -> (record id, span) — the reverse map a segment hit
    /// resolves through.
    seg_of: HashMap<u64, SegRef>,
    /// record id -> its segment ids (for unindexing).
    segs_of_rec: HashMap<u64, Vec<u64>>,
    next_seg: u64,
    pub policy: RecyclePolicy,
    /// Insert served prompts into the cache (online population).
    pub populate_cache: bool,
}

/// One indexed segment: span `[start, end)` of record `rec`'s tokens.
#[derive(Debug, Clone, Copy)]
struct SegRef {
    rec: u64,
    start: usize,
    end: usize,
}

/// A tier-2 hit, ready to seed the engine: `kv` holds `cur_len` valid
/// positions (fresh-prefilled head + `reused` re-anchored cached rows).
struct SegmentHit {
    kv: KvView,
    cur_len: usize,
    reused: usize,
    similarity: f64,
}

impl<M: ForwardModel> Recycler<M> {
    pub fn new(
        engine: Engine<M>,
        tokenizer: Arc<Tokenizer>,
        embedder: Box<dyn Embedder>,
        cache_cfg: CacheConfig,
        policy: RecyclePolicy,
    ) -> Self {
        let dim = embedder.dim();
        Recycler {
            engine,
            tokenizer,
            embedder,
            store: KvStore::new(cache_cfg),
            index: FlatIndex::new(dim),
            radix: RadixTree::new(),
            tokens_of: HashMap::new(),
            seg_index: FlatIndex::new(dim),
            seg_of: HashMap::new(),
            segs_of_rec: HashMap::new(),
            next_seg: 0,
            policy,
            populate_cache: true,
        }
    }

    /// Default stack: n-gram embedder, default cache config, strict policy.
    pub fn with_defaults(engine: Engine<M>, tokenizer: Arc<Tokenizer>) -> Self {
        Self::new(
            engine,
            tokenizer,
            Box::new(NgramEmbedder::new(128)),
            CacheConfig::default(),
            RecyclePolicy::Strict,
        )
    }

    pub fn config(&self) -> &ModelConfig {
        self.engine.config()
    }

    pub fn engine(&self) -> &Engine<M> {
        &self.engine
    }

    /// Mutable engine access for the continuous-batching scheduler, which
    /// drives prefill/decode itself via the stream API between
    /// [`Recycler::prepare`] and [`Recycler::complete`].
    pub fn engine_mut(&mut self) -> &mut Engine<M> {
        &mut self.engine
    }

    /// The paged KV arena shared by the engine and every cache record.
    pub fn arena(&self) -> &KvArena {
        self.engine.arena()
    }

    pub fn store(&self) -> &KvStore {
        &self.store
    }

    /// Attach a fault plan to every failure domain this recycler owns:
    /// the cold spill tier and the KV arena. The model's own seam lives
    /// on [`crate::testutil::MockModel::with_faults`]. A cloned handle
    /// shares one schedule, so one seeded plan drives all domains
    /// deterministically.
    pub fn install_faults(&mut self, h: crate::faults::FaultHandle) {
        self.store.install_faults(h.clone());
        self.engine.arena().install_faults(h);
    }

    pub fn tokenizer(&self) -> Arc<Tokenizer> {
        Arc::clone(&self.tokenizer)
    }

    pub fn cache_len(&self) -> usize {
        self.store.len()
    }

    /// Embedding of a prompt (exposed for output-similarity metrics).
    pub fn embed_text(&self, text: &str) -> Vec<f32> {
        self.embedder.embed(text)
    }

    /// Cosine similarity of two texts under the configured embedder — the
    /// paper's output-similarity metric.
    pub fn text_similarity(&self, a: &str, b: &str) -> f64 {
        cosine(&self.embedder.embed(a), &self.embedder.embed(b)) as f64
    }

    /// Build the cache from a prompt set (the paper's §4.4 cache
    /// construction pass: one forward per prompt, `use_cache=True`).
    pub fn warm(&mut self, prompts: &[&str]) -> Result<usize> {
        let mut n = 0;
        for p in prompts {
            self.insert_prompt(p)?;
            n += 1;
        }
        Ok(n)
    }

    /// Drop one id from index/radix/side tables (the record itself is
    /// gone: destroyed by an eviction without a spill tier, dropped by
    /// the tier's own LRU, or its spill file turned out corrupt).
    fn unindex(&mut self, id: u64) {
        self.index.remove(id);
        if let Some(tokens) = self.tokens_of.remove(&id) {
            self.radix.remove(&tokens);
        }
        if let Some(keys) = self.segs_of_rec.remove(&id) {
            for k in keys {
                self.seg_index.remove(k);
                self.seg_of.remove(&k);
            }
        }
    }

    /// Is the segment tier live? Off under the control-arm policy, a zero
    /// stride (no segmenting), or a zero fidelity budget (exact-only
    /// serving — the byte-identity contract).
    fn segment_enabled(&self) -> bool {
        let cfg = self.store.config();
        self.policy != RecyclePolicy::Off
            && cfg.segment_tokens > 0
            && cfg.segment_fidelity_budget > 0.0
    }

    /// Apply the serving-level fidelity-budget override (see
    /// `ServerConfig::segment_fidelity_budget`). Enabling the tier on a
    /// recycler whose cache was warmed while it was off back-fills the
    /// segment index from the hot store, so factory-warmed caches serve
    /// segment hits too.
    pub fn set_segment_fidelity_budget(&mut self, budget: f64) {
        self.store.set_segment_fidelity_budget(budget);
        if !self.segment_enabled() {
            return;
        }
        let ids: Vec<u64> = self
            .store
            .ids()
            .into_iter()
            .filter(|id| !self.segs_of_rec.contains_key(id))
            .collect();
        for id in ids {
            if let Some(tokens) = self.tokens_of.get(&id).cloned() {
                self.index_segments_of(id, &tokens);
            }
        }
    }

    /// Index one record's fixed-stride segments into the segment tier
    /// (no-op while the tier is disabled). Each span is decoded and
    /// embedded independently — the semantic keys a tier-2 lookup
    /// matches query windows against. Works straight off the token list
    /// (the same spans as [`KvRecord::segment_spans`]), never the
    /// record — so quantized or spilled residents index without
    /// materializing their payload.
    fn index_segments_of(&mut self, id: u64, tokens: &[u32]) {
        if !self.segment_enabled() {
            return;
        }
        let stride = self.store.config().segment_tokens;
        for i in 0..tokens.len() / stride {
            let (a, b) = (i * stride, (i + 1) * stride);
            let text = self.tokenizer.decode(&tokens[a..b]);
            let emb = self.embedder.embed(&text);
            let key = self.next_seg;
            self.next_seg += 1;
            self.seg_index.add(key, &emb);
            self.seg_of.insert(key, SegRef { rec: id, start: a, end: b });
            self.segs_of_rec.entry(id).or_default().push(key);
        }
    }

    /// Unindex records the cold tier's own LRU destroyed (spill-budget
    /// pressure) — eager, so index/radix stay in lockstep with what a
    /// lookup can still resolve (hot + spilled).
    fn sync_cold_drops(&mut self) {
        for id in self.store.take_cold_dropped() {
            self.unindex(id);
        }
    }

    /// Apply one store eviction to the side structures: a *spilled*
    /// victim keeps its index/radix entries (its id still resolves
    /// through the cold tier); a *dropped* one is unindexed.
    fn apply_eviction(&mut self, ev: Eviction) {
        if let Eviction::Dropped { id, .. } = ev {
            self.unindex(id);
        }
    }

    /// Evict one record by policy — into the cold tier when spilling is
    /// configured, destroying it otherwise — and keep the side structures
    /// consistent. False when the hot store is empty.
    fn evict_and_unindex(&mut self) -> bool {
        let Some(ev) = self.store.evict_one() else {
            return false;
        };
        self.apply_eviction(ev);
        self.sync_cold_drops();
        true
    }

    /// Evict cache entries until the arena has headroom for one worst-case
    /// request (a full-context sequence). Cached records pin blocks; under
    /// sustained population pressure the cache must shrink rather than
    /// starve live requests into `ArenaExhausted` failures.
    ///
    /// The loop is gated on the store's *reclaimable* footprint — blocks
    /// whose every live reference is a cache entry's. When that hits
    /// zero, no amount of shedding frees anything (the remaining blocks
    /// are pinned by in-flight streams or attached views), so the pass
    /// stops immediately instead of destroying one futile victim per
    /// scheduler tick. Physical accounting makes that check exact, which
    /// is why the old zero-yield stall memo is gone. Individual
    /// evictions may still free nothing *yet* (a session chain's shared
    /// blocks settle only when the last holder goes) — that is progress,
    /// not a stall, and the loop keeps going while reclaim is possible.
    fn ensure_arena_headroom(&mut self) {
        // Cap the target at half the arena: a deliberately tiny arena
        // (capacity below one full-context sequence) must not drain the
        // cache to empty on every request chasing unreachable headroom.
        let arena = self.engine.arena();
        let need = arena
            .blocks_for(self.engine.config().max_seq)
            .min(arena.capacity_blocks() / 2);
        while self.engine.arena().free_blocks() < need {
            if self.store.reclaimable_blocks() == 0 {
                break; // shedding can free nothing right now
            }
            if !self.evict_and_unindex() {
                break; // store empty
            }
        }
    }

    /// Last-resort shedding when a live request actually failed
    /// allocation: evict (spill) cache entries until the arena can hold
    /// `tokens` more positions, the store is empty, or eviction can no
    /// longer free anything. Serving the request outranks cache
    /// retention.
    pub fn shed_for_tokens(&mut self, tokens: usize) {
        let need = self.engine.arena().blocks_for(tokens);
        while self.engine.arena().free_blocks() < need
            && self.store.reclaimable_blocks() > 0
            && self.evict_and_unindex()
        {}
    }

    /// Prefill a prompt and insert its KV record into the cache.
    pub fn insert_prompt(&mut self, text: &str) -> Result<u64> {
        self.ensure_arena_headroom();
        let ids = self.tokenizer.encode(text);
        let mut kv = self.engine.empty_kv();
        self.engine.prefill(&ids, &mut kv, 0)?;
        Ok(self.admit(text, ids, &kv))
    }

    /// Admit a prefilled (text, ids, kv-view) into store + index + radix.
    /// The record *shares* the view's blocks (trimmed to the prompt) — no
    /// tensor copy; a served request and its cache entry hold the same
    /// physical prefix, copy-on-write.
    fn admit(&mut self, text: &str, ids: Vec<u32>, kv: &KvView) -> u64 {
        let emb = self.embedder.embed(text);
        let rec = KvRecord::from_view(text, ids.clone(), emb.clone(), kv);
        let (id, evicted) = self.store.insert(rec);
        for ev in evicted {
            self.apply_eviction(ev);
        }
        self.sync_cold_drops();
        self.index.add(id, &emb);
        self.radix.insert(&ids, id);
        self.index_segments_of(id, &ids);
        self.tokens_of.insert(id, ids);
        id
    }

    /// Resolve a candidate id to its record: a hot hit outright, or a
    /// transparent reload from the cold tier (shedding hot entries for
    /// arena room) — the tiered store's promise that a spilled record
    /// still serves its prefix hit. Counts the store hit (recency +
    /// frequency) on success; `None` means the record is gone from both
    /// tiers (or its spill file was corrupt / the arena cannot hold it) —
    /// the caller records the miss.
    fn fetch_hit(&mut self, id: u64) -> Option<Arc<KvRecord>> {
        if self.store.contains(id) {
            return self.store.hit(id);
        }
        if self.store.is_spilled(id) {
            let arena = self.engine.arena().clone();
            let (rec, evicted) = self.store.reload_spilled(id, &arena);
            for ev in evicted {
                self.apply_eviction(ev);
            }
            self.sync_cold_drops();
            if rec.is_some() {
                return self.store.hit(id); // hot now: count the hit
            }
            if !self.store.is_spilled(id) {
                // the spill file was corrupt (typed error recorded in
                // CacheStats::spill_load_errors) — the entry is dead
                self.unindex(id);
            }
            // else: arena pressure won; keep the cold entry for a
            // less-pressured retry and miss for now
            return None;
        }
        // stale index entry: the cold tier's LRU destroyed the record
        self.unindex(id);
        None
    }

    /// Cross-worker adoption, the miss-path fallback of [`Recycler::lookup`]:
    /// scan sibling namespaces' spill files in the shared `spill_dir` for the
    /// deepest record whose tokens prefix `ids`, COPY it into the local hot
    /// tier under a fresh local id (the owner's file and cold entry are never
    /// touched), and index it like any admitted record so the NEXT lookup
    /// resolves it locally. This is the cluster's cache-mobility layer: a
    /// prompt family placed on a different worker than the one that computed
    /// its prefix can still reuse that work through the shared cold tier.
    /// A no-op unless both `spill_dir` and `spill_namespace` are configured,
    /// so single-worker (`num_workers = 1`) behaviour — including exact
    /// hit/miss accounting — is unchanged.
    fn adopt_or_miss(
        &mut self,
        ids: &[u32],
        emb: &[f32],
        miss_sim: f64,
    ) -> (Option<(Arc<KvRecord>, usize)>, f64) {
        let adoptable = {
            let cfg = self.store.config();
            cfg.spill_dir.is_some() && !cfg.spill_namespace.is_empty()
        };
        if !adoptable {
            self.store.note_miss();
            return (None, miss_sim);
        }
        let arena = self.engine.arena().clone();
        let (adopted, evicted) = self.store.adopt_foreign(ids, &arena);
        for ev in evicted {
            self.apply_eviction(ev);
        }
        self.sync_cold_drops();
        let Some((id, rec)) = adopted else {
            self.store.note_miss();
            return (None, miss_sim);
        };
        self.index.add(id, &rec.embedding);
        self.radix.insert(&rec.tokens, id);
        self.index_segments_of(id, &rec.tokens);
        self.tokens_of.insert(id, rec.tokens.clone());
        let depth = rec.tokens.len();
        let sim = cosine(&rec.embedding, emb) as f64;
        // Count the hit (hit counter + recency/frequency touch) like any
        // served record; the adoptee is hot, so this cannot fail.
        let rec = self.store.hit(id).unwrap_or(rec);
        (Some((rec, depth)), sim)
    }

    /// The retrieval + prefix test. Returns (record, reuse_depth,
    /// similarity) on a hit; logs similarity of the candidate either way.
    fn lookup(&mut self, ids: &[u32], emb: &[f32]) -> (Option<(Arc<KvRecord>, usize)>, f64) {
        match self.policy {
            RecyclePolicy::Off => (None, f64::NAN),
            RecyclePolicy::Strict => {
                let Some((cand, sim)) = self.index.nearest(emb) else {
                    return self.adopt_or_miss(ids, emb, f64::NAN);
                };
                if sim < self.store.config().min_similarity {
                    return self.adopt_or_miss(ids, emb, sim as f64);
                }
                // Prefix test against the token side table: rejecting a
                // candidate never touches the record — in particular a
                // SPILLED candidate is only reloaded from disk after its
                // tokens pass the full-prefix test.
                let (r, full) = match self.tokens_of.get(&cand) {
                    Some(cand_tokens) => reuse_depth(cand_tokens, ids),
                    None => (0, false), // stale index entry: a miss
                };
                if !full {
                    return self.adopt_or_miss(ids, emb, sim as f64);
                }
                match self.fetch_hit(cand) {
                    Some(rec) => (Some((rec, r)), sim as f64),
                    // gone from both tiers (or unreloadable right now)
                    None => self.adopt_or_miss(ids, emb, sim as f64),
                }
            }
            RecyclePolicy::Radix => {
                let Some((depth, key)) = self.radix.longest_prefix(ids) else {
                    return self.adopt_or_miss(ids, emb, f64::NAN);
                };
                // A stale radix entry (record destroyed) is a miss like
                // any other — fetch_hit unindexes it and the single
                // adopt_or_miss fallback (which notes the miss when no
                // sibling record is adoptable) keeps miss accounting
                // exact (regression-tested below). No
                // `debug_assert_eq!(depth, rec.token_len())`: it only
                // holds while radix and store are in perfect lockstep,
                // which a stale entry violates by definition.
                let Some(rec) = self.fetch_hit(key) else {
                    return self.adopt_or_miss(ids, emb, f64::NAN);
                };
                let sim = cosine(&rec.embedding, emb) as f64;
                (Some((rec, depth)), sim)
            }
        }
    }

    /// Tier-2 lookup: semantic segment retrieval + exact-subsequence
    /// verification + position re-anchoring. Runs only after the exact
    /// tier missed (and noted the miss). Returns `None` — a plain miss —
    /// whenever anything falls short: tier disabled, prompt shorter than
    /// the stride, best candidate under `segment_min_similarity`, the
    /// candidate's tokens not literally present in the query, the record
    /// gone from both store tiers, or the arena too full for the
    /// re-anchor attach.
    fn segment_lookup(&mut self, ids: &[u32]) -> Option<SegmentHit> {
        if !self.segment_enabled() || self.seg_index.is_empty() {
            return None;
        }
        let stride = self.store.config().segment_tokens;
        let min_sim = self.store.config().segment_min_similarity;
        if ids.len() < stride {
            return None;
        }
        // Slide a stride-length window over the query at a one-token hop
        // and keep the best-scoring segment across all windows. The dense
        // hop guarantees a cached segment present anywhere in the query is
        // scanned at its exact offset (embedding equality, similarity
        // 1.0) — a coarser hop would make retrieval depend on how the
        // shared span happens to align against the window grid. Each probe
        // is one n-gram hash + one flat-index scan; fine at this scale,
        // and the tier only pays it on exact-tier misses.
        let mut key = 0u64;
        let mut sim = f32::NEG_INFINITY;
        for w in 0..=ids.len() - stride {
            let text = self.tokenizer.decode(&ids[w..w + stride]);
            let emb = self.embedder.embed(&text);
            if let Some((k, s)) = self.seg_index.nearest(&emb) {
                if s > sim {
                    sim = s;
                    key = k;
                }
            }
        }
        if sim < min_sim {
            return None; // also catches the no-candidate sentinel
        }
        // Semantic retrieval proposes; exact tokens dispose. The candidate
        // span must occur verbatim in the query (first occurrence wins),
        // and the match is then extended maximally both ways so one
        // segment-grain probe re-anchors the full shared run.
        let (rec_id, dst, src, len) = {
            let seg = self.seg_of.get(&key)?;
            let (rec_id, mut src) = (seg.rec, seg.start);
            let cand = self.tokens_of.get(&rec_id)?;
            let want = &cand[src..seg.end];
            let mut len = want.len();
            let mut dst = (0..=ids.len() - len).find(|&p| &ids[p..p + len] == want)?;
            while dst > 0 && src > 0 && ids[dst - 1] == cand[src - 1] {
                dst -= 1;
                src -= 1;
                len += 1;
            }
            while dst + len < ids.len()
                && src + len < cand.len()
                && ids[dst + len] == cand[src + len]
            {
                len += 1;
            }
            (rec_id, dst, src, len)
        };
        let rec = self.fetch_hit(rec_id)?;
        match self.reanchor_attach(&rec, src, dst, len, ids) {
            Ok((kv, cur_len)) => Some(SegmentHit {
                kv,
                cur_len,
                reused: len,
                similarity: sim as f64,
            }),
            // Arena exhausted mid-attach: the partial view frees on drop;
            // serve as a plain miss (generate's shed-and-retry backstop
            // still guards the baseline path).
            Err(_) => None,
        }
    }

    /// Build a KV view with `rec`'s rows `[src, src+len)` re-anchored at
    /// position `dst`: prefill the fresh head `ids[..dst]` exactly, then
    /// copy the cached span's rows into their new positions (COW row
    /// writes behind the arena block table — the donor record is never
    /// touched). Unlike the prefix tier's O(blocks) attach this copies
    /// `len` tokens of KV, but a row copy is still far cheaper than the
    /// forward pass it replaces. Position re-anchoring is exact on the
    /// mock backend (content-addressed KV markers) and approximate under
    /// real positional encodings — which is precisely what the fidelity
    /// budget bounds.
    fn reanchor_attach(
        &mut self,
        rec: &KvRecord,
        src: usize,
        dst: usize,
        len: usize,
        ids: &[u32],
    ) -> Result<(KvView, usize)> {
        let (n_layer, n_head) = {
            let c = self.engine.config();
            (c.n_layer, c.n_head)
        };
        let mut kv = self.engine.empty_kv();
        if dst > 0 {
            self.engine.prefill(&ids[..dst], &mut kv, 0)?;
        }
        for i in 0..len {
            for l in 0..n_layer {
                for k in 0..2 {
                    for h in 0..n_head {
                        kv.row_mut(l, k, h, dst + i)?
                            .copy_from_slice(rec.kv.row(l, k, h, src + i));
                    }
                }
            }
        }
        kv.commit(dst + len);
        Ok((kv, dst + len))
    }

    /// Serve one prompt: the paper's per-test-prompt loop.
    pub fn generate(&mut self, prompt: &str, max_new_tokens: usize) -> Result<Outcome> {
        let ids = self.tokenizer.encode(prompt);
        self.generate_ids(prompt, ids, max_new_tokens, false)
    }

    /// Serve a prompt whose token ids the caller already owns (session
    /// continuation: ids may extend a previous turn's exact token sequence,
    /// which text re-tokenization cannot guarantee at BPE merge
    /// boundaries). With `admit_full`, the *entire* final sequence
    /// (prompt + generated response) is inserted into the cache so the next
    /// turn can reuse all of it.
    pub fn generate_ids(
        &mut self,
        prompt: &str,
        ids: Vec<u32>,
        max_new_tokens: usize,
        admit_full: bool,
    ) -> Result<Outcome> {
        match self.serve_once(prompt, &ids, max_new_tokens, admit_full) {
            Err(crate::error::Error::ArenaExhausted { .. }) => {
                // The cheap headroom pass deliberately stops shedding when
                // evictions stop yielding blocks; a real allocation
                // failure is the backstop — drain the cache as far as
                // needed and retry once. The aborted attempt's store
                // hit/miss tick is accepted imprecision on this rare path.
                self.shed_for_tokens(ids.len() + max_new_tokens);
                self.serve_once(prompt, &ids, max_new_tokens, admit_full)
            }
            r => r,
        }
    }

    fn serve_once(
        &mut self,
        prompt: &str,
        ids: &[u32],
        max_new_tokens: usize,
        admit_full: bool,
    ) -> Result<Outcome> {
        let Admission { kv, cur_len, meta } = self.prepare(prompt, ids, admit_full);
        let g = self
            .engine
            .generate(ids, kv, cur_len, max_new_tokens, meta.want_capture)?;
        Ok(self.complete(prompt, ids, meta, g))
    }

    /// Phase 1 of serving (the scheduler's admission step): shed cache
    /// under arena pressure, embed, retrieve, and attach the recycled
    /// prefix (or hand back a fresh view). Infallible by design — a miss
    /// is a valid outcome, not an error.
    ///
    /// With chunked prefill the span between `prepare` and
    /// [`Recycler::complete`] covers MANY scheduler ticks: the attached
    /// view (and the record blocks it pins) lives across every prefill
    /// chunk and decode step of the request, and `ServeMeta` travels with
    /// the slot the whole way. Nothing here may assume the two phases run
    /// back-to-back; in particular the attach is a refcount bump, so
    /// eviction of the donor record mid-request only unpins blocks the
    /// request itself still holds.
    pub fn prepare(&mut self, prompt: &str, ids: &[u32], admit_full: bool) -> Admission {
        let sw = Stopwatch::start();
        // Shed cache entries first if the arena is running low — a live
        // request must never starve on blocks pinned by cold cache state.
        self.ensure_arena_headroom();
        let emb = self.embedder.embed(prompt);
        let (hit, similarity) = self.lookup(ids, &emb);
        let (kv, cur_len, cache_hit, depth, similarity) = match hit {
            Some((rec, depth)) => {
                // Zero-copy injection: attach the record's block table
                // (refcount bumps, O(prefix blocks) — no tensor memcpy).
                (rec.attach(), depth, true, depth, similarity)
            }
            // Exact tier missed (and noted the miss): fall through to the
            // segment tier. A segment hit converts the miss
            // (note_segment_hit) and serves re-anchored KV; cache_hit =
            // true keeps want_capture off — re-anchored KV is served,
            // never admitted (only exactly-computed prefixes enter the
            // cache).
            None => match self.segment_lookup(ids) {
                Some(seg) => {
                    self.store.note_segment_hit(seg.reused);
                    (seg.kv, seg.cur_len, true, seg.reused, seg.similarity)
                }
                None => (self.engine.empty_kv(), 0, false, 0, similarity),
            },
        };
        let want_capture = self.populate_cache && !cache_hit && !admit_full;
        Admission {
            kv,
            cur_len,
            meta: ServeMeta {
                cache_hit,
                depth,
                similarity,
                want_capture,
                admit_full,
                sw,
            },
        }
    }

    /// Phase 3 of serving (the scheduler's finish step, any number of
    /// ticks after [`Recycler::prepare`]): admit the new KV into the cache
    /// and assemble the request's [`Outcome`]. `ids` must be the prompt
    /// ids `prepare` saw; `g` the finished generation over them. Borrows
    /// `ids` and copies only on the branches that admit a record — the
    /// plain-hit path (most requests) is copy-free.
    pub fn complete(
        &mut self,
        prompt: &str,
        ids: &[u32],
        meta: ServeMeta,
        g: Generated,
    ) -> Outcome {
        if let Some(prompt_kv) = &g.prompt_kv {
            self.admit(prompt, ids.to_vec(), prompt_kv);
        }
        if meta.admit_full && self.populate_cache {
            // Cache prompt + response (token-exact), the session fast path.
            // The record shares the request's final view — turn N+1's
            // attach reuses turn N's blocks outright.
            let mut full_ids = ids.to_vec();
            full_ids.extend_from_slice(&g.ids);
            let full_text = format!("{prompt}{}", self.tokenizer.decode(&g.ids));
            self.admit(&full_text, full_ids, &g.final_kv);
        }
        Outcome {
            text: self.tokenizer.decode(&g.ids),
            ids: g.ids,
            prompt_tokens: g.prompt_tokens,
            reuse_depth: meta.depth,
            cache_hit: meta.cache_hit,
            similarity: meta.similarity,
            latency_s: meta.sw.elapsed_secs(),
            prefill_calls: g.prefill_calls,
        }
    }

    /// Admission gate for the continuous-batching scheduler: shed cold
    /// cache entries if needed, then report whether the arena can hold an
    /// incoming request of `incoming_tokens` (prompt + generation budget,
    /// clamped to the window) *on top of* `reserved_blocks` — the blocks
    /// already-running streams may still consume as they decode (their
    /// unwritten growth plus COW slack). Gating on the request's actual
    /// size (not worst-case max_seq) keeps short prompts batching under
    /// moderate occupancy. While decode batches are in flight the
    /// scheduler defers arrivals when this is false, instead of
    /// over-committing the arena and starving running streams mid-decode;
    /// when nothing is running the scheduler bypasses the gate entirely
    /// (serial serving is always possible — `prepare` sheds cache
    /// internally), so an unattainable `need` degrades to
    /// request-at-a-time, never deadlock.
    pub fn admission_headroom(&mut self, incoming_tokens: usize, reserved_blocks: usize) -> bool {
        self.ensure_arena_headroom();
        let arena = self.engine.arena();
        let cap = self.engine.config().max_seq;
        let need = arena.blocks_for(incoming_tokens.min(cap)) + reserved_blocks;
        arena.free_blocks() >= need
    }
}

/// Retrieval outcome + bookkeeping for one request, produced by
/// [`Recycler::prepare`]. `kv`/`cur_len` seed the engine
/// (`start_stream`/`generate`); `meta` travels with the request and is
/// redeemed by [`Recycler::complete`].
pub struct Admission {
    /// KV to start from: an attached cache record (hit) or a fresh view.
    pub kv: KvView,
    /// Valid positions in `kv` — the reuse depth on a hit, else 0.
    pub cur_len: usize,
    pub meta: ServeMeta,
}

/// Per-request serving metadata carried from [`Recycler::prepare`] to
/// [`Recycler::complete`] across the (possibly batched) decode phase.
pub struct ServeMeta {
    pub cache_hit: bool,
    pub depth: usize,
    pub similarity: f64,
    /// Snapshot the post-prefill KV for cache admission (miss path).
    pub want_capture: bool,
    /// Admit prompt + response on finish (session continuation).
    pub admit_full: bool,
    /// Started at `prepare`; `complete` reads the request latency off it.
    sw: Stopwatch,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EvictionPolicy;
    use crate::testutil::MockModel;

    fn toy_tokenizer() -> Arc<Tokenizer> {
        Arc::new(Tokenizer::new(vec![
            ("t".into(), "h".into()),
            ("th".into(), "e".into()),
        ]))
    }

    fn recycler_with(policy: RecyclePolicy, cache: CacheConfig) -> Recycler<MockModel> {
        let engine = Engine::new(MockModel::new(ModelConfig::nano()));
        Recycler::new(
            engine,
            toy_tokenizer(),
            Box::new(NgramEmbedder::new(64)),
            cache,
            policy,
        )
    }

    fn recycler(policy: RecyclePolicy) -> Recycler<MockModel> {
        recycler_with(
            policy,
            CacheConfig {
                max_entries: 8,
                eviction: EvictionPolicy::Lru,
                ..Default::default()
            },
        )
    }

    const CACHE: &str = "what is the capital of france?";
    const TEST: &str = "what is the capital of france? also mention a nearby town.";
    const OTHER: &str = "how do rockets launch into orbit today?";

    #[test]
    fn strict_hit_on_extended_prompt() {
        let mut r = recycler(RecyclePolicy::Strict);
        r.warm(&[CACHE, OTHER]).unwrap();
        let out = r.generate(TEST, 4).unwrap();
        assert!(out.cache_hit);
        let cache_len = r.tokenizer().encode(CACHE).len();
        assert_eq!(out.reuse_depth, cache_len);
        assert!(out.similarity > 0.5);
    }

    #[test]
    fn recycled_output_identical_to_baseline() {
        // the paper's fidelity claim, end-to-end through the recycler
        let mut base = recycler(RecyclePolicy::Off);
        let baseline = base.generate(TEST, 6).unwrap();
        let mut rec = recycler(RecyclePolicy::Strict);
        rec.warm(&[CACHE]).unwrap();
        let recycled = rec.generate(TEST, 6).unwrap();
        assert!(recycled.cache_hit);
        assert_eq!(recycled.ids, baseline.ids);
        assert_eq!(recycled.text, baseline.text);
    }

    #[test]
    fn miss_on_unrelated_prompt_falls_back() {
        let mut r = recycler(RecyclePolicy::Strict);
        r.warm(&[CACHE]).unwrap();
        let out = r.generate(OTHER, 4).unwrap();
        assert!(!out.cache_hit);
        assert_eq!(out.reuse_depth, 0);
        // behaviour matches baseline
        let mut b = recycler(RecyclePolicy::Off);
        assert_eq!(b.generate(OTHER, 4).unwrap().ids, out.ids);
    }

    #[test]
    fn diverging_prompt_with_high_similarity_is_rejected() {
        // shares words (high embedding similarity) but not a token prefix
        let mut r = recycler(RecyclePolicy::Strict);
        r.warm(&["what is the capital of france?"]).unwrap();
        let out = r
            .generate("what is the capital of germany? france is nearby.", 4)
            .unwrap();
        assert!(!out.cache_hit, "prefix test must reject sim={}", out.similarity);
    }

    #[test]
    fn off_policy_never_hits() {
        let mut r = recycler(RecyclePolicy::Off);
        r.warm(&[CACHE]).unwrap();
        let out = r.generate(TEST, 4).unwrap();
        assert!(!out.cache_hit);
    }

    #[test]
    fn radix_hits_deepest_entry() {
        let mut r = recycler(RecyclePolicy::Radix);
        r.populate_cache = false;
        r.warm(&["what is", "what is the capital of france?"]).unwrap();
        let out = r.generate(TEST, 4).unwrap();
        assert!(out.cache_hit);
        let deep_len = r.tokenizer().encode("what is the capital of france?").len();
        assert_eq!(out.reuse_depth, deep_len);
    }

    #[test]
    fn radix_equals_baseline_output() {
        let mut base = recycler(RecyclePolicy::Off);
        let baseline = base.generate(TEST, 5).unwrap();
        let mut r = recycler(RecyclePolicy::Radix);
        r.warm(&[CACHE]).unwrap();
        let out = r.generate(TEST, 5).unwrap();
        assert!(out.cache_hit);
        assert_eq!(out.ids, baseline.ids);
    }

    #[test]
    fn truncated_session_reanchors_after_window_cut() {
        // After a sliding-window cut the truncated turn is admitted in
        // full (admit_full), so the NEXT turn recycles it — verified via
        // the radix policy, whose token-prefix lookup is exact.
        let mut r = recycler(RecyclePolicy::Radix);
        let tok = r.tokenizer();
        let t1 = "the quick brown fox jumps over the lazy dog again and again";
        let ids1 = tok.encode(t1);
        let out1 = r.generate_ids(t1, ids1.clone(), 4, true).unwrap();

        // window cut: keep only a transcript suffix (what the scheduler
        // does near max_seq)
        let mut cut_ids = ids1.clone();
        cut_ids.extend_from_slice(&out1.ids);
        let dropped = crate::coordinator::truncate_to_window(&mut cut_ids, 20);
        assert!(dropped > 0, "workload too small to cut");
        let cut_text = tok.decode(&cut_ids);

        // the turn right after the cut misses (its head moved)…
        let out2 = r.generate_ids(&cut_text, cut_ids.clone(), 4, true).unwrap();
        assert!(!out2.cache_hit, "a cut head cannot prefix-match");

        // …but re-anchors: the following turn hits its record at full depth
        let mut ids3 = cut_ids.clone();
        ids3.extend_from_slice(&out2.ids);
        ids3.extend(tok.encode(" and then some"));
        let t3 = format!("{cut_text}{} and then some", tok.decode(&out2.ids));
        let out3 = r.generate_ids(&t3, ids3, 4, true).unwrap();
        assert!(out3.cache_hit, "post-cut transcript must re-anchor");
        assert_eq!(out3.reuse_depth, cut_ids.len() + out2.ids.len());
    }

    #[test]
    fn radix_miss_and_hit_accounting_exact() {
        // regression: the radix arm used to skip miss accounting on some
        // paths, silently undercounting misses
        let mut r = recycler(RecyclePolicy::Radix);
        r.populate_cache = false;
        r.warm(&[CACHE]).unwrap();
        let s0 = r.store().stats();
        r.generate(OTHER, 2).unwrap(); // no cached prefix -> one miss
        let s1 = r.store().stats();
        assert_eq!(s1.misses, s0.misses + 1);
        assert_eq!(s1.hits, s0.hits);
        r.generate(TEST, 2).unwrap(); // full-prefix hit, no miss
        let s2 = r.store().stats();
        assert_eq!(s2.hits, s1.hits + 1);
        assert_eq!(s2.misses, s1.misses);
    }

    #[test]
    fn phase_split_api_equals_one_shot_serving() {
        // prepare -> stream decode -> complete (the scheduler's path) must
        // be indistinguishable from generate_ids
        let mut a = recycler(RecyclePolicy::Strict);
        a.warm(&[CACHE]).unwrap();
        let one = a.generate(TEST, 5).unwrap();

        let mut b = recycler(RecyclePolicy::Strict);
        b.warm(&[CACHE]).unwrap();
        let ids = b.tokenizer().encode(TEST);
        let Admission { kv, cur_len, meta } = b.prepare(TEST, &ids, false);
        let mut stream = b
            .engine_mut()
            .start_stream(&ids, kv, cur_len, 5, meta.want_capture)
            .unwrap();
        while !stream.is_finished() {
            b.engine_mut().step_streams(&mut [&mut stream]).unwrap();
        }
        let out = b.complete(TEST, &ids, meta, stream.into_generated());
        assert_eq!(out.ids, one.ids);
        assert_eq!(out.text, one.text);
        assert_eq!(out.cache_hit, one.cache_hit);
        assert_eq!(out.reuse_depth, one.reuse_depth);
        assert_eq!(a.cache_len(), b.cache_len(), "same admissions");
    }

    #[test]
    fn online_population_enables_future_hits() {
        let mut r = recycler(RecyclePolicy::Strict);
        assert_eq!(r.cache_len(), 0);
        r.generate(CACHE, 2).unwrap(); // miss, but populates
        assert_eq!(r.cache_len(), 1);
        let out = r.generate(TEST, 2).unwrap(); // now hits
        assert!(out.cache_hit);
    }

    #[test]
    fn arena_pressure_sheds_cache_instead_of_failing_requests() {
        // A deliberately tiny arena: room for ~3 full-context sequences.
        // Sustained online population must evict cache entries to keep
        // serving, never surface ArenaExhausted to a request.
        let cfg = ModelConfig::nano();
        let arena = crate::kvcache::KvArena::new(&cfg, 16, 3 * cfg.max_seq / 16);
        let engine = Engine::with_arena(MockModel::new(cfg), arena);
        let mut r = Recycler::new(
            engine,
            toy_tokenizer(),
            Box::new(NgramEmbedder::new(64)),
            CacheConfig {
                max_entries: 0, // unbounded by count: only arena pressure evicts
                ..Default::default()
            },
            RecyclePolicy::Strict,
        );
        for i in 0..24 {
            let prompt = format!("distinct prompt number {i} padded with several words");
            let out = r.generate(&prompt, 3);
            assert!(out.is_ok(), "request {i} failed under arena pressure: {out:?}");
        }
        assert!(r.store().stats().evictions > 0, "pressure must have evicted");
        assert!(r.cache_len() >= 1, "cache still serves after shedding");
        // structures stayed in lockstep through pressure evictions
        assert_eq!(r.index.len(), r.store.len());
        assert_eq!(r.radix.len(), r.store.len());
        assert_eq!(r.tokens_of.len(), r.store.len());
    }

    #[test]
    fn session_turns_share_prefix_blocks() {
        // turn N+1's cached record must physically share turn N's blocks
        // (the arena's raison d'être) rather than duplicate them.
        let mut r = recycler(RecyclePolicy::Strict);
        let ids1 = r.tokenizer().encode(CACHE);
        let out1 = r.generate_ids(CACHE, ids1.clone(), 4, true).unwrap();
        assert_eq!(r.cache_len(), 1);

        let full_text1 = format!("{CACHE}{}", out1.text);
        let mut ids2 = ids1.clone();
        ids2.extend_from_slice(&out1.ids);
        let seg = " tell me more";
        let prompt2 = format!("{full_text1}{seg}");
        ids2.extend(r.tokenizer().encode(seg));
        let out2 = r.generate_ids(&prompt2, ids2, 4, true).unwrap();
        assert!(out2.cache_hit, "turn 2 must reuse turn 1's KV");
        assert_eq!(r.cache_len(), 2);

        let entry_ids = r.store().ids();
        let rec1 = r.store().peek(entry_ids[0]).unwrap();
        let rec2 = r.store().peek(entry_ids[1]).unwrap();
        // every fully-covered block of turn 1 is the SAME physical block in
        // turn 2's record (the boundary block may have copied on write)
        let bt = r.arena().block_tokens();
        let shared_blocks = rec1.token_len() / bt;
        assert!(shared_blocks >= 1, "workload too small to share blocks");
        assert_eq!(
            rec2.kv.block_ids()[..shared_blocks],
            rec1.kv.block_ids()[..shared_blocks],
            "prefix blocks must be shared, not copied"
        );
    }

    #[test]
    fn eviction_keeps_index_and_radix_consistent() {
        let engine = Engine::new(MockModel::new(ModelConfig::nano()));
        let mut r = Recycler::new(
            engine,
            toy_tokenizer(),
            Box::new(NgramEmbedder::new(64)),
            CacheConfig {
                max_entries: 2,
                ..Default::default()
            },
            RecyclePolicy::Strict,
        );
        r.populate_cache = false;
        r.warm(&["alpha beta gamma", "delta epsilon zeta", "eta theta iota"])
            .unwrap();
        assert_eq!(r.cache_len(), 2);
        // "alpha beta gamma" was evicted: retrieving its extension must miss
        let out = r.generate("alpha beta gamma delta", 2).unwrap();
        assert!(!out.cache_hit);
        // store/index sizes stay in lockstep
        assert_eq!(r.index.len(), r.store.len());
        assert_eq!(r.radix.len(), r.store.len());
        assert_eq!(r.tokens_of.len(), r.store.len());
    }

    #[test]
    fn spilled_record_hits_transparently_with_reload() {
        // max_entries 1 + a spill tier: warming a second prompt spills the
        // first to disk; a lookup of the spilled prompt must still be a
        // prefix hit (transparent reload), counted in spill_hits.
        let mut r = recycler_with(
            RecyclePolicy::Strict,
            CacheConfig {
                max_entries: 1,
                max_spill_bytes: 64 << 20,
                ..Default::default()
            },
        );
        r.populate_cache = false;
        r.warm(&[CACHE]).unwrap();
        r.warm(&[OTHER]).unwrap(); // CACHE -> cold tier
        assert_eq!(r.store().len(), 1);
        assert_eq!(r.store().spilled_len(), 1);
        // index/radix entries survive the spill
        assert_eq!(r.index.len(), r.store().total_len());
        assert_eq!(r.radix.len(), r.store().total_len());

        let out = r.generate(TEST, 4).unwrap();
        assert!(out.cache_hit, "spilled record must still serve a hit");
        let cache_len = r.tokenizer().encode(CACHE).len();
        assert_eq!(out.reuse_depth, cache_len);
        let s = r.store().stats();
        assert_eq!(s.spill_hits, 1);
        assert!(s.spills >= 2, "the reload re-spilled the other entry");
        assert!(s.spill_load_errors == 0);
    }

    #[test]
    fn radix_hit_reloads_spilled_record() {
        let mut r = recycler_with(
            RecyclePolicy::Radix,
            CacheConfig {
                max_entries: 1,
                max_spill_bytes: 64 << 20,
                ..Default::default()
            },
        );
        r.populate_cache = false;
        r.warm(&[CACHE]).unwrap();
        r.warm(&[OTHER]).unwrap(); // CACHE -> cold tier
        assert!(r.store().spilled_len() == 1);
        let out = r.generate(TEST, 4).unwrap();
        assert!(out.cache_hit, "radix entry survives the spill");
        assert_eq!(out.reuse_depth, r.tokenizer().encode(CACHE).len());
        assert_eq!(r.store().stats().spill_hits, 1);
    }

    #[test]
    fn lookup_miss_adopts_sibling_workers_spilled_record() {
        // Two recyclers (workers) share one spill_dir under distinct
        // namespaces. A computes CACHE's prefix and spills it; B — which
        // never saw CACHE — must adopt A's spilled record on its own
        // lookup miss and serve the extension as a hit, without touching
        // A's file (cross-worker cache mobility through the cold tier).
        let dir = std::env::temp_dir()
            .join(format!("recycle_adopt_rec_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let shared = |ns: &str| CacheConfig {
            max_entries: 1,
            max_spill_bytes: 64 << 20,
            spill_dir: Some(dir.to_string_lossy().into_owned()),
            spill_namespace: ns.into(),
            ..Default::default()
        };
        let mut a = recycler_with(RecyclePolicy::Strict, shared("w0_"));
        a.populate_cache = false;
        a.warm(&[CACHE]).unwrap();
        a.warm(&[OTHER]).unwrap(); // CACHE -> shared cold tier
        assert_eq!(a.store().spilled_len(), 1);

        let mut b = recycler_with(RecyclePolicy::Strict, shared("w1_"));
        b.populate_cache = false;
        let out = b.generate(TEST, 4).unwrap();
        assert!(out.cache_hit, "adoption must serve a cross-worker hit");
        assert_eq!(out.reuse_depth, b.tokenizer().encode(CACHE).len());
        let s = b.store().stats();
        assert_eq!(s.adoptions, 1);
        assert_eq!(s.spill_hits, 1, "adoption counts as a spill hit");
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 0);
        // the adoptee is indexed like an admitted record
        assert_eq!(b.index.len(), b.store().total_len());
        assert_eq!(b.radix.len(), b.store().total_len());

        // token identity with a cold baseline — placement and adoption
        // change latency and hit rate, never tokens
        let mut base = recycler(RecyclePolicy::Off);
        assert_eq!(base.generate(TEST, 4).unwrap().ids, out.ids);

        // adoption copies: the owner's record still serves its own hit
        let out_a = a.generate(TEST, 4).unwrap();
        assert!(out_a.cache_hit, "owner's record survives adoption");
        assert_eq!(a.store().stats().adoptions, 0);

        drop(a);
        drop(b);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn headroom_pass_stops_when_shedding_cannot_free() {
        // Regression for the deleted zero-yield stall memo: when every
        // cache block is pinned by an in-flight view, the headroom pass
        // must evict NOTHING (reclaimable == 0), and must resume evicting
        // the moment the pin drops — no latch state involved.
        let cfg = ModelConfig::nano();
        let arena = crate::kvcache::KvArena::new(&cfg, 16, 32);
        let engine = Engine::with_arena(MockModel::new(cfg), arena);
        let mut r = Recycler::new(
            engine,
            toy_tokenizer(),
            Box::new(NgramEmbedder::new(64)),
            CacheConfig {
                max_entries: 0,
                ..Default::default()
            },
            RecyclePolicy::Strict,
        );
        let id = r
            .insert_prompt("some cached prompt made of quite a few words")
            .unwrap();
        let pinned = r.store().peek(id).unwrap().attach();
        // burn free blocks below the headroom target (min(16, 16) = 16)
        let mut scratch = r.arena().new_view();
        scratch.reserve(14 * 16).unwrap();
        assert!(r.arena().free_blocks() < 16, "test needs arena pressure");

        r.ensure_arena_headroom();
        assert_eq!(r.cache_len(), 1, "futile eviction must not run");

        drop(pinned); // pin released: shedding is productive again
        r.ensure_arena_headroom();
        assert_eq!(r.cache_len(), 0, "productive eviction resumes");
        drop(scratch);
    }

    #[test]
    fn exact_duplicate_prompt_hits_with_full_depth() {
        let mut r = recycler(RecyclePolicy::Strict);
        r.warm(&[CACHE]).unwrap();
        let out = r.generate(CACHE, 3).unwrap();
        assert!(out.cache_hit);
        // baseline equivalence for the identical-prompt case
        let mut b = recycler(RecyclePolicy::Off);
        assert_eq!(b.generate(CACHE, 3).unwrap().ids, out.ids);
    }

    // ---- segment tier (tier 2) ----

    /// A shared document long enough to span several stride-8 segments.
    const DOC: &str = "the quick brown fox jumps over the lazy dog near the wide river";

    fn seg_cache(stride: usize, budget: f64) -> CacheConfig {
        CacheConfig {
            max_entries: 8,
            segment_tokens: stride,
            segment_fidelity_budget: budget,
            ..Default::default()
        }
    }

    #[test]
    fn segment_hit_serves_shared_document_at_shifted_offset() {
        let mut r = recycler_with(RecyclePolicy::Strict, seg_cache(8, 0.2));
        r.populate_cache = false;
        let cached = format!("alpha beta: {DOC}");
        r.warm(&[cached.as_str()]).unwrap();
        // same document, different (longer) head: the prefix tier can
        // never catch this — the shared span sits at a shifted offset
        let query = format!("a very different preamble, then {DOC}");
        let out = r.generate(&query, 4).unwrap();
        assert!(out.cache_hit, "shared document must segment-hit");
        let s = r.store().stats();
        assert_eq!(s.segment_hits, 1);
        assert!(s.reanchored_tokens >= 8, "got {}", s.reanchored_tokens);
        assert_eq!(s.hits, 1, "segment hit is the request's one hit");
        assert_eq!(s.misses, 0, "provisional exact-tier miss converted");
        assert!(out.reuse_depth >= 8);
        // content-exact on the mock backend: tokens match the baseline
        let mut base = recycler(RecyclePolicy::Off);
        assert_eq!(base.generate(&query, 4).unwrap().ids, out.ids);
    }

    #[test]
    fn zero_budget_keeps_serving_exact_only() {
        // budget 0.0 is the byte-identity contract: the tier neither
        // indexes nor serves, so behaviour is exact-prefix-only
        let mut r = recycler_with(RecyclePolicy::Strict, seg_cache(8, 0.0));
        r.populate_cache = false;
        let cached = format!("alpha beta: {DOC}");
        r.warm(&[cached.as_str()]).unwrap();
        assert!(r.seg_index.is_empty(), "budget 0 must not index segments");
        let query = format!("a very different preamble, then {DOC}");
        let out = r.generate(&query, 4).unwrap();
        assert!(!out.cache_hit);
        assert_eq!(out.reuse_depth, 0);
        assert_eq!(r.store().stats().segment_hits, 0);
    }

    #[test]
    fn budget_override_backfills_warmed_cache() {
        // the scheduler applies ServerConfig::segment_fidelity_budget
        // AFTER a factory may have warmed the cache; enabling must
        // back-fill the segment index from the hot store
        let mut r = recycler_with(RecyclePolicy::Strict, seg_cache(8, 0.0));
        r.populate_cache = false;
        let cached = format!("alpha beta: {DOC}");
        r.warm(&[cached.as_str()]).unwrap();
        assert!(r.seg_index.is_empty());
        r.set_segment_fidelity_budget(0.2);
        assert!(!r.seg_index.is_empty(), "enable back-fills warmed records");
        let query = format!("a very different preamble, then {DOC}");
        let out = r.generate(&query, 4).unwrap();
        assert!(out.cache_hit);
        assert_eq!(r.store().stats().segment_hits, 1);
    }

    #[test]
    fn segment_eviction_keeps_side_structures_in_lockstep() {
        // destroying a record (max_entries 1, no spill tier) must drop
        // its segment entries with it
        let mut r = recycler_with(
            RecyclePolicy::Strict,
            CacheConfig {
                max_entries: 1,
                segment_tokens: 4,
                segment_fidelity_budget: 0.2,
                ..Default::default()
            },
        );
        r.populate_cache = false;
        r.warm(&["alpha beta gamma delta epsilon zeta"]).unwrap();
        let first = r.seg_index.len();
        assert!(first > 0);
        r.warm(&["eta theta iota kappa lambda mu nu"]).unwrap();
        assert_eq!(r.segs_of_rec.len(), 1, "evicted record unindexed");
        let live: usize = r.segs_of_rec.values().map(|v| v.len()).sum();
        assert_eq!(r.seg_index.len(), live);
        assert_eq!(r.seg_of.len(), live);
    }

    #[test]
    fn empty_prompt_misses_cleanly_without_panicking() {
        // regression: an empty prompt embeds to a zero-norm vector, and
        // the index comparator used to be able to panic on the NaN
        // scores that produced. The lookup must come back a clean miss
        // and the engine's typed rejection must surface as an Err.
        let mut r = recycler_with(RecyclePolicy::Strict, seg_cache(8, 0.2));
        r.warm(&[CACHE]).unwrap();
        let hits_before = r.store().stats().hits;
        let out = r.generate("", 2);
        assert!(out.is_err(), "empty prompts are rejected, not served");
        assert_eq!(r.store().stats().hits, hits_before, "no hit counted");
    }

    #[test]
    fn segment_tier_never_admits_reanchored_kv() {
        // a segment hit serves approximated KV; it must never be captured
        // back into the cache (only exactly-computed prefixes are)
        let mut r = recycler_with(RecyclePolicy::Strict, seg_cache(8, 0.2));
        r.populate_cache = true; // online population ON
        let cached = format!("alpha beta: {DOC}");
        r.warm(&[cached.as_str()]).unwrap();
        let len_before = r.cache_len();
        let query = format!("a very different preamble, then {DOC}");
        let out = r.generate(&query, 4).unwrap();
        assert!(out.cache_hit);
        assert_eq!(r.store().stats().segment_hits, 1);
        assert_eq!(r.cache_len(), len_before, "no admission on a segment hit");
    }
}
