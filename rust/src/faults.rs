//! Deterministic, seed-driven fault injection for the serving stack.
//!
//! A [`FaultPlan`] describes *which* failure sites misbehave and *when*:
//! probabilistically (per-operation Bernoulli draws from a shared
//! [`Rng`](crate::util::rng::Rng), so a plan seed replays bit-for-bit the
//! way `PALLAS_PROP_SEED` replays a property case) or scripted (exact
//! 1-based operation indices per site, for pinpoint regression tests).
//! Installing a plan yields a [`FaultHandle`] — a cheap cloneable handle
//! the failure-domain seams hold permanently:
//!
//! | domain                              | sites |
//! |-------------------------------------|-------|
//! | `ForwardModel` (mock backend)       | [`FaultSite::ModelTransient`], [`FaultSite::ModelPermanent`], [`FaultSite::ModelSlow`] |
//! | `SpillTier` (disk cold tier)        | [`FaultSite::SpillWrite`], [`FaultSite::SpillRead`], [`FaultSite::SpillTorn`], [`FaultSite::SpillSlow`] |
//! | `KvArena` (paged block allocator)   | [`FaultSite::ArenaSpike`] |
//! | streaming front (`server/stream.rs`)| [`FaultSite::ClientStall`], [`FaultSite::TornClientWrite`] |
//!
//! The network front's sites model *misbehaving clients* from inside the
//! event loop — a socket that stops being readable mid-request and a
//! flush that lands only a prefix of its bytes — complementing the raw-
//! socket integration tests that misbehave from the outside (a client
//! that disconnects mid-line needs no in-process seam).
//!
//! The seams are compiled in unconditionally but **inert by default**:
//! an uninstalled handle ([`FaultHandle::off`]) is a `None` and every
//! [`FaultHandle::roll`] on it is a single branch — no lock, no RNG, no
//! allocation — so the production request path pays one predictable-taken
//! branch per potential fault site and nothing else.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::rng::Rng;

/// One injectable failure site. The per-site operation counter (the basis
/// of scripted injection) counts every *attempt* at the site, fired or not.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultSite {
    /// A forward call fails with a retryable backend error (`Error::Xla`).
    ModelTransient,
    /// A forward call fails with a non-retryable error
    /// (`Error::ShapeMismatch`) — the request must die typed, not loop.
    ModelPermanent,
    /// A forward call stalls for the plan's `slow_step` before running.
    ModelSlow,
    /// A spill write fails with `Error::Io` before any bytes land.
    SpillWrite,
    /// A spill-file read fails with `Error::Io` (transient media error).
    SpillRead,
    /// A spill write persists a truncated file — later reloads must detect
    /// it (`Error::Corrupt` via the CRC), never return wrong KV data.
    SpillTorn,
    /// A spill reload stalls for the plan's `slow_step` before decoding.
    SpillSlow,
    /// An arena block allocation reports exhaustion despite free blocks —
    /// a refcount-pressure spike the shed/retry paths must absorb.
    ArenaSpike,
    /// The streaming front skips one read pass on a connection — a client
    /// that stalls mid-request. The event loop must keep every other
    /// connection live and pick the stalled one up next pass.
    ClientStall,
    /// A flush writes only a prefix of the connection's buffered frames —
    /// a torn client write. The unwritten tail must stay buffered so
    /// framing is delayed, never corrupted.
    TornClientWrite,
}

impl FaultSite {
    pub const ALL: [FaultSite; 10] = [
        FaultSite::ModelTransient,
        FaultSite::ModelPermanent,
        FaultSite::ModelSlow,
        FaultSite::SpillWrite,
        FaultSite::SpillRead,
        FaultSite::SpillTorn,
        FaultSite::SpillSlow,
        FaultSite::ArenaSpike,
        FaultSite::ClientStall,
        FaultSite::TornClientWrite,
    ];
}

/// A deterministic fault schedule: per-site probabilities and/or scripted
/// operation indices, all driven by one seed.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    seed: u64,
    rates: HashMap<FaultSite, f64>,
    scripts: HashMap<FaultSite, Vec<u64>>,
    slow_step: Duration,
}

impl FaultPlan {
    pub fn new(seed: u64) -> Self {
        FaultPlan {
            seed,
            rates: HashMap::new(),
            scripts: HashMap::new(),
            slow_step: Duration::from_micros(50),
        }
    }

    /// Fire `site` on each operation independently with probability `p`.
    pub fn with_rate(mut self, site: FaultSite, p: f64) -> Self {
        self.rates.insert(site, p.clamp(0.0, 1.0));
        self
    }

    /// Fire `site` exactly at the given 1-based operation indices
    /// (in addition to any probabilistic rate on the same site).
    pub fn script(mut self, site: FaultSite, ops: &[u64]) -> Self {
        self.scripts.entry(site).or_default().extend_from_slice(ops);
        self
    }

    /// How long `ModelSlow` / `SpillSlow` injections stall.
    pub fn with_slow_step(mut self, d: Duration) -> Self {
        self.slow_step = d;
        self
    }

    /// Arm the plan: the returned handle (and its clones) is what the
    /// failure-domain seams consult.
    pub fn install(self) -> FaultHandle {
        let rng = Rng::new(self.seed);
        FaultHandle(Some(Arc::new(Inner {
            plan: self,
            state: Mutex::new(State {
                rng,
                counts: HashMap::new(),
                injected: HashMap::new(),
            }),
        })))
    }
}

struct Inner {
    plan: FaultPlan,
    state: Mutex<State>,
}

struct State {
    rng: Rng,
    /// Per-site operation counter (1-based after the bump).
    counts: HashMap<FaultSite, u64>,
    /// Per-site fired-fault counter.
    injected: HashMap<FaultSite, u64>,
}

/// Shared handle to an installed [`FaultPlan`] — or, by default, to no
/// plan at all. Cloning shares the plan state, so every seam holding a
/// clone draws from the same deterministic schedule.
#[derive(Clone, Default)]
pub struct FaultHandle(Option<Arc<Inner>>);

impl FaultHandle {
    /// The inert handle: every roll is `false` at the cost of one branch.
    pub fn off() -> Self {
        FaultHandle(None)
    }

    /// Is a plan installed?
    pub fn is_active(&self) -> bool {
        self.0.is_some()
    }

    /// Count one operation at `site` and decide whether it faults.
    /// Scripted indices fire first; otherwise the site's rate draws from
    /// the shared seeded RNG. An uninstalled handle returns `false`
    /// without touching any state — the production fast path.
    pub fn roll(&self, site: FaultSite) -> bool {
        let Some(inner) = &self.0 else {
            return false;
        };
        let mut st = inner.state.lock().expect("fault state lock");
        let op = st.counts.entry(site).or_insert(0);
        *op += 1;
        let op = *op;
        let scripted = inner
            .plan
            .scripts
            .get(&site)
            .is_some_and(|ops| ops.contains(&op));
        let fired = scripted
            || inner
                .plan
                .rates
                .get(&site)
                .copied()
                .is_some_and(|p| p > 0.0 && st.rng.chance(p));
        if fired {
            *st.injected.entry(site).or_insert(0) += 1;
        }
        fired
    }

    /// The stall duration for slow-site injections (None when inert).
    pub fn slow_step(&self) -> Option<Duration> {
        self.0.as_ref().map(|i| i.plan.slow_step)
    }

    /// How many faults have fired at `site` so far.
    pub fn injected(&self, site: FaultSite) -> u64 {
        match &self.0 {
            Some(inner) => {
                let st = inner.state.lock().expect("fault state lock");
                st.injected.get(&site).copied().unwrap_or(0)
            }
            None => 0,
        }
    }

    /// Total faults fired across all sites.
    pub fn total_injected(&self) -> u64 {
        match &self.0 {
            Some(inner) => {
                let st = inner.state.lock().expect("fault state lock");
                st.injected.values().sum()
            }
            None => 0,
        }
    }

    /// How many operations `site` has seen (fired or not).
    pub fn ops(&self, site: FaultSite) -> u64 {
        match &self.0 {
            Some(inner) => {
                let st = inner.state.lock().expect("fault state lock");
                st.counts.get(&site).copied().unwrap_or(0)
            }
            None => 0,
        }
    }
}

impl std::fmt::Debug for FaultHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.0 {
            None => write!(f, "FaultHandle(off)"),
            Some(inner) => write!(
                f,
                "FaultHandle(seed={}, injected={})",
                inner.plan.seed,
                self.total_injected()
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_handle_never_fires() {
        let h = FaultHandle::off();
        for site in FaultSite::ALL {
            for _ in 0..100 {
                assert!(!h.roll(site));
            }
            assert_eq!(h.injected(site), 0);
            assert_eq!(h.ops(site), 0);
        }
        assert!(!h.is_active());
        assert!(h.slow_step().is_none());
    }

    #[test]
    fn default_handle_is_off() {
        let h = FaultHandle::default();
        assert!(!h.is_active());
        assert!(!h.roll(FaultSite::ModelTransient));
    }

    #[test]
    fn same_seed_same_schedule() {
        let mk = || FaultPlan::new(42).with_rate(FaultSite::SpillRead, 0.3).install();
        let a = mk();
        let b = mk();
        let sa: Vec<bool> = (0..200).map(|_| a.roll(FaultSite::SpillRead)).collect();
        let sb: Vec<bool> = (0..200).map(|_| b.roll(FaultSite::SpillRead)).collect();
        assert_eq!(sa, sb, "same seed must replay the same fault schedule");
        assert!(sa.iter().any(|&x| x), "rate 0.3 over 200 ops should fire");
        assert!(!sa.iter().all(|&x| x), "rate 0.3 should not always fire");
        assert_eq!(a.injected(FaultSite::SpillRead), b.injected(FaultSite::SpillRead));
    }

    #[test]
    fn scripted_ops_fire_exactly() {
        let h = FaultPlan::new(7)
            .script(FaultSite::ModelTransient, &[2, 5])
            .install();
        let fired: Vec<bool> = (0..6).map(|_| h.roll(FaultSite::ModelTransient)).collect();
        assert_eq!(fired, vec![false, true, false, false, true, false]);
        assert_eq!(h.injected(FaultSite::ModelTransient), 2);
        assert_eq!(h.ops(FaultSite::ModelTransient), 6);
        // other sites untouched
        assert!(!h.roll(FaultSite::SpillWrite));
        assert_eq!(h.injected(FaultSite::SpillWrite), 0);
    }

    #[test]
    fn clones_share_state() {
        let h = FaultPlan::new(1)
            .script(FaultSite::ArenaSpike, &[2])
            .install();
        let h2 = h.clone();
        assert!(!h.roll(FaultSite::ArenaSpike)); // op 1
        assert!(h2.roll(FaultSite::ArenaSpike)); // op 2 — shared counter
        assert_eq!(h.total_injected(), 1);
    }

    #[test]
    fn rate_one_always_fires_rate_zero_never() {
        let h = FaultPlan::new(9)
            .with_rate(FaultSite::SpillWrite, 1.0)
            .with_rate(FaultSite::SpillRead, 0.0)
            .install();
        for _ in 0..50 {
            assert!(h.roll(FaultSite::SpillWrite));
            assert!(!h.roll(FaultSite::SpillRead));
        }
    }

    #[test]
    fn slow_step_configurable() {
        let h = FaultPlan::new(3)
            .with_slow_step(Duration::from_millis(2))
            .install();
        assert_eq!(h.slow_step(), Some(Duration::from_millis(2)));
    }
}
