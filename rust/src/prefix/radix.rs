//! Token radix tree (compressed trie) for longest-prefix retrieval.
//!
//! Maps token sequences to entry keys. `longest_prefix(tokens)` returns the
//! *deepest* stored sequence that is a full prefix of `tokens` — the
//! SGLang-radix-cache generalization of the paper's single-candidate test.
//! Operations are O(matched tokens); edges store token spans (path
//! compression) so long prompts don't blow up node counts.

use std::collections::HashMap;

#[derive(Debug)]
struct Node {
    /// Children keyed by the first token of the edge.
    children: HashMap<u32, Edge>,
    /// Entry key terminating exactly at this node, if any.
    key: Option<u64>,
}

#[derive(Debug)]
struct Edge {
    span: Vec<u32>,
    node: Node,
}

impl Node {
    fn new() -> Self {
        Node {
            children: HashMap::new(),
            key: None,
        }
    }
}

/// Compressed token trie mapping sequences -> caller keys.
#[derive(Debug)]
pub struct RadixTree {
    root: Node,
    len: usize,
}

impl Default for RadixTree {
    fn default() -> Self {
        Self::new()
    }
}

impl RadixTree {
    pub fn new() -> Self {
        RadixTree {
            root: Node::new(),
            len: 0,
        }
    }

    /// Number of stored sequences.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Insert a sequence under `key`. Replaces (and returns) a previous key
    /// stored for the identical sequence.
    pub fn insert(&mut self, tokens: &[u32], key: u64) -> Option<u64> {
        let mut node = &mut self.root;
        let mut i = 0;
        loop {
            if i == tokens.len() {
                let old = node.key.replace(key);
                if old.is_none() {
                    self.len += 1;
                }
                return old;
            }
            let first = tokens[i];
            if !node.children.contains_key(&first) {
                node.children.insert(
                    first,
                    Edge {
                        span: tokens[i..].to_vec(),
                        node: Node {
                            children: HashMap::new(),
                            key: Some(key),
                        },
                    },
                );
                self.len += 1;
                return None;
            }
            let edge = node.children.get_mut(&first).unwrap();
            let common = edge
                .span
                .iter()
                .zip(&tokens[i..])
                .take_while(|(a, b)| a == b)
                .count();
            if common < edge.span.len() {
                // Split the edge at `common`.
                let tail_span = edge.span.split_off(common);
                let mut mid = Node::new();
                let old_child = std::mem::replace(&mut edge.node, Node::new());
                mid.children.insert(
                    tail_span[0],
                    Edge {
                        span: tail_span,
                        node: old_child,
                    },
                );
                edge.node = mid;
            }
            i += common;
            node = &mut node.children.get_mut(&first).unwrap().node;
        }
    }

    /// Exact lookup: a terminal must sit at exactly `tokens.len()`.
    pub fn get(&self, tokens: &[u32]) -> Option<u64> {
        let (depth, key, _) = self.walk(tokens);
        if depth == tokens.len() {
            key
        } else {
            None
        }
    }

    /// Longest stored sequence that is a full prefix of `tokens`:
    /// returns `(depth, key)`.
    pub fn longest_prefix(&self, tokens: &[u32]) -> Option<(usize, u64)> {
        let (depth, key, _) = self.walk(tokens);
        key.map(|k| (depth, k))
    }

    /// Walk as far as `tokens` allows; track the deepest terminal node.
    /// Returns (terminal_depth, terminal_key, walked_to_end).
    fn walk(&self, tokens: &[u32]) -> (usize, Option<u64>, bool) {
        let mut node = &self.root;
        let mut i = 0;
        let mut best: (usize, Option<u64>) = (0, None);
        if node.key.is_some() {
            best = (0, node.key);
        }
        loop {
            if i == tokens.len() {
                return (best.0, best.1, true);
            }
            let Some(edge) = node.children.get(&tokens[i]) else {
                return (best.0, best.1, false);
            };
            let rest = &tokens[i..];
            if rest.len() < edge.span.len() || rest[..edge.span.len()] != edge.span[..] {
                return (best.0, best.1, false);
            }
            i += edge.span.len();
            node = &edge.node;
            if node.key.is_some() {
                best = (i, node.key);
            }
        }
    }

    /// Remove a sequence. Returns its key if present. (Nodes are left in
    /// place — fine for serving-scale entry counts; eviction rebuilds.)
    pub fn remove(&mut self, tokens: &[u32]) -> Option<u64> {
        fn go(node: &mut Node, tokens: &[u32]) -> Option<u64> {
            if tokens.is_empty() {
                return node.key.take();
            }
            let edge = node.children.get_mut(&tokens[0])?;
            if tokens.len() < edge.span.len() || tokens[..edge.span.len()] != edge.span[..] {
                return None;
            }
            go(&mut edge.node, &tokens[edge.span.len()..])
        }
        let out = go(&mut self.root, tokens);
        if out.is_some() {
            self.len -= 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get() {
        let mut t = RadixTree::new();
        assert_eq!(t.insert(&[1, 2, 3], 10), None);
        assert_eq!(t.insert(&[1, 2, 4], 20), None);
        assert_eq!(t.insert(&[1, 2], 30), None);
        assert_eq!(t.len(), 3);
        assert_eq!(t.get(&[1, 2, 3]), Some(10));
        assert_eq!(t.get(&[1, 2, 4]), Some(20));
        assert_eq!(t.get(&[1, 2]), Some(30));
        assert_eq!(t.get(&[1]), None);
        assert_eq!(t.get(&[1, 2, 5]), None);
    }

    #[test]
    fn replace_same_sequence() {
        let mut t = RadixTree::new();
        assert_eq!(t.insert(&[5, 6], 1), None);
        assert_eq!(t.insert(&[5, 6], 2), Some(1));
        assert_eq!(t.len(), 1);
        assert_eq!(t.get(&[5, 6]), Some(2));
    }

    #[test]
    fn longest_prefix_picks_deepest() {
        let mut t = RadixTree::new();
        t.insert(&[1, 2], 10);
        t.insert(&[1, 2, 3, 4], 20);
        assert_eq!(t.longest_prefix(&[1, 2, 3, 4, 5, 6]), Some((4, 20)));
        assert_eq!(t.longest_prefix(&[1, 2, 3]), Some((2, 10)));
        assert_eq!(t.longest_prefix(&[1, 2]), Some((2, 10)));
        assert_eq!(t.longest_prefix(&[9]), None);
    }

    #[test]
    fn longest_prefix_requires_full_entry() {
        let mut t = RadixTree::new();
        t.insert(&[1, 2, 3, 4], 20);
        // query diverges inside the only entry: no terminal reached
        assert_eq!(t.longest_prefix(&[1, 2, 3]), None);
        assert_eq!(t.longest_prefix(&[1, 2, 9, 9]), None);
    }

    #[test]
    fn edge_splitting() {
        let mut t = RadixTree::new();
        t.insert(&[1, 2, 3, 4, 5], 1);
        t.insert(&[1, 2, 9], 2); // splits the 1-2-3-4-5 edge after [1,2]
        assert_eq!(t.get(&[1, 2, 3, 4, 5]), Some(1));
        assert_eq!(t.get(&[1, 2, 9]), Some(2));
        assert_eq!(t.longest_prefix(&[1, 2, 9, 7]), Some((3, 2)));
    }

    #[test]
    fn empty_sequence_as_root_key() {
        let mut t = RadixTree::new();
        t.insert(&[], 5);
        assert_eq!(t.get(&[]), Some(5));
        assert_eq!(t.longest_prefix(&[1, 2]), Some((0, 5)));
    }

    #[test]
    fn remove() {
        let mut t = RadixTree::new();
        t.insert(&[1, 2, 3], 1);
        t.insert(&[1, 2], 2);
        assert_eq!(t.remove(&[1, 2, 3]), Some(1));
        assert_eq!(t.remove(&[1, 2, 3]), None);
        assert_eq!(t.len(), 1);
        assert_eq!(t.get(&[1, 2, 3]), None);
        assert_eq!(t.longest_prefix(&[1, 2, 3]), Some((2, 2)));
    }
}
