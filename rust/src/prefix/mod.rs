//! Token-prefix machinery.
//!
//! * [`common_prefix_len`] / [`reuse_depth`] — the paper's §3.1 prefix test:
//!   `r = max{ r' <= min(m,k) : x_{1:r'}^{(t)} = x_{1:r'}^{(c)} }`, with the
//!   strict condition `r == k` (cached prompt is a *full* prefix).
//! * [`radix::RadixTree`] — SGLang-style token radix tree for the
//!   longest-prefix extension (the paper's future work §6.2): instead of
//!   retrieving one embedding candidate and demanding a full-prefix match,
//!   find the deepest cached prefix across *all* entries in O(depth).

pub mod radix;

pub use radix::RadixTree;

/// Length of the common prefix of two token sequences.
pub fn common_prefix_len(a: &[u32], b: &[u32]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

/// The paper's reuse depth: common prefix of cached prompt `c` and test
/// prompt `t`, and whether the strict full-prefix condition `r == |c|`
/// holds (with `|c| > 0`).
pub fn reuse_depth(cached: &[u32], test: &[u32]) -> (usize, bool) {
    let r = common_prefix_len(cached, test);
    (r, !cached.is_empty() && r == cached.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn common_prefix_basics() {
        assert_eq!(common_prefix_len(&[1, 2, 3], &[1, 2, 4]), 2);
        assert_eq!(common_prefix_len(&[], &[1]), 0);
        assert_eq!(common_prefix_len(&[1, 2], &[1, 2]), 2);
        assert_eq!(common_prefix_len(&[5], &[6]), 0);
    }

    #[test]
    fn strict_condition() {
        // cached is a full prefix -> reusable
        assert_eq!(reuse_depth(&[1, 2], &[1, 2, 3]), (2, true));
        // equal sequences -> reusable (paper: r = k = m)
        assert_eq!(reuse_depth(&[1, 2], &[1, 2]), (2, true));
        // diverging mid-way -> NOT reusable even though r > 0
        assert_eq!(reuse_depth(&[1, 2, 9], &[1, 2, 3]), (2, false));
        // cached longer than test -> not a prefix of it
        assert_eq!(reuse_depth(&[1, 2, 3], &[1, 2]), (2, false));
        // empty cache entry is never a hit
        assert_eq!(reuse_depth(&[], &[1, 2]), (0, false));
    }
}
