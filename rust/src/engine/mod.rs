//! Generation engine: chunked prefill + greedy decode over a
//! [`ForwardModel`].
//!
//! The engine is backend-agnostic: the PJRT [`crate::runtime::Runtime`]
//! implements [`ForwardModel`] for production, and
//! [`crate::testutil::MockModel`] implements it for coordinator/recycler
//! unit tests that must run without artifacts.
//!
//! Chunk scheduling mirrors `python/compile/model.py::greedy_generate`
//! exactly (largest bucket that fits, else the smallest bucket padded), so
//! the Rust engine reproduces the Python golden fixtures token-for-token.

mod generate;

pub use generate::{Engine, Generated};

use crate::config::ModelConfig;
use crate::error::Result;
use crate::kvcache::KvView;

/// A model that can process one chunk of new tokens against a host-side
/// paged KV view. Implementations must guarantee the paper's exactness
/// property: encoding a sequence in any chunk split yields the same logits
/// and KV. Backends that need dense tensors (the PJRT executor) gather the
/// view at the chunk boundary and scatter the new rows back — the paged
/// representation never changes model semantics.
///
/// Deliberately NOT `Send`: the PJRT handles wrap `Rc` internally, so the
/// production model lives on exactly one thread — the coordinator builds it
/// *inside* its worker thread (see [`crate::coordinator::Coordinator::spawn`]).
pub trait ForwardModel {
    fn config(&self) -> &ModelConfig;

    /// Process `tokens` (padded to a bucket size; `valid_len` real) at
    /// position `cur_len`, writing new KV rows into `kv` (a paged
    /// `[L, 2, H, len, D]` view, valid for at least `cur_len` positions)
    /// and returning logits `[C, V]` flat.
    fn forward_chunk(
        &self,
        tokens: &[u32],
        valid_len: usize,
        kv: &mut KvView,
        cur_len: usize,
    ) -> Result<Vec<f32>>;
}

/// Pick the chunk bucket for `n` pending tokens: the smallest bucket that
/// covers all of them (padded), else the largest bucket. Minimizes call
/// count — every call re-uploads the KV buffer, so fewer calls beat fewer
/// padded rows. Mirrors `python greedy_generate`'s scheduler.
pub fn pick_chunk(buckets: &[usize], n: usize) -> usize {
    assert!(!buckets.is_empty() && n > 0);
    buckets
        .iter()
        .find(|&&b| b >= n)
        .copied()
        .unwrap_or_else(|| *buckets.last().unwrap())
}

/// Full chunk plan for `n` pending tokens.
pub fn plan_chunks(buckets: &[usize], mut n: usize) -> Vec<usize> {
    let mut plan = Vec::new();
    while n > 0 {
        let c = pick_chunk(buckets, n);
        plan.push(c);
        n = n.saturating_sub(c);
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_rounds_up_to_one_call() {
        let b = vec![1, 8, 32, 64];
        assert_eq!(plan_chunks(&b, 100), vec![64, 64]);
        assert_eq!(plan_chunks(&b, 64), vec![64]);
        assert_eq!(plan_chunks(&b, 7), vec![8]);
        assert_eq!(plan_chunks(&b, 9), vec![32]);
        assert_eq!(plan_chunks(&b, 1), vec![1]);
        assert!(plan_chunks(&b, 0).is_empty());
    }

    #[test]
    fn plan_total_covers() {
        let b = vec![1, 8, 32];
        for n in 1..200 {
            let plan = plan_chunks(&b, n);
            let total: usize = plan.iter().sum();
            assert!(total >= n);
            assert!(total - n < *b.last().unwrap(), "n={n} plan={plan:?}");
        }
    }
}
