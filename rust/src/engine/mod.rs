//! Generation engine: chunked prefill + greedy decode over a
//! [`ForwardModel`].
//!
//! The engine is backend-agnostic: the PJRT [`crate::runtime::Runtime`]
//! implements [`ForwardModel`] for production, and
//! [`crate::testutil::MockModel`] implements it for coordinator/recycler
//! unit tests that must run without artifacts.
//!
//! Chunk scheduling mirrors `python/compile/model.py::greedy_generate`
//! exactly (largest bucket that fits, else the smallest bucket padded), so
//! the Rust engine reproduces the Python golden fixtures token-for-token.
//!
//! Two decode surfaces share one implementation:
//! * [`Engine::generate`] — run one request to completion (the paper's
//!   `model.generate(..., do_sample=False)`);
//! * the step-wise [`DecodeStream`] API ([`Engine::start_stream`] /
//!   [`Engine::step_streams`]) — the continuous-batching substrate: many
//!   in-flight sequences advance one token per [`ForwardModel::forward_batch`]
//!   call. `generate` is literally a one-stream loop over the same steps,
//!   so batched decode is token-identical to sequential by construction.

mod batch;
mod generate;

pub use batch::{DecodeStream, PrefillProgress, PrefillStream, StepReport};
pub use generate::{Engine, Generated};

use crate::config::ModelConfig;
use crate::error::Result;
use crate::kvcache::KvView;

/// A model that can process one chunk of new tokens against a host-side
/// paged KV view. Implementations must guarantee the paper's exactness
/// property: encoding a sequence in any chunk split yields the same logits
/// and KV. Backends that need dense tensors (the PJRT executor) gather the
/// view at the chunk boundary and scatter the new rows back — the paged
/// representation never changes model semantics.
///
/// Deliberately NOT `Send`: the PJRT handles wrap `Rc` internally, so the
/// production model lives on exactly one thread — the coordinator builds it
/// *inside* its worker thread (see [`crate::coordinator::Coordinator::spawn`]).
pub trait ForwardModel {
    fn config(&self) -> &ModelConfig;

    /// Process `tokens` (padded to a bucket size; `valid_len` real) at
    /// position `cur_len`, writing new KV rows into `kv` (a paged
    /// `[L, 2, H, len, D]` view, valid for at least `cur_len` positions)
    /// and returning logits `[C, V]` flat.
    ///
    /// Contract: the final chunk of a near-window prompt may be *unpadded*
    /// (`tokens.len() == valid_len`, not a bucket size) when padding to
    /// the smallest covering bucket would spill past `max_seq` — the
    /// engine's prefill emits exactly that shape so legal prompts of up to
    /// `max_seq` tokens never fail. Backends without a matching compiled
    /// shape execute it token-by-token through the 1-bucket (see the PJRT
    /// executor), which the chunk-split-invariance property makes exact.
    fn forward_chunk(
        &self,
        tokens: &[u32],
        valid_len: usize,
        kv: &mut KvView,
        cur_len: usize,
    ) -> Result<Vec<f32>>;

    /// Process a batch of *independent* sequences' chunks in one call,
    /// returning each item's logits in order.
    ///
    /// The default implementation loops [`forward_chunk`] item by item —
    /// correct for every backend — so a backend only overrides this when
    /// the device can genuinely run lanes concurrently (one dispatch for
    /// the whole batch, e.g. a batched decode executable). Overrides must
    /// preserve the exactness contract: each item's logits and KV rows are
    /// identical to what a lone `forward_chunk` call would produce.
    ///
    /// [`forward_chunk`]: ForwardModel::forward_chunk
    fn forward_batch(&self, items: &mut [BatchItem<'_>]) -> Result<Vec<Vec<f32>>> {
        items
            .iter_mut()
            .map(|it| self.forward_chunk(it.tokens, it.valid_len, it.kv, it.cur_len))
            .collect()
    }
}

/// One sequence's slice of a [`ForwardModel::forward_batch`] call: `tokens`
/// (padded to a bucket, or the unpadded final near-window chunk) land at
/// position `cur_len` of that sequence's paged `kv` view. Items are
/// independent sequences — their views may share arena blocks (a recycled
/// common prefix), which COW isolates on write.
pub struct BatchItem<'a> {
    pub tokens: &'a [u32],
    pub valid_len: usize,
    pub kv: &'a mut KvView,
    pub cur_len: usize,
}

/// Pick the chunk bucket for `n` pending tokens: the smallest bucket that
/// covers all of them (padded), else the largest bucket. Minimizes call
/// count — every call re-uploads the KV buffer, so fewer calls beat fewer
/// padded rows. Mirrors `python greedy_generate`'s scheduler.
pub fn pick_chunk(buckets: &[usize], n: usize) -> usize {
    assert!(!buckets.is_empty() && n > 0);
    buckets
        .iter()
        .find(|&&b| b >= n)
        .copied()
        .unwrap_or_else(|| *buckets.last().unwrap())
}

/// One prefill scheduling step: `(padded_chunk, take)` for `pending` new
/// tokens with `room` positions left before the context window. `take`
/// real tokens go out in a chunk of `padded_chunk` slots; when even the
/// smallest bucket would spill past the window the chunk is *unpadded*
/// (`padded_chunk == take`, the [`ForwardModel`] near-window contract —
/// see [`crate::config::ModelConfig::unpadded_chunk_legal`]). Shared by
/// the one-shot [`Engine::prefill`] and the suspendable
/// [`Engine::step_prefill`], so a budget-limited chunk sequence picks
/// buckets exactly the way the inline path does (chunk-split-invariance
/// then makes the two token-identical).
pub(crate) fn chunk_step(cfg: &ModelConfig, pending: usize, room: usize) -> (usize, usize) {
    let mut c = pick_chunk(&cfg.chunk_sizes, pending);
    if c > room {
        // A padded bucket would spill past the context window: prefer the
        // largest bucket that still fits. When even the smallest bucket
        // overflows (`pending <= room < min bucket` — a deep recycled
        // prefix plus a prompt near max_seq), fall back to an *unpadded*
        // final chunk: the pending tokens themselves always fit
        // (`ids.len() <= max_seq` implies `pending <= room`), so a legal
        // prompt must never fail here.
        c = match cfg.chunk_sizes.iter().filter(|&&b| b <= room).next_back() {
            Some(&b) => b,
            None => pending,
        };
    }
    (c, pending.min(c))
}

/// Full chunk plan for `n` pending tokens.
pub fn plan_chunks(buckets: &[usize], mut n: usize) -> Vec<usize> {
    let mut plan = Vec::new();
    while n > 0 {
        let c = pick_chunk(buckets, n);
        plan.push(c);
        n = n.saturating_sub(c);
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_rounds_up_to_one_call() {
        let b = vec![1, 8, 32, 64];
        assert_eq!(plan_chunks(&b, 100), vec![64, 64]);
        assert_eq!(plan_chunks(&b, 64), vec![64]);
        assert_eq!(plan_chunks(&b, 7), vec![8]);
        assert_eq!(plan_chunks(&b, 9), vec![32]);
        assert_eq!(plan_chunks(&b, 1), vec![1]);
        assert!(plan_chunks(&b, 0).is_empty());
    }

    #[test]
    fn plan_total_covers() {
        let b = vec![1, 8, 32];
        for n in 1..200 {
            let plan = plan_chunks(&b, n);
            let total: usize = plan.iter().sum();
            assert!(total >= n);
            assert!(total - n < *b.last().unwrap(), "n={n} plan={plan:?}");
        }
    }
}
