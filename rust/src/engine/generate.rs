//! The greedy generation loop (the paper's `model.generate(...,
//! do_sample=False)` equivalent, with explicit KV injection).
//!
//! KV lives in a paged [`KvView`] over the engine's [`KvArena`]: a
//! recycled prefix arrives as a shared block table (zero-copy), the prefill
//! appends rows copy-on-write, and `capture_prompt_kv` snapshots are
//! O(blocks) clones instead of full-buffer copies.

use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::kvcache::{KvArena, KvView};
use crate::metrics::Counters;

use super::ForwardModel;

/// Result of one generation call.
#[derive(Debug, Clone)]
pub struct Generated {
    /// Newly generated token ids (prompt not included).
    pub ids: Vec<u32>,
    /// Prompt length in tokens (m).
    pub prompt_tokens: usize,
    /// Tokens skipped via KV injection (k — the reuse depth).
    pub reused_tokens: usize,
    /// Forward calls spent on prefill.
    pub prefill_calls: usize,
    /// Total wallclock of the generate call, seconds.
    pub latency_s: f64,
    /// Final sequence position (prompt + generated).
    pub final_len: usize,
    /// Shared snapshot of the KV right after prompt prefill (for building
    /// a cache record): present only when `capture_prompt_kv`. A block-
    /// table clone — decode writes after the snapshot COW away from it.
    pub prompt_kv: Option<KvView>,
    /// The KV view after generation finished — valid for `final_len`
    /// positions; used by session continuation to cache prompt+response.
    pub final_kv: KvView,
}

/// Generation engine over any [`ForwardModel`], owning the paged KV arena
/// every request (and the recycler's cache records) allocates from.
pub struct Engine<M: ForwardModel> {
    model: M,
    arena: KvArena,
    counters: Counters,
}

impl<M: ForwardModel> Engine<M> {
    /// Engine with a default-sized arena for the model's geometry.
    pub fn new(model: M) -> Self {
        let arena = KvArena::with_defaults(model.config());
        Self::with_arena(model, arena)
    }

    /// Engine over a caller-sized arena (benches, capacity tests).
    pub fn with_arena(model: M, arena: KvArena) -> Self {
        debug_assert!(arena.geometry().matches(model.config()));
        Engine {
            model,
            arena,
            counters: Counters::default(),
        }
    }

    pub fn config(&self) -> &ModelConfig {
        self.model.config()
    }

    pub fn model(&self) -> &M {
        &self.model
    }

    /// The shared paged-KV arena (the recycler's cache lives here too).
    pub fn arena(&self) -> &KvArena {
        &self.arena
    }

    pub fn counters(&self) -> Counters {
        self.counters
    }

    pub(super) fn counters_mut(&mut self) -> &mut Counters {
        &mut self.counters
    }

    /// A fresh empty KV view (no blocks held until prefill writes).
    pub fn empty_kv(&self) -> KvView {
        self.arena.new_view()
    }

    /// Prefill `ids[start..]` into `kv` (positions start..ids.len()).
    /// `kv` must already be valid for `start` positions (the injected
    /// prefix). Returns (last_logits_row, prefill_calls).
    pub fn prefill(
        &mut self,
        ids: &[u32],
        kv: &mut KvView,
        start: usize,
    ) -> Result<(Vec<f32>, usize)> {
        let cfg = self.model.config().clone();
        if ids.len() > cfg.max_seq {
            return Err(Error::PromptTooLong {
                got: ids.len(),
                max: cfg.max_seq,
            });
        }
        if start >= ids.len() {
            return Err(Error::Rejected(
                "prefill needs at least one new token (start >= len)".into(),
            ));
        }
        if start > kv.len() {
            return Err(Error::ShapeMismatch(format!(
                "prefill start {start} beyond injected KV length {}",
                kv.len()
            )));
        }
        let mut pos = start;
        let mut calls = 0usize;
        let mut last = Vec::new();
        while pos < ids.len() {
            let pending = ids.len() - pos;
            let room = cfg.max_seq - pos;
            // Bucket selection (incl. the near-window unpadded fallback)
            // lives in `engine::chunk_step`, shared with the suspendable
            // `step_prefill` path so the two pick chunks identically.
            let (c, take) = super::chunk_step(&cfg, pending, room);
            let mut chunk: Vec<u32> = ids[pos..pos + take].to_vec();
            chunk.resize(c, 0);
            let logits = self.model.forward_chunk(&chunk, take, kv, pos)?;
            calls += 1;
            let v = cfg.vocab_size;
            last = logits[(take - 1) * v..take * v].to_vec();
            pos += take;
            self.counters.tokens_prefilled += take as u64;
        }
        Ok((last, calls))
    }

    /// Greedy-generate continuation.
    ///
    /// * `prompt_ids` — full prompt token ids.
    /// * `kv` / `cur_len` — injected cache state: `kv` must hold valid KV
    ///   for the first `cur_len` tokens of `prompt_ids` (the recycled
    ///   prefix, typically an attached cache record). Pass
    ///   [`Engine::empty_kv`] and 0 for a baseline run.
    /// * `capture_prompt_kv` — snapshot the KV view right after prompt
    ///   prefill (an O(blocks) clone) so the caller can build a cache
    ///   record.
    pub fn generate(
        &mut self,
        prompt_ids: &[u32],
        kv: KvView,
        cur_len: usize,
        max_new_tokens: usize,
        capture_prompt_kv: bool,
    ) -> Result<Generated> {
        // One-stream continuous decode: `generate` IS the batch API at
        // occupancy 1, so batched serving is token-identical by
        // construction (see engine::batch).
        let mut stream =
            self.start_stream(prompt_ids, kv, cur_len, max_new_tokens, capture_prompt_kv)?;
        while !stream.is_finished() {
            self.step_streams(&mut [&mut stream])?;
        }
        Ok(stream.into_generated())
    }
}

/// Index of the max element (ties -> lowest index, matching jnp.argmax).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::MockModel;

    fn engine() -> Engine<MockModel> {
        Engine::new(MockModel::new(crate::config::ModelConfig::nano()))
    }

    #[test]
    fn argmax_ties_take_first() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn generate_deterministic() {
        let mut e = engine();
        let ids: Vec<u32> = (1..20).collect();
        let kv = e.empty_kv();
        let a = e.generate(&ids, kv, 0, 8, false).unwrap();
        let b = e.generate(&ids, e.empty_kv(), 0, 8, false).unwrap();
        assert_eq!(a.ids, b.ids);
        assert_eq!(a.ids.len(), 8);
        assert_eq!(a.prompt_tokens, 19);
    }

    #[test]
    fn recycled_equals_baseline() {
        // THE paper property at engine level, via the mock model.
        let mut e = engine();
        let prompt: Vec<u32> = (1..33).collect();
        let base = e.generate(&prompt, e.empty_kv(), 0, 8, false).unwrap();

        // build "cached" KV for the first 16 tokens
        let cache: Vec<u32> = prompt[..16].to_vec();
        let mut kv = e.empty_kv();
        e.prefill(&cache, &mut kv, 0).unwrap();

        let rec = e.generate(&prompt, kv, 16, 8, false).unwrap();
        assert_eq!(rec.ids, base.ids);
        assert_eq!(rec.reused_tokens, 16);
    }

    #[test]
    fn recycled_from_shared_view_leaves_donor_intact() {
        // inject a *clone* of a cached view (the recycler's attach path):
        // generation must neither corrupt the donor nor copy it eagerly.
        let mut e = engine();
        let prompt: Vec<u32> = (1..33).collect();
        let base = e.generate(&prompt, e.empty_kv(), 0, 8, false).unwrap();

        let mut cached = e.empty_kv();
        e.prefill(&prompt[..16], &mut cached, 0).unwrap();
        let donor_before = cached.to_contiguous();
        let donor_blocks = cached.block_ids();

        let used = e.arena().used_blocks();
        let attached = cached.clone(); // zero-copy injection
        assert_eq!(e.arena().used_blocks(), used);

        let rec = e.generate(&prompt, attached, 16, 8, false).unwrap();
        assert_eq!(rec.ids, base.ids);
        assert_eq!(cached.to_contiguous(), donor_before, "donor KV intact");
        assert_eq!(cached.block_ids(), donor_blocks);
    }

    #[test]
    fn full_coverage_cache_reruns_last_token() {
        let mut e = engine();
        let prompt: Vec<u32> = (1..10).collect();
        let mut kv = e.empty_kv();
        e.prefill(&prompt, &mut kv, 0).unwrap();
        // cur_len == prompt len: engine must still produce output
        let base = e.generate(&prompt, e.empty_kv(), 0, 4, false).unwrap();
        let rec = e.generate(&prompt, kv, prompt.len(), 4, false).unwrap();
        assert_eq!(rec.ids, base.ids);
        assert_eq!(rec.reused_tokens, prompt.len() - 1);
    }

    #[test]
    fn rejects_too_long_prompt() {
        let mut e = engine();
        let prompt: Vec<u32> = vec![1; 500];
        match e.generate(&prompt, e.empty_kv(), 0, 4, false) {
            Err(Error::PromptTooLong { got: 500, .. }) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rejects_empty_prompt() {
        let mut e = engine();
        assert!(e.generate(&[], e.empty_kv(), 0, 4, false).is_err());
    }

    #[test]
    fn rejects_cur_len_beyond_injected_view() {
        let mut e = engine();
        let prompt: Vec<u32> = (1..20).collect();
        // empty view but cur_len 5: the "cached prefix" doesn't exist
        match e.generate(&prompt, e.empty_kv(), 5, 4, false) {
            Err(Error::ShapeMismatch(_)) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn near_window_prefill_falls_back_to_unpadded_chunk() {
        // Regression: with `pending <= room < smallest bucket` (deep
        // recycled prefix + prompt near max_seq) the padded-chunk fallback
        // used to error with ContextExhausted on a *legal* prompt.
        let mut cfg = crate::config::ModelConfig::nano();
        cfg.chunk_sizes = vec![8, 32, 64]; // no 1-bucket: min bucket is 8
        let prompt: Vec<u32> =
            (0..cfg.max_seq as u32).map(|i| 1 + i % 400).collect();

        let mut base = Engine::new(MockModel::new(cfg.clone()));
        let mut base_kv = base.empty_kv();
        let (want, _) = base.prefill(&prompt, &mut base_kv, 0).unwrap();

        let mut e = Engine::new(MockModel::new(cfg.clone()));
        let mut kv = e.empty_kv();
        // odd recycled depth: 5 pending tokens, room 5 < bucket 8
        e.prefill(&prompt[..251], &mut kv, 0).unwrap();
        let (got, calls) = e.prefill(&prompt, &mut kv, 251).unwrap();
        assert_eq!(got, want, "unpadded final chunk must be token-exact");
        assert_eq!(calls, 1, "one unpadded chunk, not a failure");
        assert_eq!(kv.len(), cfg.max_seq);
    }

    #[test]
    fn max_seq_prompt_with_odd_recycled_prefix_generates() {
        // Acceptance: a prompt of exactly max_seq tokens with a recycled
        // prefix of arbitrary (odd) depth generates successfully.
        let mut cfg = crate::config::ModelConfig::nano();
        cfg.chunk_sizes = vec![8, 32, 64];
        let prompt: Vec<u32> =
            (0..cfg.max_seq as u32).map(|i| 1 + i % 400).collect();
        for &depth in &[199usize, 251, 255] {
            let mut e = Engine::new(MockModel::new(cfg.clone()));
            let mut kv = e.empty_kv();
            e.prefill(&prompt[..depth], &mut kv, 0).unwrap();
            let g = e.generate(&prompt, kv, depth, 4, false)
                .unwrap_or_else(|err| panic!("depth {depth}: {err}"));
            assert_eq!(g.reused_tokens, depth);
            assert_eq!(g.final_len, cfg.max_seq, "window already full");
            assert!(g.ids.is_empty(), "no room left to generate");
        }
    }

    #[test]
    fn stops_at_context_window() {
        let mut e = engine();
        let max = e.config().max_seq;
        let prompt: Vec<u32> = vec![2; max - 2];
        let g = e.generate(&prompt, e.empty_kv(), 0, 50, false).unwrap();
        assert!(g.final_len <= max);
    }

    #[test]
    fn capture_prompt_kv() {
        let mut e = engine();
        let prompt: Vec<u32> = (1..9).collect();
        let g = e.generate(&prompt, e.empty_kv(), 0, 2, true).unwrap();
        let kv = g.prompt_kv.unwrap();
        assert_eq!(kv.len(), prompt.len());
        // mock writes token markers into kv plane 0; prompt rows populated,
        // and the decode steps after the snapshot must NOT appear in it
        for (i, &t) in prompt.iter().enumerate() {
            assert_eq!(kv.row(0, 0, 0, i)[0], (t + 1) as f32, "row {i}");
        }
        assert_eq!(g.final_kv.len(), g.final_len);
        assert!(g.final_kv.len() > kv.len(), "decode extended the final view");
    }

    #[test]
    fn counters_accumulate() {
        let mut e = engine();
        let prompt: Vec<u32> = (1..17).collect();
        e.generate(&prompt, e.empty_kv(), 0, 4, false).unwrap();
        let c = e.counters();
        assert_eq!(c.requests, 1);
        assert_eq!(c.tokens_prefilled, 16);
        assert_eq!(c.tokens_generated, 4);
    }
}
