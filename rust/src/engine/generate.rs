//! The greedy generation loop (the paper's `model.generate(...,
//! do_sample=False)` equivalent, with explicit KV injection).
//!
//! KV lives in a paged [`KvView`] over the engine's [`KvArena`]: a
//! recycled prefix arrives as a shared block table (zero-copy), the prefill
//! appends rows copy-on-write, and `capture_prompt_kv` snapshots are
//! O(blocks) clones instead of full-buffer copies.

use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::kvcache::{KvArena, KvView};
use crate::metrics::Counters;
use crate::util::timing::Stopwatch;

use super::{pick_chunk, ForwardModel};

/// Result of one generation call.
#[derive(Debug, Clone)]
pub struct Generated {
    /// Newly generated token ids (prompt not included).
    pub ids: Vec<u32>,
    /// Prompt length in tokens (m).
    pub prompt_tokens: usize,
    /// Tokens skipped via KV injection (k — the reuse depth).
    pub reused_tokens: usize,
    /// Forward calls spent on prefill.
    pub prefill_calls: usize,
    /// Total wallclock of the generate call, seconds.
    pub latency_s: f64,
    /// Final sequence position (prompt + generated).
    pub final_len: usize,
    /// Shared snapshot of the KV right after prompt prefill (for building
    /// a cache record): present only when `capture_prompt_kv`. A block-
    /// table clone — decode writes after the snapshot COW away from it.
    pub prompt_kv: Option<KvView>,
    /// The KV view after generation finished — valid for `final_len`
    /// positions; used by session continuation to cache prompt+response.
    pub final_kv: KvView,
}

/// Generation engine over any [`ForwardModel`], owning the paged KV arena
/// every request (and the recycler's cache records) allocates from.
pub struct Engine<M: ForwardModel> {
    model: M,
    arena: KvArena,
    counters: Counters,
}

impl<M: ForwardModel> Engine<M> {
    /// Engine with a default-sized arena for the model's geometry.
    pub fn new(model: M) -> Self {
        let arena = KvArena::with_defaults(model.config());
        Self::with_arena(model, arena)
    }

    /// Engine over a caller-sized arena (benches, capacity tests).
    pub fn with_arena(model: M, arena: KvArena) -> Self {
        debug_assert!(arena.geometry().matches(model.config()));
        Engine {
            model,
            arena,
            counters: Counters::default(),
        }
    }

    pub fn config(&self) -> &ModelConfig {
        self.model.config()
    }

    pub fn model(&self) -> &M {
        &self.model
    }

    /// The shared paged-KV arena (the recycler's cache lives here too).
    pub fn arena(&self) -> &KvArena {
        &self.arena
    }

    pub fn counters(&self) -> Counters {
        self.counters
    }

    /// A fresh empty KV view (no blocks held until prefill writes).
    pub fn empty_kv(&self) -> KvView {
        self.arena.new_view()
    }

    /// Prefill `ids[start..]` into `kv` (positions start..ids.len()).
    /// `kv` must already be valid for `start` positions (the injected
    /// prefix). Returns (last_logits_row, prefill_calls).
    pub fn prefill(
        &mut self,
        ids: &[u32],
        kv: &mut KvView,
        start: usize,
    ) -> Result<(Vec<f32>, usize)> {
        let cfg = self.model.config().clone();
        if ids.len() > cfg.max_seq {
            return Err(Error::PromptTooLong {
                got: ids.len(),
                max: cfg.max_seq,
            });
        }
        if start >= ids.len() {
            return Err(Error::Rejected(
                "prefill needs at least one new token (start >= len)".into(),
            ));
        }
        if start > kv.len() {
            return Err(Error::ShapeMismatch(format!(
                "prefill start {start} beyond injected KV length {}",
                kv.len()
            )));
        }
        let mut pos = start;
        let mut calls = 0usize;
        let mut last = Vec::new();
        while pos < ids.len() {
            let pending = ids.len() - pos;
            let room = cfg.max_seq - pos;
            let mut c = pick_chunk(&cfg.chunk_sizes, pending);
            if c > room {
                // padded bucket would spill past the context window; fall
                // back to the largest bucket that still fits.
                c = *cfg
                    .chunk_sizes
                    .iter()
                    .filter(|&&b| b <= room)
                    .next_back()
                    .ok_or(Error::ContextExhausted(pos))?;
            }
            let take = pending.min(c);
            let mut chunk: Vec<u32> = ids[pos..pos + take].to_vec();
            chunk.resize(c, 0);
            let logits = self.model.forward_chunk(&chunk, take, kv, pos)?;
            calls += 1;
            let v = cfg.vocab_size;
            last = logits[(take - 1) * v..take * v].to_vec();
            pos += take;
            self.counters.tokens_prefilled += take as u64;
        }
        Ok((last, calls))
    }

    /// Greedy-generate continuation.
    ///
    /// * `prompt_ids` — full prompt token ids.
    /// * `kv` / `cur_len` — injected cache state: `kv` must hold valid KV
    ///   for the first `cur_len` tokens of `prompt_ids` (the recycled
    ///   prefix, typically an attached cache record). Pass
    ///   [`Engine::empty_kv`] and 0 for a baseline run.
    /// * `capture_prompt_kv` — snapshot the KV view right after prompt
    ///   prefill (an O(blocks) clone) so the caller can build a cache
    ///   record.
    pub fn generate(
        &mut self,
        prompt_ids: &[u32],
        mut kv: KvView,
        cur_len: usize,
        max_new_tokens: usize,
        capture_prompt_kv: bool,
    ) -> Result<Generated> {
        let sw = Stopwatch::start();
        let cfg = self.model.config().clone();
        if prompt_ids.is_empty() {
            return Err(Error::Rejected("empty prompt".into()));
        }
        if cur_len > kv.len() {
            return Err(Error::ShapeMismatch(format!(
                "cur_len {cur_len} beyond injected KV length {}",
                kv.len()
            )));
        }
        if cur_len >= prompt_ids.len() && cur_len > 0 {
            // Cached prompt covers the whole input: re-run the last token so
            // we have logits to continue from (paper feeds >= 1 new token).
            return self.generate(prompt_ids, kv, prompt_ids.len() - 1,
                                 max_new_tokens, capture_prompt_kv);
        }
        self.counters.requests += 1;
        self.counters.tokens_reused += cur_len as u64;

        let (mut logits, prefill_calls) = self.prefill(prompt_ids, &mut kv, cur_len)?;
        // O(blocks) snapshot: decode writes below COW away from it.
        let prompt_kv = capture_prompt_kv.then(|| kv.clone());

        let mut pos = prompt_ids.len();
        let mut out = Vec::with_capacity(max_new_tokens);
        for _ in 0..max_new_tokens {
            let next = argmax(&logits) as u32;
            if next == cfg.eot_id || pos >= cfg.max_seq {
                break;
            }
            out.push(next);
            logits = self.model.forward_chunk(&[next], 1, &mut kv, pos)?;
            pos += 1;
            self.counters.tokens_generated += 1;
        }
        Ok(Generated {
            ids: out,
            prompt_tokens: prompt_ids.len(),
            reused_tokens: cur_len,
            prefill_calls,
            latency_s: sw.elapsed_secs(),
            final_len: pos,
            prompt_kv,
            final_kv: kv,
        })
    }
}

/// Index of the max element (ties -> lowest index, matching jnp.argmax).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::MockModel;

    fn engine() -> Engine<MockModel> {
        Engine::new(MockModel::new(crate::config::ModelConfig::nano()))
    }

    #[test]
    fn argmax_ties_take_first() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    #[test]
    fn generate_deterministic() {
        let mut e = engine();
        let ids: Vec<u32> = (1..20).collect();
        let kv = e.empty_kv();
        let a = e.generate(&ids, kv, 0, 8, false).unwrap();
        let b = e.generate(&ids, e.empty_kv(), 0, 8, false).unwrap();
        assert_eq!(a.ids, b.ids);
        assert_eq!(a.ids.len(), 8);
        assert_eq!(a.prompt_tokens, 19);
    }

    #[test]
    fn recycled_equals_baseline() {
        // THE paper property at engine level, via the mock model.
        let mut e = engine();
        let prompt: Vec<u32> = (1..33).collect();
        let base = e.generate(&prompt, e.empty_kv(), 0, 8, false).unwrap();

        // build "cached" KV for the first 16 tokens
        let cache: Vec<u32> = prompt[..16].to_vec();
        let mut kv = e.empty_kv();
        e.prefill(&cache, &mut kv, 0).unwrap();

        let rec = e.generate(&prompt, kv, 16, 8, false).unwrap();
        assert_eq!(rec.ids, base.ids);
        assert_eq!(rec.reused_tokens, 16);
    }

    #[test]
    fn recycled_from_shared_view_leaves_donor_intact() {
        // inject a *clone* of a cached view (the recycler's attach path):
        // generation must neither corrupt the donor nor copy it eagerly.
        let mut e = engine();
        let prompt: Vec<u32> = (1..33).collect();
        let base = e.generate(&prompt, e.empty_kv(), 0, 8, false).unwrap();

        let mut cached = e.empty_kv();
        e.prefill(&prompt[..16], &mut cached, 0).unwrap();
        let donor_before = cached.to_contiguous();
        let donor_blocks = cached.block_ids();

        let used = e.arena().used_blocks();
        let attached = cached.clone(); // zero-copy injection
        assert_eq!(e.arena().used_blocks(), used);

        let rec = e.generate(&prompt, attached, 16, 8, false).unwrap();
        assert_eq!(rec.ids, base.ids);
        assert_eq!(cached.to_contiguous(), donor_before, "donor KV intact");
        assert_eq!(cached.block_ids(), donor_blocks);
    }

    #[test]
    fn full_coverage_cache_reruns_last_token() {
        let mut e = engine();
        let prompt: Vec<u32> = (1..10).collect();
        let mut kv = e.empty_kv();
        e.prefill(&prompt, &mut kv, 0).unwrap();
        // cur_len == prompt len: engine must still produce output
        let base = e.generate(&prompt, e.empty_kv(), 0, 4, false).unwrap();
        let rec = e.generate(&prompt, kv, prompt.len(), 4, false).unwrap();
        assert_eq!(rec.ids, base.ids);
        assert_eq!(rec.reused_tokens, prompt.len() - 1);
    }

    #[test]
    fn rejects_too_long_prompt() {
        let mut e = engine();
        let prompt: Vec<u32> = vec![1; 500];
        match e.generate(&prompt, e.empty_kv(), 0, 4, false) {
            Err(Error::PromptTooLong { got: 500, .. }) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rejects_empty_prompt() {
        let mut e = engine();
        assert!(e.generate(&[], e.empty_kv(), 0, 4, false).is_err());
    }

    #[test]
    fn rejects_cur_len_beyond_injected_view() {
        let mut e = engine();
        let prompt: Vec<u32> = (1..20).collect();
        // empty view but cur_len 5: the "cached prefix" doesn't exist
        match e.generate(&prompt, e.empty_kv(), 5, 4, false) {
            Err(Error::ShapeMismatch(_)) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn stops_at_context_window() {
        let mut e = engine();
        let max = e.config().max_seq;
        let prompt: Vec<u32> = vec![2; max - 2];
        let g = e.generate(&prompt, e.empty_kv(), 0, 50, false).unwrap();
        assert!(g.final_len <= max);
    }

    #[test]
    fn capture_prompt_kv() {
        let mut e = engine();
        let prompt: Vec<u32> = (1..9).collect();
        let g = e.generate(&prompt, e.empty_kv(), 0, 2, true).unwrap();
        let kv = g.prompt_kv.unwrap();
        assert_eq!(kv.len(), prompt.len());
        // mock writes token markers into kv plane 0; prompt rows populated,
        // and the decode steps after the snapshot must NOT appear in it
        for (i, &t) in prompt.iter().enumerate() {
            assert_eq!(kv.row(0, 0, 0, i)[0], (t + 1) as f32, "row {i}");
        }
        assert_eq!(g.final_kv.len(), g.final_len);
        assert!(g.final_kv.len() > kv.len(), "decode extended the final view");
    }

    #[test]
    fn counters_accumulate() {
        let mut e = engine();
        let prompt: Vec<u32> = (1..17).collect();
        e.generate(&prompt, e.empty_kv(), 0, 4, false).unwrap();
        let c = e.counters();
        assert_eq!(c.requests, 1);
        assert_eq!(c.tokens_prefilled, 16);
        assert_eq!(c.tokens_generated, 4);
    }
}
