//! Step-wise prefill and batched decode — the continuous-batching
//! substrate.
//!
//! A [`PrefillStream`] is one *admitting* sequence: its prompt ids, its
//! paged KV view (possibly seeded with a recycled prefix), and how far
//! prefill has progressed. [`Engine::start_prefill`] opens the stream
//! without running any forward; [`Engine::step_prefill`] advances it by at
//! most a caller-supplied token budget (one or more bucket-sized chunks),
//! so a scheduler can interleave a long cache-cold prefill with decode
//! ticks instead of stalling every in-flight stream behind it;
//! [`Engine::finish_prefill`] converts a completed prefill into a
//! [`DecodeStream`].
//!
//! A [`DecodeStream`] is one in-flight sequence: its paged KV view, the
//! logits of the last processed row, and the greedy-decode bookkeeping.
//! [`Engine::start_stream`] runs the whole prefill to completion (a
//! one-call `start_prefill` → `step_prefill` → `finish_prefill` loop) and
//! returns a stream positioned at the first decode step;
//! [`Engine::step_streams`] advances *many* streams one token in a single
//! [`ForwardModel::forward_batch`] call, which is where a batching-capable
//! backend amortizes per-dispatch overhead across lanes.
//!
//! # Exactness
//!
//! The step loop is the same greedy loop [`Engine::generate`] runs — in
//! fact `generate` is implemented as a one-stream `step_streams` loop — so
//! a request decoded in a batch of any occupancy emits exactly the tokens
//! it would emit alone (the paper's token-exactness property, extended to
//! concurrent serving; property-tested in `rust/tests/properties.rs`).
//! Budget-limited prefill picks buckets through the same
//! [`chunk_step`](super::chunk_step) rule as the inline path, and every
//! [`ForwardModel`] guarantees chunk-split invariance, so a prompt
//! prefilled across any number of ticks yields the same KV and logits as
//! one inline pass (also property-tested).
//!
//! # Failure atomicity
//!
//! A failed step leaves every stream's *logical* state (emitted tokens,
//! position, held logits) untouched: next-token choices are computed
//! before the forward but only committed after it succeeds. KV rows a
//! partially-executed batch may have written are rewritten identically on
//! retry (the forward at a fixed `(token, position)` is deterministic), so
//! a scheduler can re-step streams individually to isolate a faulty one.
//! The same holds chunk-wise for prefill: a failed `step_prefill` keeps
//! the stream at its last committed chunk boundary — resuming re-runs only
//! the failed chunk, and [`PrefillStream::prefill_calls`] counts each
//! chunk exactly once across suspend/resume/retry (no double count after
//! a shed-and-retry).

use crate::error::{Error, Result};
use crate::kvcache::KvView;
use crate::util::timing::Stopwatch;

use super::generate::{argmax, Engine, Generated};
use super::{BatchItem, ForwardModel};

/// One admitting sequence whose prompt prefill is in progress — the
/// suspendable half of the lookup → chunked-prefill → decode → finish
/// state machine. Holds its KV blocks (recycled prefix + chunks written so
/// far) across ticks; dropping the stream releases them.
pub struct PrefillStream {
    ids: Vec<u32>,
    kv: KvView,
    /// Next prompt position to prefill (starts at the clamped reuse depth).
    pos: usize,
    /// Injected recycled depth (clamped to `len - 1`), for reporting.
    reused: usize,
    max_new: usize,
    capture: bool,
    /// Successful forward chunks so far. Monotone across suspend/resume;
    /// a failed chunk adds nothing, so retries never double-count.
    calls: usize,
    /// Logits of the last processed row (the decode seed once done).
    last: Vec<f32>,
    sw: Stopwatch,
}

impl PrefillStream {
    /// Has the whole prompt been prefilled?
    pub fn is_done(&self) -> bool {
        self.pos == self.ids.len()
    }

    /// Prompt positions already valid in the KV view.
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Full prompt length in tokens.
    pub fn prompt_len(&self) -> usize {
        self.ids.len()
    }

    /// Prompt tokens still to prefill.
    pub fn remaining(&self) -> usize {
        self.ids.len() - self.pos
    }

    /// The stream's decode budget (for arena growth reservations).
    pub fn max_new(&self) -> usize {
        self.max_new
    }

    /// Successful forward chunks so far.
    pub fn prefill_calls(&self) -> usize {
        self.calls
    }

    /// Recycled prefix depth this stream was seeded with.
    pub fn reused_tokens(&self) -> usize {
        self.reused
    }

    /// The stream's KV view (diagnostics: reservation accounting).
    pub fn kv(&self) -> &KvView {
        &self.kv
    }
}

/// What one [`Engine::step_prefill`] call did.
#[derive(Debug, Clone, Copy)]
pub struct PrefillProgress {
    /// Prompt tokens prefilled by this call (real tokens, padding not
    /// counted). At most the call's budget.
    pub tokens: usize,
    /// Whether the whole prompt is now prefilled (convert via
    /// [`Engine::finish_prefill`]).
    pub done: bool,
}

/// One in-flight sequence in a continuous decode batch.
pub struct DecodeStream {
    kv: KvView,
    /// Logits of the last processed row (the next-token distribution).
    logits: Vec<f32>,
    /// Current sequence position (prompt + generated so far).
    pos: usize,
    /// Token picked in the current step's phase 1, fed in phase 2.
    fed: u32,
    /// Scheduled for this step's batched forward.
    armed: bool,
    out: Vec<u32>,
    max_new: usize,
    prompt_tokens: usize,
    reused_tokens: usize,
    prefill_calls: usize,
    prompt_kv: Option<KvView>,
    finished: bool,
    sw: Stopwatch,
}

impl DecodeStream {
    /// Has the stream hit a stop condition (EOT, window, or budget)?
    pub fn is_finished(&self) -> bool {
        self.finished
    }

    /// Tokens generated so far (prompt not included).
    pub fn generated(&self) -> &[u32] {
        &self.out
    }

    /// Current sequence position (prompt + generated).
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Tokens this stream may still emit (its budget; the context window
    /// can clamp it further — callers compare against `max_seq`).
    pub fn remaining_budget(&self) -> usize {
        if self.finished {
            0
        } else {
            self.max_new.saturating_sub(self.out.len())
        }
    }

    /// The stream's KV view (diagnostics: block sharing, conservation).
    pub fn kv(&self) -> &KvView {
        &self.kv
    }

    /// Finalize into the same [`Generated`] a `generate` call returns.
    pub fn into_generated(self) -> Generated {
        Generated {
            ids: self.out,
            prompt_tokens: self.prompt_tokens,
            reused_tokens: self.reused_tokens,
            prefill_calls: self.prefill_calls,
            latency_s: self.sw.elapsed_secs(),
            final_len: self.pos,
            prompt_kv: self.prompt_kv,
            final_kv: self.kv,
        }
    }
}

impl<M: ForwardModel> Engine<M> {
    /// Open a suspendable prefill stream — no forward runs yet.
    ///
    /// Arguments mirror [`Engine::generate`]: `kv`/`cur_len` is the
    /// injected recycled prefix (or [`Engine::empty_kv`] and 0), and
    /// `capture_prompt_kv` snapshots the post-prefill view for cache
    /// admission when the stream later converts to decode. Validation
    /// (empty prompt, window overflow, reuse depth beyond the view)
    /// happens here so a scheduler can fail a request at admission
    /// instead of mid-prefill.
    pub fn start_prefill(
        &mut self,
        prompt_ids: &[u32],
        kv: KvView,
        cur_len: usize,
        max_new_tokens: usize,
        capture_prompt_kv: bool,
    ) -> Result<PrefillStream> {
        let sw = Stopwatch::start();
        if prompt_ids.is_empty() {
            return Err(Error::Rejected("empty prompt".into()));
        }
        if prompt_ids.len() > self.config().max_seq {
            return Err(Error::PromptTooLong {
                got: prompt_ids.len(),
                max: self.config().max_seq,
            });
        }
        if cur_len > kv.len() {
            return Err(Error::ShapeMismatch(format!(
                "cur_len {cur_len} beyond injected KV length {}",
                kv.len()
            )));
        }
        // Cached prompt covers the whole input: re-run the last token so we
        // have logits to continue from (paper feeds >= 1 new token).
        let cur_len = cur_len.min(prompt_ids.len() - 1);
        Ok(PrefillStream {
            ids: prompt_ids.to_vec(),
            kv,
            pos: cur_len,
            reused: cur_len,
            max_new: max_new_tokens,
            capture: capture_prompt_kv,
            calls: 0,
            last: Vec::new(),
            sw,
        })
    }

    /// Advance a prefill stream by at most `budget` prompt tokens (one or
    /// more bucket-sized chunks via the same [`chunk_step`](super::chunk_step)
    /// rule as the inline path; at least one chunk always runs, so
    /// `budget < smallest bucket` still makes progress). A failed chunk
    /// leaves the stream at its last committed boundary — calling again
    /// re-runs exactly the failed chunk (KV writes at fixed positions are
    /// idempotent), so the caller may shed arena pressure and resume.
    pub fn step_prefill(
        &mut self,
        p: &mut PrefillStream,
        budget: usize,
    ) -> Result<PrefillProgress> {
        let cfg = self.config().clone();
        let budget = budget.max(1);
        let mut processed = 0usize;
        while p.pos < p.ids.len() && processed < budget {
            let pending = (p.ids.len() - p.pos).min(budget - processed);
            let room = cfg.max_seq - p.pos;
            let (c, take) = super::chunk_step(&cfg, pending, room);
            let mut chunk: Vec<u32> = p.ids[p.pos..p.pos + take].to_vec();
            chunk.resize(c, 0);
            let logits = self.model().forward_chunk(&chunk, take, &mut p.kv, p.pos)?;
            p.calls += 1;
            let v = cfg.vocab_size;
            p.last = logits[(take - 1) * v..take * v].to_vec();
            p.pos += take;
            processed += take;
            self.counters_mut().tokens_prefilled += take as u64;
        }
        Ok(PrefillProgress {
            tokens: processed,
            done: p.pos == p.ids.len(),
        })
    }

    /// Convert a completed prefill into a decode stream positioned at its
    /// first step (the stream holds the last prefill row's logits, so the
    /// first `step_streams` call emits the first new token). Errors if the
    /// prefill is not done. Engine counters (requests, reused tokens) are
    /// bumped here — only once per request, however many ticks and retries
    /// the prefill spanned.
    pub fn finish_prefill(&mut self, p: PrefillStream) -> Result<DecodeStream> {
        if p.pos < p.ids.len() {
            return Err(Error::Rejected(format!(
                "prefill incomplete: {} of {} prompt tokens",
                p.pos,
                p.ids.len()
            )));
        }
        self.counters_mut().requests += 1;
        self.counters_mut().tokens_reused += p.reused as u64;
        // O(blocks) snapshot: decode writes COW away from it.
        let prompt_kv = p.capture.then(|| p.kv.clone());
        Ok(DecodeStream {
            pos: p.ids.len(),
            prompt_tokens: p.ids.len(),
            kv: p.kv,
            logits: p.last,
            fed: 0,
            armed: false,
            out: Vec::with_capacity(p.max_new),
            max_new: p.max_new,
            reused_tokens: p.reused,
            prefill_calls: p.calls,
            prompt_kv,
            finished: p.max_new == 0,
            sw: p.sw,
        })
    }

    /// Prefill a prompt to completion and open a decode stream at its
    /// first step — the one-shot composition of [`Engine::start_prefill`],
    /// [`Engine::step_prefill`] (unbounded budget), and
    /// [`Engine::finish_prefill`]; the chunked path is token-identical to
    /// this by the chunk-split-invariance contract.
    pub fn start_stream(
        &mut self,
        prompt_ids: &[u32],
        kv: KvView,
        cur_len: usize,
        max_new_tokens: usize,
        capture_prompt_kv: bool,
    ) -> Result<DecodeStream> {
        let mut p =
            self.start_prefill(prompt_ids, kv, cur_len, max_new_tokens, capture_prompt_kv)?;
        while !p.is_done() {
            self.step_prefill(&mut p, usize::MAX)?;
        }
        self.finish_prefill(p)
    }

    /// Advance every active stream one greedy token via a single batched
    /// forward. Streams that hit a stop condition (token budget, EOT,
    /// context window) are marked finished and skipped. The report says
    /// how many streams actually fed the forward (`scheduled` — the true
    /// dispatch occupancy) and how many remain active.
    pub fn step_streams(&mut self, streams: &mut [&mut DecodeStream]) -> Result<StepReport> {
        let eot = self.config().eot_id;
        let max_seq = self.config().max_seq;
        // Phase 1: pick each stream's next token; commit nothing yet.
        let mut scheduled = 0usize;
        for s in streams.iter_mut() {
            s.armed = false;
            if s.finished {
                continue;
            }
            if s.out.len() >= s.max_new {
                s.finished = true;
                continue;
            }
            let next = argmax(&s.logits) as u32;
            if next == eot || s.pos >= max_seq {
                s.finished = true;
                continue;
            }
            s.fed = next;
            s.armed = true;
            scheduled += 1;
        }
        if scheduled == 0 {
            // every non-finished stream was marked finished above
            return Ok(StepReport { scheduled: 0, active: 0 });
        }
        // Phase 2: one batched forward over every emitting stream.
        let mut items: Vec<BatchItem<'_>> = streams
            .iter_mut()
            .filter(|s| s.armed)
            .map(|s| {
                let DecodeStream { kv, fed, pos, .. } = &mut **s;
                BatchItem {
                    tokens: std::slice::from_ref(&*fed),
                    valid_len: 1,
                    kv,
                    cur_len: *pos,
                }
            })
            .collect();
        let logits = self.model().forward_batch(&mut items)?;
        drop(items);
        // Commit: the forward succeeded for the whole batch.
        let mut rows = logits.into_iter();
        let mut active = 0usize;
        for s in streams.iter_mut() {
            if s.armed {
                s.armed = false;
                s.out.push(s.fed);
                s.logits = rows.next().expect("one logits row per scheduled stream");
                s.pos += 1;
                // Apply the cheap stop conditions eagerly so a drained
                // stream doesn't cost an extra zero-forward tick (EOT
                // needs the next argmax, so it is still detected in the
                // following step's phase 1). Token-exact either way.
                if s.out.len() >= s.max_new || s.pos >= max_seq {
                    s.finished = true;
                }
            }
            if !s.finished {
                active += 1;
            }
        }
        self.counters_mut().tokens_generated += scheduled as u64;
        Ok(StepReport { scheduled, active })
    }
}

/// What one [`Engine::step_streams`] tick did.
#[derive(Debug, Clone, Copy)]
pub struct StepReport {
    /// Streams that fed the batched forward (the real dispatch occupancy;
    /// 0 means the tick only drained stop conditions, no forward ran).
    pub scheduled: usize,
    /// Streams still active after the step.
    pub active: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::testutil::MockModel;

    fn engine() -> Engine<MockModel> {
        Engine::new(MockModel::new(ModelConfig::nano()))
    }

    #[test]
    fn batched_streams_match_sequential_generate() {
        // Three prompts decoded concurrently must emit exactly what three
        // lone generate calls emit.
        let prompts: Vec<Vec<u32>> = vec![
            (1..20).collect(),
            (40..45).collect(),
            (7..40).rev().collect(),
        ];
        let mut seq = engine();
        let expected: Vec<Vec<u32>> = prompts
            .iter()
            .map(|p| seq.generate(p, seq.empty_kv(), 0, 6, false).unwrap().ids)
            .collect();

        let mut e = engine();
        let mut streams: Vec<DecodeStream> = prompts
            .iter()
            .map(|p| e.start_stream(p, e.empty_kv(), 0, 6, false).unwrap())
            .collect();
        loop {
            let mut refs: Vec<&mut DecodeStream> = streams.iter_mut().collect();
            let report = e.step_streams(&mut refs).unwrap();
            assert!(report.scheduled >= report.active, "every active fed");
            if report.active == 0 {
                break;
            }
        }
        for (s, want) in streams.into_iter().zip(&expected) {
            assert_eq!(s.generated(), &want[..]);
            assert_eq!(s.into_generated().ids, *want);
        }
    }

    #[test]
    fn uneven_lengths_finish_independently() {
        let mut e = engine();
        let mut a = e.start_stream(&[1, 2, 3], e.empty_kv(), 0, 2, false).unwrap();
        let mut b = e.start_stream(&[9, 8, 7], e.empty_kv(), 0, 7, false).unwrap();
        let mut steps = 0;
        loop {
            let report = e.step_streams(&mut [&mut a, &mut b]).unwrap();
            steps += 1;
            if report.active == 0 {
                break;
            }
        }
        assert!(a.is_finished() && b.is_finished());
        assert_eq!(a.generated().len(), 2);
        assert_eq!(b.generated().len(), 7);
        // the joint loop runs exactly as long as the longest stream
        assert_eq!(steps, 7);
    }

    #[test]
    fn zero_budget_stream_is_born_finished() {
        let mut e = engine();
        let s = e.start_stream(&[1, 2], e.empty_kv(), 0, 0, false).unwrap();
        assert!(s.is_finished());
        let g = s.into_generated();
        assert!(g.ids.is_empty());
        assert_eq!(g.final_len, 2);
    }

    #[test]
    fn failed_step_leaves_streams_consistent_for_retry() {
        // Inject a failure into the batched forward; the step errors, but a
        // retry must emit exactly the baseline tokens (no duplicated or
        // dropped positions).
        let prompt: Vec<u32> = (1..12).collect();
        let mut base = engine();
        let want = base.generate(&prompt, base.empty_kv(), 0, 4, false).unwrap().ids;

        // prefill = 1 call; fail the 3rd call = the 2nd decode step
        let mut e = Engine::new(MockModel::new(ModelConfig::nano()).fail_on_call(3));
        let mut s = e.start_stream(&prompt, e.empty_kv(), 0, 4, false).unwrap();
        let mut failures = 0;
        while !s.is_finished() {
            if e.step_streams(&mut [&mut s]).is_err() {
                failures += 1;
                assert!(failures < 10, "retry never converged");
            }
        }
        assert_eq!(s.generated(), &want[..]);
    }

    #[test]
    fn stream_with_recycled_prefix_matches_baseline() {
        let prompt: Vec<u32> = (1..33).collect();
        let mut base = engine();
        let want = base.generate(&prompt, base.empty_kv(), 0, 6, false).unwrap().ids;

        let mut e = engine();
        let mut kv = e.empty_kv();
        e.prefill(&prompt[..17], &mut kv, 0).unwrap();
        let mut s = e.start_stream(&prompt, kv, 17, 6, false).unwrap();
        while !s.is_finished() {
            e.step_streams(&mut [&mut s]).unwrap();
        }
        let g = s.into_generated();
        assert_eq!(g.ids, want);
        assert_eq!(g.reused_tokens, 17);
    }

    #[test]
    fn chunked_prefill_matches_inline_for_every_budget() {
        // A prompt prefilled under any per-step token budget must yield
        // exactly the tokens the inline (one-shot) path yields — the
        // chunk-split-invariance contract, exercised through the
        // suspendable API.
        let prompt: Vec<u32> = (1..97).collect();
        let mut base = engine();
        let want = base.generate(&prompt, base.empty_kv(), 0, 5, false).unwrap();

        for budget in [1usize, 3, 8, 13, 32, 200] {
            let mut e = engine();
            let mut p = e.start_prefill(&prompt, e.empty_kv(), 0, 5, false).unwrap();
            let mut ticks = 0usize;
            while !p.is_done() {
                let prog = e.step_prefill(&mut p, budget).unwrap();
                assert!(prog.tokens >= 1, "each step makes progress");
                assert!(
                    prog.tokens <= budget.max(*e.config().chunk_sizes.first().unwrap()),
                    "budget {budget}: step took {} tokens",
                    prog.tokens
                );
                ticks += 1;
                assert!(ticks < 1000, "prefill never converged");
            }
            let mut s = e.finish_prefill(p).unwrap();
            while !s.is_finished() {
                e.step_streams(&mut [&mut s]).unwrap();
            }
            let g = s.into_generated();
            assert_eq!(g.ids, want.ids, "budget {budget} diverged");
            assert_eq!(g.prompt_tokens, prompt.len());
        }
    }

    #[test]
    fn chunked_prefill_with_recycled_prefix_matches_baseline() {
        let prompt: Vec<u32> = (1..65).collect();
        let mut base = engine();
        let want = base.generate(&prompt, base.empty_kv(), 0, 4, false).unwrap().ids;

        let mut e = engine();
        let mut kv = e.empty_kv();
        e.prefill(&prompt[..21], &mut kv, 0).unwrap();
        let mut p = e.start_prefill(&prompt, kv, 21, 4, false).unwrap();
        assert_eq!(p.remaining(), prompt.len() - 21);
        while !p.is_done() {
            e.step_prefill(&mut p, 7).unwrap();
        }
        let mut s = e.finish_prefill(p).unwrap();
        while !s.is_finished() {
            e.step_streams(&mut [&mut s]).unwrap();
        }
        let g = s.into_generated();
        assert_eq!(g.ids, want);
        assert_eq!(g.reused_tokens, 21);
    }

    #[test]
    fn failed_prefill_chunk_resumes_without_double_counting_calls() {
        // Inject a failure into one mid-prefill chunk: resuming the SAME
        // stream must re-run only that chunk, and the final prefill_calls
        // must equal the inline path's count — a shed-and-retry that
        // resumes never double-counts chunks.
        let prompt: Vec<u32> = (1..80).collect();
        let mut base = engine();
        let inline = base.generate(&prompt, base.empty_kv(), 0, 3, false).unwrap();

        // Clean chunked reference at the same budget: its call count is
        // what a failure-free run costs (the chunk plan differs from the
        // inline path's, so inline's prefill_calls is NOT the reference).
        let mut clean = engine();
        let mut cp = clean.start_prefill(&prompt, clean.empty_kv(), 0, 3, false).unwrap();
        while !cp.is_done() {
            clean.step_prefill(&mut cp, 32).unwrap();
        }
        let ref_calls = cp.prefill_calls();

        // budget 32 over 79 tokens: chunks of 32, 32, 15(pad 32) -> fail
        // the 2nd forward call (the 2nd chunk)
        let mut e = Engine::new(MockModel::new(ModelConfig::nano()).fail_on_call(2));
        let mut p = e.start_prefill(&prompt, e.empty_kv(), 0, 3, false).unwrap();
        let mut failures = 0usize;
        while !p.is_done() {
            if e.step_prefill(&mut p, 32).is_err() {
                failures += 1;
                assert!(failures < 5, "retry never converged");
            }
        }
        assert_eq!(failures, 1, "exactly the injected chunk failed");
        assert_eq!(
            p.prefill_calls(),
            ref_calls,
            "resumed chunks must not be double-counted"
        );
        let mut s = e.finish_prefill(p).unwrap();
        while !s.is_finished() {
            e.step_streams(&mut [&mut s]).unwrap();
        }
        let g = s.into_generated();
        assert_eq!(g.ids, inline.ids);
        assert_eq!(g.prefill_calls, ref_calls);
    }

    #[test]
    fn finish_prefill_rejects_incomplete_stream() {
        let mut e = engine();
        let prompt: Vec<u32> = (1..50).collect();
        let mut p = e.start_prefill(&prompt, e.empty_kv(), 0, 2, false).unwrap();
        let prog = e.step_prefill(&mut p, 8).unwrap();
        assert!(!prog.done);
        assert!(e.finish_prefill(p).is_err());
    }

    #[test]
    fn chunked_prefill_near_window_uses_unpadded_fallback() {
        // Budget-limited stepping must hit the same near-window unpadded
        // final chunk as the inline path (regression for the chunk_step
        // refactor).
        let mut cfg = ModelConfig::nano();
        cfg.chunk_sizes = vec![8, 32, 64]; // no 1-bucket
        let prompt: Vec<u32> =
            (0..cfg.max_seq as u32).map(|i| 1 + i % 400).collect();

        let mut base = Engine::new(MockModel::new(cfg.clone()));
        let base_g = base.generate(&prompt, base.empty_kv(), 0, 0, false).unwrap();

        let mut e = Engine::new(MockModel::new(cfg.clone()));
        let mut p = e.start_prefill(&prompt, e.empty_kv(), 0, 0, false).unwrap();
        while !p.is_done() {
            e.step_prefill(&mut p, 23).unwrap();
        }
        let s = e.finish_prefill(p).unwrap();
        assert!(s.is_finished(), "zero budget: born finished at max_seq");
        let g = s.into_generated();
        assert_eq!(g.final_len, cfg.max_seq);
        assert_eq!(g.final_len, base_g.final_len);
    }

    #[test]
    fn start_prefill_validates_like_start_stream() {
        let mut e = engine();
        assert!(e.start_prefill(&[], e.empty_kv(), 0, 2, false).is_err());
        let long: Vec<u32> = vec![1; e.config().max_seq + 1];
        match e.start_prefill(&long, e.empty_kv(), 0, 2, false) {
            Err(Error::PromptTooLong { .. }) => {}
            other => panic!("{:?}", other.map(|_| ())),
        }
        match e.start_prefill(&[1, 2, 3], e.empty_kv(), 2, 2, false) {
            Err(Error::ShapeMismatch(_)) => {}
            other => panic!("{:?}", other.map(|_| ())),
        }
    }
}
