//! Step-wise batched decode — the continuous-batching substrate.
//!
//! A [`DecodeStream`] is one in-flight sequence: its paged KV view, the
//! logits of the last processed row, and the greedy-decode bookkeeping.
//! [`Engine::start_stream`] runs the (chunked) prefill and returns a
//! stream positioned at the first decode step; [`Engine::step_streams`]
//! advances *many* streams one token in a single
//! [`ForwardModel::forward_batch`] call, which is where a batching-capable
//! backend amortizes per-dispatch overhead across lanes.
//!
//! # Exactness
//!
//! The step loop is the same greedy loop [`Engine::generate`] runs — in
//! fact `generate` is implemented as a one-stream `step_streams` loop — so
//! a request decoded in a batch of any occupancy emits exactly the tokens
//! it would emit alone (the paper's token-exactness property, extended to
//! concurrent serving; property-tested in `rust/tests/properties.rs`).
//!
//! # Failure atomicity
//!
//! A failed step leaves every stream's *logical* state (emitted tokens,
//! position, held logits) untouched: next-token choices are computed
//! before the forward but only committed after it succeeds. KV rows a
//! partially-executed batch may have written are rewritten identically on
//! retry (the forward at a fixed `(token, position)` is deterministic), so
//! a scheduler can re-step streams individually to isolate a faulty one.

use crate::error::{Error, Result};
use crate::kvcache::KvView;
use crate::util::timing::Stopwatch;

use super::generate::{argmax, Engine, Generated};
use super::{BatchItem, ForwardModel};

/// One in-flight sequence in a continuous decode batch.
pub struct DecodeStream {
    kv: KvView,
    /// Logits of the last processed row (the next-token distribution).
    logits: Vec<f32>,
    /// Current sequence position (prompt + generated so far).
    pos: usize,
    /// Token picked in the current step's phase 1, fed in phase 2.
    fed: u32,
    /// Scheduled for this step's batched forward.
    armed: bool,
    out: Vec<u32>,
    max_new: usize,
    prompt_tokens: usize,
    reused_tokens: usize,
    prefill_calls: usize,
    prompt_kv: Option<KvView>,
    finished: bool,
    sw: Stopwatch,
}

impl DecodeStream {
    /// Has the stream hit a stop condition (EOT, window, or budget)?
    pub fn is_finished(&self) -> bool {
        self.finished
    }

    /// Tokens generated so far (prompt not included).
    pub fn generated(&self) -> &[u32] {
        &self.out
    }

    /// Current sequence position (prompt + generated).
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Tokens this stream may still emit (its budget; the context window
    /// can clamp it further — callers compare against `max_seq`).
    pub fn remaining_budget(&self) -> usize {
        if self.finished {
            0
        } else {
            self.max_new.saturating_sub(self.out.len())
        }
    }

    /// The stream's KV view (diagnostics: block sharing, conservation).
    pub fn kv(&self) -> &KvView {
        &self.kv
    }

    /// Finalize into the same [`Generated`] a `generate` call returns.
    pub fn into_generated(self) -> Generated {
        Generated {
            ids: self.out,
            prompt_tokens: self.prompt_tokens,
            reused_tokens: self.reused_tokens,
            prefill_calls: self.prefill_calls,
            latency_s: self.sw.elapsed_secs(),
            final_len: self.pos,
            prompt_kv: self.prompt_kv,
            final_kv: self.kv,
        }
    }
}

impl<M: ForwardModel> Engine<M> {
    /// Prefill a prompt and open a decode stream at its first step.
    ///
    /// Arguments mirror [`Engine::generate`]: `kv`/`cur_len` is the
    /// injected recycled prefix (or [`Engine::empty_kv`] and 0), and
    /// `capture_prompt_kv` snapshots the post-prefill view for cache
    /// admission. The stream holds the last prefill row's logits, so the
    /// first `step_streams` call emits the first new token.
    pub fn start_stream(
        &mut self,
        prompt_ids: &[u32],
        mut kv: KvView,
        cur_len: usize,
        max_new_tokens: usize,
        capture_prompt_kv: bool,
    ) -> Result<DecodeStream> {
        let sw = Stopwatch::start();
        if prompt_ids.is_empty() {
            return Err(Error::Rejected("empty prompt".into()));
        }
        if cur_len > kv.len() {
            return Err(Error::ShapeMismatch(format!(
                "cur_len {cur_len} beyond injected KV length {}",
                kv.len()
            )));
        }
        // Cached prompt covers the whole input: re-run the last token so we
        // have logits to continue from (paper feeds >= 1 new token).
        let cur_len = cur_len.min(prompt_ids.len() - 1);
        let (logits, prefill_calls) = self.prefill(prompt_ids, &mut kv, cur_len)?;
        // Counted only after a successful prefill: a failed attempt that
        // the caller retries (the ArenaExhausted backstop) must not count
        // the same request twice.
        self.counters_mut().requests += 1;
        self.counters_mut().tokens_reused += cur_len as u64;
        // O(blocks) snapshot: decode writes COW away from it.
        let prompt_kv = capture_prompt_kv.then(|| kv.clone());
        Ok(DecodeStream {
            kv,
            logits,
            pos: prompt_ids.len(),
            fed: 0,
            armed: false,
            out: Vec::with_capacity(max_new_tokens),
            max_new: max_new_tokens,
            prompt_tokens: prompt_ids.len(),
            reused_tokens: cur_len,
            prefill_calls,
            prompt_kv,
            finished: max_new_tokens == 0,
            sw,
        })
    }

    /// Advance every active stream one greedy token via a single batched
    /// forward. Streams that hit a stop condition (token budget, EOT,
    /// context window) are marked finished and skipped. The report says
    /// how many streams actually fed the forward (`scheduled` — the true
    /// dispatch occupancy) and how many remain active.
    pub fn step_streams(&mut self, streams: &mut [&mut DecodeStream]) -> Result<StepReport> {
        let eot = self.config().eot_id;
        let max_seq = self.config().max_seq;
        // Phase 1: pick each stream's next token; commit nothing yet.
        let mut scheduled = 0usize;
        for s in streams.iter_mut() {
            s.armed = false;
            if s.finished {
                continue;
            }
            if s.out.len() >= s.max_new {
                s.finished = true;
                continue;
            }
            let next = argmax(&s.logits) as u32;
            if next == eot || s.pos >= max_seq {
                s.finished = true;
                continue;
            }
            s.fed = next;
            s.armed = true;
            scheduled += 1;
        }
        if scheduled == 0 {
            // every non-finished stream was marked finished above
            return Ok(StepReport { scheduled: 0, active: 0 });
        }
        // Phase 2: one batched forward over every emitting stream.
        let mut items: Vec<BatchItem<'_>> = streams
            .iter_mut()
            .filter(|s| s.armed)
            .map(|s| {
                let DecodeStream { kv, fed, pos, .. } = &mut **s;
                BatchItem {
                    tokens: std::slice::from_ref(&*fed),
                    valid_len: 1,
                    kv,
                    cur_len: *pos,
                }
            })
            .collect();
        let logits = self.model().forward_batch(&mut items)?;
        drop(items);
        // Commit: the forward succeeded for the whole batch.
        let mut rows = logits.into_iter();
        let mut active = 0usize;
        for s in streams.iter_mut() {
            if s.armed {
                s.armed = false;
                s.out.push(s.fed);
                s.logits = rows.next().expect("one logits row per scheduled stream");
                s.pos += 1;
                // Apply the cheap stop conditions eagerly so a drained
                // stream doesn't cost an extra zero-forward tick (EOT
                // needs the next argmax, so it is still detected in the
                // following step's phase 1). Token-exact either way.
                if s.out.len() >= s.max_new || s.pos >= max_seq {
                    s.finished = true;
                }
            }
            if !s.finished {
                active += 1;
            }
        }
        self.counters_mut().tokens_generated += scheduled as u64;
        Ok(StepReport { scheduled, active })
    }
}

/// What one [`Engine::step_streams`] tick did.
#[derive(Debug, Clone, Copy)]
pub struct StepReport {
    /// Streams that fed the batched forward (the real dispatch occupancy;
    /// 0 means the tick only drained stop conditions, no forward ran).
    pub scheduled: usize,
    /// Streams still active after the step.
    pub active: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::testutil::MockModel;

    fn engine() -> Engine<MockModel> {
        Engine::new(MockModel::new(ModelConfig::nano()))
    }

    #[test]
    fn batched_streams_match_sequential_generate() {
        // Three prompts decoded concurrently must emit exactly what three
        // lone generate calls emit.
        let prompts: Vec<Vec<u32>> = vec![
            (1..20).collect(),
            (40..45).collect(),
            (7..40).rev().collect(),
        ];
        let mut seq = engine();
        let expected: Vec<Vec<u32>> = prompts
            .iter()
            .map(|p| seq.generate(p, seq.empty_kv(), 0, 6, false).unwrap().ids)
            .collect();

        let mut e = engine();
        let mut streams: Vec<DecodeStream> = prompts
            .iter()
            .map(|p| e.start_stream(p, e.empty_kv(), 0, 6, false).unwrap())
            .collect();
        loop {
            let mut refs: Vec<&mut DecodeStream> = streams.iter_mut().collect();
            let report = e.step_streams(&mut refs).unwrap();
            assert!(report.scheduled >= report.active, "every active fed");
            if report.active == 0 {
                break;
            }
        }
        for (s, want) in streams.into_iter().zip(&expected) {
            assert_eq!(s.generated(), &want[..]);
            assert_eq!(s.into_generated().ids, *want);
        }
    }

    #[test]
    fn uneven_lengths_finish_independently() {
        let mut e = engine();
        let mut a = e.start_stream(&[1, 2, 3], e.empty_kv(), 0, 2, false).unwrap();
        let mut b = e.start_stream(&[9, 8, 7], e.empty_kv(), 0, 7, false).unwrap();
        let mut steps = 0;
        loop {
            let report = e.step_streams(&mut [&mut a, &mut b]).unwrap();
            steps += 1;
            if report.active == 0 {
                break;
            }
        }
        assert!(a.is_finished() && b.is_finished());
        assert_eq!(a.generated().len(), 2);
        assert_eq!(b.generated().len(), 7);
        // the joint loop runs exactly as long as the longest stream
        assert_eq!(steps, 7);
    }

    #[test]
    fn zero_budget_stream_is_born_finished() {
        let mut e = engine();
        let s = e.start_stream(&[1, 2], e.empty_kv(), 0, 0, false).unwrap();
        assert!(s.is_finished());
        let g = s.into_generated();
        assert!(g.ids.is_empty());
        assert_eq!(g.final_len, 2);
    }

    #[test]
    fn failed_step_leaves_streams_consistent_for_retry() {
        // Inject a failure into the batched forward; the step errors, but a
        // retry must emit exactly the baseline tokens (no duplicated or
        // dropped positions).
        let prompt: Vec<u32> = (1..12).collect();
        let mut base = engine();
        let want = base.generate(&prompt, base.empty_kv(), 0, 4, false).unwrap().ids;

        // prefill = 1 call; fail the 3rd call = the 2nd decode step
        let mut e = Engine::new(MockModel::new(ModelConfig::nano()).fail_on_call(3));
        let mut s = e.start_stream(&prompt, e.empty_kv(), 0, 4, false).unwrap();
        let mut failures = 0;
        while !s.is_finished() {
            if e.step_streams(&mut [&mut s]).is_err() {
                failures += 1;
                assert!(failures < 10, "retry never converged");
            }
        }
        assert_eq!(s.generated(), &want[..]);
    }

    #[test]
    fn stream_with_recycled_prefix_matches_baseline() {
        let prompt: Vec<u32> = (1..33).collect();
        let mut base = engine();
        let want = base.generate(&prompt, base.empty_kv(), 0, 6, false).unwrap().ids;

        let mut e = engine();
        let mut kv = e.empty_kv();
        e.prefill(&prompt[..17], &mut kv, 0).unwrap();
        let mut s = e.start_stream(&prompt, kv, 17, 6, false).unwrap();
        while !s.is_finished() {
            e.step_streams(&mut [&mut s]).unwrap();
        }
        let g = s.into_generated();
        assert_eq!(g.ids, want);
        assert_eq!(g.reused_tokens, 17);
    }
}
