//! # recycle-serve
//!
//! A serving framework reproducing **"KV Cache Recycling to Expand Usable
//! Context Capacity in Low Parameter LLMs"** (Pandey, 2025) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: request routing, the
//!   cross-prompt KV cache ([`kvcache`]) over a paged block arena
//!   ([`kvcache::arena`]) so cache hits attach by refcount instead of
//!   memcpy, embedding retrieval ([`index`]), exact-prefix matching
//!   ([`prefix`]), the recycling decision ([`recycler`]),
//!   scheduling/batching ([`coordinator`]) and a TCP server ([`server`]).
//! * **L2 (python/compile/model.py)** — a GPT-2-family decoder with the KV
//!   cache as an explicit `[L, 2, H, S, D]` argument, AOT-lowered to HLO
//!   text once at build time.
//! * **L1 (python/compile/kernels/)** — Pallas kernels (flash-style cached
//!   attention, retrieval matvec, fused layernorm) lowered into the same
//!   HLO.
//!
//! Python never runs on the request path: [`runtime`] loads the HLO text
//! artifacts through the PJRT C API (`xla` crate) and [`engine`] drives
//! greedy generation entirely in Rust.

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod error;
pub mod faults;
pub mod index;
pub mod kvcache;
pub mod metrics;
pub mod prefix;
pub mod recycler;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod testutil;
pub mod tokenizer;
pub mod util;

/// Convenience re-exports for the common request-path types.
pub mod prelude {
    pub use crate::config::ModelConfig;
    pub use crate::engine::{Engine, ForwardModel, Generated};
    pub use crate::error::Error;
    pub use crate::kvcache::{KvArena, KvRecord, KvStore, KvView};
    pub use crate::recycler::{RecyclePolicy, Recycler};
    pub use crate::runtime::Runtime;
    pub use crate::tokenizer::Tokenizer;
}
