//! Request metrics: per-request rows (the paper's baseline.csv /
//! recycled.csv schema), aggregate counters, and the merged comparison
//! table (§5.1).

use std::path::Path;

use crate::error::Result;
use crate::util::csv;
use crate::util::timing::Samples;

/// One generation's record — the row schema the paper logs per prompt.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestRow {
    pub prompt: String,
    pub output: String,
    pub latency_s: f64,
    /// Reuse depth k in tokens (0 for baseline / miss).
    pub reused_tokens: usize,
    /// Retrieval similarity of the chosen candidate (NaN if none).
    pub prompt_similarity: f64,
    /// Whether the strict prefix test passed and KV was injected.
    pub cache_hit: bool,
    /// Prompt length m in tokens.
    pub prompt_tokens: usize,
    /// Generated tokens g.
    pub new_tokens: usize,
}

impl RequestRow {
    fn to_csv(&self) -> Vec<String> {
        vec![
            self.prompt.clone(),
            self.output.clone(),
            format!("{:.6}", self.latency_s),
            self.reused_tokens.to_string(),
            format!("{:.4}", self.prompt_similarity),
            self.cache_hit.to_string(),
            self.prompt_tokens.to_string(),
            self.new_tokens.to_string(),
        ]
    }
}

const HEADER: [&str; 8] = [
    "text", "output", "latency_s", "reused_tokens", "prompt_similarity",
    "cache_hit", "prompt_tokens", "new_tokens",
];

/// Write rows in the paper's results-file format.
pub fn write_rows(path: &Path, rows: &[RequestRow]) -> Result<()> {
    let data: Vec<Vec<String>> = rows.iter().map(|r| r.to_csv()).collect();
    csv::write_file(path, &HEADER, &data)
}

/// The merged baseline-vs-recycled comparison (paper §5.1 table).
#[derive(Debug, Clone, Default)]
pub struct Comparison {
    pub total_prompts: usize,
    pub cache_hits: usize,
    pub total_tokens_reused: usize,
    /// Per-prompt speedup percentages (the paper's S).
    pub speedups_pct: Vec<f64>,
    pub output_similarity: Vec<f64>,
    pub prompt_similarity: Vec<f64>,
    pub latency_baseline: Samples,
    pub latency_recycled: Samples,
}

impl Comparison {
    /// Merge per-prompt baseline and recycled rows by the prompt-text key
    /// (the paper merges on `text`).
    pub fn merge(
        baseline: &[RequestRow],
        recycled: &[RequestRow],
        output_similarity: impl Fn(&str, &str) -> f64,
    ) -> Comparison {
        let mut cmp = Comparison {
            total_prompts: recycled.len(),
            ..Default::default()
        };
        for rec in recycled {
            let Some(base) = baseline.iter().find(|b| b.prompt == rec.prompt) else {
                continue;
            };
            if rec.cache_hit {
                cmp.cache_hits += 1;
                cmp.total_tokens_reused += rec.reused_tokens;
            }
            let s = (base.latency_s - rec.latency_s) / base.latency_s * 100.0;
            cmp.speedups_pct.push(s);
            cmp.output_similarity
                .push(output_similarity(&base.output, &rec.output));
            if rec.prompt_similarity.is_finite() {
                cmp.prompt_similarity.push(rec.prompt_similarity);
            }
            cmp.latency_baseline.push(base.latency_s);
            cmp.latency_recycled.push(rec.latency_s);
        }
        cmp
    }

    pub fn avg_speedup_pct(&self) -> f64 {
        mean(&self.speedups_pct)
    }

    /// Average speedup restricted to hits / misses (paper rows 5-6).
    pub fn avg_speedup_split(&self, recycled: &[RequestRow]) -> (f64, f64) {
        let mut hit = Vec::new();
        let mut miss = Vec::new();
        for (s, r) in self.speedups_pct.iter().zip(recycled) {
            if r.cache_hit {
                hit.push(*s);
            } else {
                miss.push(*s);
            }
        }
        (mean(&hit), mean(&miss))
    }

    pub fn avg_output_similarity(&self) -> f64 {
        mean(&self.output_similarity)
    }

    pub fn avg_prompt_similarity(&self) -> f64 {
        mean(&self.prompt_similarity)
    }

    pub fn high_similarity_count(&self, threshold: f64) -> usize {
        self.prompt_similarity.iter().filter(|&&s| s > threshold).count()
    }
}

fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        f64::NAN
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Aggregate serving counters (engine + coordinator level).
#[derive(Debug, Clone, Copy, Default)]
pub struct Counters {
    pub requests: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub tokens_prefilled: u64,
    pub tokens_reused: u64,
    pub tokens_generated: u64,
    pub rejected: u64,
}

impl Counters {
    pub fn hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Fold another worker's counters into this one (cluster aggregate:
    /// all fields are sums).
    pub fn merge(&mut self, o: &Counters) {
        self.requests += o.requests;
        self.cache_hits += o.cache_hits;
        self.cache_misses += o.cache_misses;
        self.tokens_prefilled += o.tokens_prefilled;
        self.tokens_reused += o.tokens_reused;
        self.tokens_generated += o.tokens_generated;
        self.rejected += o.rejected;
    }

    /// Fraction of prompt tokens that were NOT recomputed — the paper's
    /// "compute saved over the fixed window" framing.
    pub fn reuse_fraction(&self) -> f64 {
        let total = self.tokens_prefilled + self.tokens_reused;
        if total == 0 {
            0.0
        } else {
            self.tokens_reused as f64 / total as f64
        }
    }
}

/// Per-tenant serving counters kept by the streaming front's QoS layer
/// (keyed by the request's tenant id; the anonymous tenant gets a row
/// too). TTFT here is *client-visible* — clocked from request arrival at
/// the front to the first token frame hitting the connection's write
/// buffer — unlike `SchedulerStats::ttft_ms_*`, which clocks from queue
/// submission to the scheduler's first decode.
#[derive(Debug, Clone, Copy, Default)]
pub struct TenantCounters {
    /// Requests admitted into the tenant's QoS queue.
    pub accepted: u64,
    /// Requests shed with a typed `Overloaded` event (full tenant queue,
    /// downstream backpressure past the deadline, or the wait-based gate).
    pub shed: u64,
    /// Requests that finished with a `done` event.
    pub completed: u64,
    /// Requests that finished with an `error` event.
    pub failed: u64,
    /// Token frames delivered to this tenant's connections.
    pub tokens_streamed: u64,
    /// Requests that have produced their first token frame.
    pub first_tokens: u64,
    /// Total client-visible TTFT over those requests, milliseconds.
    pub ttft_ms_total: u64,
    /// Worst single client-visible TTFT, milliseconds.
    pub ttft_ms_max: u64,
}

impl TenantCounters {
    /// Record a request's first token frame, `ttft_ms` after arrival.
    pub fn note_first_token(&mut self, ttft_ms: u64) {
        self.first_tokens += 1;
        self.ttft_ms_total += ttft_ms;
        self.ttft_ms_max = self.ttft_ms_max.max(ttft_ms);
    }

    /// Mean client-visible TTFT over requests that emitted a token, ms.
    pub fn avg_ttft_ms(&self) -> f64 {
        if self.first_tokens == 0 {
            0.0
        } else {
            self.ttft_ms_total as f64 / self.first_tokens as f64
        }
    }

    /// Fold another front's counters for the same tenant into this one
    /// (totals add, the per-event maximum takes the max).
    pub fn merge(&mut self, o: &TenantCounters) {
        self.accepted += o.accepted;
        self.shed += o.shed;
        self.completed += o.completed;
        self.failed += o.failed;
        self.tokens_streamed += o.tokens_streamed;
        self.first_tokens += o.first_tokens;
        self.ttft_ms_total += o.ttft_ms_total;
        self.ttft_ms_max = self.ttft_ms_max.max(o.ttft_ms_max);
    }
}

/// Continuous-batching scheduler counters, surfaced through
/// `CoordinatorStats`. Occupancy is tracked as (steps, slot-steps) so the
/// average falls out without per-step history.
#[derive(Debug, Clone, Copy, Default)]
pub struct SchedulerStats {
    /// Batched decode steps executed (each is one `forward_batch` round).
    pub decode_steps: u64,
    /// Sum over steps of the number of streams stepped together — the
    /// occupancy numerator.
    pub decode_slot_steps: u64,
    /// Highest concurrent stream count observed in one step.
    pub peak_occupancy: u64,
    /// Requests admitted into the running set.
    pub admitted: u64,
    /// Total milliseconds requests spent queued before admission.
    pub queue_wait_ms_total: u64,
    /// Worst single queue wait, milliseconds.
    pub queue_wait_ms_max: u64,
    /// Forward chunks run by the scheduler's chunked prefill.
    pub prefill_chunks: u64,
    /// Prompt tokens prefilled by the scheduler (real tokens, not padding).
    pub prefill_tokens: u64,
    /// Scheduler ticks that ran at least one prefill chunk.
    pub prefill_ticks: u64,
    /// Most prompt tokens prefilled in a single tick while >= 1 decode
    /// stream was in flight — the head-of-line stall bound. Inline
    /// admission would push this to the whole prompt length; chunked
    /// prefill caps it at `prefill_chunk_tokens * max_prefilling_slots`.
    pub prefill_stall_tokens_max: u64,
    /// Prefill chunk retries (shed-and-resume after a failed step).
    pub prefill_retries: u64,
    /// Transient step failures (model/IO/arena) that armed a backoff
    /// retry instead of failing the request.
    pub transient_retries: u64,
    /// Requests failed after exhausting `transient_retry_limit` attempts.
    pub retry_give_ups: u64,
    /// Requests failed by the per-request deadline sweep
    /// (`request_timeout_ms`), wherever they were: queued, deferred,
    /// prefilling, or decoding.
    pub deadline_timeouts: u64,
    /// Requests that have emitted their first decode token.
    pub first_tokens: u64,
    /// Total time-to-first-token (queue wait + prefill ticks) over those
    /// requests, milliseconds.
    pub ttft_ms_total: u64,
    /// Worst single time-to-first-token, milliseconds.
    pub ttft_ms_max: u64,
}

impl SchedulerStats {
    /// Mean streams per decode step (1.0 == request-at-a-time; higher
    /// means the batcher is actually sharing forward dispatches).
    pub fn avg_occupancy(&self) -> f64 {
        if self.decode_steps == 0 {
            0.0
        } else {
            self.decode_slot_steps as f64 / self.decode_steps as f64
        }
    }

    /// Mean queue wait per admitted request, milliseconds.
    pub fn avg_queue_wait_ms(&self) -> f64 {
        if self.admitted == 0 {
            0.0
        } else {
            self.queue_wait_ms_total as f64 / self.admitted as f64
        }
    }

    /// Record one decode step over `occupancy` concurrent streams.
    pub fn note_step(&mut self, occupancy: usize) {
        self.decode_steps += 1;
        self.decode_slot_steps += occupancy as u64;
        self.peak_occupancy = self.peak_occupancy.max(occupancy as u64);
    }

    /// Record one admission that waited `wait_ms` in the queue.
    pub fn note_admission(&mut self, wait_ms: u64) {
        self.admitted += 1;
        self.queue_wait_ms_total += wait_ms;
        self.queue_wait_ms_max = self.queue_wait_ms_max.max(wait_ms);
    }

    /// Record one tick's chunked-prefill work: `tokens` prompt tokens over
    /// `chunks` forward chunks; `decode_active` says whether any decode
    /// stream was in flight (only then does the work count toward the
    /// head-of-line stall bound).
    pub fn note_prefill_tick(&mut self, tokens: usize, chunks: usize, decode_active: bool) {
        if chunks == 0 {
            return;
        }
        self.prefill_ticks += 1;
        self.prefill_chunks += chunks as u64;
        self.prefill_tokens += tokens as u64;
        if decode_active {
            self.prefill_stall_tokens_max =
                self.prefill_stall_tokens_max.max(tokens as u64);
        }
    }

    /// Record a request's first decoded token, `ttft_ms` after submission.
    pub fn note_first_token(&mut self, ttft_ms: u64) {
        self.first_tokens += 1;
        self.ttft_ms_total += ttft_ms;
        self.ttft_ms_max = self.ttft_ms_max.max(ttft_ms);
    }

    /// Mean time-to-first-token over requests that emitted one, ms.
    pub fn avg_ttft_ms(&self) -> f64 {
        if self.first_tokens == 0 {
            0.0
        } else {
            self.ttft_ms_total as f64 / self.first_tokens as f64
        }
    }

    /// Fold another worker's scheduler counters into this one (cluster
    /// aggregate): totals add, per-event maxima take the max. Derived
    /// rates (`avg_occupancy`, `avg_ttft_ms`, …) then read as
    /// cluster-wide means, weighted by each worker's event counts.
    pub fn merge(&mut self, o: &SchedulerStats) {
        self.decode_steps += o.decode_steps;
        self.decode_slot_steps += o.decode_slot_steps;
        self.peak_occupancy = self.peak_occupancy.max(o.peak_occupancy);
        self.admitted += o.admitted;
        self.queue_wait_ms_total += o.queue_wait_ms_total;
        self.queue_wait_ms_max = self.queue_wait_ms_max.max(o.queue_wait_ms_max);
        self.prefill_chunks += o.prefill_chunks;
        self.prefill_tokens += o.prefill_tokens;
        self.prefill_ticks += o.prefill_ticks;
        self.prefill_stall_tokens_max =
            self.prefill_stall_tokens_max.max(o.prefill_stall_tokens_max);
        self.prefill_retries += o.prefill_retries;
        self.transient_retries += o.transient_retries;
        self.retry_give_ups += o.retry_give_ups;
        self.deadline_timeouts += o.deadline_timeouts;
        self.first_tokens += o.first_tokens;
        self.ttft_ms_total += o.ttft_ms_total;
        self.ttft_ms_max = self.ttft_ms_max.max(o.ttft_ms_max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(prompt: &str, lat: f64, hit: bool, reused: usize) -> RequestRow {
        RequestRow {
            prompt: prompt.into(),
            output: format!("out-{prompt}"),
            latency_s: lat,
            reused_tokens: reused,
            prompt_similarity: if hit { 0.9 } else { f64::NAN },
            cache_hit: hit,
            prompt_tokens: 10,
            new_tokens: 5,
        }
    }

    #[test]
    fn merge_computes_paper_metrics() {
        let baseline = vec![row("a", 0.2, false, 0), row("b", 0.4, false, 0)];
        let recycled = vec![row("a", 0.1, true, 6), row("b", 0.4, false, 0)];
        let cmp = Comparison::merge(&baseline, &recycled, |_, _| 1.0);
        assert_eq!(cmp.total_prompts, 2);
        assert_eq!(cmp.cache_hits, 1);
        assert_eq!(cmp.total_tokens_reused, 6);
        assert!((cmp.speedups_pct[0] - 50.0).abs() < 1e-9);
        assert!((cmp.avg_speedup_pct() - 25.0).abs() < 1e-9);
        let (hit, miss) = cmp.avg_speedup_split(&recycled);
        assert!((hit - 50.0).abs() < 1e-9);
        assert!(miss.abs() < 1e-9);
        assert_eq!(cmp.high_similarity_count(0.8), 1);
    }

    #[test]
    fn merge_skips_unmatched_prompts() {
        let baseline = vec![row("a", 0.2, false, 0)];
        let recycled = vec![row("a", 0.1, true, 3), row("zzz", 0.1, true, 3)];
        let cmp = Comparison::merge(&baseline, &recycled, |_, _| 1.0);
        assert_eq!(cmp.speedups_pct.len(), 1);
    }

    #[test]
    fn counters_rates() {
        let c = Counters {
            cache_hits: 3,
            cache_misses: 1,
            tokens_prefilled: 60,
            tokens_reused: 40,
            ..Default::default()
        };
        assert!((c.hit_rate() - 0.75).abs() < 1e-9);
        assert!((c.reuse_fraction() - 0.4).abs() < 1e-9);
        assert_eq!(Counters::default().hit_rate(), 0.0);
    }

    #[test]
    fn scheduler_stats_averages() {
        let mut s = SchedulerStats::default();
        assert_eq!(s.avg_occupancy(), 0.0);
        assert_eq!(s.avg_queue_wait_ms(), 0.0);
        s.note_step(4);
        s.note_step(2);
        assert_eq!(s.decode_steps, 2);
        assert_eq!(s.peak_occupancy, 4);
        assert!((s.avg_occupancy() - 3.0).abs() < 1e-9);
        s.note_admission(10);
        s.note_admission(30);
        assert_eq!(s.queue_wait_ms_max, 30);
        assert!((s.avg_queue_wait_ms() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn prefill_and_ttft_counters() {
        let mut s = SchedulerStats::default();
        assert_eq!(s.avg_ttft_ms(), 0.0);
        s.note_prefill_tick(0, 0, true); // no chunk ran: not a prefill tick
        assert_eq!(s.prefill_ticks, 0);
        s.note_prefill_tick(32, 1, false); // idle scheduler: no stall
        s.note_prefill_tick(16, 2, true); // decodes in flight: stall bound
        s.note_prefill_tick(8, 1, true);
        assert_eq!(s.prefill_ticks, 3);
        assert_eq!(s.prefill_chunks, 4);
        assert_eq!(s.prefill_tokens, 56);
        assert_eq!(s.prefill_stall_tokens_max, 16, "idle tick excluded");
        s.note_first_token(10);
        s.note_first_token(40);
        assert_eq!(s.first_tokens, 2);
        assert_eq!(s.ttft_ms_max, 40);
        assert!((s.avg_ttft_ms() - 25.0).abs() < 1e-9);
    }

    #[test]
    fn tenant_counters_ttft_and_merge() {
        let mut a = TenantCounters::default();
        assert_eq!(a.avg_ttft_ms(), 0.0);
        a.accepted = 3;
        a.note_first_token(10);
        a.note_first_token(30);
        assert_eq!(a.first_tokens, 2);
        assert_eq!(a.ttft_ms_max, 30);
        assert!((a.avg_ttft_ms() - 20.0).abs() < 1e-9);
        let mut b = TenantCounters {
            accepted: 1,
            shed: 2,
            tokens_streamed: 7,
            ..Default::default()
        };
        b.note_first_token(50);
        a.merge(&b);
        assert_eq!(a.accepted, 4);
        assert_eq!(a.shed, 2);
        assert_eq!(a.tokens_streamed, 7);
        assert_eq!(a.first_tokens, 3);
        assert_eq!(a.ttft_ms_max, 50, "merge takes the max of maxima");
        assert!((a.avg_ttft_ms() - 30.0).abs() < 1e-9);
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("recycle_serve_metrics_test");
        let path = dir.join("rows.csv");
        write_rows(&path, &[row("p, with comma", 0.5, true, 2)]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = crate::util::csv::parse(&text).unwrap();
        assert_eq!(parsed.len(), 2);
        assert_eq!(parsed[1][0], "p, with comma");
        std::fs::remove_dir_all(&dir).ok();
    }
}
