//! Arena-backed paged KV storage — the zero-copy injection substrate.
//!
//! One [`KvArena`] owns a single large f32 slab carved into fixed-size
//! *token blocks* whose lifetimes are managed by the refcounted
//! [`BlockPool`]. A [`KvView`] presents a logical `[L, 2, H, len, D]`
//! sequence over a table of [`BlockRef`]s, so that:
//!
//! * **injection is zero-copy** — attaching a cached prefix clones its
//!   block table (one refcount bump per block, O(prefix blocks)), instead
//!   of memcpying megabytes into a dense per-request buffer;
//! * **prefixes are shared copy-on-write** — a view appends past a shared
//!   boundary block by copying *only that block* before writing, so a
//!   served prompt, its cache record, and a later session continuation all
//!   share the common blocks (PagedAttention's memory model);
//! * **capacity is a first-class resource** — free/held block accounting is
//!   conserved (property-tested in `rust/tests/properties.rs`): free +
//!   referenced == capacity and no block is ever both free and referenced.
//!
//! Block layout: block `b` occupies slab elements
//! `[b * block_elems, (b + 1) * block_elems)`, internally `[L, 2, H,
//! block_tokens, D]` row-major — so one (layer, k/v, head) *plane* of a
//! token run is contiguous, and gather/scatter at the model-call boundary
//! degenerates to per-plane `memcpy` runs.
//!
//! # Safety model
//!
//! The slab is a boxed slice of element-wise `UnsafeCell`s so disjoint
//! views can write their own blocks concurrently without a slab-wide lock,
//! and block slices are derived from raw pointers (never a whole-slab
//! reference, which would alias against other blocks' live slices). All
//! unsafe access is private to this module and follows one discipline:
//!
//! * a **shared** block (refcount > 1, or reachable from a `&KvView`) is
//!   only ever *read*;
//! * a block is only written through `&mut KvView` **after**
//!   [`BlockRef::is_unique`] confirms the view holds the sole handle (or
//!   the block was just allocated) — uniqueness cannot be invalidated
//!   concurrently because refcounts grow only by cloning an existing
//!   handle, which the writer holds exclusively.
//!
//! This is the same argument `Arc::get_mut` makes, applied per block.

use std::cell::UnsafeCell;
use std::sync::Arc;

use crate::config::ModelConfig;
use crate::error::{Error, Result};

use super::blocks::{BlockPool, BlockRef, QuantBlock};

/// Default positions per block (PagedAttention's canonical 16).
pub const DEFAULT_BLOCK_TOKENS: usize = 16;

/// Default arena sizing: enough blocks for this many full-context
/// sequences (cache entries + in-flight requests). The slab is allocated
/// zeroed, so untouched pages stay virtual.
const DEFAULT_SEQS: usize = 96;

/// Per-token KV geometry shared by every block in an arena.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KvGeometry {
    pub n_layer: usize,
    pub n_head: usize,
    pub head_dim: usize,
    pub block_tokens: usize,
}

impl KvGeometry {
    pub fn from_config(cfg: &ModelConfig, block_tokens: usize) -> Self {
        KvGeometry {
            n_layer: cfg.n_layer,
            n_head: cfg.n_head,
            head_dim: cfg.head_dim,
            block_tokens,
        }
    }

    /// Number of (layer, k/v, head) planes.
    pub fn planes(&self) -> usize {
        self.n_layer * 2 * self.n_head
    }

    /// f32 elements per token position across all planes.
    pub fn elems_per_token(&self) -> usize {
        self.planes() * self.head_dim
    }

    /// f32 elements in one block.
    pub fn block_elems(&self) -> usize {
        self.elems_per_token() * self.block_tokens
    }

    /// Bytes of KV per token position.
    pub fn bytes_per_token(&self) -> usize {
        4 * self.elems_per_token()
    }

    /// Does this arena geometry serve a model config?
    pub fn matches(&self, cfg: &ModelConfig) -> bool {
        self.n_layer == cfg.n_layer
            && self.n_head == cfg.n_head
            && self.head_dim == cfg.head_dim
    }

    /// Blocks needed to hold `tokens` positions.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }
}

struct ArenaInner {
    geom: KvGeometry,
    pool: BlockPool,
    /// Element-wise `UnsafeCell` so per-block slices are derived through
    /// interior mutability without ever materializing a whole-slab `&mut`
    /// (which would alias — and under `Sync`, race — against concurrent
    /// reads of other blocks).
    slab: Box<[UnsafeCell<f32>]>,
    /// Plan-driven fault seam (`FaultSite::ArenaSpike`): set at most once
    /// via [`KvArena::install_faults`]; the production cost of an
    /// uninstalled seam is one relaxed atomic load per allocation.
    faults: std::sync::OnceLock<crate::faults::FaultHandle>,
}

// SAFETY: the slab cells are only accessed through the block discipline
// documented in the module header — shared blocks are read-only, written
// blocks are uniquely held — so cross-thread use cannot race.
unsafe impl Send for ArenaInner {}
unsafe impl Sync for ArenaInner {}

impl ArenaInner {
    /// Raw base pointer of one block. The derivation never creates a
    /// reference to the cells (`UnsafeCell::raw_get` on a pointer with
    /// whole-slab provenance), so it cannot invalidate live block slices.
    fn block_ptr(&self, block_id: usize) -> *mut f32 {
        let n = self.geom.block_elems();
        debug_assert!((block_id + 1) * n <= self.slab.len());
        // SAFETY: in-bounds offset within the slab allocation.
        unsafe { UnsafeCell::raw_get(self.slab.as_ptr().add(block_id * n)) }
    }

    /// SAFETY: caller must hold a live `BlockRef` for `block_id` and ensure
    /// no `&mut` to this block exists for the returned lifetime.
    unsafe fn block(&self, block_id: usize) -> &[f32] {
        std::slice::from_raw_parts(self.block_ptr(block_id), self.geom.block_elems())
    }

    /// SAFETY: caller must hold the *unique* live `BlockRef` for `block_id`
    /// (just allocated, or `is_unique()`), and no other slice into this
    /// block may exist for the returned lifetime.
    #[allow(clippy::mut_from_ref)]
    unsafe fn block_mut(&self, block_id: usize) -> &mut [f32] {
        std::slice::from_raw_parts_mut(self.block_ptr(block_id), self.geom.block_elems())
    }
}

/// The paged KV arena: one slab + one block pool. Cheap to clone (handle).
#[derive(Clone)]
pub struct KvArena {
    inner: Arc<ArenaInner>,
}

impl std::fmt::Debug for KvArena {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "KvArena(blocks {}/{} free, {} tok/block)",
            self.free_blocks(),
            self.capacity_blocks(),
            self.block_tokens()
        )
    }
}

impl KvArena {
    /// An arena of `capacity_blocks` blocks of `block_tokens` positions
    /// each, for the given model geometry.
    pub fn new(cfg: &ModelConfig, block_tokens: usize, capacity_blocks: usize) -> Self {
        let geom = KvGeometry::from_config(cfg, block_tokens);
        // Allocate zeroed (lazily paged by the OS), then reinterpret as
        // cells. SAFETY: UnsafeCell<f32> is repr(transparent) over f32, so
        // the slice layouts are identical.
        let zeroed = vec![0f32; capacity_blocks * geom.block_elems()].into_boxed_slice();
        let slab = unsafe {
            Box::from_raw(Box::into_raw(zeroed) as *mut [UnsafeCell<f32>])
        };
        KvArena {
            inner: Arc::new(ArenaInner {
                pool: BlockPool::new(capacity_blocks, block_tokens),
                geom,
                slab,
                faults: std::sync::OnceLock::new(),
            }),
        }
    }

    /// Attach a fault plan to this arena (and every clone of the handle).
    /// `FaultSite::ArenaSpike` then makes allocations report exhaustion on
    /// schedule, despite free blocks — the refcount-pressure spike the
    /// shed/retry paths must absorb. One-shot: later installs are ignored.
    pub fn install_faults(&self, h: crate::faults::FaultHandle) {
        let _ = self.inner.faults.set(h);
    }

    /// Default sizing: [`DEFAULT_BLOCK_TOKENS`]-token blocks, capacity for
    /// 96 full-context sequences.
    pub fn with_defaults(cfg: &ModelConfig) -> Self {
        let per_seq = cfg.max_seq.div_ceil(DEFAULT_BLOCK_TOKENS);
        Self::new(cfg, DEFAULT_BLOCK_TOKENS, per_seq * DEFAULT_SEQS)
    }

    pub fn geometry(&self) -> &KvGeometry {
        &self.inner.geom
    }

    pub fn block_tokens(&self) -> usize {
        self.inner.geom.block_tokens
    }

    pub fn capacity_blocks(&self) -> usize {
        self.inner.pool.capacity()
    }

    pub fn free_blocks(&self) -> usize {
        self.inner.pool.free_blocks()
    }

    pub fn used_blocks(&self) -> usize {
        self.capacity_blocks() - self.free_blocks()
    }

    /// Total slab bytes (allocated once, zeroed, lazily paged in).
    pub fn slab_bytes(&self) -> usize {
        4 * self.capacity_blocks() * self.inner.geom.block_elems()
    }

    /// Blocks needed for `tokens` positions.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        self.inner.geom.blocks_for(tokens)
    }

    /// Diagnostic `(free list, refcounts)` snapshot (property tests).
    pub fn snapshot(&self) -> (Vec<usize>, Vec<u32>) {
        self.inner.pool.snapshot()
    }

    /// How many `(block_id, expected_refcount)` pairs match current
    /// refcounts (one lock, no cloning — see
    /// [`BlockPool::count_matching_refs`]).
    pub fn count_matching_refs(
        &self,
        pairs: impl Iterator<Item = (usize, u32)>,
    ) -> usize {
        self.inner.pool.count_matching_refs(pairs)
    }

    /// A new empty view over this arena (no blocks held yet).
    pub fn new_view(&self) -> KvView {
        KvView {
            arena: self.clone(),
            blocks: Vec::new(),
            len: 0,
        }
    }

    /// Allocate one zeroed block.
    fn alloc_zeroed(&self) -> Result<BlockRef> {
        if let Some(h) = self.inner.faults.get() {
            if h.roll(crate::faults::FaultSite::ArenaSpike) {
                return Err(Error::ArenaExhausted { needed: 1, free: 0 });
            }
        }
        let b = self.inner.pool.alloc().ok_or(Error::ArenaExhausted {
            needed: 1,
            free: 0,
        })?;
        // SAFETY: freshly allocated -> uniquely held by `b`.
        unsafe { self.inner.block_mut(b.block_id).fill(0.0) };
        Ok(b)
    }

    fn same_arena(&self, other: &KvArena) -> bool {
        Arc::ptr_eq(&self.inner, &other.inner)
    }
}

/// A logical `[L, 2, H, len, D]` KV sequence over arena blocks.
///
/// Cloning shares every block (refcount bump, O(blocks)) — this *is* the
/// zero-copy cache injection. Writes go through `&mut self` and
/// copy-on-write any block that is still shared.
pub struct KvView {
    arena: KvArena,
    blocks: Vec<BlockRef>,
    /// Valid (written) token positions.
    len: usize,
}

impl Clone for KvView {
    fn clone(&self) -> Self {
        KvView {
            arena: self.arena.clone(),
            blocks: self.blocks.clone(),
            len: self.len,
        }
    }
}

impl std::fmt::Debug for KvView {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "KvView(len={}, blocks={})", self.len, self.blocks.len())
    }
}

impl KvView {
    /// Valid token positions.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Positions currently backed by blocks.
    pub fn capacity_tokens(&self) -> usize {
        self.blocks.len() * self.arena.block_tokens()
    }

    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn geometry(&self) -> &KvGeometry {
        self.arena.geometry()
    }

    pub fn arena(&self) -> &KvArena {
        &self.arena
    }

    /// Physical block ids in table order (tests/diagnostics).
    pub fn block_ids(&self) -> Vec<usize> {
        self.blocks.iter().map(|b| b.block_id).collect()
    }

    /// Blocks for which this view holds the *only* live reference —
    /// dropping the view returns exactly these to the pool. This is the
    /// shared-aware *physical* footprint of an eviction: blocks still
    /// referenced elsewhere (a cached sibling, an in-flight stream) are
    /// excluded because releasing our handle does not free them.
    pub fn unique_blocks(&self) -> usize {
        self.blocks.iter().filter(|b| b.is_unique()).count()
    }

    /// Extend the valid length (after out-of-band `row_mut` writes).
    pub fn commit(&mut self, len: usize) {
        debug_assert!(len <= self.capacity_tokens());
        self.len = self.len.max(len);
    }

    /// Shrink the valid length to `len`, releasing whole blocks past the
    /// boundary (their refcounts drop; last holders free them).
    pub fn truncate(&mut self, len: usize) {
        if len >= self.len {
            return;
        }
        self.len = len;
        self.blocks.truncate(self.arena.blocks_for(len));
    }

    /// Ensure blocks exist for `tokens` positions; new blocks are zeroed.
    /// All-or-nothing is not required: already-acquired blocks stay with
    /// the view and are freed when it drops.
    pub fn reserve(&mut self, tokens: usize) -> Result<()> {
        let need = self.arena.blocks_for(tokens);
        while self.blocks.len() < need {
            match self.arena.alloc_zeroed() {
                Ok(b) => self.blocks.push(b),
                Err(_) => {
                    return Err(Error::ArenaExhausted {
                        needed: need - self.blocks.len(),
                        free: self.arena.free_blocks(),
                    })
                }
            }
        }
        Ok(())
    }

    /// Copy-on-write: make block `bi` of the table uniquely ours.
    fn ensure_unique(&mut self, bi: usize) -> Result<()> {
        if self.blocks[bi].is_unique() {
            return Ok(());
        }
        let fresh = self.arena.inner.pool.alloc().ok_or(Error::ArenaExhausted {
            needed: 1,
            free: 0,
        })?;
        // SAFETY: `fresh` is uniquely held (just allocated); the source
        // block is shared and therefore read-only; the two are distinct.
        unsafe {
            let src = self.arena.inner.block(self.blocks[bi].block_id);
            self.arena.inner.block_mut(fresh.block_id).copy_from_slice(src);
        }
        self.blocks[bi] = fresh;
        Ok(())
    }

    fn plane_of(&self, layer: usize, kv: usize, head: usize) -> usize {
        let g = self.geometry();
        debug_assert!(layer < g.n_layer && kv < 2 && head < g.n_head);
        (layer * 2 + kv) * g.n_head + head
    }

    /// Read one `[D]` row. `pos` must be backed (`< capacity_tokens`).
    /// Rows in `[0, len)` hold written data (or zeros, for reserved-but-
    /// unwritten positions); rows in `[len, capacity)` may hold *stale*
    /// data — a truncated view keeps its boundary block whole, including
    /// the donor's rows past the cut — so callers must bound context reads
    /// by [`len`](Self::len), as every gather in the serving path does.
    pub fn row(&self, layer: usize, kv: usize, head: usize, pos: usize) -> &[f32] {
        let g = self.geometry();
        let (bt, d) = (g.block_tokens, g.head_dim);
        assert!(pos < self.capacity_tokens(), "row {pos} beyond view capacity");
        let plane = self.plane_of(layer, kv, head);
        let off = (plane * bt + pos % bt) * d;
        // SAFETY: we hold a BlockRef; shared blocks are read-only and
        // unique blocks can only be written through `&mut self`, which the
        // borrow checker excludes while this `&self` borrow lives.
        unsafe { &self.arena.inner.block(self.blocks[pos / bt].block_id)[off..off + d] }
    }

    /// Writable `[D]` row at `pos`, allocating/COW-ing as needed. Does not
    /// advance [`len`](Self::len) — call [`commit`](Self::commit) after.
    pub fn row_mut(&mut self, layer: usize, kv: usize, head: usize, pos: usize) -> Result<&mut [f32]> {
        self.reserve(pos + 1)?;
        let bi = pos / self.geometry().block_tokens;
        self.ensure_unique(bi)?;
        let g = self.geometry();
        let (bt, d) = (g.block_tokens, g.head_dim);
        let off = (self.plane_of(layer, kv, head) * bt + pos % bt) * d;
        // SAFETY: block `bi` is uniquely held by this view (ensure_unique)
        // and `&mut self` excludes any other slice into it.
        let block = unsafe { self.arena.inner.block_mut(self.blocks[bi].block_id) };
        Ok(&mut block[off..off + d])
    }

    /// Scatter a model chunk into the view: `rows` is `[L, 2, H, chunk, D]`
    /// row-major, of which the first `count` token rows per plane are real;
    /// they land at positions `[cur_len, cur_len + count)`. Shared boundary
    /// blocks are COW-ed, new blocks allocated zeroed. Advances `len`.
    pub fn scatter_chunk(
        &mut self,
        rows: &[f32],
        chunk: usize,
        count: usize,
        cur_len: usize,
    ) -> Result<()> {
        let g = self.geometry().clone();
        let (bt, d) = (g.block_tokens, g.head_dim);
        if rows.len() != g.planes() * chunk * d {
            return Err(Error::ShapeMismatch(format!(
                "scatter rows has {} elems, expected {}",
                rows.len(),
                g.planes() * chunk * d
            )));
        }
        if count > chunk {
            return Err(Error::ShapeMismatch(format!(
                "scatter count {count} > chunk {chunk}"
            )));
        }
        if count == 0 {
            return Ok(());
        }
        self.reserve(cur_len + count)?;
        let first_b = cur_len / bt;
        let last_b = (cur_len + count - 1) / bt;
        for bi in first_b..=last_b {
            self.ensure_unique(bi)?;
        }
        // Copy per (block, plane) runs: token positions within one block
        // are contiguous in both the chunk buffer and the block plane.
        let mut pos = cur_len;
        while pos < cur_len + count {
            let bi = pos / bt;
            let slot = pos % bt;
            let run = (bt - slot).min(cur_len + count - pos);
            let i = pos - cur_len; // token index within the chunk
            // SAFETY: ensure_unique above made every touched block unique to
            // this view; `&mut self` excludes other slices.
            let block = unsafe { self.arena.inner.block_mut(self.blocks[bi].block_id) };
            for plane in 0..g.planes() {
                let src = (plane * chunk + i) * d;
                let dst = (plane * bt + slot) * d;
                block[dst..dst + run * d].copy_from_slice(&rows[src..src + run * d]);
            }
            pos += run;
        }
        self.len = self.len.max(cur_len + count);
        Ok(())
    }

    /// Gather the first `n` positions (`n <= len`) into `dst`, laid out
    /// `[L, 2, H, seq_cap, D]` row-major with `seq_cap >= n`. Rows past `n`
    /// are left untouched (callers zero-fill `dst` for padded semantics).
    pub fn gather_into(&self, dst: &mut [f32], seq_cap: usize, n: usize) {
        let g = self.geometry();
        let (bt, d) = (g.block_tokens, g.head_dim);
        assert!(n <= self.len, "gather {n} > valid len {}", self.len);
        assert!(n <= seq_cap, "gather {n} > seq capacity {seq_cap}");
        assert_eq!(dst.len(), g.planes() * seq_cap * d, "gather dst size");
        let mut pos = 0usize;
        while pos < n {
            let bi = pos / bt;
            let slot = pos % bt;
            let run = (bt - slot).min(n - pos);
            // SAFETY: read-only access under a live BlockRef (see `row`).
            let block = unsafe { self.arena.inner.block(self.blocks[bi].block_id) };
            for plane in 0..g.planes() {
                let src = (plane * bt + slot) * d;
                let dst_off = (plane * seq_cap + pos) * d;
                dst[dst_off..dst_off + run * d].copy_from_slice(&block[src..src + run * d]);
            }
            pos += run;
        }
    }

    /// Contiguous trimmed copy `[L, 2, H, len, D]` (persistence, tests).
    pub fn to_contiguous(&self) -> Vec<f32> {
        let g = self.geometry();
        let mut out = vec![0f32; g.planes() * self.len * g.head_dim];
        self.gather_into(&mut out, self.len, self.len);
        out
    }

    /// Materialize a view from a contiguous trimmed `[L, 2, H, len, D]`
    /// buffer (inverse of [`to_contiguous`](Self::to_contiguous)).
    pub fn from_contiguous(arena: &KvArena, data: &[f32], len: usize) -> Result<KvView> {
        let g = arena.geometry();
        if data.len() != g.planes() * len * g.head_dim {
            return Err(Error::ShapeMismatch(format!(
                "contiguous kv has {} elems, expected {} for {len} tokens",
                data.len(),
                g.planes() * len * g.head_dim
            )));
        }
        let mut view = arena.new_view();
        view.scatter_chunk(data, len, len, 0)?;
        Ok(view)
    }

    /// Do two views share the same arena (and can therefore share blocks)?
    pub fn same_arena(&self, other: &KvView) -> bool {
        self.arena.same_arena(&other.arena)
    }
}

/// A quantized copy of a view's payload: 8-bit blocks with per-block
/// power-of-two scales, holding **zero** arena blocks. This is the hot
/// tier's capacity multiplier (`CacheConfig::quantized_blocks`): the store
/// keeps `QuantKv`s at ~1/4 the bytes of the f32 slab rows, and a cache
/// hit dequantizes back into the arena on attach. Block granularity
/// matches the arena (`block_tokens * elems_per_token` values per
/// [`QuantBlock`]), so per-block scales track the same locality the paged
/// layout does.
pub struct QuantKv {
    geom: KvGeometry,
    n_tokens: usize,
    blocks: Vec<QuantBlock>,
}

impl std::fmt::Debug for QuantKv {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "QuantKv(tokens={}, blocks={})",
            self.n_tokens,
            self.blocks.len()
        )
    }
}

impl QuantKv {
    /// Quantize a view's gathered payload (the view itself is untouched;
    /// the caller decides whether to drop it and release its blocks).
    pub fn from_view(view: &KvView) -> QuantKv {
        let geom = view.geometry().clone();
        let flat = view.to_contiguous();
        let chunk = geom.block_elems().max(1);
        QuantKv {
            n_tokens: view.len(),
            blocks: flat.chunks(chunk).map(QuantBlock::quantize).collect(),
            geom,
        }
    }

    pub fn n_tokens(&self) -> usize {
        self.n_tokens
    }

    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn geometry(&self) -> &KvGeometry {
        &self.geom
    }

    /// Physical bytes held (i8 payloads + scale words).
    pub fn quant_bytes(&self) -> usize {
        self.blocks.iter().map(|b| b.bytes()).sum()
    }

    /// Bytes the same payload occupies as f32 arena rows — the logical
    /// size the capacity comparison is made against.
    pub fn logical_bytes(&self) -> usize {
        self.geom.bytes_per_token() * self.n_tokens
    }

    /// Dequantize to a contiguous trimmed `[L, 2, H, n_tokens, D]` buffer.
    pub fn to_f32(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.geom.elems_per_token() * self.n_tokens];
        let chunk = self.geom.block_elems().max(1);
        for (i, b) in self.blocks.iter().enumerate() {
            let start = i * chunk;
            b.dequantize_into(&mut out[start..start + b.len()]);
        }
        out
    }

    /// Materialize back into arena blocks (the attach path of a quantized
    /// cache hit). Fails with `ArenaExhausted` under block pressure —
    /// callers retry after shedding, exactly like a spill reload.
    pub fn materialize(&self, arena: &KvArena) -> Result<KvView> {
        if *arena.geometry() != self.geom {
            return Err(Error::ShapeMismatch(format!(
                "quantized payload geometry {:?} does not match arena {:?}",
                self.geom,
                arena.geometry()
            )));
        }
        KvView::from_contiguous(arena, &self.to_f32(), self.n_tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arena() -> KvArena {
        // nano geometry, 8-token blocks, 32 blocks = 256 positions total
        KvArena::new(&ModelConfig::nano(), 8, 32)
    }

    fn fill(view: &mut KvView, from: usize, count: usize, tag: f32) {
        let g = view.geometry().clone();
        let rows: Vec<f32> = (0..g.planes() * count * g.head_dim)
            .map(|i| tag + i as f32)
            .collect();
        view.scatter_chunk(&rows, count, count, from).unwrap();
    }

    #[test]
    fn scatter_gather_roundtrip() {
        let a = arena();
        let mut v = a.new_view();
        fill(&mut v, 0, 13, 100.0);
        assert_eq!(v.len(), 13);
        assert_eq!(v.num_blocks(), 2);
        let g = a.geometry();
        let flat = v.to_contiguous();
        assert_eq!(flat.len(), g.planes() * 13 * g.head_dim);
        let v2 = KvView::from_contiguous(&a, &flat, 13).unwrap();
        assert_eq!(v2.to_contiguous(), flat);
    }

    #[test]
    fn clone_shares_blocks_and_arena_accounting_holds() {
        let a = arena();
        let mut v = a.new_view();
        fill(&mut v, 0, 20, 0.0);
        let used = a.used_blocks();
        let shared = v.clone();
        assert_eq!(a.used_blocks(), used, "attach must not allocate");
        assert_eq!(shared.block_ids(), v.block_ids());
        drop(shared);
        drop(v);
        assert_eq!(a.used_blocks(), 0);
    }

    #[test]
    fn cow_write_leaves_original_intact() {
        let a = arena();
        let mut v = a.new_view();
        fill(&mut v, 0, 10, 1.0);
        let original = v.to_contiguous();
        let mut copy = v.clone();
        // append into the shared boundary block -> COW of exactly one block
        let used = a.used_blocks();
        fill(&mut copy, 10, 3, 999.0);
        assert_eq!(a.used_blocks(), used + 1, "only the boundary block copies");
        assert_eq!(v.to_contiguous(), original, "donor view unchanged");
        assert_eq!(copy.len(), 13);
        // the shared (non-boundary) block is still physically shared
        assert_eq!(copy.block_ids()[0], v.block_ids()[0]);
        assert_ne!(copy.block_ids()[1], v.block_ids()[1]);
    }

    #[test]
    fn row_accessors_cow_too() {
        let a = arena();
        let mut v = a.new_view();
        fill(&mut v, 0, 4, 5.0);
        let shared = v.clone();
        v.row_mut(0, 0, 0, 2).unwrap()[0] = -7.0;
        v.commit(4);
        assert_eq!(v.row(0, 0, 0, 2)[0], -7.0);
        assert_ne!(shared.row(0, 0, 0, 2)[0], -7.0, "COW isolated the write");
    }

    #[test]
    fn truncate_releases_blocks() {
        let a = arena();
        let mut v = a.new_view();
        fill(&mut v, 0, 24, 0.0); // 3 blocks
        assert_eq!(a.used_blocks(), 3);
        v.truncate(8);
        assert_eq!(v.len(), 8);
        assert_eq!(v.num_blocks(), 1);
        assert_eq!(a.used_blocks(), 1);
        v.truncate(0);
        assert_eq!(a.used_blocks(), 0);
    }

    #[test]
    fn fresh_blocks_are_zeroed_even_after_reuse() {
        let a = arena();
        let mut v = a.new_view();
        fill(&mut v, 0, 8, 42.0);
        drop(v); // block goes back dirty
        let mut v2 = a.new_view();
        v2.reserve(8).unwrap();
        v2.commit(8);
        assert!(v2.to_contiguous().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn exhaustion_is_an_error_not_a_panic() {
        let small = KvArena::new(&ModelConfig::nano(), 8, 2); // 16 positions
        let mut v = small.new_view();
        assert!(v.reserve(16).is_ok());
        match v.reserve(17) {
            Err(Error::ArenaExhausted { .. }) => {}
            other => panic!("expected exhaustion, got {other:?}"),
        }
        // the view keeps what it already holds
        assert_eq!(v.capacity_tokens(), 16);
    }

    #[test]
    fn geometry_matches_config() {
        let a = arena();
        assert!(a.geometry().matches(&ModelConfig::nano()));
        let mut other = ModelConfig::nano();
        other.n_layer += 1;
        assert!(!a.geometry().matches(&other));
    }

    #[test]
    fn default_sizing_covers_many_sequences() {
        let cfg = ModelConfig::nano();
        let a = KvArena::with_defaults(&cfg);
        assert!(a.capacity_blocks() * a.block_tokens() >= cfg.max_seq * 64);
    }

    #[test]
    fn quant_kv_holds_no_blocks_and_materializes_back() {
        let a = arena();
        let mut v = a.new_view();
        // integer-valued rows bounded by 127 -> exact under pow2 scales
        let g = a.geometry().clone();
        let rows: Vec<f32> = (0..g.planes() * 13 * g.head_dim)
            .map(|i| (i % 120) as f32)
            .collect();
        v.scatter_chunk(&rows, 13, 13, 0).unwrap();
        let q = QuantKv::from_view(&v);
        let flat = v.to_contiguous();
        drop(v);
        assert_eq!(a.used_blocks(), 0, "QuantKv must pin zero arena blocks");
        assert_eq!(q.n_tokens(), 13);
        assert!(
            q.quant_bytes() * 3 < q.logical_bytes(),
            "quantized bytes {} must be well under logical {}",
            q.quant_bytes(),
            q.logical_bytes()
        );
        let back = q.materialize(&a).unwrap();
        assert_eq!(back.len(), 13);
        assert_eq!(back.to_contiguous(), flat, "integer payload round-trips exactly");
    }

    #[test]
    fn quant_kv_rejects_wrong_geometry() {
        let a = arena();
        let mut v = a.new_view();
        fill(&mut v, 0, 5, 1.0);
        let q = QuantKv::from_view(&v);
        let mut other_cfg = ModelConfig::nano();
        other_cfg.n_layer += 1;
        let other = KvArena::new(&other_cfg, 8, 32);
        match q.materialize(&other) {
            Err(Error::ShapeMismatch(_)) => {}
            o => panic!("expected shape mismatch, got {o:?}"),
        }
    }

    #[test]
    fn quant_kv_exhaustion_is_transient_not_panic() {
        let a = arena();
        let mut v = a.new_view();
        fill(&mut v, 0, 16, 2.0); // 2 blocks of 8
        let q = QuantKv::from_view(&v);
        drop(v);
        let small = KvArena::new(&ModelConfig::nano(), 8, 1);
        match q.materialize(&small) {
            Err(Error::ArenaExhausted { .. }) => {}
            o => panic!("expected exhaustion, got {o:?}"),
        }
    }
}
