//! PagedAttention-inspired KV block pool.
//!
//! The paper cites PagedAttention (Kwon et al. 2023) as the
//! state-of-the-art for KV memory management; this module provides the
//! corresponding substrate: fixed-size *token blocks* with reference
//! counting so multiple cached prompts can share a common prefix's blocks
//! instead of duplicating them. The radix recycling policy and the A2
//! ablation build on it to quantify the memory saved by sharing —
//! "expanding usable context capacity" in the paper's framing.
//!
//! Invariants (property-tested):
//!  * free + Σ refcounts-held blocks == capacity
//!  * a block is never on the free list while its refcount > 0
//!  * dropping the last `BlockRef` returns the block to the free list

use std::sync::{Arc, Mutex};

/// Handle to one allocated block; cloning shares (bumps the refcount),
/// dropping releases.
pub struct BlockRef {
    pool: Arc<Mutex<Inner>>,
    pub block_id: usize,
}

impl Clone for BlockRef {
    fn clone(&self) -> Self {
        let mut inner = self.pool.lock().unwrap();
        inner.refcounts[self.block_id] += 1;
        BlockRef {
            pool: Arc::clone(&self.pool),
            block_id: self.block_id,
        }
    }
}

impl Drop for BlockRef {
    fn drop(&mut self) {
        let mut inner = self.pool.lock().unwrap();
        inner.refcounts[self.block_id] -= 1;
        if inner.refcounts[self.block_id] == 0 {
            inner.free.push(self.block_id);
        }
    }
}

impl BlockRef {
    /// True when this is the only live handle to the block — the in-place
    /// write test (the paged-KV analogue of `Arc::get_mut`). Sound against
    /// races: refcounts only grow by cloning an existing handle, so if the
    /// caller holds the single handle nobody else can bump it concurrently.
    pub fn is_unique(&self) -> bool {
        self.pool.lock().unwrap().refcounts[self.block_id] == 1
    }
}

impl std::fmt::Debug for BlockRef {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "BlockRef({})", self.block_id)
    }
}

struct Inner {
    free: Vec<usize>,
    refcounts: Vec<u32>,
}

/// Fixed-capacity pool of KV blocks of `block_tokens` positions each.
pub struct BlockPool {
    inner: Arc<Mutex<Inner>>,
    capacity: usize,
    block_tokens: usize,
}

impl BlockPool {
    pub fn new(capacity: usize, block_tokens: usize) -> Self {
        assert!(block_tokens > 0);
        BlockPool {
            inner: Arc::new(Mutex::new(Inner {
                free: (0..capacity).rev().collect(),
                refcounts: vec![0; capacity],
            })),
            capacity,
            block_tokens,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn free_blocks(&self) -> usize {
        self.inner.lock().unwrap().free.len()
    }

    /// Blocks needed to hold `tokens` positions.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Allocate one block. None when exhausted.
    pub fn alloc(&self) -> Option<BlockRef> {
        let mut inner = self.inner.lock().unwrap();
        let id = inner.free.pop()?;
        debug_assert_eq!(inner.refcounts[id], 0);
        inner.refcounts[id] = 1;
        Some(BlockRef {
            pool: Arc::clone(&self.inner),
            block_id: id,
        })
    }

    /// Allocate a run of blocks for a sequence of `tokens` positions.
    /// All-or-nothing: on shortage, nothing is leaked.
    pub fn alloc_seq(&self, tokens: usize) -> Option<Vec<BlockRef>> {
        let need = self.blocks_for(tokens);
        let mut out = Vec::with_capacity(need);
        for _ in 0..need {
            match self.alloc() {
                Some(b) => out.push(b),
                None => return None, // drops already-allocated refs -> freed
            }
        }
        Some(out)
    }

    /// Diagnostic snapshot of `(free list, refcounts)` — used by the
    /// property tests to assert conservation (free + held == capacity, no
    /// block simultaneously free and referenced) and by arena metrics.
    pub fn snapshot(&self) -> (Vec<usize>, Vec<u32>) {
        let inner = self.inner.lock().unwrap();
        (inner.free.clone(), inner.refcounts.clone())
    }

    /// How many `(block_id, expected_refcount)` pairs match the pool's
    /// current refcounts — one lock acquisition, no state cloning. The
    /// tiered store's reclaimability probe, called once per eviction
    /// under arena pressure, where cloning the whole pool state (as
    /// [`snapshot`](Self::snapshot) does) would churn allocations on the
    /// serving path.
    pub fn count_matching_refs(
        &self,
        pairs: impl Iterator<Item = (usize, u32)>,
    ) -> usize {
        let inner = self.inner.lock().unwrap();
        pairs
            .filter(|&(id, rc)| inner.refcounts.get(id).copied() == Some(rc))
            .count()
    }

    /// Bytes of KV that `n_seqs` sequences of `tokens` positions would
    /// occupy with vs without prefix sharing of `shared_tokens` — the
    /// headline "context capacity expansion" arithmetic used by the
    /// ablation bench and EXPERIMENTS.md.
    pub fn sharing_savings(
        &self,
        n_seqs: usize,
        tokens: usize,
        shared_tokens: usize,
        bytes_per_token: usize,
    ) -> (usize, usize) {
        let unshared = n_seqs * self.blocks_for(tokens);
        let shared = self.blocks_for(shared_tokens)
            + n_seqs * self.blocks_for(tokens.saturating_sub(shared_tokens));
        (
            unshared * self.block_tokens * bytes_per_token,
            shared * self.block_tokens * bytes_per_token,
        )
    }
}

/// Smallest power-of-two scale whose 8-bit symmetric range `[-127, 127]`
/// covers `max_abs`. Power-of-two scales make the codec *exact* on
/// dyadic-grid data (any value `k * 2^n` with `|value / scale| <= 127`
/// round-trips bit-identically, because both the division and the
/// multiplication are exact in f32) — which is what lets the
/// token-identity suite hold on the quantized hot tier for integer-valued
/// KV rows, while arbitrary rows degrade gracefully to <= scale/2 error.
pub fn pow2_scale(max_abs: f32) -> f32 {
    if !max_abs.is_finite() || max_abs <= 0.0 {
        return 1.0;
    }
    let mut s = 1.0f32;
    if max_abs > 127.0 {
        while max_abs > 127.0 * s {
            s *= 2.0;
        }
    } else {
        while s * 0.5 > 0.0 && max_abs <= 127.0 * (s * 0.5) {
            s *= 0.5;
        }
    }
    s
}

/// One quantized KV block: 8-bit symmetric values under a shared
/// power-of-two scale. This is the hot tier's capacity multiplier — a
/// `QuantBlock` stores a block's worth of f32 rows in a quarter of the
/// bytes, and dequantizes into a fresh arena block on attach.
pub struct QuantBlock {
    data: Box<[i8]>,
    scale: f32,
}

impl QuantBlock {
    /// Quantize a run of f32 values (one block's worth) under one
    /// power-of-two scale chosen from the run's max magnitude.
    pub fn quantize(values: &[f32]) -> QuantBlock {
        let max_abs = values
            .iter()
            .filter(|v| v.is_finite())
            .fold(0.0f32, |m, v| m.max(v.abs()));
        let scale = pow2_scale(max_abs);
        let data: Box<[i8]> = values
            .iter()
            .map(|&v| (v / scale).round().clamp(-127.0, 127.0) as i8)
            .collect();
        QuantBlock { data, scale }
    }

    /// Dequantize into `out` (must be exactly `self.len()` values).
    pub fn dequantize_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.data.len(), "dequantize size mismatch");
        for (o, &q) in out.iter_mut().zip(self.data.iter()) {
            *o = q as f32 * self.scale;
        }
    }

    /// Stored values.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Physical bytes held: one i8 per value plus the scale word.
    pub fn bytes(&self) -> usize {
        self.data.len() + 4
    }

    pub fn scale(&self) -> f32 {
        self.scale
    }
}

impl std::fmt::Debug for QuantBlock {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "QuantBlock(len={}, scale={})", self.data.len(), self.scale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_cycle() {
        let p = BlockPool::new(4, 16);
        assert_eq!(p.free_blocks(), 4);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_eq!(p.free_blocks(), 2);
        drop(a);
        assert_eq!(p.free_blocks(), 3);
        drop(b);
        assert_eq!(p.free_blocks(), 4);
    }

    #[test]
    fn sharing_keeps_block_alive() {
        let p = BlockPool::new(2, 16);
        let a = p.alloc().unwrap();
        let a2 = a.clone();
        drop(a);
        assert_eq!(p.free_blocks(), 1, "shared block must stay allocated");
        drop(a2);
        assert_eq!(p.free_blocks(), 2);
    }

    #[test]
    fn exhaustion_returns_none() {
        let p = BlockPool::new(1, 16);
        let _a = p.alloc().unwrap();
        assert!(p.alloc().is_none());
    }

    #[test]
    fn alloc_seq_all_or_nothing() {
        let p = BlockPool::new(3, 16);
        assert!(p.alloc_seq(40).is_some()); // 3 blocks, dropped immediately
        assert_eq!(p.free_blocks(), 3);
        let _hold = p.alloc().unwrap();
        assert!(p.alloc_seq(40).is_none()); // needs 3, only 2 free
        assert_eq!(p.free_blocks(), 2, "failed alloc_seq must not leak");
    }

    #[test]
    fn blocks_for_rounding() {
        let p = BlockPool::new(8, 16);
        assert_eq!(p.blocks_for(0), 0);
        assert_eq!(p.blocks_for(1), 1);
        assert_eq!(p.blocks_for(16), 1);
        assert_eq!(p.blocks_for(17), 2);
    }

    #[test]
    fn uniqueness_tracks_sharing() {
        let p = BlockPool::new(2, 16);
        let a = p.alloc().unwrap();
        assert!(a.is_unique());
        let a2 = a.clone();
        assert!(!a.is_unique());
        drop(a2);
        assert!(a.is_unique());
    }

    #[test]
    fn snapshot_is_consistent() {
        let p = BlockPool::new(3, 16);
        let a = p.alloc().unwrap();
        let _a2 = a.clone();
        let _b = p.alloc().unwrap();
        let (free, refs) = p.snapshot();
        assert_eq!(free.len() + refs.iter().filter(|&&c| c > 0).count(), 3);
        for &id in &free {
            assert_eq!(refs[id], 0, "free block {id} still referenced");
        }
        assert_eq!(refs[a.block_id], 2);
    }

    #[test]
    fn sharing_savings_math() {
        let p = BlockPool::new(64, 16);
        // 4 seqs of 64 tokens sharing a 32-token prefix
        let (unshared, shared) = p.sharing_savings(4, 64, 32, 1);
        assert_eq!(unshared, 4 * 4 * 16);
        assert_eq!(shared, (2 + 4 * 2) * 16);
        assert!(shared < unshared);
    }

    #[test]
    fn pow2_scale_covers_and_is_minimal() {
        assert_eq!(pow2_scale(0.0), 1.0);
        assert_eq!(pow2_scale(f32::NAN), 1.0);
        assert_eq!(pow2_scale(100.0), 1.0);
        assert_eq!(pow2_scale(127.0), 1.0);
        assert_eq!(pow2_scale(128.0), 2.0);
        assert_eq!(pow2_scale(300.0), 4.0);
        assert_eq!(pow2_scale(42.0), 0.5);
        assert_eq!(pow2_scale(0.4), 1.0 / 256.0);
        for m in [0.3f32, 1.0, 63.0, 64.0, 500.0, 1e-6, 1e6] {
            let s = pow2_scale(m);
            assert!(m <= 127.0 * s, "scale {s} does not cover {m}");
            assert!(
                s <= f32::MIN_POSITIVE || m > 127.0 * (s * 0.5),
                "scale {s} not minimal for {m}"
            );
        }
    }

    #[test]
    fn quant_roundtrip_exact_on_integer_grid() {
        // integers |v| <= 127 under a power-of-two scale are exact — the
        // property the token-identity suite relies on
        let vals: Vec<f32> = (-127..=127).map(|i| i as f32).collect();
        let q = QuantBlock::quantize(&vals);
        let mut out = vec![0f32; vals.len()];
        q.dequantize_into(&mut out);
        assert_eq!(out, vals);
    }

    #[test]
    fn quant_roundtrip_bounded_error_and_quarter_size() {
        let vals: Vec<f32> = (0..256).map(|i| (i as f32) * 0.731 - 90.0).collect();
        let q = QuantBlock::quantize(&vals);
        assert_eq!(q.bytes(), vals.len() + 4, "i8 payload + scale word");
        assert!(q.bytes() * 4 < vals.len() * 4 + 32, "must be ~4x smaller");
        let mut out = vec![0f32; vals.len()];
        q.dequantize_into(&mut out);
        for (a, b) in vals.iter().zip(&out) {
            assert!((a - b).abs() <= q.scale() / 2.0 + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn quant_zeros_stay_zero() {
        let vals = vec![0f32; 64];
        let q = QuantBlock::quantize(&vals);
        let mut out = vec![1f32; 64];
        q.dequantize_into(&mut out);
        assert!(out.iter().all(|&v| v == 0.0));
    }
}
