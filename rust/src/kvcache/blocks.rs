//! PagedAttention-inspired KV block pool.
//!
//! The paper cites PagedAttention (Kwon et al. 2023) as the
//! state-of-the-art for KV memory management; this module provides the
//! corresponding substrate: fixed-size *token blocks* with reference
//! counting so multiple cached prompts can share a common prefix's blocks
//! instead of duplicating them. The radix recycling policy and the A2
//! ablation build on it to quantify the memory saved by sharing —
//! "expanding usable context capacity" in the paper's framing.
//!
//! Invariants (property-tested):
//!  * free + Σ refcounts-held blocks == capacity
//!  * a block is never on the free list while its refcount > 0
//!  * dropping the last `BlockRef` returns the block to the free list

use std::sync::{Arc, Mutex};

/// Handle to one allocated block; cloning shares (bumps the refcount),
/// dropping releases.
pub struct BlockRef {
    pool: Arc<Mutex<Inner>>,
    pub block_id: usize,
}

impl Clone for BlockRef {
    fn clone(&self) -> Self {
        let mut inner = self.pool.lock().unwrap();
        inner.refcounts[self.block_id] += 1;
        BlockRef {
            pool: Arc::clone(&self.pool),
            block_id: self.block_id,
        }
    }
}

impl Drop for BlockRef {
    fn drop(&mut self) {
        let mut inner = self.pool.lock().unwrap();
        inner.refcounts[self.block_id] -= 1;
        if inner.refcounts[self.block_id] == 0 {
            inner.free.push(self.block_id);
        }
    }
}

impl BlockRef {
    /// True when this is the only live handle to the block — the in-place
    /// write test (the paged-KV analogue of `Arc::get_mut`). Sound against
    /// races: refcounts only grow by cloning an existing handle, so if the
    /// caller holds the single handle nobody else can bump it concurrently.
    pub fn is_unique(&self) -> bool {
        self.pool.lock().unwrap().refcounts[self.block_id] == 1
    }
}

impl std::fmt::Debug for BlockRef {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "BlockRef({})", self.block_id)
    }
}

struct Inner {
    free: Vec<usize>,
    refcounts: Vec<u32>,
}

/// Fixed-capacity pool of KV blocks of `block_tokens` positions each.
pub struct BlockPool {
    inner: Arc<Mutex<Inner>>,
    capacity: usize,
    block_tokens: usize,
}

impl BlockPool {
    pub fn new(capacity: usize, block_tokens: usize) -> Self {
        assert!(block_tokens > 0);
        BlockPool {
            inner: Arc::new(Mutex::new(Inner {
                free: (0..capacity).rev().collect(),
                refcounts: vec![0; capacity],
            })),
            capacity,
            block_tokens,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn free_blocks(&self) -> usize {
        self.inner.lock().unwrap().free.len()
    }

    /// Blocks needed to hold `tokens` positions.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens)
    }

    /// Allocate one block. None when exhausted.
    pub fn alloc(&self) -> Option<BlockRef> {
        let mut inner = self.inner.lock().unwrap();
        let id = inner.free.pop()?;
        debug_assert_eq!(inner.refcounts[id], 0);
        inner.refcounts[id] = 1;
        Some(BlockRef {
            pool: Arc::clone(&self.inner),
            block_id: id,
        })
    }

    /// Allocate a run of blocks for a sequence of `tokens` positions.
    /// All-or-nothing: on shortage, nothing is leaked.
    pub fn alloc_seq(&self, tokens: usize) -> Option<Vec<BlockRef>> {
        let need = self.blocks_for(tokens);
        let mut out = Vec::with_capacity(need);
        for _ in 0..need {
            match self.alloc() {
                Some(b) => out.push(b),
                None => return None, // drops already-allocated refs -> freed
            }
        }
        Some(out)
    }

    /// Diagnostic snapshot of `(free list, refcounts)` — used by the
    /// property tests to assert conservation (free + held == capacity, no
    /// block simultaneously free and referenced) and by arena metrics.
    pub fn snapshot(&self) -> (Vec<usize>, Vec<u32>) {
        let inner = self.inner.lock().unwrap();
        (inner.free.clone(), inner.refcounts.clone())
    }

    /// How many `(block_id, expected_refcount)` pairs match the pool's
    /// current refcounts — one lock acquisition, no state cloning. The
    /// tiered store's reclaimability probe, called once per eviction
    /// under arena pressure, where cloning the whole pool state (as
    /// [`snapshot`](Self::snapshot) does) would churn allocations on the
    /// serving path.
    pub fn count_matching_refs(
        &self,
        pairs: impl Iterator<Item = (usize, u32)>,
    ) -> usize {
        let inner = self.inner.lock().unwrap();
        pairs
            .filter(|&(id, rc)| inner.refcounts.get(id).copied() == Some(rc))
            .count()
    }

    /// Bytes of KV that `n_seqs` sequences of `tokens` positions would
    /// occupy with vs without prefix sharing of `shared_tokens` — the
    /// headline "context capacity expansion" arithmetic used by the
    /// ablation bench and EXPERIMENTS.md.
    pub fn sharing_savings(
        &self,
        n_seqs: usize,
        tokens: usize,
        shared_tokens: usize,
        bytes_per_token: usize,
    ) -> (usize, usize) {
        let unshared = n_seqs * self.blocks_for(tokens);
        let shared = self.blocks_for(shared_tokens)
            + n_seqs * self.blocks_for(tokens.saturating_sub(shared_tokens));
        (
            unshared * self.block_tokens * bytes_per_token,
            shared * self.block_tokens * bytes_per_token,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_cycle() {
        let p = BlockPool::new(4, 16);
        assert_eq!(p.free_blocks(), 4);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_eq!(p.free_blocks(), 2);
        drop(a);
        assert_eq!(p.free_blocks(), 3);
        drop(b);
        assert_eq!(p.free_blocks(), 4);
    }

    #[test]
    fn sharing_keeps_block_alive() {
        let p = BlockPool::new(2, 16);
        let a = p.alloc().unwrap();
        let a2 = a.clone();
        drop(a);
        assert_eq!(p.free_blocks(), 1, "shared block must stay allocated");
        drop(a2);
        assert_eq!(p.free_blocks(), 2);
    }

    #[test]
    fn exhaustion_returns_none() {
        let p = BlockPool::new(1, 16);
        let _a = p.alloc().unwrap();
        assert!(p.alloc().is_none());
    }

    #[test]
    fn alloc_seq_all_or_nothing() {
        let p = BlockPool::new(3, 16);
        assert!(p.alloc_seq(40).is_some()); // 3 blocks, dropped immediately
        assert_eq!(p.free_blocks(), 3);
        let _hold = p.alloc().unwrap();
        assert!(p.alloc_seq(40).is_none()); // needs 3, only 2 free
        assert_eq!(p.free_blocks(), 2, "failed alloc_seq must not leak");
    }

    #[test]
    fn blocks_for_rounding() {
        let p = BlockPool::new(8, 16);
        assert_eq!(p.blocks_for(0), 0);
        assert_eq!(p.blocks_for(1), 1);
        assert_eq!(p.blocks_for(16), 1);
        assert_eq!(p.blocks_for(17), 2);
    }

    #[test]
    fn uniqueness_tracks_sharing() {
        let p = BlockPool::new(2, 16);
        let a = p.alloc().unwrap();
        assert!(a.is_unique());
        let a2 = a.clone();
        assert!(!a.is_unique());
        drop(a2);
        assert!(a.is_unique());
    }

    #[test]
    fn snapshot_is_consistent() {
        let p = BlockPool::new(3, 16);
        let a = p.alloc().unwrap();
        let _a2 = a.clone();
        let _b = p.alloc().unwrap();
        let (free, refs) = p.snapshot();
        assert_eq!(free.len() + refs.iter().filter(|&&c| c > 0).count(), 3);
        for &id in &free {
            assert_eq!(refs[id], 0, "free block {id} still referenced");
        }
        assert_eq!(refs[a.block_id], 2);
    }

    #[test]
    fn sharing_savings_math() {
        let p = BlockPool::new(64, 16);
        // 4 seqs of 64 tokens sharing a 32-token prefix
        let (unshared, shared) = p.sharing_savings(4, 64, 32, 1);
        assert_eq!(unshared, 4 * 4 * 16);
        assert_eq!(shared, (2 + 4 * 2) * 16);
        assert!(shared < unshared);
    }
}
