//! The cold (disk) tier of the tiered KV store.
//!
//! Eviction from the hot (arena-resident) tier no longer destroys a
//! record: [`SpillTier::spill`] serializes it through [`persist`]
//! (CRC-stamped, optionally DEFLATE-compressed) into one file per entry
//! (`{namespace}{id}.kv`), and [`SpillTier::load`] materializes it back
//! into the arena on a later lookup — the paper's "cached KVs are
//! serialized to the CPU, reloaded, and supplied to generate", extended
//! to disk so the cache working set can exceed arena capacity.
//!
//! Several tiers (one per serving worker) may share one `spill_dir`: each
//! gets a distinct filename namespace so per-store entry ids cannot
//! collide on disk, the construction sweep is restricted to the tier's
//! own namespace, and [`SpillTier::foreign_kv_files`] enumerates
//! siblings' records as candidates for cross-worker adoption (spill files
//! are fully self-describing — text, tokens, embedding, payload — so any
//! worker can reload any record).
//!
//! The tier is budgeted by `CacheConfig::max_spill_bytes` over the
//! *physical* serialized (on-disk) sizes and evicts LRU *within the tier*
//! when the budget would overflow; those drops are terminal (the record
//! is gone) and are surfaced through [`SpillTier::take_dropped`] so the
//! owner can unindex them eagerly. Which bytes land on disk is the
//! [`persist::Codec`]'s choice — `V1Raw` / `V1PayloadDeflate` are the
//! legacy format, `V2Deflate` (the `spill_compression` knob) compresses
//! the whole record body so the same physical budget holds proportionally
//! more records. The tier tracks the *logical* (raw-encoding) bytes
//! alongside ([`SpillTier::cold_bytes_logical`]), so the capacity
//! multiplier is observable as `logical / physical`. Corrupt or truncated
//! spill files surface as [`Error::Corrupt`](crate::error::Error) from
//! `persist` — the tier never hands garbage KV to the arena; the caller
//! drops the entry ([`SpillTier::drop_entry`]) and treats the lookup as a
//! miss.
//!
//! A tier owns its directory only when it auto-created one (no
//! `spill_dir` configured): that directory is removed on drop. A
//! user-supplied directory is left in place, files included.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::{Error, Result};
use crate::faults::{FaultHandle, FaultSite};

use super::persist::{self, Codec, RecordParts};
use super::{KvArena, KvGeometry, KvRecord};

/// Does a file stem (e.g. `w0_17`) belong to namespace `ns`? Tier files
/// are exactly `{ns}{id}` with a non-empty all-digit id, so `w0_17` is in
/// `w0_` but `w0_17x`, `w0_` and a sibling's `w1_17` are not. With the
/// legacy empty namespace this is "stem is all digits", which keeps a
/// ""-tier from ever sweeping a namespaced sibling's files.
fn stem_in_namespace(ns: &str, stem: &str) -> bool {
    stem.strip_prefix(ns)
        .is_some_and(|id| !id.is_empty() && id.bytes().all(|b| b.is_ascii_digit()))
}

/// One spilled record's bookkeeping (the payload itself lives on disk).
struct ColdEntry {
    /// Serialized size on disk (what the tier budget accounts).
    bytes: usize,
    /// Bytes a raw (uncompressed v1) encoding of the same record would
    /// take — the logical size `cold_bytes_logical` reports.
    logical: usize,
    /// Token positions of the record — lets a reload pre-size its arena
    /// demand without touching the file.
    tokens: usize,
    /// Spill-time clock tick — LRU order within the tier. A record that
    /// is reloaded and later re-spilled gets a fresh tick.
    spilled_at: u64,
}

/// Disk-backed cold tier: eviction destination for the hot KV store.
pub struct SpillTier {
    dir: PathBuf,
    /// Filename prefix (`{ns}{id}.kv`) giving this tier a private
    /// namespace inside a `spill_dir` shared with sibling stores (one per
    /// serving worker). Empty = legacy single-store naming. The
    /// construction sweep and `drop_entry` only ever touch files in this
    /// namespace, so siblings cannot destroy each other's live records.
    namespace: String,
    /// Remove `dir` on drop (it was auto-created under the OS temp dir).
    owns_dir: bool,
    /// Budget over serialized bytes; > 0 (a zero budget disables the tier
    /// at construction in the store, so it never reaches here).
    max_bytes: usize,
    codec: Codec,
    entries: HashMap<u64, ColdEntry>,
    clock: u64,
    cold_bytes: usize,
    cold_bytes_logical: usize,
    /// Entries destroyed by the tier's own LRU (budget pressure), queued
    /// for the owner to unindex.
    dropped: Vec<u64>,
    drops: u64,
    /// Plan-driven fault seam (inert unless a `FaultPlan` is installed):
    /// `SpillWrite`/`SpillTorn` fire per spill, `SpillRead` per file read,
    /// `SpillSlow` per reload.
    faults: FaultHandle,
}

impl SpillTier {
    /// A tier over an explicit directory (created if missing; kept on
    /// drop), with the legacy empty namespace. Equivalent to
    /// [`with_namespace`](Self::with_namespace) with `namespace = ""`.
    pub fn new(dir: PathBuf, max_bytes: usize, compress: bool) -> Result<Self> {
        Self::with_namespace(dir, String::new(), max_bytes, compress)
    }

    /// A tier over an explicit directory (created if missing; kept on
    /// drop), writing files as `{namespace}{id}.kv`. Pre-existing files
    /// **in this tier's own namespace** are swept at construction: the
    /// tier's in-memory index does not persist across restarts, so such
    /// files are unreachable garbage that would silently escape the byte
    /// budget. Files in *other* namespaces are left alone — a shared
    /// `spill_dir` holds one namespace per live store, and a restarting
    /// worker (same stable namespace) sweeps only its own stale files,
    /// never a sibling's live ones. Cross-restart persistence is
    /// `persist_dir`'s job, not the spill tier's.
    pub fn with_namespace(
        dir: PathBuf,
        namespace: String,
        max_bytes: usize,
        compress: bool,
    ) -> Result<Self> {
        std::fs::create_dir_all(&dir)?;
        if let Ok(rd) = std::fs::read_dir(&dir) {
            for e in rd.flatten() {
                let p = e.path();
                if p.extension().is_some_and(|x| x == "kv" || x == "tmp")
                    && p.file_stem()
                        .and_then(|s| s.to_str())
                        .is_some_and(|s| stem_in_namespace(&namespace, s))
                {
                    let _ = std::fs::remove_file(&p);
                }
            }
        }
        Ok(SpillTier {
            dir,
            namespace,
            owns_dir: false,
            max_bytes,
            codec: Codec::select(false, compress),
            entries: HashMap::new(),
            clock: 0,
            cold_bytes: 0,
            cold_bytes_logical: 0,
            dropped: Vec::new(),
            drops: 0,
            faults: FaultHandle::off(),
        })
    }

    /// Switch the on-disk codec (new spills only; existing files keep
    /// whatever version they were written with — the decoder dispatches
    /// on the per-file version word).
    pub fn set_codec(&mut self, codec: Codec) {
        self.codec = codec;
    }

    pub fn codec(&self) -> Codec {
        self.codec
    }

    /// Attach a fault plan (the `SpillTier` failure-domain seam).
    pub fn set_faults(&mut self, h: FaultHandle) {
        self.faults = h;
    }

    /// A tier over a fresh unique directory under the OS temp dir,
    /// removed (files included) when the tier drops.
    pub fn at_tempdir(max_bytes: usize, compress: bool) -> Result<Self> {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let dir = std::env::temp_dir().join(format!(
            "recycle_spill_{}_{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let mut t = Self::new(dir, max_bytes, compress)?;
        t.owns_dir = true;
        Ok(t)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// This tier's filename namespace ("" = legacy single-store naming).
    pub fn namespace(&self) -> &str {
        &self.namespace
    }

    /// `.kv` files in the shared directory that belong to *other*
    /// namespaces — sibling workers' spilled records, the candidates for
    /// cross-worker adoption. Files this tier owns (its namespace) and
    /// non-tier files are excluded; `.tmp` files are in-flight writes and
    /// never candidates.
    pub fn foreign_kv_files(&self) -> Vec<PathBuf> {
        let Ok(rd) = std::fs::read_dir(&self.dir) else {
            return Vec::new();
        };
        let mut out: Vec<PathBuf> = rd
            .flatten()
            .map(|e| e.path())
            .filter(|p| {
                p.extension().is_some_and(|x| x == "kv")
                    && p.file_stem()
                        .and_then(|s| s.to_str())
                        .is_some_and(|s| !stem_in_namespace(&self.namespace, s))
            })
            .collect();
        out.sort(); // deterministic candidate order
        out
    }

    /// Spilled entries currently resident in the tier.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Physical serialized bytes currently on disk (what `max_spill_bytes`
    /// budgets).
    pub fn cold_bytes(&self) -> usize {
        self.cold_bytes
    }

    /// Logical bytes of the same entries — what a raw (uncompressed v1)
    /// encoding would occupy. `logical / physical` is the cold tier's
    /// capacity multiplier; equal when the codec is `V1Raw`.
    pub fn cold_bytes_logical(&self) -> usize {
        self.cold_bytes_logical
    }

    /// Entries the tier's own LRU has destroyed since construction.
    pub fn drops(&self) -> u64 {
        self.drops
    }

    pub fn contains(&self, id: u64) -> bool {
        self.entries.contains_key(&id)
    }

    /// On-disk serialized size of entry `id` (None if not spilled).
    pub fn bytes_of(&self, id: u64) -> Option<usize> {
        self.entries.get(&id).map(|e| e.bytes)
    }

    /// Token positions of spilled entry `id` (None if not spilled) — the
    /// arena demand of a reload, known without reading the file.
    pub fn tokens_of(&self, id: u64) -> Option<usize> {
        self.entries.get(&id).map(|e| e.tokens)
    }

    /// Drain the ids destroyed by tier-internal LRU eviction since the
    /// last call, so the owner can unindex them eagerly.
    pub fn take_dropped(&mut self) -> Vec<u64> {
        std::mem::take(&mut self.dropped)
    }

    fn path_of(&self, id: u64) -> PathBuf {
        self.dir.join(format!("{}{id}.kv", self.namespace))
    }

    /// Destroy one cold entry (file included). True if it existed.
    pub fn drop_entry(&mut self, id: u64) -> bool {
        match self.entries.remove(&id) {
            Some(e) => {
                self.cold_bytes -= e.bytes;
                self.cold_bytes_logical -= e.logical;
                let _ = std::fs::remove_file(self.path_of(id));
                true
            }
            None => false,
        }
    }

    /// Destroy the LRU cold entry to relieve budget pressure.
    fn evict_lru(&mut self) -> bool {
        let Some(victim) = self
            .entries
            .iter()
            .min_by_key(|(id, e)| (e.spilled_at, **id))
            .map(|(id, _)| *id)
        else {
            return false;
        };
        self.drop_entry(victim);
        self.dropped.push(victim);
        self.drops += 1;
        true
    }

    /// Move a record into the cold tier: serialize (CRC-stamped), make
    /// room by dropping LRU cold entries, and write atomically (temp +
    /// rename). Returns the serialized size. Fails — leaving the tier
    /// unchanged except for LRU drops already applied — when the record
    /// alone exceeds the tier budget or the write fails; the caller then
    /// falls back to destroying the record (the pre-tier behavior).
    pub fn spill(&mut self, id: u64, rec: &KvRecord) -> Result<usize> {
        self.spill_parts(id, &RecordParts::of(rec), rec.kv.geometry())
    }

    /// [`spill`](Self::spill) over pre-gathered record parts — the shared
    /// entry point for hot records (payload gathered from the arena) and
    /// quantized records (payload dequantized on the fly, no arena
    /// needed).
    pub fn spill_parts(
        &mut self,
        id: u64,
        parts: &RecordParts<'_>,
        geom: &KvGeometry,
    ) -> Result<usize> {
        if self.faults.roll(FaultSite::SpillWrite) {
            return Err(Error::Io(std::io::Error::other(
                "injected spill write fault",
            )));
        }
        let mut buf = persist::encode(parts, geom, self.codec);
        if self.faults.roll(FaultSite::SpillTorn) {
            // A torn write persists a prefix of the serialized bytes. The
            // truncation happens BEFORE accounting, so cold_bytes still
            // equals the on-disk size (conservation holds); the damage
            // surfaces at reload time as a CRC failure (`Error::Corrupt`),
            // never as silently wrong KV data.
            buf.truncate(buf.len() / 2);
        }
        if self.max_bytes > 0 && buf.len() > self.max_bytes {
            return Err(Error::Rejected(format!(
                "record of {} serialized bytes exceeds spill budget {}",
                buf.len(),
                self.max_bytes
            )));
        }
        while self.max_bytes > 0 && self.cold_bytes + buf.len() > self.max_bytes {
            if !self.evict_lru() {
                break;
            }
        }
        persist::save_bytes(&self.path_of(id), &buf)?;
        // Re-spilling an id replaces its file; retire the old accounting.
        if let Some(old) = self.entries.remove(&id) {
            self.cold_bytes -= old.bytes;
            self.cold_bytes_logical -= old.logical;
        }
        self.clock += 1;
        let logical = parts.raw_encoded_len();
        self.entries.insert(
            id,
            ColdEntry {
                bytes: buf.len(),
                logical,
                tokens: parts.tokens.len(),
                spilled_at: self.clock,
            },
        );
        self.cold_bytes += buf.len();
        self.cold_bytes_logical += logical;
        Ok(buf.len())
    }

    /// The serialized bytes of spilled entry `id`, read once from disk
    /// (validation happens at decode time, in `persist::from_bytes`).
    /// The entry is untouched — callers retry decoding under arena
    /// pressure without re-reading the file.
    pub fn read(&self, id: u64) -> Result<Vec<u8>> {
        if !self.entries.contains_key(&id) {
            return Err(Error::Corrupt(format!("id {id} not in the spill tier")));
        }
        if self.faults.roll(FaultSite::SpillRead) {
            return Err(Error::Io(std::io::Error::other(
                "injected spill read fault",
            )));
        }
        Ok(std::fs::read(self.path_of(id))?)
    }

    /// Reload a spilled record into `arena`, consuming the cold entry
    /// (file deleted) on success. On failure the entry is left in place —
    /// the caller decides: an `ArenaExhausted` is retryable after
    /// shedding hot records; a `Corrupt`/IO error means the entry is dead
    /// and should be [`drop_entry`](Self::drop_entry)-ed.
    pub fn load(&mut self, id: u64, arena: &KvArena) -> Result<KvRecord> {
        if self.faults.roll(FaultSite::SpillSlow) {
            if let Some(d) = self.faults.slow_step() {
                std::thread::sleep(d);
            }
        }
        let rec = persist::from_bytes(&self.read(id)?, arena)?;
        self.drop_entry(id);
        Ok(rec)
    }
}

impl Drop for SpillTier {
    fn drop(&mut self) {
        if self.owns_dir {
            let _ = std::fs::remove_dir_all(&self.dir);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::kvcache::KvView;

    fn arena() -> KvArena {
        KvArena::new(&ModelConfig::nano(), 16, 256)
    }

    fn rec_in(a: &KvArena, len: usize, tag: u32) -> KvRecord {
        let g = a.geometry();
        let data: Vec<f32> = (0..g.elems_per_token() * len)
            .map(|i| ((i as u32).wrapping_mul(tag) % 101) as f32)
            .collect();
        KvRecord {
            text: format!("t{tag}"),
            tokens: (0..len as u32).map(|t| t + tag).collect(),
            embedding: vec![1.0, tag as f32],
            kv: KvView::from_contiguous(a, &data, len).unwrap(),
        }
    }

    #[test]
    fn spill_load_roundtrip_consumes_entry() {
        let a = arena();
        let mut t = SpillTier::at_tempdir(1 << 20, false).unwrap();
        let r = rec_in(&a, 10, 3);
        let before = r.kv.to_contiguous();
        let n = t.spill(7, &r).unwrap();
        assert!(t.contains(7));
        assert_eq!(t.cold_bytes(), n);
        assert_eq!(t.len(), 1);
        drop(r); // blocks released; reload must re-materialize

        let back = t.load(7, &a).unwrap();
        assert_eq!(back.kv.to_contiguous(), before);
        assert!(!t.contains(7), "load consumes the cold entry");
        assert_eq!(t.cold_bytes(), 0);
        assert!(!t.dir().join("7.kv").exists(), "file deleted on load");
    }

    #[test]
    fn budget_evicts_lru_within_tier() {
        let a = arena();
        let r = rec_in(&a, 8, 1);
        let one = persist::to_bytes(&r, false).len();
        // room for two entries, not three
        let mut t = SpillTier::at_tempdir(2 * one + one / 2, false).unwrap();
        t.spill(1, &rec_in(&a, 8, 1)).unwrap();
        t.spill(2, &rec_in(&a, 8, 2)).unwrap();
        t.spill(3, &rec_in(&a, 8, 3)).unwrap(); // drops 1 (LRU)
        assert!(!t.contains(1) && t.contains(2) && t.contains(3));
        assert_eq!(t.take_dropped(), vec![1]);
        assert_eq!(t.drops(), 1);
        assert!(t.cold_bytes() <= 2 * one + one / 2);
    }

    #[test]
    fn oversized_record_is_rejected_not_stored() {
        let a = arena();
        let mut t = SpillTier::at_tempdir(16, false).unwrap();
        match t.spill(1, &rec_in(&a, 8, 1)) {
            Err(Error::Rejected(_)) => {}
            other => panic!("expected Rejected, got {other:?}"),
        }
        assert_eq!(t.len(), 0);
        assert_eq!(t.cold_bytes(), 0);
    }

    #[test]
    fn corrupt_file_is_a_typed_error_and_entry_survives_until_dropped() {
        let a = arena();
        let mut t = SpillTier::at_tempdir(1 << 20, false).unwrap();
        t.spill(5, &rec_in(&a, 6, 9)).unwrap();
        // bit-flip the file on disk
        let path = t.dir().join("5.kv");
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x20;
        std::fs::write(&path, &bytes).unwrap();
        match t.load(5, &a) {
            Err(Error::Corrupt(_)) => {}
            other => panic!("expected Corrupt, got {other:?}"),
        }
        assert!(t.contains(5), "failed load leaves the entry for the caller");
        assert!(t.drop_entry(5));
        assert!(!path.exists());
    }

    #[test]
    fn injected_write_fault_fails_spill_cleanly() {
        use crate::faults::{FaultPlan, FaultSite};
        let a = arena();
        let mut t = SpillTier::at_tempdir(1 << 20, false).unwrap();
        t.set_faults(FaultPlan::new(1).script(FaultSite::SpillWrite, &[1]).install());
        match t.spill(1, &rec_in(&a, 6, 1)) {
            Err(Error::Io(_)) => {}
            other => panic!("expected Io, got {other:?}"),
        }
        assert_eq!(t.len(), 0, "failed spill leaves the tier unchanged");
        assert_eq!(t.cold_bytes(), 0);
        // the fault was single-shot: the retry lands
        t.spill(1, &rec_in(&a, 6, 1)).unwrap();
        assert!(t.contains(1));
    }

    #[test]
    fn torn_write_keeps_accounting_consistent_and_fails_crc_on_reload() {
        use crate::faults::{FaultPlan, FaultSite};
        let a = arena();
        let mut t = SpillTier::at_tempdir(1 << 20, false).unwrap();
        t.set_faults(FaultPlan::new(2).script(FaultSite::SpillTorn, &[1]).install());
        let n = t.spill(3, &rec_in(&a, 8, 5)).unwrap();
        // cold_bytes equals the truncated on-disk size — conservation holds
        let disk = std::fs::metadata(t.dir().join("3.kv")).unwrap().len() as usize;
        assert_eq!(n, disk);
        assert_eq!(t.cold_bytes(), disk);
        // the damage surfaces as a typed Corrupt at reload, never bad KV
        match t.load(3, &a) {
            Err(Error::Corrupt(_)) => {}
            other => panic!("expected Corrupt, got {other:?}"),
        }
        assert!(t.contains(3), "caller decides to drop the dead entry");
        t.drop_entry(3);
    }

    #[test]
    fn injected_read_fault_is_transient_entry_survives() {
        use crate::faults::{FaultPlan, FaultSite};
        let a = arena();
        let mut t = SpillTier::at_tempdir(1 << 20, false).unwrap();
        t.spill(9, &rec_in(&a, 6, 2)).unwrap();
        t.set_faults(FaultPlan::new(3).script(FaultSite::SpillRead, &[1]).install());
        match t.read(9) {
            Err(e @ Error::Io(_)) => assert!(e.is_transient()),
            other => panic!("expected Io, got {other:?}"),
        }
        assert!(t.contains(9), "read fault must not destroy the entry");
        // next read succeeds — the fault was transient
        assert!(t.read(9).is_ok());
        assert!(t.load(9, &a).is_ok());
    }

    #[test]
    fn tempdir_tier_cleans_up_on_drop() {
        let a = arena();
        let dir;
        {
            let mut t = SpillTier::at_tempdir(1 << 20, true).unwrap();
            t.spill(1, &rec_in(&a, 4, 2)).unwrap();
            dir = t.dir().to_path_buf();
            assert!(dir.exists());
        }
        assert!(!dir.exists(), "owned tempdir must be removed on drop");
    }

    #[test]
    fn stale_spill_files_swept_at_construction() {
        // files from a dead process are unreachable (the index does not
        // persist) and must not silently escape the byte budget
        let dir = std::env::temp_dir().join(format!(
            "recycle_spill_sweep_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("999.kv"), b"stale").unwrap();
        std::fs::write(dir.join("7.tmp"), b"partial write").unwrap();
        std::fs::write(dir.join("keep.txt"), b"other").unwrap();
        let t = SpillTier::new(dir.clone(), 1 << 20, false).unwrap();
        assert!(!dir.join("999.kv").exists());
        assert!(!dir.join("7.tmp").exists());
        assert!(dir.join("keep.txt").exists(), "non-tier files untouched");
        assert_eq!(t.cold_bytes(), 0);
        drop(t);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn two_namespaced_tiers_share_a_dir_without_sweeping_each_other() {
        // THE shared-spill regression: worker B constructing its tier in a
        // spill_dir worker A is already using must not delete A's live
        // files (and vice versa on a later reconstruction) — only stale
        // files in a tier's OWN namespace are swept.
        let a = arena();
        let dir = std::env::temp_dir().join(format!(
            "recycle_spill_shared_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        let mut t0 =
            SpillTier::with_namespace(dir.clone(), "w0_".into(), 1 << 20, false).unwrap();
        t0.spill(5, &rec_in(&a, 6, 1)).unwrap();
        assert!(dir.join("w0_5.kv").exists());

        // stale garbage in w1_'s namespace from a dead run
        std::fs::write(dir.join("w1_999.kv"), b"stale").unwrap();
        let mut t1 =
            SpillTier::with_namespace(dir.clone(), "w1_".into(), 1 << 20, false).unwrap();
        assert!(
            dir.join("w0_5.kv").exists(),
            "sibling construction must not sweep w0's live file"
        );
        assert!(
            !dir.join("w1_999.kv").exists(),
            "own stale file is swept"
        );
        t1.spill(5, &rec_in(&a, 6, 2)).unwrap();
        assert!(
            dir.join("w0_5.kv").exists() && dir.join("w1_5.kv").exists(),
            "same entry id maps to distinct per-namespace files"
        );

        // both records load back intact under the colliding id
        let r0 = t0.load(5, &a).unwrap();
        let r1 = t1.load(5, &a).unwrap();
        assert_eq!(r0.text, "t1");
        assert_eq!(r1.text, "t2");

        // a ""-namespace tier in the same dir cannot sweep namespaced files
        t0.spill(6, &rec_in(&a, 4, 3)).unwrap();
        let legacy = SpillTier::new(dir.clone(), 1 << 20, false).unwrap();
        assert!(
            dir.join("w0_6.kv").exists(),
            "legacy empty-namespace sweep is digits-only"
        );
        drop(legacy);
        drop(t0);
        drop(t1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn foreign_kv_files_lists_only_sibling_namespaces() {
        let a = arena();
        let dir = std::env::temp_dir().join(format!(
            "recycle_spill_foreign_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        let mut t0 =
            SpillTier::with_namespace(dir.clone(), "w0_".into(), 1 << 20, false).unwrap();
        let mut t1 =
            SpillTier::with_namespace(dir.clone(), "w1_".into(), 1 << 20, false).unwrap();
        t0.spill(1, &rec_in(&a, 4, 1)).unwrap();
        t1.spill(2, &rec_in(&a, 4, 2)).unwrap();
        std::fs::write(dir.join("notes.txt"), b"x").unwrap();
        let foreign0 = t0.foreign_kv_files();
        assert_eq!(foreign0, vec![dir.join("w1_2.kv")]);
        let foreign1 = t1.foreign_kv_files();
        assert_eq!(foreign1, vec![dir.join("w0_1.kv")]);
        drop(t0);
        drop(t1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v2_codec_accounts_logical_above_physical_and_reloads() {
        let a = arena();
        let mut t = SpillTier::at_tempdir(1 << 20, false).unwrap();
        t.set_codec(Codec::V2Deflate);
        let r = rec_in(&a, 12, 4);
        let before = r.kv.to_contiguous();
        let physical = t.spill(1, &r).unwrap();
        let logical = persist::to_bytes(&r, false).len();
        assert_eq!(t.cold_bytes(), physical);
        assert_eq!(t.cold_bytes_logical(), logical);
        assert!(
            physical < logical,
            "whole-body deflate must shrink the file: {physical} !< {logical}"
        );
        let disk = std::fs::metadata(t.dir().join("1.kv")).unwrap().len() as usize;
        assert_eq!(disk, physical, "budget must track the *physical* file size");
        let back = t.load(1, &a).unwrap();
        assert_eq!(back.kv.to_contiguous(), before);
        assert_eq!(t.cold_bytes(), 0);
        assert_eq!(t.cold_bytes_logical(), 0);
    }

    #[test]
    fn v2_corrupt_file_degrades_to_typed_corrupt() {
        let a = arena();
        let mut t = SpillTier::at_tempdir(1 << 20, false).unwrap();
        t.set_codec(Codec::V2Deflate);
        t.spill(5, &rec_in(&a, 6, 9)).unwrap();
        let path = t.dir().join("5.kv");
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x20;
        std::fs::write(&path, &bytes).unwrap();
        match t.load(5, &a) {
            Err(Error::Corrupt(_)) => {}
            other => panic!("expected Corrupt, got {other:?}"),
        }
        assert!(t.contains(5), "failed load leaves the entry for the caller");
        t.drop_entry(5);
    }

    #[test]
    fn legacy_files_reload_through_a_v2_tier() {
        // codec switches only affect NEW spills — a file written raw is
        // still loadable after the tier flips to the v2 codec, because the
        // decoder dispatches on the per-file version word
        let a = arena();
        let mut t = SpillTier::at_tempdir(1 << 20, false).unwrap();
        let r = rec_in(&a, 8, 7);
        let before = r.kv.to_contiguous();
        t.spill(3, &r).unwrap(); // raw v1
        t.set_codec(Codec::V2Deflate);
        let back = t.load(3, &a).unwrap();
        assert_eq!(back.kv.to_contiguous(), before);
    }

    #[test]
    fn explicit_dir_is_kept_on_drop() {
        let a = arena();
        let dir = std::env::temp_dir().join(format!(
            "recycle_spill_keep_{}",
            std::process::id()
        ));
        {
            let mut t = SpillTier::new(dir.clone(), 1 << 20, false).unwrap();
            t.spill(1, &rec_in(&a, 4, 2)).unwrap();
        }
        assert!(dir.exists(), "caller-owned dir survives the tier");
        std::fs::remove_dir_all(&dir).ok();
    }
}
