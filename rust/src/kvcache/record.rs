//! One cached prompt's activations.

use std::sync::Arc;

use crate::config::ModelConfig;

/// A cached KV entry: the paper's `C[i] = (c_i, input_ids(c_i), {K_l, V_l})`.
///
/// The KV payload is stored *trimmed*: only `token_len` positions per layer
/// (`[L, 2, H, token_len, D]`, row-major), not the full context window —
/// this is what makes the cache footprint proportional to what was actually
/// computed. The engine re-inflates into the runtime's `[L, 2, H, S, D]`
/// buffer on injection.
#[derive(Debug, Clone)]
pub struct KvRecord {
    /// The cached prompt text (`c_i`).
    pub text: String,
    /// `input_ids(c_i)`.
    pub tokens: Vec<u32>,
    /// L2-normalized sentence embedding (`e_i`).
    pub embedding: Vec<f32>,
    /// Trimmed KV payload, `[L, 2, H, token_len, D]` row-major f32.
    /// Arc so cache hits hand out views without copying the tensor.
    pub kv: Arc<Vec<f32>>,
    /// Geometry the payload was produced under (guards against serving a
    /// cache built for a different model).
    pub n_layer: usize,
    pub n_head: usize,
    pub head_dim: usize,
}

impl KvRecord {
    /// Number of cached prefix positions (the paper's reuse depth `k` when
    /// this entry fully matches).
    pub fn token_len(&self) -> usize {
        self.tokens.len()
    }

    /// Bytes of the trimmed payload.
    pub fn kv_bytes(&self) -> usize {
        self.kv.len() * 4
    }

    /// Expected payload element count for the geometry.
    pub fn expected_elems(&self) -> usize {
        self.n_layer * 2 * self.n_head * self.token_len() * self.head_dim
    }

    /// Check payload/geometry consistency and compatibility with `cfg`.
    pub fn validate(&self, cfg: &ModelConfig) -> bool {
        self.kv.len() == self.expected_elems()
            && self.n_layer == cfg.n_layer
            && self.n_head == cfg.n_head
            && self.head_dim == cfg.head_dim
            && self.token_len() <= cfg.max_seq
            && self.embedding.len() > 0
    }

    /// Build a record from a *full* `[L, 2, H, S, D]` runtime buffer by
    /// trimming to the first `len` positions.
    pub fn from_full_buffer(
        cfg: &ModelConfig,
        text: &str,
        tokens: Vec<u32>,
        embedding: Vec<f32>,
        full: &[f32],
    ) -> Self {
        let len = tokens.len();
        let [l, two, h, s, d] = cfg.kv_shape();
        debug_assert_eq!(full.len(), l * two * h * s * d);
        let mut kv = Vec::with_capacity(l * two * h * len * d);
        for li in 0..l {
            for kvi in 0..two {
                for hi in 0..h {
                    let base = ((li * two + kvi) * h + hi) * s * d;
                    kv.extend_from_slice(&full[base..base + len * d]);
                }
            }
        }
        KvRecord {
            text: text.to_string(),
            tokens,
            embedding,
            kv: Arc::new(kv),
            n_layer: l,
            n_head: h,
            head_dim: d,
        }
    }

    /// Inflate the trimmed payload back into a full `[L, 2, H, S, D]`
    /// buffer (zero beyond `token_len`). Inverse of [`from_full_buffer`].
    pub fn to_full_buffer(&self, cfg: &ModelConfig) -> Vec<f32> {
        let [l, two, h, s, d] = cfg.kv_shape();
        let len = self.token_len();
        let mut full = vec![0f32; l * two * h * s * d];
        for li in 0..l {
            for kvi in 0..two {
                for hi in 0..h {
                    let src = ((li * two + kvi) * h + hi) * len * d;
                    let dst = ((li * two + kvi) * h + hi) * s * d;
                    full[dst..dst + len * d]
                        .copy_from_slice(&self.kv[src..src + len * d]);
                }
            }
        }
        full
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig::nano()
    }

    fn fake_full(cfg: &ModelConfig) -> Vec<f32> {
        (0..cfg.kv_elems()).map(|i| i as f32).collect()
    }

    #[test]
    fn trim_inflate_roundtrip() {
        let cfg = cfg();
        let full = fake_full(&cfg);
        let tokens: Vec<u32> = (0..10).collect();
        let rec = KvRecord::from_full_buffer(&cfg, "p", tokens, vec![1.0], &full);
        assert!(rec.validate(&cfg));
        assert_eq!(rec.kv_bytes(), cfg.kv_bytes_for_len(10));
        let inflated = rec.to_full_buffer(&cfg);
        // live rows match the original
        let [l, two, h, s, d] = cfg.kv_shape();
        for li in 0..l {
            for kvi in 0..two {
                for hi in 0..h {
                    let base = ((li * two + kvi) * h + hi) * s * d;
                    assert_eq!(&inflated[base..base + 10 * d], &full[base..base + 10 * d]);
                    // dead rows are zero
                    assert!(inflated[base + 10 * d..base + s * d].iter().all(|&x| x == 0.0));
                }
            }
        }
    }

    #[test]
    fn validate_rejects_wrong_geometry() {
        let cfg = cfg();
        let full = fake_full(&cfg);
        let mut rec =
            KvRecord::from_full_buffer(&cfg, "p", vec![1, 2, 3], vec![1.0], &full);
        assert!(rec.validate(&cfg));
        rec.n_head = 2;
        assert!(!rec.validate(&cfg));
    }

    #[test]
    fn validate_rejects_truncated_payload() {
        let cfg = cfg();
        let full = fake_full(&cfg);
        let mut rec =
            KvRecord::from_full_buffer(&cfg, "p", vec![1, 2, 3], vec![1.0], &full);
        rec.kv = Arc::new(vec![0.0; 5]);
        assert!(!rec.validate(&cfg));
    }

    #[test]
    fn zero_len_record() {
        let cfg = cfg();
        let full = fake_full(&cfg);
        let rec = KvRecord::from_full_buffer(&cfg, "", vec![], vec![1.0], &full);
        assert_eq!(rec.kv_bytes(), 0);
        assert!(rec.validate(&cfg));
    }
}
