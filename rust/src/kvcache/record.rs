//! One cached prompt's activations.

use crate::config::ModelConfig;
use crate::error::Result;

use super::arena::{KvArena, KvView, QuantKv};
use super::persist::RecordParts;

/// A cached KV entry: the paper's `C[i] = (c_i, input_ids(c_i), {K_l, V_l})`.
///
/// The KV payload is a *paged view*: exactly `token_len` positions over
/// shared arena blocks (`[L, 2, H, token_len, D]` logically). A cache hit
/// attaches the entry by cloning the block table — one refcount bump per
/// block — instead of inflating a dense context-window buffer; the serving
/// path then extends the view copy-on-write. Cloning the record itself is
/// likewise O(blocks).
#[derive(Debug, Clone)]
pub struct KvRecord {
    /// The cached prompt text (`c_i`).
    pub text: String,
    /// `input_ids(c_i)`.
    pub tokens: Vec<u32>,
    /// L2-normalized sentence embedding (`e_i`).
    pub embedding: Vec<f32>,
    /// Paged KV payload; `kv.len() == tokens.len()`.
    pub kv: KvView,
}

impl KvRecord {
    /// Number of cached prefix positions (the paper's reuse depth `k` when
    /// this entry fully matches).
    pub fn token_len(&self) -> usize {
        self.tokens.len()
    }

    /// Logical bytes of the trimmed payload (what the store accounts; the
    /// *physical* footprint can be smaller when blocks are shared).
    pub fn kv_bytes(&self) -> usize {
        self.kv.geometry().bytes_per_token() * self.token_len()
    }

    /// Blocks in the payload's table (the attach cost is O(this)).
    pub fn kv_blocks(&self) -> usize {
        self.kv.num_blocks()
    }

    /// Blocks this record is the sole holder of — the blocks an eviction
    /// of this record *actually* returns to the arena (shared prefix
    /// blocks and blocks pinned by in-flight views are excluded). This is
    /// the physical eviction yield the tiered store reports.
    pub fn unique_blocks(&self) -> usize {
        self.kv.unique_blocks()
    }

    /// Bytes one arena block of this record's geometry occupies (the unit
    /// of physical accounting).
    pub fn block_bytes(&self) -> usize {
        let g = self.kv.geometry();
        g.bytes_per_token() * g.block_tokens
    }

    /// Check payload/geometry consistency and compatibility with `cfg`.
    pub fn validate(&self, cfg: &ModelConfig) -> bool {
        self.kv.len() == self.token_len()
            && self.kv.geometry().matches(cfg)
            && self.token_len() <= cfg.max_seq
            && !self.embedding.is_empty()
    }

    /// Build a record by *sharing* a served request's view: clones the
    /// block table and trims to `tokens.len()` positions (dropping whole
    /// blocks past the boundary). No tensor copy — this is how online
    /// population shares prefix blocks with the request that produced them.
    pub fn from_view(text: &str, tokens: Vec<u32>, embedding: Vec<f32>, view: &KvView) -> Self {
        debug_assert!(view.len() >= tokens.len(), "view shorter than tokens");
        let mut kv = view.clone();
        kv.truncate(tokens.len());
        KvRecord {
            text: text.to_string(),
            tokens,
            embedding,
            kv,
        }
    }

    /// Zero-copy injection: a shared view over this record's blocks, ready
    /// to be extended copy-on-write by the engine.
    pub fn attach(&self) -> KvView {
        self.kv.clone()
    }

    /// Fixed-stride segment spans `[start, end)` over this record's
    /// tokens — the indexing grain of the segment tier (see `recycler`).
    /// Only full-stride spans are produced: a trailing fragment shorter
    /// than `stride` is not worth a segment entry (the exact-prefix path
    /// already covers offset-0 reuse, and a re-anchor shorter than the
    /// stride rarely beats recompute). `stride == 0` means segmenting is
    /// off. Spans are computed, not stored: the record's persisted form
    /// (spill tier, disk cache) is unchanged, and a different stride after
    /// a config change simply re-derives them.
    pub fn segment_spans(&self, stride: usize) -> Vec<(usize, usize)> {
        if stride == 0 {
            return Vec::new();
        }
        (0..self.tokens.len() / stride)
            .map(|i| (i * stride, (i + 1) * stride))
            .collect()
    }
}

/// A cached entry whose payload lives in quantized form (see [`QuantKv`])
/// instead of arena blocks — the resident format of the hot tier when
/// `CacheConfig::quantized_blocks` is on. Holds zero arena blocks; a hit
/// materializes a fresh [`KvRecord`] (dequantize + scatter), an eviction
/// spills through [`RecordParts`] without ever touching the arena.
#[derive(Debug)]
pub struct QuantRecord {
    pub text: String,
    pub tokens: Vec<u32>,
    pub embedding: Vec<f32>,
    pub quant: QuantKv,
}

impl QuantRecord {
    /// Quantize a hot record's payload (the record itself is untouched —
    /// the caller drops it to release its blocks).
    pub fn from_record(rec: &KvRecord) -> QuantRecord {
        QuantRecord {
            text: rec.text.clone(),
            tokens: rec.tokens.clone(),
            embedding: rec.embedding.clone(),
            quant: QuantKv::from_view(&rec.kv),
        }
    }

    pub fn token_len(&self) -> usize {
        self.tokens.len()
    }

    /// Logical payload bytes — what the same entry would occupy as f32
    /// arena rows (the store's capacity comparison unit).
    pub fn kv_bytes(&self) -> usize {
        self.quant.logical_bytes()
    }

    /// Physical bytes actually held by the quantized payload.
    pub fn quant_bytes(&self) -> usize {
        self.quant.quant_bytes()
    }

    /// Quantized blocks held (the `CacheStats::quantized_blocks` unit).
    pub fn kv_blocks(&self) -> usize {
        self.quant.num_blocks()
    }

    /// Dequantize back into a hot record over `arena` blocks (the attach
    /// path). `ArenaExhausted` is transient: callers shed and retry,
    /// exactly like a spill reload.
    pub fn materialize(&self, arena: &KvArena) -> Result<KvRecord> {
        Ok(KvRecord {
            text: self.text.clone(),
            tokens: self.tokens.clone(),
            embedding: self.embedding.clone(),
            kv: self.quant.materialize(arena)?,
        })
    }

    /// Serializable parts for the spill encoder — payload dequantized on
    /// the fly, no arena involved, so a quantized entry can spill even
    /// under total block exhaustion.
    pub fn parts(&self) -> RecordParts<'_> {
        RecordParts {
            text: &self.text,
            tokens: &self.tokens,
            embedding: &self.embedding,
            payload: self.quant.to_f32(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::KvArena;

    fn cfg() -> ModelConfig {
        ModelConfig::nano()
    }

    fn arena() -> KvArena {
        KvArena::new(&cfg(), 8, 64)
    }

    fn view_of(a: &KvArena, len: usize) -> KvView {
        let g = a.geometry();
        let data: Vec<f32> = (0..g.elems_per_token() * len).map(|i| i as f32).collect();
        KvView::from_contiguous(a, &data, len).unwrap()
    }

    #[test]
    fn from_view_shares_and_trims() {
        let a = arena();
        let v = view_of(&a, 20); // 3 blocks of 8
        let used = a.used_blocks();
        let tokens: Vec<u32> = (0..10).collect();
        let rec = KvRecord::from_view("p", tokens, vec![1.0], &v);
        assert!(rec.validate(&cfg()));
        assert_eq!(rec.kv.len(), 10);
        assert_eq!(rec.kv_blocks(), 2, "trimmed to ceil(10/8) blocks");
        // sharing, not copying: no new blocks were allocated
        assert_eq!(a.used_blocks(), used);
        assert_eq!(rec.kv.block_ids(), v.block_ids()[..2].to_vec());
        // logical bytes track token_len
        assert_eq!(rec.kv_bytes(), cfg().kv_bytes_for_len(10));
    }

    #[test]
    fn attach_is_zero_copy_and_cow_isolated() {
        let a = arena();
        let v = view_of(&a, 10);
        let rec = KvRecord::from_view("p", (0..10).collect(), vec![1.0], &v);
        drop(v);
        let before = rec.kv.to_contiguous();
        let used = a.used_blocks();
        let mut attached = rec.attach();
        assert_eq!(a.used_blocks(), used, "attach allocates nothing");
        // extending the attached view COWs; the record is untouched
        attached.row_mut(0, 0, 0, 10).unwrap()[0] = 7.0;
        attached.commit(11);
        assert_eq!(rec.kv.to_contiguous(), before);
    }

    #[test]
    fn validate_rejects_wrong_geometry() {
        let a = arena();
        let v = view_of(&a, 3);
        let rec = KvRecord::from_view("p", vec![1, 2, 3], vec![1.0], &v);
        assert!(rec.validate(&cfg()));
        let mut other = cfg();
        other.n_head = 2;
        other.head_dim = 64;
        assert!(!rec.validate(&other));
    }

    #[test]
    fn validate_rejects_truncated_payload() {
        let a = arena();
        let v = view_of(&a, 3);
        let mut rec = KvRecord::from_view("p", vec![1, 2, 3], vec![1.0], &v);
        rec.kv.truncate(1); // payload now shorter than the token list
        assert!(!rec.validate(&cfg()));
    }

    #[test]
    fn segment_spans_cover_full_strides_only() {
        let a = arena();
        let v = view_of(&a, 22);
        let rec = KvRecord::from_view("p", (0..22).collect(), vec![1.0], &v);
        assert_eq!(rec.segment_spans(8), vec![(0, 8), (8, 16)]);
        assert_eq!(rec.segment_spans(22), vec![(0, 22)]);
        assert_eq!(rec.segment_spans(23), Vec::<(usize, usize)>::new());
        assert_eq!(rec.segment_spans(0), Vec::<(usize, usize)>::new());
        assert_eq!(rec.segment_spans(1).len(), 22);
    }

    #[test]
    fn zero_len_record() {
        let a = arena();
        let v = a.new_view();
        let rec = KvRecord::from_view("", vec![], vec![1.0], &v);
        assert_eq!(rec.kv_bytes(), 0);
        assert_eq!(rec.kv_blocks(), 0);
        assert!(rec.validate(&cfg()));
    }

    #[test]
    fn quant_record_roundtrips_and_frees_blocks() {
        let a = arena();
        let g = a.geometry().clone();
        // integer rows |v| <= 127 -> exact under power-of-two scales
        let data: Vec<f32> = (0..g.elems_per_token() * 10)
            .map(|i| (i % 101) as f32)
            .collect();
        let v = KvView::from_contiguous(&a, &data, 10).unwrap();
        let rec = KvRecord::from_view("p", (0..10).collect(), vec![1.0], &v);
        drop(v);
        let q = QuantRecord::from_record(&rec);
        let flat = rec.kv.to_contiguous();
        drop(rec);
        assert_eq!(a.used_blocks(), 0, "quantized record must pin no blocks");
        assert!(q.quant_bytes() * 3 < q.kv_bytes());
        assert_eq!(q.token_len(), 10);
        let back = q.materialize(&a).unwrap();
        assert!(back.validate(&cfg()));
        assert_eq!(back.text, "p");
        assert_eq!(back.tokens, (0..10).collect::<Vec<u32>>());
        assert_eq!(back.kv.to_contiguous(), flat);
    }

    #[test]
    fn quant_record_parts_encode_without_arena() {
        let a = arena();
        let v = view_of(&a, 6);
        let rec = KvRecord::from_view("doc", (0..6).collect(), vec![0.5], &v);
        let q = QuantRecord::from_record(&rec);
        let parts = q.parts();
        assert_eq!(parts.text, "doc");
        assert_eq!(parts.tokens.len(), 6);
        assert_eq!(parts.payload.len(), a.geometry().elems_per_token() * 6);
        assert!(parts.raw_encoded_len() > 0);
    }
}
