//! The cross-prompt KV cache — the paper's central data structure, grown
//! into a **tiered store with physical accounting**.
//!
//! Layering, hot to cold:
//!
//! * [`arena`] — the paged substrate: one [`KvArena`] slab carved into
//!   refcounted token blocks, with [`KvView`] presenting a logical
//!   `[L, 2, H, len, D]` sequence over a block table. Cache injection is a
//!   block-table clone (refcount bumps), not a tensor copy.
//! * [`blocks`] — the PagedAttention-inspired refcounted block pool the
//!   arena allocates from; prefix *sharing* between entries falls out of
//!   block refcounts (the paper's future-work direction, now the hot path).
//! * [`KvRecord`] — one cached prompt: token ids, embedding, and the
//!   *paged* per-layer K/V for exactly `token_len` positions, i.e. the
//!   paper's `C[i] = (c_i, input_ids(c_i), {K_l, V_l})`.
//! * [`KvStore`] — the **hot tier**: capacity-bounded by *shared-aware
//!   physical footprint* (distinct arena blocks held by entries, counted
//!   once however many entries share them — never logical trimmed bytes),
//!   with pluggable eviction (LRU / LFU / FIFO / cost-aware) and hit/miss
//!   accounting in [`CacheStats`]. An [`Eviction`] reports the blocks it
//!   *actually* returns to the arena (the victim's uniquely-held blocks),
//!   so callers can reason about real headroom instead of guessing.
//! * [`tier`] — the **cold tier**: eviction's destination. Under memory
//!   pressure a hot record is *spilled* (serialized via [`persist`],
//!   CRC-stamped, budgeted by `CacheConfig::max_spill_bytes`, LRU within
//!   the tier) instead of destroyed; index/radix entries survive the
//!   spill, and a later lookup transparently reloads the record into the
//!   arena ([`KvStore::reload_spilled`]) — counted as a `spill_hit` with
//!   its reload latency. This is the paper's "cached KVs are serialized
//!   to the CPU, reloaded, and supplied to generate", extended so the
//!   cache working set can exceed arena capacity.
//! * [`persist`] — torch.save's stand-in: a checksummed binary file format
//!   with optional DEFLATE compression. Corrupt or truncated files are
//!   rejected with a typed error (`Error::Corrupt`) — a bad spill file
//!   degrades to a cache miss, never to garbage KV in the arena.
//!
//! Conservation across the tiers (property-tested in
//! `rust/tests/properties.rs`): arena blocks satisfy `free +
//! hot-referenced == capacity` at every step — spilled entries hold
//! *zero* arena blocks, their bytes accounted instead as the tier's
//! `cold_bytes` — and after any eviction the arena's free count grows by
//! exactly the eviction's reported unique-block footprint.

pub mod arena;
pub mod blocks;
pub mod persist;
mod record;
mod store;
pub mod tier;

pub use arena::{KvArena, KvGeometry, KvView, DEFAULT_BLOCK_TOKENS};
pub use blocks::{BlockPool, BlockRef};
pub use record::KvRecord;
pub use store::{CacheStats, Eviction, KvStore};
pub use tier::SpillTier;
