//! The cross-prompt KV cache — the paper's central data structure, grown
//! into a **tiered store with physical accounting**.
//!
//! Layering, hot to cold:
//!
//! * [`arena`] — the paged substrate: one [`KvArena`] slab carved into
//!   refcounted token blocks, with [`KvView`] presenting a logical
//!   `[L, 2, H, len, D]` sequence over a block table. Cache injection is a
//!   block-table clone (refcount bumps), not a tensor copy.
//! * [`blocks`] — the PagedAttention-inspired refcounted block pool the
//!   arena allocates from; prefix *sharing* between entries falls out of
//!   block refcounts (the paper's future-work direction, now the hot path).
//! * [`KvRecord`] — one cached prompt: token ids, embedding, and the
//!   *paged* per-layer K/V for exactly `token_len` positions, i.e. the
//!   paper's `C[i] = (c_i, input_ids(c_i), {K_l, V_l})`.
//! * [`KvStore`] — the **hot tier**: capacity-bounded by *shared-aware
//!   physical footprint* (distinct arena blocks held by entries, counted
//!   once however many entries share them — never logical trimmed bytes),
//!   with pluggable eviction (LRU / LFU / FIFO / cost-aware) and hit/miss
//!   accounting in [`CacheStats`]. An [`Eviction`] reports the blocks it
//!   *actually* returns to the arena (the victim's uniquely-held blocks),
//!   so callers can reason about real headroom instead of guessing.
//!
//!   The hot tier has **two resident formats**. The default keeps each
//!   entry's payload in f32 arena blocks, shared COW with in-flight
//!   requests. With `CacheConfig::quantized_blocks` on, entries instead
//!   rest as [`QuantRecord`]s — 8-bit rows ([`QuantBlock`]) under
//!   per-block power-of-two scales, holding **zero** arena blocks — and
//!   `max_bytes` budgets their ~4x-smaller quantized footprint
//!   (`CacheStats::quantized_bytes`), multiplying how many entries one
//!   budget admits. A hit dequantizes into a fresh arena-backed record
//!   on attach; eviction spills through the dequantized parts without
//!   touching the arena. With the knob off the store is byte-identical
//!   to the pure-f32 path (property-pinned).
//! * [`tier`] — the **cold tier**: eviction's destination. Under memory
//!   pressure a hot record is *spilled* (serialized via [`persist`],
//!   CRC-stamped, budgeted by `CacheConfig::max_spill_bytes`, LRU within
//!   the tier) instead of destroyed; index/radix entries survive the
//!   spill, and a later lookup transparently reloads the record into the
//!   arena ([`KvStore::reload_spilled`]) — counted as a `spill_hit` with
//!   its reload latency (clocked from the disk read, so decompress time
//!   is inside and other records' shed costs are not). This is the
//!   paper's "cached KVs are serialized to the CPU, reloaded, and
//!   supplied to generate", extended so the cache working set can exceed
//!   arena capacity.
//!
//!   The cold tier also has **two codecs**. The legacy v1 format stores
//!   the record raw (optionally with a payload-only DEFLATE under the
//!   old `compress` knob); with `CacheConfig::spill_compression` on, new
//!   spills use the v2 format — the whole record body DEFLATE-compressed
//!   behind a versioned header — so `max_spill_bytes` budgets *physical*
//!   compressed bytes and holds correspondingly more records. The tier
//!   tracks both meters: `cold_bytes` (physical, the budget unit) and
//!   `cold_bytes_logical` (what the same entries would occupy raw);
//!   their ratio is the compression capacity multiplier. Decoding
//!   dispatches on each file's version word, so a tier switched to v2
//!   still reloads its legacy raw files bit-identically.
//! * [`persist`] — torch.save's stand-in: a checksummed binary file
//!   format, versioned v1 (raw / payload-compressed) and v2
//!   (whole-body compressed). Corrupt or truncated files of either
//!   version are rejected with a typed error (`Error::Corrupt`) — a bad
//!   spill file degrades to a cache miss, never to garbage KV in the
//!   arena.
//!
//! Conservation across the tiers (property-tested in
//! `rust/tests/properties.rs`): arena blocks satisfy `free +
//! hot-referenced == capacity` at every step — spilled entries hold
//! *zero* arena blocks, their bytes accounted instead as the tier's
//! physical `cold_bytes` (which equals the summed on-disk file sizes
//! under either codec) — and after any eviction the arena's free count
//! grows by exactly the eviction's reported unique-block footprint.

pub mod arena;
pub mod blocks;
pub mod persist;
mod record;
mod store;
pub mod tier;

pub use arena::{KvArena, KvGeometry, KvView, QuantKv, DEFAULT_BLOCK_TOKENS};
pub use blocks::{BlockPool, BlockRef, QuantBlock};
pub use persist::{Codec, RecordParts};
pub use record::{KvRecord, QuantRecord};
pub use store::{CacheStats, Eviction, KvStore};
pub use tier::SpillTier;
