//! The cross-prompt KV cache — the paper's central data structure.
//!
//! * [`KvRecord`] — one cached prompt: token ids, embedding, and the
//!   *trimmed* per-layer K/V tensors for exactly `token_len` positions
//!   (`[L, 2, H, len, D]`), i.e. the paper's
//!   `C[i] = (c_i, input_ids(c_i), {K_l, V_l})`.
//! * [`KvStore`] — capacity-bounded store with pluggable eviction
//!   (LRU / LFU / FIFO / cost-aware) and hit/miss accounting.
//! * [`persist`] — torch.save's stand-in: a checksummed binary file format
//!   with optional DEFLATE compression, so caches survive restarts and can
//!   overflow to disk.
//! * [`blocks`] — a PagedAttention-inspired block pool: fixed-size token
//!   blocks with reference counting, enabling prefix *sharing* between
//!   entries (the paper's future-work direction; exercised by the radix
//!   policy and the ablation benches).

pub mod blocks;
pub mod persist;
mod record;
mod store;

pub use blocks::{BlockPool, BlockRef};
pub use record::KvRecord;
pub use store::{KvStore, StoreStats};
