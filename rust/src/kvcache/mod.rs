//! The cross-prompt KV cache — the paper's central data structure.
//!
//! * [`arena`] — the paged substrate: one [`KvArena`] slab carved into
//!   refcounted token blocks, with [`KvView`] presenting a logical
//!   `[L, 2, H, len, D]` sequence over a block table. Cache injection is a
//!   block-table clone (refcount bumps), not a tensor copy.
//! * [`KvRecord`] — one cached prompt: token ids, embedding, and the
//!   *paged* per-layer K/V for exactly `token_len` positions, i.e. the
//!   paper's `C[i] = (c_i, input_ids(c_i), {K_l, V_l})`.
//! * [`KvStore`] — capacity-bounded store with pluggable eviction
//!   (LRU / LFU / FIFO / cost-aware) and hit/miss accounting.
//! * [`persist`] — torch.save's stand-in: a checksummed binary file format
//!   with optional DEFLATE compression, so caches survive restarts and can
//!   overflow to disk.
//! * [`blocks`] — the PagedAttention-inspired refcounted block pool the
//!   arena allocates from; prefix *sharing* between entries falls out of
//!   block refcounts (the paper's future-work direction, now the hot path).

pub mod arena;
pub mod blocks;
pub mod persist;
mod record;
mod store;

pub use arena::{KvArena, KvGeometry, KvView, DEFAULT_BLOCK_TOKENS};
pub use blocks::{BlockPool, BlockRef};
pub use record::KvRecord;
pub use store::{KvStore, StoreStats};
