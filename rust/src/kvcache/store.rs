//! Capacity-bounded KV store with pluggable eviction.
//!
//! The paper appends cache entries without bound (10 prompts); a serving
//! system needs bounded memory, so entries are accounted by trimmed KV
//! bytes and evicted by policy when either `max_entries` or `max_bytes`
//! would be exceeded. Invariants (property-tested in testutil):
//!
//!  * live bytes == sum of entry bytes,
//!  * capacity never exceeded after any insert,
//!  * a hit refreshes recency (LRU) and bumps frequency (LFU),
//!  * eviction order respects the policy.

use std::collections::HashMap;
use std::sync::Arc;

use crate::config::{CacheConfig, EvictionPolicy};

use super::KvRecord;

/// Store statistics (exported to metrics + the paper's summary table).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StoreStats {
    pub inserts: u64,
    pub evictions: u64,
    pub hits: u64,
    pub misses: u64,
    pub live_entries: usize,
    pub live_bytes: usize,
}

struct Entry {
    record: Arc<KvRecord>,
    /// Monotonic insert sequence (FIFO order).
    seq: u64,
    /// Last touch sequence (LRU order).
    last_used: u64,
    /// Hit count (LFU / cost-aware).
    hits: u64,
}

/// The cross-prompt KV cache store, keyed by entry id.
pub struct KvStore {
    cfg: CacheConfig,
    entries: HashMap<u64, Entry>,
    next_id: u64,
    clock: u64,
    stats: StoreStats,
}

impl KvStore {
    pub fn new(cfg: CacheConfig) -> Self {
        KvStore {
            cfg,
            entries: HashMap::new(),
            next_id: 0,
            clock: 0,
            stats: StoreStats::default(),
        }
    }

    pub fn config(&self) -> &CacheConfig {
        &self.cfg
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn live_bytes(&self) -> usize {
        self.stats.live_bytes
    }

    pub fn stats(&self) -> StoreStats {
        let mut s = self.stats;
        s.live_entries = self.entries.len();
        s
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Insert a record, evicting by policy if capacity would be exceeded.
    /// Returns the new entry id and the evicted `(id, record)` pairs so the
    /// caller (recycler) can drop them from its index/radix structures.
    pub fn insert(&mut self, record: KvRecord) -> (u64, Vec<(u64, Arc<KvRecord>)>) {
        let bytes = record.kv_bytes();
        let mut evicted = Vec::new();
        // Evict until the new entry fits (an oversized record may empty the
        // store entirely and still be admitted — by design: one giant entry
        // is better than none).
        while !self.entries.is_empty() && self.would_overflow(bytes) {
            match self.evict_one() {
                Some(pair) => evicted.push(pair),
                None => break,
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        let now = self.tick();
        self.stats.inserts += 1;
        self.stats.live_bytes += bytes;
        self.entries.insert(
            id,
            Entry {
                record: Arc::new(record),
                seq: now,
                last_used: now,
                hits: 0,
            },
        );
        (id, evicted)
    }

    fn would_overflow(&self, incoming_bytes: usize) -> bool {
        let over_entries =
            self.cfg.max_entries > 0 && self.entries.len() + 1 > self.cfg.max_entries;
        let over_bytes = self.cfg.max_bytes > 0
            && self.stats.live_bytes + incoming_bytes > self.cfg.max_bytes;
        over_entries || over_bytes
    }

    fn pick_victim(&self) -> Option<u64> {
        let score = |e: &Entry| -> (u64, u64) {
            match self.cfg.eviction {
                EvictionPolicy::Lru => (e.last_used, e.seq),
                EvictionPolicy::Fifo => (e.seq, e.seq),
                EvictionPolicy::Lfu => (e.hits, e.last_used),
                EvictionPolicy::CostAware => {
                    // lowest (hits + 1) * token_len first: rarely-hit, short
                    // (cheap to recompute) entries go first.
                    ((e.hits + 1) * e.record.token_len() as u64, e.last_used)
                }
            }
        };
        self.entries
            .iter()
            .min_by_key(|(id, e)| (score(e), **id))
            .map(|(id, _)| *id)
    }

    /// Evict one entry by the configured policy (external pressure, e.g.
    /// the KV arena running low on blocks). Returns the victim so the
    /// caller can drop it from its index/radix structures.
    pub fn evict_one(&mut self) -> Option<(u64, Arc<KvRecord>)> {
        let victim = self.pick_victim()?;
        let rec = self.peek(victim)?;
        self.remove(victim);
        self.stats.evictions += 1;
        Some((victim, rec))
    }

    /// Remove an entry explicitly. Returns whether it existed.
    pub fn remove(&mut self, id: u64) -> bool {
        if let Some(e) = self.entries.remove(&id) {
            self.stats.live_bytes -= e.record.kv_bytes();
            true
        } else {
            false
        }
    }

    /// Fetch for reuse: refreshes recency and bumps hit counters.
    pub fn hit(&mut self, id: u64) -> Option<Arc<KvRecord>> {
        let now = self.tick();
        match self.entries.get_mut(&id) {
            Some(e) => {
                e.last_used = now;
                e.hits += 1;
                self.stats.hits += 1;
                Some(Arc::clone(&e.record))
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Read without touching recency/frequency (inspection, benches).
    pub fn peek(&self, id: u64) -> Option<Arc<KvRecord>> {
        self.entries.get(&id).map(|e| Arc::clone(&e.record))
    }

    /// Record a retrieval miss (no candidate passed the prefix test).
    pub fn note_miss(&mut self) {
        self.stats.misses += 1;
    }

    /// Iterate (id, record) pairs in unspecified order.
    pub fn iter(&self) -> impl Iterator<Item = (u64, &Arc<KvRecord>)> {
        self.entries.iter().map(|(id, e)| (*id, &e.record))
    }

    /// Ids in insertion order (stable for tests/benches).
    pub fn ids(&self) -> Vec<u64> {
        let mut ids: Vec<(u64, u64)> =
            self.entries.iter().map(|(id, e)| (e.seq, *id)).collect();
        ids.sort();
        ids.into_iter().map(|(_, id)| id).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::kvcache::{KvArena, KvView};

    thread_local! {
        // one generously-sized arena per test thread; records are tiny
        static ARENA: KvArena = KvArena::new(&ModelConfig::nano(), 16, 2048);
    }

    fn rec(len: usize) -> KvRecord {
        ARENA.with(|a| {
            let g = a.geometry();
            let data = vec![0.0f32; g.elems_per_token() * len];
            KvRecord {
                text: format!("prompt-{len}"),
                tokens: (0..len as u32).collect(),
                embedding: vec![1.0],
                kv: KvView::from_contiguous(a, &data, len).unwrap(),
            }
        })
    }

    fn store(policy: EvictionPolicy, max_entries: usize) -> KvStore {
        KvStore::new(CacheConfig {
            max_entries,
            eviction: policy,
            ..Default::default()
        })
    }

    #[test]
    fn insert_and_hit() {
        let mut s = store(EvictionPolicy::Lru, 4);
        let (id, ev) = s.insert(rec(5));
        assert!(ev.is_empty());
        assert_eq!(s.len(), 1);
        assert!(s.hit(id).is_some());
        assert_eq!(s.stats().hits, 1);
        assert!(s.hit(999).is_none());
        assert_eq!(s.stats().misses, 1);
    }

    #[test]
    fn lru_evicts_least_recent() {
        let mut s = store(EvictionPolicy::Lru, 2);
        let (a, _) = s.insert(rec(1));
        let (b, _) = s.insert(rec(2));
        s.hit(a); // refresh a; b is now LRU
        let (_c, ev) = s.insert(rec(3));
        assert_eq!(ev.iter().map(|(id, _)| *id).collect::<Vec<_>>(), vec![b]);
        assert!(s.peek(a).is_some());
    }

    #[test]
    fn fifo_evicts_oldest_insert() {
        let mut s = store(EvictionPolicy::Fifo, 2);
        let (a, _) = s.insert(rec(1));
        let (_b, _) = s.insert(rec(2));
        s.hit(a); // FIFO ignores recency
        let (_c, ev) = s.insert(rec(3));
        assert_eq!(ev.iter().map(|(id, _)| *id).collect::<Vec<_>>(), vec![a]);
    }

    #[test]
    fn lfu_evicts_least_hit() {
        let mut s = store(EvictionPolicy::Lfu, 2);
        let (a, _) = s.insert(rec(1));
        let (b, _) = s.insert(rec(2));
        s.hit(a);
        s.hit(a);
        s.hit(b);
        let (_c, ev) = s.insert(rec(3));
        assert_eq!(ev.iter().map(|(id, _)| *id).collect::<Vec<_>>(), vec![b]);
    }

    #[test]
    fn cost_aware_prefers_short_unhit_victims() {
        let mut s = store(EvictionPolicy::CostAware, 2);
        let (_long, _) = s.insert(rec(50));
        let (short, _) = s.insert(rec(2));
        let (_c, ev) = s.insert(rec(10));
        assert_eq!(ev.iter().map(|(id, _)| *id).collect::<Vec<_>>(), vec![short]);
    }

    #[test]
    fn byte_capacity_enforced() {
        let cfg = ModelConfig::nano();
        let mut s = KvStore::new(CacheConfig {
            max_entries: 0,
            max_bytes: cfg.kv_bytes_for_len(25),
            ..Default::default()
        });
        s.insert(rec(10));
        s.insert(rec(10));
        assert_eq!(s.len(), 2);
        let (_, ev) = s.insert(rec(10)); // 30 tokens > 25-token budget
        assert_eq!(ev.len(), 1);
        assert!(s.live_bytes() <= cfg.kv_bytes_for_len(25));
    }

    #[test]
    fn bytes_accounting_exact() {
        let mut s = store(EvictionPolicy::Lru, 0);
        let (a, _) = s.insert(rec(3));
        let (_b, _) = s.insert(rec(7));
        let expect: usize = s.iter().map(|(_, r)| r.kv_bytes()).sum();
        assert_eq!(s.live_bytes(), expect);
        s.remove(a);
        let expect: usize = s.iter().map(|(_, r)| r.kv_bytes()).sum();
        assert_eq!(s.live_bytes(), expect);
    }

    #[test]
    fn oversized_record_still_admitted() {
        let cfg = ModelConfig::nano();
        let mut s = KvStore::new(CacheConfig {
            max_bytes: cfg.kv_bytes_for_len(5),
            max_entries: 0,
            ..Default::default()
        });
        s.insert(rec(3));
        let (id, ev) = s.insert(rec(100)); // oversized
        assert_eq!(ev.len(), 1);
        assert!(s.peek(id).is_some());
    }

    #[test]
    fn ids_in_insert_order() {
        let mut s = store(EvictionPolicy::Lru, 0);
        let (a, _) = s.insert(rec(1));
        let (b, _) = s.insert(rec(2));
        let (c, _) = s.insert(rec(3));
        assert_eq!(s.ids(), vec![a, b, c]);
    }
}
