//! Tiered, capacity-bounded KV store with pluggable eviction.
//!
//! The store manages two tiers. The **hot tier** holds resident entries
//! in one of two formats. The default is an arena-resident [`KvRecord`],
//! budgeted by *shared-aware physical footprint*: entries are accounted
//! by the distinct arena blocks they reference (a block shared by N
//! entries counts once), not by logical trimmed bytes — so a session
//! chain or radix family of records sharing a prefix is charged what it
//! actually occupies, and eviction reports the blocks it will *actually*
//! free ([`Eviction::freed_blocks`]: the victim's uniquely-held blocks).
//! With `CacheConfig::quantized_blocks` on, entries instead rest as
//! [`QuantRecord`]s — 8-bit rows under per-block scales, holding **zero**
//! arena blocks — and `max_bytes` budgets their quantized byte footprint;
//! a hit dequantizes into a fresh arena-backed record on attach. The
//! **cold tier** ([`SpillTier`]) is the eviction destination: when
//! spilling is configured (`CacheConfig::max_spill_bytes > 0`), a hot
//! eviction serializes the record to disk instead of destroying it
//! (compressed when `CacheConfig::spill_compression` is on), and
//! [`KvStore::reload_spilled`] transparently promotes it back on a later
//! lookup (shedding hot entries for room), counting a `spill_hit` with
//! its reload latency in [`CacheStats`].
//!
//! Invariants (property-tested in `rust/tests/properties.rs`):
//!
//!  * logical `live_bytes` == sum of hot entry bytes (either format),
//!  * `physical_blocks` == distinct arena blocks referenced by hot
//!    entries; physical capacity is never exceeded after any insert,
//!  * quantized entries reference **zero** arena blocks; their physical
//!    footprint is `quantized_bytes`,
//!  * after an eviction settles, the arena's free count grows by exactly
//!    the eviction's reported `freed_blocks`,
//!  * spilled entries hold **zero** arena blocks; their serialized bytes
//!    are conserved as the tier's physical `cold_bytes`,
//!  * a hit refreshes recency (LRU) and bumps frequency (LFU),
//!  * eviction order respects the policy.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::config::{CacheConfig, EvictionPolicy};
use crate::error::Error;
use crate::kvcache::KvArena;
use crate::util::timing::Stopwatch;

use super::persist::{self, Codec};
use super::record::QuantRecord;
use super::tier::SpillTier;
use super::KvRecord;

/// Store statistics (exported to metrics + the paper's summary table).
/// Hot-tier counters plus the spill tier's spill/reload/drop accounting.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    pub inserts: u64,
    pub evictions: u64,
    pub hits: u64,
    pub misses: u64,
    pub live_entries: usize,
    /// Logical bytes: sum of trimmed entry sizes (double-counts shared
    /// blocks; kept for display and the paper's tables).
    pub live_bytes: usize,
    /// Distinct arena blocks referenced by hot entries — the store's real
    /// arena footprint, what `max_bytes` budgets.
    pub physical_blocks: usize,
    /// `physical_blocks` in bytes.
    pub physical_bytes: usize,
    /// Hot evictions that landed in the cold tier instead of destroying
    /// the record.
    pub spills: u64,
    /// Lookups served by reloading a spilled record into the arena.
    pub spill_hits: u64,
    /// Cold entries destroyed by the tier's own LRU (spill budget).
    pub spill_drops: u64,
    /// Spill files rejected at load time (corrupt/truncated/unreadable) —
    /// each one a would-be garbage KV that surfaced as a typed error.
    pub spill_load_errors: u64,
    /// Entries currently resident in the cold tier.
    pub spilled_entries: usize,
    /// Bytes the cold tier actually occupies on disk — what
    /// `max_spill_bytes` budgets. Under the compressed (v2) codec this is
    /// the deflated size; under the raw codec it equals the logical size.
    pub cold_bytes_physical: usize,
    /// Bytes the same cold entries would occupy under the raw (v1)
    /// encoding. `cold_bytes_logical / cold_bytes_physical` is the cold
    /// tier's capacity multiplier from compression.
    pub cold_bytes_logical: usize,
    /// Quantized blocks resident in the hot tier (0 unless
    /// `CacheConfig::quantized_blocks` is on).
    pub quantized_blocks: usize,
    /// Physical bytes held by quantized hot entries — what `max_bytes`
    /// budgets for them. `live_bytes / quantized_bytes` over an
    /// all-quantized store is the hot tier's capacity multiplier.
    pub quantized_bytes: usize,
    /// Cross-worker adoptions: lookups served by reloading a *sibling*
    /// store's spilled record out of a shared `spill_dir` — a spill-reload
    /// hit on a worker that did not produce the record. Each adoption is
    /// also counted in `spill_hits`.
    pub adoptions: u64,
    /// Lookups served by the segment tier: an exact-prefix/radix miss
    /// that matched a cached *segment* at a different offset and attached
    /// it via position re-anchoring (see `recycler`). Each segment hit is
    /// also counted in `hits` (it resolved through the store).
    pub segment_hits: u64,
    /// Cached KV positions re-anchored into a new offset by segment hits
    /// (the reuse-depth analogue for the segment tier).
    pub reanchored_tokens: u64,
    /// Total / worst reload latency over `spill_hits`, microseconds.
    pub spill_reload_us_total: u64,
    pub spill_reload_us_max: u64,
    /// Spilling was requested (`max_spill_bytes > 0`) but the spill
    /// directory could not be set up — the store degraded to
    /// drop-on-evict. Surfaced so a misconfigured `spill_dir` is
    /// diagnosable from metrics instead of silently costing hit rate.
    pub spill_setup_failed: bool,
}

impl CacheStats {
    /// Mean cold-tier reload latency in milliseconds (0 when no reload
    /// has happened).
    pub fn avg_reload_ms(&self) -> f64 {
        if self.spill_hits == 0 {
            0.0
        } else {
            self.spill_reload_us_total as f64 / self.spill_hits as f64 / 1e3
        }
    }

    /// Fold another store's counters into this one — per-worker
    /// `CacheStats` roll up into a cluster aggregate: counts add, worst
    /// latencies take the max, degraded-mode flags OR together.
    pub fn merge(&mut self, o: &CacheStats) {
        self.inserts += o.inserts;
        self.evictions += o.evictions;
        self.hits += o.hits;
        self.misses += o.misses;
        self.live_entries += o.live_entries;
        self.live_bytes += o.live_bytes;
        self.physical_blocks += o.physical_blocks;
        self.physical_bytes += o.physical_bytes;
        self.spills += o.spills;
        self.spill_hits += o.spill_hits;
        self.spill_drops += o.spill_drops;
        self.spill_load_errors += o.spill_load_errors;
        self.spilled_entries += o.spilled_entries;
        self.cold_bytes_physical += o.cold_bytes_physical;
        self.cold_bytes_logical += o.cold_bytes_logical;
        self.quantized_blocks += o.quantized_blocks;
        self.quantized_bytes += o.quantized_bytes;
        self.adoptions += o.adoptions;
        self.segment_hits += o.segment_hits;
        self.reanchored_tokens += o.reanchored_tokens;
        self.spill_reload_us_total += o.spill_reload_us_total;
        self.spill_reload_us_max = self.spill_reload_us_max.max(o.spill_reload_us_max);
        self.spill_setup_failed |= o.spill_setup_failed;
    }

    /// Hit rate over lookups that reached the store (0 when none did).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Human-readable degraded-mode warning, if the cache is running in
    /// one (spilling requested but unavailable). `None` when healthy.
    pub fn health_warning(&self) -> Option<String> {
        if self.spill_setup_failed {
            Some(
                "spill directory setup failed: cache degraded to \
                 drop-on-evict (evictions destroy records instead of \
                 spilling to disk)"
                    .to_string(),
            )
        } else {
            None
        }
    }
}

/// What became of one evicted hot entry.
#[derive(Debug)]
pub enum Eviction {
    /// The record moved to the cold (disk) tier: its id still resolves
    /// through [`KvStore::reload_spilled`], so index/radix entries for it
    /// must survive. The store's own record handle is dropped before this
    /// returns, so `freed_blocks` have settled — unless the caller still
    /// holds an `Arc<KvRecord>` from an earlier `peek`/`hit`, which keeps
    /// the blocks alive until it drops (same caveat as `Dropped`).
    Spilled { id: u64, freed_blocks: usize },
    /// The record was destroyed (no tier configured, or the tier could
    /// not hold it): the owner must drop it from its index/radix
    /// structures. `freed_blocks` settle when the returned `Arc` drops.
    /// `record` is `None` for quantized victims — they held no arena
    /// blocks, so there is nothing left to settle.
    Dropped {
        id: u64,
        record: Option<Arc<KvRecord>>,
        freed_blocks: usize,
    },
}

impl Eviction {
    pub fn id(&self) -> u64 {
        match self {
            Eviction::Spilled { id, .. } | Eviction::Dropped { id, .. } => *id,
        }
    }

    /// The arena blocks this eviction returns to the pool — the victim's
    /// uniquely-held blocks at eviction time (shared blocks stay pinned
    /// by their other holders).
    pub fn freed_blocks(&self) -> usize {
        match self {
            Eviction::Spilled { freed_blocks, .. }
            | Eviction::Dropped { freed_blocks, .. } => *freed_blocks,
        }
    }

    pub fn is_spilled(&self) -> bool {
        matches!(self, Eviction::Spilled { .. })
    }
}

/// A hot entry's resident format: arena-backed (the default) or
/// quantized (`CacheConfig::quantized_blocks`). One store holds one
/// format at a time — the knob is construction-time immutable — except
/// transiently never: reloads re-quantize on promotion.
enum Payload {
    Hot(Arc<KvRecord>),
    Quant(QuantRecord),
}

impl Payload {
    fn token_len(&self) -> usize {
        match self {
            Payload::Hot(r) => r.token_len(),
            Payload::Quant(q) => q.token_len(),
        }
    }

    /// Logical (f32, trimmed) bytes — the `live_bytes` unit for both
    /// formats.
    fn kv_bytes(&self) -> usize {
        match self {
            Payload::Hot(r) => r.kv_bytes(),
            Payload::Quant(q) => q.kv_bytes(),
        }
    }
}

struct Entry {
    payload: Payload,
    /// Monotonic insert sequence (FIFO order).
    seq: u64,
    /// Last touch sequence (LRU order).
    last_used: u64,
    /// Hit count (LFU / cost-aware).
    hits: u64,
}

/// The cross-prompt KV cache store, keyed by entry id.
pub struct KvStore {
    cfg: CacheConfig,
    entries: HashMap<u64, Entry>,
    /// block_id -> number of hot entries holding that block. All records
    /// in one store share one arena (the serving stack guarantees it), so
    /// block ids are unambiguous. `len()` of this map is the store's
    /// physical footprint in blocks.
    block_refs: HashMap<usize, u32>,
    /// The cold tier; None = spilling disabled (eviction destroys).
    tier: Option<SpillTier>,
    /// Memoized token peeks of *sibling* namespaces' spill files in a
    /// shared `spill_dir` (adoption candidates). `None` = the file was
    /// unreadable/corrupt when peeked — never retried, never deleted
    /// (it is the sibling's file to manage).
    foreign_seen: HashMap<PathBuf, Option<Vec<u32>>>,
    /// The arena every record in this store lives in, captured at first
    /// insert. Quantized entries hold no record handle, so this is the
    /// store's own route back to the pool (materialize-on-hit,
    /// reclaimability checks).
    arena: Option<KvArena>,
    /// Physical bytes held by quantized hot entries.
    quant_bytes: usize,
    /// Quantized blocks held by quantized hot entries.
    quant_blocks: usize,
    next_id: u64,
    clock: u64,
    stats: CacheStats,
}

impl KvStore {
    pub fn new(cfg: CacheConfig) -> Self {
        // An unwritable spill directory degrades to drop-on-evict (the
        // pre-tier behavior) instead of poisoning construction — loudly:
        // logged here, and flagged in CacheStats::spill_setup_failed.
        let mut stats = CacheStats::default();
        let tier = if cfg.max_spill_bytes > 0 {
            let built = match &cfg.spill_dir {
                Some(d) => SpillTier::with_namespace(
                    PathBuf::from(d),
                    cfg.spill_namespace.clone(),
                    cfg.max_spill_bytes,
                    cfg.compress,
                ),
                None => SpillTier::at_tempdir(cfg.max_spill_bytes, cfg.compress),
            };
            match built {
                Ok(mut t) => {
                    // spill_compression picks the whole-file v2 codec; it
                    // wins over the legacy payload-only `compress` knob
                    // (already folded in by the constructor).
                    if cfg.spill_compression {
                        t.set_codec(Codec::V2Deflate);
                    }
                    Some(t)
                }
                Err(e) => {
                    eprintln!(
                        "kvcache: spill tier disabled (falling back to \
                         drop-on-evict): {e}"
                    );
                    stats.spill_setup_failed = true;
                    None
                }
            }
        } else {
            None
        };
        KvStore {
            cfg,
            entries: HashMap::new(),
            block_refs: HashMap::new(),
            tier,
            foreign_seen: HashMap::new(),
            arena: None,
            quant_bytes: 0,
            quant_blocks: 0,
            next_id: 0,
            clock: 0,
            stats,
        }
    }

    pub fn config(&self) -> &CacheConfig {
        &self.cfg
    }

    /// Serving-level override of the segment-tier fidelity budget (see
    /// `ServerConfig::segment_fidelity_budget`). The one cache knob that
    /// is re-settable after construction: the scheduler applies the
    /// cluster-wide budget onto factory-built recyclers at spawn. Every
    /// other knob stays construction-time immutable (spill/eviction state
    /// depends on them).
    pub fn set_segment_fidelity_budget(&mut self, budget: f64) {
        self.cfg.segment_fidelity_budget = budget;
    }

    /// Attach a fault plan to the cold tier (no-op when spilling is
    /// disabled) — the `SpillTier` failure-domain seam.
    pub fn install_faults(&mut self, h: crate::faults::FaultHandle) {
        if let Some(t) = &mut self.tier {
            t.set_faults(h);
        }
    }

    /// Hot (arena-resident) entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Entries resident in the cold (disk) tier.
    pub fn spilled_len(&self) -> usize {
        self.tier.as_ref().map_or(0, |t| t.len())
    }

    /// Hot + cold entries — everything a lookup can still resolve.
    pub fn total_len(&self) -> usize {
        self.len() + self.spilled_len()
    }

    /// Logical bytes of the hot tier (shared blocks double-counted).
    pub fn live_bytes(&self) -> usize {
        self.stats.live_bytes
    }

    /// Distinct arena blocks held by hot entries.
    pub fn physical_blocks(&self) -> usize {
        self.block_refs.len()
    }

    /// Bytes the cold tier actually occupies on disk (the
    /// `max_spill_bytes` unit — compressed size under the v2 codec).
    pub fn cold_bytes(&self) -> usize {
        self.tier.as_ref().map_or(0, |t| t.cold_bytes())
    }

    /// Bytes the cold tier's entries would occupy under the raw encoding
    /// (see [`CacheStats::cold_bytes_logical`]).
    pub fn cold_bytes_logical(&self) -> usize {
        self.tier.as_ref().map_or(0, |t| t.cold_bytes_logical())
    }

    /// The cold tier's directory (None = spilling disabled).
    pub fn spill_dir(&self) -> Option<&Path> {
        self.tier.as_ref().map(|t| t.dir())
    }

    pub fn stats(&self) -> CacheStats {
        let mut s = self.stats;
        s.live_entries = self.entries.len();
        s.physical_blocks = self.block_refs.len();
        s.quantized_blocks = self.quant_blocks;
        s.quantized_bytes = self.quant_bytes;
        if let Some(t) = &self.tier {
            s.spilled_entries = t.len();
            s.cold_bytes_physical = t.cold_bytes();
            s.cold_bytes_logical = t.cold_bytes_logical();
            s.spill_drops = t.drops();
        }
        s
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Account a record's blocks into the physical footprint.
    fn add_blocks(&mut self, rec: &KvRecord) {
        let bb = rec.block_bytes();
        for id in rec.kv.block_ids() {
            let holders = self.block_refs.entry(id).or_insert(0);
            *holders += 1;
            if *holders == 1 {
                self.stats.physical_bytes += bb;
            }
        }
    }

    /// Release a record's blocks from the physical footprint.
    fn remove_blocks(&mut self, rec: &KvRecord) {
        let bb = rec.block_bytes();
        for id in rec.kv.block_ids() {
            let holders = self.block_refs.get_mut(&id).expect("accounted block");
            *holders -= 1;
            if *holders == 0 {
                self.block_refs.remove(&id);
                self.stats.physical_bytes -= bb;
            }
        }
    }

    /// Physical bytes `record` would ADD to the store: its blocks not
    /// already referenced by a hot entry. A record that shares every
    /// block with survivors costs nothing — this is what lets a
    /// shared-prefix record "larger than the residual logical budget"
    /// be admitted.
    fn incoming_unique_bytes(&self, record: &KvRecord) -> usize {
        let bb = record.block_bytes();
        record
            .kv
            .block_ids()
            .iter()
            .filter(|id| !self.block_refs.contains_key(id))
            .count()
            * bb
    }

    /// Would admitting `incoming` physical bytes overflow the hot budget?
    /// For arena-backed records `incoming` is the unique-block footprint;
    /// for quantized records it is the quantized payload size — both land
    /// in the same `max_bytes` meter.
    fn would_overflow_incoming(&self, incoming: usize) -> bool {
        let over_entries =
            self.cfg.max_entries > 0 && self.entries.len() + 1 > self.cfg.max_entries;
        let over_bytes = self.cfg.max_bytes > 0
            && self.stats.physical_bytes + self.quant_bytes + incoming > self.cfg.max_bytes;
        over_entries || over_bytes
    }

    fn would_overflow(&self, record: &KvRecord) -> bool {
        self.would_overflow_incoming(self.incoming_unique_bytes(record))
    }

    /// Remember the arena this store's records live in (first-insert
    /// capture; all records in one store share one arena).
    fn capture_arena(&mut self, record: &KvRecord) {
        if self.arena.is_none() {
            self.arena = Some(record.kv.arena().clone());
        }
    }

    /// Insert a record, evicting by policy if capacity would be exceeded.
    /// Returns the new entry id and the evictions performed so the caller
    /// (recycler) can unindex destroyed records (spilled ones keep their
    /// index entries — they still resolve). The overflow test is
    /// re-derived per eviction: evicting a survivor that shared blocks
    /// with the incoming record raises the incoming unique footprint, and
    /// the recomputation tracks that (the stale-`live_bytes` bug the
    /// logical accounting had).
    ///
    /// With `quantized_blocks` on, the record is quantized at admission
    /// and its arena blocks are released immediately — the resident entry
    /// costs `quant_bytes`, not blocks.
    pub fn insert(&mut self, record: KvRecord) -> (u64, Vec<Eviction>) {
        self.capture_arena(&record);
        let mut evicted = Vec::new();
        if self.cfg.quantized_blocks {
            let q = QuantRecord::from_record(&record);
            drop(record); // releases the hot blocks before admission
            let incoming = q.quant_bytes();
            while !self.entries.is_empty() && self.would_overflow_incoming(incoming) {
                match self.evict_one() {
                    Some(ev) => evicted.push(ev),
                    None => break,
                }
            }
            let id = self.next_id;
            self.next_id += 1;
            self.insert_quant_entry(id, q);
            self.stats.inserts += 1;
            return (id, evicted);
        }
        // Evict until the new entry fits (an oversized record may empty
        // the hot tier entirely and still be admitted — by design: one
        // giant entry is better than none).
        while !self.entries.is_empty() && self.would_overflow(&record) {
            match self.evict_one() {
                Some(ev) => evicted.push(ev),
                None => break,
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        self.insert_entry(id, Arc::new(record));
        self.stats.inserts += 1;
        (id, evicted)
    }

    /// Place an arena-backed record into the hot tier under `id` (shared
    /// by fresh inserts and cold-tier promotion, which must keep its
    /// original id).
    fn insert_entry(&mut self, id: u64, record: Arc<KvRecord>) {
        let now = self.tick();
        self.stats.live_bytes += record.kv_bytes();
        self.add_blocks(&record);
        self.entries.insert(
            id,
            Entry {
                payload: Payload::Hot(record),
                seq: now,
                last_used: now,
                hits: 0,
            },
        );
    }

    /// Place a quantized record into the hot tier under `id`.
    fn insert_quant_entry(&mut self, id: u64, q: QuantRecord) {
        let now = self.tick();
        self.stats.live_bytes += q.kv_bytes();
        self.quant_bytes += q.quant_bytes();
        self.quant_blocks += q.kv_blocks();
        self.entries.insert(
            id,
            Entry {
                payload: Payload::Quant(q),
                seq: now,
                last_used: now,
                hits: 0,
            },
        );
    }

    fn pick_victim(&self) -> Option<u64> {
        let score = |e: &Entry| -> (u64, u64) {
            match self.cfg.eviction {
                EvictionPolicy::Lru => (e.last_used, e.seq),
                EvictionPolicy::Fifo => (e.seq, e.seq),
                EvictionPolicy::Lfu => (e.hits, e.last_used),
                EvictionPolicy::CostAware => {
                    // lowest (hits + 1) * token_len first: rarely-hit, short
                    // (cheap to recompute) entries go first.
                    ((e.hits + 1) * e.payload.token_len() as u64, e.last_used)
                }
            }
        };
        self.entries
            .iter()
            .min_by_key(|(id, e)| (score(e), **id))
            .map(|(id, _)| *id)
    }

    /// Evict one hot entry by the configured policy (capacity overflow or
    /// external arena pressure). With a cold tier, the victim is
    /// *spilled* — serialized to disk, id still resolvable — instead of
    /// destroyed; either way the eviction reports the arena blocks it
    /// actually frees (the victim's uniquely-held blocks).
    pub fn evict_one(&mut self) -> Option<Eviction> {
        let victim = self.pick_victim()?;
        let e = self.entries.remove(&victim).expect("victim is a live entry");
        self.stats.live_bytes -= e.payload.kv_bytes();
        self.stats.evictions += 1;
        match e.payload {
            Payload::Hot(record) => {
                self.remove_blocks(&record);
                let freed_blocks = record.unique_blocks();
                if let Some(tier) = &mut self.tier {
                    if tier.spill(victim, &record).is_ok() {
                        self.stats.spills += 1;
                        // dropping the record (the last holder of its
                        // unique blocks) settles the freed count before
                        // we return
                        drop(record);
                        return Some(Eviction::Spilled {
                            id: victim,
                            freed_blocks,
                        });
                    }
                    // tier refused (oversized / IO error): destroy below
                }
                Some(Eviction::Dropped {
                    id: victim,
                    record: Some(record),
                    freed_blocks,
                })
            }
            Payload::Quant(q) => {
                self.quant_bytes -= q.quant_bytes();
                self.quant_blocks -= q.kv_blocks();
                // a quantized victim spills through its dequantized
                // parts — no arena blocks involved, so this works even
                // under total block exhaustion
                if let Some(tier) = &mut self.tier {
                    if tier
                        .spill_parts(victim, &q.parts(), q.quant.geometry())
                        .is_ok()
                    {
                        self.stats.spills += 1;
                        return Some(Eviction::Spilled {
                            id: victim,
                            freed_blocks: 0,
                        });
                    }
                }
                Some(Eviction::Dropped {
                    id: victim,
                    record: None,
                    freed_blocks: 0,
                })
            }
        }
    }

    /// Remove an entry explicitly, from whichever tier holds it. Returns
    /// whether it existed.
    pub fn remove(&mut self, id: u64) -> bool {
        if let Some(e) = self.entries.remove(&id) {
            self.stats.live_bytes -= e.payload.kv_bytes();
            match e.payload {
                Payload::Hot(record) => self.remove_blocks(&record),
                Payload::Quant(q) => {
                    self.quant_bytes -= q.quant_bytes();
                    self.quant_blocks -= q.kv_blocks();
                }
            }
            true
        } else if let Some(t) = &mut self.tier {
            t.drop_entry(id)
        } else {
            false
        }
    }

    /// Fetch a *hot* entry for reuse: refreshes recency and bumps hit
    /// counters; counts a miss when `id` is not hot (spilled entries are
    /// resolved by [`reload_spilled`](Self::reload_spilled), which the
    /// caller gates on [`is_spilled`](Self::is_spilled)).
    /// A quantized entry dequantizes into a *fresh* arena-backed record
    /// per hit (the entry itself stays quantized and keeps holding zero
    /// blocks; the returned handle's blocks free when it drops). If the
    /// arena cannot host the materialization right now, the lookup is an
    /// honest (retryable) miss and the entry is left intact.
    pub fn hit(&mut self, id: u64) -> Option<Arc<KvRecord>> {
        let now = self.tick();
        // clone the captured-arena handle up front: the entry borrow
        // below would otherwise pin `self`
        let arena = self.arena.clone();
        match self.entries.get_mut(&id) {
            Some(e) => {
                let record = match &e.payload {
                    Payload::Hot(r) => Arc::clone(r),
                    Payload::Quant(q) => {
                        let materialized = arena
                            .as_ref()
                            .ok_or(Error::Rejected)
                            .and_then(|a| q.materialize(a));
                        match materialized {
                            Ok(r) => Arc::new(r),
                            Err(_) => {
                                self.stats.misses += 1;
                                return None;
                            }
                        }
                    }
                };
                e.last_used = now;
                e.hits += 1;
                self.stats.hits += 1;
                Some(record)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Read without touching recency/frequency (inspection, benches).
    /// Quantized entries materialize a fresh record here too (`None` on
    /// arena pressure).
    pub fn peek(&self, id: u64) -> Option<Arc<KvRecord>> {
        let e = self.entries.get(&id)?;
        match &e.payload {
            Payload::Hot(r) => Some(Arc::clone(r)),
            Payload::Quant(q) => self
                .arena
                .as_ref()
                .and_then(|a| q.materialize(a).ok().map(Arc::new)),
        }
    }

    /// Count a segment-tier hit: `tokens` cached positions re-anchored
    /// into a new offset. The segment tier only runs after the exact tier
    /// recorded this request as a miss, and the resolving
    /// [`hit`](Self::hit)/reload then counted a store hit — so the
    /// provisional miss is retracted here, keeping hits/misses exactly
    /// one-per-request with `segment_hits` a subset of `hits`.
    pub fn note_segment_hit(&mut self, tokens: usize) {
        self.stats.misses = self.stats.misses.saturating_sub(1);
        self.stats.segment_hits += 1;
        self.stats.reanchored_tokens += tokens as u64;
    }

    /// Is `id` hot (arena-resident)?
    pub fn contains(&self, id: u64) -> bool {
        self.entries.contains_key(&id)
    }

    /// Is `id` resident in the cold tier?
    pub fn is_spilled(&self, id: u64) -> bool {
        self.tier.as_ref().is_some_and(|t| t.contains(id))
    }

    /// Promote a spilled record back into the hot tier under its original
    /// id, materializing its KV into `arena` — the transparent-reload
    /// half of the tiered store. Sheds hot entries (which themselves
    /// spill) when the arena lacks blocks, and enforces hot capacity on
    /// the way in; every eviction performed is returned so the caller can
    /// unindex destroyed records. `None` with the cold entry intact means
    /// arena pressure won (retryable later); `None` with the entry gone
    /// means the file was corrupt/unreadable — recorded as a typed
    /// `spill_load_error`, never garbage KV.
    pub fn reload_spilled(
        &mut self,
        id: u64,
        arena: &KvArena,
    ) -> (Option<Arc<KvRecord>>, Vec<Eviction>) {
        let mut evicted = Vec::new();
        // The tier knows the record's token count without touching the
        // file, so the arena demand is pre-sheddable up front…
        let Some(tokens) = self.tier.as_ref().and_then(|t| t.tokens_of(id)) else {
            return (None, evicted);
        };
        let need = arena.blocks_for(tokens);
        while arena.free_blocks() < need {
            // same futility gate as the recycler's headroom pass: when no
            // hot block is reclaimable (all pinned by in-flight views),
            // shedding spills records for zero freed blocks — give up and
            // keep the target cold for a less-pressured retry
            if self.reclaimable_blocks() == 0 {
                return (None, evicted);
            }
            match self.evict_one() {
                Some(ev) => evicted.push(ev),
                // hot tier drained and the record still does not fit:
                // keep it cold, report a (retryable) miss
                None => return (None, evicted),
            }
            // shedding spills, and a tight tier budget can LRU-drop the
            // very entry we are reloading: a collateral drop, not a
            // corrupt file — give up cleanly (the id surfaces through
            // take_cold_dropped for unindexing)
            if !self.is_spilled(id) {
                return (None, evicted);
            }
        }
        // …and the serialized bytes are read from disk exactly ONCE;
        // only the decode-into-arena retries under residual pressure.
        // The reload clock starts HERE, after the pre-shed: shedding
        // spills *other* records (paying their serialization/compression
        // cost), and charging that to this reload would inflate
        // `avg_reload_ms`. What remains — read, decompress, decode,
        // admission — is the latency this lookup actually waited.
        let sw = Stopwatch::start();
        let buf = match self.tier.as_ref().expect("tokens_of implies a tier").read(id) {
            Ok(b) => b,
            Err(Error::Io(_)) => {
                // transient read failure (media hiccup): keep the cold
                // entry and its index entries — the next lookup for this
                // id naturally retries the reload
                self.stats.spill_load_errors += 1;
                return (None, evicted);
            }
            Err(_) => {
                // entry desync (not in the tier): typed load error, dead
                self.tier
                    .as_mut()
                    .expect("tokens_of implies a tier")
                    .drop_entry(id);
                self.stats.spill_load_errors += 1;
                return (None, evicted);
            }
        };
        let record = loop {
            match persist::from_bytes(&buf, arena) {
                Ok(rec) => break rec,
                Err(Error::ArenaExhausted { .. }) => {
                    if self.reclaimable_blocks() == 0 {
                        return (None, evicted); // futile: see pre-shed gate
                    }
                    match self.evict_one() {
                        Some(ev) => evicted.push(ev),
                        None => return (None, evicted),
                    }
                    if !self.is_spilled(id) {
                        return (None, evicted);
                    }
                }
                Err(_) => {
                    // corrupt / truncated: surface as a typed load error
                    // and destroy the dead entry — never garbage KV
                    self.tier
                        .as_mut()
                        .expect("tokens_of implies a tier")
                        .drop_entry(id);
                    self.stats.spill_load_errors += 1;
                    return (None, evicted);
                }
            }
        };
        // success: retire the cold entry (file deleted), then hot-capacity
        // admission, same loop as insert
        self.tier
            .as_mut()
            .expect("tokens_of implies a tier")
            .drop_entry(id);
        self.capture_arena(&record);
        let record = Arc::new(record);
        if self.cfg.quantized_blocks {
            // promote back into the resident format: the stored entry is
            // re-quantized (zero blocks); the returned handle keeps the
            // freshly-decoded hot copy alive for the caller to attach
            let q = QuantRecord::from_record(&record);
            let incoming = q.quant_bytes();
            while !self.entries.is_empty() && self.would_overflow_incoming(incoming) {
                match self.evict_one() {
                    Some(ev) => evicted.push(ev),
                    None => break,
                }
            }
            self.insert_quant_entry(id, q);
        } else {
            while !self.entries.is_empty() && self.would_overflow(record.as_ref()) {
                match self.evict_one() {
                    Some(ev) => evicted.push(ev),
                    None => break,
                }
            }
            self.insert_entry(id, Arc::clone(&record));
        }
        self.stats.spill_hits += 1;
        let us = (sw.elapsed_secs() * 1e6) as u64;
        self.stats.spill_reload_us_total += us;
        self.stats.spill_reload_us_max = self.stats.spill_reload_us_max.max(us);
        (Some(record), evicted)
    }

    /// Cross-worker cache mobility: on a lookup miss, try to *adopt* a
    /// sibling store's spilled record out of the shared `spill_dir` —
    /// the serialization boundary that lets a record spilled by worker A
    /// serve worker B's prompt without recomputation. Only enabled under
    /// shared-spill semantics (an explicit `spill_dir` AND a non-empty
    /// `spill_namespace`); otherwise an immediate no-op.
    ///
    /// The candidate is the *longest* foreign record whose tokens are an
    /// exact prefix of `ids`. Adoption **copies**: the sibling's file is
    /// read and decoded into this store's arena under a FRESH local id,
    /// and the file itself is never renamed, deleted, or mutated — the
    /// owner's cold-tier index stays valid, and concurrent adoption by
    /// several workers is race-free (atomic rename publication + CRC
    /// verification from PR 4 make a file either absent or whole).
    /// Unreadable/corrupt candidates are memoized and skipped, never
    /// swept — they are the sibling's to manage.
    ///
    /// Success counts a `spill_hit` (it is one: a lookup served from the
    /// cold tier) plus an `adoption`, with reload latency accounted like
    /// any other reload. Returns the fresh id + record, and every hot
    /// eviction shed to make room (the caller unindexes dropped ones).
    pub fn adopt_foreign(
        &mut self,
        ids: &[u32],
        arena: &KvArena,
    ) -> (Option<(u64, Arc<KvRecord>)>, Vec<Eviction>) {
        let mut evicted = Vec::new();
        if self.cfg.spill_dir.is_none()
            || self.cfg.spill_namespace.is_empty()
            || ids.is_empty()
        {
            return (None, evicted);
        }
        let Some(tier) = self.tier.as_ref() else {
            return (None, evicted);
        };
        // Scan sibling namespaces, memoizing token peeks so steady-state
        // misses cost one read_dir, not one file read per candidate.
        let files = tier.foreign_kv_files();
        let mut best: Option<(usize, PathBuf)> = None;
        for path in files {
            let toks = self.foreign_seen.entry(path.clone()).or_insert_with(|| {
                std::fs::read(&path)
                    .ok()
                    .and_then(|buf| persist::peek_tokens(&buf).ok())
            });
            let Some(toks) = toks else { continue };
            let d = toks.len();
            if d == 0 || d > ids.len() || ids[..d] != toks[..] {
                continue;
            }
            if best.as_ref().map_or(true, |(bd, _)| d > *bd) {
                best = Some((d, path));
            }
        }
        let Some((depth, path)) = best else {
            return (None, evicted);
        };
        // Pre-shed for the arena demand, with the same futility gate as
        // reload_spilled: shedding pinned-only entries frees nothing.
        let need = arena.blocks_for(depth);
        while arena.free_blocks() < need {
            if self.reclaimable_blocks() == 0 {
                return (None, evicted);
            }
            match self.evict_one() {
                Some(ev) => evicted.push(ev),
                None => return (None, evicted),
            }
        }
        // Read ONCE. The owner may legitimately delete/reload the file
        // between the peek and now — that is a clean miss, and the stale
        // memo entry is dropped so the path can be re-peeked if reused.
        // Like reload_spilled, the clock starts after the pre-shed so
        // the adoption latency is the read+decompress+decode this lookup
        // waited for, not other records' spill costs.
        let sw = Stopwatch::start();
        let buf = match std::fs::read(&path) {
            Ok(b) => b,
            Err(_) => {
                self.foreign_seen.remove(&path);
                return (None, evicted);
            }
        };
        let record = loop {
            match persist::from_bytes(&buf, arena) {
                Ok(rec) => break rec,
                Err(Error::ArenaExhausted { .. }) => {
                    if self.reclaimable_blocks() == 0 {
                        return (None, evicted);
                    }
                    match self.evict_one() {
                        Some(ev) => evicted.push(ev),
                        None => return (None, evicted),
                    }
                }
                Err(_) => {
                    // corrupt despite the peek (torn media): memoize as
                    // dead and give up — the file stays, it is not ours
                    self.foreign_seen.insert(path, None);
                    self.stats.spill_load_errors += 1;
                    return (None, evicted);
                }
            }
        };
        // hot-capacity admission, then insert under a FRESH local id —
        // the record is now this store's, fully decoupled from the file
        self.capture_arena(&record);
        let record = Arc::new(record);
        let id = self.next_id;
        self.next_id += 1;
        if self.cfg.quantized_blocks {
            let q = QuantRecord::from_record(&record);
            let incoming = q.quant_bytes();
            while !self.entries.is_empty() && self.would_overflow_incoming(incoming) {
                match self.evict_one() {
                    Some(ev) => evicted.push(ev),
                    None => break,
                }
            }
            self.insert_quant_entry(id, q);
        } else {
            while !self.entries.is_empty() && self.would_overflow(record.as_ref()) {
                match self.evict_one() {
                    Some(ev) => evicted.push(ev),
                    None => break,
                }
            }
            self.insert_entry(id, Arc::clone(&record));
        }
        self.stats.inserts += 1;
        self.stats.spill_hits += 1;
        self.stats.adoptions += 1;
        let us = (sw.elapsed_secs() * 1e6) as u64;
        self.stats.spill_reload_us_total += us;
        self.stats.spill_reload_us_max = self.stats.spill_reload_us_max.max(us);
        (Some((id, record)), evicted)
    }

    /// Drain the ids the cold tier's own LRU destroyed (spill-budget
    /// pressure) since the last call, so the owner can unindex them.
    pub fn take_cold_dropped(&mut self) -> Vec<u64> {
        self.tier.as_mut().map_or_else(Vec::new, |t| t.take_dropped())
    }

    /// Arena blocks that draining the ENTIRE hot tier would return to the
    /// pool: blocks whose every live reference is a hot entry's (global
    /// refcount == store holders). Blocks also pinned by in-flight
    /// streams or attached views are excluded — no amount of cache
    /// shedding frees those. This is what lets the recycler's headroom
    /// pass stop shedding the moment eviction turns futile, with no
    /// stall-memo latch.
    pub fn reclaimable_blocks(&self) -> usize {
        // quantized entries hold no blocks, so only `block_refs` matters:
        // empty means no amount of shedding frees arena space
        if self.block_refs.is_empty() {
            return 0;
        }
        let Some(arena) = &self.arena else {
            return 0;
        };
        // one pool lock, no state cloning — this runs once per eviction
        // in the recycler's shed loops
        arena.count_matching_refs(self.block_refs.iter().map(|(&id, &h)| (id, h)))
    }

    /// Record a retrieval miss (no candidate passed the prefix test).
    pub fn note_miss(&mut self) {
        self.stats.misses += 1;
    }

    /// Iterate arena-backed hot `(id, record)` pairs in unspecified
    /// order. Quantized entries are skipped — they hold no record handle
    /// to borrow (use [`peek`](Self::peek) to materialize one).
    pub fn iter(&self) -> impl Iterator<Item = (u64, &Arc<KvRecord>)> {
        self.entries.iter().filter_map(|(id, e)| match &e.payload {
            Payload::Hot(r) => Some((*id, r)),
            Payload::Quant(_) => None,
        })
    }

    /// Hot ids in insertion order (stable for tests/benches).
    pub fn ids(&self) -> Vec<u64> {
        let mut ids: Vec<(u64, u64)> =
            self.entries.iter().map(|(id, e)| (e.seq, *id)).collect();
        ids.sort();
        ids.into_iter().map(|(_, id)| id).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::kvcache::{KvArena, KvView};

    thread_local! {
        // one generously-sized arena per test thread; records are tiny
        static ARENA: KvArena = KvArena::new(&ModelConfig::nano(), 16, 2048);
    }

    /// Bytes one 16-token arena block occupies under the nano geometry.
    fn block_bytes() -> usize {
        ModelConfig::nano().kv_bytes_for_len(16)
    }

    fn rec(len: usize) -> KvRecord {
        ARENA.with(|a| {
            let g = a.geometry();
            let data = vec![0.0f32; g.elems_per_token() * len];
            KvRecord {
                text: format!("prompt-{len}"),
                tokens: (0..len as u32).collect(),
                embedding: vec![1.0],
                kv: KvView::from_contiguous(a, &data, len).unwrap(),
            }
        })
    }

    fn store(policy: EvictionPolicy, max_entries: usize) -> KvStore {
        KvStore::new(CacheConfig {
            max_entries,
            eviction: policy,
            ..Default::default()
        })
    }

    fn dropped_ids(evs: &[Eviction]) -> Vec<u64> {
        evs.iter().map(|e| e.id()).collect()
    }

    #[test]
    fn insert_and_hit() {
        let mut s = store(EvictionPolicy::Lru, 4);
        let (id, ev) = s.insert(rec(5));
        assert!(ev.is_empty());
        assert_eq!(s.len(), 1);
        assert!(s.hit(id).is_some());
        assert_eq!(s.stats().hits, 1);
        assert!(s.hit(999).is_none());
        assert_eq!(s.stats().misses, 1);
    }

    #[test]
    fn lru_evicts_least_recent() {
        let mut s = store(EvictionPolicy::Lru, 2);
        let (a, _) = s.insert(rec(1));
        let (b, _) = s.insert(rec(2));
        s.hit(a); // refresh a; b is now LRU
        let (_c, ev) = s.insert(rec(3));
        assert_eq!(dropped_ids(&ev), vec![b]);
        assert!(s.peek(a).is_some());
    }

    #[test]
    fn fifo_evicts_oldest_insert() {
        let mut s = store(EvictionPolicy::Fifo, 2);
        let (a, _) = s.insert(rec(1));
        let (_b, _) = s.insert(rec(2));
        s.hit(a); // FIFO ignores recency
        let (_c, ev) = s.insert(rec(3));
        assert_eq!(dropped_ids(&ev), vec![a]);
    }

    #[test]
    fn lfu_evicts_least_hit() {
        let mut s = store(EvictionPolicy::Lfu, 2);
        let (a, _) = s.insert(rec(1));
        let (b, _) = s.insert(rec(2));
        s.hit(a);
        s.hit(a);
        s.hit(b);
        let (_c, ev) = s.insert(rec(3));
        assert_eq!(dropped_ids(&ev), vec![b]);
    }

    #[test]
    fn cost_aware_prefers_short_unhit_victims() {
        let mut s = store(EvictionPolicy::CostAware, 2);
        let (_long, _) = s.insert(rec(50));
        let (short, _) = s.insert(rec(2));
        let (_c, ev) = s.insert(rec(10));
        assert_eq!(dropped_ids(&ev), vec![short]);
    }

    #[test]
    fn physical_byte_capacity_enforced() {
        // Budget of 2 blocks. rec(10) occupies 1 physical block (16-token
        // blocks), so two fit exactly and a third forces an eviction —
        // block-granular physical accounting, not logical token bytes.
        let mut s = KvStore::new(CacheConfig {
            max_entries: 0,
            max_bytes: 2 * block_bytes(),
            ..Default::default()
        });
        s.insert(rec(10));
        s.insert(rec(10));
        assert_eq!(s.len(), 2);
        assert_eq!(s.stats().physical_blocks, 2);
        let (_, ev) = s.insert(rec(10)); // a third block would overflow
        assert_eq!(ev.len(), 1);
        assert_eq!(s.len(), 2);
        assert!(s.stats().physical_bytes <= 2 * block_bytes());
    }

    #[test]
    fn logical_bytes_accounting_exact() {
        let mut s = store(EvictionPolicy::Lru, 0);
        let (a, _) = s.insert(rec(3));
        let (_b, _) = s.insert(rec(7));
        let expect: usize = s.iter().map(|(_, r)| r.kv_bytes()).sum();
        assert_eq!(s.live_bytes(), expect);
        s.remove(a);
        let expect: usize = s.iter().map(|(_, r)| r.kv_bytes()).sum();
        assert_eq!(s.live_bytes(), expect);
    }

    #[test]
    fn physical_accounting_counts_shared_blocks_once() {
        ARENA.with(|a| {
            let g = a.geometry();
            let data = vec![0.25f32; g.elems_per_token() * 48];
            let v = KvView::from_contiguous(a, &data, 48).unwrap(); // 3 blocks
            let ra = KvRecord::from_view("a", (0..32).collect(), vec![1.0], &v);
            let rb = KvRecord::from_view("b", (0..48).collect(), vec![1.0], &v);
            drop(v);
            let mut s = store(EvictionPolicy::Lru, 0);
            let (ia, _) = s.insert(ra);
            s.insert(rb);
            // ra holds blocks {0,1} of the run, rb holds {0,1,2}: 3 distinct
            assert_eq!(s.stats().physical_blocks, 3);
            assert_eq!(s.stats().physical_bytes, 3 * block_bytes());
            // logical double-counts: 32 + 48 tokens
            assert_eq!(
                s.live_bytes(),
                ModelConfig::nano().kv_bytes_for_len(32 + 48)
            );
            s.remove(ia);
            // rb alone still holds all 3 blocks
            assert_eq!(s.stats().physical_blocks, 3);
        });
    }

    #[test]
    fn shared_prefix_record_admitted_within_physical_budget() {
        // Regression (the stale-live_bytes bounce): a record sharing its
        // blocks with a survivor exceeds the residual LOGICAL budget but
        // adds only its unique blocks physically — it must be admitted
        // without evicting anyone.
        ARENA.with(|a| {
            let g = a.geometry();
            let data = vec![0.5f32; g.elems_per_token() * 48];
            let v = KvView::from_contiguous(a, &data, 48).unwrap(); // 3 blocks
            let ra = KvRecord::from_view("a", (0..32).collect(), vec![1.0], &v);
            let rb = KvRecord::from_view("b", (0..48).collect(), vec![1.0], &v);
            drop(v);
            // budget: exactly 3 blocks. Logically ra+rb = 80 tokens > 48.
            let mut s = KvStore::new(CacheConfig {
                max_entries: 0,
                max_bytes: 3 * block_bytes(),
                ..Default::default()
            });
            let (_, ev_a) = s.insert(ra);
            assert!(ev_a.is_empty());
            let (ib, ev_b) = s.insert(rb);
            assert!(
                ev_b.is_empty(),
                "physically-free shared-prefix record was bounced"
            );
            assert_eq!(s.len(), 2);
            assert!(s.peek(ib).is_some());
            assert_eq!(s.stats().physical_blocks, 3);
        });
    }

    #[test]
    fn eviction_reports_unique_footprint() {
        ARENA.with(|a| {
            let g = a.geometry();
            let data = vec![0.5f32; g.elems_per_token() * 48];
            let v = KvView::from_contiguous(a, &data, 48).unwrap();
            let ra = KvRecord::from_view("a", (0..32).collect(), vec![1.0], &v);
            let rb = KvRecord::from_view("b", (0..48).collect(), vec![1.0], &v);
            drop(v);
            let mut s = store(EvictionPolicy::Fifo, 0);
            s.insert(ra);
            s.insert(rb);
            let free_before = a.free_blocks();
            // FIFO evicts ra first: both its blocks are shared with rb
            let ev = s.evict_one().unwrap();
            assert_eq!(ev.freed_blocks(), 0, "fully-shared victim frees nothing");
            drop(ev);
            assert_eq!(a.free_blocks(), free_before);
            // rb now holds all 3 blocks uniquely
            let ev = s.evict_one().unwrap();
            assert_eq!(ev.freed_blocks(), 3);
            drop(ev);
            assert_eq!(a.free_blocks(), free_before + 3);
        });
    }

    #[test]
    fn oversized_record_still_admitted() {
        let mut s = KvStore::new(CacheConfig {
            max_bytes: block_bytes() / 2, // less than one block
            max_entries: 0,
            ..Default::default()
        });
        s.insert(rec(3));
        let (id, ev) = s.insert(rec(100)); // oversized
        assert_eq!(ev.len(), 1);
        assert!(s.peek(id).is_some());
    }

    #[test]
    fn ids_in_insert_order() {
        let mut s = store(EvictionPolicy::Lru, 0);
        let (a, _) = s.insert(rec(1));
        let (b, _) = s.insert(rec(2));
        let (c, _) = s.insert(rec(3));
        assert_eq!(s.ids(), vec![a, b, c]);
    }

    #[test]
    fn eviction_spills_and_reload_promotes_same_id() {
        let mut s = KvStore::new(CacheConfig {
            max_entries: 1,
            max_spill_bytes: 64 << 20,
            ..Default::default()
        });
        let (a, _) = s.insert(rec(20));
        let payload = s.peek(a).unwrap().kv.to_contiguous();
        let (_b, ev) = s.insert(rec(30)); // evicts a -> spilled
        assert_eq!(ev.len(), 1);
        assert!(ev[0].is_spilled());
        assert_eq!(ev[0].id(), a);
        assert!(!s.contains(a));
        assert!(s.is_spilled(a));
        assert_eq!(s.total_len(), 2);
        assert!(s.cold_bytes() > 0);
        assert_eq!(s.stats().spills, 1);

        let arena = ARENA.with(|ar| ar.clone());
        let (back, evicted) = s.reload_spilled(a, &arena);
        let back = back.expect("reload succeeds");
        assert_eq!(back.kv.to_contiguous(), payload, "payload survives the trip");
        assert!(s.contains(a), "promoted under the original id");
        assert!(!s.is_spilled(a));
        // max_entries 1: promoting a spilled the other entry
        assert_eq!(evicted.len(), 1);
        assert!(evicted[0].is_spilled());
        let st = s.stats();
        assert_eq!(st.spill_hits, 1);
        assert_eq!(st.spills, 2);
        assert!(st.spill_reload_us_max >= 1 || st.spill_reload_us_total == 0);
    }

    #[test]
    fn adopt_foreign_copies_a_sibling_stores_spilled_record() {
        // cross-worker cache mobility through a shared spill_dir: store B
        // adopts (by COPY) a record store A spilled, under a fresh local
        // id, leaving A's file and cold-tier entry untouched.
        let dir = std::env::temp_dir()
            .join(format!("recycle_store_adopt_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let mk = |ns: &str| {
            KvStore::new(CacheConfig {
                max_entries: 1,
                max_spill_bytes: 64 << 20,
                spill_dir: Some(dir.to_string_lossy().into_owned()),
                spill_namespace: ns.into(),
                ..Default::default()
            })
        };
        let mut a = mk("w0_");
        let mut b = mk("w1_");
        let (ida, _) = a.insert(rec(20));
        let payload = a.peek(ida).unwrap().kv.to_contiguous();
        a.insert(rec(30)); // evicts ida -> w0_<ida>.kv in the shared dir
        assert!(a.is_spilled(ida));

        let arena = ARENA.with(|ar| ar.clone());
        // B's prompt extends the spilled record's tokens: adoptable
        let prompt: Vec<u32> = (0..25).collect();
        let (got, ev) = b.adopt_foreign(&prompt, &arena);
        assert!(ev.is_empty(), "B was empty, nothing to shed");
        let (idb, recb) = got.expect("adoption succeeds");
        assert_eq!(recb.tokens, (0..20u32).collect::<Vec<_>>());
        assert_eq!(recb.kv.to_contiguous(), payload, "payload survives the hop");
        assert!(b.contains(idb));
        let st = b.stats();
        assert_eq!(st.adoptions, 1);
        assert_eq!(st.spill_hits, 1, "an adoption IS a spill hit");
        // copy, not steal: the sibling's cold entry and file are intact
        assert!(a.is_spilled(ida));
        assert!(dir.join(format!("w0_{ida}.kv")).exists());

        // a prompt no foreign record prefixes: clean no-op
        let (none, _) = b.adopt_foreign(&[99, 98, 97], &arena);
        assert!(none.is_none());
        assert_eq!(b.stats().adoptions, 1);

        // empty namespace = shared-spill semantics off: immediate no-op
        let mut legacy = KvStore::new(CacheConfig {
            max_entries: 1,
            max_spill_bytes: 64 << 20,
            spill_dir: Some(dir.to_string_lossy().into_owned()),
            ..Default::default()
        });
        let (none, _) = legacy.adopt_foreign(&prompt, &arena);
        assert!(none.is_none());
        drop(a);
        drop(b);
        drop(legacy);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn remove_reaches_the_cold_tier() {
        let mut s = KvStore::new(CacheConfig {
            max_entries: 1,
            max_spill_bytes: 64 << 20,
            ..Default::default()
        });
        let (a, _) = s.insert(rec(5));
        s.insert(rec(6)); // a -> cold
        assert!(s.is_spilled(a));
        assert!(s.remove(a));
        assert!(!s.is_spilled(a));
        assert!(!s.remove(a));
    }

    #[test]
    fn unwritable_spill_dir_degrades_loudly() {
        // procfs rejects mkdir, so tier setup fails: the store must fall
        // back to drop-on-evict AND flag it in stats (not silently).
        let mut s = KvStore::new(CacheConfig {
            max_entries: 1,
            max_spill_bytes: 1 << 20,
            spill_dir: Some("/proc/definitely/not/writable/spill".into()),
            ..Default::default()
        });
        assert!(s.stats().spill_setup_failed);
        let (a, _) = s.insert(rec(4));
        let (_b, ev) = s.insert(rec(5));
        assert!(!ev[0].is_spilled(), "degraded to drop-on-evict");
        assert!(!s.is_spilled(a));
        assert_eq!(s.spilled_len(), 0);
    }

    #[test]
    fn spill_disabled_eviction_drops() {
        let mut s = KvStore::new(CacheConfig {
            max_entries: 1,
            max_spill_bytes: 0,
            ..Default::default()
        });
        let (a, _) = s.insert(rec(5));
        let (_b, ev) = s.insert(rec(6));
        assert_eq!(ev.len(), 1);
        assert!(!ev[0].is_spilled());
        assert!(!s.is_spilled(a));
        assert_eq!(s.total_len(), 1);
    }

    #[test]
    fn merge_adds_capacity_counters() {
        let mut a = CacheStats {
            cold_bytes_physical: 10,
            cold_bytes_logical: 40,
            quantized_blocks: 2,
            quantized_bytes: 100,
            ..Default::default()
        };
        let b = CacheStats {
            cold_bytes_physical: 5,
            cold_bytes_logical: 9,
            quantized_blocks: 1,
            quantized_bytes: 11,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.cold_bytes_physical, 15);
        assert_eq!(a.cold_bytes_logical, 49);
        assert_eq!(a.quantized_blocks, 3);
        assert_eq!(a.quantized_bytes, 111);
    }

    #[test]
    fn compressed_tier_reports_logical_over_physical() {
        let mut s = KvStore::new(CacheConfig {
            max_entries: 1,
            max_spill_bytes: 64 << 20,
            spill_compression: true,
            ..Default::default()
        });
        let (a, _) = s.insert(rec(40));
        s.insert(rec(5)); // spills a under the v2 codec
        assert!(s.is_spilled(a));
        let st = s.stats();
        assert_eq!(st.cold_bytes_physical, s.cold_bytes());
        assert_eq!(st.cold_bytes_logical, s.cold_bytes_logical());
        assert!(
            st.cold_bytes_physical * 2 < st.cold_bytes_logical,
            "zero payload must deflate well: {} vs {}",
            st.cold_bytes_physical,
            st.cold_bytes_logical
        );
    }

    #[test]
    fn reload_latency_excludes_preshed_and_stays_monotone() {
        let mut s = KvStore::new(CacheConfig {
            max_entries: 1,
            max_spill_bytes: 64 << 20,
            spill_compression: true,
            ..Default::default()
        });
        let (a, _) = s.insert(rec(20));
        let (b, _) = s.insert(rec(30)); // spills a
        assert!(s.is_spilled(a));
        let arena = ARENA.with(|ar| ar.clone());
        let (got, _) = s.reload_spilled(a, &arena);
        assert!(got.is_some());
        let st1 = s.stats();
        assert_eq!(st1.spill_hits, 1);
        assert!(st1.spill_reload_us_total >= st1.spill_reload_us_max);
        // promoting a spilled b (max_entries 1): reload it too
        assert!(s.is_spilled(b));
        let (got, _) = s.reload_spilled(b, &arena);
        assert!(got.is_some());
        let st2 = s.stats();
        assert_eq!(st2.spill_hits, 2);
        // decompress time is inside the reload clock, pre-shed spill
        // time is not; either way the counters only ever grow
        assert!(st2.spill_reload_us_total >= st1.spill_reload_us_total);
        assert!(st2.spill_reload_us_max >= st1.spill_reload_us_max);
        assert!(st2.spill_reload_us_total >= st2.spill_reload_us_max);
    }

    #[test]
    fn quantized_store_multiplies_capacity_at_same_budget() {
        ARENA.with(|a| {
            let used0 = a.used_blocks();
            let mk = |quant: bool| {
                KvStore::new(CacheConfig {
                    max_entries: 0,
                    max_bytes: 2 * block_bytes(),
                    quantized_blocks: quant,
                    ..Default::default()
                })
            };
            let mut hot = mk(false);
            for _ in 0..8 {
                hot.insert(rec(10));
            }
            let hot_n = hot.len();
            drop(hot);
            let mut q = mk(true);
            for _ in 0..8 {
                q.insert(rec(10));
            }
            assert!(
                q.len() >= 2 * hot_n,
                "quantized store admitted {} vs hot {hot_n} at the same budget",
                q.len()
            );
            let st = q.stats();
            assert_eq!(st.physical_blocks, 0, "quantized entries pin no blocks");
            assert!(st.quantized_blocks >= q.len());
            assert!(st.quantized_bytes > 0 && st.quantized_bytes <= 2 * block_bytes());
            assert!(st.quantized_bytes * 3 < st.live_bytes);
            assert_eq!(a.used_blocks(), used0, "all hot copies released");
        });
    }

    #[test]
    fn quantized_hit_materializes_fresh_and_entry_stays_cheap() {
        ARENA.with(|a| {
            let g = a.geometry();
            // integer rows |v| <= 127: exact under power-of-two scales
            let data: Vec<f32> = (0..g.elems_per_token() * 10)
                .map(|i| (i % 101) as f32)
                .collect();
            let v = KvView::from_contiguous(a, &data, 10).unwrap();
            let r = KvRecord::from_view("p", (0..10).collect(), vec![1.0], &v);
            drop(v);
            let flat = r.kv.to_contiguous();
            let mut s = KvStore::new(CacheConfig {
                max_entries: 4,
                quantized_blocks: true,
                ..Default::default()
            });
            let (id, _) = s.insert(r);
            assert_eq!(a.used_blocks(), 0, "resident entry holds no blocks");
            let got = s.hit(id).expect("materializes on hit");
            assert_eq!(got.kv.to_contiguous(), flat, "integer grid is exact");
            assert!(a.used_blocks() > 0, "the returned handle is arena-backed");
            drop(got);
            assert_eq!(a.used_blocks(), 0, "blocks free when the handle drops");
            let st = s.stats();
            assert_eq!(st.hits, 1);
            assert!(st.quantized_blocks > 0 && st.quantized_bytes > 0);
        });
    }

    #[test]
    fn quantized_entries_spill_and_reload_exactly() {
        ARENA.with(|a| {
            let g = a.geometry();
            let mk_rec = |seed: u32, len: usize| {
                let data: Vec<f32> = (0..g.elems_per_token() * len)
                    .map(|i| ((i as u32 + seed) % 97) as f32)
                    .collect();
                let v = KvView::from_contiguous(a, &data, len).unwrap();
                KvRecord::from_view(
                    &format!("p{seed}"),
                    (0..len as u32).collect(),
                    vec![1.0],
                    &v,
                )
            };
            let mut s = KvStore::new(CacheConfig {
                max_entries: 1,
                max_spill_bytes: 64 << 20,
                spill_compression: true,
                quantized_blocks: true,
                ..Default::default()
            });
            let r1 = mk_rec(1, 20);
            let flat = r1.kv.to_contiguous();
            let (id1, _) = s.insert(r1);
            // evicts id1, which spills through its dequantized parts
            let (_id2, ev) = s.insert(mk_rec(2, 12));
            assert_eq!(ev.len(), 1);
            assert!(ev[0].is_spilled());
            assert!(s.is_spilled(id1));
            assert!(s.stats().cold_bytes_physical > 0);
            let arena = a.clone();
            let (back, _) = s.reload_spilled(id1, &arena);
            let back = back.expect("reload succeeds");
            assert_eq!(
                back.kv.to_contiguous(),
                flat,
                "quantize -> spill -> reload is exact on the integer grid"
            );
            assert!(s.contains(id1));
            assert_eq!(s.stats().spill_hits, 1);
            drop(back);
            assert_eq!(a.used_blocks(), 0, "promoted entry re-quantized: zero blocks");
        });
    }

    #[test]
    fn reclaimable_excludes_blocks_pinned_outside_the_store() {
        ARENA.with(|a| {
            let mut s = store(EvictionPolicy::Lru, 0);
            let (id, _) = s.insert(rec(20)); // 2 blocks
            assert_eq!(s.reclaimable_blocks(), 2);
            // an attached in-flight view pins both blocks
            let attached = s.peek(id).unwrap().attach();
            assert_eq!(s.reclaimable_blocks(), 0);
            drop(attached);
            assert_eq!(s.reclaimable_blocks(), 2);
            let _ = a; // arena identity shared via the thread_local
        });
    }
}
