//! Disk persistence for KV records — the `torch.save` stand-in.
//!
//! Two on-disk versions coexist, selected by a [`Codec`]:
//!
//! **Version 1** (little-endian, unchanged since the dense-buffer encoder —
//! the paged-arena refactor serializes the *gathered* payload, so files are
//! byte-identical to the original encoder and old caches stay loadable):
//!
//! ```text
//! magic   u32  = 0x4B56_5243  ("KVRC")
//! version u32  = 1
//! flags   u32  (bit 0: payload DEFLATE-compressed)
//! geometry: n_layer u32, n_head u32, head_dim u32
//! text:      len u32, utf-8 bytes
//! tokens:    len u32, u32 ids
//! embedding: len u32, f32 values
//! payload:   raw_len u32 (f32 count), stored_len u32 (bytes), bytes
//! crc32 u32 over everything above
//! ```
//!
//! **Version 2** compresses the *whole body* (metadata + payload) with
//! DEFLATE, so text/token/embedding bytes stop costing the spill budget
//! too. The fixed header stays uncompressed and records both the logical
//! and stored body sizes, which is how the spill tier budgets *physical*
//! bytes while still reporting the logical bytes a raw encoding would
//! have taken:
//!
//! ```text
//! magic   u32  = 0x4B56_5243  ("KVRC")
//! version u32  = 2
//! flags   u32  (bit 0: body DEFLATE-compressed)
//! body_raw_len    u32 (bytes, before compression)
//! body_stored_len u32 (bytes, as stored)
//! body: geometry (3 u32), text (len u32 + bytes),
//!       tokens (len u32 + u32 ids), embedding (len u32 + f32s),
//!       payload (raw_len u32 f32-count + f32 bytes)
//! crc32 u32 over everything above
//! ```
//!
//! Encoding uses bulk little-endian byte-slice writes (one `memcpy` per
//! array on LE targets, not one `put_u32` per element). Corruption (bit
//! flips, truncation) must surface as `Error::Corrupt` — never as a
//! silently wrong KV tensor; the integration and property tests inject
//! both, against both versions. Loading materializes the payload into a
//! caller-provided [`KvArena`].

use std::io::{Read, Write};
use std::path::Path;

use flate2::read::DeflateDecoder;
use flate2::write::DeflateEncoder;
use flate2::Compression;

use crate::error::{Error, Result};
use crate::util::crc32;

use super::{KvArena, KvGeometry, KvRecord, KvView};

const MAGIC: u32 = 0x4B56_5243;
const VERSION: u32 = 1;
const VERSION_V2: u32 = 2;
const FLAG_COMPRESSED: u32 = 1;

/// On-disk encoding selector. `V1Raw` and `V1PayloadDeflate` are the
/// legacy format (version word 1, payload-only optional compression);
/// `V2Deflate` is the whole-body codec behind `spill_compression`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Codec {
    V1Raw,
    V1PayloadDeflate,
    V2Deflate,
}

impl Codec {
    /// Map the two `CacheConfig` knobs onto a codec: `spill_compression`
    /// selects the v2 whole-body format and wins over the legacy
    /// `compress` (v1 payload-only) knob.
    pub fn select(spill_compression: bool, compress: bool) -> Codec {
        if spill_compression {
            Codec::V2Deflate
        } else if compress {
            Codec::V1PayloadDeflate
        } else {
            Codec::V1Raw
        }
    }
}

/// The serializable fields of a record, borrowed — so both hot `KvRecord`s
/// (payload gathered from the arena) and quantized records (payload
/// dequantized on the fly, no arena needed) encode through one path.
pub struct RecordParts<'a> {
    pub text: &'a str,
    pub tokens: &'a [u32],
    pub embedding: &'a [f32],
    /// Gathered f32 payload, `elems_per_token * tokens.len()` values.
    pub payload: Vec<f32>,
}

impl<'a> RecordParts<'a> {
    pub fn of(rec: &'a KvRecord) -> RecordParts<'a> {
        RecordParts {
            text: &rec.text,
            tokens: &rec.tokens,
            embedding: &rec.embedding,
            payload: rec.kv.to_contiguous(),
        }
    }

    /// Exact byte length a raw (uncompressed v1) encoding would take —
    /// the *logical* size the spill tier reports next to the physical
    /// bytes actually written. Computed arithmetically; nothing is
    /// encoded.
    pub fn raw_encoded_len(&self) -> usize {
        6 * 4
            + 4 + self.text.len()
            + 4 + self.tokens.len() * 4
            + 4 + self.embedding.len() * 4
            + 4 + 4 + self.payload.len() * 4
            + 4
    }
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Bulk little-endian write of a u32 slice: a single byte-slice append on
/// LE targets, per-element fallback elsewhere.
fn put_u32_slice(buf: &mut Vec<u8>, vals: &[u32]) {
    if cfg!(target_endian = "little") {
        // SAFETY: u32 is plain-old-data; reinterpreting the slice as bytes
        // of length 4 * len is valid for reads.
        let bytes = unsafe {
            std::slice::from_raw_parts(vals.as_ptr() as *const u8, vals.len() * 4)
        };
        buf.extend_from_slice(bytes);
    } else {
        for &v in vals {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
}

/// Bulk little-endian write of an f32 slice (see [`put_u32_slice`]).
fn put_f32_slice(buf: &mut Vec<u8>, vals: &[f32]) {
    if cfg!(target_endian = "little") {
        // SAFETY: f32 is plain-old-data; see put_u32_slice.
        let bytes = unsafe {
            std::slice::from_raw_parts(vals.as_ptr() as *const u8, vals.len() * 4)
        };
        buf.extend_from_slice(bytes);
    } else {
        for &v in vals {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
}

/// Bulk little-endian read of an f32 array.
fn get_f32s(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(Error::Corrupt("truncated file".into()));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
}

fn deflate(raw: &[u8]) -> Vec<u8> {
    let mut enc = DeflateEncoder::new(Vec::new(), Compression::fast());
    enc.write_all(raw).expect("in-memory deflate cannot fail");
    enc.finish().expect("in-memory deflate cannot fail")
}

/// Verify the trailing CRC and split it off, returning the covered body.
fn checked_body(buf: &[u8]) -> Result<&[u8]> {
    if buf.len() < 8 {
        return Err(Error::Corrupt("file too small".into()));
    }
    let (body, crc_bytes) = buf.split_at(buf.len() - 4);
    let want = u32::from_le_bytes(crc_bytes.try_into().unwrap());
    if crc32::hash(body) != want {
        return Err(Error::Corrupt("crc mismatch".into()));
    }
    Ok(body)
}

/// Serialize record parts under the chosen codec.
pub fn encode(parts: &RecordParts<'_>, geom: &KvGeometry, codec: Codec) -> Vec<u8> {
    match codec {
        Codec::V1Raw => encode_v1(parts, geom, false),
        Codec::V1PayloadDeflate => encode_v1(parts, geom, true),
        Codec::V2Deflate => encode_v2(parts, geom),
    }
}

/// Version-1 encoder, byte-identical to the original `to_bytes` (pinned
/// by the frozen reference encoder in the tests below).
fn encode_v1(parts: &RecordParts<'_>, g: &KvGeometry, compress: bool) -> Vec<u8> {
    let payload = &parts.payload;
    let packed = compress.then(|| {
        let mut raw = Vec::with_capacity(payload.len() * 4);
        put_f32_slice(&mut raw, payload);
        deflate(&raw)
    });
    let stored_len = packed.as_ref().map_or(payload.len() * 4, |p| p.len());
    // Exact capacity: 6 header words, 3 length-prefixed arrays, the
    // payload's two length words + bytes, and the trailing crc.
    let total = 6 * 4
        + 4 + parts.text.len()
        + 4 + parts.tokens.len() * 4
        + 4 + parts.embedding.len() * 4
        + 4 + 4 + stored_len
        + 4;
    let mut out = Vec::with_capacity(total);
    put_u32(&mut out, MAGIC);
    put_u32(&mut out, VERSION);
    put_u32(&mut out, if compress { FLAG_COMPRESSED } else { 0 });
    put_u32(&mut out, g.n_layer as u32);
    put_u32(&mut out, g.n_head as u32);
    put_u32(&mut out, g.head_dim as u32);
    put_u32(&mut out, parts.text.len() as u32);
    out.extend_from_slice(parts.text.as_bytes());
    put_u32(&mut out, parts.tokens.len() as u32);
    put_u32_slice(&mut out, parts.tokens);
    put_u32(&mut out, parts.embedding.len() as u32);
    put_f32_slice(&mut out, parts.embedding);
    put_u32(&mut out, payload.len() as u32);
    match packed {
        Some(p) => {
            put_u32(&mut out, p.len() as u32);
            out.extend_from_slice(&p);
        }
        None => {
            put_u32(&mut out, (payload.len() * 4) as u32);
            put_f32_slice(&mut out, payload);
        }
    }
    let crc = crc32::hash(&out);
    put_u32(&mut out, crc);
    debug_assert_eq!(out.len(), total, "capacity estimate drifted");
    out
}

/// Version-2 encoder: the whole body (metadata + payload) goes through
/// one DEFLATE stream behind a 5-word uncompressed header.
fn encode_v2(parts: &RecordParts<'_>, g: &KvGeometry) -> Vec<u8> {
    let payload = &parts.payload;
    let body_raw_len = 3 * 4
        + 4 + parts.text.len()
        + 4 + parts.tokens.len() * 4
        + 4 + parts.embedding.len() * 4
        + 4 + payload.len() * 4;
    let mut body = Vec::with_capacity(body_raw_len);
    put_u32(&mut body, g.n_layer as u32);
    put_u32(&mut body, g.n_head as u32);
    put_u32(&mut body, g.head_dim as u32);
    put_u32(&mut body, parts.text.len() as u32);
    body.extend_from_slice(parts.text.as_bytes());
    put_u32(&mut body, parts.tokens.len() as u32);
    put_u32_slice(&mut body, parts.tokens);
    put_u32(&mut body, parts.embedding.len() as u32);
    put_f32_slice(&mut body, parts.embedding);
    put_u32(&mut body, payload.len() as u32);
    put_f32_slice(&mut body, payload);
    debug_assert_eq!(body.len(), body_raw_len, "v2 body estimate drifted");
    let stored = deflate(&body);
    let mut out = Vec::with_capacity(5 * 4 + stored.len() + 4);
    put_u32(&mut out, MAGIC);
    put_u32(&mut out, VERSION_V2);
    put_u32(&mut out, FLAG_COMPRESSED);
    put_u32(&mut out, body.len() as u32);
    put_u32(&mut out, stored.len() as u32);
    out.extend_from_slice(&stored);
    let crc = crc32::hash(&out);
    put_u32(&mut out, crc);
    out
}

/// Serialize a record to bytes in the legacy version-1 layout (kept for
/// every existing caller; `compress` selects payload-only DEFLATE).
pub fn to_bytes(rec: &KvRecord, compress: bool) -> Vec<u8> {
    let codec = if compress { Codec::V1PayloadDeflate } else { Codec::V1Raw };
    encode(&RecordParts::of(rec), rec.kv.geometry(), codec)
}

/// Decode the geometry triple and reject it if it does not match `arena`.
fn read_geometry(r: &mut Reader<'_>, arena: &KvArena) -> Result<()> {
    let n_layer = r.u32()? as usize;
    let n_head = r.u32()? as usize;
    let head_dim = r.u32()? as usize;
    let g = arena.geometry();
    if n_layer != g.n_layer || n_head != g.n_head || head_dim != g.head_dim {
        return Err(Error::ShapeMismatch(format!(
            "cache file geometry [{n_layer}, {n_head}, {head_dim}] does not \
             match arena [{}, {}, {}]",
            g.n_layer, g.n_head, g.head_dim
        )));
    }
    Ok(())
}

/// Decode the text / tokens / embedding triplet shared by both body
/// layouts.
fn read_meta(r: &mut Reader<'_>) -> Result<(String, Vec<u32>, Vec<f32>)> {
    let text_len = r.u32()? as usize;
    let text = String::from_utf8(r.take(text_len)?.to_vec())
        .map_err(|_| Error::Corrupt("bad utf8 in text".into()))?;
    let n_tokens = r.u32()? as usize;
    let tokens: Vec<u32> = r
        .take(n_tokens * 4)?
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let n_emb = r.u32()? as usize;
    let embedding = get_f32s(r.take(n_emb * 4)?);
    Ok((text, tokens, embedding))
}

/// Validate payload element count against geometry and materialize the
/// view.
fn finish_record(
    arena: &KvArena,
    text: String,
    tokens: Vec<u32>,
    embedding: Vec<f32>,
    raw_len: usize,
    raw: &[u8],
) -> Result<KvRecord> {
    if raw.len() != raw_len * 4 {
        return Err(Error::Corrupt(format!(
            "payload length {} != declared {}",
            raw.len(),
            raw_len * 4
        )));
    }
    let n_tokens = tokens.len();
    let g = arena.geometry();
    if raw_len != g.elems_per_token() * n_tokens {
        return Err(Error::Corrupt(format!(
            "payload has {raw_len} elems, geometry implies {} for {n_tokens} tokens",
            g.elems_per_token() * n_tokens
        )));
    }
    let kv_f32 = get_f32s(raw);
    let kv = KvView::from_contiguous(arena, &kv_f32, n_tokens)?;
    Ok(KvRecord {
        text,
        tokens,
        embedding,
        kv,
    })
}

/// Deserialize a record from bytes, verifying the checksum and
/// materializing the payload into `arena` (which must match the record's
/// geometry). Dispatches on the version word: both on-disk versions load
/// through here, so legacy raw `.kv` files written before the v2 codec
/// still reload.
pub fn from_bytes(buf: &[u8], arena: &KvArena) -> Result<KvRecord> {
    let body = checked_body(buf)?;
    let mut r = Reader { buf: body, pos: 0 };
    if r.u32()? != MAGIC {
        return Err(Error::Corrupt("bad magic".into()));
    }
    let version = r.u32()?;
    match version {
        VERSION => from_bytes_v1(r, arena),
        VERSION_V2 => from_bytes_v2(r, arena),
        other => Err(Error::Version(other)),
    }
}

fn from_bytes_v1(mut r: Reader<'_>, arena: &KvArena) -> Result<KvRecord> {
    let flags = r.u32()?;
    read_geometry(&mut r, arena)?;
    let (text, tokens, embedding) = read_meta(&mut r)?;
    let raw_len = r.u32()? as usize;
    let stored_len = r.u32()? as usize;
    let stored = r.take(stored_len)?;
    let raw = if flags & FLAG_COMPRESSED != 0 {
        let mut dec = DeflateDecoder::new(stored);
        let mut out = Vec::with_capacity(raw_len * 4);
        dec.read_to_end(&mut out)
            .map_err(|e| Error::Corrupt(format!("deflate: {e}")))?;
        out
    } else {
        stored.to_vec()
    };
    if r.pos != r.buf.len() {
        return Err(Error::Corrupt("trailing bytes".into()));
    }
    finish_record(arena, text, tokens, embedding, raw_len, &raw)
}

fn from_bytes_v2(mut r: Reader<'_>, arena: &KvArena) -> Result<KvRecord> {
    let flags = r.u32()?;
    let body_raw_len = r.u32()? as usize;
    let stored_len = r.u32()? as usize;
    let stored = r.take(stored_len)?;
    if r.pos != r.buf.len() {
        return Err(Error::Corrupt("trailing bytes".into()));
    }
    let body = if flags & FLAG_COMPRESSED != 0 {
        let mut dec = DeflateDecoder::new(stored);
        let mut out = Vec::with_capacity(body_raw_len);
        dec.read_to_end(&mut out)
            .map_err(|e| Error::Corrupt(format!("deflate: {e}")))?;
        out
    } else {
        stored.to_vec()
    };
    if body.len() != body_raw_len {
        return Err(Error::Corrupt(format!(
            "body length {} != declared {body_raw_len}",
            body.len()
        )));
    }
    let mut b = Reader { buf: &body, pos: 0 };
    read_geometry(&mut b, arena)?;
    let (text, tokens, embedding) = read_meta(&mut b)?;
    let raw_len = b.u32()? as usize;
    let raw = b.take(raw_len * 4)?.to_vec();
    if b.pos != b.buf.len() {
        return Err(Error::Corrupt("trailing bytes".into()));
    }
    finish_record(arena, text, tokens, embedding, raw_len, &raw)
}

/// Parse just the token ids out of serialized record bytes (full CRC
/// verified, header decoded up to the token array) without materializing
/// the payload into an arena. Spill files are self-describing, so this is
/// how a worker filters a sibling's spilled records down to
/// prefix-matching adoption candidates before paying for a decode. For
/// version-2 files the DEFLATE stream is decoded incrementally and
/// abandoned right after the token array — the payload (the bulk of the
/// body) is never inflated.
pub fn peek_tokens(buf: &[u8]) -> Result<Vec<u32>> {
    let body = checked_body(buf)?;
    let mut r = Reader { buf: body, pos: 0 };
    if r.u32()? != MAGIC {
        return Err(Error::Corrupt("bad magic".into()));
    }
    let version = r.u32()?;
    match version {
        VERSION => {
            let _flags = r.u32()?;
            let _geometry = (r.u32()?, r.u32()?, r.u32()?);
            let text_len = r.u32()? as usize;
            r.take(text_len)?;
            let n_tokens = r.u32()? as usize;
            Ok(r.take(n_tokens * 4)?
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect())
        }
        VERSION_V2 => {
            let flags = r.u32()?;
            let _body_raw_len = r.u32()?;
            let stored_len = r.u32()? as usize;
            let stored = r.take(stored_len)?;
            if flags & FLAG_COMPRESSED != 0 {
                peek_tokens_stream(DeflateDecoder::new(stored))
            } else {
                peek_tokens_stream(stored)
            }
        }
        other => Err(Error::Version(other)),
    }
}

/// Read geometry + text prefix + token ids off a streaming body reader.
fn peek_tokens_stream<R: Read>(mut src: R) -> Result<Vec<u32>> {
    fn read_n<R: Read>(src: &mut R, n: usize) -> Result<Vec<u8>> {
        let mut v = vec![0u8; n];
        src.read_exact(&mut v)
            .map_err(|e| Error::Corrupt(format!("deflate: {e}")))?;
        Ok(v)
    }
    fn read_u32<R: Read>(src: &mut R) -> Result<u32> {
        let b = read_n(src, 4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    let _geometry = read_n(&mut src, 12)?;
    let text_len = read_u32(&mut src)? as usize;
    read_n(&mut src, text_len)?;
    let n_tokens = read_u32(&mut src)? as usize;
    Ok(read_n(&mut src, n_tokens * 4)?
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Atomically write pre-serialized record bytes (write temp, then
/// rename) — the one home of the atomic-write discipline, shared by
/// [`save`] and the spill tier (which serializes once to learn the size
/// it must budget).
pub fn save_bytes(path: &Path, bytes: &[u8]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, bytes)?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Save to a file (atomic: write temp then rename).
pub fn save(rec: &KvRecord, path: &Path, compress: bool) -> Result<()> {
    save_bytes(path, &to_bytes(rec, compress))
}

/// Load from a file, materializing into `arena`.
pub fn load(path: &Path, arena: &KvArena) -> Result<KvRecord> {
    let buf = std::fs::read(path)?;
    from_bytes(&buf, arena)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn arena() -> KvArena {
        KvArena::new(&ModelConfig::nano(), 16, 256)
    }

    fn rec_in(a: &KvArena) -> KvRecord {
        let g = a.geometry();
        let tokens: Vec<u32> = vec![4, 7, 9];
        let data: Vec<f32> = (0..g.elems_per_token() * tokens.len())
            .map(|i| (i % 97) as f32 * 0.5)
            .collect();
        let kv = KvView::from_contiguous(a, &data, tokens.len()).unwrap();
        KvRecord {
            text: "the prompt".into(),
            tokens,
            embedding: vec![0.1, -0.2],
            kv,
        }
    }

    /// The pre-refactor element-at-a-time encoder, kept verbatim as a
    /// reference so the bulk writer is provably byte-identical.
    fn to_bytes_reference(rec: &KvRecord, compress: bool) -> Vec<u8> {
        fn put_bytes(buf: &mut Vec<u8>, b: &[u8]) {
            put_u32(buf, b.len() as u32);
            buf.extend_from_slice(b);
        }
        let g = rec.kv.geometry();
        let payload = rec.kv.to_contiguous();
        let mut out = Vec::with_capacity(64 + payload.len() * 4);
        put_u32(&mut out, MAGIC);
        put_u32(&mut out, VERSION);
        put_u32(&mut out, if compress { FLAG_COMPRESSED } else { 0 });
        put_u32(&mut out, g.n_layer as u32);
        put_u32(&mut out, g.n_head as u32);
        put_u32(&mut out, g.head_dim as u32);
        put_bytes(&mut out, rec.text.as_bytes());
        put_u32(&mut out, rec.tokens.len() as u32);
        for &t in &rec.tokens {
            put_u32(&mut out, t);
        }
        put_u32(&mut out, rec.embedding.len() as u32);
        for &e in &rec.embedding {
            out.extend_from_slice(&e.to_le_bytes());
        }
        let raw: Vec<u8> = payload.iter().flat_map(|f| f.to_le_bytes()).collect();
        put_u32(&mut out, payload.len() as u32);
        if compress {
            let mut enc = DeflateEncoder::new(Vec::new(), Compression::fast());
            enc.write_all(&raw).expect("in-memory deflate cannot fail");
            let packed = enc.finish().expect("in-memory deflate cannot fail");
            put_bytes(&mut out, &packed);
        } else {
            put_bytes(&mut out, &raw);
        }
        let crc = crc32::hash(&out);
        put_u32(&mut out, crc);
        out
    }

    #[test]
    fn bulk_encoder_byte_identical_to_reference() {
        let a = arena();
        let r = rec_in(&a);
        for compress in [false, true] {
            assert_eq!(
                to_bytes(&r, compress),
                to_bytes_reference(&r, compress),
                "compress={compress}"
            );
        }
    }

    #[test]
    fn size_estimate_is_exact() {
        // The encoder preallocates `total` and the debug_assert in
        // encode_v1 pins len == total; verify the estimate independently
        // here (capacity() == len() is not asserted — Vec::with_capacity
        // may legally over-allocate).
        let a = arena();
        let r = rec_in(&a);
        let out = to_bytes(&r, false);
        let expected = 6 * 4
            + 4 + r.text.len()
            + 4 + r.tokens.len() * 4
            + 4 + r.embedding.len() * 4
            + 4 + 4 + r.kv.to_contiguous().len() * 4
            + 4;
        assert_eq!(out.len(), expected, "exact-capacity estimate drifted");
    }

    #[test]
    fn raw_encoded_len_matches_raw_encoding() {
        let a = arena();
        let r = rec_in(&a);
        let parts = RecordParts::of(&r);
        assert_eq!(
            parts.raw_encoded_len(),
            to_bytes(&r, false).len(),
            "logical-size arithmetic drifted from the raw encoder"
        );
    }

    #[test]
    fn peek_tokens_matches_full_decode_and_rejects_corruption() {
        let a = arena();
        let r = rec_in(&a);
        for codec in [Codec::V1Raw, Codec::V1PayloadDeflate, Codec::V2Deflate] {
            let buf = encode(&RecordParts::of(&r), a.geometry(), codec);
            assert_eq!(peek_tokens(&buf).unwrap(), r.tokens, "{codec:?}");
            let mut bad = buf.clone();
            let mid = bad.len() / 2;
            bad[mid] ^= 0x10;
            assert!(peek_tokens(&bad).is_err(), "bitflip must not peek ({codec:?})");
            assert!(peek_tokens(&buf[..buf.len() / 2]).is_err(), "{codec:?}");
        }
    }

    #[test]
    fn roundtrip_uncompressed() {
        let a = arena();
        let r = rec_in(&a);
        let r2 = from_bytes(&to_bytes(&r, false), &a).unwrap();
        assert_eq!(r2.text, r.text);
        assert_eq!(r2.tokens, r.tokens);
        assert_eq!(r2.embedding, r.embedding);
        assert_eq!(r2.kv.to_contiguous(), r.kv.to_contiguous());
    }

    #[test]
    fn roundtrip_compressed_and_smaller() {
        let a = arena();
        let r = rec_in(&a);
        let plain = to_bytes(&r, false);
        let packed = to_bytes(&r, true);
        assert!(packed.len() < plain.len(), "{} !< {}", packed.len(), plain.len());
        let r2 = from_bytes(&packed, &a).unwrap();
        assert_eq!(r2.kv.to_contiguous(), r.kv.to_contiguous());
    }

    #[test]
    fn v2_roundtrip_and_smaller_than_raw() {
        let a = arena();
        let r = rec_in(&a);
        let parts = RecordParts::of(&r);
        let v2 = encode(&parts, a.geometry(), Codec::V2Deflate);
        assert!(
            v2.len() < parts.raw_encoded_len(),
            "whole-body deflate must beat raw: {} !< {}",
            v2.len(),
            parts.raw_encoded_len()
        );
        let r2 = from_bytes(&v2, &a).unwrap();
        assert_eq!(r2.text, r.text);
        assert_eq!(r2.tokens, r.tokens);
        assert_eq!(r2.embedding, r.embedding);
        assert_eq!(r2.kv.to_contiguous(), r.kv.to_contiguous());
    }

    #[test]
    fn v2_bitflip_and_truncation_detected() {
        let a = arena();
        let r = rec_in(&a);
        let buf = encode(&RecordParts::of(&r), a.geometry(), Codec::V2Deflate);
        for i in (0..buf.len()).step_by(buf.len() / 7 + 1) {
            let mut bad = buf.clone();
            bad[i] ^= 0x40;
            match from_bytes(&bad, &a) {
                Err(Error::Corrupt(_)) => {}
                other => panic!("bitflip at {i} not detected: {other:?}"),
            }
        }
        for cut in [1, buf.len() / 3, buf.len() - 1] {
            match from_bytes(&buf[..cut], &a) {
                Err(Error::Corrupt(_)) => {}
                other => panic!("truncation at {cut} not detected: {other:?}"),
            }
        }
    }

    #[test]
    fn v2_wrong_arena_geometry_rejected() {
        let a = arena();
        let r = rec_in(&a);
        let buf = encode(&RecordParts::of(&r), a.geometry(), Codec::V2Deflate);
        let mut other_cfg = ModelConfig::nano();
        other_cfg.n_layer = 2;
        let other = KvArena::new(&other_cfg, 16, 8);
        match from_bytes(&buf, &other) {
            Err(Error::ShapeMismatch(_)) => {}
            other => panic!("expected geometry mismatch: {other:?}"),
        }
    }

    #[test]
    fn bitflip_detected() {
        let a = arena();
        let r = rec_in(&a);
        for compress in [false, true] {
            let mut buf = to_bytes(&r, compress);
            let mid = buf.len() / 2;
            buf[mid] ^= 0x40;
            match from_bytes(&buf, &a) {
                Err(Error::Corrupt(_)) => {}
                other => panic!("bitflip not detected: {other:?}"),
            }
        }
    }

    #[test]
    fn truncation_detected() {
        let a = arena();
        let r = rec_in(&a);
        let buf = to_bytes(&r, false);
        for cut in [1, buf.len() / 3, buf.len() - 1] {
            assert!(from_bytes(&buf[..cut], &a).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn truncated_file_on_disk_rejected() {
        // a spill/persist file cut mid-write (crash, full disk) must load
        // as a typed error, never as a short-but-plausible record
        let dir = std::env::temp_dir().join(format!(
            "recycle_persist_trunc_{}",
            std::process::id()
        ));
        let path = dir.join("t.kv");
        let a = arena();
        let r = rec_in(&a);
        for codec in [Codec::V1Raw, Codec::V1PayloadDeflate, Codec::V2Deflate] {
            let buf = encode(&RecordParts::of(&r), a.geometry(), codec);
            save_bytes(&path, &buf).unwrap();
            let full = std::fs::read(&path).unwrap();
            std::fs::write(&path, &full[..full.len() / 2]).unwrap();
            match load(&path, &a) {
                Err(Error::Corrupt(_)) => {}
                other => panic!("truncated load not rejected ({codec:?}): {other:?}"),
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wrong_version_reported() {
        let a = arena();
        let r = rec_in(&a);
        let mut buf = to_bytes(&r, false);
        buf[4] = 99; // version field
        // fix crc so we reach the version check
        let n = buf.len();
        let crc = crc32::hash(&buf[..n - 4]);
        buf[n - 4..].copy_from_slice(&crc.to_le_bytes());
        match from_bytes(&buf, &a) {
            Err(Error::Version(99)) => {}
            other => panic!("expected Version error: {other:?}"),
        }
        match peek_tokens(&buf) {
            Err(Error::Version(99)) => {}
            other => panic!("expected Version error from peek: {other:?}"),
        }
    }

    #[test]
    fn wrong_arena_geometry_rejected() {
        let a = arena();
        let r = rec_in(&a);
        let buf = to_bytes(&r, false);
        let mut other_cfg = ModelConfig::nano();
        other_cfg.n_layer = 2;
        let other = KvArena::new(&other_cfg, 16, 8);
        match from_bytes(&buf, &other) {
            Err(Error::ShapeMismatch(_)) => {}
            other => panic!("expected geometry mismatch: {other:?}"),
        }
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("recycle_serve_persist_test");
        let path = dir.join("a.kv");
        let a = arena();
        let r = rec_in(&a);
        save(&r, &path, true).unwrap();
        let r2 = load(&path, &a).unwrap();
        assert_eq!(r2.kv.to_contiguous(), r.kv.to_contiguous());
        std::fs::remove_dir_all(&dir).ok();
    }
}
