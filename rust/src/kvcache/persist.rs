//! Disk persistence for KV records — the `torch.save` stand-in.
//!
//! File layout (little-endian):
//!
//! ```text
//! magic   u32  = 0x4B56_5243  ("KVRC")
//! version u32  = 1
//! flags   u32  (bit 0: payload DEFLATE-compressed)
//! geometry: n_layer u32, n_head u32, head_dim u32
//! text:      len u32, utf-8 bytes
//! tokens:    len u32, u32 ids
//! embedding: len u32, f32 values
//! payload:   raw_len u32 (f32 count), stored_len u32 (bytes), bytes
//! crc32 u32 over everything above
//! ```
//!
//! Corruption (bit flips, truncation) must surface as `Error::Corrupt` —
//! never as a silently wrong KV tensor; the integration tests inject both.

use std::io::{Read, Write};
use std::path::Path;
use std::sync::Arc;

use flate2::read::DeflateDecoder;
use flate2::write::DeflateEncoder;
use flate2::Compression;

use crate::error::{Error, Result};

use super::KvRecord;

const MAGIC: u32 = 0x4B56_5243;
const VERSION: u32 = 1;
const FLAG_COMPRESSED: u32 = 1;

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_bytes(buf: &mut Vec<u8>, b: &[u8]) {
    put_u32(buf, b.len() as u32);
    buf.extend_from_slice(b);
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(Error::Corrupt("truncated file".into()));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
}

/// Serialize a record to bytes.
pub fn to_bytes(rec: &KvRecord, compress: bool) -> Vec<u8> {
    let mut out = Vec::with_capacity(64 + rec.kv.len() * 4);
    put_u32(&mut out, MAGIC);
    put_u32(&mut out, VERSION);
    put_u32(&mut out, if compress { FLAG_COMPRESSED } else { 0 });
    put_u32(&mut out, rec.n_layer as u32);
    put_u32(&mut out, rec.n_head as u32);
    put_u32(&mut out, rec.head_dim as u32);
    put_bytes(&mut out, rec.text.as_bytes());
    put_u32(&mut out, rec.tokens.len() as u32);
    for &t in &rec.tokens {
        put_u32(&mut out, t);
    }
    put_u32(&mut out, rec.embedding.len() as u32);
    for &e in &rec.embedding {
        out.extend_from_slice(&e.to_le_bytes());
    }
    // payload
    let raw: Vec<u8> = rec.kv.iter().flat_map(|f| f.to_le_bytes()).collect();
    put_u32(&mut out, rec.kv.len() as u32);
    if compress {
        let mut enc = DeflateEncoder::new(Vec::new(), Compression::fast());
        enc.write_all(&raw).expect("in-memory deflate cannot fail");
        let packed = enc.finish().expect("in-memory deflate cannot fail");
        put_bytes(&mut out, &packed);
    } else {
        put_bytes(&mut out, &raw);
    }
    let crc = crc32fast::hash(&out);
    put_u32(&mut out, crc);
    out
}

/// Deserialize a record from bytes, verifying the checksum.
pub fn from_bytes(buf: &[u8]) -> Result<KvRecord> {
    if buf.len() < 8 {
        return Err(Error::Corrupt("file too small".into()));
    }
    let (body, crc_bytes) = buf.split_at(buf.len() - 4);
    let want = u32::from_le_bytes(crc_bytes.try_into().unwrap());
    if crc32fast::hash(body) != want {
        return Err(Error::Corrupt("crc mismatch".into()));
    }
    let mut r = Reader { buf: body, pos: 0 };
    if r.u32()? != MAGIC {
        return Err(Error::Corrupt("bad magic".into()));
    }
    let version = r.u32()?;
    if version != VERSION {
        return Err(Error::Version(version));
    }
    let flags = r.u32()?;
    let n_layer = r.u32()? as usize;
    let n_head = r.u32()? as usize;
    let head_dim = r.u32()? as usize;
    let text_len = r.u32()? as usize;
    let text = String::from_utf8(r.take(text_len)?.to_vec())
        .map_err(|_| Error::Corrupt("bad utf8 in text".into()))?;
    let n_tokens = r.u32()? as usize;
    let mut tokens = Vec::with_capacity(n_tokens);
    for _ in 0..n_tokens {
        tokens.push(r.u32()?);
    }
    let n_emb = r.u32()? as usize;
    let mut embedding = Vec::with_capacity(n_emb);
    for _ in 0..n_emb {
        let b = r.take(4)?;
        embedding.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
    }
    let raw_len = r.u32()? as usize;
    let stored_len = r.u32()? as usize;
    let stored = r.take(stored_len)?;
    let raw = if flags & FLAG_COMPRESSED != 0 {
        let mut dec = DeflateDecoder::new(stored);
        let mut out = Vec::with_capacity(raw_len * 4);
        dec.read_to_end(&mut out)
            .map_err(|e| Error::Corrupt(format!("deflate: {e}")))?;
        out
    } else {
        stored.to_vec()
    };
    if raw.len() != raw_len * 4 {
        return Err(Error::Corrupt(format!(
            "payload length {} != declared {}",
            raw.len(),
            raw_len * 4
        )));
    }
    let kv: Vec<f32> = raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    if r.pos != body.len() {
        return Err(Error::Corrupt("trailing bytes".into()));
    }
    Ok(KvRecord {
        text,
        tokens,
        embedding,
        kv: Arc::new(kv),
        n_layer,
        n_head,
        head_dim,
    })
}

/// Save to a file (atomic: write temp then rename).
pub fn save(rec: &KvRecord, path: &Path, compress: bool) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, to_bytes(rec, compress))?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Load from a file.
pub fn load(path: &Path) -> Result<KvRecord> {
    let buf = std::fs::read(path)?;
    from_bytes(&buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn rec() -> KvRecord {
        let cfg = ModelConfig::nano();
        let full: Vec<f32> = (0..cfg.kv_elems()).map(|i| (i % 97) as f32 * 0.5).collect();
        KvRecord::from_full_buffer(&cfg, "the prompt", vec![4, 7, 9], vec![0.1, -0.2], &full)
    }

    #[test]
    fn roundtrip_uncompressed() {
        let r = rec();
        let r2 = from_bytes(&to_bytes(&r, false)).unwrap();
        assert_eq!(r2.text, r.text);
        assert_eq!(r2.tokens, r.tokens);
        assert_eq!(r2.embedding, r.embedding);
        assert_eq!(*r2.kv, *r.kv);
    }

    #[test]
    fn roundtrip_compressed_and_smaller() {
        let r = rec();
        let plain = to_bytes(&r, false);
        let packed = to_bytes(&r, true);
        assert!(packed.len() < plain.len(), "{} !< {}", packed.len(), plain.len());
        let r2 = from_bytes(&packed).unwrap();
        assert_eq!(*r2.kv, *r.kv);
    }

    #[test]
    fn bitflip_detected() {
        let r = rec();
        for compress in [false, true] {
            let mut buf = to_bytes(&r, compress);
            let mid = buf.len() / 2;
            buf[mid] ^= 0x40;
            match from_bytes(&buf) {
                Err(Error::Corrupt(_)) => {}
                other => panic!("bitflip not detected: {other:?}"),
            }
        }
    }

    #[test]
    fn truncation_detected() {
        let r = rec();
        let buf = to_bytes(&r, false);
        for cut in [1, buf.len() / 3, buf.len() - 1] {
            assert!(from_bytes(&buf[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn wrong_version_reported() {
        let r = rec();
        let mut buf = to_bytes(&r, false);
        buf[4] = 99; // version field
        // fix crc so we reach the version check
        let n = buf.len();
        let crc = crc32fast::hash(&buf[..n - 4]);
        buf[n - 4..].copy_from_slice(&crc.to_le_bytes());
        match from_bytes(&buf) {
            Err(Error::Version(99)) => {}
            other => panic!("expected Version error: {other:?}"),
        }
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("recycle_serve_persist_test");
        let path = dir.join("a.kv");
        let r = rec();
        save(&r, &path, true).unwrap();
        let r2 = load(&path).unwrap();
        assert_eq!(*r2.kv, *r.kv);
        std::fs::remove_dir_all(&dir).ok();
    }
}
