//! Per-tenant QoS for the streaming front: weighted deficit round-robin
//! admission over bounded per-tenant queues, plus a queue-wait-driven
//! overload gate.
//!
//! The front parses requests off sockets faster than workers drain them;
//! without an admission layer one chatty tenant's burst would occupy the
//! whole downstream queue and starve everyone else. [`TenantQueues`]
//! holds each tenant's backlog separately (bounded by
//! `ServerConfig::tenant_queue_capacity` — a full queue sheds with a
//! typed `Overloaded`, never silently) and releases work by **weighted
//! deficit round-robin** in *token* units: a visit credits a tenant
//! `quantum × weight` tokens of deficit, and popping a request debits
//! its decode budget (`max_new_tokens`). Over any backlogged interval
//! each tenant's admitted token share converges to `weight / Σweights` —
//! the fairness bound the streaming ablation bench asserts.
//!
//! [`OverloadMonitor`] is the shed gate: it differences successive
//! `SchedulerStats` snapshots (`queue_wait_ms_total` / `admitted`) into
//! a recent-average worker queue wait, and trips when that exceeds
//! `ServerConfig::qos_shed_wait_ms` (0 disables the gate). While
//! tripped, the front rejects *new* arrivals with `Overloaded` instead
//! of queuing them into an ever-growing latency tail; already-queued
//! requests keep draining.
//!
//! Both pieces are pure data structures (no sockets, no threads) so the
//! fairness math is unit-tested here, independent of the event loop.

use std::collections::{BTreeMap, VecDeque};

/// One tenant's backlog plus its running DRR deficit (in tokens).
struct TenantQueue<T> {
    deficit: usize,
    items: VecDeque<(usize, T)>, // (cost in tokens, item)
}

/// Bounded per-tenant queues drained by weighted deficit round-robin.
///
/// `T` is the queued request; the container never inspects it, so the
/// event loop can queue whatever bookkeeping it needs. Costs are
/// attached at push time and must be repeated verbatim on
/// [`TenantQueues::unpop`] so deficit accounting stays exact.
pub struct TenantQueues<T> {
    capacity: usize,
    quantum: usize,
    default_weight: usize,
    weights: BTreeMap<String, usize>,
    queues: BTreeMap<String, TenantQueue<T>>,
    /// Round-robin order (first-appearance order) and the DRR cursor.
    order: Vec<String>,
    cursor: usize,
    len: usize,
}

impl<T> TenantQueues<T> {
    pub fn new(
        capacity: usize,
        quantum: usize,
        default_weight: usize,
        weights: &[(String, usize)],
    ) -> Self {
        TenantQueues {
            capacity: capacity.max(1),
            quantum: quantum.max(1),
            default_weight: default_weight.max(1),
            weights: weights.iter().cloned().collect(),
            queues: BTreeMap::new(),
            order: Vec::new(),
            cursor: 0,
            len: 0,
        }
    }

    /// The configured weight for `tenant` (default for unlisted tenants).
    pub fn weight_of(&self, tenant: &str) -> usize {
        self.weights
            .get(tenant)
            .copied()
            .unwrap_or(self.default_weight)
    }

    /// Total queued requests across all tenants.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Per-tenant queue bound (the shed threshold).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Queued requests for one tenant.
    pub fn depth(&self, tenant: &str) -> usize {
        self.queues.get(tenant).map_or(0, |q| q.items.len())
    }

    /// Enqueue at `cost` tokens; `Err(item)` when the tenant's queue is
    /// full (the caller sheds with a typed `Overloaded`).
    pub fn push(&mut self, tenant: &str, cost: usize, item: T) -> Result<(), T> {
        let q = match self.queues.get_mut(tenant) {
            Some(q) => q,
            None => {
                self.order.push(tenant.to_string());
                self.queues
                    .entry(tenant.to_string())
                    .or_insert_with(|| TenantQueue {
                        deficit: 0,
                        items: VecDeque::new(),
                    })
            }
        };
        if q.items.len() >= self.capacity {
            return Err(item);
        }
        q.items.push_back((cost, item));
        self.len += 1;
        Ok(())
    }

    /// Requeue a popped item at the *front* of its tenant's queue,
    /// restoring the deficit the pop debited. Used when the downstream
    /// worker queue rejects: the request was already admitted here, so
    /// it bypasses the capacity bound and keeps its drain position.
    pub fn unpop(&mut self, tenant: &str, cost: usize, item: T) {
        let q = match self.queues.get_mut(tenant) {
            Some(q) => q,
            None => {
                self.order.push(tenant.to_string());
                self.queues
                    .entry(tenant.to_string())
                    .or_insert_with(|| TenantQueue {
                        deficit: 0,
                        items: VecDeque::new(),
                    })
            }
        };
        q.deficit = q.deficit.saturating_add(cost);
        q.items.push_front((cost, item));
        self.len += 1;
    }

    /// The next request under weighted deficit round-robin, with its
    /// tenant key. Visiting a backlogged tenant whose deficit can't
    /// cover its head-of-line cost credits `quantum × weight` and moves
    /// on; service therefore interleaves tenants at token granularity
    /// proportional to weight. A tenant's deficit resets when its queue
    /// drains (classic DRR — idle tenants bank no credit).
    pub fn pop(&mut self) -> Option<(String, T)> {
        if self.len == 0 {
            return None;
        }
        loop {
            if self.cursor >= self.order.len() {
                self.cursor = 0;
            }
            let name = self.order[self.cursor].clone();
            let weight = self.weight_of(&name);
            let q = self.queues.get_mut(&name).expect("ordered tenant exists");
            let Some(&(cost, _)) = q.items.front() else {
                q.deficit = 0;
                self.cursor += 1;
                continue;
            };
            if q.deficit >= cost {
                q.deficit -= cost;
                let (_, item) = q.items.pop_front().expect("non-empty front");
                if q.items.is_empty() {
                    q.deficit = 0;
                    self.cursor += 1;
                }
                self.len -= 1;
                return Some((name, item));
            }
            q.deficit = q.deficit.saturating_add(self.quantum * weight);
            self.cursor += 1;
        }
    }

    /// Drain queued items matching `expired`, front-first per tenant
    /// (arrival times are monotone within a tenant's FIFO, so expiry is
    /// always a prefix). Returns the expired items with their tenants.
    pub fn expire<F: FnMut(&T) -> bool>(&mut self, mut expired: F) -> Vec<(String, T)> {
        let mut out = Vec::new();
        for (name, q) in self.queues.iter_mut() {
            while q.items.front().is_some_and(|(_, it)| expired(it)) {
                let (_, item) = q.items.pop_front().expect("non-empty front");
                self.len -= 1;
                out.push((name.clone(), item));
            }
        }
        out
    }

    /// Does any queued item match `f`? (Connection-reap bookkeeping.)
    pub fn any<F: Fn(&T) -> bool>(&self, f: F) -> bool {
        self.queues
            .values()
            .any(|q| q.items.iter().any(|(_, it)| f(it)))
    }
}

/// Queue-wait-driven overload gate over successive scheduler snapshots.
///
/// The front can't see worker queue wait directly — only the cumulative
/// `queue_wait_ms_total` / `admitted` counters in `SchedulerStats`.
/// Differencing consecutive snapshots yields the average wait of the
/// *recently* admitted requests, which is the live overload signal: it
/// climbs as soon as queues back up and falls as they drain, where the
/// all-time average would lag both ways.
#[derive(Debug)]
pub struct OverloadMonitor {
    shed_wait_ms: u64,
    last_total: u64,
    last_admitted: u64,
    overloaded: bool,
}

impl OverloadMonitor {
    /// `shed_wait_ms = 0` disables the gate (never overloaded).
    pub fn new(shed_wait_ms: u64) -> Self {
        OverloadMonitor {
            shed_wait_ms,
            last_total: 0,
            last_admitted: 0,
            overloaded: false,
        }
    }

    /// Feed a snapshot of the cumulative counters; returns the updated
    /// gate state. Snapshots with no new admissions keep the previous
    /// verdict (no information either way).
    pub fn observe(&mut self, queue_wait_ms_total: u64, admitted: u64) -> bool {
        if self.shed_wait_ms == 0 {
            return false;
        }
        let dw = queue_wait_ms_total.saturating_sub(self.last_total);
        let dn = admitted.saturating_sub(self.last_admitted);
        if dn > 0 {
            self.overloaded = dw / dn >= self.shed_wait_ms;
            self.last_total = queue_wait_ms_total;
            self.last_admitted = admitted;
        }
        self.overloaded
    }

    /// The gate's current verdict (last `observe` outcome).
    pub fn is_overloaded(&self) -> bool {
        self.shed_wait_ms > 0 && self.overloaded
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drain `pops` items, summing each popped item as its token cost
    /// (the tests push the cost as the item so shares are observable).
    fn drain_tokens(q: &mut TenantQueues<usize>, pops: usize) -> BTreeMap<String, usize> {
        let mut served: BTreeMap<String, usize> = BTreeMap::new();
        for _ in 0..pops {
            let Some((tenant, cost)) = q.pop() else { break };
            *served.entry(tenant).or_insert(0) += cost;
        }
        served
    }

    #[test]
    fn equal_weights_share_tokens_equally() {
        let mut q = TenantQueues::new(1000, 8, 1, &[]);
        for _ in 0..100 {
            q.push("a", 8, 8).unwrap();
            q.push("b", 8, 8).unwrap();
        }
        let served = drain_tokens(&mut q, 100);
        let (a, b) = (served["a"], served["b"]);
        assert!(
            (a as i64 - b as i64).unsigned_abs() <= 8,
            "equal weights must serve equal token shares: a={a} b={b}"
        );
    }

    #[test]
    fn weighted_tenants_get_proportional_shares() {
        // b at weight 2 must drain ~2x a's tokens over any backlogged
        // window, independent of arrival interleaving
        let weights = vec![("b".to_string(), 2usize)];
        let mut q = TenantQueues::new(1000, 4, 1, &weights);
        for _ in 0..200 {
            q.push("a", 4, 4).unwrap();
            q.push("b", 4, 4).unwrap();
        }
        let served = drain_tokens(&mut q, 150);
        let (a, b) = (served["a"] as f64, served["b"] as f64);
        let ratio = b / a;
        assert!(
            (1.7..=2.3).contains(&ratio),
            "weight 2:1 must serve ~2:1 tokens, got {b}:{a} ({ratio:.2})"
        );
    }

    #[test]
    fn unequal_costs_still_split_by_tokens_not_requests() {
        // a sends 16-token requests, b sends 4-token requests at equal
        // weight: b must pop ~4x as many REQUESTS (same token share)
        let mut q = TenantQueues::new(1000, 8, 1, &[]);
        for _ in 0..100 {
            q.push("a", 16, 16).unwrap();
        }
        for _ in 0..400 {
            q.push("b", 4, 4).unwrap();
        }
        let mut reqs: BTreeMap<String, usize> = BTreeMap::new();
        let mut toks: BTreeMap<String, usize> = BTreeMap::new();
        for _ in 0..200 {
            let (tenant, cost) = q.pop().unwrap();
            *reqs.entry(tenant.clone()).or_insert(0) += 1;
            *toks.entry(tenant).or_insert(0) += cost;
        }
        let ratio = toks["a"] as f64 / toks["b"] as f64;
        assert!(
            (0.75..=1.35).contains(&ratio),
            "token shares must stay near equal despite 4x cost skew: {toks:?}"
        );
        assert!(
            reqs["b"] > reqs["a"] * 3,
            "cheap requests must pop more often: {reqs:?}"
        );
    }

    #[test]
    fn full_tenant_queue_sheds_without_touching_others() {
        let mut q = TenantQueues::new(2, 8, 1, &[]);
        q.push("a", 1, 0).unwrap();
        q.push("a", 1, 1).unwrap();
        assert_eq!(q.push("a", 1, 2), Err(2), "third push must shed");
        // an unrelated tenant is unaffected by a's full queue
        q.push("b", 1, 0).unwrap();
        assert_eq!(q.depth("a"), 2);
        assert_eq!(q.depth("b"), 1);
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn unpop_restores_drain_position_and_deficit() {
        let mut q = TenantQueues::new(10, 8, 1, &[]);
        q.push("a", 8, 1).unwrap();
        q.push("a", 8, 2).unwrap();
        let (t, item) = q.pop().unwrap();
        assert_eq!((t.as_str(), item), ("a", 1));
        q.unpop("a", 8, item);
        // the requeued item pops first again — position preserved
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert!(q.pop().is_none());
    }

    #[test]
    fn expire_drains_matching_prefix_per_tenant() {
        let mut q = TenantQueues::new(10, 8, 1, &[]);
        q.push("a", 1, 10).unwrap(); // "old"
        q.push("a", 1, 99).unwrap(); // "fresh"
        q.push("b", 1, 11).unwrap(); // "old"
        let dead = q.expire(|it| *it < 50);
        let mut tenants: Vec<&str> = dead.iter().map(|(t, _)| t.as_str()).collect();
        tenants.sort_unstable();
        assert_eq!(tenants, vec!["a", "b"]);
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop().unwrap().1, 99);
    }

    #[test]
    fn empty_pop_returns_none_and_any_scans_items() {
        let mut q: TenantQueues<usize> = TenantQueues::new(4, 8, 1, &[]);
        assert!(q.pop().is_none());
        assert!(q.is_empty());
        q.push("a", 1, 7).unwrap();
        assert!(q.any(|it| *it == 7));
        assert!(!q.any(|it| *it == 8));
    }

    #[test]
    fn monitor_disabled_at_zero_threshold() {
        let mut m = OverloadMonitor::new(0);
        assert!(!m.observe(1_000_000, 1));
        assert!(!m.is_overloaded());
    }

    #[test]
    fn monitor_trips_on_recent_wait_and_recovers() {
        let mut m = OverloadMonitor::new(100);
        // 10 admissions, 50ms average wait: healthy
        assert!(!m.observe(500, 10));
        // next 10 admissions waited 300ms each: tripped
        assert!(m.observe(500 + 3000, 20));
        assert!(m.is_overloaded());
        // the NEXT window drains fast (10ms each): recovers, even though
        // the all-time average is still high
        assert!(!m.observe(3500 + 100, 30));
        assert!(!m.is_overloaded());
        // no new admissions: verdict unchanged
        assert!(!m.observe(3600, 30));
    }
}
