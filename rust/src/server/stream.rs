//! The nonblocking streaming network front: one event-loop thread over
//! `std::net` readiness polling (no async runtime — tokio/mio are not in
//! the offline vendor set, and one loop thread is the right size for a
//! single-host worker fleet).
//!
//! # Event loop
//!
//! Every pass over the loop does, in order:
//!
//! 1. **accept** — drain the nonblocking listener into connection slots
//!    (slot indices are recycled behind a generation counter, so a late
//!    frame for a closed connection can never reach its slot's new owner);
//! 2. **read** — nonblocking reads per connection into a byte buffer;
//!    complete `\n`-terminated lines are parsed and admitted
//!    ([`FaultSite::ClientStall`] skips one connection's read pass —
//!    a stalled client must never stall the loop);
//! 3. **pump** — release front-queued requests into the coordinator by
//!    weighted deficit round-robin ([`super::qos::TenantQueues`]); a
//!    downstream `Overloaded` requeues at the front and ends the pass
//!    (backpressure, not a hot retry loop);
//! 4. **poll** — `try_recv` every in-flight request's channels, turning
//!    [`StreamEvent`]s into wire frames the same pass the worker tick
//!    emitted them (this is what makes TTFT client-visible: first token
//!    frame hits the write buffer one loop pass after the model produced
//!    the token, not after the whole reply);
//! 5. **flush** — write each connection's buffered frames; partial
//!    writes (`WouldBlock` or [`FaultSite::TornClientWrite`]) keep the
//!    unwritten tail buffered, so framing is delayed, never torn;
//! 6. **reap** — drop dead connections and half-closed ones that have
//!    drained; release is visible to tests as arena conservation.
//!
//! With no activity the loop sleeps 1ms, which also bounds
//! [`Server::stop`] latency: the shutdown flag is checked every pass, so
//! stop completes in single-digit milliseconds with clients still
//! connected — no 50ms read-timeout poll to ride out.
//!
//! # Admission / QoS
//!
//! Requests carry an optional `"tenant"` label. Each tenant gets a
//! bounded front queue (`tenant_queue_capacity`; full ⇒ typed
//! `overloaded` reply) drained in token-weighted round-robin
//! (`qos_quantum_tokens` × per-tenant weight from `tenant_weights` /
//! `qos_default_weight`), so a flooding tenant saturates its own queue
//! while everyone else's goodput tracks their fair share. Two further
//! gates: requests queued at the front longer than `request_timeout_ms`
//! die with a typed `deadline_exceeded`, and when the live per-worker
//! queue wait (differenced from `CoordinatorStats::scheduler` snapshots)
//! exceeds `qos_shed_wait_ms`, new arrivals shed immediately with
//! `overloaded` instead of joining the latency tail.
//!
//! See [`super`] (the module docs) for the wire-level frame grammar.

use std::collections::BTreeMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::ServerConfig;
use crate::coordinator::{Coordinator, Response, StreamEvent, SubmitOptions};
use crate::error::{Error, Result};
use crate::faults::{FaultHandle, FaultSite};
use crate::metrics::TenantCounters;
use crate::util::json::{self, Value};

use super::qos::{OverloadMonitor, TenantQueues};
use super::tcp::{error_reply, response_reply};

/// Idle sleep between loop passes; also the shutdown-latency bound.
const IDLE_TICK: Duration = Duration::from_millis(1);

/// How often the overload monitor re-snapshots scheduler stats.
const MONITOR_PERIOD: Duration = Duration::from_millis(10);

/// Tenant key used for requests without a `"tenant"` field.
pub const ANON_TENANT: &str = "anon";

/// Running server handle over the event-loop thread.
pub struct Server {
    addr: SocketAddr,
    thread: Option<JoinHandle<()>>,
    shutdown: Arc<AtomicBool>,
}

impl Server {
    /// Bind and start serving on `listen` ("host:port"; port 0 picks a
    /// free port — the bound address is available via [`Server::addr`]).
    pub fn start(coordinator: Arc<Coordinator>, listen: &str) -> Result<Server> {
        Server::start_with_faults(coordinator, listen, FaultHandle::off())
    }

    /// [`Server::start`] with a fault handle armed at the front's client
    /// seams ([`FaultSite::ClientStall`], [`FaultSite::TornClientWrite`])
    /// — the chaos suites drive the event loop through this.
    pub fn start_with_faults(
        coordinator: Arc<Coordinator>,
        listen: &str,
        faults: FaultHandle,
    ) -> Result<Server> {
        let listener = TcpListener::bind(listen)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&shutdown);
        let thread = std::thread::Builder::new()
            .name("recycle-server-front".into())
            .spawn(move || event_loop(listener, coordinator, faults, flag))
            .expect("spawn server event loop");
        Ok(Server {
            addr,
            thread: Some(thread),
            shutdown,
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Signal the event loop and join it. Readiness-driven: the loop
    /// observes the flag within one pass (≤ [`IDLE_TICK`] plus work in
    /// flight), closes the listener and every connection, and exits —
    /// no per-connection read timeouts to ride out.
    pub fn stop(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Stable reference to a connection slot: the generation guard makes
/// frames addressed to a closed connection drop instead of reaching the
/// slot's next occupant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct ConnId {
    slot: usize,
    gen: u64,
}

/// One client connection's loop-local state.
struct Conn {
    stream: TcpStream,
    /// Bytes read but not yet framed into a complete line.
    rbuf: Vec<u8>,
    /// Frames serialized but not yet written to the socket.
    wbuf: Vec<u8>,
    /// Aggregate-reply FIFO tickets: replies are written in request
    /// order per connection (the blocking protocol's contract), so a
    /// fast request completing behind a slow one parks in `agg_done`.
    agg_issued: u64,
    agg_next: u64,
    agg_done: BTreeMap<u64, Value>,
    /// Read side closed (EOF / half-close): keep flushing until every
    /// in-flight reply for this connection has drained, then reap.
    eof: bool,
    /// Socket error: reap immediately, dropping buffered output.
    dead: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Self {
        Conn {
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            agg_issued: 0,
            agg_next: 0,
            agg_done: BTreeMap::new(),
            eof: false,
            dead: false,
        }
    }
}

/// A parsed request waiting in the per-tenant front queues.
struct Pending {
    conn: ConnId,
    /// Client-chosen request id, echoed verbatim on every frame.
    rid: Option<Value>,
    /// Streaming (`"stream": true`) or aggregate reply mode.
    streaming: bool,
    /// FIFO ticket for aggregate replies (unused when streaming).
    agg_seq: u64,
    tenant: Option<String>,
    prompt: String,
    max_new: usize,
    session: Option<String>,
    /// WDRR token cost debited at pop; repeated on requeue.
    cost: usize,
    /// Front arrival time: the deadline clock and the TTFT origin.
    queued: Instant,
}

/// A request submitted to the coordinator, awaiting events/reply.
struct Inflight {
    conn: ConnId,
    rid: Option<Value>,
    streaming: bool,
    agg_seq: u64,
    tenant: String,
    reply_rx: mpsc::Receiver<Response>,
    event_rx: Option<mpsc::Receiver<StreamEvent>>,
    queued: Instant,
    /// Next expected token index; frames below it are replays after a
    /// defensive truncation and are dropped (fault-free streams are
    /// strictly increasing — see [`StreamEvent`]).
    next_index: usize,
    got_first: bool,
    done: bool,
}

/// A frame ready for delivery, tagged with its write discipline.
enum Delivery {
    /// Streaming frame: appended to the write buffer immediately.
    Frame(Value),
    /// Aggregate reply: enters the per-connection FIFO at its ticket.
    Agg(u64, Value),
}

/// Loop-local server state (single-threaded: no locks anywhere).
struct Front {
    coordinator: Arc<Coordinator>,
    faults: FaultHandle,
    cfg: ServerConfig,
    conns: Vec<Option<Conn>>,
    gens: Vec<u64>,
    qos: TenantQueues<Pending>,
    monitor: OverloadMonitor,
    inflight: Vec<Inflight>,
    tenants: BTreeMap<String, TenantCounters>,
}

/// Bump a tenant's counters (free function so it can run while a field
/// of `Front` is mutably borrowed — disjoint-field discipline).
fn tally<F: FnOnce(&mut TenantCounters)>(
    tenants: &mut BTreeMap<String, TenantCounters>,
    tenant: &str,
    f: F,
) {
    f(tenants.entry(tenant.to_string()).or_default());
}

fn token_frame(rid: &Option<Value>, index: usize, id: u32, text: &str) -> Value {
    let mut fields = vec![("event", json::s("token"))];
    if let Some(r) = rid {
        fields.push(("rid", r.clone()));
    }
    fields.push(("index", json::n(index as f64)));
    fields.push(("id", json::n(id as f64)));
    fields.push(("text", json::s(text)));
    json::obj(fields)
}

/// Terminal frame for a stream: `done` (success payload identical to
/// the aggregate reply) or `error` (message + taxonomy kind).
fn terminal_frame(rid: &Option<Value>, resp: &Response) -> Value {
    let mut fields = match resp {
        Response::Ok(_) => vec![("event", json::s("done"))],
        Response::Err { .. } => vec![("event", json::s("error"))],
    };
    if let Some(r) = rid {
        fields.push(("rid", r.clone()));
    }
    match resp {
        Response::Ok(o) => {
            fields.push(("ok", json::b(true)));
            fields.push(("output", json::s(&o.text)));
            fields.push(("latency_s", json::n(o.latency_s)));
            fields.push(("reuse_depth", json::n(o.reuse_depth as f64)));
            fields.push(("cache_hit", json::b(o.cache_hit)));
            fields.push(("prompt_tokens", json::n(o.prompt_tokens as f64)));
            fields.push(("new_tokens", json::n(o.ids.len() as f64)));
        }
        Response::Err { msg, kind } => {
            fields.push(("ok", json::b(false)));
            fields.push(("error", json::s(msg)));
            fields.push(("error_kind", json::s(kind)));
        }
    }
    json::obj(fields)
}

fn error_event(rid: &Option<Value>, e: &Error) -> Value {
    terminal_frame(rid, &Response::err(e))
}

fn event_loop(
    listener: TcpListener,
    coordinator: Arc<Coordinator>,
    faults: FaultHandle,
    shutdown: Arc<AtomicBool>,
) {
    let cfg = coordinator.config().clone();
    let mut front = Front {
        qos: TenantQueues::new(
            cfg.tenant_queue_capacity,
            cfg.qos_quantum_tokens,
            cfg.qos_default_weight,
            &cfg.tenant_weights,
        ),
        monitor: OverloadMonitor::new(cfg.qos_shed_wait_ms),
        coordinator,
        faults,
        cfg,
        conns: Vec::new(),
        gens: Vec::new(),
        inflight: Vec::new(),
        tenants: BTreeMap::new(),
    };
    let mut last_snapshot = Instant::now() - MONITOR_PERIOD;
    loop {
        if shutdown.load(Ordering::Relaxed) {
            break; // listener + conns drop here: ports and fds released
        }
        let mut activity = false;
        activity |= front.accept_pass(&listener);
        activity |= front.read_pass();
        if last_snapshot.elapsed() >= MONITOR_PERIOD {
            last_snapshot = Instant::now();
            let s = front.coordinator.stats().scheduler;
            front.monitor.observe(s.queue_wait_ms_total, s.admitted);
        }
        activity |= front.pump();
        activity |= front.poll_inflight();
        activity |= front.flush_pass();
        front.reap();
        if !activity {
            std::thread::sleep(IDLE_TICK);
        }
    }
}

impl Front {
    // --- connection plumbing ------------------------------------------------

    fn conn_mut(&mut self, cid: ConnId) -> Option<&mut Conn> {
        if self.gens.get(cid.slot) != Some(&cid.gen) {
            return None;
        }
        self.conns.get_mut(cid.slot).and_then(|c| c.as_mut())
    }

    /// Append a serialized frame to a connection's write buffer.
    fn write_frame(&mut self, cid: ConnId, v: Value) {
        if let Some(conn) = self.conn_mut(cid) {
            conn.wbuf.extend_from_slice((v.to_json() + "\n").as_bytes());
        }
    }

    /// Allocate the next aggregate FIFO ticket for a connection.
    fn next_agg_seq(&mut self, cid: ConnId) -> u64 {
        match self.conn_mut(cid) {
            Some(conn) => {
                let seq = conn.agg_issued;
                conn.agg_issued += 1;
                seq
            }
            None => 0,
        }
    }

    /// Complete an aggregate request: park the reply at its ticket and
    /// release the in-order prefix into the write buffer.
    fn complete_aggregate(&mut self, cid: ConnId, seq: u64, v: Value) {
        if let Some(conn) = self.conn_mut(cid) {
            conn.agg_done.insert(seq, v);
            while let Some(ready) = conn.agg_done.remove(&conn.agg_next) {
                conn.wbuf
                    .extend_from_slice((ready.to_json() + "\n").as_bytes());
                conn.agg_next += 1;
            }
        }
    }

    /// Reply to a request that never entered the queues (parse errors,
    /// control commands): allocate a ticket and complete it at once, so
    /// even immediate replies respect per-connection FIFO order.
    fn finish_aggregate_now(&mut self, cid: ConnId, v: Value) {
        let seq = self.next_agg_seq(cid);
        self.complete_aggregate(cid, seq, v);
    }

    /// Typed failure for a parsed-but-unserved request, routed per its
    /// reply mode (stream error event vs aggregate error object).
    fn deliver_error(&mut self, p: &Pending, e: &Error) {
        if p.streaming {
            let frame = error_event(&p.rid, e);
            self.write_frame(p.conn, frame);
        } else {
            self.complete_aggregate(p.conn, p.agg_seq, error_reply(e));
        }
    }

    // --- loop passes --------------------------------------------------------

    fn accept_pass(&mut self, listener: &TcpListener) -> bool {
        let mut activity = false;
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    let _ = stream.set_nonblocking(true);
                    let _ = stream.set_nodelay(true);
                    let conn = Conn::new(stream);
                    match self.conns.iter().position(|c| c.is_none()) {
                        Some(slot) => self.conns[slot] = Some(conn),
                        None => {
                            self.conns.push(Some(conn));
                            self.gens.push(0);
                        }
                    }
                    activity = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }
        activity
    }

    fn read_pass(&mut self) -> bool {
        let mut activity = false;
        let mut lines: Vec<(ConnId, Vec<u8>)> = Vec::new();
        for slot in 0..self.conns.len() {
            let gen = self.gens[slot];
            let Some(conn) = self.conns[slot].as_mut() else {
                continue;
            };
            if conn.eof || conn.dead {
                continue;
            }
            // a stalled client: skip this connection's read pass only —
            // every other connection proceeds (the isolation property)
            if self.faults.roll(FaultSite::ClientStall) {
                continue;
            }
            let mut buf = [0u8; 4096];
            loop {
                match conn.stream.read(&mut buf) {
                    Ok(0) => {
                        conn.eof = true;
                        break;
                    }
                    Ok(n) => {
                        conn.rbuf.extend_from_slice(&buf[..n]);
                        activity = true;
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        conn.dead = true;
                        break;
                    }
                }
            }
            if conn.dead {
                continue;
            }
            while let Some(pos) = conn.rbuf.iter().position(|&b| b == b'\n') {
                let line: Vec<u8> = conn.rbuf.drain(..=pos).collect();
                lines.push((ConnId { slot, gen }, line));
            }
            // EOF with an unterminated final line: serve it (the client
            // may legitimately half-close after its last request)
            if conn.eof && !conn.rbuf.is_empty() {
                let line = std::mem::take(&mut conn.rbuf);
                lines.push((ConnId { slot, gen }, line));
            }
        }
        for (cid, raw) in lines {
            self.handle_line(cid, &raw);
            activity = true;
        }
        activity
    }

    /// Parse one request line and admit it (or reply immediately).
    fn handle_line(&mut self, cid: ConnId, raw: &[u8]) {
        let text = match std::str::from_utf8(raw) {
            Ok(t) => t,
            Err(_) => {
                let e = Error::Json("request line is not valid UTF-8".into());
                self.finish_aggregate_now(cid, error_reply(&e));
                return;
            }
        };
        if text.trim().is_empty() {
            return;
        }
        let req = match json::parse(text) {
            Ok(v) => v,
            Err(e) => {
                self.finish_aggregate_now(cid, error_reply(&e));
                return;
            }
        };
        if let Some(cmd) = req.get("cmd").and_then(|v| v.as_str()) {
            let reply = match cmd {
                "stats" => self.stats_reply(),
                _ => error_reply(&Error::Json(format!("unknown cmd '{cmd}'"))),
            };
            self.finish_aggregate_now(cid, reply);
            return;
        }
        let streaming = req.get("stream").and_then(|v| v.as_bool()).unwrap_or(false);
        let rid = req.get("rid").cloned();
        let tenant = req
            .get("tenant")
            .and_then(|v| v.as_str())
            .map(|s| s.to_string());
        let prompt = match req.req_str("prompt") {
            Ok(p) => p.to_string(),
            Err(e) => {
                if streaming {
                    let frame = error_event(&rid, &e);
                    self.write_frame(cid, frame);
                } else {
                    self.finish_aggregate_now(cid, error_reply(&e));
                }
                return;
            }
        };
        let max_new = req
            .get("max_new_tokens")
            .and_then(|v| v.as_usize())
            .unwrap_or(0);
        let session = req
            .get("session")
            .and_then(|v| v.as_str())
            .map(|s| s.to_string());
        let tkey = tenant.clone().unwrap_or_else(|| ANON_TENANT.to_string());
        // WDRR debits decode budget; 0 means "server default" downstream
        let cost = if max_new == 0 {
            self.cfg.default_max_new_tokens.max(1)
        } else {
            max_new
        };
        let p = Pending {
            conn: cid,
            rid,
            streaming,
            agg_seq: if streaming { 0 } else { self.next_agg_seq(cid) },
            tenant,
            prompt,
            max_new,
            session,
            cost,
            queued: Instant::now(),
        };
        // overload gate: live worker queue wait over the shed threshold
        // fails fast instead of queuing into the latency tail
        if self.monitor.is_overloaded() {
            let e = Error::Overloaded {
                depth: self.qos.len(),
                capacity: self.qos.capacity(),
            };
            tally(&mut self.tenants, &tkey, |c| c.shed += 1);
            self.deliver_error(&p, &e);
            return;
        }
        match self.qos.push(&tkey, cost, p) {
            Ok(()) => tally(&mut self.tenants, &tkey, |c| c.accepted += 1),
            Err(p) => {
                let e = Error::Overloaded {
                    depth: self.qos.depth(&tkey),
                    capacity: self.qos.capacity(),
                };
                tally(&mut self.tenants, &tkey, |c| c.shed += 1);
                self.deliver_error(&p, &e);
            }
        }
    }

    /// Release front-queued requests into the coordinator by WDRR until
    /// the queues drain or the downstream sheds.
    fn pump(&mut self) -> bool {
        let mut activity = false;
        // front-queue deadline: a request that has already waited out its
        // serving budget here dies typed, without spending a worker slot
        let budget = Duration::from_millis(self.cfg.request_timeout_ms);
        let expired = self.qos.expire(|p| p.queued.elapsed() >= budget);
        for (tkey, p) in expired {
            let e = Error::DeadlineExceeded {
                waited_ms: p.queued.elapsed().as_millis() as u64,
                budget_ms: self.cfg.request_timeout_ms,
            };
            tally(&mut self.tenants, &tkey, |c| c.failed += 1);
            self.deliver_error(&p, &e);
            activity = true;
        }
        loop {
            let Some((tkey, p)) = self.qos.pop() else { break };
            let (event_tx, event_rx) = mpsc::channel();
            let opts = SubmitOptions {
                tenant: p.tenant.clone(),
                stream: if p.streaming { Some(event_tx) } else { None },
            };
            match self
                .coordinator
                .submit_with(&p.prompt, p.max_new, p.session.clone(), opts)
            {
                Ok(reply_rx) => {
                    self.inflight.push(Inflight {
                        conn: p.conn,
                        rid: p.rid,
                        streaming: p.streaming,
                        agg_seq: p.agg_seq,
                        tenant: tkey,
                        reply_rx,
                        event_rx: if p.streaming { Some(event_rx) } else { None },
                        queued: p.queued,
                        next_index: 0,
                        got_first: false,
                        done: false,
                    });
                    activity = true;
                }
                Err(Error::Overloaded { .. }) => {
                    // downstream worker queues are full: keep the request
                    // at the front of its tenant's queue and stop pumping
                    // this pass — backpressure instead of a retry spin
                    let cost = p.cost;
                    self.qos.unpop(&tkey, cost, p);
                    break;
                }
                Err(e) => {
                    tally(&mut self.tenants, &tkey, |c| c.failed += 1);
                    self.deliver_error(&p, &e);
                    activity = true;
                }
            }
        }
        activity
    }

    /// Drain every in-flight request's channels into wire frames.
    fn poll_inflight(&mut self) -> bool {
        let mut activity = false;
        let mut out: Vec<(ConnId, Delivery)> = Vec::new();
        for fl in &mut self.inflight {
            if fl.streaming {
                let rx = fl.event_rx.as_ref().expect("streaming inflight has rx");
                loop {
                    match rx.try_recv() {
                        Ok(StreamEvent::Token { index, id, text }) => {
                            if index < fl.next_index {
                                continue; // replay below the high-water mark
                            }
                            fl.next_index = index + 1;
                            if !fl.got_first {
                                fl.got_first = true;
                                let ttft = fl.queued.elapsed().as_millis() as u64;
                                tally(&mut self.tenants, &fl.tenant, |c| {
                                    c.note_first_token(ttft)
                                });
                            }
                            tally(&mut self.tenants, &fl.tenant, |c| {
                                c.tokens_streamed += 1
                            });
                            out.push((
                                fl.conn,
                                Delivery::Frame(token_frame(&fl.rid, index, id, &text)),
                            ));
                            activity = true;
                        }
                        Ok(StreamEvent::End(resp)) => {
                            match &resp {
                                Response::Ok(_) => {
                                    tally(&mut self.tenants, &fl.tenant, |c| c.completed += 1)
                                }
                                Response::Err { .. } => {
                                    tally(&mut self.tenants, &fl.tenant, |c| c.failed += 1)
                                }
                            }
                            out.push((
                                fl.conn,
                                Delivery::Frame(terminal_frame(&fl.rid, &resp)),
                            ));
                            fl.done = true;
                            activity = true;
                            break;
                        }
                        Err(mpsc::TryRecvError::Empty) => break,
                        Err(mpsc::TryRecvError::Disconnected) => {
                            // worker died without a terminal event: the
                            // stream still ends with exactly one terminal
                            tally(&mut self.tenants, &fl.tenant, |c| c.failed += 1);
                            out.push((
                                fl.conn,
                                Delivery::Frame(error_event(&fl.rid, &Error::ShutDown)),
                            ));
                            fl.done = true;
                            activity = true;
                            break;
                        }
                    }
                }
            } else {
                match fl.reply_rx.try_recv() {
                    Ok(resp) => {
                        match &resp {
                            Response::Ok(_) => {
                                tally(&mut self.tenants, &fl.tenant, |c| c.completed += 1)
                            }
                            Response::Err { .. } => {
                                tally(&mut self.tenants, &fl.tenant, |c| c.failed += 1)
                            }
                        }
                        out.push((fl.conn, Delivery::Agg(fl.agg_seq, response_reply(&resp))));
                        fl.done = true;
                        activity = true;
                    }
                    Err(mpsc::TryRecvError::Empty) => {}
                    Err(mpsc::TryRecvError::Disconnected) => {
                        tally(&mut self.tenants, &fl.tenant, |c| c.failed += 1);
                        out.push((
                            fl.conn,
                            Delivery::Agg(fl.agg_seq, error_reply(&Error::ShutDown)),
                        ));
                        fl.done = true;
                        activity = true;
                    }
                }
            }
        }
        self.inflight.retain(|f| !f.done);
        for (cid, delivery) in out {
            match delivery {
                Delivery::Frame(v) => self.write_frame(cid, v),
                Delivery::Agg(seq, v) => self.complete_aggregate(cid, seq, v),
            }
        }
        activity
    }

    fn flush_pass(&mut self) -> bool {
        let mut activity = false;
        for conn in self.conns.iter_mut().flatten() {
            if conn.dead || conn.wbuf.is_empty() {
                continue;
            }
            // a torn write lands only a prefix; the tail STAYS BUFFERED,
            // so frames are delayed, never corrupted mid-line
            let budget = if self.faults.roll(FaultSite::TornClientWrite) {
                (conn.wbuf.len() / 2).max(1)
            } else {
                conn.wbuf.len()
            };
            match conn.stream.write(&conn.wbuf[..budget]) {
                Ok(0) => conn.dead = true,
                Ok(n) => {
                    conn.wbuf.drain(..n);
                    activity = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {}
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => conn.dead = true,
            }
        }
        activity
    }

    /// Close dead connections immediately and half-closed ones once all
    /// their replies have drained. Slot generations bump on close.
    fn reap(&mut self) {
        for slot in 0..self.conns.len() {
            let remove = match self.conns[slot].as_ref() {
                None => false,
                Some(conn) => {
                    let gen = self.gens[slot];
                    conn.dead
                        || (conn.eof
                            && conn.wbuf.is_empty()
                            && conn.agg_done.is_empty()
                            && !self
                                .inflight
                                .iter()
                                .any(|f| f.conn.slot == slot && f.conn.gen == gen)
                            && !self
                                .qos
                                .any(|p| p.conn.slot == slot && p.conn.gen == gen))
                }
            };
            if remove {
                self.conns[slot] = None;
                self.gens[slot] += 1;
            }
        }
    }

    // --- control plane ------------------------------------------------------

    /// The `{"cmd":"stats"}` payload: cluster breakdown plus the front's
    /// per-tenant QoS counters (client-visible TTFT lives here — it is
    /// measured from front arrival to first token frame, a superset of
    /// the scheduler's queue-relative TTFT).
    fn stats_reply(&self) -> Value {
        let tenant_rows: Vec<(String, Value)> = self
            .tenants
            .iter()
            .map(|(name, c)| {
                (
                    name.clone(),
                    json::obj(vec![
                        ("accepted", json::n(c.accepted as f64)),
                        ("shed", json::n(c.shed as f64)),
                        ("completed", json::n(c.completed as f64)),
                        ("failed", json::n(c.failed as f64)),
                        ("tokens_streamed", json::n(c.tokens_streamed as f64)),
                        ("first_tokens", json::n(c.first_tokens as f64)),
                        ("avg_ttft_ms", json::n(c.avg_ttft_ms())),
                        ("max_ttft_ms", json::n(c.ttft_ms_max as f64)),
                        ("weight", json::n(self.qos.weight_of(name) as f64)),
                    ]),
                )
            })
            .collect();
        json::obj(vec![
            ("ok", json::b(true)),
            ("stats", self.coordinator.cluster_stats().to_json()),
            (
                "front",
                json::obj(vec![
                    ("queued", json::n(self.qos.len() as f64)),
                    ("inflight", json::n(self.inflight.len() as f64)),
                    (
                        "overloaded",
                        json::b(self.monitor.is_overloaded()),
                    ),
                    ("tenants", Value::Obj(tenant_rows)),
                ]),
            ),
        ])
    }
}
