//! Line-protocol glue and the blocking test/example client.
//!
//! The server side lives in [`super::stream`] (the nonblocking event
//! loop); this module keeps the *pure* request-line semantics
//! ([`serve_line`] — unit-testable without sockets, and the reference
//! for what an aggregate reply contains) and [`TcpClient`], a minimal
//! blocking client speaking both reply modes:
//!
//! * [`TcpClient::request`] — aggregate: one line out, one reply line in
//!   (the pre-streaming protocol, unchanged on the wire);
//! * [`TcpClient::generate_streaming`] — streaming: sends
//!   `"stream": true`, then consumes `token` frames until the terminal
//!   `done`/`error` frame, recording client-visible TTFT (first token
//!   frame arrival) along the way.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use crate::coordinator::{Coordinator, Response};
use crate::error::{Error, Result};
use crate::util::json::{self, Value};

/// The wire-format failure reply: message plus the stable
/// machine-readable `error_kind` label from the failure taxonomy.
pub(crate) fn error_reply(e: &Error) -> Value {
    json::obj(vec![
        ("ok", json::b(false)),
        ("error", json::s(&e.to_string())),
        ("error_kind", json::s(e.kind())),
    ])
}

/// A worker [`Response`] as an aggregate wire reply. A scheduler-side
/// failure (deadline, retry exhaustion, ...) keeps its typed kind all
/// the way to the wire instead of collapsing into "rejected".
pub(crate) fn response_reply(resp: &Response) -> Value {
    match resp {
        Response::Ok(outcome) => json::obj(vec![
            ("ok", json::b(true)),
            ("output", json::s(&outcome.text)),
            ("latency_s", json::n(outcome.latency_s)),
            ("reuse_depth", json::n(outcome.reuse_depth as f64)),
            ("cache_hit", json::b(outcome.cache_hit)),
            ("prompt_tokens", json::n(outcome.prompt_tokens as f64)),
            ("new_tokens", json::n(outcome.ids.len() as f64)),
        ]),
        Response::Err { msg, kind } => json::obj(vec![
            ("ok", json::b(false)),
            ("error", json::s(msg)),
            ("error_kind", json::s(kind)),
        ]),
    }
}

/// One request line -> one response value (pure; unit-testable). This is
/// the *blocking* aggregate semantics — the event loop implements the
/// same mapping nonblockingly, plus streaming and QoS admission.
pub fn serve_line(line: &str, coordinator: &Coordinator) -> Value {
    match serve_line_inner(line, coordinator) {
        Ok(v) => v,
        Err(e) => error_reply(&e),
    }
}

fn serve_line_inner(line: &str, coordinator: &Coordinator) -> Result<Value> {
    let req = json::parse(line)?;
    // Control-plane commands ride the same wire as prompts. `stats`
    // returns the aggregate + per-worker cluster breakdown.
    if let Some(cmd) = req.get("cmd").and_then(|v| v.as_str()) {
        return match cmd {
            "stats" => Ok(json::obj(vec![
                ("ok", json::b(true)),
                ("stats", coordinator.cluster_stats().to_json()),
            ])),
            _ => Err(Error::Json(format!("unknown cmd '{cmd}'"))),
        };
    }
    let prompt = req.req_str("prompt")?;
    let max_new = req
        .get("max_new_tokens")
        .and_then(|v| v.as_usize())
        .unwrap_or(0);
    let session = req
        .get("session")
        .and_then(|v| v.as_str())
        .map(|s| s.to_string());
    let resp = coordinator.serve(prompt, max_new, session)?;
    Ok(response_reply(&resp))
}

/// One consumed token stream: the per-token frames in arrival order plus
/// the terminal frame and the client-side first-token latency.
#[derive(Debug)]
pub struct StreamedReply {
    /// `(token id, incremental text)` per `token` frame, index-ordered.
    /// On an index regression mid-stream (a transient retry replaying the
    /// prefix) the client truncates back — the surviving sequence is
    /// exactly what the terminal reply aggregates.
    pub tokens: Vec<(u32, String)>,
    /// The terminal frame: `event == "done"` with the aggregate payload,
    /// or `event == "error"` with `error` / `error_kind`.
    pub done: Value,
    /// Wall time from request write to the first `token` frame (None for
    /// zero-token streams, e.g. errors before the first token).
    pub ttft: Option<Duration>,
}

impl StreamedReply {
    /// Did the stream end in a successful `done` frame?
    pub fn is_ok(&self) -> bool {
        self.done.get("ok").and_then(|v| v.as_bool()) == Some(true)
    }

    /// The streamed token texts concatenated (valid UTF-8 by the
    /// incremental decoder's hold-back contract).
    pub fn text(&self) -> String {
        self.tokens.iter().map(|(_, t)| t.as_str()).collect()
    }

    /// The streamed token ids in order.
    pub fn ids(&self) -> Vec<u32> {
        self.tokens.iter().map(|(id, _)| *id).collect()
    }
}

/// Minimal blocking client for tests/examples.
pub struct TcpClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl TcpClient {
    pub fn connect(addr: SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(TcpClient {
            reader: BufReader::new(stream),
            writer,
        })
    }

    /// Send one aggregate request, wait for its one reply line.
    pub fn request(
        &mut self,
        prompt: &str,
        max_new_tokens: usize,
        session: Option<&str>,
    ) -> Result<Value> {
        self.request_opts(prompt, max_new_tokens, session, None)
    }

    /// [`TcpClient::request`] with a tenant label for QoS accounting.
    pub fn request_opts(
        &mut self,
        prompt: &str,
        max_new_tokens: usize,
        session: Option<&str>,
        tenant: Option<&str>,
    ) -> Result<Value> {
        let line = request_line(prompt, max_new_tokens, session, tenant, false);
        self.roundtrip(&line)
    }

    /// Streaming request: consumes `token` frames as the server emits
    /// them and returns once the terminal `done`/`error` frame arrives.
    /// `ttft` is the client-visible first-token latency — the quantity
    /// the streaming ablation compares against the blocking front.
    pub fn generate_streaming(
        &mut self,
        prompt: &str,
        max_new_tokens: usize,
        session: Option<&str>,
        tenant: Option<&str>,
    ) -> Result<StreamedReply> {
        let line = request_line(prompt, max_new_tokens, session, tenant, true);
        self.writer.write_all(line.as_bytes())?;
        let sent = Instant::now();
        let mut tokens: Vec<(u32, String)> = Vec::new();
        let mut ttft = None;
        loop {
            let mut reply = String::new();
            self.reader.read_line(&mut reply)?;
            if reply.is_empty() {
                return Err(Error::ShutDown);
            }
            let v = json::parse(&reply)?;
            match v.get("event").and_then(|e| e.as_str()) {
                Some("token") => {
                    if ttft.is_none() {
                        ttft = Some(sent.elapsed());
                    }
                    let index = v
                        .get("index")
                        .and_then(|x| x.as_usize())
                        .unwrap_or(tokens.len());
                    let id = v.get("id").and_then(|x| x.as_i64()).unwrap_or(0) as u32;
                    let text = v
                        .get("text")
                        .and_then(|x| x.as_str())
                        .unwrap_or_default()
                        .to_string();
                    // defensive truncate-on-regression (see StreamedReply)
                    tokens.truncate(index);
                    tokens.push((id, text));
                }
                Some("done") | Some("error") => {
                    return Ok(StreamedReply {
                        tokens,
                        done: v,
                        ttft,
                    })
                }
                _ => {
                    return Err(Error::Json(format!(
                        "unexpected frame in stream: {}",
                        reply.trim()
                    )))
                }
            }
        }
    }

    /// Fetch the server's aggregate + per-worker stats breakdown plus
    /// the front's per-tenant QoS counters (`{"cmd":"stats"}`).
    pub fn stats(&mut self) -> Result<Value> {
        let line = json::obj(vec![("cmd", json::s("stats"))]).to_json() + "\n";
        self.roundtrip(&line)
    }

    fn roundtrip(&mut self, line: &str) -> Result<Value> {
        self.writer.write_all(line.as_bytes())?;
        let mut reply = String::new();
        self.reader.read_line(&mut reply)?;
        if reply.is_empty() {
            return Err(Error::ShutDown);
        }
        json::parse(&reply)
    }
}

fn request_line(
    prompt: &str,
    max_new_tokens: usize,
    session: Option<&str>,
    tenant: Option<&str>,
    stream: bool,
) -> String {
    let mut fields = vec![
        ("prompt", json::s(prompt)),
        ("max_new_tokens", json::n(max_new_tokens as f64)),
    ];
    if let Some(s) = session {
        fields.push(("session", json::s(s)));
    }
    if let Some(t) = tenant {
        fields.push(("tenant", json::s(t)));
    }
    if stream {
        fields.push(("stream", json::b(true)));
    }
    json::obj(fields).to_json() + "\n"
}
