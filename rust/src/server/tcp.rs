//! Blocking TCP server over the coordinator (one thread per connection —
//! appropriate for the single-stream serving substrate; the coordinator
//! queue is the real concurrency point).

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::{Coordinator, Response};
use crate::error::{Error, Result};
use crate::util::json::{self, Value};
use crate::util::sync::lock_recover;

/// How long a connection thread blocks in a read before re-checking the
/// shutdown flag. Bounds [`Server::stop`]'s join latency on idle
/// connections; partial request lines accumulate across timeouts, so
/// framing is unaffected.
const CONN_POLL: Duration = Duration::from_millis(50);

/// Running TCP server handle.
pub struct Server {
    addr: SocketAddr,
    accept_thread: Option<JoinHandle<()>>,
    /// Live connection threads. The accept loop registers each spawn and
    /// reaps finished handles in passing; [`Server::stop`] joins the
    /// remainder, so shutdown leaks no threads even with clients still
    /// connected (their reads time out on `CONN_POLL` and observe the
    /// flag). A plain detach-on-spawn would leak every open connection's
    /// thread past `stop()` — the registry makes teardown total.
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    shutdown: Arc<AtomicBool>,
}

impl Server {
    /// Bind and start serving on `listen` ("host:port"; port 0 picks a free
    /// port — the bound address is available via [`Server::addr`]).
    pub fn start(coordinator: Arc<Coordinator>, listen: &str) -> Result<Server> {
        let listener = TcpListener::bind(listen)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let conns = Arc::new(Mutex::new(Vec::new()));
        let flag = Arc::clone(&shutdown);
        let registry = Arc::clone(&conns);
        let accept_thread = std::thread::Builder::new()
            .name("recycle-server-accept".into())
            .spawn(move || {
                loop {
                    if flag.load(Ordering::Relaxed) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let c = Arc::clone(&coordinator);
                            let f = Arc::clone(&flag);
                            // Joining here would head-of-line-block the
                            // accept loop on connected clients, so the
                            // handle goes into the registry instead and
                            // stop() joins it; finished handles are
                            // reaped in passing to keep the registry
                            // bounded by *live* connections.
                            let h = std::thread::Builder::new()
                                .name("recycle-server-conn".into())
                                .spawn(move || handle_conn(stream, c, f))
                                .expect("spawn conn thread");
                            // poison-recovering lock: a connection thread
                            // that panicked must not kill the accept loop
                            // (and with it every future connection)
                            let mut reg = lock_recover(&registry);
                            reg.retain(|h: &JoinHandle<()>| !h.is_finished());
                            reg.push(h);
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })
            .expect("spawn accept thread");
        Ok(Server {
            addr,
            accept_thread: Some(accept_thread),
            conns,
            shutdown,
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, then join the accept thread AND every connection
    /// thread: when this returns, the server owns no running threads.
    pub fn stop(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        // poison recovery keeps stop() total even after a connection
        // thread panicked while registering
        let handles: Vec<JoinHandle<()>> =
            std::mem::take(&mut *lock_recover(&self.conns));
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn handle_conn(stream: TcpStream, coordinator: Arc<Coordinator>, shutdown: Arc<AtomicBool>) {
    let peer = stream.peer_addr().ok();
    // Bounded reads so the thread can observe shutdown between requests;
    // failing to set the timeout degrades to blocking reads (the thread
    // then exits on client disconnect, as before the registry existed).
    let _ = stream.set_read_timeout(Some(CONN_POLL));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    // Byte-level framing (not `lines()`): a misbehaving client sending
    // invalid UTF-8 gets a typed error reply and the connection KEEPS
    // serving — only EOF or a real socket error closes it. (`lines()`
    // folds invalid UTF-8 into `Err` and silently dropped the stream.)
    let mut buf: Vec<u8> = Vec::new();
    'serve: loop {
        buf.clear();
        // Accumulate one full line; a read timeout only re-checks the
        // shutdown flag (bytes already read stay in `buf` — a slow
        // client's partial request is never dropped).
        loop {
            match reader.read_until(b'\n', &mut buf) {
                Ok(0) => break 'serve, // EOF
                Ok(_) if buf.ends_with(b"\n") => break,
                // EOF with an unterminated final line: serve it; the
                // next read returns Ok(0) and closes the connection.
                Ok(_) => break,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    if shutdown.load(Ordering::Relaxed) {
                        break 'serve;
                    }
                }
                Err(_) => break 'serve, // socket error
            }
        }
        let reply = match std::str::from_utf8(&buf) {
            Ok(text) => {
                if text.trim().is_empty() {
                    continue;
                }
                serve_line(text, &coordinator)
            }
            Err(_) => error_reply(&Error::Json("request line is not valid UTF-8".into())),
        };
        if writer
            .write_all((reply.to_json() + "\n").as_bytes())
            .is_err()
        {
            break;
        }
    }
    log::debug!("connection closed: {peer:?}");
}

/// The wire-format failure reply: message plus the stable
/// machine-readable `error_kind` label from the failure taxonomy.
fn error_reply(e: &Error) -> Value {
    json::obj(vec![
        ("ok", json::b(false)),
        ("error", json::s(&e.to_string())),
        ("error_kind", json::s(e.kind())),
    ])
}

/// One request line -> one response value (pure; unit-testable).
pub fn serve_line(line: &str, coordinator: &Coordinator) -> Value {
    match serve_line_inner(line, coordinator) {
        Ok(v) => v,
        Err(e) => error_reply(&e),
    }
}

fn serve_line_inner(line: &str, coordinator: &Coordinator) -> Result<Value> {
    let req = json::parse(line)?;
    // Control-plane commands ride the same wire as prompts. `stats`
    // returns the aggregate + per-worker cluster breakdown.
    if let Some(cmd) = req.get("cmd").and_then(|v| v.as_str()) {
        return match cmd {
            "stats" => Ok(json::obj(vec![
                ("ok", json::b(true)),
                ("stats", coordinator.cluster_stats().to_json()),
            ])),
            _ => Err(Error::Json(format!("unknown cmd '{cmd}'"))),
        };
    }
    let prompt = req.req_str("prompt")?;
    let max_new = req
        .get("max_new_tokens")
        .and_then(|v| v.as_usize())
        .unwrap_or(0);
    let session = req
        .get("session")
        .and_then(|v| v.as_str())
        .map(|s| s.to_string());
    // `serve` hands back the worker's raw reply, so a scheduler-side
    // failure (deadline, retry exhaustion, ...) keeps its typed kind all
    // the way to the wire instead of collapsing into "rejected".
    match coordinator.serve(prompt, max_new, session)? {
        Response::Ok(outcome) => Ok(json::obj(vec![
            ("ok", json::b(true)),
            ("output", json::s(&outcome.text)),
            ("latency_s", json::n(outcome.latency_s)),
            ("reuse_depth", json::n(outcome.reuse_depth as f64)),
            ("cache_hit", json::b(outcome.cache_hit)),
            ("prompt_tokens", json::n(outcome.prompt_tokens as f64)),
            ("new_tokens", json::n(outcome.ids.len() as f64)),
        ])),
        Response::Err { msg, kind } => Ok(json::obj(vec![
            ("ok", json::b(false)),
            ("error", json::s(&msg)),
            ("error_kind", json::s(kind)),
        ])),
    }
}

/// Minimal blocking client for tests/examples.
pub struct TcpClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl TcpClient {
    pub fn connect(addr: SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(TcpClient {
            reader: BufReader::new(stream),
            writer,
        })
    }

    /// Send one request, wait for one response.
    pub fn request(
        &mut self,
        prompt: &str,
        max_new_tokens: usize,
        session: Option<&str>,
    ) -> Result<Value> {
        let mut fields = vec![
            ("prompt", json::s(prompt)),
            ("max_new_tokens", json::n(max_new_tokens as f64)),
        ];
        if let Some(s) = session {
            fields.push(("session", json::s(s)));
        }
        let line = json::obj(fields).to_json() + "\n";
        self.roundtrip(&line)
    }

    /// Fetch the server's aggregate + per-worker stats breakdown
    /// (`{"cmd":"stats"}`).
    pub fn stats(&mut self) -> Result<Value> {
        let line = json::obj(vec![("cmd", json::s("stats"))]).to_json() + "\n";
        self.roundtrip(&line)
    }

    fn roundtrip(&mut self, line: &str) -> Result<Value> {
        self.writer.write_all(line.as_bytes())?;
        let mut reply = String::new();
        self.reader.read_line(&mut reply)?;
        if reply.is_empty() {
            return Err(Error::ShutDown);
        }
        json::parse(&reply)
    }
}
