//! Network front: a nonblocking, event-driven TCP server streaming
//! tokens as they are generated, with per-tenant QoS admission.
//!
//! # Wire protocol
//!
//! Newline-delimited JSON in both directions, one value per line.
//! Requests:
//!
//! ```text
//! {"prompt": "...", "max_new_tokens": N}            aggregate request
//!   optional fields:
//!     "session": "id"      multi-turn context carry-over
//!     "tenant":  "id"      QoS accounting/fairness label (default "anon")
//!     "stream":  true      per-token streaming reply mode
//!     "rid":     <any>     client request id, echoed on every frame
//! {"cmd": "stats"}                                  control plane
//! ```
//!
//! # Reply modes
//!
//! **Aggregate** (no `"stream"`): exactly one reply line per request,
//! in per-connection request order (pipelining-safe):
//!
//! ```text
//! {"ok":true,"output":...,"latency_s":...,"reuse_depth":...,
//!  "cache_hit":...,"prompt_tokens":...,"new_tokens":...}
//! {"ok":false,"error":msg,"error_kind":kind}
//! ```
//!
//! **Streaming** (`"stream": true`): zero or more `token` frames the
//! moment the owning worker's tick emits each token, then exactly one
//! terminal frame. Streams may interleave with other replies on the
//! same connection — the echoed `rid` is the demultiplexing key:
//!
//! ```text
//! {"event":"token","rid":...,"index":N,"id":T,"text":S}
//! {"event":"done","rid":...,"ok":true, <aggregate success fields>}
//! {"event":"error","rid":...,"ok":false,"error":msg,"error_kind":kind}
//! ```
//!
//! Event taxonomy: `token` indices are 0-based and strictly increasing
//! within an attempt; a transient retry may replay from an earlier
//! index, and consumers MUST truncate on regression (fault-free streams
//! never regress). `done` carries the same payload as the aggregate
//! success reply, so `concat(token.text) == done.output` and
//! `count(token) == done.new_tokens` — the streaming-identity property.
//! `error` is terminal and carries the stable `error_kind` taxonomy
//! label ([`crate::error::Error::kind`]); mid-stream failures
//! (`overloaded`, `deadline_exceeded`, ...) arrive as `error` frames on
//! the live stream, never as silent disconnects.
//!
//! # QoS knobs (`ServerConfig`)
//!
//! | knob                    | role |
//! |-------------------------|------|
//! | `tenant_queue_capacity` | per-tenant front-queue bound; full ⇒ typed `overloaded` |
//! | `qos_quantum_tokens`    | WDRR quantum: tokens credited per scheduling visit |
//! | `qos_default_weight`    | weight for unlisted tenants (and `"anon"`) |
//! | `tenant_weights`        | per-tenant weight map — goodput shares converge to weight/Σweights |
//! | `qos_shed_wait_ms`      | live queue-wait shed gate (0 = disabled) |
//!
//! See [`stream`] for the event-loop architecture and [`tcp`] for the
//! pure line semantics and the blocking client.

pub mod qos;
pub mod stream;
pub mod tcp;

pub use stream::{Server, ANON_TENANT};
pub use tcp::{serve_line, StreamedReply, TcpClient};
