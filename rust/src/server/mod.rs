//! Network front-end: a line-delimited JSON protocol over TCP.
//!
//! Request:  `{"prompt": "...", "max_new_tokens": 32, "session": "id?"}`
//! Response: `{"ok": true, "output": "...", "latency_s": 0.01,
//!             "reuse_depth": 7, "cache_hit": true, "prompt_tokens": 12}`
//! or        `{"ok": false, "error": "..."}`

mod tcp;

pub use tcp::{Server, TcpClient};
