//! recycle-serve CLI: the leader entrypoint.
//!
//! Subcommands:
//!   serve  [--artifacts DIR] [--listen ADDR] [--policy strict|radix|off]
//!          [--max-entries N] [--compress] [--workers N]
//!          [--routing prefix-affinity|round-robin|least-loaded]
//!          [--spill-dir DIR] [--spill-mb N]  — run the TCP server.
//!   eval   [--artifacts DIR] [--data DIR] [--results DIR] [--max-new N]
//!          [--policy ...]                    — paper §4.4 two-arm evaluation.
//!   info   [--artifacts DIR]                 — print manifest/config summary.
//!
//! (Arg parsing is hand-rolled: clap is not in the offline vendor set.)

use std::path::PathBuf;
use std::sync::Arc;

use recycle_serve::bench::{format_table, paper_cache_prompts, paper_test_prompts,
                           run_comparison, EvalOptions, Workload};
use recycle_serve::error::{Error, Result};
use recycle_serve::config::{CacheConfig, RoutingPolicy, ServerConfig};
use recycle_serve::coordinator::Coordinator;
use recycle_serve::engine::Engine;
use recycle_serve::index::NgramEmbedder;
use recycle_serve::recycler::{RecyclePolicy, Recycler};
use recycle_serve::runtime::Runtime;
use recycle_serve::server::Server;
use recycle_serve::sim::Roofline;

/// Tiny flag parser: `--key value` and `--flag`.
struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut positional = Vec::new();
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key} must be a number"))),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

/// Build the production recycler. Must run on the thread that will own the
/// PJRT handles (the coordinator worker).
fn build_recycler(artifacts: &PathBuf, policy: RecyclePolicy, cache: CacheConfig)
                  -> Result<Recycler<Runtime>> {
    let rt = Runtime::load(artifacts).map_err(|e| {
        Error::Config(format!("loading artifacts from {}: {e}", artifacts.display()))
    })?;
    let tokenizer = rt.tokenizer();
    Ok(Recycler::new(
        Engine::new(rt),
        tokenizer,
        Box::new(NgramEmbedder::new(128)),
        cache,
        policy,
    ))
}

fn cmd_serve(args: &Args) -> Result<()> {
    let artifacts = PathBuf::from(args.get("artifacts", "artifacts"));
    let policy = RecyclePolicy::parse(&args.get("policy", "strict"))
        .ok_or_else(|| Error::Config("--policy must be strict|radix|off".into()))?;
    let cache = CacheConfig {
        max_entries: args.get_usize("max-entries", 64)?,
        compress: args.has("compress"),
        spill_dir: args.flags.get("spill-dir").cloned(),
        max_spill_bytes: args.get_usize("spill-mb", 0)? << 20,
        ..Default::default()
    };
    cache.validate()?;
    // Validate artifacts cheaply on the main thread for a clear error.
    let manifest = recycle_serve::runtime::Manifest::load(&artifacts)?;
    let routing = RoutingPolicy::parse(&args.get("routing", "prefix-affinity"))?;
    let cfg = ServerConfig {
        listen: args.get("listen", "127.0.0.1:7077"),
        max_batch: args.get_usize("max-batch", 8)?,
        num_workers: args.get_usize("workers", 1)?.max(1),
        routing,
        ..Default::default()
    };
    println!(
        "recycle-serve: model '{}' from {} | policy {} | {} worker(s), routing {} | listening on {}",
        manifest.model.name,
        artifacts.display(),
        policy.name(),
        cfg.num_workers,
        cfg.routing.name(),
        cfg.listen
    );
    let listen = cfg.listen.clone();
    let coordinator = Arc::new(Coordinator::spawn(
        move |worker| {
            let mut cache = cache.clone();
            if cache.spill_dir.is_some() {
                // Per-worker spill identity: workers share the configured
                // spill_dir without file collisions, sweep only their own
                // stale files, and can adopt each other's spilled records.
                cache.spill_namespace = format!("w{worker}_");
            }
            build_recycler(&artifacts, policy, cache).expect("runtime init")
        },
        cfg,
    ));
    let server = Server::start(Arc::clone(&coordinator), &listen)?;
    println!("ready on {} — protocol: one JSON object per line", server.addr());
    // Serve until killed.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_eval(args: &Args) -> Result<()> {
    let artifacts = PathBuf::from(args.get("artifacts", "artifacts"));
    let data = PathBuf::from(args.get("data", "data"));
    let results = PathBuf::from(args.get("results", "results"));
    std::fs::create_dir_all(&results)?;
    let policy = RecyclePolicy::parse(&args.get("policy", "strict"))
        .ok_or_else(|| Error::Config("--policy must be strict|radix|off".into()))?;

    let rt0 = Runtime::load(&artifacts)?;
    let tokenizer = rt0.tokenizer();
    drop(rt0);

    let workload = Workload {
        cache_prompts: paper_cache_prompts(&data),
        test_prompts: paper_test_prompts(&data),
    };
    let opts = EvalOptions {
        max_new_tokens: args.get_usize("max-new", 32)?,
        policy,
        results_dir: Some(results.clone()),
        ..Default::default()
    };
    let report = run_comparison(
        || Runtime::load(&artifacts).expect("reload artifacts"),
        tokenizer,
        &workload,
        &opts,
    )?;
    println!("{}", format_table("Paper §5.1 summary", &report.summary_rows()));
    println!("alpha (S ≈ α·k/m fit, §5.5): {:.3}", report.alpha);
    println!("rows written to {}", results.display());
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let artifacts = PathBuf::from(args.get("artifacts", "artifacts"));
    let rt = Runtime::load(&artifacts)?;
    let cfg = rt.config();
    let roof = Roofline::new(cfg.clone());
    println!("model        : {}", cfg.name);
    println!("layers/heads : {} / {}", cfg.n_layer, cfg.n_head);
    println!("d_model/d_ff : {} / {}", cfg.d_model, cfg.d_ff);
    println!("vocab        : {}", cfg.vocab_size);
    println!("context      : {} tokens", cfg.max_seq);
    println!("chunk buckets: {:?}", cfg.chunk_sizes);
    println!("params       : {:.2}M", roof.param_count() as f64 / 1e6);
    println!("kv buffer    : {:.2} MiB", cfg.kv_bytes() as f64 / (1 << 20) as f64);
    println!(
        "kv per token : {:.1} KiB",
        cfg.kv_bytes_for_len(1) as f64 / 1024.0
    );
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    match args.positional.first().map(|s| s.as_str()) {
        Some("serve") => cmd_serve(&args),
        Some("eval") => cmd_eval(&args),
        Some("info") => cmd_info(&args),
        other => {
            if let Some(o) = other {
                eprintln!("unknown command: {o}\n");
            }
            eprintln!("usage: recycle-serve <serve|eval|info> [--artifacts DIR] ...");
            eprintln!(
                "  serve --listen 127.0.0.1:7077 --policy strict|radix|off \
                 --workers 4 --routing prefix-affinity --spill-dir /tmp/spill --spill-mb 256"
            );
            eprintln!("  eval  --data data --results results --max-new 32");
            eprintln!("  info");
            Err(Error::Config("no command given".into()))
        }
    }
}
