//! Multi-turn sessions: the "expand usable context capacity" half of the
//! paper's title.
//!
//! Sessions are tracked at the *token* level: each turn's prompt ids are
//! the previous turn's exact final ids (prompt + generated response) plus
//! the newly-encoded user segment. Token-level continuation is what makes
//! the cached-KV prefix match guaranteed — re-tokenizing the transcript
//! text could split BPE merges differently at generation boundaries and
//! silently break the prefix condition. The recycler caches the full
//! prompt+response KV per turn (`admit_full`), so turn N+1 reuses all of
//! turn N's computation; the `context_extension` example measures this.
//!
//! With the paged arena, continuation is also *allocation*-incremental:
//! turn N+1 attaches turn N's record by cloning its block table and only
//! the boundary block copies on write, so a T-turn conversation holds one
//! physical copy of the transcript KV plus O(turns) boundary blocks —
//! not T copies of an ever-growing dense buffer.
//! [`SessionManager::kv_blocks`] gives the logical per-session estimate
//! (token count / block size; COW-duplicated boundary blocks not
//! included — the arena's own accounting in `CoordinatorStats` is the
//! physical ground truth).
//!
//! Transcripts do not grow without bound: near the context window the
//! scheduler applies [`truncate_to_window`] (keep the token suffix) before
//! serving, so a session keeps answering indefinitely instead of failing
//! `PromptTooLong` forever once `context_tokens` reaches `max_seq`.

use std::collections::HashMap;

/// One dialogue turn (bookkeeping/display).
#[derive(Debug, Clone, PartialEq)]
pub struct Turn {
    pub user: String,
    pub bot: String,
}

/// Accumulated session state: the exact text AND token ids of the
/// transcript so far (including the last bot response).
///
/// `turns` keeps only the most recent [`MAX_TURN_HISTORY`] entries — the
/// sliding-window truncation lets sessions live indefinitely, so an
/// unbounded per-turn text log would grow linearly forever. `total_turns`
/// counts every committed turn regardless.
#[derive(Debug, Clone, Default)]
pub struct SessionState {
    pub text: String,
    pub ids: Vec<u32>,
    pub turns: Vec<Turn>,
    pub total_turns: usize,
}

/// Most recent turns retained per session for display/debugging.
pub const MAX_TURN_HISTORY: usize = 64;

/// In-memory session registry.
#[derive(Debug, Default)]
pub struct SessionManager {
    sessions: HashMap<String, SessionState>,
}

impl SessionManager {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// The text segment appended for a new user message (the only part
    /// that needs fresh tokenization).
    pub fn segment_for(&self, session_id: &str, user_msg: &str) -> String {
        let has_history = self
            .sessions
            .get(session_id)
            .is_some_and(|s| !s.ids.is_empty());
        if has_history {
            format!("\nUser: {user_msg}\nBot:")
        } else {
            format!("User: {user_msg}\nBot:")
        }
    }

    /// Current transcript (text, ids) — empty for a fresh session.
    pub fn state_of(&self, session_id: &str) -> (String, Vec<u32>) {
        match self.sessions.get(session_id) {
            Some(s) => (s.text.clone(), s.ids.clone()),
            None => (String::new(), Vec::new()),
        }
    }

    /// Commit a completed turn: the transcript becomes the full prompt
    /// text/ids plus the bot response.
    pub fn commit(
        &mut self,
        session_id: &str,
        user_msg: &str,
        full_text: String,
        full_ids: Vec<u32>,
        bot_text: &str,
    ) {
        let s = self.sessions.entry(session_id.to_string()).or_default();
        s.text = full_text;
        s.ids = full_ids;
        s.turns.push(Turn {
            user: user_msg.to_string(),
            bot: bot_text.to_string(),
        });
        if s.turns.len() > MAX_TURN_HISTORY {
            s.turns.remove(0);
        }
        s.total_turns += 1;
    }

    /// Total committed turns (the retained [`Turn`] history is capped at
    /// [`MAX_TURN_HISTORY`] — see [`SessionManager::history_len`]).
    pub fn turns(&self, session_id: &str) -> usize {
        self.sessions.get(session_id).map_or(0, |s| s.total_turns)
    }

    /// Turns actually retained in the display/debug history.
    pub fn history_len(&self, session_id: &str) -> usize {
        self.sessions.get(session_id).map_or(0, |s| s.turns.len())
    }

    /// Transcript token count (context usage).
    pub fn context_tokens(&self, session_id: &str) -> usize {
        self.sessions.get(session_id).map_or(0, |s| s.ids.len())
    }

    /// Logical estimate of the KV blocks the transcript occupies in a
    /// paged arena with `block_tokens` positions per block (the footprint
    /// the latest cached turn pins; earlier turns share its prefix blocks;
    /// COW-duplicated boundary blocks are not counted — see the arena
    /// occupancy in `CoordinatorStats` for physical truth).
    pub fn kv_blocks(&self, session_id: &str, block_tokens: usize) -> usize {
        self.context_tokens(session_id).div_ceil(block_tokens)
    }

    pub fn drop_session(&mut self, session_id: &str) -> bool {
        self.sessions.remove(session_id).is_some()
    }
}

/// Token-level sliding window: truncate `ids` to its last `budget` tokens,
/// returning how many were dropped from the head.
///
/// This is what keeps a long-lived session serving past the context
/// window instead of wedging on `PromptTooLong` forever: once the
/// transcript plus the new segment exceeds `max_seq - max_new`, the
/// scheduler cuts the transcript down to HALF that budget (hysteresis: a
/// cut to the edge would re-truncate every subsequent turn, and the
/// ever-moving head would never prefix-match a cached record again) and
/// re-derives the display text. The truncated prompt no longer
/// token-matches the pre-cut cache record, but it is *re-anchored* on the
/// very next turn — the session path admits the full truncated prompt +
/// response (`admit_full`), and the following turns fit untruncated, so
/// turn N+2 onward recycles turn N+1's post-cut KV through the normal
/// lookup (radix or strict; regression-tested in `recycler`).
pub fn truncate_to_window(ids: &mut Vec<u32>, budget: usize) -> usize {
    if ids.len() <= budget {
        return 0;
    }
    let cut = ids.len() - budget;
    ids.drain(..cut);
    cut
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_turn_segment() {
        let m = SessionManager::new();
        assert_eq!(m.segment_for("s1", "hi"), "User: hi\nBot:");
        assert_eq!(m.state_of("s1"), (String::new(), vec![]));
    }

    #[test]
    fn committed_ids_are_the_next_turn_prefix() {
        // the key property: turn N+1's prompt ids literally extend turn
        // N's committed ids
        let mut m = SessionManager::new();
        let seg1 = m.segment_for("s", "hi");
        let prompt1_ids = vec![1, 2, 3]; // encode(seg1), stand-in
        let full1: Vec<u32> = vec![1, 2, 3, 9, 8]; // + generated
        m.commit("s", "hi", format!("{seg1} yo!"), full1.clone(), " yo!");

        let seg2 = m.segment_for("s", "more");
        assert!(seg2.starts_with('\n'), "history -> newline-joined segment");
        let (text2, ids2) = m.state_of("s");
        assert_eq!(ids2, full1);
        assert!(text2.ends_with(" yo!"));
        assert_eq!(m.turns("s"), 1);
        assert_eq!(m.context_tokens("s"), 5);
        assert_eq!(m.kv_blocks("s", 4), 2, "5 tokens -> 2 four-token blocks");
        assert_eq!(m.kv_blocks("missing", 4), 0);
        drop(prompt1_ids);
    }

    #[test]
    fn sessions_are_isolated() {
        let mut m = SessionManager::new();
        m.commit("a", "x", "t".into(), vec![1], "y");
        assert_eq!(m.state_of("b"), (String::new(), vec![]));
        assert_eq!(m.segment_for("b", "hi"), "User: hi\nBot:");
    }

    #[test]
    fn turn_history_is_capped_but_count_is_not() {
        let mut m = SessionManager::new();
        for i in 0..(MAX_TURN_HISTORY + 10) {
            m.commit("s", &format!("u{i}"), format!("t{i}"), vec![i as u32], "b");
        }
        assert_eq!(m.turns("s"), MAX_TURN_HISTORY + 10, "count keeps going");
        assert_eq!(m.history_len("s"), MAX_TURN_HISTORY, "history bounded");
    }

    #[test]
    fn truncate_to_window_keeps_suffix() {
        let mut ids: Vec<u32> = (0..10).collect();
        assert_eq!(truncate_to_window(&mut ids, 12), 0);
        assert_eq!(ids.len(), 10);
        assert_eq!(truncate_to_window(&mut ids, 10), 0, "exact fit keeps all");
        assert_eq!(truncate_to_window(&mut ids, 4), 6);
        assert_eq!(ids, vec![6, 7, 8, 9], "the newest tokens survive");
        assert_eq!(truncate_to_window(&mut ids, 0), 4);
        assert!(ids.is_empty());
    }

    #[test]
    fn drop_session() {
        let mut m = SessionManager::new();
        m.commit("a", "x", "t".into(), vec![1], "y");
        assert!(m.drop_session("a"));
        assert!(!m.drop_session("a"));
        assert!(m.is_empty());
    }
}
