//! Bounded MPSC request queue with close semantics and backpressure.
//!
//! `std::sync::mpsc::sync_channel` cannot reject-instead-of-block or report
//! depth, both of which the coordinator needs (reject = backpressure,
//! depth = metrics), hence this small Mutex+Condvar queue.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Why a push was refused.
#[derive(Debug, PartialEq, Eq)]
pub enum QueueError {
    Full,
    Closed,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded MPSC queue.
pub struct RequestQueue<T> {
    inner: Mutex<Inner<T>>,
    notify: Condvar,
    capacity: usize,
}

impl<T> RequestQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        RequestQueue {
            inner: Mutex::new(Inner {
                items: VecDeque::new(),
                closed: false,
            }),
            notify: Condvar::new(),
            capacity,
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Non-blocking push; rejects when full or closed (backpressure).
    pub fn push(&self, item: T) -> Result<(), QueueError> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return Err(QueueError::Closed);
        }
        if inner.items.len() >= self.capacity {
            return Err(QueueError::Full);
        }
        inner.items.push_back(item);
        self.notify.notify_one();
        Ok(())
    }

    /// Blocking pop with timeout. None on timeout or when closed+drained.
    pub fn pop_timeout(&self, timeout: Duration) -> Option<T> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(item) = inner.items.pop_front() {
                return Some(item);
            }
            if inner.closed {
                return None;
            }
            let (guard, res) = self.notify.wait_timeout(inner, timeout).unwrap();
            inner = guard;
            if res.timed_out() && inner.items.is_empty() {
                return None;
            }
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        self.inner.lock().unwrap().items.pop_front()
    }

    /// Close: further pushes fail; poppers drain the backlog then get None.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.notify.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = RequestQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.try_pop(), Some(1));
        assert_eq!(q.try_pop(), Some(2));
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn rejects_when_full() {
        let q = RequestQueue::new(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.push(3), Err(QueueError::Full));
        q.try_pop();
        q.push(3).unwrap();
    }

    #[test]
    fn close_semantics() {
        let q = RequestQueue::new(2);
        q.push(1).unwrap();
        q.close();
        assert_eq!(q.push(2), Err(QueueError::Closed));
        // backlog still drains
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), Some(1));
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), None);
    }

    #[test]
    fn pop_timeout_times_out() {
        let q: RequestQueue<i32> = RequestQueue::new(1);
        let t = std::time::Instant::now();
        assert_eq!(q.pop_timeout(Duration::from_millis(20)), None);
        assert!(t.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn cross_thread_handoff() {
        let q = Arc::new(RequestQueue::new(64));
        let q2 = Arc::clone(&q);
        let producer = std::thread::spawn(move || {
            for i in 0..100 {
                while q2.push(i).is_err() {
                    std::thread::yield_now();
                }
            }
            q2.close();
        });
        let mut got = Vec::new();
        while let Some(v) = q.pop_timeout(Duration::from_millis(200)) {
            got.push(v);
        }
        producer.join().unwrap();
        assert_eq!(got, (0..100).collect::<Vec<_>>());
    }
}
