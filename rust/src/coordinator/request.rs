//! Request/response types crossing the queue boundary.

use std::sync::mpsc;
use std::time::Instant;

use crate::recycler::Outcome;

/// A queued generation request.
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub max_new_tokens: usize,
    /// Optional session for multi-turn context carry-over.
    pub session: Option<String>,
    /// Response channel (one-shot).
    pub reply: mpsc::Sender<Response>,
    /// When the request entered the queue (queue-wait metrics).
    pub queued_at: Instant,
    /// Tenant id for per-tenant QoS accounting (None = anonymous).
    pub tenant: Option<String>,
    /// Optional streaming channel: when set, the scheduler mirrors every
    /// decoded token as a [`StreamEvent::Token`] the tick it is produced
    /// and the terminal reply as [`StreamEvent::End`]. The aggregate
    /// `reply` channel fires regardless, so streaming consumers may drop
    /// either side.
    pub stream: Option<mpsc::Sender<StreamEvent>>,
}

/// Per-token streaming events mirrored out of the scheduler tick loop.
///
/// Ordering contract: zero or more `Token`s (with strictly increasing
/// `index` per attempt), then exactly one `End`. A transient retry
/// restarts generation, so the `index` sequence may reset to 0 mid-stream;
/// consumers MUST treat `index` as authoritative and truncate their
/// buffer on regression. Fault-free streams never regress.
#[derive(Debug, Clone)]
pub enum StreamEvent {
    Token {
        /// Position in the generated sequence (0-based).
        index: usize,
        /// The token id.
        id: u32,
        /// Incremental text for this token: decoded bytes held back at a
        /// UTF-8 boundary by [`crate::tokenizer::StreamDecoder`], so the
        /// concatenation over a stream is valid UTF-8.
        text: String,
    },
    /// Terminal event: same payload as the aggregate reply.
    End(Response),
}

/// What the worker sends back.
#[derive(Debug, Clone)]
pub enum Response {
    Ok(Box<Outcome>),
    /// Failure reply: human-readable message plus the stable
    /// [`Error::kind`](crate::error::Error::kind) label, so transports
    /// can expose a machine-readable `error_kind` field without parsing
    /// messages.
    Err { msg: String, kind: &'static str },
}

impl Response {
    /// Build the failure reply for a typed error.
    pub fn err(e: &crate::error::Error) -> Self {
        Response::Err {
            msg: e.to_string(),
            kind: e.kind(),
        }
    }

    pub fn ok(self) -> Result<Outcome, String> {
        match self {
            Response::Ok(o) => Ok(*o),
            Response::Err { msg, .. } => Err(msg),
        }
    }
}
