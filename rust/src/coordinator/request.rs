//! Request/response types crossing the queue boundary.

use std::sync::mpsc;
use std::time::Instant;

use crate::recycler::Outcome;

/// A queued generation request.
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub max_new_tokens: usize,
    /// Optional session for multi-turn context carry-over.
    pub session: Option<String>,
    /// Response channel (one-shot).
    pub reply: mpsc::Sender<Response>,
    /// When the request entered the queue (queue-wait metrics).
    pub queued_at: Instant,
}

/// What the worker sends back.
#[derive(Debug)]
pub enum Response {
    Ok(Box<Outcome>),
    /// Failure reply: human-readable message plus the stable
    /// [`Error::kind`](crate::error::Error::kind) label, so transports
    /// can expose a machine-readable `error_kind` field without parsing
    /// messages.
    Err { msg: String, kind: &'static str },
}

impl Response {
    /// Build the failure reply for a typed error.
    pub fn err(e: &crate::error::Error) -> Self {
        Response::Err {
            msg: e.to_string(),
            kind: e.kind(),
        }
    }

    pub fn ok(self) -> Result<Outcome, String> {
        match self {
            Response::Ok(o) => Ok(*o),
            Response::Err { msg, .. } => Err(msg),
        }
    }
}
