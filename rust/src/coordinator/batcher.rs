//! Batch formation: drain up to `max_batch` requests, waiting at most
//! `first_wait` for the first and a short follow-up window for stragglers.
//!
//! Two entry points serve the continuous-batching scheduler:
//! [`drain_batch`] blocks (used only while the scheduler is *idle* — the
//! first wait is `ServerConfig::batch_first_wait_ms`), and [`drain_ready`]
//! is strictly non-blocking (used while decode streams are in flight, so
//! admission never stalls running requests). `max_batch = 1` reproduces
//! the paper's request-at-a-time setting exactly.

use std::time::Duration;

use super::queue::RequestQueue;

/// Drain a batch: blocks up to `first_wait` for the first item, then keeps
/// taking ready items (up to `follow_wait` each) until `max_batch`.
pub fn drain_batch<T>(
    queue: &RequestQueue<T>,
    max_batch: usize,
    first_wait: Duration,
    follow_wait: Duration,
) -> Vec<T> {
    let mut batch = Vec::new();
    let Some(first) = queue.pop_timeout(first_wait) else {
        return batch;
    };
    batch.push(first);
    while batch.len() < max_batch {
        match queue.pop_timeout(follow_wait) {
            Some(item) => batch.push(item),
            None => break,
        }
    }
    batch
}

/// Non-blocking drain of up to `max` already-queued items. The scheduler
/// calls this between decode steps: arrivals join the running set
/// immediately, requests never wait for the whole batch to finish.
pub fn drain_ready<T>(queue: &RequestQueue<T>, max: usize) -> Vec<T> {
    let mut batch = Vec::new();
    while batch.len() < max {
        match queue.try_pop() {
            Some(item) => batch.push(item),
            None => break,
        }
    }
    batch
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_queue_returns_empty_batch() {
        let q: RequestQueue<i32> = RequestQueue::new(8);
        let b = drain_batch(&q, 4, Duration::from_millis(5), Duration::from_millis(1));
        assert!(b.is_empty());
    }

    #[test]
    fn drains_up_to_max_batch() {
        let q = RequestQueue::new(8);
        for i in 0..6 {
            q.push(i).unwrap();
        }
        let b = drain_batch(&q, 4, Duration::from_millis(5), Duration::from_millis(1));
        assert_eq!(b, vec![0, 1, 2, 3]);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn batch_of_one_reproduces_paper_setting() {
        let q = RequestQueue::new(8);
        q.push(7).unwrap();
        q.push(8).unwrap();
        let b = drain_batch(&q, 1, Duration::from_millis(5), Duration::from_millis(1));
        assert_eq!(b, vec![7]);
    }

    #[test]
    fn first_wait_is_honored_not_hardcoded() {
        // The idle wait is the caller's first_wait (the coordinator passes
        // ServerConfig::batch_first_wait_ms), not a baked-in 50 ms. Only a
        // LOWER bound is asserted — a wait of >= 110 ms is impossible if
        // the old hardcoded 50 ms were still in effect, and lower bounds
        // are immune to CI scheduler jitter (which only inflates elapsed).
        let q: RequestQueue<i32> = RequestQueue::new(8);
        let t = std::time::Instant::now();
        let b = drain_batch(&q, 4, Duration::from_millis(120), Duration::from_millis(1));
        let waited = t.elapsed();
        assert!(b.is_empty());
        assert!(waited >= Duration::from_millis(110), "waited {waited:?}");
    }

    #[test]
    fn drain_ready_never_blocks() {
        let q = RequestQueue::new(8);
        // generous bound: catches an accidental blocking wait without being
        // sensitive to scheduler jitter
        let t = std::time::Instant::now();
        assert!(drain_ready(&q, 4).is_empty());
        assert!(t.elapsed() < Duration::from_secs(5));
        for i in 0..3 {
            q.push(i).unwrap();
        }
        assert_eq!(drain_ready(&q, 2), vec![0, 1]);
        assert_eq!(drain_ready(&q, 2), vec![2]);
    }
}
