//! Batch formation: drain up to `max_batch` requests, waiting at most
//! `window` for the first and a short follow-up window for stragglers.
//!
//! The paper serves batch size 1; the batcher generalizes that (max_batch=1
//! reproduces the paper exactly). On the single-stream CPU runtime a batch
//! is still *executed* sequentially — batching here amortizes queue/lock
//! overhead and groups cache lookups, which is what the ablation measures.

use std::time::Duration;

use super::queue::RequestQueue;

/// Drain a batch: blocks up to `first_wait` for the first item, then keeps
/// taking ready items (up to `follow_wait` each) until `max_batch`.
pub fn drain_batch<T>(
    queue: &RequestQueue<T>,
    max_batch: usize,
    first_wait: Duration,
    follow_wait: Duration,
) -> Vec<T> {
    let mut batch = Vec::new();
    let Some(first) = queue.pop_timeout(first_wait) else {
        return batch;
    };
    batch.push(first);
    while batch.len() < max_batch {
        match queue.pop_timeout(follow_wait) {
            Some(item) => batch.push(item),
            None => break,
        }
    }
    batch
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_queue_returns_empty_batch() {
        let q: RequestQueue<i32> = RequestQueue::new(8);
        let b = drain_batch(&q, 4, Duration::from_millis(5), Duration::from_millis(1));
        assert!(b.is_empty());
    }

    #[test]
    fn drains_up_to_max_batch() {
        let q = RequestQueue::new(8);
        for i in 0..6 {
            q.push(i).unwrap();
        }
        let b = drain_batch(&q, 4, Duration::from_millis(5), Duration::from_millis(1));
        assert_eq!(b, vec![0, 1, 2, 3]);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn batch_of_one_reproduces_paper_setting() {
        let q = RequestQueue::new(8);
        q.push(7).unwrap();
        q.push(8).unwrap();
        let b = drain_batch(&q, 1, Duration::from_millis(5), Duration::from_millis(1));
        assert_eq!(b, vec![7]);
    }
}
