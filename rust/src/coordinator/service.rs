//! The coordinator service: worker thread + submission handle.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::config::ServerConfig;
use crate::engine::ForwardModel;
use crate::error::{Error, Result};
use crate::metrics::Counters;
use crate::recycler::{Outcome, Recycler};

use super::batcher::drain_batch;
use super::queue::{QueueError, RequestQueue};
use super::request::{Request, Response};
use super::session::SessionManager;

/// Aggregate coordinator statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct CoordinatorStats {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub rejected: u64,
    pub batches: u64,
    /// Engine-level counters snapshot.
    pub engine: Counters,
    pub cache_entries: usize,
    pub cache_bytes: usize,
    /// Paged-KV arena occupancy (cache records + in-flight requests).
    pub arena_used_blocks: usize,
    pub arena_capacity_blocks: usize,
}

struct Shared {
    queue: RequestQueue<Request>,
    stats: Mutex<CoordinatorStats>,
    next_id: AtomicU64,
}

/// Handle to a running coordinator. Dropping it shuts the worker down.
pub struct Coordinator {
    shared: Arc<Shared>,
    worker: Option<JoinHandle<()>>,
    cfg: ServerConfig,
}

impl Coordinator {
    /// Spawn the worker thread. `mk_recycler` runs ON the worker thread —
    /// the PJRT runtime's handles are not `Send`, so the model must be
    /// constructed where it will be used.
    pub fn spawn<M, F>(mk_recycler: F, cfg: ServerConfig) -> Coordinator
    where
        M: ForwardModel + 'static,
        F: FnOnce() -> Recycler<M> + Send + 'static,
    {
        let shared = Arc::new(Shared {
            queue: RequestQueue::new(cfg.queue_capacity),
            stats: Mutex::new(CoordinatorStats::default()),
            next_id: AtomicU64::new(1),
        });
        let worker_shared = Arc::clone(&shared);
        let wcfg = cfg.clone();
        let worker = std::thread::Builder::new()
            .name("recycle-coordinator".into())
            .spawn(move || {
                let mut recycler = mk_recycler();
                recycler.populate_cache = wcfg.populate_cache;
                worker_loop(worker_shared, recycler, wcfg)
            })
            .expect("spawn coordinator worker");
        Coordinator {
            shared,
            worker: Some(worker),
            cfg,
        }
    }

    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    /// Submit a request; returns a receiver for the response.
    pub fn submit(
        &self,
        prompt: &str,
        max_new_tokens: usize,
        session: Option<String>,
    ) -> Result<mpsc::Receiver<Response>> {
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: self.shared.next_id.fetch_add(1, Ordering::Relaxed),
            prompt: prompt.to_string(),
            max_new_tokens,
            session,
            reply: tx,
        };
        match self.shared.queue.push(req) {
            Ok(()) => {
                self.shared.stats.lock().unwrap().submitted += 1;
                Ok(rx)
            }
            Err(QueueError::Full) => {
                self.shared.stats.lock().unwrap().rejected += 1;
                Err(Error::Rejected("queue full".into()))
            }
            Err(QueueError::Closed) => Err(Error::ShutDown),
        }
    }

    /// Submit and wait (convenience for examples/tests).
    pub fn generate(&self, prompt: &str, max_new_tokens: usize) -> Result<Outcome> {
        let rx = self.submit(prompt, max_new_tokens, None)?;
        let resp = rx
            .recv()
            .map_err(|_| Error::ShutDown)?;
        resp.ok().map_err(Error::Rejected)
    }

    /// Multi-turn session request: builds the transcript prompt, serves it,
    /// records the turn.
    pub fn chat(&self, session_id: &str, user_msg: &str, max_new: usize) -> Result<Outcome> {
        let rx = self.submit(user_msg, max_new, Some(session_id.to_string()))?;
        let resp = rx.recv().map_err(|_| Error::ShutDown)?;
        resp.ok().map_err(Error::Rejected)
    }

    pub fn stats(&self) -> CoordinatorStats {
        *self.shared.stats.lock().unwrap()
    }

    pub fn queue_depth(&self) -> usize {
        self.shared.queue.len()
    }

    /// Graceful shutdown: stop accepting, drain, join.
    pub fn shutdown(mut self) {
        self.shared.queue.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shared.queue.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop<M: ForwardModel>(
    shared: Arc<Shared>,
    mut recycler: Recycler<M>,
    cfg: ServerConfig,
) {
    let mut sessions = SessionManager::new();
    loop {
        let batch = drain_batch(
            &shared.queue,
            cfg.max_batch,
            Duration::from_millis(50),
            Duration::from_millis(cfg.batch_window_ms),
        );
        if batch.is_empty() {
            if shared.queue.is_closed() && shared.queue.is_empty() {
                break;
            }
            continue;
        }
        shared.stats.lock().unwrap().batches += 1;
        for req in batch {
            let max_new = if req.max_new_tokens == 0 {
                cfg.default_max_new_tokens
            } else {
                req.max_new_tokens
            };
            // Session requests continue the transcript at the *token*
            // level; the previous turn's cached prompt+response KV makes
            // the prefill incremental (see coordinator::session).
            let tokenizer = recycler.tokenizer();
            let (prompt_text, prompt_ids, is_session) = match &req.session {
                Some(sid) => {
                    let seg = sessions.segment_for(sid, &req.prompt);
                    let (mut text, mut ids) = sessions.state_of(sid);
                    text.push_str(&seg);
                    ids.extend(tokenizer.encode(&seg));
                    (text, ids, true)
                }
                None => (req.prompt.clone(), tokenizer.encode(&req.prompt), false),
            };
            let result =
                recycler.generate_ids(&prompt_text, prompt_ids.clone(), max_new, is_session);
            let mut stats = shared.stats.lock().unwrap();
            match result {
                Ok(outcome) => {
                    stats.completed += 1;
                    drop(stats);
                    if let Some(sid) = &req.session {
                        let mut full_ids = prompt_ids;
                        full_ids.extend_from_slice(&outcome.ids);
                        let full_text = format!("{prompt_text}{}", outcome.text);
                        sessions.commit(sid, &req.prompt, full_text, full_ids,
                                        &outcome.text);
                    }
                    let _ = req.reply.send(Response::Ok(Box::new(outcome)));
                }
                Err(e) => {
                    stats.failed += 1;
                    drop(stats);
                    let _ = req.reply.send(Response::Err(e.to_string()));
                }
            }
        }
        // refresh derived stats
        let mut stats = shared.stats.lock().unwrap();
        stats.engine = recycler.engine().counters();
        stats.cache_entries = recycler.store().len();
        stats.cache_bytes = recycler.store().live_bytes();
        stats.arena_used_blocks = recycler.arena().used_blocks();
        stats.arena_capacity_blocks = recycler.arena().capacity_blocks();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::engine::Engine;
    use crate::index::NgramEmbedder;
    use crate::recycler::RecyclePolicy;
    use crate::testutil::MockModel;
    use crate::tokenizer::Tokenizer;

    fn coordinator(cfg: ServerConfig) -> Coordinator {
        Coordinator::spawn(
            || {
                let engine = Engine::new(MockModel::new(ModelConfig::nano()));
                Recycler::new(
                    engine,
                    std::sync::Arc::new(Tokenizer::new(vec![])),
                    Box::new(NgramEmbedder::new(64)),
                    Default::default(),
                    RecyclePolicy::Strict,
                )
            },
            cfg,
        )
    }

    #[test]
    fn serves_a_request() {
        let c = coordinator(ServerConfig::default());
        let out = c.generate("hello world this is a prompt", 4).unwrap();
        assert_eq!(out.ids.len(), 4);
        let stats = c.stats();
        assert_eq!(stats.submitted, 1);
        assert_eq!(stats.completed, 1);
        c.shutdown();
    }

    #[test]
    fn repeated_prompt_hits_cache() {
        let c = coordinator(ServerConfig::default());
        let a = c.generate("what is the capital of france?", 4).unwrap();
        assert!(!a.cache_hit);
        let b = c
            .generate("what is the capital of france? and italy?", 4)
            .unwrap();
        assert!(b.cache_hit);
        assert!(b.reuse_depth > 0);
        c.shutdown();
    }

    #[test]
    fn concurrent_submitters() {
        let c = std::sync::Arc::new(coordinator(ServerConfig {
            max_batch: 4,
            ..Default::default()
        }));
        let mut handles = Vec::new();
        for t in 0..4 {
            let c2 = std::sync::Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                let out = c2.generate(&format!("prompt number {t} for testing"), 3).unwrap();
                out.ids.len()
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), 3);
        }
        assert_eq!(c.stats().completed, 4);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // tiny queue + a worker that's busy: fill it up
        let c = coordinator(ServerConfig {
            queue_capacity: 1,
            ..Default::default()
        });
        // Burst faster than the worker drains; at least one must be
        // rejected OR all succeed quickly — assert the error type when it
        // fires rather than racing the worker.
        let mut rejected = false;
        let mut receivers = Vec::new();
        for i in 0..50 {
            match c.submit(&format!("p{i} xxxx"), 2, None) {
                Ok(rx) => receivers.push(rx),
                Err(Error::Rejected(_)) => {
                    rejected = true;
                    break;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        for rx in receivers {
            let _ = rx.recv();
        }
        if rejected {
            assert!(c.stats().rejected >= 1);
        }
        c.shutdown();
    }

    #[test]
    fn session_turns_recycle_their_transcript() {
        let c = coordinator(ServerConfig::default());
        let t1 = c.chat("sess", "hello there friend", 3).unwrap();
        assert!(!t1.cache_hit, "first turn has nothing to reuse");
        let t2 = c.chat("sess", "tell me more", 3).unwrap();
        assert!(t2.cache_hit, "turn 2 must reuse turn 1's transcript KV");
        assert!(t2.reuse_depth > 0);
        // the paged arena is live and bounded
        let stats = c.stats();
        assert!(stats.arena_used_blocks > 0, "session KV must hold blocks");
        assert!(stats.arena_used_blocks <= stats.arena_capacity_blocks);
        c.shutdown();
    }

    #[test]
    fn failure_surfaces_as_error_response() {
        let c = Coordinator::spawn(
            || {
                let engine =
                    Engine::new(MockModel::new(ModelConfig::nano()).fail_on_call(1));
                Recycler::new(
                    engine,
                    std::sync::Arc::new(Tokenizer::new(vec![])),
                    Box::new(NgramEmbedder::new(64)),
                    Default::default(),
                    RecyclePolicy::Strict,
                )
            },
            ServerConfig::default(),
        );
        let err = c.generate("boom", 2).unwrap_err();
        assert!(err.to_string().contains("injected"));
        assert_eq!(c.stats().failed, 1);
        // next request works (failure was transient)
        assert!(c.generate("fine now", 2).is_ok());
        c.shutdown();
    }

    #[test]
    fn shutdown_then_submit_fails() {
        let c = coordinator(ServerConfig::default());
        let shared = std::sync::Arc::clone(&c.shared);
        c.shutdown();
        let (tx, _rx) = mpsc::channel();
        let req = Request {
            id: 1,
            prompt: "x".into(),
            max_new_tokens: 1,
            session: None,
            reply: tx,
        };
        assert_eq!(shared.queue.push(req).err(), Some(QueueError::Closed));
    }
}
