//! The coordinator service: a tick-driven scheduler, the worker thread
//! that drives it, and the submission handle.
//!
//! The worker runs a **continuous-batching scheduler with chunked
//! prefill**: each queued request becomes a per-slot state machine —
//! **lookup → chunked-prefill → decode → finish** — held in a running set
//! of slots. Admission (`lookup`) retrieves the recycled prefix and
//! opens a suspendable [`PrefillStream`] *without running any forward*;
//! every scheduler tick then advances at most
//! `ServerConfig::max_prefilling_slots` admitting slots by at most
//! `ServerConfig::prefill_chunk_tokens` prompt tokens each
//! (`chunked-prefill`), alongside the single `forward_batch` dispatch that
//! advances all decoding streams one token (`decode`). A long cache-cold
//! prompt therefore never stalls in-flight decodes for more than one
//! chunk budget of work per tick — the head-of-line bound the
//! `prefill_stall_tokens_max` counter records — instead of running its
//! whole prefill inline at admission. Finished streams reply immediately
//! (`finish`). `max_batch = 1` with a window-sized chunk budget
//! degenerates to the paper's request-at-a-time serving; both batched
//! decode and chunked prefill are token-identical to it (property-tested
//! in `rust/tests/properties.rs` via the deterministic trace harness in
//! [`crate::testutil::trace`]).
//!
//! The scheduler core is the [`Scheduler`] struct: one [`Scheduler::tick`]
//! call runs admission, one prefill step, one decode step, and the finish
//! sweep, and returns the tick's [`SchedEvent`] trace. The worker thread
//! is a thin loop around it (drain the queue, tick, publish stats); tests
//! drive the same `tick` directly with scripted arrivals for
//! deterministic, replayable interleavings.
//!
//! Scheduler invariants, restated for multi-tick admission:
//!
//! * **Same-session order** — two turns of one session never run
//!   concurrently, where "running" includes slots still in the
//!   chunked-prefill state; a later turn waits behind an earlier one
//!   whether it is prefilling, decoding, or queued ahead of it in the
//!   holdback queue.
//! * **Arena conservation** — a partially-prefilled slot pins exactly the
//!   blocks its written chunks cover; admission reserves the *remaining*
//!   growth (rest of the prompt plus the decode budget) for every running
//!   slot, prefilling or decoding, so a newcomer cannot eat blocks an
//!   in-flight slot will need across its chunk boundaries. Dropping a
//!   slot at any chunk boundary releases its blocks (clean shedding).
//! * **Reclaim-gated shedding** — arena-pressure shedding goes through
//!   [`Recycler`]'s headroom pass, which is gated on the tiered store's
//!   *reclaimable* footprint (blocks whose every live reference is a
//!   cache entry's): when per-tick shedding can free nothing it stops
//!   immediately — and with a spill tier configured, victims land on
//!   disk and stay hit-able instead of being destroyed. The chunked
//!   path adds one shed-and-*resume* retry on a mid-prefill
//!   `ArenaExhausted`: the stream keeps its completed chunks, so the
//!   retry re-runs only the failed chunk and `prefill_calls` counts
//!   each chunk exactly once.
//! * **Headroom FIFO** — while any request is held back for arena
//!   headroom, no fresh request is drained past it (unchanged).
//! * **Bounded failure handling** — every failure is replied to exactly
//!   once, with a typed message. Transient step failures
//!   ([`Error::is_transient`]) get at most
//!   `ServerConfig::transient_retry_limit` total attempts with
//!   exponential tick-based backoff (`retry_backoff_ticks << (k-1)`
//!   ticks before retry k; the slot keeps its blocks and resumes at its
//!   last committed chunk, so retries are token-exact). A per-request
//!   deadline (`request_timeout_ms`) reaps requests at tick boundaries
//!   wherever they sit — queued, deferred, prefilling, or decoding — and
//!   the bounded submit queue sheds with a typed
//!   [`Error::Overloaded`] reply carrying its depth. Dropped slots
//!   release their blocks and growth reservations, so arena conservation
//!   holds across arbitrary fault schedules (property-tested by the
//!   chaos suite in `rust/tests/properties.rs`).

use std::collections::VecDeque;
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::ServerConfig;
use crate::engine::{DecodeStream, ForwardModel, PrefillStream};
use crate::error::{Error, Result};
use crate::kvcache::CacheStats;
use crate::metrics::{Counters, SchedulerStats};
use crate::recycler::{Outcome, Recycler, ServeMeta};
use crate::tokenizer::StreamDecoder;
use crate::util::sync::lock_recover;

use super::batcher::{drain_batch, drain_ready};
use super::queue::{QueueError, RequestQueue};
use super::request::{Request, Response, StreamEvent};
use super::session::{truncate_to_window, SessionManager};

/// Aggregate coordinator statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct CoordinatorStats {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub rejected: u64,
    /// Admission waves (scheduler ticks that admitted >= 1 request).
    pub batches: u64,
    /// Engine-level counters snapshot.
    pub engine: Counters,
    /// Continuous-batching occupancy + queue-wait + chunked-prefill
    /// counters (time-to-first-token, prefill stall bound).
    pub scheduler: SchedulerStats,
    /// Tiered KV store counters: hot hit/miss/eviction plus the spill
    /// tier's spill / spill-hit / reload-latency accounting.
    pub cache: CacheStats,
    /// Hot cache entries (== `cache.live_entries`, kept for dashboards).
    pub cache_entries: usize,
    /// Logical hot-cache bytes (see `cache.physical_bytes` for the real
    /// arena footprint).
    pub cache_bytes: usize,
    /// Paged-KV arena occupancy (cache records + in-flight requests).
    pub arena_used_blocks: usize,
    pub arena_capacity_blocks: usize,
}

impl CoordinatorStats {
    /// Degraded-mode warnings across the serving stack (empty when
    /// healthy). Currently: the cache's spill tier failing to set up
    /// (`CacheStats::spill_setup_failed`) — serving continues but
    /// evictions destroy records instead of spilling.
    pub fn health_warnings(&self) -> Vec<String> {
        let mut warnings = Vec::new();
        if let Some(w) = self.cache.health_warning() {
            warnings.push(w);
        }
        warnings
    }

    /// Fold another worker's stats into this one — the cluster aggregate
    /// the router exposes. Counts and totals add; per-event maxima
    /// (`ttft_ms_max`, `peak_occupancy`, …) take the max; degraded-mode
    /// flags OR. At one worker the merge of `[w0]` is exactly `w0`, so
    /// the aggregate view is identity at `num_workers = 1`.
    pub fn merge(&mut self, o: &CoordinatorStats) {
        self.submitted += o.submitted;
        self.completed += o.completed;
        self.failed += o.failed;
        self.rejected += o.rejected;
        self.batches += o.batches;
        self.engine.merge(&o.engine);
        self.scheduler.merge(&o.scheduler);
        self.cache.merge(&o.cache);
        self.cache_entries += o.cache_entries;
        self.cache_bytes += o.cache_bytes;
        self.arena_used_blocks += o.arena_used_blocks;
        self.arena_capacity_blocks += o.arena_capacity_blocks;
    }
}

/// State shared between one worker's submit side and its thread.
pub(super) struct WorkerShared {
    pub(super) queue: RequestQueue<Request>,
    pub(super) stats: Mutex<CoordinatorStats>,
}

/// One serving worker: a full `Scheduler` + arena + recycler stack driven
/// by its own thread off its own bounded queue. The router
/// ([`super::router::Coordinator`]) owns N of these and places requests
/// across them; at N=1 the single worker IS the old single-scheduler
/// coordinator — same thread layout, same queue semantics, same stats.
pub(super) struct Worker {
    pub(super) shared: Arc<WorkerShared>,
    pub(super) index: usize,
    handle: Option<JoinHandle<()>>,
}

impl Worker {
    /// Spawn worker `index`. `mk_recycler` runs ON the worker thread —
    /// the PJRT runtime's handles are not `Send`, so the model must be
    /// constructed where it will be used.
    pub(super) fn spawn<M, F>(index: usize, mk_recycler: F, cfg: ServerConfig) -> Worker
    where
        M: ForwardModel + 'static,
        F: FnOnce() -> Recycler<M> + Send + 'static,
    {
        let shared = Arc::new(WorkerShared {
            queue: RequestQueue::new(cfg.queue_capacity),
            stats: Mutex::new(CoordinatorStats::default()),
        });
        let worker_shared = Arc::clone(&shared);
        let handle = std::thread::Builder::new()
            .name(format!("recycle-worker-{index}"))
            .spawn(move || {
                // populate_cache is applied from the config by
                // Scheduler::new — the single owner of that flag
                worker_loop(worker_shared, mk_recycler(), cfg)
            })
            .expect("spawn coordinator worker");
        Worker {
            shared,
            index,
            handle: Some(handle),
        }
    }

    /// Try to place `req` on this worker's queue; bumps `submitted` on
    /// success. A `Full` result is NOT counted here — the router may
    /// still retry the request on a sibling, and only the terminal
    /// rejection is recorded (via [`Worker::note_rejected`] on the
    /// worker that turned the request into an [`Error::Overloaded`]
    /// reply).
    pub(super) fn try_push(&self, req: Request) -> std::result::Result<(), QueueError> {
        match self.shared.queue.push(req) {
            Ok(()) => {
                // poison-recovering lock: a worker thread that panicked
                // mid-publish must not cascade into the submit path
                lock_recover(&self.shared.stats).submitted += 1;
                Ok(())
            }
            Err(e) => Err(e),
        }
    }

    /// Count a terminal load-shed rejection against this worker.
    pub(super) fn note_rejected(&self) {
        lock_recover(&self.shared.stats).rejected += 1;
    }

    pub(super) fn queue_depth(&self) -> usize {
        self.shared.queue.len()
    }

    pub(super) fn queue_capacity(&self) -> usize {
        self.shared.queue.capacity()
    }

    pub(super) fn stats(&self) -> CoordinatorStats {
        // a dead worker degrades to its last published snapshot instead of
        // panicking the caller (router stats aggregation, `{"cmd":"stats"}`)
        *lock_recover(&self.shared.stats)
    }

    /// Stop accepting; the thread drains its backlog then exits.
    pub(super) fn close(&self) {
        self.shared.queue.close();
    }

    pub(super) fn join(&mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Worker {
    fn drop(&mut self) {
        self.close();
        self.join();
    }
}

/// Where one running slot is in the lookup → chunked-prefill → decode →
/// finish state machine. `Transit` exists only inside a single conversion
/// statement (moving the prefill stream into `finish_prefill`) and is
/// never observable across ticks.
enum SlotState {
    /// Admission done (lookup + recycled-prefix attach); the prompt is
    /// being prefilled chunk-by-chunk across ticks.
    Prefilling(PrefillStream),
    /// Prefill complete; the stream decodes one token per tick through
    /// the shared `forward_batch` dispatch.
    Decoding(DecodeStream),
    /// Momentary placeholder during the prefill→decode conversion.
    Transit,
}

/// One request in flight through the scheduler: its state-machine stage
/// plus everything needed to finish it (session commit, cache admission,
/// reply channel). Failures are replied-to and dropped where they occur,
/// so a slot in `running` is always healthy.
struct Slot {
    req: Request,
    prompt_text: String,
    prompt_ids: Vec<u32>,
    meta: ServeMeta,
    state: SlotState,
    /// First decode token already recorded for TTFT accounting.
    ttft_noted: bool,
    /// Transient step failures this slot has absorbed so far. The slot is
    /// failed once this reaches `ServerConfig::transient_retry_limit`
    /// total attempts.
    attempt: usize,
    /// Ticks left before the slot may step again (exponential tick-based
    /// backoff after a transient failure). While > 0 the prefill and
    /// decode phases skip the slot; it keeps its blocks and reservations,
    /// so a retried step resumes exactly where the failed one left off.
    cooldown: usize,
    /// Generated tokens already mirrored to `req.stream` (the emission
    /// sweep sends `generated()[streamed..]` each tick). Retries are
    /// token-exact — the stream keeps its generated prefix — so this
    /// index never regresses.
    streamed: usize,
    /// Per-slot incremental UTF-8 decoder for streamed token text.
    decoder: StreamDecoder,
}

impl Slot {
    fn is_prefilling(&self) -> bool {
        matches!(self.state, SlotState::Prefilling(_))
    }

    /// In retry backoff this tick (skipped by the step phases).
    fn cooling(&self) -> bool {
        self.cooldown > 0
    }
}

/// What became of one admission attempt.
enum Admit {
    /// Looked-up and ready to prefill — a new running slot in the
    /// `Prefilling` state (no forward has run yet).
    Ready(Box<Slot>),
    /// The arena lacks headroom for this request right now; hold it back
    /// until running streams free blocks.
    Defer(Request),
    /// Tokenization/validation failed; reply with the typed error.
    Fail(Request, Error),
}

/// Why a tick held a request back (trace-visible admission outcome).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeferReason {
    /// An earlier turn of its session is still in flight or queued ahead.
    Session,
    /// The arena lacks headroom (FIFO applies behind it).
    Headroom,
    /// All `max_prefilling_slots` admitting slots (or all `max_batch`
    /// running slots) are taken.
    Slot,
}

/// One tick's outputs: the event trace plus the replies the tick
/// produced. The scheduler never sends on the reply channels itself — the
/// driver must deliver `replies` only AFTER it has published the
/// scheduler's counters, so a submitter that wakes on its reply and
/// immediately reads `CoordinatorStats` sees its own completion reflected
/// there (the ordering the sequential loop provided).
pub struct TickReport {
    pub events: Vec<SchedEvent>,
    pub replies: TickReplies,
}

/// The replies one tick produced: each response paired with its request's
/// reply channel, in completion order.
pub type TickReplies = Vec<(mpsc::Sender<Response>, Response)>;

/// Queue a request's terminal reply: mirror it as [`StreamEvent::End`] on
/// the streaming channel (if any) immediately — token events were sent the
/// tick they decoded, so End is always last — and push the aggregate reply
/// into the outbox for the driver's publish-then-reply delivery. Every
/// terminal path goes through here, so a streaming consumer sees exactly
/// one End per request no matter where it failed.
fn send_terminal(
    outbox: &mut TickReplies,
    reply: mpsc::Sender<Response>,
    stream: Option<mpsc::Sender<StreamEvent>>,
    resp: Response,
) {
    if let Some(tx) = stream {
        let _ = tx.send(StreamEvent::End(resp.clone()));
    }
    outbox.push((reply, resp));
}

/// One scheduler-tick event, as recorded by [`Scheduler::tick`]. The
/// deterministic trace harness ([`crate::testutil::trace`]) collects these
/// per tick so any interleaving of admissions, prefill chunks, decode
/// dispatches, and completions can be asserted on and replayed.
#[derive(Debug, Clone)]
pub enum SchedEvent {
    /// Request entered the running set (a prefill slot opened).
    Admitted { id: u64 },
    /// Request was held back this wave.
    Deferred { id: u64, reason: DeferReason },
    /// One chunked-prefill step advanced a slot by `tokens` prompt tokens;
    /// `done` means the slot converts to decode this tick.
    PrefillChunk { id: u64, tokens: usize, done: bool },
    /// A failed prefill step was retried after shedding (arena pressure).
    PrefillRetry { id: u64 },
    /// One batched decode dispatch over `occupancy` streams.
    DecodeStep { occupancy: usize },
    /// Request emitted its first decode token.
    FirstToken { id: u64 },
    /// Request finished with `tokens` generated tokens and was replied to.
    Finished { id: u64, tokens: usize },
    /// Request failed and was replied to with the message.
    Failed { id: u64, msg: String },
    /// A transient step failure armed a tick-based backoff retry; the
    /// slot was kept (with its blocks) and will step again after
    /// `cooldown_ticks` ticks. `attempt` counts the failures absorbed so
    /// far (bounded by `ServerConfig::transient_retry_limit`).
    Retried {
        id: u64,
        attempt: usize,
        cooldown_ticks: usize,
    },
    /// Request exceeded `ServerConfig::request_timeout_ms` and was failed
    /// by the deadline sweep (wherever it was: deferred, prefilling, or
    /// decoding); its blocks and reservations were released.
    TimedOut { id: u64, waited_ms: u64 },
}

/// Gate + tokenize + session-extend + lookup one request into a running
/// slot (the `lookup` stage — no prefill forward runs here; the slot is
/// returned in the `Prefilling` state). `headroom_reserved` is
/// `Some(blocks)` while other slots are running (their unconsumed
/// growth): admission then requires arena headroom for THIS request's
/// estimated prompt + budget on top of that reserve, so a wave of
/// near-window prompts cannot exhaust the arena mid-wave and hard-fail
/// requests the sequential loop would have served. With `None` (idle
/// scheduler) admission always proceeds — `prepare` sheds cache
/// internally, so serial serving is always possible.
fn admit_one<M: ForwardModel>(
    req: Request,
    recycler: &mut Recycler<M>,
    sessions: &SessionManager,
    cfg: &ServerConfig,
    headroom_reserved: Option<usize>,
) -> Admit {
    let max_new = if req.max_new_tokens == 0 {
        cfg.default_max_new_tokens
    } else {
        req.max_new_tokens
    };
    let max_seq = recycler.config().max_seq;
    // Session prompts are cut to this budget before serving (the sliding
    // window inside `admission_prompt`), so both the admission estimate
    // and the truncation must use the same number.
    let session_budget = session_window_budget(max_seq, max_new);
    if let Some(reserved) = headroom_reserved {
        // Cheap size upper bound BEFORE any transcript cloning or
        // tokenization: byte length bounds the BPE token count from above
        // (merges only shrink) and session transcripts report their token
        // count in O(1). A headroom-deferred request is re-tried every
        // scheduler tick, so this path must stay O(1); the bound is
        // conservative, so a request it passes cannot out-size the gate.
        let est_prompt = match &req.session {
            // + segment markers ("\nUser: ...\nBot:"); clamped by the
            // sliding-window budget — gating on the pre-truncation
            // transcript would permanently defer long-lived sessions and
            // stall the whole scheduler behind them (Hold::Headroom FIFO)
            Some(sid) => {
                (sessions.context_tokens(sid) + req.prompt.len() + 16).min(session_budget)
            }
            None => req.prompt.len(),
        };
        if !recycler.admission_headroom(est_prompt + max_new, reserved) {
            return Admit::Defer(req);
        }
    }
    let (prompt_text, prompt_ids) =
        admission_prompt(recycler, sessions, req.session.as_deref(), &req.prompt, max_new);
    let is_session = req.session.is_some();
    match try_begin(recycler, &prompt_text, &prompt_ids, max_new, is_session) {
        Ok((stream, meta)) => Admit::Ready(Box::new(Slot {
            req,
            prompt_text,
            prompt_ids,
            meta,
            state: SlotState::Prefilling(stream),
            ttft_noted: false,
            attempt: 0,
            cooldown: 0,
            streamed: 0,
            decoder: StreamDecoder::new(),
        })),
        Err(e) => Admit::Fail(req, e),
    }
}

/// The generation-budget reserve a session prompt must leave free before
/// the context window: prompts are cut to `max_seq - min(max_new,
/// max_seq/2)` (the reserve is capped at half the window so a huge
/// max_new cannot gut the whole transcript).
fn session_window_budget(max_seq: usize, max_new: usize) -> usize {
    max_seq.saturating_sub(max_new.min(max_seq / 2)).max(1)
}

/// Build the exact prompt admission serves for a request: plain requests
/// pass through; session requests continue the transcript at the *token*
/// level (the previous turn's cached prompt+response KV makes the prefill
/// incremental — see coordinator::session) and apply the sliding-window
/// cut near the context window. Exposed so the sequential reference arm
/// of the chunked-prefill property tests serves byte-identical prompts
/// through `Recycler::generate_ids` — the two arms then differ only in
/// scheduling, which is exactly what the property quantifies.
pub fn admission_prompt<M: ForwardModel>(
    recycler: &Recycler<M>,
    sessions: &SessionManager,
    session: Option<&str>,
    user_msg: &str,
    max_new: usize,
) -> (String, Vec<u32>) {
    let tokenizer = recycler.tokenizer();
    let (mut prompt_text, mut prompt_ids) = match session {
        Some(sid) => {
            let seg = sessions.segment_for(sid, user_msg);
            let (mut text, mut ids) = sessions.state_of(sid);
            text.push_str(&seg);
            ids.extend(tokenizer.encode(&seg));
            (text, ids)
        }
        None => (user_msg.to_string(), tokenizer.encode(user_msg)),
    };
    if session.is_some() {
        // Sliding window: keep the transcript suffix when the prompt plus
        // the generation budget would overflow the context window, so a
        // long-lived session keeps serving instead of wedging on
        // PromptTooLong forever.
        let budget = session_window_budget(recycler.config().max_seq, max_new);
        if prompt_ids.len() > budget {
            // Hysteresis: cut to HALF the budget, not to its edge —
            // trimming to the edge would re-truncate every following turn,
            // and the ever-moving head would never prefix-match a cached
            // record again (zero KV reuse past the window). A deep cut
            // lets the next several turns fit untruncated, so turn N+1
            // admits a post-cut record and turn N+2 onward recycles it
            // (the re-anchor the session docs promise).
            let keep = (budget / 2).max(1);
            truncate_to_window(&mut prompt_ids, keep);
            // the truncated ids are authoritative; re-derive display text
            prompt_text = tokenizer.decode(&prompt_ids);
        }
    }
    (prompt_text, prompt_ids)
}

/// Lookup + open the prefill stream: the recycled prefix is attached and
/// the prompt validated, but no forward runs and no new blocks are
/// written — chunked prefill happens tick-by-tick in the scheduler.
/// (`ArenaExhausted` therefore cannot fire here; mid-prefill pressure is
/// handled by the scheduler's shed-and-resume retry.)
fn try_begin<M: ForwardModel>(
    recycler: &mut Recycler<M>,
    prompt_text: &str,
    prompt_ids: &[u32],
    max_new: usize,
    admit_full: bool,
) -> Result<(PrefillStream, ServeMeta)> {
    let adm = recycler.prepare(prompt_text, prompt_ids, admit_full);
    let stream = recycler.engine_mut().start_prefill(
        prompt_ids,
        adm.kv,
        adm.cur_len,
        max_new,
        adm.meta.want_capture,
    )?;
    Ok((stream, adm.meta))
}

/// Why a request sits in the holdback queue.
#[derive(Clone, Copy)]
enum Hold {
    /// An earlier turn of its session is still in flight, an arena-held
    /// request is ahead of it, or no running/prefilling slot was free;
    /// other traffic may pass.
    Session,
    /// The arena lacks headroom for it. FIFO applies: no fresh request is
    /// drained past it, otherwise a stream of small admissible arrivals
    /// could keep the arena full and starve it forever.
    Headroom,
}

/// Is an earlier request of session `sid` still ahead of a candidate?
/// "Ahead" means: in the running set (`running` — prefilling OR decoding;
/// a slot mid-prefill is as committed to turn order as a decoding one),
/// already picked this wave (`arrivals`), waiting in the holdback queue
/// before the candidate (`deferred[..deferred_limit]`), or re-queued this
/// wave (`requeue_front`). Turn order within a session is a correctness
/// invariant — turn N+1's prompt extends turn N's committed ids — so a
/// candidate must wait behind ALL of these, not just the running set.
fn session_blocked(
    sid: &str,
    running: &[Slot],
    arrivals: &[Request],
    deferred: &VecDeque<(Request, Hold)>,
    deferred_limit: usize,
    requeue_front: &[(Request, Hold)],
) -> bool {
    running.iter().any(|r| r.req.session.as_deref() == Some(sid))
        || arrivals.iter().any(|a| a.session.as_deref() == Some(sid))
        || deferred
            .iter()
            .take(deferred_limit)
            .any(|(d, _)| d.session.as_deref() == Some(sid))
        || requeue_front.iter().any(|(d, _)| d.session.as_deref() == Some(sid))
}

/// Arena blocks the running slots may still consume. For a decoding slot:
/// its unwritten decode growth (budget clamped to the window). For a
/// prefilling slot: the rest of its prompt plus its whole decode budget —
/// the reservation is held across chunk boundaries so a slot admitted at
/// tick T cannot be starved of blocks at tick T+k by later admissions.
/// Each slot also reserves one block of COW slack for its shared boundary
/// block. Admission reserves this so a newcomer's prefill cannot eat the
/// blocks in-flight slots will need.
fn reserved_growth_blocks<M: ForwardModel>(
    running: &[Slot],
    recycler: &Recycler<M>,
) -> usize {
    let max_seq = recycler.config().max_seq;
    let arena = recycler.arena();
    running
        .iter()
        .map(|slot| {
            let (held, target) = match &slot.state {
                SlotState::Decoding(s) => (
                    s.kv().num_blocks(),
                    (s.pos() + s.remaining_budget()).min(max_seq),
                ),
                SlotState::Prefilling(p) => (
                    p.kv().num_blocks(),
                    (p.prompt_len() + p.max_new()).min(max_seq),
                ),
                SlotState::Transit => (0, 0),
            };
            arena.blocks_for(target).saturating_sub(held) + 1
        })
        .sum()
}

/// The continuous-batching scheduler core, separated from the worker
/// thread so it can be driven tick-by-tick — by the worker loop in
/// production, and by the deterministic trace harness
/// ([`crate::testutil::trace`]) with scripted arrivals in tests.
pub struct Scheduler<M: ForwardModel> {
    recycler: Recycler<M>,
    cfg: ServerConfig,
    sessions: SessionManager,
    running: Vec<Slot>,
    /// Requests held back: an earlier turn of their session is still in
    /// flight (turn N+1's prompt extends turn N's committed ids, so the
    /// two must not run concurrently), the arena lacks headroom, or no
    /// prefill slot was free.
    deferred: VecDeque<(Request, Hold)>,
    /// Replies produced by the current tick, handed back in
    /// [`TickReport::replies`] for the driver to deliver after it has
    /// published stats.
    outbox: TickReplies,
    stats: SchedulerStats,
    completed: u64,
    failed: u64,
    admission_waves: u64,
}

impl<M: ForwardModel> Scheduler<M> {
    pub fn new(mut recycler: Recycler<M>, cfg: ServerConfig) -> Self {
        // the config is authoritative however the scheduler is driven
        // (worker thread or the tick-level trace harness)
        recycler.populate_cache = cfg.populate_cache;
        if let Some(b) = cfg.segment_fidelity_budget {
            // cluster-wide segment-tier budget outranks whatever the
            // recycler factory configured (None leaves it alone)
            recycler.set_segment_fidelity_budget(b);
        }
        Scheduler {
            recycler,
            cfg,
            sessions: SessionManager::new(),
            running: Vec::new(),
            deferred: VecDeque::new(),
            outbox: Vec::new(),
            stats: SchedulerStats::default(),
            completed: 0,
            failed: 0,
            admission_waves: 0,
        }
    }

    /// Nothing in flight and nothing held back.
    pub fn is_idle(&self) -> bool {
        self.running.is_empty() && self.deferred.is_empty()
    }

    /// Slots currently in the running set (prefilling + decoding).
    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    /// Requests in the holdback queue.
    pub fn deferred_len(&self) -> usize {
        self.deferred.len()
    }

    pub fn stats(&self) -> SchedulerStats {
        self.stats
    }

    pub fn completed(&self) -> u64 {
        self.completed
    }

    pub fn failed(&self) -> u64 {
        self.failed
    }

    /// Ticks that admitted at least one request.
    pub fn admission_waves(&self) -> u64 {
        self.admission_waves
    }

    pub fn recycler(&self) -> &Recycler<M> {
        &self.recycler
    }

    /// How many fresh requests the driver should drain for the next tick.
    /// Zero while a headroom-held request waits (FIFO over the arena
    /// gate) or while the holdback queue is large — `deferred` sits
    /// outside the queue's capacity accounting, so draining into it
    /// without bound would quietly disable the submit-side backpressure
    /// (QueueError::Full) the sequential loop provided.
    pub fn fresh_quota(&self) -> usize {
        let free = self.cfg.max_batch.saturating_sub(self.running.len());
        let headroom_waiting = self.deferred.iter().any(|(_, h)| matches!(h, Hold::Headroom));
        if free == 0 || headroom_waiting || self.deferred.len() >= self.cfg.max_batch {
            0
        } else {
            free.saturating_sub(self.deferred.len())
        }
    }

    /// One scheduler tick: admission (holdback queue first, then `fresh`),
    /// one chunked-prefill step for the admitting slots, one batched
    /// decode dispatch, and the finish sweep. Returns the tick's event
    /// trace (admissions, deferrals, chunks, dispatches, completions)
    /// plus the replies to deliver — see [`TickReport`] for the required
    /// publish-then-reply ordering.
    pub fn tick(&mut self, fresh: Vec<Request>) -> TickReport {
        let mut events = Vec::new();
        let fresh = self.deadline_phase(fresh, &mut events);
        self.admit_wave(fresh, &mut events);
        self.prefill_phase(&mut events);
        self.decode_phase(&mut events);
        self.finish_phase(&mut events);
        TickReport {
            events,
            replies: std::mem::take(&mut self.outbox),
        }
    }

    /// Per-request deadline sweep, run at the top of every tick: any
    /// request that has spent more than `request_timeout_ms` since
    /// submission — wherever it sits (fresh off the queue, in the
    /// holdback queue, prefilling, or decoding) — is failed with a typed
    /// `DeadlineExceeded` reply. Dropping a running slot releases its
    /// blocks and growth reservation at the tick boundary, so a wedged or
    /// endlessly-retried request cannot pin arena capacity forever. Also
    /// advances retry cooldowns (one tick closer to the next attempt).
    fn deadline_phase(
        &mut self,
        fresh: Vec<Request>,
        events: &mut Vec<SchedEvent>,
    ) -> Vec<Request> {
        let budget_ms = self.cfg.request_timeout_ms;
        let mut i = 0;
        while i < self.running.len() {
            let waited_ms = self.running[i].req.queued_at.elapsed().as_millis() as u64;
            if waited_ms <= budget_ms {
                i += 1;
                continue;
            }
            let slot = self.running.swap_remove(i);
            self.fail_deadline(slot.req, waited_ms, events);
            // i not advanced: swap_remove moved a new slot here; dropping
            // `slot` released its stream's blocks
        }
        let mut keep = VecDeque::with_capacity(self.deferred.len());
        for (req, hold) in std::mem::take(&mut self.deferred) {
            let waited_ms = req.queued_at.elapsed().as_millis() as u64;
            if waited_ms <= budget_ms {
                keep.push_back((req, hold));
            } else {
                self.fail_deadline(req, waited_ms, events);
            }
        }
        self.deferred = keep;
        let mut pass = Vec::with_capacity(fresh.len());
        for req in fresh {
            let waited_ms = req.queued_at.elapsed().as_millis() as u64;
            if waited_ms <= budget_ms {
                pass.push(req);
            } else {
                self.fail_deadline(req, waited_ms, events);
            }
        }
        for slot in &mut self.running {
            slot.cooldown = slot.cooldown.saturating_sub(1);
        }
        pass
    }

    fn fail_deadline(&mut self, req: Request, waited_ms: u64, events: &mut Vec<SchedEvent>) {
        let e = Error::DeadlineExceeded {
            waited_ms,
            budget_ms: self.cfg.request_timeout_ms,
        };
        self.failed += 1;
        self.stats.deadline_timeouts += 1;
        events.push(SchedEvent::TimedOut {
            id: req.id,
            waited_ms,
        });
        send_terminal(&mut self.outbox, req.reply, req.stream, Response::err(&e));
    }

    /// Decide what a failed step means for slot `i`: a transient error
    /// with retry budget left arms an exponential tick-based cooldown
    /// (`retry_backoff_ticks << (attempt - 1)`) and keeps the slot —
    /// returns `true`. A permanent error, or a transient one past
    /// `transient_retry_limit` total attempts, returns `false`: the
    /// caller must reply and drop the slot.
    fn keep_for_retry(&mut self, i: usize, e: &Error, events: &mut Vec<SchedEvent>) -> bool {
        let slot = &mut self.running[i];
        if e.is_transient() && slot.attempt + 1 < self.cfg.transient_retry_limit {
            slot.attempt += 1;
            slot.cooldown = self.cfg.retry_backoff_ticks << (slot.attempt - 1);
            self.stats.transient_retries += 1;
            events.push(SchedEvent::Retried {
                id: slot.req.id,
                attempt: slot.attempt,
                cooldown_ticks: slot.cooldown,
            });
            true
        } else {
            if e.is_transient() {
                self.stats.retry_give_ups += 1;
            }
            false
        }
    }

    /// Fill free slots without stalling active streams: holdback queue
    /// first (their blocking turn may have finished last tick), then the
    /// fresh arrivals the driver drained.
    fn admit_wave(&mut self, fresh: Vec<Request>, events: &mut Vec<SchedEvent>) {
        let free = self.cfg.max_batch.saturating_sub(self.running.len());
        let mut arrivals: Vec<Request> = Vec::new();
        if free > 0 {
            // a deferred entry also waits behind any EARLIER deferred
            // entry of its session, so per-session FIFO holds across the
            // holdback queue too
            let mut i = 0;
            while i < self.deferred.len() && arrivals.len() < free {
                let blocked = self.deferred[i].0.session.as_deref().is_some_and(|sid| {
                    session_blocked(sid, &self.running, &arrivals, &self.deferred, i, &[])
                });
                if blocked {
                    i += 1;
                } else {
                    arrivals.push(self.deferred.remove(i).expect("index in bounds").0);
                }
            }
        }
        let from_deferred = arrivals.len();
        arrivals.extend(fresh);
        // Requests held back this wave. Ones that came OUT of `deferred`
        // (index < from_deferred) must return to its FRONT so they stay
        // ahead of later arrivals of their session — per-session order is
        // a correctness invariant; fresh arrivals go to the back.
        let mut requeue_front: Vec<(Request, Hold)> = Vec::new();
        let mut admitted_this_wave = false;
        // Set when a candidate is held for arena headroom this wave:
        // everything behind it is then held too (FIFO over the gate).
        let mut headroom_hold = false;
        let mut prefilling = self.running.iter().filter(|s| s.is_prefilling()).count();
        for (ai, req) in arrivals.into_iter().enumerate() {
            let hold_back = |req: Request,
                             hold: Hold,
                             requeue_front: &mut Vec<(Request, Hold)>,
                             deferred: &mut VecDeque<(Request, Hold)>| {
                if ai < from_deferred {
                    requeue_front.push((req, hold));
                } else {
                    deferred.push_back((req, hold));
                }
            };
            if headroom_hold {
                events.push(SchedEvent::Deferred {
                    id: req.id,
                    reason: DeferReason::Headroom,
                });
                hold_back(req, Hold::Session, &mut requeue_front, &mut self.deferred);
                continue;
            }
            let blocked = req.session.as_deref().is_some_and(|sid| {
                // A candidate pulled from the holdback queue must NOT be
                // blocked by `deferred`'s remaining same-session entries:
                // the pull loop took the EARLIEST, so whatever is left of
                // its session is a strictly later turn (scanning them
                // would re-block it forever — livelock). Fresh arrivals
                // wait behind the whole holdback queue.
                let deferred_ahead = if ai < from_deferred { 0 } else { self.deferred.len() };
                session_blocked(sid, &self.running, &[], &self.deferred, deferred_ahead,
                                &requeue_front)
            });
            if blocked {
                events.push(SchedEvent::Deferred {
                    id: req.id,
                    reason: DeferReason::Session,
                });
                hold_back(req, Hold::Session, &mut requeue_front, &mut self.deferred);
                continue;
            }
            // Admission opens a prefill slot, so both capacity gates apply:
            // the running set (`max_batch`) and the admitting subset
            // (`max_prefilling_slots` — bounding how many multi-tick
            // prefills interleave with decode at once).
            if self.running.len() >= self.cfg.max_batch
                || prefilling >= self.cfg.max_prefilling_slots
            {
                events.push(SchedEvent::Deferred {
                    id: req.id,
                    reason: DeferReason::Slot,
                });
                hold_back(req, Hold::Session, &mut requeue_front, &mut self.deferred);
                continue;
            }
            // Arena headroom is re-derived per admission: the gate inside
            // admit_one compares the request's estimated prompt + budget
            // against the free blocks left after reserving every running
            // slot's unconsumed growth — including the remaining prompt of
            // slots still mid-prefill (reservations span chunk boundaries).
            let headroom_reserved = if self.running.is_empty() {
                None
            } else {
                Some(reserved_growth_blocks(&self.running, &self.recycler))
            };
            let waited_ms = req.queued_at.elapsed().as_millis() as u64;
            match admit_one(req, &mut self.recycler, &self.sessions, &self.cfg,
                            headroom_reserved) {
                Admit::Ready(slot) => {
                    self.stats.note_admission(waited_ms);
                    events.push(SchedEvent::Admitted { id: slot.req.id });
                    self.running.push(*slot);
                    prefilling += 1;
                    admitted_this_wave = true;
                }
                Admit::Defer(req) => {
                    headroom_hold = true;
                    events.push(SchedEvent::Deferred {
                        id: req.id,
                        reason: DeferReason::Headroom,
                    });
                    hold_back(req, Hold::Headroom, &mut requeue_front, &mut self.deferred);
                }
                Admit::Fail(req, e) => {
                    self.failed += 1;
                    events.push(SchedEvent::Failed {
                        id: req.id,
                        msg: e.to_string(),
                    });
                    send_terminal(&mut self.outbox, req.reply, req.stream, Response::err(&e));
                }
            }
        }
        for held in requeue_front.into_iter().rev() {
            self.deferred.push_front(held);
        }
        if admitted_this_wave {
            self.admission_waves += 1;
        }
    }

    /// Advance every admitting slot's prefill by at most the per-tick
    /// chunk budget. A mid-prefill `ArenaExhausted` gets one
    /// shed-and-*resume* retry (the stream keeps its completed chunks, so
    /// no chunk is re-run or double-counted); any other failure — or a
    /// failed retry — is replied-to and the slot dropped, releasing its
    /// blocks at the chunk boundary.
    fn prefill_phase(&mut self, events: &mut Vec<SchedEvent>) {
        let budget = self.cfg.prefill_chunk_tokens;
        let decode_active = self
            .running
            .iter()
            .any(|s| matches!(&s.state, SlotState::Decoding(d) if !d.is_finished()));
        let mut tick_tokens = 0usize;
        let mut tick_chunks = 0usize;
        let mut i = 0;
        while i < self.running.len() {
            if !self.running[i].is_prefilling() || self.running[i].cooling() {
                i += 1;
                continue;
            }
            let id = self.running[i].req.id;
            let (step, slot_tokens, slot_chunks, done_now) = {
                let SlotState::Prefilling(ps) = &mut self.running[i].state else {
                    unreachable!("checked is_prefilling above")
                };
                let pos0 = ps.pos();
                let calls0 = ps.prefill_calls();
                let mut res = self.recycler.engine_mut().step_prefill(ps, budget);
                if matches!(res, Err(Error::ArenaExhausted { .. })) {
                    // Shed-and-RESUME: the cheap headroom pass stops
                    // shedding when evictions stop yielding blocks; an
                    // actual allocation failure is the backstop — drain
                    // the cache as far as needed and retry once. The
                    // stream stays at its last committed chunk boundary,
                    // so only the failed chunk re-runs (prefill_calls
                    // stays exact) and the remaining per-tick budget
                    // still bounds this tick's stall.
                    self.recycler.shed_for_tokens(ps.remaining() + ps.max_new());
                    self.stats.prefill_retries += 1;
                    events.push(SchedEvent::PrefillRetry { id });
                    let left = budget.saturating_sub(ps.pos() - pos0).max(1);
                    res = self.recycler.engine_mut().step_prefill(ps, left);
                }
                (
                    res.map(|_| ()),
                    ps.pos() - pos0,
                    ps.prefill_calls() - calls0,
                    ps.is_done(),
                )
            };
            tick_tokens += slot_tokens;
            tick_chunks += slot_chunks;
            match step {
                Ok(()) => {
                    events.push(SchedEvent::PrefillChunk {
                        id,
                        tokens: slot_tokens,
                        done: done_now,
                    });
                    if done_now {
                        let state =
                            std::mem::replace(&mut self.running[i].state, SlotState::Transit);
                        let SlotState::Prefilling(ps) = state else {
                            unreachable!("slot was prefilling")
                        };
                        match self.recycler.engine_mut().finish_prefill(ps) {
                            Ok(ds) => self.running[i].state = SlotState::Decoding(ds),
                            Err(e) => {
                                // defensive: finish_prefill only errors on
                                // an incomplete stream, which done_now rules
                                // out
                                let slot = self.running.swap_remove(i);
                                self.failed += 1;
                                events.push(SchedEvent::Failed {
                                    id,
                                    msg: e.to_string(),
                                });
                                send_terminal(
                                    &mut self.outbox,
                                    slot.req.reply,
                                    slot.req.stream,
                                    Response::err(&e),
                                );
                                continue; // i not advanced: swap_remove
                            }
                        }
                    }
                    i += 1;
                }
                Err(e) => {
                    // A transient failure (model hiccup, IO, residual
                    // arena pressure after the shed-resume above) gets a
                    // bounded tick-based backoff retry: the stream stays
                    // at its last committed chunk boundary, so the retry
                    // re-runs only the failed chunk. Anything else — or an
                    // exhausted retry budget — is replied-to and the slot
                    // dropped ON THE SPOT, releasing its partial blocks so
                    // one faulty request never wedges the scheduler.
                    if self.keep_for_retry(i, &e, events) {
                        i += 1;
                    } else {
                        let slot = self.running.swap_remove(i);
                        self.failed += 1;
                        events.push(SchedEvent::Failed {
                            id,
                            msg: e.to_string(),
                        });
                        send_terminal(
                            &mut self.outbox,
                            slot.req.reply,
                            slot.req.stream,
                            Response::err(&e),
                        );
                        // i not advanced: swap_remove moved a new slot here
                    }
                }
            }
        }
        self.stats.note_prefill_tick(tick_tokens, tick_chunks, decode_active);
    }

    /// One batched decode step over every active stream, then first-token
    /// latency accounting.
    fn decode_phase(&mut self, events: &mut Vec<SchedEvent>) {
        let mut refs: Vec<&mut DecodeStream> = self
            .running
            .iter_mut()
            .filter_map(|s| match &mut s.state {
                // cooling slots sit out the dispatch until their retry
                // backoff elapses
                SlotState::Decoding(d) if !d.is_finished() && s.cooldown == 0 => Some(d),
                _ => None,
            })
            .collect();
        if !refs.is_empty() {
            let step = self.recycler.engine_mut().step_streams(&mut refs);
            drop(refs);
            match step {
                Ok(report) if report.scheduled > 0 => {
                    // record the true dispatch occupancy (streams that fed
                    // the forward), not the pre-drain running-set size
                    self.stats.note_step(report.scheduled);
                    events.push(SchedEvent::DecodeStep {
                        occupancy: report.scheduled,
                    });
                }
                Ok(_) => {}
                Err(_) => {
                    // Isolate the faulty stream(s): a failed step leaves
                    // every stream's logical state untouched and KV writes
                    // at a fixed (token, position) are idempotent, so
                    // per-stream retries are token-exact. Every stream —
                    // including a lone one, so the failure policy does not
                    // depend on unrelated traffic — gets exactly one
                    // retry; a stream that fails it is replied to and
                    // dropped ON THE SPOT, freeing its KV blocks so a
                    // resource error (ArenaExhausted) fails one stream,
                    // not the batch.
                    let mut i = 0;
                    while i < self.running.len() {
                        let active = self.running[i].cooldown == 0
                            && matches!(
                                &self.running[i].state,
                                SlotState::Decoding(d) if !d.is_finished()
                            );
                        if !active {
                            i += 1;
                            continue;
                        }
                        let id = self.running[i].req.id;
                        let res = {
                            let SlotState::Decoding(d) = &mut self.running[i].state else {
                                unreachable!("checked active above")
                            };
                            self.recycler.engine_mut().step_streams(&mut [d])
                        };
                        match res {
                            Ok(report) => {
                                // retries are dispatches too: keep the
                                // occupancy counters covering every step
                                if report.scheduled > 0 {
                                    self.stats.note_step(report.scheduled);
                                    events.push(SchedEvent::DecodeStep {
                                        occupancy: report.scheduled,
                                    });
                                }
                                i += 1;
                            }
                            Err(e) => {
                                // transient + budget left: keep the slot in
                                // backoff (retries are token-exact — a
                                // failed step left its logical state
                                // untouched); otherwise reply-and-drop
                                if self.keep_for_retry(i, &e, events) {
                                    i += 1;
                                    continue;
                                }
                                let r = self.running.swap_remove(i);
                                self.failed += 1;
                                events.push(SchedEvent::Failed {
                                    id,
                                    msg: e.to_string(),
                                });
                                send_terminal(
                                    &mut self.outbox,
                                    r.req.reply,
                                    r.req.stream,
                                    Response::err(&e),
                                );
                                // i not advanced: swap_remove moved a new
                                // slot here; dropping `r` released blocks
                            }
                        }
                    }
                }
            }
        }
        // Time-to-first-token accounting and the streaming emission sweep:
        // every token a stream's decode produced this tick (at most one per
        // slot) is mirrored to the request's stream channel the moment it
        // exists — before finish_phase runs, so token events always precede
        // the End event of the same tick. TTFT is measured from submission
        // (queue wait plus however many prefill ticks admission took).
        let tokenizer = self.recycler.tokenizer();
        for slot in &mut self.running {
            let SlotState::Decoding(d) = &slot.state else {
                continue;
            };
            let gen = d.generated();
            if !slot.ttft_noted && !gen.is_empty() {
                slot.ttft_noted = true;
                self.stats
                    .note_first_token(slot.req.queued_at.elapsed().as_millis() as u64);
                events.push(SchedEvent::FirstToken { id: slot.req.id });
            }
            if let Some(tx) = &slot.req.stream {
                let finished = d.is_finished();
                while slot.streamed < gen.len() {
                    let index = slot.streamed;
                    let id = gen[index];
                    let mut text = slot.decoder.push(&tokenizer, id);
                    slot.streamed += 1;
                    // A finished stream flushes its held-back incomplete
                    // UTF-8 tail into the final token (lossy, exactly as
                    // whole-sequence decode replaces it), so
                    // concat(token.text) == done.output holds byte-exact.
                    if finished && slot.streamed == gen.len() {
                        text.push_str(&slot.decoder.flush_lossy());
                    }
                    let _ = tx.send(StreamEvent::Token { index, id, text });
                }
            }
        }
    }

    /// Reply per request the moment its stream completes.
    fn finish_phase(&mut self, events: &mut Vec<SchedEvent>) {
        let mut i = 0;
        while i < self.running.len() {
            let done = matches!(
                &self.running[i].state,
                SlotState::Decoding(d) if d.is_finished()
            );
            if !done {
                i += 1;
                continue;
            }
            let slot = self.running.swap_remove(i);
            let SlotState::Decoding(stream) = slot.state else {
                unreachable!("checked done above")
            };
            let g = stream.into_generated();
            let n_out = g.ids.len();
            let outcome =
                self.recycler
                    .complete(&slot.prompt_text, &slot.prompt_ids, slot.meta, g);
            self.completed += 1;
            events.push(SchedEvent::Finished {
                id: slot.req.id,
                tokens: n_out,
            });
            if let Some(sid) = &slot.req.session {
                let mut full_ids = slot.prompt_ids;
                full_ids.extend_from_slice(&outcome.ids);
                let full_text = format!("{}{}", slot.prompt_text, outcome.text);
                self.sessions
                    .commit(sid, &slot.req.prompt, full_text, full_ids, &outcome.text);
            }
            send_terminal(
                &mut self.outbox,
                slot.req.reply,
                slot.req.stream,
                Response::Ok(Box::new(outcome)),
            );
        }
    }
}

fn worker_loop<M: ForwardModel>(
    shared: Arc<WorkerShared>,
    recycler: Recycler<M>,
    cfg: ServerConfig,
) {
    let mut sched = Scheduler::new(recycler, cfg.clone());
    loop {
        let quota = sched.fresh_quota();
        let fresh = if sched.is_idle() {
            if shared.queue.is_closed() && shared.queue.is_empty() {
                break;
            }
            // idle: block briefly for the first request, then a short
            // follow-up window for stragglers
            drain_batch(
                &shared.queue,
                quota.max(1),
                Duration::from_millis(cfg.batch_first_wait_ms),
                Duration::from_millis(cfg.batch_window_ms),
            )
        } else if quota > 0 {
            // slots in flight: never block, take what's ready
            drain_ready(&shared.queue, quota)
        } else {
            Vec::new()
        };
        let tick = sched.tick(fresh);
        let made_progress = !tick.events.is_empty() || !tick.replies.is_empty();
        // publish scheduler + engine + cache state (submitted/rejected are
        // owned by the submit side) BEFORE delivering replies, so a
        // submitter that wakes on its reply reads counters that already
        // include its own completion
        {
            let mut stats = lock_recover(&shared.stats);
            stats.scheduler = sched.stats();
            stats.completed = sched.completed();
            stats.failed = sched.failed();
            stats.batches = sched.admission_waves();
            let recycler = sched.recycler();
            stats.engine = recycler.engine().counters();
            stats.cache = recycler.store().stats();
            stats.cache_entries = recycler.store().len();
            stats.cache_bytes = recycler.store().live_bytes();
            stats.arena_used_blocks = recycler.arena().used_blocks();
            stats.arena_capacity_blocks = recycler.arena().capacity_blocks();
        }
        for (tx, resp) in tick.replies {
            let _ = tx.send(resp);
        }
        if !made_progress && !sched.is_idle() {
            // every runnable slot is sitting out a retry cooldown: yield
            // briefly instead of hot-spinning ticks while the tick-based
            // backoff elapses
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::coordinator::Coordinator;
    use crate::engine::Engine;
    use crate::index::NgramEmbedder;
    use crate::recycler::RecyclePolicy;
    use crate::testutil::MockModel;
    use crate::tokenizer::Tokenizer;

    fn coordinator(cfg: ServerConfig) -> Coordinator {
        Coordinator::spawn(
            |_| {
                let engine = Engine::new(MockModel::new(ModelConfig::nano()));
                Recycler::new(
                    engine,
                    std::sync::Arc::new(Tokenizer::new(vec![])),
                    Box::new(NgramEmbedder::new(64)),
                    Default::default(),
                    RecyclePolicy::Strict,
                )
            },
            cfg,
        )
    }

    #[test]
    fn serves_a_request() {
        let c = coordinator(ServerConfig::default());
        let out = c.generate("hello world this is a prompt", 4).unwrap();
        assert_eq!(out.ids.len(), 4);
        let stats = c.stats();
        assert_eq!(stats.submitted, 1);
        assert_eq!(stats.completed, 1);
        c.shutdown();
    }

    #[test]
    fn repeated_prompt_hits_cache() {
        let c = coordinator(ServerConfig::default());
        let a = c.generate("what is the capital of france?", 4).unwrap();
        assert!(!a.cache_hit);
        let b = c
            .generate("what is the capital of france? and italy?", 4)
            .unwrap();
        assert!(b.cache_hit);
        assert!(b.reuse_depth > 0);
        c.shutdown();
    }

    #[test]
    fn concurrent_submitters() {
        let c = std::sync::Arc::new(coordinator(ServerConfig {
            max_batch: 4,
            ..Default::default()
        }));
        let mut handles = Vec::new();
        for t in 0..4 {
            let c2 = std::sync::Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                let out = c2.generate(&format!("prompt number {t} for testing"), 3).unwrap();
                out.ids.len()
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), 3);
        }
        assert_eq!(c.stats().completed, 4);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // tiny queue + a worker that's busy: fill it up
        let c = coordinator(ServerConfig {
            queue_capacity: 1,
            ..Default::default()
        });
        // Burst faster than the worker drains; at least one must be
        // rejected OR all succeed quickly — assert the error type when it
        // fires rather than racing the worker.
        let mut rejected = false;
        let mut receivers = Vec::new();
        for i in 0..50 {
            match c.submit(&format!("p{i} xxxx"), 2, None) {
                Ok(rx) => receivers.push(rx),
                Err(Error::Overloaded { depth, capacity }) => {
                    assert_eq!(capacity, 1, "shed reply reports the bound");
                    assert!(depth <= capacity);
                    rejected = true;
                    break;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        for rx in receivers {
            let _ = rx.recv();
        }
        if rejected {
            assert!(c.stats().rejected >= 1);
        }
        c.shutdown();
    }

    #[test]
    fn session_turns_recycle_their_transcript() {
        let c = coordinator(ServerConfig::default());
        let t1 = c.chat("sess", "hello there friend", 3).unwrap();
        assert!(!t1.cache_hit, "first turn has nothing to reuse");
        let t2 = c.chat("sess", "tell me more", 3).unwrap();
        assert!(t2.cache_hit, "turn 2 must reuse turn 1's transcript KV");
        assert!(t2.reuse_depth > 0);
        // the paged arena is live and bounded
        let stats = c.stats();
        assert!(stats.arena_used_blocks > 0, "session KV must hold blocks");
        assert!(stats.arena_used_blocks <= stats.arena_capacity_blocks);
        c.shutdown();
    }

    #[test]
    fn concurrent_batch_matches_sequential_outputs() {
        // the same request set served at max_batch 4 and max_batch 1 must
        // be token-identical (the paper's exactness property, batched)
        let prompts: Vec<String> = (0..8)
            .map(|i| format!("unrelated prompt number {i} about topic {}", i * 7))
            .collect();
        let seq = coordinator(ServerConfig {
            max_batch: 1,
            ..Default::default()
        });
        let expected: Vec<Vec<u32>> = prompts
            .iter()
            .map(|p| seq.generate(p, 5).unwrap().ids)
            .collect();
        seq.shutdown();

        let bat = std::sync::Arc::new(coordinator(ServerConfig {
            max_batch: 4,
            ..Default::default()
        }));
        let mut handles = Vec::new();
        for (i, p) in prompts.iter().enumerate() {
            let c = std::sync::Arc::clone(&bat);
            let p = p.clone();
            handles.push(std::thread::spawn(move || {
                (i, c.generate(&p, 5).unwrap().ids)
            }));
        }
        for h in handles {
            let (i, ids) = h.join().unwrap();
            assert_eq!(ids, expected[i], "request {i} diverged under batching");
        }
        let stats = bat.stats();
        assert_eq!(stats.completed, 8);
        assert!(stats.scheduler.decode_steps > 0);
        assert!(stats.scheduler.admitted == 8);
        assert!(stats.scheduler.avg_occupancy() >= 1.0);
    }

    #[test]
    fn session_survives_past_context_window() {
        // Acceptance: a session must keep serving for >= 3x max_seq
        // cumulative tokens — the old path wedged on PromptTooLong forever
        // once the transcript neared the window.
        let c = coordinator(ServerConfig::default());
        let max_seq = ModelConfig::nano().max_seq; // 256
        let mut cumulative = 0usize;
        let mut turns = 0usize;
        while cumulative < 3 * max_seq + max_seq / 2 {
            let out = c
                .chat("marathon", "tell me something new about the weather", 8)
                .unwrap_or_else(|e| panic!("turn {turns} wedged: {e}"));
            cumulative += out.prompt_tokens + out.ids.len();
            turns += 1;
            assert!(turns < 500, "not making progress");
        }
        assert!(turns > 3, "window-sized turns should take several rounds");
        // the session is still healthy after crossing the window repeatedly
        let out = c.chat("marathon", "one more for the road", 4).unwrap();
        assert!(out.prompt_tokens <= max_seq);
        assert_eq!(c.stats().failed, 0);
        c.shutdown();
    }

    #[test]
    fn same_session_turns_never_run_concurrently() {
        // fire two turns of one session back-to-back without waiting; the
        // scheduler must defer turn 2 until turn 1 commits, and both succeed
        let c = coordinator(ServerConfig {
            max_batch: 4,
            ..Default::default()
        });
        let rx1 = c.submit("first turn", 4, Some("s".into())).unwrap();
        let rx2 = c.submit("second turn", 4, Some("s".into())).unwrap();
        let o1 = rx1.recv().unwrap().ok().unwrap();
        let o2 = rx2.recv().unwrap().ok().unwrap();
        assert_eq!(o1.ids.len(), 4);
        assert_eq!(o2.ids.len(), 4);
        assert!(
            o2.prompt_tokens > o1.prompt_tokens,
            "turn 2 must see turn 1's committed transcript"
        );
        assert!(o2.cache_hit, "turn 2 recycles turn 1's KV");
        c.shutdown();
    }

    #[test]
    fn three_queued_session_turns_all_complete_in_order() {
        // regression: with >= 2 turns of one session parked in the
        // holdback queue, the first pulled turn must not be re-blocked by
        // its own LATER turns still sitting there (that was a livelock)
        let c = coordinator(ServerConfig {
            max_batch: 4,
            ..Default::default()
        });
        let rx1 = c.submit("turn one", 3, Some("s".into())).unwrap();
        let rx2 = c.submit("turn two", 3, Some("s".into())).unwrap();
        let rx3 = c.submit("turn three", 3, Some("s".into())).unwrap();
        let o1 = rx1.recv().unwrap().ok().unwrap();
        let o2 = rx2.recv().unwrap().ok().unwrap();
        let o3 = rx3.recv().unwrap().ok().unwrap();
        assert!(o2.prompt_tokens > o1.prompt_tokens, "turn 2 after turn 1");
        assert!(o3.prompt_tokens > o2.prompt_tokens, "turn 3 after turn 2");
        assert_eq!(c.stats().completed, 3);
        c.shutdown();
    }

    fn faulty_coordinator(fail_call: usize, cfg: ServerConfig) -> Coordinator {
        Coordinator::spawn(
            move |_| {
                let engine =
                    Engine::new(MockModel::new(ModelConfig::nano()).fail_on_call(fail_call));
                Recycler::new(
                    engine,
                    std::sync::Arc::new(Tokenizer::new(vec![])),
                    Box::new(NgramEmbedder::new(64)),
                    Default::default(),
                    RecyclePolicy::Strict,
                )
            },
            cfg,
        )
    }

    #[test]
    fn transient_failure_is_retried_to_success() {
        // one transient forward failure, default retry budget (3 attempts):
        // the scheduler absorbs it with a backoff retry and the request
        // still completes — no error ever reaches the client
        let c = faulty_coordinator(1, ServerConfig::default());
        let out = c.generate("boom but recoverable", 2).unwrap();
        assert_eq!(out.ids.len(), 2);
        let stats = c.stats();
        assert_eq!(stats.failed, 0);
        assert_eq!(stats.completed, 1);
        assert!(stats.scheduler.transient_retries >= 1, "retry was counted");
        assert_eq!(stats.scheduler.retry_give_ups, 0);
        c.shutdown();
    }

    #[test]
    fn fail_fast_surfaces_transient_error_when_retries_disabled() {
        // transient_retry_limit 1 = fail fast: the same single fault now
        // surfaces as a typed error response, and the stream's blocks are
        // released so the next request serves cleanly
        let c = faulty_coordinator(
            1,
            ServerConfig {
                transient_retry_limit: 1,
                ..Default::default()
            },
        );
        let err = c.generate("boom", 2).unwrap_err();
        assert!(err.to_string().contains("injected"));
        let stats = c.stats();
        assert_eq!(stats.failed, 1);
        assert_eq!(stats.scheduler.transient_retries, 0);
        assert_eq!(stats.scheduler.retry_give_ups, 1);
        // next request works (failure was transient)
        assert!(c.generate("fine now", 2).is_ok());
        c.shutdown();
    }

    #[test]
    fn permanent_fault_fails_immediately_despite_retry_budget() {
        use crate::faults::{FaultPlan, FaultSite};
        let h = FaultPlan::new(7).script(FaultSite::ModelPermanent, &[1]).install();
        let c = Coordinator::spawn(
            move |_| {
                let engine =
                    Engine::new(MockModel::new(ModelConfig::nano()).with_faults(h.clone()));
                Recycler::new(
                    engine,
                    std::sync::Arc::new(Tokenizer::new(vec![])),
                    Box::new(NgramEmbedder::new(64)),
                    Default::default(),
                    RecyclePolicy::Strict,
                )
            },
            ServerConfig::default(),
        );
        let err = c.generate("doomed from the start", 2).unwrap_err();
        assert!(err.to_string().contains("permanent"));
        let stats = c.stats();
        assert_eq!(stats.failed, 1);
        assert_eq!(stats.scheduler.transient_retries, 0, "no retry wasted");
        assert!(c.generate("healthy again", 2).is_ok());
        c.shutdown();
    }

    #[test]
    fn deadline_reaps_slow_request_with_typed_reply() {
        // a 2ms budget against a model that sleeps 5ms per token: the
        // deadline sweep must reap the slot at a tick boundary and reply
        // with the typed deadline error instead of letting the client hang
        let c = Coordinator::spawn(
            |_| {
                let engine = Engine::new(MockModel::with_delay(
                    ModelConfig::nano(),
                    Duration::from_millis(5),
                ));
                Recycler::new(
                    engine,
                    std::sync::Arc::new(Tokenizer::new(vec![])),
                    Box::new(NgramEmbedder::new(64)),
                    Default::default(),
                    RecyclePolicy::Strict,
                )
            },
            ServerConfig {
                request_timeout_ms: 2,
                ..Default::default()
            },
        );
        let err = c.generate("this prompt cannot finish in time", 8).unwrap_err();
        assert!(
            err.to_string().contains("deadline exceeded"),
            "typed deadline reply, got: {err}"
        );
        let stats = c.stats();
        assert_eq!(stats.failed, 1);
        assert_eq!(stats.scheduler.deadline_timeouts, 1);
        c.shutdown();
    }

    #[test]
    fn long_cold_prompt_prefills_across_multiple_ticks() {
        // A cache-cold prompt longer than the chunk budget must take
        // several prefill ticks (visible in the counters) and still serve
        // exactly; TTFT accounting fires for it.
        let c = coordinator(ServerConfig {
            prefill_chunk_tokens: 16,
            populate_cache: false,
            ..Default::default()
        });
        let prompt = "abcdefgh".repeat(20); // 160 byte-tokens
        let out = c.generate(&prompt, 3).unwrap();
        assert_eq!(out.ids.len(), 3);
        let s = c.stats().scheduler;
        assert!(
            s.prefill_ticks >= 160 / 16,
            "160-token prompt at 16/tick: got {} prefill ticks",
            s.prefill_ticks
        );
        assert_eq!(s.prefill_tokens, 160);
        assert!(s.prefill_chunks >= s.prefill_ticks);
        assert_eq!(s.first_tokens, 1, "TTFT recorded once");
        c.shutdown();
    }

    #[test]
    fn inline_budget_reproduces_single_tick_prefill() {
        // prefill_chunk_tokens >= max_seq: the whole prompt prefills in
        // its admission tick (the PR2 inline behavior, now a config point)
        let c = coordinator(ServerConfig {
            prefill_chunk_tokens: ModelConfig::nano().max_seq,
            populate_cache: false,
            ..Default::default()
        });
        let prompt = "xy".repeat(60);
        let out = c.generate(&prompt, 2).unwrap();
        assert_eq!(out.ids.len(), 2);
        let s = c.stats().scheduler;
        assert_eq!(s.prefill_ticks, 1, "one tick covered the whole prompt");
        assert_eq!(s.prefill_tokens, 120);
        c.shutdown();
    }

    #[test]
    fn closed_worker_rejects_submission() {
        let mut w = Worker::spawn(
            0,
            || {
                let engine = Engine::new(MockModel::new(ModelConfig::nano()));
                Recycler::new(
                    engine,
                    std::sync::Arc::new(Tokenizer::new(vec![])),
                    Box::new(NgramEmbedder::new(64)),
                    Default::default(),
                    RecyclePolicy::Strict,
                )
            },
            ServerConfig::default(),
        );
        w.close();
        w.join();
        let (tx, _rx) = mpsc::channel();
        let req = Request {
            id: 1,
            prompt: "x".into(),
            max_new_tokens: 1,
            session: None,
            reply: tx,
            queued_at: Instant::now(),
            tenant: None,
            stream: None,
        };
        assert_eq!(w.try_push(req).err(), Some(QueueError::Closed));
    }
}
