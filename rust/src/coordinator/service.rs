//! The coordinator service: worker thread + submission handle.
//!
//! The worker runs a **continuous-batching scheduler**: each queued
//! request becomes a per-request state machine (lookup → prefill → decode
//! → finish) held in a running set of [`DecodeStream`]s. Every scheduler
//! tick advances *all* active streams one token through a single
//! `forward_batch` call, and new arrivals are admitted between ticks —
//! a short request never waits for a long one to drain, and a
//! batching-capable backend amortizes per-dispatch overhead across the
//! whole running set. `max_batch = 1` degenerates to the paper's
//! request-at-a-time serving; batched decode is token-identical to it
//! (property-tested in `rust/tests/properties.rs`).
//!
//! Admission is arena-aware: while streams are in flight, new requests are
//! only admitted when [`Recycler::admission_headroom`] holds (cold cache
//! entries are shed first), so a newcomer cannot starve running decodes of
//! KV blocks. Two turns of the same session are never decoded
//! concurrently — the later one is deferred until the earlier commits.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::ServerConfig;
use crate::engine::{DecodeStream, ForwardModel};
use crate::error::{Error, Result};
use crate::metrics::{Counters, SchedulerStats};
use crate::recycler::{Outcome, Recycler, ServeMeta};

use super::batcher::{drain_batch, drain_ready};
use super::queue::{QueueError, RequestQueue};
use super::request::{Request, Response};
use super::session::{truncate_to_window, SessionManager};

/// Aggregate coordinator statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct CoordinatorStats {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub rejected: u64,
    /// Admission waves (scheduler ticks that admitted >= 1 request).
    pub batches: u64,
    /// Engine-level counters snapshot.
    pub engine: Counters,
    /// Continuous-batching occupancy + queue-wait counters.
    pub scheduler: SchedulerStats,
    pub cache_entries: usize,
    pub cache_bytes: usize,
    /// Paged-KV arena occupancy (cache records + in-flight requests).
    pub arena_used_blocks: usize,
    pub arena_capacity_blocks: usize,
}

struct Shared {
    queue: RequestQueue<Request>,
    stats: Mutex<CoordinatorStats>,
    next_id: AtomicU64,
}

/// Handle to a running coordinator. Dropping it shuts the worker down.
pub struct Coordinator {
    shared: Arc<Shared>,
    worker: Option<JoinHandle<()>>,
    cfg: ServerConfig,
}

impl Coordinator {
    /// Spawn the worker thread. `mk_recycler` runs ON the worker thread —
    /// the PJRT runtime's handles are not `Send`, so the model must be
    /// constructed where it will be used.
    pub fn spawn<M, F>(mk_recycler: F, cfg: ServerConfig) -> Coordinator
    where
        M: ForwardModel + 'static,
        F: FnOnce() -> Recycler<M> + Send + 'static,
    {
        let shared = Arc::new(Shared {
            queue: RequestQueue::new(cfg.queue_capacity),
            stats: Mutex::new(CoordinatorStats::default()),
            next_id: AtomicU64::new(1),
        });
        let worker_shared = Arc::clone(&shared);
        let wcfg = cfg.clone();
        let worker = std::thread::Builder::new()
            .name("recycle-coordinator".into())
            .spawn(move || {
                let mut recycler = mk_recycler();
                recycler.populate_cache = wcfg.populate_cache;
                worker_loop(worker_shared, recycler, wcfg)
            })
            .expect("spawn coordinator worker");
        Coordinator {
            shared,
            worker: Some(worker),
            cfg,
        }
    }

    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    /// Submit a request; returns a receiver for the response.
    pub fn submit(
        &self,
        prompt: &str,
        max_new_tokens: usize,
        session: Option<String>,
    ) -> Result<mpsc::Receiver<Response>> {
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: self.shared.next_id.fetch_add(1, Ordering::Relaxed),
            prompt: prompt.to_string(),
            max_new_tokens,
            session,
            reply: tx,
            queued_at: Instant::now(),
        };
        match self.shared.queue.push(req) {
            Ok(()) => {
                self.shared.stats.lock().unwrap().submitted += 1;
                Ok(rx)
            }
            Err(QueueError::Full) => {
                self.shared.stats.lock().unwrap().rejected += 1;
                Err(Error::Rejected("queue full".into()))
            }
            Err(QueueError::Closed) => Err(Error::ShutDown),
        }
    }

    /// Submit and wait (convenience for examples/tests).
    pub fn generate(&self, prompt: &str, max_new_tokens: usize) -> Result<Outcome> {
        let rx = self.submit(prompt, max_new_tokens, None)?;
        let resp = rx
            .recv()
            .map_err(|_| Error::ShutDown)?;
        resp.ok().map_err(Error::Rejected)
    }

    /// Multi-turn session request: builds the transcript prompt, serves it,
    /// records the turn.
    pub fn chat(&self, session_id: &str, user_msg: &str, max_new: usize) -> Result<Outcome> {
        let rx = self.submit(user_msg, max_new, Some(session_id.to_string()))?;
        let resp = rx.recv().map_err(|_| Error::ShutDown)?;
        resp.ok().map_err(Error::Rejected)
    }

    pub fn stats(&self) -> CoordinatorStats {
        *self.shared.stats.lock().unwrap()
    }

    pub fn queue_depth(&self) -> usize {
        self.shared.queue.len()
    }

    /// Graceful shutdown: stop accepting, drain, join.
    pub fn shutdown(mut self) {
        self.shared.queue.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shared.queue.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// One request in flight through the scheduler: its stream plus everything
/// needed to finish it (session commit, cache admission, reply channel).
/// Failures are replied-to and dropped where they occur (admission or the
/// step-retry path), so a slot in `running` is always healthy.
struct Running {
    req: Request,
    prompt_text: String,
    prompt_ids: Vec<u32>,
    meta: ServeMeta,
    stream: DecodeStream,
}

/// What became of one admission attempt.
enum Admit {
    /// Prefilled and decoding — a new running slot.
    Ready(Box<Running>),
    /// The arena lacks headroom for this request right now; hold it back
    /// until running streams free blocks.
    Defer(Request),
    /// Tokenization/prefill failed; reply with the message.
    Fail(Request, String),
}

/// Gate + tokenize + session-extend + lookup + prefill one request into a
/// running slot. `headroom_reserved` is `Some(blocks)` while other streams
/// are decoding (their unconsumed growth): admission then requires arena
/// headroom for THIS request's estimated prompt + budget on top of that
/// reserve, so a wave of near-window prompts cannot exhaust the arena
/// mid-wave and hard-fail requests the sequential loop would have served.
/// With `None` (idle scheduler) admission always proceeds — `prepare`
/// sheds cache internally, so serial serving is always possible.
fn admit_one<M: ForwardModel>(
    req: Request,
    recycler: &mut Recycler<M>,
    sessions: &SessionManager,
    cfg: &ServerConfig,
    headroom_reserved: Option<usize>,
) -> Admit {
    let max_new = if req.max_new_tokens == 0 {
        cfg.default_max_new_tokens
    } else {
        req.max_new_tokens
    };
    let max_seq = recycler.config().max_seq;
    // Session prompts are cut to this budget before serving (sliding
    // window below), so both the admission estimate and the truncation
    // must use the same number.
    let session_budget = max_seq.saturating_sub(max_new.min(max_seq / 2)).max(1);
    if let Some(reserved) = headroom_reserved {
        // Cheap size upper bound BEFORE any transcript cloning or
        // tokenization: byte length bounds the BPE token count from above
        // (merges only shrink) and session transcripts report their token
        // count in O(1). A headroom-deferred request is re-tried every
        // scheduler tick, so this path must stay O(1); the bound is
        // conservative, so a request it passes cannot out-size the gate.
        let est_prompt = match &req.session {
            // + segment markers ("\nUser: ...\nBot:"); clamped by the
            // sliding-window budget — gating on the pre-truncation
            // transcript would permanently defer long-lived sessions and
            // stall the whole scheduler behind them (Hold::Headroom FIFO)
            Some(sid) => {
                (sessions.context_tokens(sid) + req.prompt.len() + 16).min(session_budget)
            }
            None => req.prompt.len(),
        };
        if !recycler.admission_headroom(est_prompt + max_new, reserved) {
            return Admit::Defer(req);
        }
    }
    // Session requests continue the transcript at the *token* level; the
    // previous turn's cached prompt+response KV makes the prefill
    // incremental (see coordinator::session).
    let tokenizer = recycler.tokenizer();
    let (mut prompt_text, mut prompt_ids) = match &req.session {
        Some(sid) => {
            let seg = sessions.segment_for(sid, &req.prompt);
            let (mut text, mut ids) = sessions.state_of(sid);
            text.push_str(&seg);
            ids.extend(tokenizer.encode(&seg));
            (text, ids)
        }
        None => (req.prompt.clone(), tokenizer.encode(&req.prompt)),
    };
    let is_session = req.session.is_some();
    if is_session {
        // Sliding window: keep the transcript suffix when the prompt plus
        // the generation budget would overflow the context window, so a
        // long-lived session keeps serving instead of wedging on
        // PromptTooLong forever. The reserve is capped at half the window
        // so a huge max_new cannot gut the whole transcript.
        let budget = session_budget;
        if prompt_ids.len() > budget {
            // Hysteresis: cut to HALF the budget, not to its edge —
            // trimming to the edge would re-truncate every following turn,
            // and the ever-moving head would never prefix-match a cached
            // record again (zero KV reuse past the window). A deep cut
            // lets the next several turns fit untruncated, so turn N+1
            // admits a post-cut record and turn N+2 onward recycles it
            // (the re-anchor the session docs promise).
            let keep = (budget / 2).max(1);
            truncate_to_window(&mut prompt_ids, keep);
            // the truncated ids are authoritative; re-derive display text
            prompt_text = tokenizer.decode(&prompt_ids);
        }
    }
    let started = try_start(recycler, &prompt_text, &prompt_ids, max_new, is_session)
        .or_else(|e| match e {
            Error::ArenaExhausted { .. } => {
                // The cheap headroom pass stops shedding when evictions
                // stop yielding blocks; an actual allocation failure is
                // the backstop — drain the cache as far as needed and
                // retry once (the failed attempt's partial blocks were
                // released with its stream).
                recycler.shed_for_tokens(prompt_ids.len() + max_new);
                try_start(recycler, &prompt_text, &prompt_ids, max_new, is_session)
            }
            e => Err(e),
        });
    match started {
        Ok((stream, meta)) => Admit::Ready(Box::new(Running {
            req,
            prompt_text,
            prompt_ids,
            meta,
            stream,
        })),
        Err(e) => Admit::Fail(req, e.to_string()),
    }
}

/// Lookup + prefill: one admission attempt (shared by the primary path and
/// the shed-and-retry backstop in [`admit_one`]).
fn try_start<M: ForwardModel>(
    recycler: &mut Recycler<M>,
    prompt_text: &str,
    prompt_ids: &[u32],
    max_new: usize,
    admit_full: bool,
) -> Result<(DecodeStream, ServeMeta)> {
    let adm = recycler.prepare(prompt_text, prompt_ids, admit_full);
    let stream = recycler.engine_mut().start_stream(
        prompt_ids,
        adm.kv,
        adm.cur_len,
        max_new,
        adm.meta.want_capture,
    )?;
    Ok((stream, adm.meta))
}

/// Why a request sits in the holdback queue.
#[derive(Clone, Copy)]
enum Hold {
    /// An earlier turn of its session is still in flight (or an arena-held
    /// request is ahead of it); other traffic may pass.
    Session,
    /// The arena lacks headroom for it. FIFO applies: no fresh request is
    /// drained past it, otherwise a stream of small admissible arrivals
    /// could keep the arena full and starve it forever.
    Headroom,
}

/// Is an earlier request of session `sid` still ahead of a candidate?
/// "Ahead" means: decoding (`running`), already picked this wave
/// (`arrivals`), waiting in the holdback queue before the candidate
/// (`deferred[..deferred_limit]`), or re-queued this wave
/// (`requeue_front`). Turn order within a session is a correctness
/// invariant — turn N+1's prompt extends turn N's committed ids — so a
/// candidate must wait behind ALL of these, not just the running set.
fn session_blocked(
    sid: &str,
    running: &[Running],
    arrivals: &[Request],
    deferred: &VecDeque<(Request, Hold)>,
    deferred_limit: usize,
    requeue_front: &[(Request, Hold)],
) -> bool {
    running.iter().any(|r| r.req.session.as_deref() == Some(sid))
        || arrivals.iter().any(|a| a.session.as_deref() == Some(sid))
        || deferred
            .iter()
            .take(deferred_limit)
            .any(|(d, _)| d.session.as_deref() == Some(sid))
        || requeue_front.iter().any(|(d, _)| d.session.as_deref() == Some(sid))
}

/// Arena blocks the running streams may still consume: each stream's
/// unwritten decode growth (budget clamped to the window) plus one block
/// of COW slack for its shared boundary block. Admission reserves this so
/// a newcomer's prefill cannot eat the blocks in-flight decodes will need.
fn reserved_growth_blocks<M: ForwardModel>(
    running: &[Running],
    recycler: &Recycler<M>,
) -> usize {
    let max_seq = recycler.config().max_seq;
    let arena = recycler.arena();
    running
        .iter()
        .map(|r| {
            let s = &r.stream;
            let target = (s.pos() + s.remaining_budget()).min(max_seq);
            arena
                .blocks_for(target)
                .saturating_sub(s.kv().num_blocks())
                + 1
        })
        .sum()
}

fn worker_loop<M: ForwardModel>(
    shared: Arc<Shared>,
    mut recycler: Recycler<M>,
    cfg: ServerConfig,
) {
    let mut sessions = SessionManager::new();
    let mut running: Vec<Running> = Vec::new();
    // Requests held back: an earlier turn of their session is still
    // decoding (turn N+1's prompt extends turn N's committed ids, so the
    // two must not run concurrently), or the arena lacks headroom.
    let mut deferred: VecDeque<(Request, Hold)> = VecDeque::new();
    loop {
        // --- admission: fill free slots without stalling active streams ---
        let free = cfg.max_batch.saturating_sub(running.len());
        let mut arrivals: Vec<Request> = Vec::new();
        let mut from_deferred = 0usize;
        // FIFO over the arena gate: while any request is held back for
        // headroom, no fresh request is drained past it (a stream of small
        // admissible arrivals could otherwise keep the arena full forever).
        let headroom_waiting = deferred.iter().any(|(_, h)| matches!(h, Hold::Headroom));
        if free > 0 {
            // deferred requests first (their blocking turn may have
            // finished last tick); a deferred entry also waits behind any
            // EARLIER deferred entry of its session, so per-session FIFO
            // holds across the holdback queue too
            let mut i = 0;
            while i < deferred.len() && arrivals.len() < free {
                let blocked = deferred[i].0.session.as_deref().is_some_and(|sid| {
                    session_blocked(sid, &running, &arrivals, &deferred, i, &[])
                });
                if blocked {
                    i += 1;
                } else {
                    arrivals.push(deferred.remove(i).expect("index in bounds").0);
                }
            }
            from_deferred = arrivals.len();
            // Only pull fresh requests off the bounded queue while the
            // holdback set is small: `deferred` sits outside the queue's
            // capacity accounting, so draining into it without bound would
            // quietly disable the submit-side backpressure
            // (QueueError::Full) the sequential loop provided.
            let want = if headroom_waiting || deferred.len() >= cfg.max_batch {
                0
            } else {
                free - arrivals.len()
            };
            if want > 0 {
                let fresh = if running.is_empty() && arrivals.is_empty() {
                    // idle: block briefly for the first request, then a
                    // short follow-up window for stragglers
                    drain_batch(
                        &shared.queue,
                        want,
                        Duration::from_millis(cfg.batch_first_wait_ms),
                        Duration::from_millis(cfg.batch_window_ms),
                    )
                } else {
                    // streams in flight: never block, take what's ready
                    drain_ready(&shared.queue, want)
                };
                arrivals.extend(fresh);
            }
        }
        // Requests held back this wave. Ones that came OUT of `deferred`
        // (index < from_deferred) must return to its FRONT so they stay
        // ahead of later arrivals of their session — per-session order is
        // a correctness invariant; fresh arrivals go to the back.
        let mut requeue_front: Vec<(Request, Hold)> = Vec::new();
        let mut admitted_this_wave = false;
        // Set when a candidate is held for arena headroom this wave:
        // everything behind it is then held too (FIFO over the gate).
        let mut headroom_hold = false;
        for (ai, req) in arrivals.into_iter().enumerate() {
            let hold_back = |req: Request, hold: Hold,
                             requeue_front: &mut Vec<(Request, Hold)>,
                             deferred: &mut VecDeque<(Request, Hold)>| {
                if ai < from_deferred {
                    requeue_front.push((req, hold));
                } else {
                    deferred.push_back((req, hold));
                }
            };
            if headroom_hold {
                hold_back(req, Hold::Session, &mut requeue_front, &mut deferred);
                continue;
            }
            let blocked = req.session.as_deref().is_some_and(|sid| {
                // A candidate pulled from the holdback queue must NOT be
                // blocked by `deferred`'s remaining same-session entries:
                // the pull loop took the EARLIEST, so whatever is left of
                // its session is a strictly later turn (scanning them
                // would re-block it forever — livelock). Fresh arrivals
                // wait behind the whole holdback queue.
                let deferred_ahead = if ai < from_deferred { 0 } else { deferred.len() };
                session_blocked(sid, &running, &[], &deferred, deferred_ahead,
                                &requeue_front)
            });
            if blocked {
                hold_back(req, Hold::Session, &mut requeue_front, &mut deferred);
                continue;
            }
            // Arena headroom is re-derived per admission (each inline
            // prefill pins blocks): the gate inside admit_one compares the
            // request's estimated prompt + budget against the free blocks
            // left after reserving the running streams' unconsumed growth.
            let headroom_reserved = if running.is_empty() {
                None
            } else {
                Some(reserved_growth_blocks(&running, &recycler))
            };
            let waited_ms = req.queued_at.elapsed().as_millis() as u64;
            match admit_one(req, &mut recycler, &sessions, &cfg, headroom_reserved) {
                Admit::Ready(slot) => {
                    shared.stats.lock().unwrap().scheduler.note_admission(waited_ms);
                    running.push(*slot);
                    admitted_this_wave = true;
                }
                Admit::Defer(req) => {
                    headroom_hold = true;
                    hold_back(req, Hold::Headroom, &mut requeue_front, &mut deferred);
                }
                Admit::Fail(req, msg) => {
                    shared.stats.lock().unwrap().failed += 1;
                    let _ = req.reply.send(Response::Err(msg));
                }
            }
        }
        for held in requeue_front.into_iter().rev() {
            deferred.push_front(held);
        }
        if admitted_this_wave {
            shared.stats.lock().unwrap().batches += 1;
        }

        if running.is_empty() {
            if shared.queue.is_closed() && shared.queue.is_empty() && deferred.is_empty() {
                break;
            }
            continue;
        }

        // --- one batched decode step over every active stream ---
        let mut refs: Vec<&mut DecodeStream> = running
            .iter_mut()
            .filter(|r| !r.stream.is_finished())
            .map(|r| &mut r.stream)
            .collect();
        if !refs.is_empty() {
            let step = recycler.engine_mut().step_streams(&mut refs);
            drop(refs);
            match step {
                Ok(report) if report.scheduled > 0 => {
                    // record the true dispatch occupancy (streams that fed
                    // the forward), not the pre-drain running-set size
                    shared.stats.lock().unwrap().scheduler.note_step(report.scheduled);
                }
                Ok(_) => {}
                Err(_) => {
                    // Isolate the faulty stream(s): a failed step leaves
                    // every stream's logical state untouched and KV writes
                    // at a fixed (token, position) are idempotent, so
                    // per-stream retries are token-exact. Every stream —
                    // including a lone one, so the failure policy does not
                    // depend on unrelated traffic — gets exactly one
                    // retry; a stream that fails it is replied to and
                    // dropped ON THE SPOT, freeing its KV blocks so a
                    // resource error (ArenaExhausted) fails one stream,
                    // not the batch.
                    let mut i = 0;
                    while i < running.len() {
                        if running[i].stream.is_finished() {
                            i += 1;
                            continue;
                        }
                        match recycler
                            .engine_mut()
                            .step_streams(&mut [&mut running[i].stream])
                        {
                            Ok(report) => {
                                // retries are dispatches too: keep the
                                // occupancy counters covering every step
                                if report.scheduled > 0 {
                                    shared
                                        .stats
                                        .lock()
                                        .unwrap()
                                        .scheduler
                                        .note_step(report.scheduled);
                                }
                                i += 1;
                            }
                            Err(e) => {
                                let r = running.swap_remove(i);
                                shared.stats.lock().unwrap().failed += 1;
                                let _ = r.req.reply.send(Response::Err(e.to_string()));
                                // i not advanced: swap_remove moved a new
                                // slot here; dropping `r` released blocks
                            }
                        }
                    }
                }
            }
        }

        // --- finish: reply per request the moment its stream completes ---
        let mut i = 0;
        while i < running.len() {
            if !running[i].stream.is_finished() {
                i += 1;
                continue;
            }
            let r = running.swap_remove(i);
            let g = r.stream.into_generated();
            let outcome = recycler.complete(&r.prompt_text, &r.prompt_ids, r.meta, g);
            shared.stats.lock().unwrap().completed += 1;
            if let Some(sid) = &r.req.session {
                let mut full_ids = r.prompt_ids;
                full_ids.extend_from_slice(&outcome.ids);
                let full_text = format!("{}{}", r.prompt_text, outcome.text);
                sessions.commit(sid, &r.req.prompt, full_text, full_ids,
                                &outcome.text);
            }
            let _ = r.req.reply.send(Response::Ok(Box::new(outcome)));
        }

        // refresh derived stats
        let mut stats = shared.stats.lock().unwrap();
        stats.engine = recycler.engine().counters();
        stats.cache_entries = recycler.store().len();
        stats.cache_bytes = recycler.store().live_bytes();
        stats.arena_used_blocks = recycler.arena().used_blocks();
        stats.arena_capacity_blocks = recycler.arena().capacity_blocks();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::engine::Engine;
    use crate::index::NgramEmbedder;
    use crate::recycler::RecyclePolicy;
    use crate::testutil::MockModel;
    use crate::tokenizer::Tokenizer;

    fn coordinator(cfg: ServerConfig) -> Coordinator {
        Coordinator::spawn(
            || {
                let engine = Engine::new(MockModel::new(ModelConfig::nano()));
                Recycler::new(
                    engine,
                    std::sync::Arc::new(Tokenizer::new(vec![])),
                    Box::new(NgramEmbedder::new(64)),
                    Default::default(),
                    RecyclePolicy::Strict,
                )
            },
            cfg,
        )
    }

    #[test]
    fn serves_a_request() {
        let c = coordinator(ServerConfig::default());
        let out = c.generate("hello world this is a prompt", 4).unwrap();
        assert_eq!(out.ids.len(), 4);
        let stats = c.stats();
        assert_eq!(stats.submitted, 1);
        assert_eq!(stats.completed, 1);
        c.shutdown();
    }

    #[test]
    fn repeated_prompt_hits_cache() {
        let c = coordinator(ServerConfig::default());
        let a = c.generate("what is the capital of france?", 4).unwrap();
        assert!(!a.cache_hit);
        let b = c
            .generate("what is the capital of france? and italy?", 4)
            .unwrap();
        assert!(b.cache_hit);
        assert!(b.reuse_depth > 0);
        c.shutdown();
    }

    #[test]
    fn concurrent_submitters() {
        let c = std::sync::Arc::new(coordinator(ServerConfig {
            max_batch: 4,
            ..Default::default()
        }));
        let mut handles = Vec::new();
        for t in 0..4 {
            let c2 = std::sync::Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                let out = c2.generate(&format!("prompt number {t} for testing"), 3).unwrap();
                out.ids.len()
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), 3);
        }
        assert_eq!(c.stats().completed, 4);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // tiny queue + a worker that's busy: fill it up
        let c = coordinator(ServerConfig {
            queue_capacity: 1,
            ..Default::default()
        });
        // Burst faster than the worker drains; at least one must be
        // rejected OR all succeed quickly — assert the error type when it
        // fires rather than racing the worker.
        let mut rejected = false;
        let mut receivers = Vec::new();
        for i in 0..50 {
            match c.submit(&format!("p{i} xxxx"), 2, None) {
                Ok(rx) => receivers.push(rx),
                Err(Error::Rejected(_)) => {
                    rejected = true;
                    break;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        for rx in receivers {
            let _ = rx.recv();
        }
        if rejected {
            assert!(c.stats().rejected >= 1);
        }
        c.shutdown();
    }

    #[test]
    fn session_turns_recycle_their_transcript() {
        let c = coordinator(ServerConfig::default());
        let t1 = c.chat("sess", "hello there friend", 3).unwrap();
        assert!(!t1.cache_hit, "first turn has nothing to reuse");
        let t2 = c.chat("sess", "tell me more", 3).unwrap();
        assert!(t2.cache_hit, "turn 2 must reuse turn 1's transcript KV");
        assert!(t2.reuse_depth > 0);
        // the paged arena is live and bounded
        let stats = c.stats();
        assert!(stats.arena_used_blocks > 0, "session KV must hold blocks");
        assert!(stats.arena_used_blocks <= stats.arena_capacity_blocks);
        c.shutdown();
    }

    #[test]
    fn concurrent_batch_matches_sequential_outputs() {
        // the same request set served at max_batch 4 and max_batch 1 must
        // be token-identical (the paper's exactness property, batched)
        let prompts: Vec<String> = (0..8)
            .map(|i| format!("unrelated prompt number {i} about topic {}", i * 7))
            .collect();
        let seq = coordinator(ServerConfig {
            max_batch: 1,
            ..Default::default()
        });
        let expected: Vec<Vec<u32>> = prompts
            .iter()
            .map(|p| seq.generate(p, 5).unwrap().ids)
            .collect();
        seq.shutdown();

        let bat = std::sync::Arc::new(coordinator(ServerConfig {
            max_batch: 4,
            ..Default::default()
        }));
        let mut handles = Vec::new();
        for (i, p) in prompts.iter().enumerate() {
            let c = std::sync::Arc::clone(&bat);
            let p = p.clone();
            handles.push(std::thread::spawn(move || {
                (i, c.generate(&p, 5).unwrap().ids)
            }));
        }
        for h in handles {
            let (i, ids) = h.join().unwrap();
            assert_eq!(ids, expected[i], "request {i} diverged under batching");
        }
        let stats = bat.stats();
        assert_eq!(stats.completed, 8);
        assert!(stats.scheduler.decode_steps > 0);
        assert!(stats.scheduler.admitted == 8);
        assert!(stats.scheduler.avg_occupancy() >= 1.0);
    }

    #[test]
    fn session_survives_past_context_window() {
        // Acceptance: a session must keep serving for >= 3x max_seq
        // cumulative tokens — the old path wedged on PromptTooLong forever
        // once the transcript neared the window.
        let c = coordinator(ServerConfig::default());
        let max_seq = ModelConfig::nano().max_seq; // 256
        let mut cumulative = 0usize;
        let mut turns = 0usize;
        while cumulative < 3 * max_seq + max_seq / 2 {
            let out = c
                .chat("marathon", "tell me something new about the weather", 8)
                .unwrap_or_else(|e| panic!("turn {turns} wedged: {e}"));
            cumulative += out.prompt_tokens + out.ids.len();
            turns += 1;
            assert!(turns < 500, "not making progress");
        }
        assert!(turns > 3, "window-sized turns should take several rounds");
        // the session is still healthy after crossing the window repeatedly
        let out = c.chat("marathon", "one more for the road", 4).unwrap();
        assert!(out.prompt_tokens <= max_seq);
        assert_eq!(c.stats().failed, 0);
        c.shutdown();
    }

    #[test]
    fn same_session_turns_never_run_concurrently() {
        // fire two turns of one session back-to-back without waiting; the
        // scheduler must defer turn 2 until turn 1 commits, and both succeed
        let c = coordinator(ServerConfig {
            max_batch: 4,
            ..Default::default()
        });
        let rx1 = c.submit("first turn", 4, Some("s".into())).unwrap();
        let rx2 = c.submit("second turn", 4, Some("s".into())).unwrap();
        let o1 = rx1.recv().unwrap().ok().unwrap();
        let o2 = rx2.recv().unwrap().ok().unwrap();
        assert_eq!(o1.ids.len(), 4);
        assert_eq!(o2.ids.len(), 4);
        assert!(
            o2.prompt_tokens > o1.prompt_tokens,
            "turn 2 must see turn 1's committed transcript"
        );
        assert!(o2.cache_hit, "turn 2 recycles turn 1's KV");
        c.shutdown();
    }

    #[test]
    fn three_queued_session_turns_all_complete_in_order() {
        // regression: with >= 2 turns of one session parked in the
        // holdback queue, the first pulled turn must not be re-blocked by
        // its own LATER turns still sitting there (that was a livelock)
        let c = coordinator(ServerConfig {
            max_batch: 4,
            ..Default::default()
        });
        let rx1 = c.submit("turn one", 3, Some("s".into())).unwrap();
        let rx2 = c.submit("turn two", 3, Some("s".into())).unwrap();
        let rx3 = c.submit("turn three", 3, Some("s".into())).unwrap();
        let o1 = rx1.recv().unwrap().ok().unwrap();
        let o2 = rx2.recv().unwrap().ok().unwrap();
        let o3 = rx3.recv().unwrap().ok().unwrap();
        assert!(o2.prompt_tokens > o1.prompt_tokens, "turn 2 after turn 1");
        assert!(o3.prompt_tokens > o2.prompt_tokens, "turn 3 after turn 2");
        assert_eq!(c.stats().completed, 3);
        c.shutdown();
    }

    #[test]
    fn failure_surfaces_as_error_response() {
        let c = Coordinator::spawn(
            || {
                let engine =
                    Engine::new(MockModel::new(ModelConfig::nano()).fail_on_call(1));
                Recycler::new(
                    engine,
                    std::sync::Arc::new(Tokenizer::new(vec![])),
                    Box::new(NgramEmbedder::new(64)),
                    Default::default(),
                    RecyclePolicy::Strict,
                )
            },
            ServerConfig::default(),
        );
        let err = c.generate("boom", 2).unwrap_err();
        assert!(err.to_string().contains("injected"));
        assert_eq!(c.stats().failed, 1);
        // next request works (failure was transient)
        assert!(c.generate("fine now", 2).is_ok());
        c.shutdown();
    }

    #[test]
    fn shutdown_then_submit_fails() {
        let c = coordinator(ServerConfig::default());
        let shared = std::sync::Arc::clone(&c.shared);
        c.shutdown();
        let (tx, _rx) = mpsc::channel();
        let req = Request {
            id: 1,
            prompt: "x".into(),
            max_new_tokens: 1,
            session: None,
            reply: tx,
            queued_at: Instant::now(),
        };
        assert_eq!(shared.queue.push(req).err(), Some(QueueError::Closed));
    }
}
