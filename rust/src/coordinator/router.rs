//! The prefix-affinity router: the cluster front over N serving workers.
//!
//! [`Coordinator`] owns `ServerConfig::num_workers` self-contained
//! [`Worker`]s — each a full `Scheduler` + arena + recycler stack on its
//! own thread with its own bounded queue — and places every submitted
//! request on exactly one of them. Placement is where the paper's
//! recycling thesis meets horizontal scaling: a router that scatters a
//! prompt family across workers destroys every prefix hit the recycler
//! worked to keep, so the default [`RoutingPolicy::PrefixAffinity`]
//! fingerprints the prompt's leading bytes and sticks each prefix family
//! to one worker, with `RoundRobin` and `LeastLoaded` as the
//! cache-oblivious ablation baselines.
//!
//! Placement rules, in priority order:
//!
//! 1. **Session stickiness (all policies)** — a session's later turns
//!    always go to the worker that served its first turn. This is a
//!    *correctness* requirement, not a preference: the per-worker
//!    `SessionManager` owns the transcript, and a turn landing elsewhere
//!    would silently drop the conversation history. Session turns never
//!    fall back under overload; they get the honest `Overloaded` reply.
//! 2. **Policy choice (sessionless requests + first session turns)** —
//!    prefix-family affinity, round-robin rotation, or shallowest queue.
//! 3. **Overload fallback (PrefixAffinity, sessionless only)** — when
//!    the affine worker's queue is full, the request spills to the
//!    least-loaded sibling instead of being rejected: affinity is a hit-
//!    rate preference, shedding available capacity is not acceptable.
//!
//! Placement changes *latency and hit rate, never tokens*: workers run
//! the same deterministic scheduler stack, so any placement of a request
//! set yields token-identical outputs (the routing-invariance property
//! in `rust/tests/properties.rs`). With `num_workers = 1` every rule
//! degenerates to "worker 0" and the router IS the old single-scheduler
//! coordinator, behavior-preserved.
//!
//! The workers' KV stores may share one `spill_dir` (distinct
//! `CacheConfig::spill_namespace` per worker): an affinity miss on
//! worker B can then *adopt* a record worker A spilled — cross-worker
//! cache mobility through the cold tier instead of recomputation (see
//! `kvcache::store::KvStore::adopt_foreign`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use crate::config::{RoutingPolicy, ServerConfig};
use crate::engine::ForwardModel;
use crate::error::{Error, Result};
use crate::recycler::{Outcome, Recycler};
use crate::util::json::{self, Value};
use crate::util::sync::lock_recover;

use super::queue::QueueError;
use super::request::{Request, Response, StreamEvent};
use super::service::{CoordinatorStats, Worker};

/// Submission extras beyond the prompt/budget/session triple: the QoS
/// tenant label and the optional per-token streaming channel. `submit`
/// passes the default (anonymous, aggregate-only); the streaming front
/// uses [`Coordinator::submit_with`] directly.
#[derive(Default)]
pub struct SubmitOptions {
    /// Tenant id for per-tenant QoS accounting (None = anonymous).
    pub tenant: Option<String>,
    /// When set, the owning worker's scheduler mirrors each decoded token
    /// as a [`StreamEvent::Token`] the tick it is produced, then exactly
    /// one [`StreamEvent::End`]. The aggregate reply still fires.
    pub stream: Option<mpsc::Sender<StreamEvent>>,
}

/// Leading bytes hashed into the prefix-family fingerprint. The byte-
/// level tokenizer makes bytes ≈ tokens, so 32 bytes ≈ two arena blocks
/// of shared prompt — long enough to separate unrelated prompts, short
/// enough that template-sharing prompts (the recyclable kind) collide
/// onto the same worker, which is the point.
const PREFIX_FINGERPRINT_BYTES: usize = 32;

/// FNV-1a over the prompt's leading bytes.
///
/// Prompts shorter than [`PREFIX_FINGERPRINT_BYTES`] hash whatever bytes
/// they have (the `take` just doesn't saturate): the fingerprint is still
/// a pure function of the prompt text, so short prompts route
/// deterministically — the same short prompt always lands on the same
/// worker. The empty prompt hashes to the FNV offset basis, one ordinary
/// family. Distinct prompts *can* collide (64-bit FNV over ≤32 bytes) and
/// pile onto one worker; that skew is absorbed by the overload fallback in
/// [`Coordinator::submit`], and made diagnosable by its
/// `overload_fallbacks` counter in `{"cmd":"stats"}`.
fn prefix_fingerprint(prompt: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in prompt.as_bytes().iter().take(PREFIX_FINGERPRINT_BYTES) {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// Mutable routing tables, behind one short-lived lock per placement.
#[derive(Default)]
struct RouterState {
    /// session id -> pinned worker (stickiness, all policies).
    sessions: HashMap<String, usize>,
    /// prefix-family fingerprint -> affine worker (PrefixAffinity).
    families: HashMap<u64, usize>,
    /// Round-robin cursor.
    rr: usize,
}

/// Handle to the running worker fleet. Dropping it shuts every worker
/// down (close all queues first, then join — workers drain in parallel).
pub struct Coordinator {
    workers: Vec<Worker>,
    state: Mutex<RouterState>,
    next_id: AtomicU64,
    /// Sessionless requests that spilled off a saturated affine worker to
    /// the least-loaded sibling. A climbing value under PrefixAffinity is
    /// the fingerprint-collision / hot-family skew signal — visible in
    /// `{"cmd":"stats"}` so skew is diagnosable without logs.
    overload_fallbacks: AtomicU64,
    cfg: ServerConfig,
}

impl Coordinator {
    /// Spawn `cfg.num_workers` workers. `mk_recycler` runs ON each worker
    /// thread with that worker's index (the PJRT runtime's handles are
    /// not `Send`, so each model is constructed where it will be used);
    /// the index also lets the factory derive per-worker state such as a
    /// `spill_namespace` over a shared `spill_dir`.
    pub fn spawn<M, F>(mk_recycler: F, cfg: ServerConfig) -> Coordinator
    where
        M: ForwardModel + 'static,
        F: Fn(usize) -> Recycler<M> + Send + Sync + 'static,
    {
        let n = cfg.num_workers.max(1);
        let mk = Arc::new(mk_recycler);
        let workers = (0..n)
            .map(|i| {
                let mk = Arc::clone(&mk);
                Worker::spawn(i, move || mk(i), cfg.clone())
            })
            .collect();
        Coordinator {
            workers,
            state: Mutex::new(RouterState::default()),
            next_id: AtomicU64::new(1),
            overload_fallbacks: AtomicU64::new(0),
            cfg,
        }
    }

    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }

    /// The worker with the shallowest queue (ties to the lowest index).
    fn least_loaded(&self) -> usize {
        self.workers
            .iter()
            .enumerate()
            .min_by_key(|(i, w)| (w.queue_depth(), *i))
            .map(|(i, _)| i)
            .expect("at least one worker")
    }

    /// Choose the primary worker for a request (see the module docs for
    /// the placement rules). Records the placement in the session /
    /// family tables so later arrivals stick.
    fn route(&self, prompt: &str, session: Option<&str>) -> usize {
        if self.workers.len() == 1 {
            return 0;
        }
        // poison-recovering lock: the routing tables are valid at every
        // step (plain maps + a cursor), so a panic elsewhere must not
        // cascade into every later placement
        let mut state = lock_recover(&self.state);
        if let Some(s) = session {
            if let Some(&w) = state.sessions.get(s) {
                return w;
            }
        }
        let w = match self.cfg.routing {
            RoutingPolicy::PrefixAffinity => {
                let fam = prefix_fingerprint(prompt);
                match state.families.get(&fam) {
                    Some(&w) => w,
                    None => {
                        let w = self.least_loaded();
                        state.families.insert(fam, w);
                        w
                    }
                }
            }
            RoutingPolicy::RoundRobin => {
                let w = state.rr % self.workers.len();
                state.rr += 1;
                w
            }
            RoutingPolicy::LeastLoaded => self.least_loaded(),
        };
        if let Some(s) = session {
            state.sessions.insert(s.to_string(), w);
        }
        w
    }

    /// Submit a request; returns a receiver for the response.
    pub fn submit(
        &self,
        prompt: &str,
        max_new_tokens: usize,
        session: Option<String>,
    ) -> Result<mpsc::Receiver<Response>> {
        self.submit_with(prompt, max_new_tokens, session, SubmitOptions::default())
    }

    /// [`Coordinator::submit`] with QoS/streaming extras (see
    /// [`SubmitOptions`]). Placement, overload fallback, and shedding are
    /// identical — streaming and tenancy never change where a request runs.
    pub fn submit_with(
        &self,
        prompt: &str,
        max_new_tokens: usize,
        session: Option<String>,
        opts: SubmitOptions,
    ) -> Result<mpsc::Receiver<Response>> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let widx = self.route(prompt, session.as_deref());
        let mk_req = |tx: mpsc::Sender<Response>| Request {
            id,
            prompt: prompt.to_string(),
            max_new_tokens,
            session: session.clone(),
            reply: tx,
            queued_at: Instant::now(),
            tenant: opts.tenant.clone(),
            stream: opts.stream.clone(),
        };
        let (tx, rx) = mpsc::channel();
        match self.workers[widx].try_push(mk_req(tx)) {
            Ok(()) => return Ok(rx),
            Err(QueueError::Closed) => return Err(Error::ShutDown),
            Err(QueueError::Full) => {}
        }
        // Overload fallback: a saturated affine worker sheds *sessionless*
        // requests to the least-loaded sibling — affinity is a hit-rate
        // preference, rejecting while capacity sits idle is not. Session
        // turns never move (their transcript lives on the pinned worker).
        if session.is_none()
            && self.cfg.routing == RoutingPolicy::PrefixAffinity
            && self.workers.len() > 1
        {
            let alt = self.least_loaded();
            if alt != widx {
                let (tx, rx) = mpsc::channel();
                match self.workers[alt].try_push(mk_req(tx)) {
                    Ok(()) => {
                        self.overload_fallbacks.fetch_add(1, Ordering::Relaxed);
                        return Ok(rx);
                    }
                    Err(QueueError::Closed) => return Err(Error::ShutDown),
                    Err(QueueError::Full) => {}
                }
            }
        }
        // Terminal load shed: the typed reply carries the (per-worker)
        // observed depth so clients can back off informedly.
        self.workers[widx].note_rejected();
        Err(Error::Overloaded {
            depth: self.workers[widx].queue_depth(),
            capacity: self.workers[widx].queue_capacity(),
        })
    }

    /// Submit and wait, returning the worker's raw [`Response`] (message
    /// plus the stable error-kind label) — transports use this to expose
    /// `error_kind` without parsing messages. Submit-side shedding
    /// (`Overloaded`/`ShutDown`) still surfaces as a typed `Err`.
    pub fn serve(
        &self,
        prompt: &str,
        max_new_tokens: usize,
        session: Option<String>,
    ) -> Result<Response> {
        let rx = self.submit(prompt, max_new_tokens, session)?;
        rx.recv().map_err(|_| Error::ShutDown)
    }

    /// Submit and wait (convenience for examples/tests).
    pub fn generate(&self, prompt: &str, max_new_tokens: usize) -> Result<Outcome> {
        self.serve(prompt, max_new_tokens, None)?
            .ok()
            .map_err(Error::Rejected)
    }

    /// Multi-turn session request: builds the transcript prompt, serves it,
    /// records the turn.
    pub fn chat(&self, session_id: &str, user_msg: &str, max_new: usize) -> Result<Outcome> {
        self.serve(user_msg, max_new, Some(session_id.to_string()))?
            .ok()
            .map_err(Error::Rejected)
    }

    /// Cluster-aggregate stats (the merge of every worker's stats; at one
    /// worker this is exactly that worker's stats).
    pub fn stats(&self) -> CoordinatorStats {
        let mut agg = CoordinatorStats::default();
        for w in &self.workers {
            agg.merge(&w.stats());
        }
        agg
    }

    /// Aggregate + per-worker stats breakdown (the `{"cmd":"stats"}` wire
    /// payload and the ablation bench's per-worker probe).
    pub fn cluster_stats(&self) -> ClusterStats {
        let workers: Vec<WorkerStats> = self
            .workers
            .iter()
            .map(|w| WorkerStats {
                worker: w.index,
                queue_depth: w.queue_depth(),
                stats: w.stats(),
            })
            .collect();
        let mut aggregate = CoordinatorStats::default();
        for w in &workers {
            aggregate.merge(&w.stats);
        }
        ClusterStats {
            routing: self.cfg.routing,
            overload_fallbacks: self.overload_fallbacks.load(Ordering::Relaxed),
            aggregate,
            workers,
        }
    }

    /// Sessionless requests that spilled off a saturated affine worker.
    pub fn overload_fallbacks(&self) -> u64 {
        self.overload_fallbacks.load(Ordering::Relaxed)
    }

    /// Requests queued across all workers.
    pub fn queue_depth(&self) -> usize {
        self.workers.iter().map(|w| w.queue_depth()).sum()
    }

    /// Graceful shutdown: stop accepting on every worker, then join them
    /// (all queues close before the first join, so workers drain their
    /// backlogs in parallel).
    pub fn shutdown(mut self) {
        self.close_and_join();
    }

    fn close_and_join(&mut self) {
        for w in &self.workers {
            w.close();
        }
        for w in &mut self.workers {
            w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

/// One worker's row in the cluster breakdown.
#[derive(Debug, Clone)]
pub struct WorkerStats {
    pub worker: usize,
    pub queue_depth: usize,
    pub stats: CoordinatorStats,
}

/// Aggregate + per-worker serving stats, JSON-serializable for the
/// `{"cmd":"stats"}` wire request.
#[derive(Debug, Clone)]
pub struct ClusterStats {
    pub routing: RoutingPolicy,
    /// Router-owned skew signal (see [`Coordinator::overload_fallbacks`]).
    pub overload_fallbacks: u64,
    pub aggregate: CoordinatorStats,
    pub workers: Vec<WorkerStats>,
}

impl ClusterStats {
    pub fn to_json(&self) -> Value {
        let stats_obj = |s: &CoordinatorStats, extra: Vec<(&str, Value)>| {
            let mut fields = vec![
                ("submitted", json::n(s.submitted as f64)),
                ("completed", json::n(s.completed as f64)),
                ("failed", json::n(s.failed as f64)),
                ("rejected", json::n(s.rejected as f64)),
                ("hit_rate", json::n(s.cache.hit_rate())),
                ("cache_hits", json::n(s.cache.hits as f64)),
                ("cache_misses", json::n(s.cache.misses as f64)),
                ("spills", json::n(s.cache.spills as f64)),
                ("spill_hits", json::n(s.cache.spill_hits as f64)),
                (
                    "cold_bytes_physical",
                    json::n(s.cache.cold_bytes_physical as f64),
                ),
                (
                    "cold_bytes_logical",
                    json::n(s.cache.cold_bytes_logical as f64),
                ),
                ("quantized_blocks", json::n(s.cache.quantized_blocks as f64)),
                ("quantized_bytes", json::n(s.cache.quantized_bytes as f64)),
                ("adoptions", json::n(s.cache.adoptions as f64)),
                ("segment_hits", json::n(s.cache.segment_hits as f64)),
                (
                    "reanchored_tokens",
                    json::n(s.cache.reanchored_tokens as f64),
                ),
                ("tokens_generated", json::n(s.engine.tokens_generated as f64)),
                ("tokens_reused", json::n(s.engine.tokens_reused as f64)),
                ("avg_ttft_ms", json::n(s.scheduler.avg_ttft_ms())),
                ("avg_occupancy", json::n(s.scheduler.avg_occupancy())),
                ("peak_occupancy", json::n(s.scheduler.peak_occupancy as f64)),
                ("arena_used_blocks", json::n(s.arena_used_blocks as f64)),
                (
                    "arena_capacity_blocks",
                    json::n(s.arena_capacity_blocks as f64),
                ),
            ];
            fields.extend(extra);
            json::obj(fields)
        };
        json::obj(vec![
            ("routing", json::s(self.routing.name())),
            ("num_workers", json::n(self.workers.len() as f64)),
            (
                "overload_fallbacks",
                json::n(self.overload_fallbacks as f64),
            ),
            ("aggregate", stats_obj(&self.aggregate, vec![])),
            (
                "workers",
                json::arr(
                    self.workers
                        .iter()
                        .map(|w| {
                            stats_obj(
                                &w.stats,
                                vec![
                                    ("worker", json::n(w.worker as f64)),
                                    ("queue_depth", json::n(w.queue_depth as f64)),
                                ],
                            )
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::engine::Engine;
    use crate::index::NgramEmbedder;
    use crate::recycler::RecyclePolicy;
    use crate::testutil::MockModel;
    use crate::tokenizer::Tokenizer;

    fn cluster(n: usize, routing: RoutingPolicy) -> Coordinator {
        Coordinator::spawn(
            |_| {
                let engine = Engine::new(MockModel::new(ModelConfig::nano()));
                Recycler::new(
                    engine,
                    Arc::new(Tokenizer::new(vec![])),
                    Box::new(NgramEmbedder::new(64)),
                    Default::default(),
                    RecyclePolicy::Strict,
                )
            },
            ServerConfig {
                num_workers: n,
                routing,
                ..Default::default()
            },
        )
    }

    #[test]
    fn single_worker_routes_everything_to_worker_0() {
        let c = cluster(1, RoutingPolicy::RoundRobin);
        for p in ["alpha", "beta", "gamma"] {
            assert_eq!(c.route(p, None), 0);
            assert_eq!(c.route(p, Some("s")), 0);
        }
        c.shutdown();
    }

    #[test]
    fn round_robin_rotates_sessionless_requests() {
        let c = cluster(3, RoutingPolicy::RoundRobin);
        let placements: Vec<usize> =
            (0..6).map(|i| c.route(&format!("p{i}"), None)).collect();
        assert_eq!(placements, vec![0, 1, 2, 0, 1, 2]);
        c.shutdown();
    }

    #[test]
    fn prefix_affinity_sticks_prompt_families_and_sessions() {
        let c = cluster(4, RoutingPolicy::PrefixAffinity);
        // same leading 32 bytes = same family = same worker, regardless of
        // the suffix
        let base = "a".repeat(32);
        let w0 = c.route(&base, None);
        assert_eq!(c.route(&format!("{base} extended further"), None), w0);
        assert_eq!(c.route(&base, None), w0);
        // a session pins to its first worker even when later turns carry
        // completely unrelated prompt text
        let ws = c.route("session opener text", Some("sess"));
        assert_eq!(c.route("zzz unrelated follow-up", Some("sess")), ws);
        c.shutdown();
    }

    #[test]
    fn least_loaded_prefers_shallowest_queue() {
        let c = cluster(2, RoutingPolicy::LeastLoaded);
        // queues are empty: ties break to worker 0 deterministically
        assert_eq!(c.route("x", None), 0);
        c.shutdown();
    }

    #[test]
    fn multi_worker_cluster_serves_and_aggregates() {
        let c = cluster(2, RoutingPolicy::RoundRobin);
        for i in 0..4 {
            let out = c.generate(&format!("prompt number {i} padded out"), 3).unwrap();
            assert_eq!(out.ids.len(), 3);
        }
        let agg = c.stats();
        assert_eq!(agg.submitted, 4);
        assert_eq!(agg.completed, 4);
        let cs = c.cluster_stats();
        assert_eq!(cs.workers.len(), 2);
        // round-robin: both workers served half the sessionless load
        assert_eq!(cs.workers[0].stats.submitted, 2);
        assert_eq!(cs.workers[1].stats.submitted, 2);
        let js = cs.to_json().to_json();
        assert!(js.contains("\"aggregate\""));
        assert!(js.contains("\"workers\""));
        assert!(js.contains("\"adoptions\""));
        // capacity-multiplier meters ride the same wire payload
        assert!(js.contains("\"cold_bytes_physical\""));
        assert!(js.contains("\"cold_bytes_logical\""));
        assert!(js.contains("\"quantized_blocks\""));
        c.shutdown();
    }

    #[test]
    fn affinity_repeat_prompts_hit_one_workers_cache() {
        let c = cluster(2, RoutingPolicy::PrefixAffinity);
        let base = "shared template prefix that exceeds the fingerprint width";
        let a = c.generate(base, 3).unwrap();
        assert!(!a.cache_hit);
        let b = c.generate(&format!("{base} with a question appended"), 3).unwrap();
        assert!(b.cache_hit, "family affinity must land the repeat on the same worker");
        assert!(b.reuse_depth > 0);
        c.shutdown();
    }

    #[test]
    fn session_turns_stay_on_one_worker_across_the_cluster() {
        let c = cluster(3, RoutingPolicy::RoundRobin);
        let t1 = c.chat("conv", "hello there friend", 3).unwrap();
        assert!(!t1.cache_hit);
        let t2 = c.chat("conv", "tell me more", 3).unwrap();
        assert!(t2.cache_hit, "turn 2 must find turn 1's transcript KV");
        assert!(t2.prompt_tokens > t1.prompt_tokens);
        // exactly one worker saw both turns
        let per_worker: Vec<u64> = c
            .cluster_stats()
            .workers
            .iter()
            .map(|w| w.stats.submitted)
            .collect();
        assert!(per_worker.contains(&2), "one worker owns the session: {per_worker:?}");
        c.shutdown();
    }

    #[test]
    fn fingerprint_separates_on_leading_bytes_only() {
        let a = "x".repeat(PREFIX_FINGERPRINT_BYTES);
        assert_eq!(
            prefix_fingerprint(&a),
            prefix_fingerprint(&format!("{a}suffix-is-ignored"))
        );
        assert_ne!(prefix_fingerprint("abc"), prefix_fingerprint("abd"));
    }

    #[test]
    fn short_prompts_route_deterministically() {
        // prompts shorter than the 32-byte window (including empty) must
        // be pure functions of their text: repeats always land on the
        // worker the family table pinned first
        for p in ["", "a", "hi", "short one"] {
            assert_eq!(prefix_fingerprint(p), prefix_fingerprint(p));
        }
        assert_ne!(prefix_fingerprint("a"), prefix_fingerprint("b"));
        let c = cluster(4, RoutingPolicy::PrefixAffinity);
        for p in ["", "a", "hi", "short one"] {
            let w = c.route(p, None);
            for _ in 0..3 {
                assert_eq!(c.route(p, None), w, "short prompt {p:?} moved");
            }
        }
        c.shutdown();
    }

    #[test]
    fn overload_fallback_is_counted_and_visible_in_stats() {
        // tiny queues + a slow-draining worker: saturate the affine
        // worker's queue with one prompt family, then watch the same
        // family spill to the sibling and bump the router's skew counter
        let c = Coordinator::spawn(
            |_| {
                let engine = Engine::new(MockModel::with_delay(
                    ModelConfig::nano(),
                    std::time::Duration::from_millis(5),
                ));
                Recycler::new(
                    engine,
                    Arc::new(Tokenizer::new(vec![])),
                    Box::new(NgramEmbedder::new(64)),
                    Default::default(),
                    RecyclePolicy::Strict,
                )
            },
            ServerConfig {
                num_workers: 2,
                routing: RoutingPolicy::PrefixAffinity,
                queue_capacity: 1,
                ..Default::default()
            },
        );
        let fam = "one shared family prefix padded well past the window";
        // flood one family faster than a 5ms/token worker can drain it;
        // with capacity 1 the affine queue saturates almost immediately
        let mut receivers = Vec::new();
        for _ in 0..40 {
            match c.submit(fam, 2, None) {
                Ok(rx) => receivers.push(rx),
                Err(Error::Overloaded { .. }) => {} // both queues full: fine
                Err(e) => panic!("unexpected submit error: {e}"),
            }
            if c.overload_fallbacks() > 0 {
                break;
            }
        }
        assert!(
            c.overload_fallbacks() > 0,
            "saturating the affine worker must trigger a counted fallback"
        );
        let js = c.cluster_stats().to_json().to_json();
        assert!(
            js.contains("\"overload_fallbacks\""),
            "skew counter missing from the stats payload: {js}"
        );
        for rx in receivers {
            let _ = rx.recv();
        }
        c.shutdown();
    }
}
