//! The serving coordinator: a prefix-affinity **router** over N
//! self-contained scheduler **workers**, each a bounded request queue +
//! continuous-batching scheduler with chunked prefill + session manager
//! driving its own recycler stack.
//!
//! # Worker/router architecture
//!
//! ```text
//!   submitters ──> Coordinator (router.rs)
//!                   │ placement: session-sticky, then policy
//!                   │  (prefix-affinity | round-robin | least-loaded)
//!                   ├─> Worker 0: RequestQueue -> Scheduler -> Recycler
//!                   ├─> Worker 1:      "            "            "
//!                   └─> Worker N-1     "            "            "
//!                        └── shared spill_dir (cold tier): records
//!                            spilled by one worker are adoptable by
//!                            the others (cross-worker cache mobility)
//! ```
//!
//! The public [`Coordinator`] (in `router.rs`) owns
//! `ServerConfig::num_workers` workers and places each request on
//! exactly one (see `router.rs` for the placement rules: session
//! stickiness is a correctness invariant under every policy; prefix
//! affinity is the hit-rate-preserving default; placement changes
//! latency and hit rate, never tokens). At `num_workers = 1` the router
//! degenerates to the old single-scheduler coordinator exactly. Each
//! worker's `KvStore` may share one `spill_dir` through per-worker
//! `CacheConfig::spill_namespace`s, making the CRC-stamped spill files
//! the cluster's cache-mobility layer.
//!
//! # Worker threading model
//!
//! Threading model (tokio is not in the offline vendor set): the router
//! enqueues into the chosen worker's bounded [`queue::RequestQueue`];
//! that worker's thread runs the tick-driven [`Scheduler`] in
//! [`service`]. Each request is a per-slot state machine — lookup →
//! **chunked-prefill** → decode → finish — held in a running set.
//! Admission attaches the recycled prefix without running any forward;
//! each tick then advances the admitting slots' prefill by at most
//! `ServerConfig::prefill_chunk_tokens` prompt tokens alongside the
//! single `forward_batch` call that advances all decoding streams one
//! token ([`crate::engine`]'s stream API), so a long cache-cold prompt
//! cannot head-of-line-block in-flight decodes. Finished requests reply
//! immediately on their per-request channel, and new arrivals are
//! admitted between ticks ([`batcher::drain_ready`], non-blocking)
//! instead of waiting for the whole batch to drain. Admission is
//! arena-aware ([`crate::recycler::Recycler::admission_headroom`], with
//! reservations held across chunk boundaries) and two turns of one
//! session never run concurrently — prefilling counts as running. Both
//! batched decode and chunked prefill are token-identical to sequential
//! serving (`max_batch = 1`, the paper's setting) — property-tested in
//! `rust/tests/properties.rs` through the deterministic scheduler-trace
//! harness in [`crate::testutil::trace`], and routing invariance
//! (any placement ≡ N=1, token-for-token) is property-tested the same
//! way.
//!
//! # Failure semantics
//!
//! Every submitted request gets **exactly one reply** — an outcome or a
//! typed error — no matter what fails underneath (see the taxonomy table
//! in [`crate::error`]):
//!
//! * **Shedding**: the bounded queue rejects at submit time with
//!   [`crate::error::Error::Overloaded`] (depth + capacity attached), so
//!   overload backpressure is explicit and immediate rather than an
//!   unbounded latency tail.
//! * **Deadlines**: a request that spends more than
//!   `ServerConfig::request_timeout_ms` in the serving path — queued,
//!   deferred, prefilling, or decoding — is reaped at the next scheduler
//!   tick with a typed `DeadlineExceeded` reply; its KV blocks and
//!   growth reservations are released at that tick boundary.
//! * **Transient faults** (backend hiccup, spill IO, arena exhaustion
//!   spikes): retried in place with exponential tick-based backoff, at
//!   most `ServerConfig::transient_retry_limit` total attempts. Forward
//!   steps are atomic-on-failure and KV rewrites idempotent
//!   (`engine/batch.rs`), so retries are token-exact. Exhausting the
//!   budget fails the request with the last error.
//! * **Permanent faults** fail the request immediately; the slot's
//!   blocks are released where it died and every other slot keeps
//!   serving — one faulty request never wedges the scheduler.
//!
//! The chaos property suite (`rust/tests/properties.rs`) drives random
//! workloads under seeded random fault plans ([`crate::faults`]) and
//! asserts exactly this contract: termination, one reply per request,
//! arena conservation after every schedule, and fault-free requests
//! token-identical to an undisturbed run.

mod batcher;
mod queue;
mod request;
mod router;
mod service;
mod session;

pub use batcher::{drain_batch, drain_ready};
pub use queue::{QueueError, RequestQueue};
pub use request::{Request, Response, StreamEvent};
pub use router::{ClusterStats, Coordinator, SubmitOptions, WorkerStats};
pub use service::{
    admission_prompt, CoordinatorStats, DeferReason, SchedEvent, Scheduler, TickReport,
};
pub use session::{truncate_to_window, SessionManager, Turn};
