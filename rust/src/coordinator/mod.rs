//! The serving coordinator: bounded request queue, batching scheduler,
//! session manager, and the worker loop that drives the recycler.
//!
//! Threading model (tokio is not in the offline vendor set — and the PJRT
//! CPU runtime is single-stream anyway): submitters enqueue into a bounded
//! [`queue::RequestQueue`]; one worker thread drains batches
//! ([`batcher::drain_batch`]) and executes them sequentially through the
//! recycler; responses travel back over per-request channels.

mod batcher;
mod queue;
mod request;
mod service;
mod session;

pub use batcher::drain_batch;
pub use queue::{QueueError, RequestQueue};
pub use request::{Request, Response};
pub use service::{Coordinator, CoordinatorStats};
pub use session::{SessionManager, Turn};
