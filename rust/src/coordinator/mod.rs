//! The serving coordinator: bounded request queue, continuous-batching
//! scheduler, session manager, and the worker loop that drives the
//! recycler.
//!
//! Threading model (tokio is not in the offline vendor set): submitters
//! enqueue into a bounded [`queue::RequestQueue`]; one worker thread runs
//! the scheduler in [`service`]. Each request is a per-request state
//! machine — lookup → prefill → decode → finish — held in a running set of
//! decode streams. Every scheduler tick advances *all* active streams one
//! token through a single `forward_batch` call ([`crate::engine`]'s
//! stream API), finished requests reply immediately on their per-request
//! channel, and new arrivals are admitted between ticks
//! ([`batcher::drain_ready`], non-blocking) instead of waiting for the
//! whole batch to drain. Admission is arena-aware
//! ([`crate::recycler::Recycler::admission_headroom`]) and two turns of
//! one session never decode concurrently. Batched decode is
//! token-identical to sequential serving (`max_batch = 1`, the paper's
//! setting) — property-tested in `rust/tests/properties.rs`.

mod batcher;
mod queue;
mod request;
mod service;
mod session;

pub use batcher::{drain_batch, drain_ready};
pub use queue::{QueueError, RequestQueue};
pub use request::{Request, Response};
pub use service::{Coordinator, CoordinatorStats};
pub use session::{truncate_to_window, SessionManager, Turn};
