//! Byte-level BPE tokenizer — the request-path twin of
//! `python/compile/tokenizer.py`.
//!
//! The two implementations MUST agree token-for-token: Python trains the
//! merges once at build time and emits `artifacts/tokenizer.json` plus
//! encode fixtures; `rust/tests/integration_runtime.rs` replays every
//! fixture through this implementation.

mod bpe;
mod bytes;

pub use bpe::{StreamDecoder, Tokenizer};
pub use bytes::{byte_to_unicode, unicode_to_byte};

/// Pre-tokenize text into BPE word pieces.
///
/// Scanner rules (identical char-class logic in both languages — see the
/// Python docstring):
///  * a run of newlines is one piece;
///  * a run of (space-class) whitespace followed by a word glues to the
///    word (`" hello"` is one piece);
///  * a trailing/isolated whitespace run is its own piece.
///
/// The space class is the explicit set `{' ', '\t', '\r', '\x0b', '\x0c'}`,
/// NOT `char::is_whitespace`, whose semantics differ from Python's
/// `str.isspace` on exotic code points.
pub fn pretokenize(text: &str) -> Vec<&str> {
    let chars: Vec<(usize, char)> = text.char_indices().collect();
    let n = chars.len();
    let mut pieces = Vec::new();
    let mut i = 0;

    let end_of = |idx: usize| -> usize {
        if idx < n {
            chars[idx].0
        } else {
            text.len()
        }
    };

    while i < n {
        let c = chars[i].1;
        if c == '\n' {
            let mut j = i;
            while j < n && chars[j].1 == '\n' {
                j += 1;
            }
            pieces.push(&text[chars[i].0..end_of(j)]);
            i = j;
        } else if is_space(c) {
            let mut j = i;
            while j < n && is_space(chars[j].1) {
                j += 1;
            }
            if j < n && chars[j].1 != '\n' {
                let mut k = j;
                while k < n && !is_space(chars[k].1) && chars[k].1 != '\n' {
                    k += 1;
                }
                pieces.push(&text[chars[i].0..end_of(k)]);
                i = k;
            } else {
                pieces.push(&text[chars[i].0..end_of(j)]);
                i = j;
            }
        } else {
            let mut j = i;
            while j < n && !is_space(chars[j].1) && chars[j].1 != '\n' {
                j += 1;
            }
            pieces.push(&text[chars[i].0..end_of(j)]);
            i = j;
        }
    }
    pieces
}

#[inline]
fn is_space(c: char) -> bool {
    matches!(c, ' ' | '\t' | '\r' | '\u{b}' | '\u{c}')
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pretokenize_basic() {
        assert_eq!(pretokenize("User: hi\nBot: yo"),
                   vec!["User:", " hi", "\n", "Bot:", " yo"]);
    }

    #[test]
    fn pretokenize_concat_identity() {
        let cases = [
            "hello world",
            "  double  spaces ",
            "\n\nnl\n",
            "tabs\tand spaces",
            "",
            " ",
            "\n",
            "unicode café → あ",
            "a \n b",
            "  \n",
        ];
        for c in cases {
            assert_eq!(pretokenize(c).concat(), c, "{c:?}");
        }
    }

    #[test]
    fn space_glues_to_word() {
        assert_eq!(pretokenize("a b"), vec!["a", " b"]);
        assert_eq!(pretokenize("  ab"), vec!["  ab"]);
    }

    #[test]
    fn trailing_space_is_own_piece() {
        assert_eq!(pretokenize("ab  "), vec!["ab", "  "]);
        assert_eq!(pretokenize("ab \n"), vec!["ab", " ", "\n"]);
    }
}
