//! GPT-2's reversible byte <-> printable-unicode table.
//!
//! Every byte value maps to a printable code point so BPE merge symbols are
//! valid unicode strings. Mirrors `bytes_to_unicode()` in the Python side.

use once_cell::sync::Lazy;
use std::collections::HashMap;

static TABLES: Lazy<(Vec<char>, HashMap<char, u8>)> = Lazy::new(|| {
    let mut bs: Vec<u16> = (b'!' as u16..=b'~' as u16)
        .chain(0xa1..=0xac)
        .chain(0xae..=0xff)
        .collect();
    let mut cs: Vec<u32> = bs.iter().map(|&b| b as u32).collect();
    let mut n = 0u32;
    for b in 0u16..256 {
        if !bs.contains(&b) {
            bs.push(b);
            cs.push(256 + n);
            n += 1;
        }
    }
    let mut fwd = vec!['\0'; 256];
    let mut rev = HashMap::new();
    for (&b, &c) in bs.iter().zip(cs.iter()) {
        let ch = char::from_u32(c).unwrap();
        fwd[b as usize] = ch;
        rev.insert(ch, b as u8);
    }
    (fwd, rev)
});

/// Byte -> printable char.
pub fn byte_to_unicode(b: u8) -> char {
    TABLES.0[b as usize]
}

/// Printable char -> byte (None for chars outside the table).
pub fn unicode_to_byte(c: char) -> Option<u8> {
    TABLES.1.get(&c).copied()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bijective() {
        let mut seen = std::collections::HashSet::new();
        for b in 0..=255u8 {
            let c = byte_to_unicode(b);
            assert!(seen.insert(c), "duplicate mapping for byte {b}");
            assert_eq!(unicode_to_byte(c), Some(b));
        }
    }

    #[test]
    fn matches_python_reference_points() {
        // Spot values from the Python table: '!' -> '!', space -> 'Ġ' (U+0120),
        // newline -> 'Ċ' (U+010A).
        assert_eq!(byte_to_unicode(b'!'), '!');
        assert_eq!(byte_to_unicode(b' '), '\u{120}');
        assert_eq!(byte_to_unicode(b'\n'), '\u{10a}');
        assert_eq!(byte_to_unicode(b'A'), 'A');
    }
}
