//! BPE encoder/decoder over a fixed merge list (loaded from
//! `artifacts/tokenizer.json`).

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::util::json;

use super::bytes::{byte_to_unicode, unicode_to_byte};
use super::pretokenize;

/// Vocabulary layout (must match Python): specials, 256 byte symbols, merges.
pub const END_OF_TEXT: &str = "<|endoftext|>";

/// Byte-level BPE tokenizer.
pub struct Tokenizer {
    merges: Vec<(String, String)>,
    rank: HashMap<(String, String), usize>,
    token_to_id: HashMap<String, u32>,
    id_to_token: Vec<String>,
    n_specials: usize,
    /// piece -> ids memo (prompt workloads repeat pieces heavily).
    cache: Mutex<HashMap<String, Vec<u32>>>,
}

impl Tokenizer {
    /// Build from a merge list (order defines merge priority and vocab ids).
    pub fn new(merges: Vec<(String, String)>) -> Self {
        let specials = vec![END_OF_TEXT.to_string()];
        let n_specials = specials.len();
        let mut id_to_token = specials;
        for b in 0..=255u8 {
            id_to_token.push(byte_to_unicode(b).to_string());
        }
        for (a, b) in &merges {
            id_to_token.push(format!("{a}{b}"));
        }
        let token_to_id = id_to_token
            .iter()
            .enumerate()
            .map(|(i, t)| (t.clone(), i as u32))
            .collect();
        let rank = merges
            .iter()
            .enumerate()
            .map(|(i, m)| (m.clone(), i))
            .collect();
        Tokenizer {
            merges,
            rank,
            token_to_id,
            id_to_token,
            n_specials,
            cache: Mutex::new(HashMap::new()),
        }
    }

    /// Load `tokenizer.json` ({"specials": [...], "merges": [[a, b], ...]}).
    pub fn from_json(text: &str) -> Result<Self> {
        let v = json::parse(text)?;
        let merges = v
            .req_arr("merges")?
            .iter()
            .map(|m| {
                let pair = m
                    .as_arr()
                    .filter(|a| a.len() == 2)
                    .ok_or_else(|| Error::Json("merge entry is not a pair".into()))?;
                let a = pair[0]
                    .as_str()
                    .ok_or_else(|| Error::Json("merge lhs not a string".into()))?;
                let b = pair[1]
                    .as_str()
                    .ok_or_else(|| Error::Json("merge rhs not a string".into()))?;
                Ok((a.to_string(), b.to_string()))
            })
            .collect::<Result<Vec<_>>>()?;
        // Sanity: specials must match our layout.
        if let Some(sp) = v.get("specials").and_then(|s| s.as_arr()) {
            if sp.len() != 1 || sp[0].as_str() != Some(END_OF_TEXT) {
                return Err(Error::ManifestInvalid(
                    "tokenizer specials layout mismatch".into(),
                ));
            }
        }
        Ok(Tokenizer::new(merges))
    }

    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::ArtifactMissing(format!("{}: {e}", path.display())))?;
        Self::from_json(&text)
    }

    pub fn vocab_size(&self) -> usize {
        self.id_to_token.len()
    }

    pub fn eot_id(&self) -> u32 {
        0
    }

    pub fn n_merges(&self) -> usize {
        self.merges.len()
    }

    /// Encode text to token ids.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut ids = Vec::new();
        for piece in pretokenize(text) {
            if let Some(cached) = self.cache.lock().unwrap().get(piece) {
                ids.extend_from_slice(cached);
                continue;
            }
            let piece_ids = self.encode_piece(piece);
            ids.extend_from_slice(&piece_ids);
            let mut cache = self.cache.lock().unwrap();
            if cache.len() < 65_536 {
                cache.insert(piece.to_string(), piece_ids);
            }
        }
        ids
    }

    fn encode_piece(&self, piece: &str) -> Vec<u32> {
        let mut word: Vec<String> = piece
            .bytes()
            .map(|b| byte_to_unicode(b).to_string())
            .collect();
        while word.len() > 1 {
            let mut best: Option<(usize, usize)> = None; // (rank, index)
            for i in 0..word.len() - 1 {
                // Avoid cloning: look up by reference via a temporary pair.
                let key = (word[i].clone(), word[i + 1].clone());
                if let Some(&r) = self.rank.get(&key) {
                    if best.map_or(true, |(br, _)| r < br) {
                        best = Some((r, i));
                    }
                }
            }
            match best {
                None => break,
                Some((_, i)) => {
                    let merged = format!("{}{}", word[i], word[i + 1]);
                    word.splice(i..i + 2, [merged]);
                }
            }
        }
        word.iter()
            .map(|t| {
                *self
                    .token_to_id
                    .get(t)
                    .expect("byte-level BPE symbol must be in vocab")
            })
            .collect()
    }

    /// Decode ids to raw bytes (specials and unknown ids are dropped).
    /// Token boundaries need not align with UTF-8 boundaries — this is
    /// the lossless form that [`StreamDecoder`] re-segments incrementally.
    pub fn decode_bytes(&self, ids: &[u32]) -> Vec<u8> {
        let mut bytes = Vec::new();
        for &id in ids {
            let Some(tok) = self.id_to_token.get(id as usize) else {
                continue;
            };
            if (id as usize) < self.n_specials {
                continue;
            }
            for c in tok.chars() {
                if let Some(b) = unicode_to_byte(c) {
                    bytes.push(b);
                }
            }
        }
        bytes
    }

    /// Decode ids back to text (specials are dropped; invalid UTF-8 is
    /// replaced, mirroring Python's errors="replace").
    pub fn decode(&self, ids: &[u32]) -> String {
        String::from_utf8_lossy(&self.decode_bytes(ids)).into_owned()
    }

    /// Token string for an id (debugging / cache explorer).
    pub fn token(&self, id: u32) -> Option<&str> {
        self.id_to_token.get(id as usize).map(|s| s.as_str())
    }
}

/// Incremental per-token decoder for streaming delivery.
///
/// Byte-level BPE token boundaries do not respect UTF-8 boundaries: a
/// multi-byte character can be split across two tokens, so decoding each
/// token independently with `decode` would emit U+FFFD for both halves.
/// `StreamDecoder` holds back a trailing *incomplete* UTF-8 sequence
/// until the bytes that finish it arrive, emitting only whole characters.
/// Genuinely invalid bytes (a sequence no continuation could repair) are
/// replaced with U+FFFD exactly as the whole-sequence decode would.
///
/// The concatenation of `push` outputs equals `decode(ids)` up to a
/// possibly held-back incomplete trailing sequence (which whole-sequence
/// decode lossy-replaces; a stream keeps waiting for it instead).
#[derive(Debug, Default)]
pub struct StreamDecoder {
    hold: Vec<u8>,
}

impl StreamDecoder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Feed one token id; returns the text completed by it (possibly "").
    pub fn push(&mut self, tok: &Tokenizer, id: u32) -> String {
        self.hold.extend_from_slice(&tok.decode_bytes(&[id]));
        let mut out = String::new();
        loop {
            match std::str::from_utf8(&self.hold) {
                Ok(s) => {
                    out.push_str(s);
                    self.hold.clear();
                    return out;
                }
                Err(e) => {
                    let valid = e.valid_up_to();
                    out.push_str(std::str::from_utf8(&self.hold[..valid]).unwrap());
                    match e.error_len() {
                        // Incomplete trailing sequence: hold it for the
                        // next token's bytes.
                        None => {
                            self.hold.drain(..valid);
                            return out;
                        }
                        // Irreparably invalid: replace and keep scanning.
                        Some(bad) => {
                            out.push('\u{FFFD}');
                            self.hold.drain(..valid + bad);
                        }
                    }
                }
            }
        }
    }

    /// Bytes currently held back waiting for a UTF-8 continuation.
    pub fn pending(&self) -> usize {
        self.hold.len()
    }

    /// End-of-stream flush: no continuation is coming, so held-back bytes
    /// are lossy-replaced exactly as whole-sequence `decode` would. With
    /// this appended to the final `push`, the concatenation of a stream's
    /// outputs equals `decode(ids)` *exactly* — the streaming-identity
    /// law the network front promises.
    pub fn flush_lossy(&mut self) -> String {
        if self.hold.is_empty() {
            return String::new();
        }
        let out = String::from_utf8_lossy(&self.hold).into_owned();
        self.hold.clear();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Tokenizer {
        // merges: "h"+"e" -> "he", "he"+"l" -> "hel"
        Tokenizer::new(vec![
            ("h".into(), "e".into()),
            ("he".into(), "l".into()),
        ])
    }

    #[test]
    fn vocab_layout() {
        let t = toy();
        assert_eq!(t.vocab_size(), 1 + 256 + 2);
        assert_eq!(t.eot_id(), 0);
        assert_eq!(t.token(0), Some(END_OF_TEXT));
        // byte tokens follow the specials in byte order: id 1 is byte 0's
        // remapped symbol, id 1 + b'!' is the literal "!".
        assert_eq!(t.token(1 + b'!' as u32), Some("!"));
        assert!(t.token(256).is_some());
    }

    #[test]
    fn merges_apply_in_rank_order() {
        let t = toy();
        let ids = t.encode("hello");
        // "hello" -> he+l merged to "hel", then "l", "o" remain as bytes.
        let toks: Vec<&str> = ids.iter().map(|&i| t.token(i).unwrap()).collect();
        assert_eq!(toks, vec!["hel", "l", "o"]);
    }

    #[test]
    fn roundtrip_ascii_and_unicode() {
        let t = toy();
        for s in ["hello world", "café → あ", "a\nb", "", "  x  ", "\t"] {
            assert_eq!(t.decode(&t.encode(s)), s, "{s:?}");
        }
    }

    #[test]
    fn encode_deterministic_with_cache() {
        let t = toy();
        assert_eq!(t.encode("hello hello"), t.encode("hello hello"));
    }

    #[test]
    fn from_json_roundtrip() {
        let j = r#"{"specials": ["<|endoftext|>"], "merges": [["h","e"],["he","l"]]}"#;
        let t = Tokenizer::from_json(j).unwrap();
        assert_eq!(t.n_merges(), 2);
        assert_eq!(t.decode(&t.encode("hello")), "hello");
    }

    #[test]
    fn from_json_rejects_bad_layout() {
        let j = r#"{"specials": ["<|x|>"], "merges": []}"#;
        assert!(Tokenizer::from_json(j).is_err());
        assert!(Tokenizer::from_json("{").is_err());
        assert!(Tokenizer::from_json(r#"{"merges": [["a"]]}"#).is_err());
    }

    #[test]
    fn stream_decoder_matches_whole_sequence_decode() {
        let t = toy();
        for s in ["hello world", "café → あ", "a\nb", "  x  ", "日本語テスト"] {
            let ids = t.encode(s);
            let mut d = StreamDecoder::new();
            let streamed: String = ids.iter().map(|&id| d.push(&t, id)).collect();
            assert_eq!(streamed, s, "{s:?}");
            assert_eq!(d.pending(), 0, "{s:?}");
        }
    }

    #[test]
    fn stream_decoder_holds_split_multibyte_char() {
        let t = toy();
        // "あ" is 3 UTF-8 bytes; with no merges each byte is its own token.
        let ids = t.encode("あ");
        assert_eq!(ids.len(), 3);
        let mut d = StreamDecoder::new();
        assert_eq!(d.push(&t, ids[0]), "");
        assert_eq!(d.pending(), 1);
        assert_eq!(d.push(&t, ids[1]), "");
        assert_eq!(d.push(&t, ids[2]), "あ");
        assert_eq!(d.pending(), 0);
    }

    #[test]
    fn stream_decoder_replaces_invalid_bytes() {
        let t = toy();
        let mut d = StreamDecoder::new();
        // A lone continuation byte can never become valid UTF-8.
        assert_eq!(d.push(&t, 1 + 0x80), "\u{FFFD}");
        // An incomplete lead byte is held — until a non-continuation
        // proves it irreparable.
        assert_eq!(d.push(&t, 1 + 0xE3), "");
        assert_eq!(d.push(&t, 1 + b'a' as u32), "\u{FFFD}a");
        assert_eq!(d.pending(), 0);
    }

    #[test]
    fn prefix_stability() {
        // the paper's prefix condition at the tokenizer level
        let t = toy();
        let a = t.encode("What is the capital of France?");
        let b = t.encode("What is the capital of France? Also mention more.");
        assert_eq!(&b[..a.len()], &a[..]);
    }
}
