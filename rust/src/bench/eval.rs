//! The paper's evaluation protocol as a reusable harness.
//!
//! `run_comparison` executes the two-arm §4.4 loop — a baseline pass
//! (recycling off) and a recycled pass (cache warmed from the cache
//! prompts) over the same test prompts — then merges rows by prompt text
//! into the §5.1 summary.

use std::path::Path;

use crate::config::{CacheConfig, ModelConfig};
use crate::engine::{Engine, ForwardModel};
use crate::error::Result;
use crate::index::NgramEmbedder;
use crate::metrics::{self, Comparison, RequestRow};
use crate::recycler::{RecyclePolicy, Recycler};
use crate::sim::fit_alpha;
use crate::tokenizer::Tokenizer;

use super::workload::Workload;

/// Options for an evaluation run.
#[derive(Debug, Clone)]
pub struct EvalOptions {
    pub max_new_tokens: usize,
    pub policy: RecyclePolicy,
    pub cache: CacheConfig,
    /// Where to write baseline.csv / recycled.csv (None = don't write).
    pub results_dir: Option<std::path::PathBuf>,
    /// Timing repetitions per prompt per arm; the reported latency is the
    /// median (the paper timed single-shot, which is noisy on small
    /// prompts; medians keep the same expectation with lower variance).
    pub reps: usize,
}

impl Default for EvalOptions {
    fn default() -> Self {
        EvalOptions {
            max_new_tokens: 32,
            policy: RecyclePolicy::Strict,
            cache: CacheConfig::default(),
            results_dir: None,
            reps: 3,
        }
    }
}

/// Everything the paper's §5 reports, for one workload.
#[derive(Debug)]
pub struct ComparisonReport {
    pub baseline_rows: Vec<RequestRow>,
    pub recycled_rows: Vec<RequestRow>,
    pub comparison: Comparison,
    /// (k, m, speedup_fraction) samples for the §5.5 α fit.
    pub speedup_samples: Vec<(usize, usize, f64)>,
    pub alpha: f64,
}

impl ComparisonReport {
    /// Output fidelity of the recycled arm: the mean baseline-vs-recycled
    /// output similarity across the workload (1.0 = token-identical).
    /// This is the gate a lossy cache representation (quantized hot
    /// blocks) must clear before its capacity win counts.
    pub fn fidelity(&self) -> f64 {
        self.comparison.avg_output_similarity()
    }

    /// Whether the recycled arm's outputs are faithful enough to trust.
    /// Fails closed: an empty workload or NaN similarity is *not* a pass.
    pub fn passes_fidelity(&self, min: f64) -> bool {
        let f = self.fidelity();
        f.is_finite() && f >= min
    }

    /// Render the §5.1 summary table rows (same metrics, same order).
    pub fn summary_rows(&self) -> Vec<(&'static str, String)> {
        let c = &self.comparison;
        let (hit_speedup, miss_speedup) = c.avg_speedup_split(&self.recycled_rows);
        vec![
            ("Total Prompts", format!("{}", c.total_prompts)),
            (
                "Cache Hits",
                format!(
                    "{}/{} ({:.1}%)",
                    c.cache_hits,
                    c.total_prompts,
                    100.0 * c.cache_hits as f64 / c.total_prompts.max(1) as f64
                ),
            ),
            ("Total Tokens Reused", format!("{:.1}", c.total_tokens_reused as f64)),
            ("Overall Average Speedup", format!("{:.2}%", c.avg_speedup_pct())),
            ("Average Speedup (with cache)", format!("{hit_speedup:.2}%")),
            ("Average Speedup (no cache)", format!("{miss_speedup:.2}%")),
            ("Average Output Similarity", format!("{:.3}", c.avg_output_similarity())),
            ("Average Prompt Similarity", format!("{:.3}", c.avg_prompt_similarity())),
            (
                "High Similarity Prompts (>0.8)",
                format!("{}/{}", c.high_similarity_count(0.8), c.total_prompts),
            ),
            ("Latency Baseline Average", format!("{:.4}s", c.latency_baseline.mean())),
            ("Latency Recycled Average", format!("{:.4}s", c.latency_recycled.mean())),
        ]
    }
}

/// Build a recycler with the standard evaluation stack.
pub fn eval_recycler<M: ForwardModel>(
    model: M,
    tokenizer: std::sync::Arc<Tokenizer>,
    opts: &EvalOptions,
    policy: RecyclePolicy,
) -> Recycler<M> {
    let mut r = Recycler::new(
        Engine::new(model),
        tokenizer,
        Box::new(NgramEmbedder::new(128)),
        opts.cache.clone(),
        policy,
    );
    // The paper builds the cache in a dedicated pass; the evaluation arms
    // don't additionally populate online (keeps the two arms comparable).
    r.populate_cache = false;
    r
}

/// Run the full §4.4 baseline-vs-recycled protocol.
///
/// `mk_model` builds a fresh model per arm (the two arms must not share
/// engine state).
pub fn run_comparison<M: ForwardModel>(
    mut mk_model: impl FnMut() -> M,
    tokenizer: std::sync::Arc<Tokenizer>,
    workload: &Workload,
    opts: &EvalOptions,
) -> Result<ComparisonReport> {
    let reps = opts.reps.max(1);
    let median_run = |r: &mut Recycler<M>, p: &str| -> Result<crate::recycler::Outcome> {
        let mut outs = Vec::with_capacity(reps);
        for _ in 0..reps {
            outs.push(r.generate(p, opts.max_new_tokens)?);
        }
        outs.sort_by(|a, b| a.latency_s.partial_cmp(&b.latency_s).unwrap());
        Ok(outs.swap_remove(reps / 2))
    };

    // --- arm 1: baseline ---
    let mut baseline = eval_recycler(mk_model(), tokenizer.clone(), opts, RecyclePolicy::Off);
    let mut baseline_rows = Vec::new();
    for p in &workload.test_prompts {
        let out = median_run(&mut baseline, p)?;
        baseline_rows.push(out.to_row(p));
    }

    // --- arm 2: recycled (warm cache first, §4.4 cache construction) ---
    let mut recycled = eval_recycler(mk_model(), tokenizer.clone(), opts, opts.policy);
    let cache_refs: Vec<&str> = workload.cache_prompts.iter().map(|s| s.as_str()).collect();
    recycled.warm(&cache_refs)?;
    let mut recycled_rows = Vec::new();
    for p in &workload.test_prompts {
        let out = median_run(&mut recycled, p)?;
        recycled_rows.push(out.to_row(p));
    }

    // --- merge (paper §5.1) ---
    let comparison = Comparison::merge(&baseline_rows, &recycled_rows, |a, b| {
        recycled.text_similarity(a, b)
    });

    let mut speedup_samples = Vec::new();
    for (b, r) in baseline_rows.iter().zip(&recycled_rows) {
        if r.cache_hit {
            let s = (b.latency_s - r.latency_s) / b.latency_s;
            speedup_samples.push((r.reused_tokens, r.prompt_tokens, s));
        }
    }
    let alpha = fit_alpha(&speedup_samples);

    if let Some(dir) = &opts.results_dir {
        metrics::write_rows(&dir.join("baseline.csv"), &baseline_rows)?;
        metrics::write_rows(&dir.join("recycled.csv"), &recycled_rows)?;
    }

    Ok(ComparisonReport {
        baseline_rows,
        recycled_rows,
        comparison,
        speedup_samples,
        alpha,
    })
}

/// Convenience: load the nano config + artifact tokenizer when present,
/// else a merge-free tokenizer (tests).
pub fn tokenizer_or_fallback(artifacts_dir: &Path) -> std::sync::Arc<Tokenizer> {
    let path = artifacts_dir.join("tokenizer.json");
    match Tokenizer::from_file(&path) {
        Ok(t) => std::sync::Arc::new(t),
        Err(_) => std::sync::Arc::new(Tokenizer::new(vec![])),
    }
}

/// The nano model config (artifact manifest when present, else built-in).
pub fn config_or_fallback(artifacts_dir: &Path) -> ModelConfig {
    crate::runtime::Manifest::load(artifacts_dir)
        .map(|m| m.model)
        .unwrap_or_else(|_| ModelConfig::nano())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workload::{overlap_workload, OverlapSpec};
    use crate::testutil::MockModel;
    use std::time::Duration;

    fn mock() -> MockModel {
        // measurable per-token encode cost so speedups are visible
        MockModel::with_delay(ModelConfig::nano(), Duration::from_micros(120))
    }

    #[test]
    fn comparison_on_full_overlap_workload() {
        let w = overlap_workload(OverlapSpec {
            pairs: 4,
            prefix_words: 12,
            suffix_words: 3,
            miss_rate: 0.0,
            seed: 1,
        });
        let tok = std::sync::Arc::new(Tokenizer::new(vec![]));
        let report = run_comparison(mock, tok, &w, &EvalOptions {
            max_new_tokens: 4,
            ..Default::default()
        })
        .unwrap();
        let c = &report.comparison;
        assert_eq!(c.total_prompts, 4);
        assert_eq!(c.cache_hits, 4, "full-overlap workload must hit 4/4");
        assert!(c.total_tokens_reused > 0);
        // recycled must be faster on average with the delay model
        assert!(c.latency_recycled.mean() < c.latency_baseline.mean());
        assert!(c.avg_speedup_pct() > 0.0);
        // greedy + exact KV -> outputs identical -> similarity 1.0
        assert!(c.avg_output_similarity() > 0.999);
        assert!(report.alpha.is_finite() && report.alpha > 0.0);
        // the fidelity gate reads the same similarity and must pass here
        assert!(report.passes_fidelity(0.999));
        assert!(!report.passes_fidelity(1.01), "gate must not pass above its own score");
    }

    #[test]
    fn fidelity_gate_fails_closed_on_empty_workload() {
        // no prompts -> similarity mean is NaN -> the gate must refuse
        let report = ComparisonReport {
            baseline_rows: vec![],
            recycled_rows: vec![],
            comparison: Comparison::merge(&[], &[], |_, _| 0.0),
            speedup_samples: vec![],
            alpha: 0.0,
        };
        assert!(!report.fidelity().is_finite() || report.fidelity() == 0.0);
        assert!(!report.passes_fidelity(0.5));
    }

    #[test]
    fn comparison_on_miss_workload_matches_baseline() {
        let w = overlap_workload(OverlapSpec {
            pairs: 4,
            prefix_words: 8,
            suffix_words: 3,
            miss_rate: 1.0,
            seed: 2,
        });
        let tok = std::sync::Arc::new(Tokenizer::new(vec![]));
        let report = run_comparison(mock, tok, &w, &EvalOptions {
            max_new_tokens: 4,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(report.comparison.cache_hits, 0);
        // outputs identical (same model, no cache effect)
        for (b, r) in report.baseline_rows.iter().zip(&report.recycled_rows) {
            assert_eq!(b.output, r.output);
        }
    }

    #[test]
    fn summary_rows_have_paper_shape() {
        let w = overlap_workload(OverlapSpec {
            pairs: 2,
            prefix_words: 6,
            suffix_words: 2,
            miss_rate: 0.0,
            seed: 3,
        });
        let tok = std::sync::Arc::new(Tokenizer::new(vec![]));
        let report =
            run_comparison(mock, tok, &w, &EvalOptions::default()).unwrap();
        let rows = report.summary_rows();
        assert_eq!(rows.len(), 11, "the paper's table has 11 rows");
        assert_eq!(rows[0].0, "Total Prompts");
        assert_eq!(rows[10].0, "Latency Recycled Average");
    }
}
