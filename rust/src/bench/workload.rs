//! Workload generation.
//!
//! * The paper's exact prompt sets (§4.3): 10 cache prompts + 6 test
//!   prompts, loaded from `data/*.csv` when present, with the same
//!   built-in constants as fallback (they're written by the artifact
//!   build from the same source of truth).
//! * Synthetic overlap workloads with a controlled k/m ratio for the §5.5
//!   sweep and the ablations.
//! * A seeded **multi-tenant serving trace** ([`multi_tenant_trace`]):
//!   bursty arrivals, heavy-tailed tenant popularity and session reuse,
//!   mixed prompt lengths — the shared input of the sharding ablation
//!   bench and the routing-invariance property suite, so both exercise
//!   the same traffic shape.

use std::path::Path;

use crate::util::csv;
use crate::util::rng::Rng;

/// A cache-prompts + test-prompts pair.
#[derive(Debug, Clone)]
pub struct Workload {
    pub cache_prompts: Vec<String>,
    pub test_prompts: Vec<String>,
}

const PAPER_CACHE: [&str; 10] = [
    "Explain machine learning in simple terms.",
    "What is the capital of France?",
    "How do airplanes fly?",
    "What is deep learning?",
    "Explain gravity in simple terms.",
    "How do boats float?",
    "What is the capital of Japan?",
    "Explain photosynthesis in simple terms.",
    "How do rockets launch?",
    "What is a cache?",
];

const PAPER_TEST: [&str; 6] = [
    "Explain machine learning in simple terms. Give an example application.",
    "What is the capital of France? Also mention a nearby tourist destination.",
    "How do airplanes fly? Keep the answer short.",
    "What is deep learning? Compare it with machine learning.",
    "Explain gravity in simple terms. Why does the moon stay in orbit?",
    "What is a cache? Why do browsers use one?",
];

fn load_or(path: &Path, fallback: &[&str]) -> Vec<String> {
    csv::read_single_column(path)
        .unwrap_or_else(|_| fallback.iter().map(|s| s.to_string()).collect())
}

/// The paper's 10 cache prompts (data/cache_prompts.csv when available).
pub fn paper_cache_prompts(data_dir: &Path) -> Vec<String> {
    load_or(&data_dir.join("cache_prompts.csv"), &PAPER_CACHE)
}

/// The paper's 6 test prompts (data/test_prompts.csv when available).
pub fn paper_test_prompts(data_dir: &Path) -> Vec<String> {
    load_or(&data_dir.join("test_prompts.csv"), &PAPER_TEST)
}

/// Parameters for a synthetic overlap workload.
#[derive(Debug, Clone, Copy)]
pub struct OverlapSpec {
    /// Number of (cache, test) prompt pairs.
    pub pairs: usize,
    /// Words in the shared prefix (≈ reuse depth k in tokens).
    pub prefix_words: usize,
    /// Extra words appended to the test prompt (m - k).
    pub suffix_words: usize,
    /// Fraction of test prompts that should NOT match any cache prompt.
    pub miss_rate: f64,
    pub seed: u64,
}

const WORDS: [&str; 32] = [
    "signal", "engine", "garden", "window", "planet", "cache", "memory",
    "token", "river", "mountain", "bridge", "circuit", "market", "forest",
    "needle", "harbor", "crystal", "lantern", "meadow", "rocket", "anchor",
    "compass", "granite", "whistle", "violet", "thunder", "saddle", "ribbon",
    "copper", "marble", "falcon", "ember",
];

fn sentence(rng: &mut Rng, words: usize) -> String {
    (0..words)
        .map(|_| *rng.choice(&WORDS))
        .collect::<Vec<_>>()
        .join(" ")
}

/// Build a workload where each test prompt extends its cache prompt by
/// `suffix_words` (hit) or is freshly random (miss).
pub fn overlap_workload(spec: OverlapSpec) -> Workload {
    let mut rng = Rng::new(spec.seed);
    let mut cache_prompts = Vec::with_capacity(spec.pairs);
    let mut test_prompts = Vec::with_capacity(spec.pairs);
    for i in 0..spec.pairs {
        let prefix = format!("q{i} {}", sentence(&mut rng, spec.prefix_words));
        cache_prompts.push(prefix.clone());
        if rng.chance(spec.miss_rate) {
            test_prompts.push(format!("z{i} {}", sentence(&mut rng,
                spec.prefix_words + spec.suffix_words)));
        } else {
            test_prompts.push(format!("{prefix} {}", sentence(&mut rng, spec.suffix_words)));
        }
    }
    Workload {
        cache_prompts,
        test_prompts,
    }
}

/// One request in a seeded multi-tenant serving trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRequest {
    /// Arrival slot: requests sharing a slot arrive back-to-back (a
    /// burst); consumers map slots to scheduler ticks or submission
    /// rounds as they see fit. Nondecreasing across the trace.
    pub at_tick: usize,
    /// Issuing tenant. One tenant = one stable prompt-template prefix =
    /// one prefix family under affinity routing.
    pub tenant: usize,
    /// `Some` for a turn of a multi-turn session, `None` for a one-shot.
    pub session: Option<String>,
    pub prompt: String,
    pub max_new_tokens: usize,
}

/// Knobs for [`multi_tenant_trace`].
#[derive(Debug, Clone, Copy)]
pub struct TraceSpec {
    /// Distinct tenants (prompt-template prefix families). Popularity is
    /// heavy-tailed: low tenant ids issue most of the traffic.
    pub tenants: usize,
    /// Total requests in the trace.
    pub requests: usize,
    /// Mean arrivals per burst; actual burst sizes are uniform in
    /// `1..=2*mean_burst`, separated by multi-slot gaps (bursty, not
    /// Poisson-smooth).
    pub mean_burst: usize,
    /// Probability a request continues an existing session rather than
    /// opening new work. Continuations prefer recently-active sessions
    /// (heavy-tailed reuse), like real chat traffic.
    pub session_reuse: f64,
    /// Prompt body length bounds in words — mixed short and long prompts
    /// in one trace.
    pub min_words: usize,
    pub max_words: usize,
    pub max_new_tokens: usize,
    pub seed: u64,
}

impl Default for TraceSpec {
    fn default() -> Self {
        TraceSpec {
            tenants: 4,
            requests: 64,
            mean_burst: 4,
            session_reuse: 0.4,
            min_words: 4,
            max_words: 24,
            max_new_tokens: 8,
            seed: 0xC0FFEE,
        }
    }
}

/// Generate a deterministic multi-tenant serving trace (see [`TraceSpec`]).
/// Same spec -> byte-identical trace, so ablation numbers and property
/// shrinks are reproducible from the printed seed alone.
pub fn multi_tenant_trace(spec: TraceSpec) -> Vec<TraceRequest> {
    assert!(spec.tenants > 0 && spec.requests > 0);
    assert!(spec.min_words > 0 && spec.max_words >= spec.min_words);
    let mut rng = Rng::new(spec.seed);
    // Stable per-tenant template prefixes, longer than any router
    // fingerprint window: every request of a tenant starts with its
    // template, so a tenant is exactly one prefix family.
    let templates: Vec<String> = (0..spec.tenants)
        .map(|t| format!("tenant {t:03} standing instructions: {}.", sentence(&mut rng, 8)))
        .collect();
    let mut out = Vec::with_capacity(spec.requests);
    // (session id, owning tenant), most recently active last.
    let mut sessions: Vec<(String, usize)> = Vec::new();
    let mut tick = 0usize;
    let mut left_in_burst = 1 + rng.below(spec.mean_burst.max(1) * 2);
    for i in 0..spec.requests {
        if left_in_burst == 0 {
            tick += 1 + rng.below(4); // inter-burst gap
            left_in_burst = 1 + rng.below(spec.mean_burst.max(1) * 2);
        }
        left_in_burst -= 1;
        let body_words = rng.range(spec.min_words, spec.max_words + 1);
        let (tenant, session, prompt) = if !sessions.is_empty()
            && rng.chance(spec.session_reuse)
        {
            // Heavy-tailed continuation: cubing the uniform draw piles
            // the mass onto the most recently active sessions.
            let n = sessions.len();
            let back = ((n as f64) * rng.f64().powi(3)) as usize % n;
            let idx = n - 1 - back;
            let (id, t) = sessions.remove(idx);
            sessions.push((id.clone(), t));
            (t, Some(id), sentence(&mut rng, body_words))
        } else {
            // heavy-tailed tenant popularity: low ids dominate
            let t = (((spec.tenants as f64) * rng.f64().powi(2)) as usize)
                % spec.tenants;
            let prompt = format!("{} {}", templates[t], sentence(&mut rng, body_words));
            if rng.chance(0.5) {
                let id = format!("s{i:04}");
                sessions.push((id.clone(), t));
                (t, Some(id), prompt)
            } else {
                (t, None, prompt)
            }
        };
        out.push(TraceRequest {
            at_tick: tick,
            tenant,
            session,
            prompt,
            max_new_tokens: spec.max_new_tokens,
        });
    }
    out
}

/// Multi-turn user messages for the session/e2e demo.
pub fn session_workload(turns: usize, seed: u64) -> Vec<String> {
    let mut rng = Rng::new(seed);
    let questions = [
        "What is the capital of France?",
        "How do airplanes fly?",
        "Explain machine learning in simple terms.",
        "What is a cache?",
        "How do boats float?",
        "Explain gravity in simple terms.",
    ];
    (0..turns).map(|_| rng.choice(&questions).to_string()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_sets_have_paper_sizes() {
        let dir = Path::new("definitely-not-a-dir");
        assert_eq!(paper_cache_prompts(dir).len(), 10);
        assert_eq!(paper_test_prompts(dir).len(), 6);
    }

    #[test]
    fn every_paper_test_prompt_extends_a_cache_prompt() {
        let dir = Path::new("definitely-not-a-dir");
        let cache = paper_cache_prompts(dir);
        for t in paper_test_prompts(dir) {
            assert!(
                cache.iter().any(|c| t.starts_with(c.as_str()) && t.len() > c.len()),
                "{t}"
            );
        }
    }

    #[test]
    fn overlap_workload_hits_share_prefix() {
        let w = overlap_workload(OverlapSpec {
            pairs: 20,
            prefix_words: 8,
            suffix_words: 4,
            miss_rate: 0.0,
            seed: 3,
        });
        for (c, t) in w.cache_prompts.iter().zip(&w.test_prompts) {
            assert!(t.starts_with(c.as_str()));
            assert!(t.len() > c.len());
        }
    }

    #[test]
    fn overlap_workload_misses_diverge() {
        let w = overlap_workload(OverlapSpec {
            pairs: 30,
            prefix_words: 6,
            suffix_words: 3,
            miss_rate: 1.0,
            seed: 4,
        });
        for (c, t) in w.cache_prompts.iter().zip(&w.test_prompts) {
            assert!(!t.starts_with(c.as_str()));
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let spec = OverlapSpec {
            pairs: 5,
            prefix_words: 5,
            suffix_words: 2,
            miss_rate: 0.5,
            seed: 9,
        };
        let a = overlap_workload(spec);
        let b = overlap_workload(spec);
        assert_eq!(a.test_prompts, b.test_prompts);
    }

    #[test]
    fn trace_is_deterministic_by_seed() {
        let spec = TraceSpec::default();
        assert_eq!(multi_tenant_trace(spec), multi_tenant_trace(spec));
        let other = TraceSpec { seed: 1, ..spec };
        assert_ne!(multi_tenant_trace(spec), multi_tenant_trace(other));
    }

    #[test]
    fn trace_arrivals_are_bursty_and_ordered() {
        let trace = multi_tenant_trace(TraceSpec {
            requests: 200,
            ..Default::default()
        });
        assert_eq!(trace.len(), 200);
        // nondecreasing arrival slots
        for w in trace.windows(2) {
            assert!(w[1].at_tick >= w[0].at_tick);
        }
        // bursty: some slot holds several arrivals AND some gap > 1 exists
        let mut per_slot = std::collections::HashMap::new();
        for r in &trace {
            *per_slot.entry(r.at_tick).or_insert(0usize) += 1;
        }
        assert!(per_slot.values().any(|&n| n >= 2), "no bursts generated");
        assert!(
            trace.windows(2).any(|w| w[1].at_tick > w[0].at_tick + 1),
            "no inter-burst gaps generated"
        );
    }

    #[test]
    fn trace_tenants_share_template_prefixes() {
        let trace = multi_tenant_trace(TraceSpec {
            requests: 200,
            ..Default::default()
        });
        // fresh (non-continuation) requests of one tenant share a long
        // common prefix — the prefix family affinity routing keys on
        let mut by_tenant: std::collections::HashMap<usize, Vec<&str>> =
            std::collections::HashMap::new();
        for r in trace.iter().filter(|r| r.prompt.starts_with("tenant ")) {
            by_tenant.entry(r.tenant).or_default().push(&r.prompt);
        }
        let mut checked = 0;
        for (_, prompts) in by_tenant {
            if prompts.len() < 2 {
                continue;
            }
            let shared = prompts[0]
                .bytes()
                .zip(prompts[1].bytes())
                .take_while(|(a, b)| a == b)
                .count();
            assert!(shared > 32, "template prefix too short: {shared} bytes");
            checked += 1;
        }
        assert!(checked >= 2, "trace never reused a tenant template");
    }

    #[test]
    fn trace_reuses_sessions_heavy_tailed() {
        let trace = multi_tenant_trace(TraceSpec {
            requests: 200,
            session_reuse: 0.6,
            ..Default::default()
        });
        let mut turns: std::collections::HashMap<&str, usize> =
            std::collections::HashMap::new();
        for r in &trace {
            if let Some(s) = &r.session {
                *turns.entry(s.as_str()).or_insert(0) += 1;
            }
        }
        assert!(
            turns.values().any(|&n| n >= 3),
            "no session accumulated multiple turns: {turns:?}"
        );
        // one-shots coexist with sessions (mixed traffic)
        assert!(trace.iter().any(|r| r.session.is_none()));
    }

    #[test]
    fn trace_mixes_prompt_lengths() {
        let trace = multi_tenant_trace(TraceSpec {
            requests: 200,
            min_words: 4,
            max_words: 24,
            ..Default::default()
        });
        let lengths: std::collections::HashSet<usize> =
            trace.iter().map(|r| r.prompt.split(' ').count()).collect();
        assert!(lengths.len() > 5, "prompt lengths not mixed: {lengths:?}");
    }
}
